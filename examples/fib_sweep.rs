//! Figure-5 sweep: fibonacci gain from bubbles on both paper machines.
//!
//! ```sh
//! cargo run --release --example fib_sweep            # full sweep
//! cargo run --release --example fib_sweep -- --quick # CI-sized
//! ```

use bubbles::apps::fib::FibParams;
use bubbles::experiments::fig5;
use bubbles::topology::Topology;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let counts: Vec<usize> = if quick {
        vec![4, 16, 64]
    } else {
        fig5::default_thread_counts()
    };
    println!("Figure 5 — gain (%) of bubbles over the classical scheduler");
    println!("(paper: (a) HT Xeon stabilises at 30-40% from 16 threads;");
    println!("        (b) NUMA 4x4 Itanium: 40% @ 32 threads, ~80% @ 512)\n");
    for topo in [Topology::xeon_2x_ht(), Topology::numa(4, 4)] {
        let series = fig5::run(&topo, &counts, &FibParams::default());
        println!("{}", series.render());
    }
}
