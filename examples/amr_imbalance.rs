//! The paper's stated future workload (§5.2): AMR-style imbalance, and
//! the §3.3.3 terminal-imbalance scenario where bubble rebalancing
//! earns its keep — plus the §3.4 ping-pong caveat, measured.
//!
//! ```sh
//! cargo run --release --example amr_imbalance [-- --quick]
//! ```

use bubbles::apps::amr::{self, AmrParams, SkewParams};
use bubbles::apps::StructureMode;
use bubbles::experiments::ablations;
use bubbles::topology::Topology;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let topo = Topology::numa(4, 4);
    let p = AmrParams {
        cycles: if quick { 8 } else { 24 },
        redraw_every: if quick { 4 } else { 6 },
        ..Default::default()
    };

    println!("== AMR imbalance (barrier-coupled cycles) on {} ==", topo.name());
    println!("stripes: {}, heavy-tail shape: {}\n", p.threads, p.shape);
    for mode in [StructureMode::Simple, StructureMode::Bound, StructureMode::Bubbles] {
        let rep = amr::run(&topo, mode, &p);
        println!(
            "{:<10} makespan {:>12} cycles  utilisation {:.3}",
            mode.label(),
            rep.total_time,
            rep.utilisation()
        );
    }

    println!("\n== Terminal imbalance (§3.3.3): heavy group outlives the rest ==");
    println!("{}", ablations::regeneration_skewed(&topo, &SkewParams::default()).render());
    println!(
        "note: 'idle regeneration' alone moves whole bubbles and cannot split\n\
         one heavy group — the §3.4 ping-pong caveat, measured. Thread steal\n\
         (tried first by the bubble scheduler) is what fills idle nodes."
    );

    println!("\n== Regeneration on barrier-coupled cycles (§3.4 caveat) ==");
    println!("{}", ablations::regeneration(&topo, &p).render());
}
