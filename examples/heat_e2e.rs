//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! A 64×256 heat-conduction mesh is split into 16 stripes. Each stripe
//! is a *green thread* (user-level fiber) scheduled by the bubble
//! scheduler (or a baseline) over worker OS threads; each iteration the
//! thread executes the **AOT-compiled Pallas stencil kernel** through
//! the PJRT runtime, then crosses a native barrier (halo exchange).
//! Python never runs here — the artifacts were compiled by
//! `make artifacts`.
//!
//! Correctness: the final mesh is compared against a sequential
//! whole-mesh run via the AOT residual kernel.
//!
//! ```sh
//! cargo run --release --example heat_e2e -- --iters 100
//! ```

use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

use bubbles::apps::{scheduler_for, StructureMode};
use bubbles::exec::Executor;
use bubbles::marcel::Marcel;
use bubbles::runtime::service::PjrtService;
use bubbles::sched::System;
use bubbles::topology::Topology;

const ROWS: usize = 64;
const COLS: usize = 256;
const STRIPES: usize = 16;
const STRIPE_H: usize = ROWS / STRIPES;
const ALPHA: f32 = 0.2;

fn initial_mesh() -> Vec<f32> {
    // A hot square in a cold field.
    let mut mesh = vec![0.0f32; ROWS * COLS];
    for r in 24..40 {
        for c in 96..160 {
            mesh[r * COLS + c] = 100.0;
        }
    }
    mesh
}

/// Stripe + halo rows from a mesh snapshot.
fn stripe_with_halo(mesh: &[f32], s: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity((STRIPE_H + 2) * COLS);
    let top = if s == 0 { 0 } else { s * STRIPE_H - 1 };
    out.extend_from_slice(&mesh[top * COLS..(top + 1) * COLS]);
    out.extend_from_slice(&mesh[s * STRIPE_H * COLS..(s + 1) * STRIPE_H * COLS]);
    let bot = if s == STRIPES - 1 { ROWS - 1 } else { (s + 1) * STRIPE_H };
    out.extend_from_slice(&mesh[bot * COLS..(bot + 1) * COLS]);
    out
}

#[allow(clippy::too_many_arguments)]
fn register_stripe(
    ex: &mut Executor,
    task: bubbles::task::TaskId,
    s: usize,
    svc: &PjrtService,
    bufs: &Arc<[Mutex<Vec<f32>>; 2]>,
    bar: usize,
    iters: usize,
) {
    let h = svc.handle();
    let bufs = bufs.clone();
    ex.register(task, move |api| {
        for it in 0..iters {
            let input = {
                let cur = bufs[it % 2].lock().unwrap();
                stripe_with_halo(&cur, s)
            };
            let out = h
                .exec(
                    &format!("conduction_r{STRIPE_H}_c{COLS}"),
                    vec![(input, vec![STRIPE_H + 2, COLS]), (vec![ALPHA], vec![1])],
                )
                .expect("stencil exec");
            {
                let mut next = bufs[(it + 1) % 2].lock().unwrap();
                next[s * STRIPE_H * COLS..(s + 1) * STRIPE_H * COLS].copy_from_slice(&out);
            }
            api.barrier(bar);
        }
    });
}

/// One parallel run under a structure mode; returns (wall, migrations,
/// final mesh).
fn run_mode(
    mode: StructureMode,
    svc: &PjrtService,
    iters: usize,
) -> (std::time::Duration, u64, Vec<f32>) {
    let topo = Topology::numa(4, 4);
    let sys = Arc::new(System::new(Arc::new(topo)));
    let sched = scheduler_for(mode);
    let m = Marcel::with_system(&sys);
    let mut ex = Executor::new(sys.clone(), sched.clone());
    // Double-buffered mesh shared by all stripes.
    let bufs: Arc<[Mutex<Vec<f32>>; 2]> =
        Arc::new([Mutex::new(initial_mesh()), Mutex::new(initial_mesh())]);
    let bar = ex.alloc_barrier(STRIPES);

    // Structure: per-NUMA-node bubbles (Bubbles mode) or loose threads.
    let names: Vec<String> = (0..STRIPES).map(|i| format!("stripe{i}")).collect();
    match mode {
        StructureMode::Bubbles => {
            let (root, threads) = m.bubbles_from_topology(&names);
            for (s, &t) in threads.iter().enumerate() {
                register_stripe(&mut ex, t, s, svc, &bufs, bar, iters);
            }
            sched.wake(&sys, root);
        }
        _ => {
            for (s, name) in names.iter().enumerate() {
                let t = m.create_dontsched(name.clone());
                register_stripe(&mut ex, t, s, svc, &bufs, bar, iters);
                sched.wake(&sys, t);
            }
        }
    }
    let rep = ex.run();
    let final_mesh = bufs[iters % 2].lock().unwrap().clone();
    let migrations = sys.metrics.migrations.load(Ordering::Relaxed);
    (rep.elapsed, migrations, final_mesh)
}

/// Sequential whole-mesh reference through the same artifacts.
fn run_sequential(svc: &PjrtService, iters: usize) -> (std::time::Duration, Vec<f32>) {
    let t0 = std::time::Instant::now();
    let h = svc.handle();
    let mut mesh = initial_mesh();
    for _ in 0..iters {
        // Whole mesh as one stripe (r64 artifact) with replicated halo.
        let mut input = Vec::with_capacity((ROWS + 2) * COLS);
        input.extend_from_slice(&mesh[..COLS]);
        input.extend_from_slice(&mesh);
        input.extend_from_slice(&mesh[(ROWS - 1) * COLS..]);
        mesh = h
            .exec(
                &format!("conduction_r{ROWS}_c{COLS}"),
                vec![(input, vec![ROWS + 2, COLS]), (vec![ALPHA], vec![1])],
            )
            .expect("sequential exec");
    }
    (t0.elapsed(), mesh)
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let iters: usize = args
        .iter()
        .position(|a| a == "--iters")
        .and_then(|i| args.get(i + 1))
        .and_then(|s| s.parse().ok())
        .unwrap_or(100);

    let svc = PjrtService::start_default().expect("run `make artifacts` first");
    println!("heat_e2e: {ROWS}x{COLS} mesh, {STRIPES} stripes, {iters} iterations");
    println!("payload: AOT Pallas stencil via PJRT CPU; python not involved\n");

    let (seq_wall, reference) = run_sequential(&svc, iters);
    println!("sequential whole-mesh reference: {:.1} ms", seq_wall.as_secs_f64() * 1e3);

    let h = svc.handle();
    println!(
        "\n{:<10} {:>12} {:>12} {:>16}",
        "mode", "wall (ms)", "migrations", "max|mesh-ref|"
    );
    for mode in [StructureMode::Simple, StructureMode::Bound, StructureMode::Bubbles] {
        let (wall, migrations, mesh) = run_mode(mode, &svc, iters);
        // Residual against the sequential reference (AOT kernel too).
        let res = h
            .exec(
                &format!("residual_r{ROWS}_c{COLS}"),
                vec![(mesh, vec![ROWS, COLS]), (reference.clone(), vec![ROWS, COLS])],
            )
            .expect("residual");
        println!(
            "{:<10} {:>12.1} {:>12} {:>16.2e}",
            mode.label(),
            wall.as_secs_f64() * 1e3,
            migrations,
            res[0]
        );
        assert!(res[0] < 1e-3, "{} diverged from the reference", mode.label());
    }
    println!("\nall modes numerically match the sequential whole-mesh reference ✓");
}
