//! Quickstart: the Figure-4 API, bubble evolution (Figure 3), and a
//! first simulated run.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use bubbles::apps::conduction::{self, HeatParams};
use bubbles::apps::StructureMode;
use bubbles::marcel::Marcel;
use bubbles::sched::Scheduler;
use bubbles::topology::{CpuId, Topology};

fn main() {
    // ---- 1. Figure 4: build and launch a bubble ---------------------
    println!("== Figure 4: marcel-style API ==");
    let m = Marcel::new(Topology::numa(2, 2));
    let sys = m.system().clone();
    sys.trace.set_enabled(true);

    let bubble = m.bubble_init();
    let t1 = m.create_dontsched("thread1");
    let t2 = m.create_dontsched("thread2");
    m.bubble_inserttask(bubble, t1);
    m.wake_up_bubble(bubble);
    m.bubble_inserttask(bubble, t2); // late insertion, as in the paper

    // ---- 2. Figure 3: watch the bubble descend and burst ------------
    let sched = m.scheduler().clone();
    let got = sched.pick(&sys, CpuId(0));
    println!("cpu0 picked: {:?}", got.map(|t| sys.tasks.name(t)));
    println!("\nscheduler trace (Figure 3 evolution):");
    print!("{}", sys.trace.dump());

    // ---- 3. A first experiment: Table-2 rows on a small machine -----
    println!("\n== conduction on numa-2x2, all three approaches ==");
    let topo = Topology::numa(2, 2);
    let p = HeatParams { threads: 4, cycles: 10, work: 500_000, mem_fraction: 0.35 };
    let seq = conduction::run_sequential(&topo, &p).total_time;
    println!("{:<12} {:>12} cycles", "sequential", seq);
    for mode in [StructureMode::Simple, StructureMode::Bound, StructureMode::Bubbles] {
        let t = conduction::run(&topo, mode, &p).total_time;
        println!(
            "{:<12} {:>12} cycles   speedup {:.2}",
            mode.label(),
            t,
            seq as f64 / t as f64
        );
    }
    println!("\nNext: `repro table2`, `repro fig5`, `cargo run --release --example heat_e2e`");
}
