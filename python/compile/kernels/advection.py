"""Advection kernel (Pallas, Layer 1).

The paper's second Table-2 application is an advection simulation with
the same structure as conduction (parallel stripes + global barrier) but
a much shorter runtime (16.13 s sequential vs 250.2 s). We implement a
first-order upwind scheme for constant positive velocity (cu, cv) in
Courant-number form:

  q' = q - cu * (q - q[up]) - cv * (q - q[left])

Stability requires cu + cv <= 1. The stripe layout matches the
conduction kernel: one halo row above and below, Dirichlet columns.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .stencil import pick_row_block


def _advection_kernel(x_ref, c_ref, o_ref):
    """One row-block of the upwind advection update.

    x_ref: (R+2, C) stripe with halo rows; c_ref: (2,) = [cu, cv]
    Courant numbers (row-wind, column-wind); o_ref: (BR, C).
    """
    i = pl.program_id(0)
    br = o_ref.shape[0]
    win = x_ref[pl.ds(i * br, br + 2), :]
    cu = c_ref[0]
    cv = c_ref[1]
    center = win[1:-1, :]
    up = win[:-2, :]
    left = jnp.concatenate([center[:, :1], center[:, :-1]], axis=1)
    out = center - cu * (center - up) - cv * (center - left)
    # Inflow column boundary (Dirichlet): keep the wall value.
    out = jnp.concatenate([center[:, :1], out[:, 1:]], axis=1)
    o_ref[...] = out


@functools.partial(jax.named_call, name="advection_step")
def advection_step(x, c):
    """One upwind advection step over a stripe.

    Args:
      x: (R+2, C) stripe with halo rows.
      c: (2,) f32 Courant numbers [cu, cv], cu + cv <= 1, both >= 0.

    Returns:
      (R, C) updated interior stripe.
    """
    rows = x.shape[0] - 2
    cols = x.shape[1]
    br = pick_row_block(rows)
    return pl.pallas_call(
        _advection_kernel,
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((rows + 2, cols), lambda i: (0, 0)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), x.dtype),
        interpret=True,
    )(x, c)
