"""Pure-jnp correctness oracles for the Layer-1 Pallas kernels.

These are the specification: simple, obviously-correct jnp expressions
with no Pallas machinery. pytest asserts allclose(kernel, ref) across a
hypothesis-driven sweep of shapes, dtypes and parameter values.
"""

import jax.numpy as jnp


def conduction_ref(x, alpha):
    """5-point Jacobi heat step over stripe x: (R+2, C) -> (R, C)."""
    a = jnp.asarray(alpha).reshape(())
    center = x[1:-1, :]
    up = x[:-2, :]
    down = x[2:, :]
    left = jnp.concatenate([center[:, :1], center[:, :-1]], axis=1)
    right = jnp.concatenate([center[:, 1:], center[:, -1:]], axis=1)
    out = center + a * (up + down + left + right - 4.0 * center)
    return jnp.concatenate([center[:, :1], out[:, 1:-1], center[:, -1:]], axis=1)


def advection_ref(x, c):
    """First-order upwind advection over stripe x: (R+2, C) -> (R, C)."""
    c = jnp.asarray(c)
    cu, cv = c[0], c[1]
    center = x[1:-1, :]
    up = x[:-2, :]
    left = jnp.concatenate([center[:, :1], center[:, :-1]], axis=1)
    out = center - cu * (center - up) - cv * (center - left)
    return jnp.concatenate([center[:, :1], out[:, 1:]], axis=1)


def residual_max_ref(a, b):
    """max |a - b| as a (1, 1) array."""
    return jnp.max(jnp.abs(a - b)).reshape(1, 1)
