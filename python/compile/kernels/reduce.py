"""Reduction kernel (Pallas, Layer 1): max-abs residual between meshes.

Used by the rust end-to-end driver to verify convergence of the
conduction run (paper §5.2 applications iterate until their cycle count;
we additionally check the numerics against the pure-jnp oracle).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _residual_kernel(a_ref, b_ref, o_ref):
    o_ref[0, 0] = jnp.max(jnp.abs(a_ref[...] - b_ref[...]))


@functools.partial(jax.named_call, name="residual_max")
def residual_max(a, b):
    """max |a - b| over two equally-shaped meshes, returned as (1, 1)."""
    rows, cols = a.shape
    return pl.pallas_call(
        _residual_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((rows, cols), lambda i: (0, 0)),
            pl.BlockSpec((rows, cols), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), a.dtype),
        interpret=True,
    )(a, b)
