"""Heat-conduction stencil kernel (Pallas, Layer 1).

The paper's Table-2 "conduction" application performs cycles of fully
parallel stripe computation followed by a global hierarchical barrier.
Each MARCEL thread owns one horizontal stripe of the mesh. This kernel
is the per-stripe compute hot-spot: one explicit-Euler step of the 2-D
heat equation (5-point Jacobi stencil) over a stripe that carries one
halo row above and one below.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper tiles
work per NUMA node; here the same "keep data next to compute" insight is
expressed at kernel level with a row-block grid. Each grid step copies
its (block + halo) row window from the stripe (HBM→VMEM in a real TPU
lowering; the BlockSpec schedule below is what a threadblock/shared-mem
schedule would be on the paper's-era hardware) and writes one output
block. VMEM footprint per step = (BR+2+BR)*C*4 bytes, far under the
~16 MiB VMEM budget for every shape we emit.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; correctness is validated against ``ref.py`` by pytest and
the interpreted lowering is what ships in ``artifacts/``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-block size for the grid. Shapes emitted by aot.py always have the
# stripe height as a multiple of the chosen block (pick_row_block).
CONDUCTION_ROW_BLOCK = 16


def pick_row_block(rows: int) -> int:
    """Largest block <= CONDUCTION_ROW_BLOCK that divides ``rows``."""
    for cand in (CONDUCTION_ROW_BLOCK, 8, 4, 2, 1):
        if rows % cand == 0 and cand <= rows:
            return cand
    return 1


def _conduction_kernel(x_ref, a_ref, o_ref):
    """One row-block of the 5-point stencil.

    x_ref: (R+2, C) full stripe incl. top/bottom halo rows (ANY memory);
    a_ref: (1,) diffusion coefficient alpha (= dt/dx^2 premultiplied);
    o_ref: (BR, C) output row block.
    """
    i = pl.program_id(0)
    br = o_ref.shape[0]
    # Load this block's window: BR interior rows plus one halo row on
    # each side. In a real TPU lowering this is the HBM->VMEM copy.
    win = x_ref[pl.ds(i * br, br + 2), :]
    alpha = a_ref[0]
    center = win[1:-1, :]
    up = win[:-2, :]
    down = win[2:, :]
    # Edge-replicated column neighbours; the true boundary columns are
    # overwritten below (Dirichlet in the column direction).
    left = jnp.concatenate([center[:, :1], center[:, :-1]], axis=1)
    right = jnp.concatenate([center[:, 1:], center[:, -1:]], axis=1)
    out = center + alpha * (up + down + left + right - 4.0 * center)
    # Dirichlet side walls: boundary columns keep their value.
    out = jnp.concatenate([center[:, :1], out[:, 1:-1], center[:, -1:]], axis=1)
    o_ref[...] = out


@functools.partial(jax.named_call, name="conduction_step")
def conduction_step(x, alpha):
    """One explicit heat-equation step over a stripe.

    Args:
      x: (R+2, C) stripe with halo rows. Row 0 and row R+1 are halo
         (either a neighbour stripe's edge or the global Dirichlet wall).
      alpha: (1,) f32, stability requires alpha < 0.25.

    Returns:
      (R, C) updated interior stripe.
    """
    rows = x.shape[0] - 2
    cols = x.shape[1]
    br = pick_row_block(rows)
    return pl.pallas_call(
        _conduction_kernel,
        grid=(rows // br,),
        in_specs=[
            # Full stripe visible to every grid step; each step slices
            # its own overlapping halo window (overlap is not
            # expressible as a non-overlapping BlockSpec partition).
            pl.BlockSpec((rows + 2, cols), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), x.dtype),
        interpret=True,
    )(x, alpha)
