"""Layer-1 Pallas kernels (build-time only).

Each kernel is written as a Pallas kernel and lowered with
``interpret=True`` so the resulting HLO runs on any PJRT backend,
including the rust CPU client on the request path. ``ref.py`` holds the
pure-jnp oracles the pytest suite checks the kernels against.
"""

from .stencil import conduction_step, pick_row_block, CONDUCTION_ROW_BLOCK
from .advection import advection_step
from .reduce import residual_max

__all__ = [
    "conduction_step",
    "advection_step",
    "residual_max",
    "pick_row_block",
    "CONDUCTION_ROW_BLOCK",
]
