"""AOT lowering: jax (L2+L1) -> HLO *text* -> artifacts/ for the rust runtime.

Interchange format is HLO **text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Every artifact is a single HLO module for one (function, stripe shape).
A ``manifest.txt`` indexes them for the rust artifact registry
(rust/src/runtime/artifact.rs); its line format is::

    <name> <kind> <rows> <cols> <dtype> <file>

where ``rows`` is the *output* stripe height (the input carries +2 halo
rows for the stencil kinds).

Run once via ``make artifacts``; python never runs on the request path.
"""

import argparse
import functools
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Mesh geometry shared with the rust side (rust/src/apps/conduction.rs):
# the Table-2 reproduction uses a MESH_ROWS x MESH_COLS mesh split into
# 1/4/8/16 stripes (16 = one per CPU of the numa-4x4 machine).
MESH_ROWS = 64
MESH_COLS = 256
STRIPE_HEIGHTS = (4, 8, 16, 64)
# Small shapes exercised by the unit/integration tests.
TEST_SHAPES = ((4, 32), (8, 16))


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_conduction(rows: int, cols: int) -> str:
    fn = jax.jit(model.conduction_stripe_step)
    return to_hlo_text(fn.lower(_spec((rows + 2, cols)), _spec((1,))))


def lower_advection(rows: int, cols: int) -> str:
    fn = jax.jit(model.advection_stripe_step)
    return to_hlo_text(fn.lower(_spec((rows + 2, cols)), _spec((2,))))


def lower_residual(rows: int, cols: int) -> str:
    fn = jax.jit(model.mesh_residual)
    return to_hlo_text(fn.lower(_spec((rows, cols)), _spec((rows, cols))))


def lower_conduction_multistep(rows: int, cols: int, n_steps: int) -> str:
    fn = jax.jit(functools.partial(model.conduction_stripe_multistep, n_steps=n_steps))
    return to_hlo_text(fn.lower(_spec((rows + 2, cols)), _spec((1,))))


def artifact_plan():
    """Yield (name, kind, rows, cols, lower_fn) for every artifact."""
    for rows in STRIPE_HEIGHTS:
        cols = MESH_COLS
        yield (f"conduction_r{rows}_c{cols}", "conduction", rows, cols,
               lambda r=rows, c=cols: lower_conduction(r, c))
        yield (f"advection_r{rows}_c{cols}", "advection", rows, cols,
               lambda r=rows, c=cols: lower_advection(r, c))
    # Multistep variant for the perf ablation (frozen-halo inner loop).
    yield (f"conduction_ms8_r{STRIPE_HEIGHTS[0]}_c{MESH_COLS}", "conduction_ms8",
           STRIPE_HEIGHTS[0], MESH_COLS,
           lambda: lower_conduction_multistep(STRIPE_HEIGHTS[0], MESH_COLS, 8))
    # Whole-mesh residual for convergence verification in the e2e driver.
    yield (f"residual_r{MESH_ROWS}_c{MESH_COLS}", "residual", MESH_ROWS, MESH_COLS,
           lambda: lower_residual(MESH_ROWS, MESH_COLS))
    # Small shapes for the rust unit tests (fast to compile + run).
    for rows, cols in TEST_SHAPES:
        yield (f"conduction_r{rows}_c{cols}", "conduction", rows, cols,
               lambda r=rows, c=cols: lower_conduction(r, c))
        yield (f"advection_r{rows}_c{cols}", "advection", rows, cols,
               lambda r=rows, c=cols: lower_advection(r, c))
    yield (f"residual_r{TEST_SHAPES[0][0]}_c{TEST_SHAPES[0][1]}", "residual",
           TEST_SHAPES[0][0], TEST_SHAPES[0][1],
           lambda: lower_residual(*TEST_SHAPES[0]))


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--list", action="store_true", help="print the plan and exit")
    args = ap.parse_args()

    plan = list(artifact_plan())
    if args.list:
        for name, kind, rows, cols, _ in plan:
            print(f"{name} {kind} {rows} {cols}")
        return 0

    os.makedirs(args.out_dir, exist_ok=True)
    manifest_lines = []
    total = 0
    for name, kind, rows, cols, lower in plan:
        text = lower()
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest_lines.append(f"{name} {kind} {rows} {cols} f32 {fname}")
        total += len(text)
        print(f"  wrote {fname} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("# name kind rows cols dtype file\n")
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {len(plan)} artifacts ({total} chars) + manifest.txt to {args.out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
