"""Layer-2 JAX model: the step functions the rust coordinator executes.

These are the jax functions that get AOT-lowered (see ``aot.py``) into
``artifacts/*.hlo.txt`` and loaded by ``rust/src/runtime/``. They call
the Layer-1 Pallas kernels so kernel + glue lower into a single HLO
module per (function, stripe-shape).

The paper's applications (§5.2) perform *cycles of fully parallel
computing followed by a global hierarchical communication barrier*: each
thread computes one stripe, then all threads synchronise. The halo
exchange between stripes is the rust coordinator's job (it happens at
the barrier); each artifact therefore computes exactly one stripe step.
"""

import jax
import jax.numpy as jnp

from .kernels import advection_step, conduction_step, residual_max


def conduction_stripe_step(x, alpha):
    """One heat-conduction step for one stripe.

    x: (R+2, C) stripe with halo rows; alpha: (1,) diffusion number.
    Returns the (R, C) updated interior.
    """
    return (conduction_step(x, alpha),)


def advection_stripe_step(x, c):
    """One upwind advection step for one stripe.

    x: (R+2, C) stripe with halo rows; c: (2,) Courant numbers.
    Returns the (R, C) updated interior.
    """
    return (advection_step(x, c),)


def mesh_residual(a, b):
    """max |a - b| over two meshes, as (1, 1). Convergence check."""
    return (residual_max(a, b),)


def conduction_stripe_multistep(x, alpha, n_steps: int):
    """n interior steps with *frozen* halos (used to amortise PJRT call
    overhead when a stripe is tall enough that its interior dominates;
    the rust side still exchanges halos between multistep calls).

    Halo rows are treated as constant over the n steps, which matches
    the paper's per-cycle barrier semantics when n == 1 and is an
    explicitly-documented approximation for n > 1 (used only by the
    perf ablation, never by the Table-2 reproduction).
    """

    def body(_, xcur):
        inner = conduction_step(xcur, alpha)
        return jnp.concatenate([xcur[:1, :], inner, xcur[-1:, :]], axis=0)

    out = jax.lax.fori_loop(0, n_steps, body, x)
    return (out[1:-1, :],)
