"""L1 correctness: Pallas kernels vs pure-jnp oracles.

hypothesis sweeps shapes/dtypes/parameters; numpy.testing.assert_allclose
is the judge. This is the CORE correctness signal for everything the
rust runtime later executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import advection_step, conduction_step, residual_max, pick_row_block
from compile.kernels.ref import advection_ref, conduction_ref, residual_max_ref

jax.config.update("jax_enable_x64", False)


def rng_stripe(rows, cols, seed=0, dtype=np.float32):
    r = np.random.RandomState(seed)
    return jnp.asarray(r.uniform(-1.0, 2.0, size=(rows + 2, cols)).astype(dtype))


# ---------------------------------------------------------------- conduction

@pytest.mark.parametrize("rows,cols", [(1, 8), (2, 8), (4, 32), (8, 16), (16, 256), (64, 256), (5, 7), (3, 128)])
def test_conduction_matches_ref(rows, cols):
    x = rng_stripe(rows, cols, seed=rows * 1000 + cols)
    alpha = jnp.asarray([0.2], dtype=jnp.float32)
    got = conduction_step(x, alpha)
    want = conduction_ref(x, alpha)
    assert got.shape == (rows, cols)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_conduction_zero_alpha_is_identity():
    x = rng_stripe(8, 16, seed=3)
    alpha = jnp.asarray([0.0], dtype=jnp.float32)
    got = conduction_step(x, alpha)
    assert_allclose(np.asarray(got), np.asarray(x[1:-1]), rtol=0, atol=0)


def test_conduction_uniform_field_is_fixed_point():
    x = jnp.full((10, 32), 3.25, dtype=jnp.float32)
    got = conduction_step(x, jnp.asarray([0.25 - 1e-3], jnp.float32))
    assert_allclose(np.asarray(got), np.full((8, 32), 3.25), rtol=1e-6)


def test_conduction_preserves_dirichlet_columns():
    x = rng_stripe(6, 12, seed=9)
    got = conduction_step(x, jnp.asarray([0.1], jnp.float32))
    assert_allclose(np.asarray(got)[:, 0], np.asarray(x)[1:-1, 0])
    assert_allclose(np.asarray(got)[:, -1], np.asarray(x)[1:-1, -1])


def test_conduction_maximum_principle():
    """Explicit stable step never exceeds the data range (alpha <= 1/4)."""
    x = rng_stripe(8, 64, seed=11)
    got = np.asarray(conduction_step(x, jnp.asarray([0.24], jnp.float32)))
    assert got.max() <= float(np.asarray(x).max()) + 1e-6
    assert got.min() >= float(np.asarray(x).min()) - 1e-6


@settings(max_examples=40, deadline=None)
@given(
    rows=st.integers(min_value=1, max_value=48),
    cols=st.integers(min_value=2, max_value=96),
    alpha=st.floats(min_value=0.0, max_value=0.25, allow_nan=False, allow_subnormal=False, width=32),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_conduction_hypothesis_sweep(rows, cols, alpha, seed):
    x = rng_stripe(rows, cols, seed=seed)
    a = jnp.asarray([alpha], dtype=jnp.float32)
    got = conduction_step(x, a)
    want = conduction_ref(x, a)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- advection

@pytest.mark.parametrize("rows,cols", [(1, 8), (4, 32), (8, 16), (16, 256), (64, 256), (7, 9)])
def test_advection_matches_ref(rows, cols):
    x = rng_stripe(rows, cols, seed=rows * 77 + cols)
    c = jnp.asarray([0.3, 0.4], dtype=jnp.float32)
    got = advection_step(x, c)
    want = advection_ref(x, c)
    assert got.shape == (rows, cols)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_advection_zero_velocity_is_identity():
    x = rng_stripe(8, 16, seed=5)
    got = advection_step(x, jnp.asarray([0.0, 0.0], jnp.float32))
    assert_allclose(np.asarray(got), np.asarray(x[1:-1]), rtol=0, atol=0)


def test_advection_transports_downward():
    """A hot top-halo row must bleed into the first interior row."""
    x = jnp.zeros((6, 8), jnp.float32).at[0, :].set(10.0)
    got = np.asarray(advection_step(x, jnp.asarray([0.5, 0.0], jnp.float32)))
    assert (got[0, 1:] > 0).all()
    assert_allclose(got[1:], 0.0)


@settings(max_examples=40, deadline=None)
@given(
    rows=st.integers(min_value=1, max_value=48),
    cols=st.integers(min_value=2, max_value=96),
    cu=st.floats(min_value=0.0, max_value=0.5, allow_nan=False, allow_subnormal=False, width=32),
    cv=st.floats(min_value=0.0, max_value=0.375, allow_nan=False, allow_subnormal=False, width=32),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_advection_hypothesis_sweep(rows, cols, cu, cv, seed):
    x = rng_stripe(rows, cols, seed=seed)
    c = jnp.asarray([cu, cv], dtype=jnp.float32)
    got = advection_step(x, c)
    want = advection_ref(x, c)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------- residual

@pytest.mark.parametrize("rows,cols", [(1, 1), (4, 32), (64, 256)])
def test_residual_matches_ref(rows, cols):
    r = np.random.RandomState(rows + cols)
    a = jnp.asarray(r.randn(rows, cols).astype(np.float32))
    b = jnp.asarray(r.randn(rows, cols).astype(np.float32))
    got = residual_max(a, b)
    want = residual_max_ref(a, b)
    assert got.shape == (1, 1)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_residual_identical_is_zero():
    a = jnp.ones((8, 8), jnp.float32)
    assert float(residual_max(a, a)[0, 0]) == 0.0


# -------------------------------------------------------------- block picker

@pytest.mark.parametrize("rows,expect", [(64, 16), (16, 16), (8, 8), (4, 4), (2, 2), (1, 1), (48, 16), (12, 4), (6, 2), (5, 1), (7, 1)])
def test_pick_row_block(rows, expect):
    assert pick_row_block(rows) == expect
    assert rows % pick_row_block(rows) == 0
