"""AOT path: every artifact in the plan lowers to parseable HLO text and
the emitted module has the expected parameter/result shapes."""

import re

import pytest

from compile import aot


def test_plan_names_are_unique():
    names = [name for name, *_ in aot.artifact_plan()]
    assert len(names) == len(set(names))


def test_plan_covers_table2_stripe_heights():
    kinds = {}
    for name, kind, rows, cols, _ in aot.artifact_plan():
        kinds.setdefault(kind, set()).add(rows)
    # 16/8/4-way splits of the 64-row mesh plus whole-mesh sequential.
    assert {4, 8, 16, 64} <= kinds["conduction"]
    assert {4, 8, 16, 64} <= kinds["advection"]
    assert "residual" in kinds


@pytest.mark.parametrize("rows,cols", [(4, 32), (8, 16)])
def test_conduction_lowers_to_hlo_text(rows, cols):
    text = aot.lower_conduction(rows, cols)
    assert "HloModule" in text
    assert f"f32[{rows + 2},{cols}]" in text    # input with halo
    assert f"f32[{rows},{cols}]" in text        # output stripe
    assert "f32[1]" in text                     # alpha parameter


def test_advection_lowers_to_hlo_text():
    text = aot.lower_advection(4, 32)
    assert "HloModule" in text
    assert "f32[6,32]" in text
    assert "f32[2]" in text                     # [cu, cv]


def test_residual_lowers_to_hlo_text():
    text = aot.lower_residual(4, 32)
    assert "HloModule" in text
    assert "f32[1,1]" in text


def test_hlo_has_root_tuple():
    """return_tuple=True so the rust side can always to_tuple1()."""
    text = aot.lower_conduction(4, 32)
    root = [l for l in text.splitlines() if "ROOT" in l]
    assert root, text
    assert any("tuple" in l or "(f32" in l for l in root)


def test_multistep_lowers_with_loop():
    text = aot.lower_conduction_multistep(4, 32, 8)
    assert "HloModule" in text
    # fori_loop lowers to a while op in HLO.
    assert "while" in text


def test_hlo_text_ids_fit_32bit():
    """The whole reason we ship text: ids must be reparseable; sanity-check
    none of the textual ids overflow i32 (xla_extension 0.5.1 limit)."""
    text = aot.lower_conduction(4, 32)
    for m in re.finditer(r"%[A-Za-z_0-9.\-]+\.(\d+)", text):
        assert int(m.group(1)) <= 2**31 - 1
