"""L2 model semantics: multi-stripe composition equals whole-mesh stepping.

The rust coordinator splits the mesh into stripes and exchanges halos at
each barrier; these tests prove that decomposition is exact, i.e. the
distributed computation the scheduler orchestrates equals the sequential
oracle regardless of the stripe count.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model
from compile.kernels.ref import advection_ref, conduction_ref


def step_whole_mesh_ref(mesh, kind, params):
    """One whole-mesh step with Dirichlet walls all around."""
    padded = jnp.concatenate([mesh[:1], mesh, mesh[-1:]], axis=0)
    if kind == "conduction":
        return conduction_ref(padded, params)
    return advection_ref(padded, params)


def step_striped(mesh, kind, params, n_stripes):
    """Split into stripes, add halos from neighbours, step, reassemble —
    exactly what rust/src/apps/conduction.rs does at every barrier."""
    rows = mesh.shape[0]
    assert rows % n_stripes == 0
    h = rows // n_stripes
    outs = []
    for s in range(n_stripes):
        top = mesh[s * h - 1 : s * h] if s > 0 else mesh[:1]
        bot = mesh[(s + 1) * h : (s + 1) * h + 1] if s < n_stripes - 1 else mesh[-1:]
        stripe = jnp.concatenate([top, mesh[s * h : (s + 1) * h], bot], axis=0)
        if kind == "conduction":
            (out,) = model.conduction_stripe_step(stripe, params)
        else:
            (out,) = model.advection_stripe_step(stripe, params)
        outs.append(out)
    return jnp.concatenate(outs, axis=0)


def make_mesh(rows=64, cols=64, seed=0):
    r = np.random.RandomState(seed)
    return jnp.asarray(r.uniform(0.0, 1.0, size=(rows, cols)).astype(np.float32))


@pytest.mark.parametrize("n_stripes", [1, 2, 4, 8, 16])
def test_conduction_striping_is_exact(n_stripes):
    mesh = make_mesh(seed=n_stripes)
    alpha = jnp.asarray([0.2], jnp.float32)
    got = step_striped(mesh, "conduction", alpha, n_stripes)
    want = step_whole_mesh_ref(mesh, "conduction", alpha)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("n_stripes", [1, 2, 4, 8, 16])
def test_advection_striping_is_exact(n_stripes):
    mesh = make_mesh(seed=100 + n_stripes)
    c = jnp.asarray([0.25, 0.25], jnp.float32)
    got = step_striped(mesh, "advection", c, n_stripes)
    want = step_whole_mesh_ref(mesh, "advection", c)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_conduction_multi_iteration_striped_equals_sequential():
    """Five steps with halo exchange each cycle == five whole-mesh steps."""
    mesh = make_mesh(rows=32, cols=32, seed=7)
    alpha = jnp.asarray([0.15], jnp.float32)
    striped = mesh
    whole = mesh
    for _ in range(5):
        striped = step_striped(striped, "conduction", alpha, 4)
        whole = step_whole_mesh_ref(whole, "conduction", alpha)
    assert_allclose(np.asarray(striped), np.asarray(whole), rtol=1e-5, atol=1e-6)


def test_conduction_converges_to_uniform():
    """With adiabatic-ish walls (replicated halos) the field flattens."""
    mesh = make_mesh(rows=16, cols=16, seed=3)
    alpha = jnp.asarray([0.2], jnp.float32)
    cur = mesh
    for _ in range(400):
        cur = step_whole_mesh_ref(cur, "conduction", alpha)
    interior = np.asarray(cur)[1:-1, 1:-1]
    assert interior.std() < 0.5 * np.asarray(mesh)[1:-1, 1:-1].std()


def test_multistep_frozen_halo_matches_manual_loop():
    r = np.random.RandomState(5)
    x = jnp.asarray(r.rand(10, 16).astype(np.float32))
    alpha = jnp.asarray([0.2], jnp.float32)
    (got,) = model.conduction_stripe_multistep(x, alpha, 3)
    cur = x
    for _ in range(3):
        (inner,) = model.conduction_stripe_step(cur, alpha)
        cur = jnp.concatenate([cur[:1], inner, cur[-1:]], axis=0)
    assert_allclose(np.asarray(got), np.asarray(cur[1:-1]), rtol=1e-6)


def test_residual_model_wrapper():
    a = make_mesh(8, 8, seed=1)
    b = a + 0.5
    (res,) = model.mesh_residual(a, b)
    assert_allclose(np.asarray(res), [[0.5]], rtol=1e-6)
