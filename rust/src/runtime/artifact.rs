//! Artifact registry: the manifest emitted by `python/compile/aot.py`
//! mapping (kernel kind, stripe shape) to HLO files, with compile
//! caching.

use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;

use super::client::{Executable, Runtime};
use crate::error::{Error, Result};

/// One line of `artifacts/manifest.txt`:
/// `<name> <kind> <rows> <cols> <dtype> <file>`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactEntry {
    pub name: String,
    pub kind: String,
    pub rows: usize,
    pub cols: usize,
    pub dtype: String,
    pub file: String,
}

/// Parse a manifest file's text.
pub fn parse_manifest(text: &str) -> Result<Vec<ArtifactEntry>> {
    let mut out = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let f: Vec<&str> = line.split_whitespace().collect();
        if f.len() != 6 {
            return Err(Error::Runtime(format!("manifest line {}: expected 6 fields", ln + 1)));
        }
        out.push(ArtifactEntry {
            name: f[0].to_string(),
            kind: f[1].to_string(),
            rows: f[2].parse().map_err(|_| Error::Runtime(format!("bad rows line {}", ln + 1)))?,
            cols: f[3].parse().map_err(|_| Error::Runtime(format!("bad cols line {}", ln + 1)))?,
            dtype: f[4].to_string(),
            file: f[5].to_string(),
        });
    }
    Ok(out)
}

/// Registry over one artifacts directory. Not `Send` (the underlying
/// PJRT handles are thread-pinned); see [`super::service`] for the
/// multi-threaded front.
pub struct ArtifactRegistry {
    dir: PathBuf,
    runtime: Runtime,
    entries: Vec<ArtifactEntry>,
    cache: std::cell::RefCell<HashMap<String, Rc<Executable>>>,
}

impl ArtifactRegistry {
    /// Open the registry at `dir` (must contain `manifest.txt`).
    pub fn open(dir: impl Into<PathBuf>) -> Result<ArtifactRegistry> {
        let dir = dir.into();
        let manifest = std::fs::read_to_string(dir.join("manifest.txt"))?;
        Ok(ArtifactRegistry {
            dir,
            runtime: Runtime::cpu()?,
            entries: parse_manifest(&manifest)?,
            cache: std::cell::RefCell::new(HashMap::new()),
        })
    }

    /// Open the default location (walks up for `artifacts/`).
    pub fn open_default() -> Result<ArtifactRegistry> {
        let dir = super::artifact_dir()
            .ok_or_else(|| Error::Runtime("artifacts/ not found: run `make artifacts`".into()))?;
        ArtifactRegistry::open(dir)
    }

    /// All manifest entries.
    pub fn entries(&self) -> &[ArtifactEntry] {
        &self.entries
    }

    /// Find the entry for a kernel kind and output-stripe shape.
    pub fn find(&self, kind: &str, rows: usize, cols: usize) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.kind == kind && e.rows == rows && e.cols == cols)
    }

    /// Load (compile) an artifact by name, with caching.
    pub fn load(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let entry = self
            .entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| Error::Runtime(format!("unknown artifact `{name}`")))?;
        let exe = Rc::new(self.runtime.load_hlo_text(self.dir.join(&entry.file))?);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Load by (kind, shape).
    pub fn load_kind(&self, kind: &str, rows: usize, cols: usize) -> Result<Rc<Executable>> {
        let name = self
            .find(kind, rows, cols)
            .ok_or_else(|| {
                Error::Runtime(format!("no artifact for {kind} r{rows} c{cols}"))
            })?
            .name
            .clone();
        self.load(&name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parsing() {
        let text = "# comment\n\
                    conduction_r4_c32 conduction 4 32 f32 conduction_r4_c32.hlo.txt\n\
                    \n\
                    residual_r4_c32 residual 4 32 f32 residual_r4_c32.hlo.txt\n";
        let entries = parse_manifest(text).unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].kind, "conduction");
        assert_eq!(entries[0].rows, 4);
        assert_eq!(entries[1].file, "residual_r4_c32.hlo.txt");
    }

    #[test]
    fn manifest_rejects_malformed() {
        assert!(parse_manifest("too few fields").is_err());
        assert!(parse_manifest("a b notanumber 3 f32 f").is_err());
    }

    #[test]
    fn registry_roundtrip() {
        let Some(dir) = crate::runtime::artifact_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let reg = ArtifactRegistry::open(dir).unwrap();
        assert!(!reg.entries().is_empty());
        let e = reg.find("conduction", 4, 32).expect("test artifact present");
        assert_eq!(e.name, "conduction_r4_c32");
        // Load twice: second hit must come from cache (same Rc).
        let a = reg.load("conduction_r4_c32").unwrap();
        let b = reg.load("conduction_r4_c32").unwrap();
        assert!(Rc::ptr_eq(&a, &b));
        assert!(reg.load("nope").is_err());
        assert!(reg.load_kind("conduction", 999, 999).is_err());
    }
}
