//! PJRT CPU client wrapper: HLO text → compiled executable → execute.
//!
//! Interchange is HLO **text**, not serialized protos: jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md and
//! python/compile/aot.py).

use std::path::Path;

use crate::error::{Error, Result};

/// A PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Start a CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime { client: xla::PjRtClient::cpu()? })
    }

    /// Platform name ("cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text file and compile it.
    pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path).map_err(|e| {
            Error::Runtime(format!("parse {}: {e}", path.display()))
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(Executable { exe })
    }
}

/// A compiled HLO module, ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with f32 inputs given as `(data, shape)` pairs; returns
    /// the flattened f32 output. The AOT pipeline lowers every function
    /// with `return_tuple=True`, so the single result is unwrapped from
    /// a 1-tuple.
    pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<f32>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data).reshape(&dims)?;
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let first = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| Error::Runtime("empty execution result".into()))?;
        let out = first.to_literal_sync()?.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact_dir;

    /// Tests are skipped (not failed) when artifacts have not been
    /// built: `make artifacts` is a separate build step.
    fn registry_dir() -> Option<std::path::PathBuf> {
        let d = artifact_dir();
        if d.is_none() {
            eprintln!("skipping: run `make artifacts` first");
        }
        d
    }

    #[test]
    fn compile_and_run_conduction_small() {
        let Some(dir) = registry_dir() else { return };
        let rt = Runtime::cpu().unwrap();
        let exe = rt.load_hlo_text(dir.join("conduction_r4_c32.hlo.txt")).unwrap();
        // Uniform field + alpha=0.2 must stay uniform (stencil identity).
        let x = vec![1.5f32; 6 * 32];
        let alpha = vec![0.2f32];
        let out = exe.run_f32(&[(&x, &[6, 32]), (&alpha, &[1])]).unwrap();
        assert_eq!(out.len(), 4 * 32);
        for v in &out {
            assert!((v - 1.5).abs() < 1e-6, "{v}");
        }
    }

    #[test]
    fn conduction_matches_reference_stencil() {
        let Some(dir) = registry_dir() else { return };
        let rt = Runtime::cpu().unwrap();
        let exe = rt.load_hlo_text(dir.join("conduction_r4_c32.hlo.txt")).unwrap();
        // Deterministic pseudo-random stripe.
        let mut x = vec![0f32; 6 * 32];
        for (i, v) in x.iter_mut().enumerate() {
            *v = ((i * 2654435761) % 1000) as f32 / 1000.0;
        }
        let alpha = 0.15f32;
        let out = exe.run_f32(&[(&x, &[6, 32]), (&[alpha][..], &[1])]).unwrap();
        // Rust-side oracle of the same stencil.
        let idx = |r: usize, c: usize| r * 32 + c;
        for r in 0..4 {
            for c in 1..31 {
                let center = x[idx(r + 1, c)];
                let want = center
                    + alpha
                        * (x[idx(r, c)] + x[idx(r + 2, c)] + x[idx(r + 1, c - 1)]
                            + x[idx(r + 1, c + 1)]
                            - 4.0 * center);
                let got = out[idx(r, c)];
                assert!((got - want).abs() < 1e-5, "r{r} c{c}: {got} vs {want}");
            }
            // Dirichlet columns.
            assert_eq!(out[idx(r, 0)], x[idx(r + 1, 0)]);
            assert_eq!(out[idx(r, 31)], x[idx(r + 1, 31)]);
        }
    }

    #[test]
    fn residual_artifact_runs() {
        let Some(dir) = registry_dir() else { return };
        let rt = Runtime::cpu().unwrap();
        let exe = rt.load_hlo_text(dir.join("residual_r4_c32.hlo.txt")).unwrap();
        let a = vec![1.0f32; 4 * 32];
        let mut b = a.clone();
        b[37] = 3.5;
        let out = exe.run_f32(&[(&a, &[4, 32]), (&b, &[4, 32])]).unwrap();
        assert_eq!(out.len(), 1);
        assert!((out[0] - 2.5).abs() < 1e-6);
    }

    #[test]
    fn missing_artifact_is_an_error() {
        let rt = Runtime::cpu().unwrap();
        assert!(rt.load_hlo_text("/nonexistent/x.hlo.txt").is_err());
    }
}
