//! Thread-pinned PJRT service.
//!
//! The `xla` wrapper types are not `Send`, so one dedicated OS thread
//! owns the [`ArtifactRegistry`]; any worker thread submits
//! [`ExecRequest`]s over an mpsc channel and blocks on its private
//! response channel. This is the standard "pin the FFI world to a
//! thread" coordinator shape.

use std::sync::mpsc;
use std::thread::JoinHandle;

use super::ArtifactRegistry;
use crate::error::{Error, Result};

/// One execution request: artifact name + f32 inputs with shapes.
pub struct ExecRequest {
    pub artifact: String,
    pub inputs: Vec<(Vec<f32>, Vec<usize>)>,
    respond: mpsc::Sender<Result<Vec<f32>>>,
}

enum Msg {
    Exec(ExecRequest),
    Shutdown,
}

/// Handle to the PJRT service thread. Clone [`PjrtHandle`]s to share
/// across workers.
pub struct PjrtService {
    tx: mpsc::Sender<Msg>,
    join: Option<JoinHandle<()>>,
}

/// Cloneable submission handle.
#[derive(Clone)]
pub struct PjrtHandle {
    tx: mpsc::Sender<Msg>,
}

impl PjrtService {
    /// Spawn the service thread over the default artifact directory.
    pub fn start_default() -> Result<PjrtService> {
        let dir = super::artifact_dir()
            .ok_or_else(|| Error::Runtime("artifacts/ not found: run `make artifacts`".into()))?;
        PjrtService::start(dir)
    }

    /// Spawn the service thread over an explicit directory.
    pub fn start(dir: std::path::PathBuf) -> Result<PjrtService> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("pjrt-service".into())
            .spawn(move || {
                let reg = match ArtifactRegistry::open(dir) {
                    Ok(r) => {
                        let _ = ready_tx.send(Ok(()));
                        r
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(msg) = rx.recv() {
                    match msg {
                        Msg::Exec(req) => {
                            let result = reg.load(&req.artifact).and_then(|exe| {
                                let refs: Vec<(&[f32], &[usize])> = req
                                    .inputs
                                    .iter()
                                    .map(|(d, s)| (d.as_slice(), s.as_slice()))
                                    .collect();
                                exe.run_f32(&refs)
                            });
                            let _ = req.respond.send(result);
                        }
                        Msg::Shutdown => break,
                    }
                }
            })
            .map_err(|e| Error::Runtime(format!("spawn pjrt thread: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| Error::Runtime("pjrt service died during startup".into()))??;
        Ok(PjrtService { tx, join: Some(join) })
    }

    /// A cloneable handle for worker threads.
    pub fn handle(&self) -> PjrtHandle {
        PjrtHandle { tx: self.tx.clone() }
    }
}

impl PjrtHandle {
    /// Execute an artifact synchronously (blocks this worker only).
    pub fn exec(&self, artifact: &str, inputs: Vec<(Vec<f32>, Vec<usize>)>) -> Result<Vec<f32>> {
        let (respond, rx) = mpsc::channel();
        self.tx
            .send(Msg::Exec(ExecRequest { artifact: artifact.to_string(), inputs, respond }))
            .map_err(|_| Error::Runtime("pjrt service is down".into()))?;
        rx.recv().map_err(|_| Error::Runtime("pjrt service dropped the request".into()))?
    }
}

impl Drop for PjrtService {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn concurrent_workers_share_the_service() {
        let Ok(svc) = PjrtService::start_default() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let mut joins = Vec::new();
        for k in 0..4 {
            let h = svc.handle();
            joins.push(std::thread::spawn(move || {
                let x = vec![k as f32; 6 * 32];
                let out = h
                    .exec(
                        "conduction_r4_c32",
                        vec![(x, vec![6, 32]), (vec![0.2], vec![1])],
                    )
                    .unwrap();
                assert_eq!(out.len(), 4 * 32);
                // Uniform field stays uniform.
                assert!(out.iter().all(|v| (v - k as f32).abs() < 1e-6));
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
    }

    #[test]
    fn unknown_artifact_errors_through_channel() {
        let Ok(svc) = PjrtService::start_default() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let h = svc.handle();
        assert!(h.exec("does-not-exist", vec![]).is_err());
    }
}
