//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas
//! artifacts from the rust request path.
//!
//! Layer split (DESIGN.md §2): python lowers the L2/L1 computation to
//! HLO *text* once (`make artifacts`); this module compiles that text
//! on the PJRT CPU client and executes it — python never runs on the
//! request path.
//!
//! The `xla` crate's wrapper types hold raw C++ pointers and are not
//! `Send`; [`service::PjrtService`] therefore pins the whole runtime to
//! one OS thread and serves execute requests over channels — the shape
//! a multi-worker coordinator needs.

mod artifact;
mod client;
pub mod service;

pub use artifact::{ArtifactEntry, ArtifactRegistry};
pub use client::{Executable, Runtime};

/// Default artifacts directory (relative to the repo root).
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// Resolve the artifacts directory: `$BUBBLES_ARTIFACTS` or the
/// default, walking up from the current directory so tests work from
/// any cwd inside the repo.
pub fn artifact_dir() -> Option<std::path::PathBuf> {
    if let Ok(d) = std::env::var("BUBBLES_ARTIFACTS") {
        let p = std::path::PathBuf::from(d);
        return p.join("manifest.txt").exists().then_some(p);
    }
    let mut cur = std::env::current_dir().ok()?;
    loop {
        let cand = cur.join(DEFAULT_ARTIFACT_DIR);
        if cand.join("manifest.txt").exists() {
            return Some(cand);
        }
        if !cur.pop() {
            return None;
        }
    }
}
