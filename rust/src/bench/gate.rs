//! Bench regression gate: compare a `BENCH_rq.json` run against the
//! committed baseline and fail CI on a thresholded ns/op regression.
//!
//! The bench files are written by this crate's own plain-main benches
//! (no external JSON dependency exists by design), so the parser here
//! is a deliberately small extractor matched to that shape: every
//! *flat* `{...}` object carrying `"shape"`, `"threads"`, `"leg"` and
//! `"ns_op"` fields is a contended-bench leg; everything else in the
//! file (prose fields, the legacy `contention`/`pick_path` arrays) is
//! ignored. A leg is identified by `shape/threads/leg` — e.g.
//! `numa-4x4/t8/lockless` — and compared by `ns_op`:
//!
//! * `current / baseline > threshold` → **regression** (the gate's
//!   nonzero exit).
//! * Legs present on only one side are reported and skipped — a bench
//!   matrix change must not masquerade as a perf change.
//! * An empty baseline (no contended legs, e.g. the first commit of the
//!   file) makes the run **record-only**: nothing to compare against.
//!
//! The default threshold is ±25% ([`DEFAULT_THRESHOLD`]): wide enough
//! to absorb shared-runner noise on a smoke-length run, tight enough to
//! catch a lock slipped back into the pick hot path (which costs ≥2×
//! under contention — see the `rq_scaling` bench).
//!
//! The comparator is shared: `repro sweep diff` feeds it generic
//! `(cell key, metric)` pairs via [`compare_cells`] / [`parse_cells`],
//! so sweep regression reports and the contended-rq gate use one
//! matched-cell ratio engine. The provenance helpers ([`fnv1a`],
//! [`git_rev`]) live here too so benches and the experiment harness
//! stamp artifacts identically.

use crate::util::json::{field_num, field_str, flat_fields, flat_objects, FieldValue};

/// Ratio above which a leg counts as regressed (1.25 = +25% ns/op).
pub const DEFAULT_THRESHOLD: f64 = 1.25;

/// Metric fields the generic differ gates on when it finds them in a
/// result row; every other numeric field is informational.
pub const GATED_METRICS: &[&str] = &["ns_op", "makespan", "mix_makespan", "p99_slowdown"];

/// FNV-1a 64-bit — the config/provenance hash used by every bench and
/// sweep artifact. Stable across runs and platforms by construction.
pub fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Short git revision of the working tree, or `"unknown"` outside a
/// checkout — artifact provenance, best-effort by design.
pub fn git_rev() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".into())
}

/// One contended-bench leg, parsed from a `BENCH_rq.json`.
#[derive(Debug, Clone, PartialEq)]
pub struct LegResult {
    /// Machine shape the leg ran on (`smp-4`, `numa-4x4`).
    pub shape: String,
    /// Worker OS threads hammering the lists.
    pub threads: usize,
    /// Which runqueue variant: `locked` or `lockless`.
    pub leg: String,
    /// Nanoseconds per operation (lower is better — the gated number).
    pub ns_op: f64,
    /// Throughput in Mops/s (informational).
    pub mops: f64,
}

impl LegResult {
    /// Stable identity of a leg across runs.
    pub fn key(&self) -> String {
        format!("{}/t{}/{}", self.shape, self.threads, self.leg)
    }
}

/// One leg-pair comparison.
#[derive(Debug, Clone)]
pub struct LegDelta {
    pub key: String,
    pub baseline_ns: f64,
    pub current_ns: f64,
    /// `current / baseline` (> 1 = slower).
    pub ratio: f64,
    pub regressed: bool,
}

/// Outcome of gating one run against a baseline.
#[derive(Debug, Clone, Default)]
pub struct GateReport {
    /// Per-leg comparisons, in current-run order.
    pub deltas: Vec<LegDelta>,
    /// Current legs with no baseline counterpart (matrix grew).
    pub unmatched_current: Vec<String>,
    /// Baseline legs missing from the current run (matrix shrank).
    pub unmatched_baseline: Vec<String>,
}

impl GateReport {
    /// Legs over the threshold.
    pub fn regressions(&self) -> Vec<&LegDelta> {
        self.deltas.iter().filter(|d| d.regressed).collect()
    }

    /// Did the gate pass (no regressed leg)?
    pub fn passed(&self) -> bool {
        self.deltas.iter().all(|d| !d.regressed)
    }

    /// Human-readable per-leg lines for the CI log.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for d in &self.deltas {
            out.push_str(&format!(
                "{} {:>24}  {:>10.2} -> {:>10.2}  ({:+.1}%)\n",
                if d.regressed { "REGRESSED" } else { "ok       " },
                d.key,
                d.baseline_ns,
                d.current_ns,
                (d.ratio - 1.0) * 100.0,
            ));
        }
        for k in &self.unmatched_current {
            out.push_str(&format!("skipped   {k:>24}  (no baseline leg)\n"));
        }
        for k in &self.unmatched_baseline {
            out.push_str(&format!("skipped   {k:>24}  (leg gone from current run)\n"));
        }
        out
    }
}

fn parse_leg(obj: &str) -> Option<LegResult> {
    Some(LegResult {
        shape: field_str(obj, "shape")?,
        threads: field_num(obj, "threads")? as usize,
        leg: field_str(obj, "leg")?,
        ns_op: field_num(obj, "ns_op")?,
        mops: field_num(obj, "mops").unwrap_or(0.0),
    })
}

/// Extract every contended-bench leg from a `BENCH_rq.json` document.
/// Scans for *innermost* `{...}` spans (the leg objects are flat) and
/// keeps those with the full leg field set; anything else — including
/// the legacy `contention`/`pick_path` rows — is skipped silently.
pub fn parse_legs(json: &str) -> Vec<LegResult> {
    flat_objects(json).into_iter().filter_map(parse_leg).collect()
}

/// Extract generic gateable cells from any artifact this crate writes:
/// every innermost flat object whose string fields form a label (sorted
/// `k=v` pairs) contributes one cell per `gated` metric it carries,
/// keyed `<labels>:<metric>`. Rows without string labels are skipped —
/// there is nothing stable to match them by across runs.
pub fn parse_cells(json: &str, gated: &[&str]) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    for obj in flat_objects(json) {
        let mut labels: Vec<(String, String)> = Vec::new();
        let mut nums: Vec<(String, f64)> = Vec::new();
        for (k, v) in flat_fields(obj) {
            match v {
                FieldValue::Str(s) => labels.push((k, s)),
                FieldValue::Num(n) => nums.push((k, n)),
                FieldValue::Other => {}
            }
        }
        if labels.is_empty() {
            continue;
        }
        labels.sort();
        let label_key: Vec<String> = labels.iter().map(|(k, v)| format!("{k}={v}")).collect();
        let label_key = label_key.join(" ");
        for g in gated {
            if let Some((_, n)) = nums.iter().find(|(k, _)| k == g) {
                out.push((format!("{label_key}:{g}"), *n));
            }
        }
    }
    out
}

/// Compare generic `(key, value)` cells: a cell regresses when
/// `current / baseline > threshold` (lower is better for every gated
/// metric). Unmatched cells on either side — and cells with a zero or
/// negative baseline, which cannot form a ratio — are reported, never
/// gated on.
pub fn compare_cells(
    baseline: &[(String, f64)],
    current: &[(String, f64)],
    threshold: f64,
) -> GateReport {
    let mut report = GateReport::default();
    for (key, cur) in current {
        match baseline.iter().find(|(k, _)| k == key) {
            Some((_, base)) if *base > 0.0 => {
                let ratio = cur / base;
                report.deltas.push(LegDelta {
                    key: key.clone(),
                    baseline_ns: *base,
                    current_ns: *cur,
                    ratio,
                    regressed: ratio > threshold,
                });
            }
            _ => report.unmatched_current.push(key.clone()),
        }
    }
    for (key, _) in baseline {
        if !current.iter().any(|(k, _)| k == key) {
            report.unmatched_baseline.push(key.clone());
        }
    }
    report
}

/// Compare `current` legs against `baseline` by key; a leg regresses
/// when `current.ns_op / baseline.ns_op > threshold`. Unmatched legs on
/// either side are reported, never gated on. (A thin wrapper over
/// [`compare_cells`] keyed by [`LegResult::key`].)
pub fn compare(baseline: &[LegResult], current: &[LegResult], threshold: f64) -> GateReport {
    let cells = |legs: &[LegResult]| -> Vec<(String, f64)> {
        legs.iter().map(|l| (l.key(), l.ns_op)).collect()
    };
    compare_cells(&cells(baseline), &cells(current), threshold)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn leg(shape: &str, threads: usize, leg_name: &str, ns_op: f64) -> LegResult {
        LegResult {
            shape: shape.into(),
            threads,
            leg: leg_name.into(),
            ns_op,
            mops: if ns_op > 0.0 { 1e3 / ns_op } else { 0.0 },
        }
    }

    #[test]
    fn parses_legs_out_of_a_full_document() {
        let doc = r#"{
  "bench": "rq_scaling",
  "schema": 2,
  "git_rev": "abc1234",
  "contention": [{"threads":2,"global_mops":1.00,"percpu_mops":2.00}],
  "contended": [{"shape":"smp-4","threads":2,"leg":"locked","ns_op":81.25,"mops":12.31},
{"shape":"numa-4x4","threads":8,"leg":"lockless","ns_op":40.50,"mops":24.69}],
  "pick_path": [{"threads":4,"bucket_ns":120.00}]
}
"#;
        let legs = parse_legs(doc);
        assert_eq!(legs.len(), 2, "only full leg objects count: {legs:?}");
        assert_eq!(legs[0].key(), "smp-4/t2/locked");
        assert_eq!(legs[0].ns_op, 81.25);
        assert_eq!(legs[1].key(), "numa-4x4/t8/lockless");
        assert_eq!(legs[1].mops, 24.69);
    }

    #[test]
    fn two_x_regression_fails_the_gate() {
        let base = vec![leg("numa-4x4", 8, "lockless", 50.0)];
        let cur = vec![leg("numa-4x4", 8, "lockless", 100.0)];
        let report = compare(&base, &cur, DEFAULT_THRESHOLD);
        assert!(!report.passed());
        let regs = report.regressions();
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].key, "numa-4x4/t8/lockless");
        assert!((regs[0].ratio - 2.0).abs() < 1e-9);
        assert!(report.render().contains("REGRESSED"));
    }

    #[test]
    fn armed_baseline_trips_on_a_planted_regression() {
        // The CI arming scheme end to end, in miniature: a recorded
        // baseline document with the full contended matrix, then a
        // current run whose ns/op was multiplied by a planted factor
        // (what BENCH_INJECT_REGRESSION=2 does to the measurements).
        // Every leg is matched — nothing may be skipped — and every
        // matched leg must trip the ±25% gate.
        let doc = r#"{
  "bench": "rq_scaling",
  "schema": 2,
  "mode": "fast",
  "contended": [{"shape":"smp-4","threads":2,"leg":"locked","ns_op":80.00,"mops":12.50},
{"shape":"smp-4","threads":2,"leg":"lockless","ns_op":45.00,"mops":22.22},
{"shape":"numa-4x4","threads":8,"leg":"locked","ns_op":120.00,"mops":8.33},
{"shape":"numa-4x4","threads":8,"leg":"lockless","ns_op":40.00,"mops":25.00}]
}
"#;
        let base = parse_legs(doc);
        assert_eq!(base.len(), 4);
        let planted: Vec<LegResult> = base
            .iter()
            .map(|l| LegResult { ns_op: l.ns_op * 2.0, mops: l.mops / 2.0, ..l.clone() })
            .collect();
        let report = compare(&base, &planted, DEFAULT_THRESHOLD);
        assert!(report.unmatched_current.is_empty(), "armed baseline must match every leg");
        assert!(report.unmatched_baseline.is_empty());
        assert!(!report.passed(), "a planted 2x regression must fail the armed gate");
        assert_eq!(report.regressions().len(), 4, "every matched leg trips");
        // And the same matched baseline passes an un-planted run.
        let clean = compare(&base, &base.clone(), DEFAULT_THRESHOLD);
        assert!(clean.passed());
        assert_eq!(clean.deltas.len(), 4);
    }

    #[test]
    fn noise_within_threshold_passes() {
        let base = vec![leg("smp-4", 4, "locked", 100.0), leg("smp-4", 4, "lockless", 60.0)];
        let cur = vec![leg("smp-4", 4, "locked", 120.0), leg("smp-4", 4, "lockless", 49.0)];
        let report = compare(&base, &cur, DEFAULT_THRESHOLD);
        assert!(report.passed(), "+20% and an improvement are both inside ±25%: {report:?}");
        assert_eq!(report.deltas.len(), 2);
    }

    #[test]
    fn unmatched_legs_are_skipped_not_gated() {
        let base = vec![leg("smp-4", 2, "locked", 100.0), leg("smp-4", 16, "locked", 90.0)];
        let cur = vec![leg("smp-4", 2, "locked", 101.0), leg("numa-4x4", 2, "locked", 70.0)];
        let report = compare(&base, &cur, DEFAULT_THRESHOLD);
        assert!(report.passed());
        assert_eq!(report.unmatched_current, vec!["numa-4x4/t2/locked".to_string()]);
        assert_eq!(report.unmatched_baseline, vec!["smp-4/t16/locked".to_string()]);
        assert!(report.render().contains("skipped"));
    }

    #[test]
    fn empty_baseline_is_record_only() {
        let cur = vec![leg("smp-4", 2, "locked", 100.0)];
        let report = compare(&[], &cur, DEFAULT_THRESHOLD);
        assert!(report.passed(), "nothing to compare against cannot fail");
        assert!(report.deltas.is_empty());
        assert_eq!(report.unmatched_current.len(), 1);
    }

    #[test]
    fn zero_baseline_ns_cannot_divide() {
        let base = vec![leg("smp-4", 2, "locked", 0.0)];
        let cur = vec![leg("smp-4", 2, "locked", 100.0)];
        let report = compare(&base, &cur, DEFAULT_THRESHOLD);
        assert!(report.passed());
        assert_eq!(report.unmatched_current.len(), 1, "a 0 ns baseline leg is unusable");
    }

    #[test]
    fn fnv1a_is_stable_and_distinct() {
        assert_eq!(fnv1a(""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a("policy=afs seed=1"), fnv1a("policy=afs seed=1"));
        assert_ne!(fnv1a("policy=afs seed=1"), fnv1a("policy=afs seed=2"));
    }

    #[test]
    fn serve_rows_gate_through_generic_cells() {
        // The BENCH_serve.json row shape: engine/policy labels, mix
        // makespan and tail slowdown as the gated metrics per engine.
        let doc = r#"{"bench":"serve","results":[
{"engine":"sim","policy":"job-fair","jobs":30,"mix_makespan":5000,"p99_slowdown":2.5000},
{"engine":"native","policy":"job-fair","jobs":30,"mix_makespan":7000,"p99_slowdown":3.0000}]}
"#;
        let base = parse_cells(doc, GATED_METRICS);
        assert_eq!(base.len(), 4, "two rows x (mix_makespan, p99_slowdown): {base:?}");
        assert!(base
            .iter()
            .any(|(k, v)| k == "engine=sim policy=job-fair:mix_makespan" && *v == 5000.0));
        // Identical runs: every cell matched, nothing regresses.
        let clean = compare_cells(&base, &base.clone(), DEFAULT_THRESHOLD);
        assert!(clean.passed());
        assert_eq!(clean.deltas.len(), 4);
        assert!(clean.unmatched_current.is_empty());
        // A planted 2x on every metric trips every matched cell.
        let planted: Vec<(String, f64)> =
            base.iter().map(|(k, v)| (k.clone(), v * 2.0)).collect();
        let report = compare_cells(&base, &planted, DEFAULT_THRESHOLD);
        assert!(!report.passed());
        assert_eq!(report.regressions().len(), 4);
    }

    #[test]
    fn cells_without_labels_are_skipped() {
        let doc = r#"{"results":[{"makespan":100},{"policy":"afs","makespan":200}]}"#;
        let cells = parse_cells(doc, GATED_METRICS);
        assert_eq!(cells.len(), 1, "label-less rows cannot be matched across runs");
        assert_eq!(cells[0].0, "policy=afs:makespan");
    }
}
