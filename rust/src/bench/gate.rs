//! Bench regression gate: compare a `BENCH_rq.json` run against the
//! committed baseline and fail CI on a thresholded ns/op regression.
//!
//! The bench files are written by this crate's own plain-main benches
//! (no external JSON dependency exists by design), so the parser here
//! is a deliberately small extractor matched to that shape: every
//! *flat* `{...}` object carrying `"shape"`, `"threads"`, `"leg"` and
//! `"ns_op"` fields is a contended-bench leg; everything else in the
//! file (prose fields, the legacy `contention`/`pick_path` arrays) is
//! ignored. A leg is identified by `shape/threads/leg` — e.g.
//! `numa-4x4/t8/lockless` — and compared by `ns_op`:
//!
//! * `current / baseline > threshold` → **regression** (the gate's
//!   nonzero exit).
//! * Legs present on only one side are reported and skipped — a bench
//!   matrix change must not masquerade as a perf change.
//! * An empty baseline (no contended legs, e.g. the first commit of the
//!   file) makes the run **record-only**: nothing to compare against.
//!
//! The default threshold is ±25% ([`DEFAULT_THRESHOLD`]): wide enough
//! to absorb shared-runner noise on a smoke-length run, tight enough to
//! catch a lock slipped back into the pick hot path (which costs ≥2×
//! under contention — see the `rq_scaling` bench).

/// Ratio above which a leg counts as regressed (1.25 = +25% ns/op).
pub const DEFAULT_THRESHOLD: f64 = 1.25;

/// One contended-bench leg, parsed from a `BENCH_rq.json`.
#[derive(Debug, Clone, PartialEq)]
pub struct LegResult {
    /// Machine shape the leg ran on (`smp-4`, `numa-4x4`).
    pub shape: String,
    /// Worker OS threads hammering the lists.
    pub threads: usize,
    /// Which runqueue variant: `locked` or `lockless`.
    pub leg: String,
    /// Nanoseconds per operation (lower is better — the gated number).
    pub ns_op: f64,
    /// Throughput in Mops/s (informational).
    pub mops: f64,
}

impl LegResult {
    /// Stable identity of a leg across runs.
    pub fn key(&self) -> String {
        format!("{}/t{}/{}", self.shape, self.threads, self.leg)
    }
}

/// One leg-pair comparison.
#[derive(Debug, Clone)]
pub struct LegDelta {
    pub key: String,
    pub baseline_ns: f64,
    pub current_ns: f64,
    /// `current / baseline` (> 1 = slower).
    pub ratio: f64,
    pub regressed: bool,
}

/// Outcome of gating one run against a baseline.
#[derive(Debug, Clone, Default)]
pub struct GateReport {
    /// Per-leg comparisons, in current-run order.
    pub deltas: Vec<LegDelta>,
    /// Current legs with no baseline counterpart (matrix grew).
    pub unmatched_current: Vec<String>,
    /// Baseline legs missing from the current run (matrix shrank).
    pub unmatched_baseline: Vec<String>,
}

impl GateReport {
    /// Legs over the threshold.
    pub fn regressions(&self) -> Vec<&LegDelta> {
        self.deltas.iter().filter(|d| d.regressed).collect()
    }

    /// Did the gate pass (no regressed leg)?
    pub fn passed(&self) -> bool {
        self.deltas.iter().all(|d| !d.regressed)
    }

    /// Human-readable per-leg lines for the CI log.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for d in &self.deltas {
            out.push_str(&format!(
                "{} {:>24}  {:>9.1} -> {:>9.1} ns/op  ({:+.1}%)\n",
                if d.regressed { "REGRESSED" } else { "ok       " },
                d.key,
                d.baseline_ns,
                d.current_ns,
                (d.ratio - 1.0) * 100.0,
            ));
        }
        for k in &self.unmatched_current {
            out.push_str(&format!("skipped   {k:>24}  (no baseline leg)\n"));
        }
        for k in &self.unmatched_baseline {
            out.push_str(&format!("skipped   {k:>24}  (leg gone from current run)\n"));
        }
        out
    }
}

fn field_num(obj: &str, key: &str) -> Option<f64> {
    let pat = format!("\"{key}\":");
    let rest = obj[obj.find(&pat)? + pat.len()..].trim_start();
    let end = rest
        .find(|c: char| c == ',' || c == '}' || c.is_whitespace())
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

fn field_str(obj: &str, key: &str) -> Option<String> {
    let pat = format!("\"{key}\":");
    let rest = obj[obj.find(&pat)? + pat.len()..].trim_start();
    let quoted = rest.strip_prefix('"')?;
    Some(quoted[..quoted.find('"')?].to_string())
}

fn parse_leg(obj: &str) -> Option<LegResult> {
    Some(LegResult {
        shape: field_str(obj, "shape")?,
        threads: field_num(obj, "threads")? as usize,
        leg: field_str(obj, "leg")?,
        ns_op: field_num(obj, "ns_op")?,
        mops: field_num(obj, "mops").unwrap_or(0.0),
    })
}

/// Extract every contended-bench leg from a `BENCH_rq.json` document.
/// Scans for *innermost* `{...}` spans (the leg objects are flat) and
/// keeps those with the full leg field set; anything else — including
/// the legacy `contention`/`pick_path` rows — is skipped silently.
pub fn parse_legs(json: &str) -> Vec<LegResult> {
    let mut out = Vec::new();
    let mut start = None;
    for (i, b) in json.bytes().enumerate() {
        match b {
            b'{' => start = Some(i),
            b'}' => {
                if let Some(s) = start.take() {
                    if let Some(leg) = parse_leg(&json[s..=i]) {
                        out.push(leg);
                    }
                }
            }
            _ => {}
        }
    }
    out
}

/// Compare `current` legs against `baseline` by key; a leg regresses
/// when `current.ns_op / baseline.ns_op > threshold`. Unmatched legs on
/// either side are reported, never gated on.
pub fn compare(baseline: &[LegResult], current: &[LegResult], threshold: f64) -> GateReport {
    let mut report = GateReport::default();
    for cur in current {
        match baseline.iter().find(|b| b.key() == cur.key()) {
            Some(base) if base.ns_op > 0.0 => {
                let ratio = cur.ns_op / base.ns_op;
                report.deltas.push(LegDelta {
                    key: cur.key(),
                    baseline_ns: base.ns_op,
                    current_ns: cur.ns_op,
                    ratio,
                    regressed: ratio > threshold,
                });
            }
            _ => report.unmatched_current.push(cur.key()),
        }
    }
    for base in baseline {
        if !current.iter().any(|c| c.key() == base.key()) {
            report.unmatched_baseline.push(base.key());
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    fn leg(shape: &str, threads: usize, leg_name: &str, ns_op: f64) -> LegResult {
        LegResult {
            shape: shape.into(),
            threads,
            leg: leg_name.into(),
            ns_op,
            mops: if ns_op > 0.0 { 1e3 / ns_op } else { 0.0 },
        }
    }

    #[test]
    fn parses_legs_out_of_a_full_document() {
        let doc = r#"{
  "bench": "rq_scaling",
  "schema": 2,
  "git_rev": "abc1234",
  "contention": [{"threads":2,"global_mops":1.00,"percpu_mops":2.00}],
  "contended": [{"shape":"smp-4","threads":2,"leg":"locked","ns_op":81.25,"mops":12.31},
{"shape":"numa-4x4","threads":8,"leg":"lockless","ns_op":40.50,"mops":24.69}],
  "pick_path": [{"threads":4,"bucket_ns":120.00}]
}
"#;
        let legs = parse_legs(doc);
        assert_eq!(legs.len(), 2, "only full leg objects count: {legs:?}");
        assert_eq!(legs[0].key(), "smp-4/t2/locked");
        assert_eq!(legs[0].ns_op, 81.25);
        assert_eq!(legs[1].key(), "numa-4x4/t8/lockless");
        assert_eq!(legs[1].mops, 24.69);
    }

    #[test]
    fn two_x_regression_fails_the_gate() {
        let base = vec![leg("numa-4x4", 8, "lockless", 50.0)];
        let cur = vec![leg("numa-4x4", 8, "lockless", 100.0)];
        let report = compare(&base, &cur, DEFAULT_THRESHOLD);
        assert!(!report.passed());
        let regs = report.regressions();
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].key, "numa-4x4/t8/lockless");
        assert!((regs[0].ratio - 2.0).abs() < 1e-9);
        assert!(report.render().contains("REGRESSED"));
    }

    #[test]
    fn armed_baseline_trips_on_a_planted_regression() {
        // The CI arming scheme end to end, in miniature: a recorded
        // baseline document with the full contended matrix, then a
        // current run whose ns/op was multiplied by a planted factor
        // (what BENCH_INJECT_REGRESSION=2 does to the measurements).
        // Every leg is matched — nothing may be skipped — and every
        // matched leg must trip the ±25% gate.
        let doc = r#"{
  "bench": "rq_scaling",
  "schema": 2,
  "mode": "fast",
  "contended": [{"shape":"smp-4","threads":2,"leg":"locked","ns_op":80.00,"mops":12.50},
{"shape":"smp-4","threads":2,"leg":"lockless","ns_op":45.00,"mops":22.22},
{"shape":"numa-4x4","threads":8,"leg":"locked","ns_op":120.00,"mops":8.33},
{"shape":"numa-4x4","threads":8,"leg":"lockless","ns_op":40.00,"mops":25.00}]
}
"#;
        let base = parse_legs(doc);
        assert_eq!(base.len(), 4);
        let planted: Vec<LegResult> = base
            .iter()
            .map(|l| LegResult { ns_op: l.ns_op * 2.0, mops: l.mops / 2.0, ..l.clone() })
            .collect();
        let report = compare(&base, &planted, DEFAULT_THRESHOLD);
        assert!(report.unmatched_current.is_empty(), "armed baseline must match every leg");
        assert!(report.unmatched_baseline.is_empty());
        assert!(!report.passed(), "a planted 2x regression must fail the armed gate");
        assert_eq!(report.regressions().len(), 4, "every matched leg trips");
        // And the same matched baseline passes an un-planted run.
        let clean = compare(&base, &base.clone(), DEFAULT_THRESHOLD);
        assert!(clean.passed());
        assert_eq!(clean.deltas.len(), 4);
    }

    #[test]
    fn noise_within_threshold_passes() {
        let base = vec![leg("smp-4", 4, "locked", 100.0), leg("smp-4", 4, "lockless", 60.0)];
        let cur = vec![leg("smp-4", 4, "locked", 120.0), leg("smp-4", 4, "lockless", 49.0)];
        let report = compare(&base, &cur, DEFAULT_THRESHOLD);
        assert!(report.passed(), "+20% and an improvement are both inside ±25%: {report:?}");
        assert_eq!(report.deltas.len(), 2);
    }

    #[test]
    fn unmatched_legs_are_skipped_not_gated() {
        let base = vec![leg("smp-4", 2, "locked", 100.0), leg("smp-4", 16, "locked", 90.0)];
        let cur = vec![leg("smp-4", 2, "locked", 101.0), leg("numa-4x4", 2, "locked", 70.0)];
        let report = compare(&base, &cur, DEFAULT_THRESHOLD);
        assert!(report.passed());
        assert_eq!(report.unmatched_current, vec!["numa-4x4/t2/locked".to_string()]);
        assert_eq!(report.unmatched_baseline, vec!["smp-4/t16/locked".to_string()]);
        assert!(report.render().contains("skipped"));
    }

    #[test]
    fn empty_baseline_is_record_only() {
        let cur = vec![leg("smp-4", 2, "locked", 100.0)];
        let report = compare(&[], &cur, DEFAULT_THRESHOLD);
        assert!(report.passed(), "nothing to compare against cannot fail");
        assert!(report.deltas.is_empty());
        assert_eq!(report.unmatched_current.len(), 1);
    }

    #[test]
    fn zero_baseline_ns_cannot_divide() {
        let base = vec![leg("smp-4", 2, "locked", 0.0)];
        let cur = vec![leg("smp-4", 2, "locked", 100.0)];
        let report = compare(&base, &cur, DEFAULT_THRESHOLD);
        assert!(report.passed());
        assert_eq!(report.unmatched_current.len(), 1, "a 0 ns baseline leg is unusable");
    }
}
