//! criterion-lite: a minimal benchmark harness (criterion is not
//! vendored in this offline environment).
//!
//! Usage in a `harness = false` bench target:
//!
//! ```no_run
//! use bubbles::bench::Bench;
//! let mut b = Bench::new("table1");
//! b.bench("yield", || { /* measured body */ });
//! b.report();
//! ```
//!
//! Methodology: warmup iterations, then `samples` timed batches; each
//! batch auto-sizes its iteration count so a batch lasts ≥ `min_batch`;
//! Tukey outlier trimming; mean/median/σ/p95 in the report. Honors
//! `BENCH_FAST=1` for smoke runs.
//!
//! [`gate`] holds the bench *regression gate*: the comparator CI uses
//! to fail a build when a `BENCH_rq.json` run regresses past threshold
//! against the committed baseline.

pub mod gate;

use std::time::Instant;

use crate::util::fmt::{ns, Table};
use crate::util::stats::{trim_outliers, Summary};

/// One benchmark's result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration time summary (nanoseconds).
    pub summary: Summary,
    pub iters_per_sample: u64,
}

/// A named group of benchmarks.
pub struct Bench {
    group: String,
    warmup_batches: usize,
    samples: usize,
    /// Minimum batch duration, ns.
    min_batch_ns: u128,
    results: Vec<BenchResult>,
}

impl Bench {
    /// Create a bench group with default methodology (fast mode via
    /// env `BENCH_FAST=1` cuts samples for CI smoke runs).
    pub fn new(group: impl Into<String>) -> Bench {
        let fast = std::env::var("BENCH_FAST").map(|v| v == "1").unwrap_or(false);
        Bench {
            group: group.into(),
            warmup_batches: if fast { 1 } else { 3 },
            samples: if fast { 10 } else { 40 },
            min_batch_ns: if fast { 200_000 } else { 2_000_000 },
            results: Vec::new(),
        }
    }

    /// Override the sample count.
    pub fn samples(mut self, n: usize) -> Bench {
        self.samples = n;
        self
    }

    /// Measure a closure. The closure is the *iteration body*; batching
    /// is automatic.
    pub fn bench(&mut self, name: impl Into<String>, mut f: impl FnMut()) -> &BenchResult {
        // Determine batch size: grow until a batch exceeds min_batch.
        let mut iters: u64 = 1;
        loop {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            let dt = t0.elapsed().as_nanos();
            if dt >= self.min_batch_ns || iters >= 1 << 24 {
                break;
            }
            // Aim directly at the target with 2x headroom.
            let scale = (self.min_batch_ns as f64 / dt.max(1) as f64 * 2.0).ceil();
            iters = (iters as f64 * scale.clamp(2.0, 1024.0)) as u64;
        }
        for _ in 0..self.warmup_batches {
            for _ in 0..iters {
                f();
            }
        }
        let mut per_iter = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            per_iter.push(t0.elapsed().as_nanos() as f64 / iters as f64);
        }
        let kept = trim_outliers(&per_iter, 3.0);
        self.results.push(BenchResult {
            name: name.into(),
            summary: Summary::of(&kept),
            iters_per_sample: iters,
        });
        self.results.last().unwrap()
    }

    /// Measure a closure that returns its own duration in ns (for
    /// bodies that must exclude setup time).
    pub fn bench_timed(
        &mut self,
        name: impl Into<String>,
        mut f: impl FnMut() -> f64,
    ) -> &BenchResult {
        for _ in 0..self.warmup_batches {
            f();
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            samples.push(f());
        }
        let kept = trim_outliers(&samples, 3.0);
        self.results.push(BenchResult {
            name: name.into(),
            summary: Summary::of(&kept),
            iters_per_sample: 1,
        });
        self.results.last().unwrap()
    }

    /// Access collected results.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Print the group report.
    pub fn report(&self) {
        println!("\n== bench group: {} ==", self.group);
        let mut t = Table::new(&["name", "mean", "median", "p95", "stddev", "iters"]);
        for r in &self.results {
            t.row(&[
                r.name.clone(),
                ns(r.summary.mean),
                ns(r.summary.median),
                ns(r.summary.p95),
                ns(r.summary.stddev),
                r.iters_per_sample.to_string(),
            ]);
        }
        print!("{}", t.render());
    }
}

/// Prevent the optimizer from discarding a value (ptr-read black box,
/// same trick std::hint::black_box uses; we avoid the std one only on
/// MSRV grounds — it exists here, so delegate).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_numbers() {
        std::env::set_var("BENCH_FAST", "1");
        let mut b = Bench::new("test");
        let r = b
            .bench("spin50", || {
                let mut acc = 0u64;
                for i in 0..50 {
                    acc = acc.wrapping_add(black_box(i));
                }
                black_box(acc);
            })
            .clone();
        assert!(r.summary.mean > 0.0);
        assert!(r.summary.mean < 100_000.0, "50 adds should be fast: {}", r.summary.mean);
        assert!(r.iters_per_sample >= 1);
    }

    #[test]
    fn bench_timed_collects_samples() {
        std::env::set_var("BENCH_FAST", "1");
        let mut b = Bench::new("test");
        let mut k = 0.0;
        let r = b.bench_timed("fixed", || {
            k += 1.0;
            100.0 + k
        });
        assert!(r.summary.mean > 100.0);
    }

    #[test]
    fn ordering_of_magnitudes() {
        // A 10x heavier body must measure meaningfully slower.
        std::env::set_var("BENCH_FAST", "1");
        let mut b = Bench::new("test");
        let light = b
            .bench("light", || {
                let mut a = 0u64;
                for i in 0..20u64 {
                    a = a.wrapping_add(black_box(i));
                }
                black_box(a);
            })
            .summary
            .mean;
        let heavy = b
            .bench("heavy", || {
                let mut a = 0u64;
                for i in 0..2000u64 {
                    a = a.wrapping_add(black_box(i));
                }
                black_box(a);
            })
            .summary
            .mean;
        assert!(heavy > light * 3.0, "heavy {heavy} vs light {light}");
    }
}
