//! # NUMA memory subsystem: where data lives, and whose it is.
//!
//! The paper's argument is that hierarchical scheduling pays off only
//! when threads run *near their data* ("accessing the memory of its own
//! node is about 3 times faster", §5.2) — and its follow-up work makes
//! joint thread+memory affinity the point (ForestGOMP, arXiv 0706.2073).
//! This module gives the scheduler that missing notion of data:
//!
//! * [`registry::RegionRegistry`] — the **region registry**: every
//!   application memory block is a [`RegionId`] with a size, a home
//!   NUMA node (first-touch, round-robin or explicit, §2.3), touch
//!   statistics, and an optional owning task.
//! * [`footprint::Footprint`] — **per-task and per-bubble footprint
//!   accounting**: incremental per-node byte counters aggregated up the
//!   bubble hierarchy like `LoadStats` aggregates running counts up the
//!   machine hierarchy, so "where does this bubble's memory live?" is
//!   O(nodes), not O(regions).
//! * **Next-touch migration**: a region marked next-touch re-homes onto
//!   the node of the next CPU touching it, letting memory follow a
//!   migrated thread; migrated bytes surface in
//!   [`crate::metrics::Metrics`].
//!
//! * **Striped regions** ([`MemState::alloc_striped`]): one region
//!   split across several home nodes, per-stripe touch attribution and
//!   per-stripe next-touch migration — see [`registry`].
//! * **Pressure view** ([`MemState::node_pressure`] /
//!   [`MemState::pressure_view`]): per-node homed-byte counters the
//!   pick and steal paths consult for footprint *headroom* (the
//!   pressure-aware pass 1 in [`crate::sched::core::pick`], and the
//!   `memaware` steal tie-break and wake fallback). The counters are
//!   versioned by [`MemState::pressure_epoch`], so per-pick readers can
//!   cache a snapshot ([`MemState::pressure_view_into`]) and refresh
//!   only when placement moved.
//! * **Lock-free steady-state touches**: a touch of a homed, unmarked
//!   region commits through atomics ([`RegionRegistry::touch_fast`])
//!   without the registry mutex *or* the `sync` mutex below — see
//!   [`MemState::touch`].
//!
//! [`MemState`] bundles the two and keeps them consistent: every
//! operation that changes a region's home or owner applies the matching
//! footprint delta. It hangs off [`crate::sched::System`] so policies
//! (e.g. `memaware`, see [`crate::sched::MemAwareScheduler`]) can
//! consult it on the wake/pick/steal paths. Both engines touch regions
//! through [`crate::sched::System::touch_region`]: the simulator on
//! every memory-bound compute chunk, the native executor from green
//! threads via `GreenApi::touch_region` — so footprints, next-touch
//! migration and the local/remote access metrics are engine-agnostic.
//!
//! **Conservation invariant** (checked by [`MemState::conserved`] /
//! [`MemState::hierarchy_consistent`] and the `mem_props` +
//! `mem_striping` + `policy_conformance` suites): at every step, the
//! sum of per-node bytes over root tasks equals the total size of
//! attached, homed regions, and every bubble's footprint equals the sum
//! of its subtree's.

pub mod arena;
pub mod footprint;
pub mod registry;

pub use arena::ArenaSet;
pub use footprint::Footprint;
pub use registry::{
    AllocPolicy, HomeChange, RegionId, RegionInfo, RegionRegistry, Stripe, Touch,
    DEFAULT_REGION_BYTES,
};

use std::sync::Mutex;

use crate::task::{TaskId, TaskTable};
use crate::topology::{CpuId, Topology};

/// Registry + footprint, kept mutually consistent.
#[derive(Debug)]
pub struct MemState {
    pub regions: RegionRegistry,
    pub footprint: Footprint,
    /// Serialises the registry-delta → footprint-update pairs in
    /// [`MemState::attach`]/[`MemState::touch`]/[`MemState::note_insert`]:
    /// without it, a concurrent attach and first touch of one region
    /// could interleave their deltas and double-charge bytes, breaking
    /// the conservation invariant for good.
    sync: Mutex<()>,
    /// Optional real `mmap` backing per region (native engine,
    /// `--arena`): touches additionally walk real pages so first-touch /
    /// next-touch measure actual cross-node behaviour. Disabled (and
    /// free) by default — see [`arena::ArenaSet`].
    pub arenas: ArenaSet,
}

impl MemState {
    /// Fresh memory state for a machine.
    pub fn new(topo: &Topology) -> MemState {
        let n = topo.n_numa().max(1);
        MemState {
            regions: RegionRegistry::new(n),
            footprint: Footprint::new(n),
            sync: Mutex::new(()),
            arenas: ArenaSet::new(),
        }
    }

    /// Back *subsequent* allocations with real `mmap` arenas (see
    /// [`arena::ArenaSet`]). Off by default; failure to map or bind any
    /// individual region degrades that region to counter-only mode.
    pub fn enable_arenas(&self) {
        self.arenas.set_enabled(true);
    }

    /// Allocate a region of `size` bytes under `policy`.
    pub fn alloc(&self, size: u64, policy: AllocPolicy) -> RegionId {
        let home = if let AllocPolicy::Fixed(n) = policy { Some(n) } else { None };
        let r = self.regions.alloc(size, policy);
        self.arenas.back(r, size, home);
        r
    }

    /// Allocate a striped region of `size` bytes spread over `nodes`
    /// (see [`RegionRegistry::alloc_striped`]).
    pub fn alloc_striped(&self, size: u64, nodes: &[usize]) -> RegionId {
        let r = self.regions.alloc_striped(size, nodes);
        // One mapping per region, with each stripe's page range bound
        // to its declared node so the kernel layout mirrors the model
        // (best-effort; rejections count in [`ArenaSet::bind_failures`]).
        self.arenas.back_striped(r, size, &self.regions.info(r).stripes);
        r
    }

    /// Attach a region to `task`: its bytes count towards the task's
    /// (and every enclosing bubble's) footprint once the region is
    /// homed — per stripe for striped regions. Re-attaching moves the
    /// bytes to the new owner.
    pub fn attach(&self, tasks: &TaskTable, task: TaskId, r: RegionId) {
        let _sync = self.sync.lock().unwrap();
        let (prev, deltas) = self.regions.attach(r, task);
        for delta in deltas {
            if let HomeChange::Homed { node, size, .. } = delta {
                if let Some(old) = prev {
                    if old != task {
                        self.footprint.sub(tasks, old, node, size);
                    }
                }
                if prev != Some(task) {
                    self.footprint.add(tasks, task, node, size);
                }
            }
        }
    }

    /// Record a touch by `cpu`: resolves the home (first touch homes,
    /// next-touch migrates) and keeps the footprint in sync.
    ///
    /// Steady-state touches (region homed, no next-touch mark pending)
    /// commit lock-free through [`RegionRegistry::touch_fast`]: they
    /// change no placement, so there is no registry→footprint delta to
    /// serialise and the `sync` mutex — the old per-touch bottleneck
    /// for native workers — is skipped entirely. Placement-changing
    /// touches still queue on it, preserving conservation.
    ///
    /// With arenas enabled the touch additionally walks a window of the
    /// region's real backing pages (both paths — see [`arena::ArenaSet`]).
    pub fn touch(&self, tasks: &TaskTable, topo: &Topology, r: RegionId, cpu: CpuId) -> Touch {
        if let Some(touch) = self.regions.touch_fast(r, cpu) {
            self.arenas.touch(r);
            return touch;
        }
        let _sync = self.sync.lock().unwrap();
        let node = topo.numa_of(cpu);
        let (touch, delta) = self.regions.touch(r, cpu, node);
        match delta {
            Some(HomeChange::Homed { owner: Some(owner), node, size }) => {
                self.footprint.add(tasks, owner, node, size);
            }
            Some(HomeChange::Moved { owner: Some(owner), from, to, size }) => {
                self.footprint.rehome(tasks, owner, from, to, size);
            }
            _ => {}
        }
        self.arenas.touch(r);
        touch
    }

    /// Home node of a region (None before first touch; None for
    /// striped regions — their homes are per stripe).
    pub fn home(&self, r: RegionId) -> Option<usize> {
        self.regions.home(r)
    }

    /// Bytes of homed regions on `node` — the node's memory pressure
    /// (lock-free, advisory).
    pub fn node_pressure(&self, node: usize) -> u64 {
        self.regions.node_pressure(node)
    }

    /// Per-node homed-bytes snapshot (index = NUMA node).
    pub fn pressure_view(&self) -> Vec<u64> {
        self.regions.pressure_view()
    }

    /// Allocation-free [`Self::pressure_view`] into a caller buffer.
    pub fn pressure_view_into(&self, out: &mut Vec<u64>) {
        self.regions.pressure_view_into(out);
    }

    /// Monotonic pressure version: moves exactly when some node's homed
    /// bytes do, so per-pick readers can cache a snapshot and refresh
    /// only on change.
    pub fn pressure_epoch(&self) -> u64 {
        self.regions.pressure_epoch()
    }

    /// Snapshot of one region.
    pub fn info(&self, r: RegionId) -> RegionInfo {
        self.regions.info(r)
    }

    /// Mark one region for next-touch migration.
    pub fn mark_next_touch(&self, r: RegionId) {
        self.regions.mark_next_touch(r);
    }

    /// Mark every region attached to `task` for next-touch migration;
    /// returns the bytes marked.
    pub fn mark_task_regions_next_touch(&self, task: TaskId) -> u64 {
        self.regions.mark_owner_next_touch(task)
    }

    /// Node holding the plurality of `task`'s footprint (bubbles
    /// aggregate their contents).
    pub fn dominant_node(&self, task: TaskId) -> Option<usize> {
        self.footprint.dominant_node(task)
    }

    /// `task` was inserted into a bubble *after* regions were already
    /// attached to it: fold its footprint into the new enclosing
    /// bubbles ([`crate::marcel::Marcel::bubble_inserttask`] calls
    /// this, so attach/insert order does not matter).
    pub fn note_insert(&self, tasks: &TaskTable, task: TaskId) {
        let _sync = self.sync.lock().unwrap();
        self.footprint.on_insert(tasks, task);
    }

    /// Conservation check: per-node bytes summed over *root* tasks
    /// (tasks without an enclosing bubble) must equal the total size of
    /// attached, homed regions. O(tasks × nodes) — test/debug use.
    pub fn conserved(&self, tasks: &TaskTable) -> bool {
        let mut accounted = 0u64;
        for id in tasks.ids() {
            if tasks.parent(id).is_none() {
                accounted += self.footprint.total(id);
            }
        }
        accounted == self.regions.attached_homed_bytes()
    }

    /// Strong per-task/per-bubble conservation: rebuild every task's
    /// expected per-node footprint from the region registry (each
    /// attached, homed region charges its owner and every enclosing
    /// bubble — per stripe for striped regions) and compare against the
    /// incremental counters. Subsumes [`Self::conserved`]; O(regions ×
    /// depth + tasks × nodes) — test/debug use.
    pub fn hierarchy_consistent(&self, tasks: &TaskTable) -> bool {
        let n = self.footprint.n_nodes();
        let ids: Vec<TaskId> = tasks.ids();
        let mut expected: std::collections::HashMap<TaskId, Vec<u64>> =
            ids.iter().map(|&t| (t, vec![0u64; n])).collect();
        for region in self.regions.snapshot() {
            let Some(owner) = region.owner else { continue };
            let bytes = region.homed_bytes_per_node(n);
            // Charge the owner and every enclosing bubble.
            let mut cur = Some(owner);
            while let Some(t) = cur {
                let slot = expected.entry(t).or_insert_with(|| vec![0u64; n]);
                for (node, b) in bytes.iter().enumerate() {
                    slot[node] += b;
                }
                cur = tasks.parent(t);
            }
        }
        ids.iter().all(|&t| self.footprint.of(t) == expected[&t])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::task::{PRIO_BUBBLE, PRIO_THREAD};

    fn numa22() -> Topology {
        Topology::numa(2, 2)
    }

    #[test]
    fn attach_then_first_touch_accounts_once() {
        let topo = numa22();
        let mem = MemState::new(&topo);
        let tasks = TaskTable::new();
        let t = tasks.new_thread("t", PRIO_THREAD);
        let r = mem.alloc(100, AllocPolicy::FirstTouch);
        mem.attach(&tasks, t, r);
        assert!(mem.conserved(&tasks), "unhomed region needs no accounting");
        assert_eq!(mem.dominant_node(t), None);
        // First touch on cpu2 (node 1) homes the region and charges it.
        mem.touch(&tasks, &topo, r, CpuId(2));
        assert_eq!(mem.home(r), Some(1));
        assert_eq!(mem.dominant_node(t), Some(1));
        assert!(mem.conserved(&tasks));
    }

    #[test]
    fn bubble_footprint_aggregates_members() {
        let topo = numa22();
        let mem = MemState::new(&topo);
        let tasks = TaskTable::new();
        let b = tasks.new_bubble("b", PRIO_BUBBLE);
        let t0 = tasks.new_thread("t0", PRIO_THREAD);
        let t1 = tasks.new_thread("t1", PRIO_THREAD);
        tasks.with(t0, |x| x.parent = Some(b));
        tasks.with(t1, |x| x.parent = Some(b));
        let r0 = mem.alloc(300, AllocPolicy::Fixed(0));
        let r1 = mem.alloc(100, AllocPolicy::Fixed(1));
        mem.attach(&tasks, t0, r0);
        mem.attach(&tasks, t1, r1);
        assert_eq!(mem.dominant_node(b), Some(0));
        assert_eq!(mem.footprint.of(b), vec![300, 100]);
        assert!(mem.conserved(&tasks));
    }

    #[test]
    fn next_touch_migration_rebalances_footprint() {
        let topo = numa22();
        let mem = MemState::new(&topo);
        let tasks = TaskTable::new();
        let t = tasks.new_thread("t", PRIO_THREAD);
        let r = mem.alloc(200, AllocPolicy::Fixed(0));
        mem.attach(&tasks, t, r);
        assert_eq!(mem.dominant_node(t), Some(0));
        mem.mark_task_regions_next_touch(t);
        let touch = mem.touch(&tasks, &topo, r, CpuId(3)); // node 1
        assert_eq!(touch.migrated, 200);
        assert_eq!(mem.home(r), Some(1));
        assert_eq!(mem.dominant_node(t), Some(1));
        assert!(mem.conserved(&tasks));
    }

    #[test]
    fn striped_attach_charges_each_declared_node() {
        let topo = numa22();
        let mem = MemState::new(&topo);
        let tasks = TaskTable::new();
        let b = tasks.new_bubble("b", PRIO_BUBBLE);
        let t = tasks.new_thread("t", PRIO_THREAD);
        tasks.with(t, |x| x.parent = Some(b));
        let r = mem.alloc_striped(100, &[0, 1]);
        mem.attach(&tasks, t, r);
        assert_eq!(mem.footprint.of(t), vec![50, 50]);
        assert_eq!(mem.footprint.of(b), vec![50, 50]);
        assert!(mem.conserved(&tasks));
        assert!(mem.hierarchy_consistent(&tasks));
        // Striped next-touch moves one stripe; the footprint follows.
        mem.mark_next_touch(r);
        let touch = mem.touch(&tasks, &topo, r, CpuId(3)); // node 1, stripe 0
        assert_eq!(touch.migrated, 50);
        assert_eq!(mem.footprint.of(b), vec![0, 100]);
        assert_eq!(mem.pressure_view(), vec![0, 100]);
        assert!(mem.hierarchy_consistent(&tasks));
    }

    #[test]
    fn pressure_helpers_expose_headroom() {
        let topo = numa22();
        let mem = MemState::new(&topo);
        assert_eq!(mem.pressure_view(), vec![0, 0]);
        let _ = mem.alloc(100, AllocPolicy::Fixed(0));
        assert_eq!(mem.node_pressure(0), 100);
        assert_eq!(mem.node_pressure(1), 0);
        let _ = mem.alloc(200, AllocPolicy::Fixed(1));
        assert_eq!(mem.pressure_view(), vec![100, 200]);
    }

    #[test]
    fn steady_state_touches_skip_the_sync_lock_and_conserve() {
        let topo = numa22();
        let mem = MemState::new(&topo);
        let tasks = TaskTable::new();
        let t = tasks.new_thread("t", PRIO_THREAD);
        let r = mem.alloc(100, AllocPolicy::FirstTouch);
        mem.attach(&tasks, t, r);
        mem.touch(&tasks, &topo, r, CpuId(0)); // first touch: slow path, homes on node 0
        let epoch = mem.pressure_epoch();
        // Hold the sync mutex across a steady-state touch: if the touch
        // needed the lock (fast path regressed), this would deadlock.
        let guard = mem.sync.lock().unwrap();
        let touch = mem.touch(&tasks, &topo, r, CpuId(3));
        drop(guard);
        assert_eq!((touch.home, touch.migrated), (0, 0));
        assert_eq!(touch.last_toucher, Some(CpuId(0)));
        assert_eq!(mem.pressure_epoch(), epoch, "no placement change, no epoch move");
        assert_eq!(mem.regions.info(r).touches, 2);
        assert!(mem.conserved(&tasks));
        assert!(mem.hierarchy_consistent(&tasks));
    }

    #[test]
    fn arena_backed_touches_walk_real_bytes_and_conserve() {
        let topo = numa22();
        let mem = MemState::new(&topo);
        mem.enable_arenas();
        let tasks = TaskTable::new();
        let t = tasks.new_thread("t", PRIO_THREAD);
        let r = mem.alloc(8192, AllocPolicy::Fixed(0));
        mem.attach(&tasks, t, r);
        mem.touch(&tasks, &topo, r, CpuId(0)); // slow path (first resolve)
        mem.touch(&tasks, &topo, r, CpuId(1)); // fast path
        let (bytes, touches) = mem.arenas.stats();
        // On platforms without mmap the region degrades to counter-only.
        if bytes > 0 {
            assert_eq!(touches, 2, "both touch paths must walk the arena");
        }
        assert!(mem.conserved(&tasks));
        assert!(mem.hierarchy_consistent(&tasks));
    }

    #[test]
    fn striped_alloc_binds_per_stripe_when_arenas_on() {
        let topo = numa22();
        let mem = MemState::new(&topo);
        mem.enable_arenas();
        let r = mem.alloc_striped(8192, &[0, 1]);
        assert_eq!(mem.info(r).stripes.len(), 2);
        let (bytes, _) = mem.arenas.stats();
        // mmap may be unavailable off-Linux; when it works, the
        // per-stripe binds are best-effort (at most one failure each).
        if bytes > 0 {
            assert_eq!(bytes, 8192);
        }
        assert!(mem.arenas.bind_failures() <= 2);
    }

    #[test]
    fn reattach_moves_bytes_between_owners() {
        let topo = numa22();
        let mem = MemState::new(&topo);
        let tasks = TaskTable::new();
        let a = tasks.new_thread("a", PRIO_THREAD);
        let b = tasks.new_thread("b", PRIO_THREAD);
        let r = mem.alloc(64, AllocPolicy::Fixed(1));
        mem.attach(&tasks, a, r);
        assert_eq!(mem.footprint.total(a), 64);
        mem.attach(&tasks, b, r);
        assert_eq!(mem.footprint.total(a), 0);
        assert_eq!(mem.footprint.total(b), 64);
        assert!(mem.conserved(&tasks));
    }
}
