//! # NUMA memory subsystem: where data lives, and whose it is.
//!
//! The paper's argument is that hierarchical scheduling pays off only
//! when threads run *near their data* ("accessing the memory of its own
//! node is about 3 times faster", §5.2) — and its follow-up work makes
//! joint thread+memory affinity the point (ForestGOMP, arXiv 0706.2073).
//! This module gives the scheduler that missing notion of data:
//!
//! * [`registry::RegionRegistry`] — the **region registry**: every
//!   application memory block is a [`RegionId`] with a size, a home
//!   NUMA node (first-touch, round-robin or explicit, §2.3), touch
//!   statistics, and an optional owning task.
//! * [`footprint::Footprint`] — **per-task and per-bubble footprint
//!   accounting**: incremental per-node byte counters aggregated up the
//!   bubble hierarchy like `LoadStats` aggregates running counts up the
//!   machine hierarchy, so "where does this bubble's memory live?" is
//!   O(nodes), not O(regions).
//! * **Next-touch migration**: a region marked next-touch re-homes onto
//!   the node of the next CPU touching it, letting memory follow a
//!   migrated thread; migrated bytes surface in
//!   [`crate::metrics::Metrics`].
//!
//! [`MemState`] bundles the two and keeps them consistent: every
//! operation that changes a region's home or owner applies the matching
//! footprint delta. It hangs off [`crate::sched::System`] so policies
//! (e.g. `memaware`, see [`crate::sched::MemAwareScheduler`]) can
//! consult it on the wake/pick/steal paths.
//!
//! **Conservation invariant** (checked by [`MemState::conserved`] and
//! the `mem_props` integration suite): at every step, the sum of
//! per-node bytes over root tasks equals the total size of attached,
//! homed regions.

pub mod footprint;
pub mod registry;

pub use footprint::Footprint;
pub use registry::{
    AllocPolicy, HomeChange, RegionId, RegionInfo, RegionRegistry, Touch, DEFAULT_REGION_BYTES,
};

use std::sync::Mutex;

use crate::task::{TaskId, TaskTable};
use crate::topology::{CpuId, Topology};

/// Registry + footprint, kept mutually consistent.
#[derive(Debug)]
pub struct MemState {
    pub regions: RegionRegistry,
    pub footprint: Footprint,
    /// Serialises the registry-delta → footprint-update pairs in
    /// [`MemState::attach`]/[`MemState::touch`]/[`MemState::note_insert`]:
    /// without it, a concurrent attach and first touch of one region
    /// could interleave their deltas and double-charge bytes, breaking
    /// the conservation invariant for good.
    sync: Mutex<()>,
}

impl MemState {
    /// Fresh memory state for a machine.
    pub fn new(topo: &Topology) -> MemState {
        let n = topo.n_numa().max(1);
        MemState {
            regions: RegionRegistry::new(n),
            footprint: Footprint::new(n),
            sync: Mutex::new(()),
        }
    }

    /// Allocate a region of `size` bytes under `policy`.
    pub fn alloc(&self, size: u64, policy: AllocPolicy) -> RegionId {
        self.regions.alloc(size, policy)
    }

    /// Attach a region to `task`: its bytes count towards the task's
    /// (and every enclosing bubble's) footprint once the region is
    /// homed. Re-attaching moves the bytes to the new owner.
    pub fn attach(&self, tasks: &TaskTable, task: TaskId, r: RegionId) {
        let _sync = self.sync.lock().unwrap();
        let (prev, delta) = self.regions.attach(r, task);
        if let Some(HomeChange::Homed { node, size, .. }) = delta {
            if let Some(old) = prev {
                if old != task {
                    self.footprint.sub(tasks, old, node, size);
                }
            }
            if prev != Some(task) {
                self.footprint.add(tasks, task, node, size);
            }
        }
    }

    /// Record a touch by `cpu`: resolves the home (first touch homes,
    /// next-touch migrates) and keeps the footprint in sync.
    pub fn touch(&self, tasks: &TaskTable, topo: &Topology, r: RegionId, cpu: CpuId) -> Touch {
        let _sync = self.sync.lock().unwrap();
        let node = topo.numa_of(cpu);
        let (touch, delta) = self.regions.touch(r, cpu, node);
        match delta {
            Some(HomeChange::Homed { owner: Some(owner), node, size }) => {
                self.footprint.add(tasks, owner, node, size);
            }
            Some(HomeChange::Moved { owner: Some(owner), from, to, size }) => {
                self.footprint.rehome(tasks, owner, from, to, size);
            }
            _ => {}
        }
        touch
    }

    /// Home node of a region (None before first touch).
    pub fn home(&self, r: RegionId) -> Option<usize> {
        self.regions.home(r)
    }

    /// Snapshot of one region.
    pub fn info(&self, r: RegionId) -> RegionInfo {
        self.regions.info(r)
    }

    /// Mark one region for next-touch migration.
    pub fn mark_next_touch(&self, r: RegionId) {
        self.regions.mark_next_touch(r);
    }

    /// Mark every region attached to `task` for next-touch migration;
    /// returns the bytes marked.
    pub fn mark_task_regions_next_touch(&self, task: TaskId) -> u64 {
        self.regions.mark_owner_next_touch(task)
    }

    /// Node holding the plurality of `task`'s footprint (bubbles
    /// aggregate their contents).
    pub fn dominant_node(&self, task: TaskId) -> Option<usize> {
        self.footprint.dominant_node(task)
    }

    /// `task` was inserted into a bubble *after* regions were already
    /// attached to it: fold its footprint into the new enclosing
    /// bubbles ([`crate::marcel::Marcel::bubble_inserttask`] calls
    /// this, so attach/insert order does not matter).
    pub fn note_insert(&self, tasks: &TaskTable, task: TaskId) {
        let _sync = self.sync.lock().unwrap();
        self.footprint.on_insert(tasks, task);
    }

    /// Conservation check: per-node bytes summed over *root* tasks
    /// (tasks without an enclosing bubble) must equal the total size of
    /// attached, homed regions. O(tasks × nodes) — test/debug use.
    pub fn conserved(&self, tasks: &TaskTable) -> bool {
        let mut accounted = 0u64;
        for id in tasks.ids() {
            if tasks.parent(id).is_none() {
                accounted += self.footprint.total(id);
            }
        }
        accounted == self.regions.attached_homed_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::task::{PRIO_BUBBLE, PRIO_THREAD};

    fn numa22() -> Topology {
        Topology::numa(2, 2)
    }

    #[test]
    fn attach_then_first_touch_accounts_once() {
        let topo = numa22();
        let mem = MemState::new(&topo);
        let tasks = TaskTable::new();
        let t = tasks.new_thread("t", PRIO_THREAD);
        let r = mem.alloc(100, AllocPolicy::FirstTouch);
        mem.attach(&tasks, t, r);
        assert!(mem.conserved(&tasks), "unhomed region needs no accounting");
        assert_eq!(mem.dominant_node(t), None);
        // First touch on cpu2 (node 1) homes the region and charges it.
        mem.touch(&tasks, &topo, r, CpuId(2));
        assert_eq!(mem.home(r), Some(1));
        assert_eq!(mem.dominant_node(t), Some(1));
        assert!(mem.conserved(&tasks));
    }

    #[test]
    fn bubble_footprint_aggregates_members() {
        let topo = numa22();
        let mem = MemState::new(&topo);
        let tasks = TaskTable::new();
        let b = tasks.new_bubble("b", PRIO_BUBBLE);
        let t0 = tasks.new_thread("t0", PRIO_THREAD);
        let t1 = tasks.new_thread("t1", PRIO_THREAD);
        tasks.with(t0, |x| x.parent = Some(b));
        tasks.with(t1, |x| x.parent = Some(b));
        let r0 = mem.alloc(300, AllocPolicy::Fixed(0));
        let r1 = mem.alloc(100, AllocPolicy::Fixed(1));
        mem.attach(&tasks, t0, r0);
        mem.attach(&tasks, t1, r1);
        assert_eq!(mem.dominant_node(b), Some(0));
        assert_eq!(mem.footprint.of(b), vec![300, 100]);
        assert!(mem.conserved(&tasks));
    }

    #[test]
    fn next_touch_migration_rebalances_footprint() {
        let topo = numa22();
        let mem = MemState::new(&topo);
        let tasks = TaskTable::new();
        let t = tasks.new_thread("t", PRIO_THREAD);
        let r = mem.alloc(200, AllocPolicy::Fixed(0));
        mem.attach(&tasks, t, r);
        assert_eq!(mem.dominant_node(t), Some(0));
        mem.mark_task_regions_next_touch(t);
        let touch = mem.touch(&tasks, &topo, r, CpuId(3)); // node 1
        assert_eq!(touch.migrated, 200);
        assert_eq!(mem.home(r), Some(1));
        assert_eq!(mem.dominant_node(t), Some(1));
        assert!(mem.conserved(&tasks));
    }

    #[test]
    fn reattach_moves_bytes_between_owners() {
        let topo = numa22();
        let mem = MemState::new(&topo);
        let tasks = TaskTable::new();
        let a = tasks.new_thread("a", PRIO_THREAD);
        let b = tasks.new_thread("b", PRIO_THREAD);
        let r = mem.alloc(64, AllocPolicy::Fixed(1));
        mem.attach(&tasks, a, r);
        assert_eq!(mem.footprint.total(a), 64);
        mem.attach(&tasks, b, r);
        assert_eq!(mem.footprint.total(a), 0);
        assert_eq!(mem.footprint.total(b), 64);
        assert!(mem.conserved(&tasks));
    }
}
