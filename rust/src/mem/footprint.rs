//! Per-task / per-bubble memory footprint accounting.
//!
//! Incrementally-maintained per-NUMA-node byte counters, aggregated up
//! the *bubble* hierarchy exactly like [`crate::sched::core::stats::LoadStats`]
//! aggregates running counts up the *machine* hierarchy: when a region
//! homed on node `n` is attached to (or re-homed under) a task, `n`'s
//! byte counter is bumped for that task **and every enclosing bubble**
//! (O(nesting depth)). A policy can then ask "where does this bubble's
//! memory live?" in O(nodes) without walking its contents.
//!
//! Each (task, node) counter is an `AtomicU64`: mutation is a lock-free
//! atomic op, so native workers touching regions concurrently never
//! serialize on a table-wide mutex. The outer `RwLock` exists only to
//! grow the table on first sight of a task id — the hot paths take the
//! shared side. Multi-counter updates (`rehome`'s sub+add pair, the
//! chain walk) are not one atomic transaction; a concurrent reader can
//! see a transient split, which is fine for counters that are advisory
//! while running and checked (conservation invariants) at quiescence.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

use crate::task::{TaskId, TaskTable};

/// Per-task per-node footprint byte counters (subtree-aggregated).
#[derive(Debug)]
pub struct Footprint {
    n_nodes: usize,
    /// `foot[task.0][node]` = bytes of attached regions homed on `node`
    /// owned by the task or anything nested under it (for bubbles).
    foot: RwLock<Vec<Box<[AtomicU64]>>>,
}

/// The bubble chain of a task: itself, then every enclosing bubble.
fn chain(tasks: &TaskTable, task: TaskId) -> Vec<TaskId> {
    let mut out = vec![task];
    let mut cur = task;
    while let Some(p) = tasks.parent(cur) {
        out.push(p);
        cur = p;
    }
    out
}

impl Footprint {
    /// Zeroed counters for a machine with `n_nodes` NUMA nodes.
    pub fn new(n_nodes: usize) -> Footprint {
        Footprint { n_nodes: n_nodes.max(1), foot: RwLock::new(Vec::new()) }
    }

    /// Number of NUMA nodes accounted.
    pub fn n_nodes(&self) -> usize {
        self.n_nodes
    }

    /// Make sure rows up to `max_task` exist (write lock only when the
    /// table actually needs to grow).
    fn ensure(&self, max_task: usize) {
        if self.foot.read().unwrap().len() > max_task {
            return;
        }
        let mut w = self.foot.write().unwrap();
        while w.len() <= max_task {
            w.push((0..self.n_nodes).map(|_| AtomicU64::new(0)).collect());
        }
    }

    /// `bytes` homed on `node` now belong to `task`: bump the task and
    /// every enclosing bubble.
    pub fn add(&self, tasks: &TaskTable, task: TaskId, node: usize, bytes: u64) {
        let chain = chain(tasks, task);
        self.ensure(chain.iter().map(|t| t.0).max().unwrap_or(0));
        let foot = self.foot.read().unwrap();
        for t in chain {
            foot[t.0][node].fetch_add(bytes, Ordering::Relaxed);
        }
    }

    /// `bytes` on `node` no longer belong to `task` (detach or re-home).
    /// Saturating, so an unbalanced call cannot wrap the counters.
    pub fn sub(&self, tasks: &TaskTable, task: TaskId, node: usize, bytes: u64) {
        let chain = chain(tasks, task);
        self.ensure(chain.iter().map(|t| t.0).max().unwrap_or(0));
        let foot = self.foot.read().unwrap();
        for t in chain {
            let _ = foot[t.0][node]
                .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                    Some(v.saturating_sub(bytes))
                });
        }
    }

    /// A region owned by `task` migrated from node `from` to node `to`.
    pub fn rehome(&self, tasks: &TaskTable, task: TaskId, from: usize, to: usize, bytes: u64) {
        if from == to {
            return;
        }
        let chain = chain(tasks, task);
        self.ensure(chain.iter().map(|t| t.0).max().unwrap_or(0));
        let foot = self.foot.read().unwrap();
        for t in chain {
            let row = &foot[t.0];
            let _ = row[from].fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                Some(v.saturating_sub(bytes))
            });
            row[to].fetch_add(bytes, Ordering::Relaxed);
        }
    }

    /// `task` (with its whole subtree footprint) was just inserted into
    /// a bubble: fold its existing bytes into every *new* enclosing
    /// bubble, so attach-before-insert and insert-before-attach agree.
    /// Call after the parent link is set, once per insertion.
    pub fn on_insert(&self, tasks: &TaskTable, task: TaskId) {
        let mut ancestors = chain(tasks, task);
        ancestors.remove(0); // the task itself is already charged
        if ancestors.is_empty() {
            return;
        }
        let own = self.of(task);
        if own.iter().all(|&b| b == 0) {
            return;
        }
        self.ensure(ancestors.iter().map(|t| t.0).max().unwrap_or(0));
        let foot = self.foot.read().unwrap();
        for t in ancestors {
            for (node, &bytes) in own.iter().enumerate() {
                foot[t.0][node].fetch_add(bytes, Ordering::Relaxed);
            }
        }
    }

    /// Per-node byte vector of a task's (subtree) footprint.
    pub fn of(&self, task: TaskId) -> Vec<u64> {
        let foot = self.foot.read().unwrap();
        match foot.get(task.0) {
            Some(row) => row.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            None => vec![0; self.n_nodes],
        }
    }

    /// Bytes of `task`'s footprint homed on `node`.
    pub fn node_bytes(&self, task: TaskId, node: usize) -> u64 {
        let foot = self.foot.read().unwrap();
        foot.get(task.0).map_or(0, |row| row[node].load(Ordering::Relaxed))
    }

    /// Total attached bytes of a task's footprint.
    pub fn total(&self, task: TaskId) -> u64 {
        let foot = self.foot.read().unwrap();
        foot.get(task.0)
            .map_or(0, |row| row.iter().map(|b| b.load(Ordering::Relaxed)).sum())
    }

    /// The node holding the plurality of `task`'s footprint (lowest
    /// index on ties; None when the footprint is empty).
    pub fn dominant_node(&self, task: TaskId) -> Option<usize> {
        let v = self.of(task);
        let (best, bytes) = v
            .iter()
            .enumerate()
            .max_by_key(|(i, b)| (**b, std::cmp::Reverse(*i)))?;
        if *bytes == 0 {
            None
        } else {
            Some(best)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::task::{PRIO_BUBBLE, PRIO_THREAD};

    fn table_with_bubble() -> (TaskTable, TaskId, TaskId, TaskId) {
        // root bubble > inner bubble > thread
        let tasks = TaskTable::new();
        let root = tasks.new_bubble("root", PRIO_BUBBLE);
        let inner = tasks.new_bubble("inner", PRIO_BUBBLE);
        let t = tasks.new_thread("t", PRIO_THREAD);
        tasks.with(inner, |x| x.parent = Some(root));
        tasks.with(t, |x| x.parent = Some(inner));
        (tasks, root, inner, t)
    }

    #[test]
    fn add_aggregates_up_the_bubble_chain() {
        let (tasks, root, inner, t) = table_with_bubble();
        let f = Footprint::new(4);
        f.add(&tasks, t, 1, 100);
        f.add(&tasks, t, 3, 50);
        assert_eq!(f.of(t), vec![0, 100, 0, 50]);
        assert_eq!(f.of(inner), vec![0, 100, 0, 50]);
        assert_eq!(f.of(root), vec![0, 100, 0, 50]);
        assert_eq!(f.total(root), 150);
        assert_eq!(f.dominant_node(root), Some(1));
    }

    #[test]
    fn rehome_moves_bytes_along_the_chain() {
        let (tasks, root, _inner, t) = table_with_bubble();
        let f = Footprint::new(4);
        f.add(&tasks, t, 0, 100);
        f.rehome(&tasks, t, 0, 2, 100);
        assert_eq!(f.of(root), vec![0, 0, 100, 0]);
        assert_eq!(f.dominant_node(t), Some(2));
    }

    #[test]
    fn sub_saturates() {
        let (tasks, root, _inner, t) = table_with_bubble();
        let f = Footprint::new(2);
        f.add(&tasks, t, 0, 10);
        f.sub(&tasks, t, 0, 100);
        assert_eq!(f.of(root), vec![0, 0]);
    }

    #[test]
    fn empty_footprint_has_no_dominant_node() {
        let tasks = TaskTable::new();
        let t = tasks.new_thread("t", PRIO_THREAD);
        let f = Footprint::new(4);
        assert_eq!(f.dominant_node(t), None);
        assert_eq!(f.total(t), 0);
        assert_eq!(f.of(t), vec![0, 0, 0, 0]);
    }

    #[test]
    fn dominant_node_breaks_ties_low() {
        let tasks = TaskTable::new();
        let t = tasks.new_thread("t", PRIO_THREAD);
        let f = Footprint::new(3);
        f.add(&tasks, t, 2, 100);
        f.add(&tasks, t, 1, 100);
        assert_eq!(f.dominant_node(t), Some(1));
    }

    #[test]
    fn concurrent_touch_accounting_is_exact() {
        // Many threads hammering one (task, node) counter: atomics must
        // keep the sum exact without a table-wide lock.
        use std::sync::Arc;
        let tasks = Arc::new(TaskTable::new());
        let t = tasks.new_thread("t", PRIO_THREAD);
        let f = Arc::new(Footprint::new(2));
        let mut joins = Vec::new();
        for w in 0..4 {
            let f = f.clone();
            let tasks = tasks.clone();
            joins.push(std::thread::spawn(move || {
                for _ in 0..1_000 {
                    f.add(&tasks, t, w % 2, 3);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(f.total(t), 12_000);
        assert_eq!(f.node_bytes(t, 0), 6_000);
        assert_eq!(f.node_bytes(t, 1), 6_000);
    }
}
