//! Per-task / per-bubble memory footprint accounting.
//!
//! Incrementally-maintained per-NUMA-node byte counters, aggregated up
//! the *bubble* hierarchy exactly like [`crate::sched::core::stats::LoadStats`]
//! aggregates running counts up the *machine* hierarchy: when a region
//! homed on node `n` is attached to (or re-homed under) a task, `n`'s
//! byte counter is bumped for that task **and every enclosing bubble**
//! (O(nesting depth)). A policy can then ask "where does this bubble's
//! memory live?" in O(nodes) without walking its contents.

use std::sync::Mutex;

use crate::task::{TaskId, TaskTable};

/// Per-task per-node footprint byte counters (subtree-aggregated).
#[derive(Debug)]
pub struct Footprint {
    n_nodes: usize,
    /// `foot[task.0][node]` = bytes of attached regions homed on `node`
    /// owned by the task or anything nested under it (for bubbles).
    foot: Mutex<Vec<Vec<u64>>>,
}

/// The bubble chain of a task: itself, then every enclosing bubble.
fn chain(tasks: &TaskTable, task: TaskId) -> Vec<TaskId> {
    let mut out = vec![task];
    let mut cur = task;
    while let Some(p) = tasks.parent(cur) {
        out.push(p);
        cur = p;
    }
    out
}

impl Footprint {
    /// Zeroed counters for a machine with `n_nodes` NUMA nodes.
    pub fn new(n_nodes: usize) -> Footprint {
        Footprint { n_nodes: n_nodes.max(1), foot: Mutex::new(Vec::new()) }
    }

    /// Number of NUMA nodes accounted.
    pub fn n_nodes(&self) -> usize {
        self.n_nodes
    }

    fn slot<'a>(v: &'a mut Vec<Vec<u64>>, t: TaskId, n_nodes: usize) -> &'a mut Vec<u64> {
        if v.len() <= t.0 {
            v.resize_with(t.0 + 1, || vec![0; n_nodes]);
        }
        &mut v[t.0]
    }

    /// `bytes` homed on `node` now belong to `task`: bump the task and
    /// every enclosing bubble.
    pub fn add(&self, tasks: &TaskTable, task: TaskId, node: usize, bytes: u64) {
        let chain = chain(tasks, task);
        let mut foot = self.foot.lock().unwrap();
        for t in chain {
            Self::slot(&mut foot, t, self.n_nodes)[node] += bytes;
        }
    }

    /// `bytes` on `node` no longer belong to `task` (detach or re-home).
    /// Saturating, so an unbalanced call cannot wrap the counters.
    pub fn sub(&self, tasks: &TaskTable, task: TaskId, node: usize, bytes: u64) {
        let chain = chain(tasks, task);
        let mut foot = self.foot.lock().unwrap();
        for t in chain {
            let slot = Self::slot(&mut foot, t, self.n_nodes);
            slot[node] = slot[node].saturating_sub(bytes);
        }
    }

    /// A region owned by `task` migrated from node `from` to node `to`.
    pub fn rehome(&self, tasks: &TaskTable, task: TaskId, from: usize, to: usize, bytes: u64) {
        if from == to {
            return;
        }
        let chain = chain(tasks, task);
        let mut foot = self.foot.lock().unwrap();
        for t in chain {
            let slot = Self::slot(&mut foot, t, self.n_nodes);
            slot[from] = slot[from].saturating_sub(bytes);
            slot[to] += bytes;
        }
    }

    /// `task` (with its whole subtree footprint) was just inserted into
    /// a bubble: fold its existing bytes into every *new* enclosing
    /// bubble, so attach-before-insert and insert-before-attach agree.
    /// Call after the parent link is set, once per insertion.
    pub fn on_insert(&self, tasks: &TaskTable, task: TaskId) {
        let mut ancestors = chain(tasks, task);
        ancestors.remove(0); // the task itself is already charged
        if ancestors.is_empty() {
            return;
        }
        let mut foot = self.foot.lock().unwrap();
        let own = match foot.get(task.0) {
            Some(v) => v.clone(),
            None => return,
        };
        if own.iter().all(|&b| b == 0) {
            return;
        }
        for t in ancestors {
            let slot = Self::slot(&mut foot, t, self.n_nodes);
            for (node, &bytes) in own.iter().enumerate() {
                slot[node] += bytes;
            }
        }
    }

    /// Per-node byte vector of a task's (subtree) footprint.
    pub fn of(&self, task: TaskId) -> Vec<u64> {
        let foot = self.foot.lock().unwrap();
        match foot.get(task.0) {
            Some(v) => v.clone(),
            None => vec![0; self.n_nodes],
        }
    }

    /// Bytes of `task`'s footprint homed on `node`.
    pub fn node_bytes(&self, task: TaskId, node: usize) -> u64 {
        let foot = self.foot.lock().unwrap();
        foot.get(task.0).map_or(0, |v| v[node])
    }

    /// Total attached bytes of a task's footprint.
    pub fn total(&self, task: TaskId) -> u64 {
        let foot = self.foot.lock().unwrap();
        foot.get(task.0).map_or(0, |v| v.iter().sum())
    }

    /// The node holding the plurality of `task`'s footprint (lowest
    /// index on ties; None when the footprint is empty).
    pub fn dominant_node(&self, task: TaskId) -> Option<usize> {
        let foot = self.foot.lock().unwrap();
        let v = foot.get(task.0)?;
        let (best, bytes) = v
            .iter()
            .enumerate()
            .max_by_key(|(i, b)| (**b, std::cmp::Reverse(*i)))?;
        if *bytes == 0 {
            None
        } else {
            Some(best)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::task::{PRIO_BUBBLE, PRIO_THREAD};

    fn table_with_bubble() -> (TaskTable, TaskId, TaskId, TaskId) {
        // root bubble > inner bubble > thread
        let tasks = TaskTable::new();
        let root = tasks.new_bubble("root", PRIO_BUBBLE);
        let inner = tasks.new_bubble("inner", PRIO_BUBBLE);
        let t = tasks.new_thread("t", PRIO_THREAD);
        tasks.with(inner, |x| x.parent = Some(root));
        tasks.with(t, |x| x.parent = Some(inner));
        (tasks, root, inner, t)
    }

    #[test]
    fn add_aggregates_up_the_bubble_chain() {
        let (tasks, root, inner, t) = table_with_bubble();
        let f = Footprint::new(4);
        f.add(&tasks, t, 1, 100);
        f.add(&tasks, t, 3, 50);
        assert_eq!(f.of(t), vec![0, 100, 0, 50]);
        assert_eq!(f.of(inner), vec![0, 100, 0, 50]);
        assert_eq!(f.of(root), vec![0, 100, 0, 50]);
        assert_eq!(f.total(root), 150);
        assert_eq!(f.dominant_node(root), Some(1));
    }

    #[test]
    fn rehome_moves_bytes_along_the_chain() {
        let (tasks, root, _inner, t) = table_with_bubble();
        let f = Footprint::new(4);
        f.add(&tasks, t, 0, 100);
        f.rehome(&tasks, t, 0, 2, 100);
        assert_eq!(f.of(root), vec![0, 0, 100, 0]);
        assert_eq!(f.dominant_node(t), Some(2));
    }

    #[test]
    fn sub_saturates() {
        let (tasks, root, _inner, t) = table_with_bubble();
        let f = Footprint::new(2);
        f.add(&tasks, t, 0, 10);
        f.sub(&tasks, t, 0, 100);
        assert_eq!(f.of(root), vec![0, 0]);
    }

    #[test]
    fn empty_footprint_has_no_dominant_node() {
        let tasks = TaskTable::new();
        let t = tasks.new_thread("t", PRIO_THREAD);
        let f = Footprint::new(4);
        assert_eq!(f.dominant_node(t), None);
        assert_eq!(f.total(t), 0);
        assert_eq!(f.of(t), vec![0, 0, 0, 0]);
    }

    #[test]
    fn dominant_node_breaks_ties_low() {
        let tasks = TaskTable::new();
        let t = tasks.new_thread("t", PRIO_THREAD);
        let f = Footprint::new(3);
        f.add(&tasks, t, 2, 100);
        f.add(&tasks, t, 1, 100);
        assert_eq!(f.dominant_node(t), Some(1));
    }
}
