//! Optional mmap-backed arenas behind the region registry.
//!
//! By default `mem/` regions are byte *counters*: touches move numbers,
//! not cache lines, which is exactly right for the simulator and cheap
//! for native smoke runs. On real hardware that means the locality
//! numbers measure the model, not the machine. This module closes the
//! loop: when arenas are enabled (`--arena` on the native memcmp leg),
//! every allocated region is backed by an anonymous `mmap` mapping,
//! [`ArenaSet::touch`] walks a bounded window of its pages with real
//! volatile writes, and the region's home-node preference is forwarded
//! to the kernel via `mbind` (best-effort — see
//! [`crate::util::os::bind_to_node`]). Striped regions bind *per
//! stripe*: each stripe's page range within the one mapping gets its
//! own `mbind` to the stripe's declared node
//! ([`ArenaSet::back_striped`]), so the kernel layout mirrors the
//! modelled one instead of collapsing onto the first node.
//!
//! Failure is always soft: a denied map or bind leaves the region in
//! counter-only mode and the run proceeds unchanged. Mapping sizes are
//! clamped to [`MAX_MAP_BYTES`] so modelled multi-GB regions don't
//! reserve real multi-GB mappings in CI.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::RwLock;

use super::{RegionId, Stripe};
use crate::util::os::{bind_to_node, MapRegion};

/// Page stride for touch walks (the kernel page size on every platform
/// the native engine targets; a wrong guess only changes the stride).
const PAGE: usize = 4096;
/// Hard cap on bytes actually mapped per region.
const MAX_MAP_BYTES: usize = 16 << 20;
/// Pages written per [`ArenaSet::touch`] call: enough to leave the
/// core's L1 between touches, small enough to keep smoke runs fast.
const PAGES_PER_TOUCH: usize = 8;

/// One region's backing mapping plus a rotating touch cursor.
#[derive(Debug)]
struct Arena {
    map: MapRegion,
    cursor: AtomicUsize,
}

impl Arena {
    fn new(bytes: u64) -> Option<Arena> {
        let len = (bytes as usize).clamp(PAGE, MAX_MAP_BYTES);
        let len = (len + PAGE - 1) & !(PAGE - 1);
        MapRegion::map(len).map(|map| Arena { map, cursor: AtomicUsize::new(0) })
    }

    /// Write one byte per page across the next window (wrapping), so
    /// repeated touches eventually fault in and re-visit every page.
    fn touch_next(&self) {
        let pages_total = self.map.len() / PAGE;
        if pages_total == 0 {
            return;
        }
        let start = self.cursor.fetch_add(PAGES_PER_TOUCH, Ordering::Relaxed);
        let ptr = self.map.as_ptr();
        for i in 0..PAGES_PER_TOUCH.min(pages_total) {
            let page = (start + i) % pages_total;
            // SAFETY: `page * PAGE` is in bounds of the live mapping;
            // volatile read-modify-write tolerates concurrent touchers
            // (the value is never interpreted).
            unsafe {
                let p = ptr.add(page * PAGE);
                p.write_volatile(p.read_volatile().wrapping_add(1));
            }
        }
    }
}

/// RegionId-indexed arena table. Disabled (and free) unless explicitly
/// switched on; every operation is a no-op while disabled.
#[derive(Debug, Default)]
pub struct ArenaSet {
    enabled: AtomicBool,
    arenas: RwLock<Vec<Option<Arena>>>,
    bytes_mapped: AtomicU64,
    touches: AtomicU64,
    /// `mbind` calls the kernel rejected (sandboxed CI, single-node
    /// kernels). Binding stays best-effort; this keeps the misses
    /// observable instead of silent.
    bind_failures: AtomicU64,
}

impl ArenaSet {
    pub fn new() -> ArenaSet {
        ArenaSet::default()
    }

    /// Turn real backing on/off for *subsequent* allocations.
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Release);
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Acquire)
    }

    /// Back region `r` (modelled size `bytes`) with an anonymous
    /// mapping, preferring NUMA node `home` when given. Returns whether
    /// a mapping now backs the region; `false` (disabled, or mmap
    /// denied) means the region stays counter-only.
    pub fn back(&self, r: RegionId, bytes: u64, home: Option<usize>) -> bool {
        if !self.enabled() {
            return false;
        }
        let Some(arena) = Arena::new(bytes) else { return false };
        if let Some(node) = home {
            if !bind_to_node(arena.map.as_ptr(), arena.map.len(), node) {
                self.bind_failures.fetch_add(1, Ordering::Relaxed);
            }
        }
        self.install(r, arena);
        true
    }

    /// Back a *striped* region: one mapping, with each stripe's page
    /// range `mbind`-preferred onto that stripe's declared node. The
    /// modelled stripe sizes are scaled onto the (possibly clamped)
    /// mapping length and rounded to page boundaries, so a stripe too
    /// small to own a full page simply cedes it to a neighbour. Binds
    /// are best-effort; rejections count in [`ArenaSet::bind_failures`].
    pub fn back_striped(&self, r: RegionId, bytes: u64, stripes: &[Stripe]) -> bool {
        if !self.enabled() {
            return false;
        }
        if stripes.is_empty() {
            return self.back(r, bytes, None);
        }
        let Some(arena) = Arena::new(bytes) else { return false };
        let len = arena.map.len();
        let total: u128 = stripes.iter().map(|s| u128::from(s.size)).sum::<u128>().max(1);
        let ptr = arena.map.as_ptr();
        let mut acc: u128 = 0;
        let mut start = 0usize;
        for (i, s) in stripes.iter().enumerate() {
            acc += u128::from(s.size);
            let end = if i + 1 == stripes.len() {
                len
            } else {
                ((acc * len as u128 / total) as usize) & !(PAGE - 1)
            };
            if end > start {
                // SAFETY: `start < end <= len`, so the whole range lies
                // inside the live mapping.
                let range = unsafe { ptr.add(start) };
                if !bind_to_node(range, end - start, s.node) {
                    self.bind_failures.fetch_add(1, Ordering::Relaxed);
                }
                start = end;
            }
        }
        self.install(r, arena);
        true
    }

    fn install(&self, r: RegionId, arena: Arena) {
        self.bytes_mapped.fetch_add(arena.map.len() as u64, Ordering::Relaxed);
        let mut v = self.arenas.write().unwrap();
        if v.len() <= r {
            v.resize_with(r + 1, || None);
        }
        v[r] = Some(arena);
    }

    /// Walk real bytes of region `r`'s backing window, if any.
    pub fn touch(&self, r: RegionId) {
        if !self.enabled() {
            return;
        }
        let v = self.arenas.read().unwrap();
        if let Some(Some(a)) = v.get(r) {
            a.touch_next();
            self.touches.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// (bytes actually mapped, touch walks performed).
    pub fn stats(&self) -> (u64, u64) {
        (
            self.bytes_mapped.load(Ordering::Relaxed),
            self.touches.load(Ordering::Relaxed),
        )
    }

    /// `mbind` calls rejected by the kernel so far (best-effort
    /// binding never fails the allocation).
    pub fn bind_failures(&self) -> u64 {
        self.bind_failures.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_set_is_inert() {
        let set = ArenaSet::new();
        assert!(!set.back(0, 4096, None));
        set.touch(0);
        assert_eq!(set.stats(), (0, 0));
    }

    #[test]
    #[cfg(target_os = "linux")]
    fn enabled_set_maps_and_walks_real_pages() {
        let set = ArenaSet::new();
        set.set_enabled(true);
        assert!(set.back(3, 8 * 4096, Some(0)), "anonymous mmap should succeed");
        set.touch(3);
        set.touch(3);
        let (bytes, touches) = set.stats();
        assert_eq!(bytes, 8 * 4096);
        assert_eq!(touches, 2);
        // Unbacked ids stay no-ops even while enabled.
        set.touch(999);
        assert_eq!(set.stats().1, 2);
    }

    #[test]
    #[cfg(target_os = "linux")]
    fn striped_backing_binds_each_stripe_best_effort() {
        let set = ArenaSet::new();
        set.set_enabled(true);
        let stripes = [Stripe { node: 0, size: 4 * 4096 }, Stripe { node: 1, size: 4 * 4096 }];
        assert!(set.back_striped(7, 8 * 4096, &stripes), "anonymous mmap should succeed");
        set.touch(7);
        let (bytes, touches) = set.stats();
        assert_eq!(bytes, 8 * 4096);
        assert_eq!(touches, 1);
        // The kernel may reject mbind (sandbox, node 1 absent on a
        // single-node machine); best-effort means at worst one counted
        // failure per stripe and the mapping still stands.
        assert!(set.bind_failures() <= stripes.len() as u64, "{}", set.bind_failures());
    }

    #[test]
    fn striped_backing_without_stripes_degrades_to_plain() {
        let set = ArenaSet::new();
        set.set_enabled(true);
        if set.back_striped(0, 4096, &[]) {
            assert_eq!(set.stats().0, 4096);
        }
        // Disabled sets stay inert on the striped path too.
        let off = ArenaSet::new();
        assert!(!off.back_striped(0, 4096, &[Stripe { node: 0, size: 4096 }]));
        assert_eq!(off.bind_failures(), 0);
    }

    #[test]
    fn mapping_size_is_clamped() {
        let set = ArenaSet::new();
        set.set_enabled(true);
        if set.back(0, u64::MAX, None) {
            assert_eq!(set.stats().0 as usize, MAX_MAP_BYTES);
        }
    }
}
