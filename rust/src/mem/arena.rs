//! Optional mmap-backed arenas behind the region registry.
//!
//! By default `mem/` regions are byte *counters*: touches move numbers,
//! not cache lines, which is exactly right for the simulator and cheap
//! for native smoke runs. On real hardware that means the locality
//! numbers measure the model, not the machine. This module closes the
//! loop: when arenas are enabled (`--arena` on the native memcmp leg),
//! every allocated region is backed by an anonymous `mmap` mapping,
//! [`ArenaSet::touch`] walks a bounded window of its pages with real
//! volatile writes, and the region's home-node preference is forwarded
//! to the kernel via `mbind` (best-effort — see
//! [`crate::util::os::bind_to_node`]).
//!
//! Failure is always soft: a denied map or bind leaves the region in
//! counter-only mode and the run proceeds unchanged. Mapping sizes are
//! clamped to [`MAX_MAP_BYTES`] so modelled multi-GB regions don't
//! reserve real multi-GB mappings in CI.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::RwLock;

use super::RegionId;
use crate::util::os::{bind_to_node, MapRegion};

/// Page stride for touch walks (the kernel page size on every platform
/// the native engine targets; a wrong guess only changes the stride).
const PAGE: usize = 4096;
/// Hard cap on bytes actually mapped per region.
const MAX_MAP_BYTES: usize = 16 << 20;
/// Pages written per [`ArenaSet::touch`] call: enough to leave the
/// core's L1 between touches, small enough to keep smoke runs fast.
const PAGES_PER_TOUCH: usize = 8;

/// One region's backing mapping plus a rotating touch cursor.
#[derive(Debug)]
struct Arena {
    map: MapRegion,
    cursor: AtomicUsize,
}

impl Arena {
    fn new(bytes: u64) -> Option<Arena> {
        let len = (bytes as usize).clamp(PAGE, MAX_MAP_BYTES);
        let len = (len + PAGE - 1) & !(PAGE - 1);
        MapRegion::map(len).map(|map| Arena { map, cursor: AtomicUsize::new(0) })
    }

    /// Write one byte per page across the next window (wrapping), so
    /// repeated touches eventually fault in and re-visit every page.
    fn touch_next(&self) {
        let pages_total = self.map.len() / PAGE;
        if pages_total == 0 {
            return;
        }
        let start = self.cursor.fetch_add(PAGES_PER_TOUCH, Ordering::Relaxed);
        let ptr = self.map.as_ptr();
        for i in 0..PAGES_PER_TOUCH.min(pages_total) {
            let page = (start + i) % pages_total;
            // SAFETY: `page * PAGE` is in bounds of the live mapping;
            // volatile read-modify-write tolerates concurrent touchers
            // (the value is never interpreted).
            unsafe {
                let p = ptr.add(page * PAGE);
                p.write_volatile(p.read_volatile().wrapping_add(1));
            }
        }
    }
}

/// RegionId-indexed arena table. Disabled (and free) unless explicitly
/// switched on; every operation is a no-op while disabled.
#[derive(Debug, Default)]
pub struct ArenaSet {
    enabled: AtomicBool,
    arenas: RwLock<Vec<Option<Arena>>>,
    bytes_mapped: AtomicU64,
    touches: AtomicU64,
}

impl ArenaSet {
    pub fn new() -> ArenaSet {
        ArenaSet::default()
    }

    /// Turn real backing on/off for *subsequent* allocations.
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Release);
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Acquire)
    }

    /// Back region `r` (modelled size `bytes`) with an anonymous
    /// mapping, preferring NUMA node `home` when given. Returns whether
    /// a mapping now backs the region; `false` (disabled, or mmap
    /// denied) means the region stays counter-only.
    pub fn back(&self, r: RegionId, bytes: u64, home: Option<usize>) -> bool {
        if !self.enabled() {
            return false;
        }
        let Some(arena) = Arena::new(bytes) else { return false };
        if let Some(node) = home {
            let _ = bind_to_node(arena.map.as_ptr(), arena.map.len(), node);
        }
        self.bytes_mapped.fetch_add(arena.map.len() as u64, Ordering::Relaxed);
        let mut v = self.arenas.write().unwrap();
        if v.len() <= r {
            v.resize_with(r + 1, || None);
        }
        v[r] = Some(arena);
        true
    }

    /// Walk real bytes of region `r`'s backing window, if any.
    pub fn touch(&self, r: RegionId) {
        if !self.enabled() {
            return;
        }
        let v = self.arenas.read().unwrap();
        if let Some(Some(a)) = v.get(r) {
            a.touch_next();
            self.touches.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// (bytes actually mapped, touch walks performed).
    pub fn stats(&self) -> (u64, u64) {
        (
            self.bytes_mapped.load(Ordering::Relaxed),
            self.touches.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_set_is_inert() {
        let set = ArenaSet::new();
        assert!(!set.back(0, 4096, None));
        set.touch(0);
        assert_eq!(set.stats(), (0, 0));
    }

    #[test]
    #[cfg(target_os = "linux")]
    fn enabled_set_maps_and_walks_real_pages() {
        let set = ArenaSet::new();
        set.set_enabled(true);
        assert!(set.back(3, 8 * 4096, Some(0)), "anonymous mmap should succeed");
        set.touch(3);
        set.touch(3);
        let (bytes, touches) = set.stats();
        assert_eq!(bytes, 8 * 4096);
        assert_eq!(touches, 2);
        // Unbacked ids stay no-ops even while enabled.
        set.touch(999);
        assert_eq!(set.stats().1, 2);
    }

    #[test]
    fn mapping_size_is_clamped() {
        let set = ArenaSet::new();
        set.set_enabled(true);
        if set.back(0, u64::MAX, None) {
            assert_eq!(set.stats().0 as usize, MAX_MAP_BYTES);
        }
    }
}
