//! Memory-region registry: where data lives.
//!
//! A *region* is a block of application memory with a size, a home NUMA
//! node and touch statistics. Regions are homed by **first touch** (the
//! OS default the paper's applications rely on, §2.3), **round robin**
//! or **explicit placement**, and may be *attached* to a task so the
//! [`super::Footprint`] accounting can attribute their bytes to the
//! bubble hierarchy.
//!
//! **Next-touch migration** (the ForestGOMP direction, arXiv 0706.2073):
//! a region marked next-touch re-homes onto the node of the *next* CPU
//! that touches it, so memory can follow a migrated thread. Migrated
//! bytes are reported to the caller for metrics accounting.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::task::TaskId;
use crate::topology::CpuId;

/// Region handle: index into the registry.
pub type RegionId = usize;

/// Default region size when the caller does not say (1 MiB).
pub const DEFAULT_REGION_BYTES: u64 = 1 << 20;

/// Memory allocation policy for regions (paper §2.3: modern systems
/// "let the application choose the memory allocation policy (specific
/// memory node, first touch or round robin)").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllocPolicy {
    /// Homed on the node of the first CPU that touches it.
    FirstTouch,
    /// Spread across nodes in allocation order.
    RoundRobin,
    /// Explicitly placed on one node.
    Fixed(usize),
}

/// One region's full state (also the snapshot returned by `info`).
#[derive(Debug, Clone)]
pub struct RegionInfo {
    /// Size in bytes.
    pub size: u64,
    /// Home NUMA node (None until first touch under `FirstTouch`).
    pub home: Option<usize>,
    /// CPU that last touched the region (cache-line ownership).
    pub last_toucher: Option<CpuId>,
    /// Task the region is attached to (footprint attribution).
    pub owner: Option<TaskId>,
    /// Number of touches recorded.
    pub touches: u64,
    /// Re-home onto the next toucher's node (next-touch migration).
    pub next_touch: bool,
}

/// Outcome of one touch, resolved against the registry.
#[derive(Debug, Clone, Copy)]
pub struct Touch {
    /// Home node after the touch (first touch homes the region).
    pub home: usize,
    /// CPU that touched the region *before* this touch.
    pub last_toucher: Option<CpuId>,
    /// Bytes moved by next-touch migration (0 = none).
    pub migrated: u64,
}

/// How a touch or attach changed footprint attribution (consumed by
/// [`super::MemState`] to keep [`super::Footprint`] in sync).
#[derive(Debug, Clone, Copy)]
pub enum HomeChange {
    /// The region gained a home (first touch or late attach).
    Homed { owner: Option<TaskId>, node: usize, size: u64 },
    /// The region migrated between nodes (next-touch).
    Moved { owner: Option<TaskId>, from: usize, to: usize, size: u64 },
}

/// The registry proper: an append-only arena of regions.
#[derive(Debug)]
pub struct RegionRegistry {
    slots: Mutex<Vec<RegionInfo>>,
    /// Round-robin placement cursor.
    rr_next: AtomicUsize,
    /// NUMA node count for round-robin wrapping.
    n_nodes: usize,
}

impl RegionRegistry {
    /// Empty registry for a machine with `n_nodes` NUMA nodes.
    pub fn new(n_nodes: usize) -> RegionRegistry {
        RegionRegistry {
            slots: Mutex::new(Vec::new()),
            rr_next: AtomicUsize::new(0),
            n_nodes: n_nodes.max(1),
        }
    }

    /// Allocate a region of `size` bytes under `policy`.
    ///
    /// Panics when `Fixed(node)` names a node the machine does not have
    /// — catching the caller's mistake here instead of as an opaque
    /// index error deep in the footprint accounting.
    pub fn alloc(&self, size: u64, policy: AllocPolicy) -> RegionId {
        let home = match policy {
            AllocPolicy::FirstTouch => None,
            AllocPolicy::Fixed(node) => {
                assert!(
                    node < self.n_nodes,
                    "AllocPolicy::Fixed({node}) on a machine with {} NUMA nodes",
                    self.n_nodes
                );
                Some(node)
            }
            AllocPolicy::RoundRobin => {
                Some(self.rr_next.fetch_add(1, Ordering::Relaxed) % self.n_nodes)
            }
        };
        let mut slots = self.slots.lock().unwrap();
        slots.push(RegionInfo {
            size,
            home,
            last_toucher: None,
            owner: None,
            touches: 0,
            next_touch: false,
        });
        slots.len() - 1
    }

    /// Number of regions allocated.
    pub fn len(&self) -> usize {
        self.slots.lock().unwrap().len()
    }

    /// True when no region was allocated.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of one region.
    pub fn info(&self, r: RegionId) -> RegionInfo {
        self.slots.lock().unwrap()[r].clone()
    }

    /// Home node of a region (None before first touch).
    pub fn home(&self, r: RegionId) -> Option<usize> {
        self.slots.lock().unwrap()[r].home
    }

    /// Attach a region to `task`, replacing any previous owner. Returns
    /// the previous owner and, when the region is already homed, the
    /// footprint delta the caller must apply.
    pub fn attach(&self, r: RegionId, task: TaskId) -> (Option<TaskId>, Option<HomeChange>) {
        let mut slots = self.slots.lock().unwrap();
        let slot = &mut slots[r];
        let prev = slot.owner.replace(task);
        let delta = slot.home.map(|node| HomeChange::Homed {
            owner: Some(task),
            node,
            size: slot.size,
        });
        (prev, delta)
    }

    /// Record a touch by a CPU on NUMA node `node`: first touch homes
    /// the region, next-touch migrates it. Returns the resolved touch
    /// and any footprint delta.
    pub fn touch(&self, r: RegionId, cpu: CpuId, node: usize) -> (Touch, Option<HomeChange>) {
        let mut slots = self.slots.lock().unwrap();
        let slot = &mut slots[r];
        slot.touches += 1;
        let prev_toucher = slot.last_toucher;
        slot.last_toucher = Some(cpu);
        let (home, delta, migrated) = match slot.home {
            None => {
                slot.home = Some(node);
                (node, Some(HomeChange::Homed { owner: slot.owner, node, size: slot.size }), 0)
            }
            Some(old) if slot.next_touch && old != node => {
                slot.home = Some(node);
                slot.next_touch = false;
                (
                    node,
                    Some(HomeChange::Moved {
                        owner: slot.owner,
                        from: old,
                        to: node,
                        size: slot.size,
                    }),
                    slot.size,
                )
            }
            Some(old) => {
                // A same-node touch also consumes the next-touch mark:
                // the data already is where the toucher runs.
                slot.next_touch = false;
                (old, None, 0)
            }
        };
        (Touch { home, last_toucher: prev_toucher, migrated }, delta)
    }

    /// Mark one region for next-touch migration.
    pub fn mark_next_touch(&self, r: RegionId) {
        self.slots.lock().unwrap()[r].next_touch = true;
    }

    /// Mark every region attached to `task` for next-touch migration
    /// (a migrated thread asks its memory to follow it). Returns the
    /// bytes marked.
    pub fn mark_owner_next_touch(&self, task: TaskId) -> u64 {
        let mut slots = self.slots.lock().unwrap();
        let mut bytes = 0;
        for slot in slots.iter_mut() {
            if slot.owner == Some(task) {
                slot.next_touch = true;
                bytes += slot.size;
            }
        }
        bytes
    }

    /// Total bytes of regions that are both attached and homed — the
    /// amount the footprint counters must account for (conservation).
    pub fn attached_homed_bytes(&self) -> u64 {
        let slots = self.slots.lock().unwrap();
        slots
            .iter()
            .filter(|s| s.owner.is_some() && s.home.is_some())
            .map(|s| s.size)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_policies_place_homes() {
        let reg = RegionRegistry::new(4);
        let ft = reg.alloc(100, AllocPolicy::FirstTouch);
        let fx = reg.alloc(100, AllocPolicy::Fixed(2));
        let r0 = reg.alloc(100, AllocPolicy::RoundRobin);
        let r1 = reg.alloc(100, AllocPolicy::RoundRobin);
        assert_eq!(reg.home(ft), None);
        assert_eq!(reg.home(fx), Some(2));
        assert_eq!(reg.home(r0), Some(0));
        assert_eq!(reg.home(r1), Some(1));
        assert_eq!(reg.len(), 4);
    }

    #[test]
    fn first_touch_homes_and_reports() {
        let reg = RegionRegistry::new(2);
        let r = reg.alloc(64, AllocPolicy::FirstTouch);
        let (t, delta) = reg.touch(r, CpuId(3), 1);
        assert_eq!(t.home, 1);
        assert_eq!(t.last_toucher, None);
        assert_eq!(t.migrated, 0);
        assert!(matches!(delta, Some(HomeChange::Homed { node: 1, size: 64, .. })));
        // Second touch: stable home, last toucher reported.
        let (t2, delta2) = reg.touch(r, CpuId(0), 0);
        assert_eq!(t2.home, 1);
        assert_eq!(t2.last_toucher, Some(CpuId(3)));
        assert!(delta2.is_none());
        assert_eq!(reg.info(r).touches, 2);
    }

    #[test]
    fn next_touch_migrates_once() {
        let reg = RegionRegistry::new(2);
        let r = reg.alloc(128, AllocPolicy::Fixed(0));
        reg.mark_next_touch(r);
        let (t, delta) = reg.touch(r, CpuId(2), 1);
        assert_eq!(t.home, 1);
        assert_eq!(t.migrated, 128);
        assert!(matches!(
            delta,
            Some(HomeChange::Moved { from: 0, to: 1, size: 128, .. })
        ));
        // Mark consumed: a further remote touch does not migrate.
        let (t2, delta2) = reg.touch(r, CpuId(0), 0);
        assert_eq!(t2.home, 1);
        assert_eq!(t2.migrated, 0);
        assert!(delta2.is_none());
    }

    #[test]
    fn same_node_touch_consumes_the_mark() {
        let reg = RegionRegistry::new(2);
        let r = reg.alloc(128, AllocPolicy::Fixed(1));
        reg.mark_next_touch(r);
        let (t, _) = reg.touch(r, CpuId(2), 1);
        assert_eq!((t.home, t.migrated), (1, 0));
        assert!(!reg.info(r).next_touch);
    }

    #[test]
    fn owner_marking_and_conservation_sum() {
        let reg = RegionRegistry::new(2);
        let a = reg.alloc(100, AllocPolicy::Fixed(0));
        let b = reg.alloc(50, AllocPolicy::FirstTouch);
        let (prev, delta) = reg.attach(a, TaskId(7));
        assert_eq!(prev, None);
        assert!(matches!(delta, Some(HomeChange::Homed { node: 0, size: 100, .. })));
        let (_, delta_b) = reg.attach(b, TaskId(7));
        assert!(delta_b.is_none(), "unhomed region has no footprint yet");
        assert_eq!(reg.attached_homed_bytes(), 100);
        reg.touch(b, CpuId(0), 0);
        assert_eq!(reg.attached_homed_bytes(), 150);
        assert_eq!(reg.mark_owner_next_touch(TaskId(7)), 150);
        assert!(reg.info(a).next_touch && reg.info(b).next_touch);
    }
}
