//! Memory-region registry: where data lives.
//!
//! A *region* is a block of application memory with a size, a home NUMA
//! node and touch statistics. Regions are homed by **first touch** (the
//! OS default the paper's applications rely on, §2.3), **round robin**
//! or **explicit placement**, and may be *attached* to a task so the
//! [`super::Footprint`] accounting can attribute their bytes to the
//! bubble hierarchy.
//!
//! **Next-touch migration** (the ForestGOMP direction, arXiv 0706.2073):
//! a region marked next-touch re-homes onto the node of the *next* CPU
//! that touches it, so memory can follow a migrated thread. Migrated
//! bytes are reported to the caller for metrics accounting.
//!
//! **Striped regions** ([`RegionRegistry::alloc_striped`]): one region
//! split across several home nodes — the shared-mesh / round-robin-page
//! layout real NUMA allocators produce. Each [`Stripe`] owns a share of
//! the bytes on one node; touches rotate over the stripes (a sequential
//! sweep over a striped array lands on each node in turn), and a
//! next-touch mark migrates only the *touched* stripe to the toucher's
//! node. Footprint attribution is per stripe, so a striped region
//! charges each declared node exactly its stripe's bytes.
//!
//! **Lock-free steady-state touches** ([`RegionRegistry::touch_fast`]):
//! each region's mutable hot state (touch count, last toucher,
//! next-touch flag, home / stripe nodes) lives in a [`RegionHot`] of
//! atomics, separate from the lock-protected static part (size, stripe
//! sizes, owner). A touch of a homed, unmarked region changes no
//! placement, so it commits with three atomic ops and never takes the
//! registry mutex — that is the overwhelmingly common case once an
//! application's working set is placed. Touches that *can* move bytes
//! (first touch, a pending next-touch mark) fall back to the locked
//! [`RegionRegistry::touch`], which serialises against attach so the
//! footprint conservation invariant holds. A mark racing in after a
//! fast touch commits simply linearises that touch before the mark —
//! the next touch migrates, exactly as if the two had queued on a lock.
//!
//! **Pressure view**: the registry keeps per-node homed-byte counters
//! (lock-free reads) so the pick path can ask "which node has footprint
//! headroom?" in O(1) — see [`RegionRegistry::node_pressure`] and the
//! pressure-aware pass 1 in `sched::core::pick`. The counters carry a
//! monotonic [`RegionRegistry::pressure_epoch`] bumped on every change,
//! so per-pick readers can cache a snapshot (via
//! [`RegionRegistry::pressure_view_into`], allocation-free) and refresh
//! only when placement actually moved.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::task::TaskId;
use crate::topology::CpuId;

/// Region handle: index into the registry.
pub type RegionId = usize;

/// Default region size when the caller does not say (1 MiB).
pub const DEFAULT_REGION_BYTES: u64 = 1 << 20;

/// Sentinel for "no node / no CPU" in the hot-state atomics.
const NONE_IDX: usize = usize::MAX;

/// Memory allocation policy for regions (paper §2.3: modern systems
/// "let the application choose the memory allocation policy (specific
/// memory node, first touch or round robin)").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllocPolicy {
    /// Homed on the node of the first CPU that touches it.
    FirstTouch,
    /// Spread across nodes in allocation order.
    RoundRobin,
    /// Explicitly placed on one node.
    Fixed(usize),
}

/// One stripe of a striped region: a share of the region's bytes homed
/// on one node. The stripe's node changes only under next-touch
/// migration; its size is fixed at declaration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Stripe {
    /// Node currently holding this stripe's bytes.
    pub node: usize,
    /// Bytes in this stripe.
    pub size: u64,
}

/// One region's full state (the snapshot returned by `info`).
#[derive(Debug, Clone)]
pub struct RegionInfo {
    /// Size in bytes.
    pub size: u64,
    /// Home NUMA node (None until first touch under `FirstTouch`, and
    /// always None for striped regions — their homes are per stripe).
    pub home: Option<usize>,
    /// Stripes of a striped region (empty for ordinary regions).
    pub stripes: Vec<Stripe>,
    /// CPU that last touched the region (cache-line ownership).
    pub last_toucher: Option<CpuId>,
    /// Task the region is attached to (footprint attribution).
    pub owner: Option<TaskId>,
    /// Number of touches recorded.
    pub touches: u64,
    /// Touches resolved on the toucher's own NUMA node (engine-side
    /// locality attribution, see [`RegionRegistry::note_locality`]).
    pub local_touches: u64,
    /// Touches resolved on a remote node.
    pub remote_touches: u64,
    /// Re-home onto the next toucher's node (next-touch migration).
    pub next_touch: bool,
}

impl RegionInfo {
    /// Is the region homed (single-home assigned, or striped — stripes
    /// are placed at declaration)?
    pub fn is_homed(&self) -> bool {
        self.home.is_some() || !self.stripes.is_empty()
    }

    /// Per-node byte vector of the region's homed bytes (all zeros when
    /// unhomed).
    pub fn homed_bytes_per_node(&self, n_nodes: usize) -> Vec<u64> {
        let mut v = vec![0u64; n_nodes];
        if !self.stripes.is_empty() {
            for s in &self.stripes {
                v[s.node] += s.size;
            }
        } else if let Some(n) = self.home {
            v[n] += self.size;
        }
        v
    }
}

/// Outcome of one touch, resolved against the registry.
#[derive(Debug, Clone, Copy)]
pub struct Touch {
    /// Home node after the touch (first touch homes the region).
    pub home: usize,
    /// CPU that touched the region *before* this touch.
    pub last_toucher: Option<CpuId>,
    /// Bytes moved by next-touch migration (0 = none).
    pub migrated: u64,
}

/// How a touch or attach changed footprint attribution (consumed by
/// [`super::MemState`] to keep [`super::Footprint`] in sync).
#[derive(Debug, Clone, Copy)]
pub enum HomeChange {
    /// The region gained a home (first touch or late attach).
    Homed { owner: Option<TaskId>, node: usize, size: u64 },
    /// The region migrated between nodes (next-touch).
    Moved { owner: Option<TaskId>, from: usize, to: usize, size: u64 },
}

/// Static (lock-protected) part of a region: what never changes per
/// touch. `owner` changes only through `attach`, which is rare and
/// placement-relevant, so it stays behind the lock.
#[derive(Debug)]
struct RegionSlot {
    size: u64,
    /// Per-stripe byte counts (empty for ordinary regions). Sizes are
    /// fixed at declaration; the stripes' *nodes* live in the hot part.
    stripe_sizes: Vec<u64>,
    owner: Option<TaskId>,
}

/// Hot (lock-free) part of a region: everything a steady-state touch
/// reads or writes. Single source of truth for these fields — the
/// locked paths update the same atomics, so the two tiers cannot
/// drift.
#[derive(Debug)]
struct RegionHot {
    /// Touches recorded; a touch's 0-based index (`fetch_add` result)
    /// drives the stripe rotation.
    touches: AtomicU64,
    /// CPU of the previous toucher (`NONE_IDX` = never touched).
    last_toucher: AtomicUsize,
    /// Pending next-touch migration mark.
    next_touch: AtomicBool,
    /// Touches that resolved on the toucher's node / a remote node.
    /// Written by the engines via [`RegionRegistry::note_locality`]
    /// (the registry itself cannot map a CPU to its node).
    locals: AtomicU64,
    remotes: AtomicU64,
    /// Home node of a single-home region (`NONE_IDX` = unhomed; always
    /// `NONE_IDX` for striped regions).
    home: AtomicUsize,
    /// Current node of each stripe (empty for ordinary regions).
    stripe_nodes: Box<[AtomicUsize]>,
}

impl RegionHot {
    fn new(home: Option<usize>, stripe_nodes: &[usize]) -> RegionHot {
        RegionHot {
            touches: AtomicU64::new(0),
            last_toucher: AtomicUsize::new(NONE_IDX),
            next_touch: AtomicBool::new(false),
            locals: AtomicU64::new(0),
            remotes: AtomicU64::new(0),
            home: AtomicUsize::new(home.unwrap_or(NONE_IDX)),
            stripe_nodes: stripe_nodes.iter().map(|&n| AtomicUsize::new(n)).collect(),
        }
    }

    fn home_node(&self) -> Option<usize> {
        let h = self.home.load(Ordering::Acquire);
        (h != NONE_IDX).then_some(h)
    }

    fn last(&self) -> Option<CpuId> {
        let c = self.last_toucher.load(Ordering::Acquire);
        (c != NONE_IDX).then_some(CpuId(c))
    }

    fn is_homed(&self) -> bool {
        !self.stripe_nodes.is_empty() || self.home_node().is_some()
    }
}

/// The registry proper: an append-only arena of regions.
///
/// Lock order (where both are taken): `slots` mutex, then `hot` read
/// lock. `hot`'s write side is taken only while appending in `alloc`.
#[derive(Debug)]
pub struct RegionRegistry {
    slots: Mutex<Vec<RegionSlot>>,
    /// Hot per-region state, `Arc`'d so the fast path can drop the
    /// (uncontended) read guard before committing its atomics.
    hot: RwLock<Vec<Arc<RegionHot>>>,
    /// Round-robin placement cursor.
    rr_next: AtomicUsize,
    /// NUMA node count for round-robin wrapping.
    n_nodes: usize,
    /// Per-node homed bytes (all regions, attached or not): the memory
    /// *pressure* view. Written by the placement-changing (locked)
    /// paths, read lock-free by the pressure-aware pick pass 1.
    node_homed: Vec<AtomicU64>,
    /// Monotonic pressure version: bumped whenever `node_homed` moves,
    /// so per-pick readers can cache a snapshot and refresh only when
    /// placement actually changed.
    epoch: AtomicU64,
}

impl RegionRegistry {
    /// Empty registry for a machine with `n_nodes` NUMA nodes.
    pub fn new(n_nodes: usize) -> RegionRegistry {
        let n = n_nodes.max(1);
        RegionRegistry {
            slots: Mutex::new(Vec::new()),
            hot: RwLock::new(Vec::new()),
            rr_next: AtomicUsize::new(0),
            n_nodes: n,
            node_homed: (0..n).map(|_| AtomicU64::new(0)).collect(),
            epoch: AtomicU64::new(0),
        }
    }

    /// Bytes of homed regions on `node` (the pressure the node is
    /// under). Lock-free, advisory.
    pub fn node_pressure(&self, node: usize) -> u64 {
        self.node_homed[node].load(Ordering::Relaxed)
    }

    /// Per-node homed-bytes snapshot (index = NUMA node).
    pub fn pressure_view(&self) -> Vec<u64> {
        self.node_homed.iter().map(|a| a.load(Ordering::Relaxed)).collect()
    }

    /// Allocation-free [`Self::pressure_view`]: clears and refills
    /// `out` so per-pick readers can reuse one buffer.
    pub fn pressure_view_into(&self, out: &mut Vec<u64>) {
        out.clear();
        out.extend(self.node_homed.iter().map(|a| a.load(Ordering::Relaxed)));
    }

    /// Current pressure epoch: moves (monotonically) exactly when some
    /// `node_homed` counter does.
    pub fn pressure_epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    fn pressure_add(&self, node: usize, bytes: u64) {
        self.node_homed[node].fetch_add(bytes, Ordering::Relaxed);
        self.epoch.fetch_add(1, Ordering::Release);
    }

    fn pressure_move(&self, from: usize, to: usize, bytes: u64) {
        if from == to {
            return;
        }
        let _ = self.node_homed[from]
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| Some(v.saturating_sub(bytes)));
        self.node_homed[to].fetch_add(bytes, Ordering::Relaxed);
        self.epoch.fetch_add(1, Ordering::Release);
    }

    /// The hot handle of one region (cloned out of the uncontended read
    /// guard).
    fn hot_of(&self, r: RegionId) -> Arc<RegionHot> {
        self.hot.read().unwrap()[r].clone()
    }

    /// Allocate a region of `size` bytes under `policy`.
    ///
    /// Panics when `Fixed(node)` names a node the machine does not have
    /// — catching the caller's mistake here instead of as an opaque
    /// index error deep in the footprint accounting.
    pub fn alloc(&self, size: u64, policy: AllocPolicy) -> RegionId {
        let home = match policy {
            AllocPolicy::FirstTouch => None,
            AllocPolicy::Fixed(node) => {
                assert!(
                    node < self.n_nodes,
                    "AllocPolicy::Fixed({node}) on a machine with {} NUMA nodes",
                    self.n_nodes
                );
                Some(node)
            }
            AllocPolicy::RoundRobin => {
                Some(self.rr_next.fetch_add(1, Ordering::Relaxed) % self.n_nodes)
            }
        };
        let mut slots = self.slots.lock().unwrap();
        let mut hot = self.hot.write().unwrap();
        if let Some(n) = home {
            self.pressure_add(n, size);
        }
        slots.push(RegionSlot { size, stripe_sizes: Vec::new(), owner: None });
        hot.push(Arc::new(RegionHot::new(home, &[])));
        slots.len() - 1
    }

    /// Allocate a *striped* region of `size` bytes spread over `nodes`:
    /// stripe `i` holds `size/n` bytes (the remainder goes to the first
    /// stripes) homed on `nodes[i]`. Panics on an empty node list or an
    /// out-of-range node — caller mistakes, caught here rather than as
    /// index errors in the footprint accounting.
    pub fn alloc_striped(&self, size: u64, nodes: &[usize]) -> RegionId {
        assert!(!nodes.is_empty(), "alloc_striped with no nodes");
        for &n in nodes {
            assert!(
                n < self.n_nodes,
                "alloc_striped over node {n} on a machine with {} NUMA nodes",
                self.n_nodes
            );
        }
        let n = nodes.len() as u64;
        let (base, rem) = (size / n, size % n);
        let stripe_sizes: Vec<u64> = (0..nodes.len())
            .map(|i| base + u64::from((i as u64) < rem))
            .collect();
        let mut slots = self.slots.lock().unwrap();
        let mut hot = self.hot.write().unwrap();
        for (&node, &bytes) in nodes.iter().zip(&stripe_sizes) {
            self.pressure_add(node, bytes);
        }
        slots.push(RegionSlot { size, stripe_sizes, owner: None });
        hot.push(Arc::new(RegionHot::new(None, nodes)));
        slots.len() - 1
    }

    /// Number of regions allocated.
    pub fn len(&self) -> usize {
        self.slots.lock().unwrap().len()
    }

    /// True when no region was allocated.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn build_info(slot: &RegionSlot, h: &RegionHot) -> RegionInfo {
        RegionInfo {
            size: slot.size,
            home: if slot.stripe_sizes.is_empty() { h.home_node() } else { None },
            stripes: slot
                .stripe_sizes
                .iter()
                .enumerate()
                .map(|(i, &size)| Stripe { node: h.stripe_nodes[i].load(Ordering::Acquire), size })
                .collect(),
            last_toucher: h.last(),
            owner: slot.owner,
            touches: h.touches.load(Ordering::Acquire),
            local_touches: h.locals.load(Ordering::Acquire),
            remote_touches: h.remotes.load(Ordering::Acquire),
            next_touch: h.next_touch.load(Ordering::Acquire),
        }
    }

    /// Snapshot of one region.
    pub fn info(&self, r: RegionId) -> RegionInfo {
        let slots = self.slots.lock().unwrap();
        let hot = self.hot.read().unwrap();
        Self::build_info(&slots[r], &hot[r])
    }

    /// Snapshot of every region (test/debug iteration).
    pub fn snapshot(&self) -> Vec<RegionInfo> {
        let slots = self.slots.lock().unwrap();
        let hot = self.hot.read().unwrap();
        slots.iter().zip(hot.iter()).map(|(s, h)| Self::build_info(s, h)).collect()
    }

    /// Total touches recorded across all regions.
    pub fn total_touches(&self) -> u64 {
        self.hot.read().unwrap().iter().map(|h| h.touches.load(Ordering::Acquire)).sum()
    }

    /// Home node of a region (None before first touch, and None for
    /// striped regions — their homes are per stripe, see [`Self::info`]).
    pub fn home(&self, r: RegionId) -> Option<usize> {
        self.hot_of(r).home_node()
    }

    /// Attach a region to `task`, replacing any previous owner. Returns
    /// the previous owner and, when the region is already homed, the
    /// footprint deltas the caller must apply (one per stripe for a
    /// striped region).
    pub fn attach(&self, r: RegionId, task: TaskId) -> (Option<TaskId>, Vec<HomeChange>) {
        let mut slots = self.slots.lock().unwrap();
        let hot = self.hot.read().unwrap();
        let slot = &mut slots[r];
        let h = &hot[r];
        let prev = slot.owner.replace(task);
        let deltas = if !slot.stripe_sizes.is_empty() {
            slot.stripe_sizes
                .iter()
                .enumerate()
                .map(|(i, &size)| HomeChange::Homed {
                    owner: Some(task),
                    node: h.stripe_nodes[i].load(Ordering::Acquire),
                    size,
                })
                .collect()
        } else if let Some(node) = h.home_node() {
            vec![HomeChange::Homed { owner: Some(task), node, size: slot.size }]
        } else {
            Vec::new()
        };
        (prev, deltas)
    }

    /// Lock-free steady-state touch: commits iff the touch cannot
    /// change placement — the region is homed (or striped) and carries
    /// no next-touch mark. Returns None when the locked [`Self::touch`]
    /// must run instead (first touch, pending migration). A mark racing
    /// in after the commit linearises this touch before the mark.
    pub fn touch_fast(&self, r: RegionId, cpu: CpuId) -> Option<Touch> {
        let h = self.hot_of(r);
        if h.next_touch.load(Ordering::Acquire) || !h.is_homed() {
            return None;
        }
        let k = h.touches.fetch_add(1, Ordering::AcqRel);
        let prev = h.last_toucher.swap(cpu.0, Ordering::AcqRel);
        let home = if h.stripe_nodes.is_empty() {
            h.home.load(Ordering::Acquire)
        } else {
            h.stripe_nodes[(k % h.stripe_nodes.len() as u64) as usize].load(Ordering::Acquire)
        };
        Some(Touch { home, last_toucher: (prev != NONE_IDX).then_some(CpuId(prev)), migrated: 0 })
    }

    /// Record a touch by a CPU on NUMA node `node`: first touch homes
    /// the region, next-touch migrates. On a striped region the touch
    /// lands on the stripes in rotation (touch `k` hits stripe
    /// `k mod n` — a sequential sweep over the striped array), and a
    /// next-touch mark migrates only the touched stripe. Returns the
    /// resolved touch and any footprint delta. This is the locked slow
    /// path; [`Self::touch_fast`] handles the placement-neutral case.
    pub fn touch(&self, r: RegionId, cpu: CpuId, node: usize) -> (Touch, Option<HomeChange>) {
        let slots = self.slots.lock().unwrap();
        let hot = self.hot.read().unwrap();
        let slot = &slots[r];
        let h = &hot[r];
        let k = h.touches.fetch_add(1, Ordering::AcqRel);
        let prev = h.last_toucher.swap(cpu.0, Ordering::AcqRel);
        let prev_toucher = (prev != NONE_IDX).then_some(CpuId(prev));
        if !slot.stripe_sizes.is_empty() {
            let idx = (k % slot.stripe_sizes.len() as u64) as usize;
            let owner = slot.owner;
            let old = h.stripe_nodes[idx].load(Ordering::Acquire);
            // Any touch consumes the mark (a same-node touch means the
            // touched stripe already is where the toucher runs).
            let marked = h.next_touch.swap(false, Ordering::AcqRel);
            let (delta, migrated) = if marked && old != node {
                h.stripe_nodes[idx].store(node, Ordering::Release);
                let size = slot.stripe_sizes[idx];
                self.pressure_move(old, node, size);
                (Some(HomeChange::Moved { owner, from: old, to: node, size }), size)
            } else {
                (None, 0)
            };
            let home = h.stripe_nodes[idx].load(Ordering::Acquire);
            return (Touch { home, last_toucher: prev_toucher, migrated }, delta);
        }
        let (home, delta, migrated) = match h.home_node() {
            None => {
                h.home.store(node, Ordering::Release);
                self.pressure_add(node, slot.size);
                (node, Some(HomeChange::Homed { owner: slot.owner, node, size: slot.size }), 0)
            }
            Some(old) => {
                // A same-node touch also consumes the next-touch mark:
                // the data already is where the toucher runs.
                let marked = h.next_touch.swap(false, Ordering::AcqRel);
                if marked && old != node {
                    h.home.store(node, Ordering::Release);
                    self.pressure_move(old, node, slot.size);
                    (
                        node,
                        Some(HomeChange::Moved {
                            owner: slot.owner,
                            from: old,
                            to: node,
                            size: slot.size,
                        }),
                        slot.size,
                    )
                } else {
                    (old, None, 0)
                }
            }
        };
        (Touch { home, last_toucher: prev_toucher, migrated }, delta)
    }

    /// Attribute one resolved touch as local (the toucher ran on the
    /// region's home node) or remote. The engines call this right after
    /// resolving a touch — only they know the machine's CPU→node map —
    /// which gives every region, and hence every *job* owning regions,
    /// its own locality ratio (lock-free, two atomic ops).
    pub fn note_locality(&self, r: RegionId, local: bool) {
        let h = self.hot_of(r);
        if local {
            h.locals.fetch_add(1, Ordering::Relaxed);
        } else {
            h.remotes.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Mark one region for next-touch migration.
    pub fn mark_next_touch(&self, r: RegionId) {
        self.hot_of(r).next_touch.store(true, Ordering::Release);
    }

    /// Mark every region attached to `task` for next-touch migration
    /// (a migrated thread asks its memory to follow it). Returns the
    /// bytes marked.
    pub fn mark_owner_next_touch(&self, task: TaskId) -> u64 {
        let slots = self.slots.lock().unwrap();
        let hot = self.hot.read().unwrap();
        let mut bytes = 0;
        for (slot, h) in slots.iter().zip(hot.iter()) {
            if slot.owner == Some(task) {
                h.next_touch.store(true, Ordering::Release);
                bytes += slot.size;
            }
        }
        bytes
    }

    /// Total bytes of regions that are both attached and homed — the
    /// amount the footprint counters must account for (conservation).
    /// Striped regions are homed at declaration, so they count in full.
    pub fn attached_homed_bytes(&self) -> u64 {
        let slots = self.slots.lock().unwrap();
        let hot = self.hot.read().unwrap();
        slots
            .iter()
            .zip(hot.iter())
            .filter(|(s, h)| s.owner.is_some() && h.is_homed())
            .map(|(s, _)| s.size)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_policies_place_homes() {
        let reg = RegionRegistry::new(4);
        let ft = reg.alloc(100, AllocPolicy::FirstTouch);
        let fx = reg.alloc(100, AllocPolicy::Fixed(2));
        let r0 = reg.alloc(100, AllocPolicy::RoundRobin);
        let r1 = reg.alloc(100, AllocPolicy::RoundRobin);
        assert_eq!(reg.home(ft), None);
        assert_eq!(reg.home(fx), Some(2));
        assert_eq!(reg.home(r0), Some(0));
        assert_eq!(reg.home(r1), Some(1));
        assert_eq!(reg.len(), 4);
    }

    #[test]
    fn first_touch_homes_and_reports() {
        let reg = RegionRegistry::new(2);
        let r = reg.alloc(64, AllocPolicy::FirstTouch);
        let (t, delta) = reg.touch(r, CpuId(3), 1);
        assert_eq!(t.home, 1);
        assert_eq!(t.last_toucher, None);
        assert_eq!(t.migrated, 0);
        assert!(matches!(delta, Some(HomeChange::Homed { node: 1, size: 64, .. })));
        // Second touch: stable home, last toucher reported.
        let (t2, delta2) = reg.touch(r, CpuId(0), 0);
        assert_eq!(t2.home, 1);
        assert_eq!(t2.last_toucher, Some(CpuId(3)));
        assert!(delta2.is_none());
        assert_eq!(reg.info(r).touches, 2);
    }

    #[test]
    fn next_touch_migrates_once() {
        let reg = RegionRegistry::new(2);
        let r = reg.alloc(128, AllocPolicy::Fixed(0));
        reg.mark_next_touch(r);
        let (t, delta) = reg.touch(r, CpuId(2), 1);
        assert_eq!(t.home, 1);
        assert_eq!(t.migrated, 128);
        assert!(matches!(
            delta,
            Some(HomeChange::Moved { from: 0, to: 1, size: 128, .. })
        ));
        // Mark consumed: a further remote touch does not migrate.
        let (t2, delta2) = reg.touch(r, CpuId(0), 0);
        assert_eq!(t2.home, 1);
        assert_eq!(t2.migrated, 0);
        assert!(delta2.is_none());
    }

    #[test]
    fn same_node_touch_consumes_the_mark() {
        let reg = RegionRegistry::new(2);
        let r = reg.alloc(128, AllocPolicy::Fixed(1));
        reg.mark_next_touch(r);
        let (t, _) = reg.touch(r, CpuId(2), 1);
        assert_eq!((t.home, t.migrated), (1, 0));
        assert!(!reg.info(r).next_touch);
    }

    #[test]
    fn owner_marking_and_conservation_sum() {
        let reg = RegionRegistry::new(2);
        let a = reg.alloc(100, AllocPolicy::Fixed(0));
        let b = reg.alloc(50, AllocPolicy::FirstTouch);
        let (prev, delta) = reg.attach(a, TaskId(7));
        assert_eq!(prev, None);
        assert!(matches!(delta.as_slice(), [HomeChange::Homed { node: 0, size: 100, .. }]));
        let (_, delta_b) = reg.attach(b, TaskId(7));
        assert!(delta_b.is_empty(), "unhomed region has no footprint yet");
        assert_eq!(reg.attached_homed_bytes(), 100);
        reg.touch(b, CpuId(0), 0);
        assert_eq!(reg.attached_homed_bytes(), 150);
        assert_eq!(reg.mark_owner_next_touch(TaskId(7)), 150);
        assert!(reg.info(a).next_touch && reg.info(b).next_touch);
    }

    #[test]
    fn striped_alloc_splits_bytes_over_declared_nodes() {
        let reg = RegionRegistry::new(4);
        let r = reg.alloc_striped(10, &[1, 3, 0]);
        let info = reg.info(r);
        assert_eq!(info.home, None, "striped regions have no single home");
        assert!(info.is_homed());
        let nodes: Vec<usize> = info.stripes.iter().map(|s| s.node).collect();
        assert_eq!(nodes, vec![1, 3, 0]);
        let sizes: Vec<u64> = info.stripes.iter().map(|s| s.size).collect();
        assert_eq!(sizes, vec![4, 3, 3], "remainder goes to the first stripes");
        assert_eq!(sizes.iter().sum::<u64>(), 10);
        assert_eq!(info.homed_bytes_per_node(4), vec![3, 4, 0, 3]);
    }

    #[test]
    fn striped_touches_rotate_and_next_touch_moves_one_stripe() {
        let reg = RegionRegistry::new(4);
        let r = reg.alloc_striped(30, &[0, 1, 2]);
        // Touches sweep the stripes: nodes 0, 1, 2, 0, ...
        let (t0, d0) = reg.touch(r, CpuId(0), 3);
        let (t1, d1) = reg.touch(r, CpuId(0), 3);
        assert_eq!((t0.home, t1.home), (0, 1));
        assert!(d0.is_none() && d1.is_none());
        // Mark next-touch: the *third* touch (stripe 2) migrates only
        // that stripe to the toucher's node.
        reg.mark_next_touch(r);
        let (t2, d2) = reg.touch(r, CpuId(12), 3);
        assert_eq!(t2.home, 3);
        assert_eq!(t2.migrated, 10);
        assert!(matches!(d2, Some(HomeChange::Moved { from: 2, to: 3, size: 10, .. })));
        // The other stripes did not move; the rotation continues.
        let (t3, d3) = reg.touch(r, CpuId(0), 0);
        assert_eq!((t3.home, t3.migrated), (0, 0));
        assert!(d3.is_none());
        assert_eq!(reg.info(r).homed_bytes_per_node(4), vec![10, 10, 0, 10]);
    }

    #[test]
    fn pressure_view_tracks_homes_and_migrations() {
        let reg = RegionRegistry::new(2);
        assert_eq!(reg.pressure_view(), vec![0, 0]);
        let _ = reg.alloc(100, AllocPolicy::Fixed(0));
        assert_eq!(reg.pressure_view(), vec![100, 0]);
        let b = reg.alloc(60, AllocPolicy::FirstTouch);
        assert_eq!(reg.pressure_view(), vec![100, 0], "unhomed bytes exert no pressure");
        reg.touch(b, CpuId(2), 1);
        assert_eq!(reg.pressure_view(), vec![100, 60]);
        reg.mark_next_touch(b);
        reg.touch(b, CpuId(0), 0);
        assert_eq!(reg.pressure_view(), vec![160, 0], "next-touch moved the bytes");
        let _ = reg.alloc_striped(10, &[0, 1]);
        assert_eq!(reg.pressure_view(), vec![165, 5]);
        assert_eq!(reg.node_pressure(1), 5);
    }

    #[test]
    fn fast_touch_commits_only_when_placement_cannot_change() {
        let reg = RegionRegistry::new(2);
        let r = reg.alloc(64, AllocPolicy::FirstTouch);
        // Unhomed: the first touch must home it — slow path only.
        assert!(reg.touch_fast(r, CpuId(0)).is_none());
        assert_eq!(reg.info(r).touches, 0, "a declined fast touch records nothing");
        reg.touch(r, CpuId(0), 0);
        // Homed and unmarked: fast path commits.
        let t = reg.touch_fast(r, CpuId(3)).expect("steady state takes the fast path");
        assert_eq!((t.home, t.migrated), (0, 0));
        assert_eq!(t.last_toucher, Some(CpuId(0)));
        assert_eq!(reg.info(r).touches, 2);
        assert_eq!(reg.info(r).last_toucher, Some(CpuId(3)));
        // Marked: migration pending — back to the slow path.
        reg.mark_next_touch(r);
        assert!(reg.touch_fast(r, CpuId(1)).is_none());
    }

    #[test]
    fn fast_touches_share_the_stripe_rotation() {
        let reg = RegionRegistry::new(4);
        let r = reg.alloc_striped(30, &[0, 1, 2]);
        // Striped regions are placed at declaration, so even the very
        // first touch is placement-neutral. Fast and slow touches drive
        // one shared rotation counter.
        let t0 = reg.touch_fast(r, CpuId(0)).unwrap();
        let (t1, _) = reg.touch(r, CpuId(0), 3);
        let t2 = reg.touch_fast(r, CpuId(0)).unwrap();
        let t3 = reg.touch_fast(r, CpuId(0)).unwrap();
        assert_eq!(
            (t0.home, t1.home, t2.home, t3.home),
            (0, 1, 2, 0),
            "rotation sweeps the stripes regardless of path"
        );
        assert_eq!(reg.info(r).touches, 4);
    }

    #[test]
    fn locality_notes_accumulate_per_region() {
        let reg = RegionRegistry::new(2);
        let r = reg.alloc(64, AllocPolicy::Fixed(0));
        let s = reg.alloc(64, AllocPolicy::Fixed(1));
        reg.note_locality(r, true);
        reg.note_locality(r, true);
        reg.note_locality(r, false);
        reg.note_locality(s, false);
        let ri = reg.info(r);
        assert_eq!((ri.local_touches, ri.remote_touches), (2, 1));
        let si = reg.info(s);
        assert_eq!((si.local_touches, si.remote_touches), (0, 1));
    }

    #[test]
    fn pressure_epoch_moves_exactly_with_placement() {
        let reg = RegionRegistry::new(2);
        let e0 = reg.pressure_epoch();
        let r = reg.alloc(100, AllocPolicy::Fixed(0));
        let e1 = reg.pressure_epoch();
        assert!(e1 > e0, "placing a region moves the epoch");
        // Steady-state touches change nothing: epoch holds, so a cached
        // pressure snapshot stays valid.
        reg.touch_fast(r, CpuId(1)).unwrap();
        reg.touch(r, CpuId(1), 1);
        assert_eq!(reg.pressure_epoch(), e1);
        // Migration moves bytes: epoch moves.
        reg.mark_next_touch(r);
        reg.touch(r, CpuId(2), 1);
        assert!(reg.pressure_epoch() > e1);
        let mut buf = Vec::new();
        reg.pressure_view_into(&mut buf);
        assert_eq!(buf, vec![0, 100]);
    }
}
