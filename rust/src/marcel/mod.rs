//! MARCEL-style public API (paper §4, Figure 4).
//!
//! Mirrors the C interface of the paper's implementation:
//!
//! ```c
//! marcel_bubble_init(&bubble);
//! marcel_create_dontsched(&thread1, NULL, fun1, para1);
//! marcel_bubble_inserttask(&bubble, thread1);
//! marcel_wake_up_bubble(&bubble);
//! marcel_bubble_inserttask(&bubble, thread2);   // late insertion works
//! ```
//!
//! [`Marcel`] can own its [`System`] + [`BubbleScheduler`] (application
//! use) or be constructed over an existing system (tests / engines that
//! drive the scheduler themselves).

use std::sync::Arc;

use crate::mem::{AllocPolicy, RegionId};
use crate::sched::{BubbleConfig, BubbleScheduler, Scheduler, System};
use crate::task::{BubblePhase, BurstLevel, Prio, TaskId, TaskState, PRIO_BUBBLE, PRIO_THREAD};
use crate::topology::Topology;

/// Handle to the thread/bubble construction API.
pub struct Marcel {
    sys: Arc<System>,
    sched: Arc<BubbleScheduler>,
}

impl Marcel {
    /// Create a fresh system over `topo` with a default bubble scheduler.
    pub fn new(topo: Topology) -> Marcel {
        Marcel::with_config(topo, BubbleConfig::default())
    }

    /// Create with explicit scheduler tunables.
    pub fn with_config(topo: Topology, cfg: BubbleConfig) -> Marcel {
        Marcel {
            sys: Arc::new(System::new(Arc::new(topo))),
            sched: Arc::new(BubbleScheduler::new(cfg)),
        }
    }

    /// Borrow an existing system (the scheduler here is only used by
    /// `wake_up_bubble`; engines usually drive their own).
    pub fn with_system(sys: &Arc<System>) -> Marcel {
        Marcel { sys: sys.clone(), sched: Arc::new(BubbleScheduler::new(BubbleConfig::default())) }
    }

    /// Wire an existing system to an existing scheduler.
    pub fn over(sys: Arc<System>, sched: Arc<BubbleScheduler>) -> Marcel {
        Marcel { sys, sched }
    }

    /// The underlying system.
    pub fn system(&self) -> &Arc<System> {
        &self.sys
    }

    /// The underlying scheduler.
    pub fn scheduler(&self) -> &Arc<BubbleScheduler> {
        &self.sched
    }

    // ------------------------------------------------------------- threads

    /// `marcel_create_dontsched`: create a thread *without* starting it
    /// (it runs only once released by a bubble or woken explicitly).
    pub fn create_dontsched(&self, name: impl Into<String>) -> TaskId {
        self.sys.tasks.new_thread(name, PRIO_THREAD)
    }

    /// Create a thread with an explicit priority (Figure 1's highly
    /// prioritised communication thread).
    pub fn create_dontsched_prio(&self, name: impl Into<String>, prio: Prio) -> TaskId {
        self.sys.tasks.new_thread(name, prio)
    }

    // ------------------------------------------------------------- bubbles

    /// `marcel_bubble_init`: a fresh, closed, empty bubble.
    pub fn bubble_init(&self) -> TaskId {
        self.sys.tasks.new_bubble("bubble", PRIO_BUBBLE)
    }

    /// A bubble with an explicit bursting level and priority.
    pub fn bubble_init_with(&self, burst: BurstLevel, prio: Prio) -> TaskId {
        let b = self.sys.tasks.new_bubble("bubble", prio);
        self.sys.tasks.with(b, |t| t.bubble_data_mut().burst = Some(burst));
        b
    }

    /// Set a bubble's time slice (preventive regeneration / gang
    /// scheduling, §3.3.3).
    pub fn bubble_settimeslice(&self, bubble: TaskId, slice: u64) {
        self.sys.tasks.with(bubble, |t| t.bubble_data_mut().timeslice = Some(slice));
    }

    /// `marcel_bubble_inserttask`: put a thread (or anything schedulable)
    /// into a bubble. Late insertion into an already-burst bubble
    /// releases the task onto the bubble's home list (Figure 4 inserts
    /// thread2 after `wake_up_bubble`).
    pub fn bubble_inserttask(&self, bubble: TaskId, task: TaskId) {
        let phase = self.sys.tasks.with(bubble, |b| {
            let d = b.bubble_data_mut();
            d.contents.push(task);
            d.live += 1;
            d.phase
        });
        self.sys.tasks.with(task, |t| {
            debug_assert!(
                t.parent.is_none(),
                "{} already belongs to a bubble",
                t.id
            );
            t.parent = Some(bubble);
            if t.state == TaskState::New {
                t.state = TaskState::InBubble;
            }
        });
        // Regions attached before the insertion now count towards the
        // enclosing bubbles too (attach/insert order must not matter).
        self.sys.mem.note_insert(&self.sys.tasks, task);
        if phase == BubblePhase::Burst {
            // Late insertion: release immediately.
            self.sched.wake(&self.sys, task);
        }
    }

    /// Nest a sub-bubble inside a bubble (refining the affinity
    /// relation, §3.1).
    pub fn bubble_insertbubble(&self, outer: TaskId, inner: TaskId) {
        debug_assert!(self.sys.tasks.is_bubble(inner));
        self.bubble_inserttask(outer, inner);
    }

    /// `marcel_wake_up_bubble`: hand the bubble to the scheduler (it
    /// starts descending from the machine root).
    pub fn wake_up_bubble(&self, bubble: TaskId) {
        self.sched.wake(&self.sys, bubble);
    }

    /// Wake a standalone thread (no bubble).
    pub fn wake_thread(&self, task: TaskId) {
        self.sched.wake(&self.sys, task);
    }

    // ------------------------------------------------------------- memory

    /// `marcel_region_alloc`: register a block of application memory
    /// with the system registry ([`crate::mem`]). The region is homed
    /// per `policy` (first touch by default, as in the paper §2.3).
    pub fn region_alloc(&self, bytes: u64, policy: AllocPolicy) -> RegionId {
        self.sys.mem.alloc(bytes, policy)
    }

    /// `marcel_region_alloc_striped`: one region spread over several
    /// home nodes — shared data no single thread owns. Touches rotate
    /// over the stripes and next-touch migrates one stripe at a time
    /// (see [`crate::mem::RegionRegistry::alloc_striped`]).
    pub fn region_alloc_striped(&self, bytes: u64, nodes: &[usize]) -> RegionId {
        self.sys.mem.alloc_striped(bytes, nodes)
    }

    /// `marcel_attach_region`: declare that `task` (thread or bubble)
    /// works on `region`. Its bytes then count towards the task's — and
    /// every enclosing bubble's — NUMA footprint, which memory-aware
    /// policies consult for placement.
    pub fn attach_region(&self, task: TaskId, region: RegionId) {
        self.sys.mem.attach(&self.sys.tasks, task, region);
    }

    /// Declare two threads SMT-symbiotic (§3.1: pairs that exploit the
    /// logical processors of one physical core without interfering).
    pub fn set_symbiotic(&self, a: TaskId, b: TaskId) {
        self.sys.tasks.with(a, |t| t.thread_data_mut().symbiotic = Some(b));
        self.sys.tasks.with(b, |t| t.thread_data_mut().symbiotic = Some(a));
    }

    /// Build a bubble hierarchy mirroring the machine: one bubble per
    /// NUMA node holding `threads_per_node` threads (the Table-2
    /// "Bubbles" row: "the application query MARCEL about the number of
    /// NUMA nodes and processors and then automatically build bubbles
    /// according to the hierarchy of the machine").
    pub fn bubbles_from_topology(&self, names: &[String]) -> (TaskId, Vec<TaskId>) {
        let n_nodes = self.sys.topo.n_numa().max(1);
        let per = names.len().div_ceil(n_nodes);
        // The root bubble must burst on the machine list so the
        // per-node bubbles can fan out to *different* nodes.
        let root = self.bubble_init_with(BurstLevel::Immediate, PRIO_BUBBLE);
        let mut threads = Vec::with_capacity(names.len());
        for chunk in names.chunks(per.max(1)) {
            let node_bubble = self.bubble_init();
            for name in chunk {
                let t = self.create_dontsched(name.clone());
                self.bubble_inserttask(node_bubble, t);
                threads.push(t);
            }
            self.bubble_insertbubble(root, node_bubble);
        }
        (root, threads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::CpuId;

    #[test]
    fn figure4_sequence() {
        let m = Marcel::new(Topology::numa(2, 2));
        let b = m.bubble_init();
        let t1 = m.create_dontsched("t1");
        let t2 = m.create_dontsched("t2");
        m.bubble_inserttask(b, t1);
        m.wake_up_bubble(b);
        m.bubble_inserttask(b, t2); // after wake, as in Figure 4
        let sys = m.system();
        let s = m.scheduler();
        let a = s.pick(sys, CpuId(0));
        let c = s.pick(sys, CpuId(1));
        let got: std::collections::BTreeSet<_> = [a, c].into_iter().flatten().collect();
        assert_eq!(got, [t1, t2].into());
    }

    #[test]
    fn topology_driven_bubbles() {
        let m = Marcel::new(Topology::numa(4, 4));
        let names: Vec<String> = (0..16).map(|i| format!("w{i}")).collect();
        let (root, threads) = m.bubbles_from_topology(&names);
        assert_eq!(threads.len(), 16);
        let contents = m.system().tasks.with(root, |t| t.kind_contents_snapshot());
        assert_eq!(contents.len(), 4, "one sub-bubble per NUMA node");
        for b in contents {
            let inner = m.system().tasks.with(b, |t| t.kind_contents_snapshot());
            assert_eq!(inner.len(), 4);
        }
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn double_insert_panics_in_debug() {
        let m = Marcel::new(Topology::smp(2));
        let b1 = m.bubble_init();
        let b2 = m.bubble_init();
        let t = m.create_dontsched("t");
        m.bubble_inserttask(b1, t);
        m.bubble_inserttask(b2, t);
    }

    #[test]
    fn symbiosis_is_mutual() {
        let m = Marcel::new(Topology::xeon_2x_ht());
        let a = m.create_dontsched("a");
        let b = m.create_dontsched("b");
        m.set_symbiotic(a, b);
        assert_eq!(m.system().tasks.with(a, |t| t.thread_data().symbiotic), Some(b));
        assert_eq!(m.system().tasks.with(b, |t| t.thread_data().symbiotic), Some(a));
    }

    #[test]
    fn attach_before_insert_still_aggregates() {
        // Regression: regions attached while the thread was loose must
        // surface in the bubble's footprint after insertion.
        let m = Marcel::new(Topology::numa(2, 2));
        let t = m.create_dontsched("t");
        let r = m.region_alloc(4096, AllocPolicy::Fixed(1));
        m.attach_region(t, r);
        let b = m.bubble_init();
        m.bubble_inserttask(b, t);
        let sys = m.system();
        assert_eq!(sys.mem.dominant_node(b), Some(1), "bubble must see pre-attached bytes");
        assert!(sys.mem.conserved(&sys.tasks));
    }

    #[test]
    fn region_attach_feeds_bubble_footprint() {
        let m = Marcel::new(Topology::numa(2, 2));
        let b = m.bubble_init();
        let t = m.create_dontsched("t");
        m.bubble_inserttask(b, t);
        let r = m.region_alloc(4096, AllocPolicy::Fixed(1));
        m.attach_region(t, r);
        let sys = m.system();
        assert_eq!(sys.mem.dominant_node(t), Some(1));
        assert_eq!(sys.mem.dominant_node(b), Some(1), "bubbles aggregate members");
        assert!(sys.mem.conserved(&sys.tasks));
    }

    #[test]
    fn striped_region_spreads_bubble_footprint() {
        let m = Marcel::new(Topology::numa(2, 2));
        let b = m.bubble_init();
        let t = m.create_dontsched("t");
        m.bubble_inserttask(b, t);
        let r = m.region_alloc_striped(4096, &[0, 1]);
        m.attach_region(t, r);
        let sys = m.system();
        assert_eq!(sys.mem.footprint.of(t), vec![2048, 2048]);
        assert_eq!(sys.mem.footprint.of(b), vec![2048, 2048]);
        assert!(sys.mem.conserved(&sys.tasks));
        assert!(sys.mem.hierarchy_consistent(&sys.tasks));
    }

    #[test]
    fn timeslice_setter() {
        let m = Marcel::new(Topology::smp(2));
        let b = m.bubble_init();
        m.bubble_settimeslice(b, 500);
        assert_eq!(m.system().tasks.with(b, |t| t.bubble_data().timeslice), Some(500));
    }
}
