//! Worker pool: virtual processors running green threads under a
//! pluggable scheduler.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use super::fiber::{Fiber, YieldAction};
use crate::sched::{Scheduler, StopReason, System};
use crate::task::TaskId;
use crate::topology::CpuId;

/// Barrier state shared between workers.
#[derive(Debug, Default)]
struct BarrierState {
    parties: usize,
    arrived: usize,
    waiting: Vec<TaskId>,
}

/// Shared executor state.
struct Inner {
    sys: Arc<System>,
    sched: Arc<dyn Scheduler>,
    fibers: Mutex<HashMap<TaskId, Fiber>>,
    barriers: Mutex<Vec<BarrierState>>,
    live: AtomicUsize,
    stop: AtomicBool,
    /// Idle workers park here until work may be available.
    idle: Mutex<()>,
    idle_cv: Condvar,
}

/// API handed to green-thread bodies (thin facade over fiber yields).
#[derive(Clone)]
pub struct GreenApi {
    inner: Arc<Inner>,
}

impl GreenApi {
    /// Voluntary reschedule point.
    pub fn yield_now(&self) {
        super::fiber::yield_now();
    }

    /// Arrive at barrier `id` and wait for all parties.
    pub fn barrier(&self, id: usize) {
        super::fiber::fiber_yield(YieldAction::Barrier(id));
    }

    /// The system (topology, metrics) for introspection.
    pub fn system(&self) -> &Arc<System> {
        &self.inner.sys
    }
}

/// Run report.
#[derive(Debug, Clone)]
pub struct ExecReport {
    /// Wall time of the whole run.
    pub elapsed: std::time::Duration,
    /// Green threads executed.
    pub threads: usize,
}

/// The native executor.
pub struct Executor {
    inner: Arc<Inner>,
    threads: usize,
}

impl Executor {
    /// Build over a system + scheduler. One worker OS thread will be
    /// spawned per topology CPU at [`Executor::run`].
    pub fn new(sys: Arc<System>, sched: Arc<dyn Scheduler>) -> Executor {
        Executor {
            inner: Arc::new(Inner {
                sys,
                sched,
                fibers: Mutex::new(HashMap::new()),
                barriers: Mutex::new(Vec::new()),
                live: AtomicUsize::new(0),
                stop: AtomicBool::new(false),
                idle: Mutex::new(()),
                idle_cv: Condvar::new(),
            }),
            threads: 0,
        }
    }

    /// Allocate a native barrier.
    pub fn alloc_barrier(&self, parties: usize) -> usize {
        let mut b = self.inner.barriers.lock().unwrap();
        b.push(BarrierState { parties, arrived: 0, waiting: Vec::new() });
        b.len() - 1
    }

    /// Register a green thread (task must already exist in the system,
    /// e.g. created through [`crate::marcel::Marcel`]).
    pub fn register(&mut self, task: TaskId, body: impl FnOnce(GreenApi) + Send + 'static) {
        let api = GreenApi { inner: self.inner.clone() };
        let fiber = Fiber::new(move || body(api));
        self.inner.fibers.lock().unwrap().insert(task, fiber);
        self.inner.live.fetch_add(1, Ordering::SeqCst);
        self.threads += 1;
    }

    /// Convenience: create + register + wake a loose green thread.
    pub fn spawn(
        &mut self,
        name: impl Into<String>,
        body: impl FnOnce(GreenApi) + Send + 'static,
    ) -> TaskId {
        let t = self.inner.sys.tasks.new_thread(name, crate::task::PRIO_THREAD);
        self.register(t, body);
        self.inner.sched.wake(&self.inner.sys, t);
        t
    }

    /// Wake a task (thread or bubble) through the scheduler.
    pub fn wake(&self, task: TaskId) {
        self.inner.sched.wake(&self.inner.sys, task);
    }

    /// Run until every registered green thread has exited. Spawns one
    /// worker per topology CPU.
    pub fn run(&mut self) -> ExecReport {
        let t0 = Instant::now();
        let n = self.inner.sys.topo.n_cpus();
        let mut joins = Vec::with_capacity(n);
        for c in 0..n {
            let inner = self.inner.clone();
            joins.push(
                std::thread::Builder::new()
                    .name(format!("vcpu{c}"))
                    .spawn(move || worker_loop(inner, CpuId(c)))
                    .expect("spawn worker"),
            );
        }
        for j in joins {
            j.join().expect("worker panicked");
        }
        ExecReport { elapsed: t0.elapsed(), threads: self.threads }
    }

    /// The underlying system.
    pub fn system(&self) -> &Arc<System> {
        &self.inner.sys
    }
}

fn worker_loop(inner: Arc<Inner>, cpu: CpuId) {
    loop {
        if inner.live.load(Ordering::SeqCst) == 0 || inner.stop.load(Ordering::SeqCst) {
            inner.idle_cv.notify_all();
            return;
        }
        let Some(task) = inner.sched.pick(&inner.sys, cpu) else {
            // Park briefly; a finishing/blocking thread notifies.
            let guard = inner.idle.lock().unwrap();
            let _ = inner
                .idle_cv
                .wait_timeout(guard, std::time::Duration::from_micros(200))
                .unwrap();
            continue;
        };
        // Take exclusive ownership of the fiber while it runs.
        let mut fiber = {
            let mut fibers = inner.fibers.lock().unwrap();
            match fibers.remove(&task) {
                Some(f) => f,
                None => {
                    // A task without a fiber body (shouldn't happen):
                    // terminate it defensively.
                    inner.sched.stop(&inner.sys, cpu, task, StopReason::Terminate);
                    continue;
                }
            }
        };
        let action = fiber.resume();
        match action {
            YieldAction::Yield => {
                inner.fibers.lock().unwrap().insert(task, fiber);
                inner.sched.stop(&inner.sys, cpu, task, StopReason::Yield);
            }
            YieldAction::Barrier(id) => {
                inner.fibers.lock().unwrap().insert(task, fiber);
                let released = {
                    let mut bars = inner.barriers.lock().unwrap();
                    let bar = &mut bars[id];
                    bar.arrived += 1;
                    if bar.arrived == bar.parties {
                        bar.arrived = 0;
                        Some(std::mem::take(&mut bar.waiting))
                    } else {
                        bar.waiting.push(task);
                        None
                    }
                };
                match released {
                    Some(waiters) => {
                        inner.sys.trace.emit(
                            inner.sys.now(),
                            crate::trace::Event::BarrierRelease {
                                id,
                                waiters: waiters.len() + 1,
                            },
                        );
                        // Last arriver yields; the blocked ones wake.
                        inner.sched.stop(&inner.sys, cpu, task, StopReason::Yield);
                        for w in waiters {
                            inner.sched.wake(&inner.sys, w);
                        }
                        inner.idle_cv.notify_all();
                    }
                    None => {
                        inner.sched.stop(&inner.sys, cpu, task, StopReason::Block);
                    }
                }
            }
            YieldAction::Exited => {
                drop(fiber);
                inner.sched.stop(&inner.sys, cpu, task, StopReason::Terminate);
                inner.live.fetch_sub(1, Ordering::SeqCst);
                inner.idle_cv.notify_all();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::marcel::Marcel;
    use crate::sched::{BubbleConfig, BubbleScheduler};
    use crate::task::TaskState;
    use crate::topology::Topology;
    use std::sync::atomic::AtomicU64;

    fn executor(topo: Topology) -> Executor {
        let sys = Arc::new(System::new(Arc::new(topo)));
        let sched = Arc::new(BubbleScheduler::new(BubbleConfig::default()));
        Executor::new(sys, sched)
    }

    #[test]
    fn runs_loose_threads_to_completion() {
        let mut ex = executor(Topology::smp(4));
        let count = Arc::new(AtomicU64::new(0));
        for i in 0..16 {
            let c = count.clone();
            ex.spawn(format!("t{i}"), move |api| {
                for _ in 0..3 {
                    c.fetch_add(1, Ordering::SeqCst);
                    api.yield_now();
                }
            });
        }
        let rep = ex.run();
        assert_eq!(rep.threads, 16);
        assert_eq!(count.load(Ordering::SeqCst), 48);
    }

    #[test]
    fn native_barrier_synchronises() {
        let mut ex = executor(Topology::smp(4));
        let bar = ex.alloc_barrier(4);
        let phase = Arc::new(AtomicU64::new(0));
        let after = Arc::new(AtomicU64::new(0));
        for i in 0..4 {
            let (p, a) = (phase.clone(), after.clone());
            ex.spawn(format!("t{i}"), move |api| {
                p.fetch_add(1, Ordering::SeqCst);
                api.barrier(bar);
                // Everyone must have finished phase 1 by now.
                a.fetch_max(p.load(Ordering::SeqCst), Ordering::SeqCst);
            });
        }
        ex.run();
        assert_eq!(after.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn bubble_structured_green_threads() {
        // Full stack: marcel bubbles + bubble scheduler + native
        // fibers on a NUMA topology.
        let sys = Arc::new(System::new(Arc::new(Topology::numa(2, 2))));
        let sched = Arc::new(BubbleScheduler::new(BubbleConfig::default()));
        let m = Marcel::over(sys.clone(), sched.clone());
        let mut ex = Executor::new(sys, sched);
        let done = Arc::new(AtomicU64::new(0));
        let b = m.bubble_init();
        for i in 0..4 {
            let t = m.create_dontsched(format!("w{i}"));
            m.bubble_inserttask(b, t);
            let d = done.clone();
            ex.register(t, move |api| {
                d.fetch_add(1, Ordering::SeqCst);
                api.yield_now();
                d.fetch_add(1, Ordering::SeqCst);
            });
        }
        m.wake_up_bubble(b);
        ex.run();
        assert_eq!(done.load(Ordering::SeqCst), 8);
        assert_eq!(ex.system().tasks.state(b), TaskState::Terminated);
    }

    #[test]
    fn barrier_cycles_under_bubbles() {
        // Conduction-shaped native run: stripes + repeated barriers.
        let sys = Arc::new(System::new(Arc::new(Topology::numa(2, 2))));
        let sched = Arc::new(BubbleScheduler::new(BubbleConfig::default()));
        let m = Marcel::over(sys.clone(), sched.clone());
        let mut ex = Executor::new(sys, sched);
        let bar = ex.alloc_barrier(4);
        let sum = Arc::new(AtomicU64::new(0));
        let b = m.bubble_init();
        for i in 0..4 {
            let t = m.create_dontsched(format!("stripe{i}"));
            m.bubble_inserttask(b, t);
            let s = sum.clone();
            ex.register(t, move |api| {
                for _ in 0..5 {
                    s.fetch_add(1, Ordering::SeqCst);
                    api.barrier(bar);
                }
            });
        }
        m.wake_up_bubble(b);
        ex.run();
        assert_eq!(sum.load(Ordering::SeqCst), 20);
    }
}
