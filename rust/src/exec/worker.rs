//! Worker pool: virtual processors running green threads under a
//! pluggable scheduler.
//!
//! Green threads see the memory subsystem through [`GreenApi`]: a body
//! calls [`GreenApi::touch_region`] as it works through its data, and
//! the touch is attributed to the *worker CPU actually running the
//! fiber* (a thread-local set by the worker loop). That makes
//! footprints, next-touch migration and the local/remote access
//! metrics live on real OS workers exactly as on the simulator — both
//! engines share [`System::touch_region`].
//!
//! **Tick protocol** (mirrors [`crate::sim`]'s `segment_end`): every
//! `resume()` of a fiber is one scheduling segment. The worker measures
//! the segment's wall nanoseconds and charges them to the scheduler
//! through [`Scheduler::tick`] before resolving the fiber's yield
//! action. A `true` return turns a voluntary yield into a
//! [`StopReason::Preempt`] — that is how strict-gang rotation, moldable
//! timeslice rotation and the bubble scheduler's preventive
//! regeneration run on real OS workers. Barrier and exit actions keep
//! their own stop reasons (unlike the simulator, a fiber that yielded
//! *at* a barrier has already passed the arrival point, so the barrier
//! must be processed; the tick's side effects — gang rotation etc. —
//! still happen).
//!
//! **Idle protocol**: a worker whose pick came up empty parks on the
//! [`Park`] condvar against the wake generation `seq`. Plain idleness
//! (nothing queued anywhere) waits for an enqueue notification; queued
//! but *unpickable* work (a policy refused this CPU, e.g. a parked
//! moldable gang on another component) takes a capped exponential
//! backoff on the same condvar — still woken instantly by any enqueue,
//! counted in `metrics.exec_backoffs` so tests can bound it. All
//! termination paths bump `seq` and notify under the park lock, so the
//! remaining timeouts are pure safety backstops, not wake mechanisms.
//!
//! **Pinning protocol**: when the topology carries a vCPU → OS CPU map
//! ([`crate::topology::Topology::os_cpus`], i.e. `--machine detect`),
//! each worker pins itself to its vCPU's OS CPU with
//! `sched_setaffinity` before its first pick, so "vCPU c" is a real
//! hardware placement and the memory-locality numbers describe
//! silicon. The fallback is *per worker* and graceful: a denied
//! affinity call (cgroup-restricted CI, seccomp) bumps
//! `metrics.pin_failures` and leaves that worker loose — semantics are
//! identical, only the placement guarantee is lost. Preset topologies
//! have no map and skip pinning entirely. If the scheduler *requires*
//! binding ([`Scheduler::needs_binding`], the `bound` policy), running
//! without affinity additionally emits a one-time warning on stderr
//! and counts `metrics.bound_unpinned` per worker, instead of silently
//! degrading bound threads to loose ones.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use super::fiber::{Fiber, YieldAction};
use crate::mem::{RegionId, Touch};
use crate::sched::{Scheduler, StopReason, System};
use crate::task::TaskId;
use crate::topology::CpuId;

/// Barrier state shared between workers.
#[derive(Debug, Default)]
struct BarrierState {
    parties: usize,
    arrived: usize,
    waiting: Vec<TaskId>,
}

/// Parking lot for idle workers. Split out of [`Inner`] so the
/// enqueue-notification hook installed on the [`System`] can capture it
/// without creating an `Inner → System → Inner` reference cycle.
#[derive(Default)]
struct Park {
    lock: Mutex<()>,
    cv: Condvar,
    /// Workers currently parked (or about to park) on `cv`; lets the
    /// enqueue hook skip the lock entirely when nobody is waiting.
    parked: AtomicUsize,
    /// Wake generation, bumped on every notification. Workers compare
    /// it against a pre-pick snapshot before sleeping, so a wake from a
    /// scheduler whose work is *not* visible in `sys.rq` (gang's
    /// internal queue) still prevents the sleep.
    seq: AtomicUsize,
}

impl Park {
    /// Wake every parked worker, closing the missed-wakeup race: the
    /// generation bump happens before the locked notify, so a worker
    /// either sees the new generation during its (locked) pre-sleep
    /// check or is already in `wait` and receives the notification.
    /// Used by the termination paths, which the enqueue hook does not
    /// cover.
    fn wake_all(&self) {
        self.seq.fetch_add(1, Ordering::SeqCst);
        let _guard = self.lock.lock().unwrap();
        self.cv.notify_all();
    }
}

/// Backstop timeout for a plainly idle worker. All wake paths notify
/// under the park lock, so this is defense-in-depth against an unknown
/// missed-wakeup bug, not part of the protocol (it used to be 2 ms
/// *because* exit-path notifies fired unlocked and could be missed).
const PARK_BACKSTOP: std::time::Duration = std::time::Duration::from_millis(10);
/// Exponential backoff window for queued-but-unpickable work.
const BACKOFF_MIN: std::time::Duration = std::time::Duration::from_micros(20);
const BACKOFF_MAX: std::time::Duration = std::time::Duration::from_millis(2);

/// Shared executor state.
struct Inner {
    sys: Arc<System>,
    sched: Arc<dyn Scheduler>,
    fibers: Mutex<HashMap<TaskId, Fiber>>,
    barriers: Mutex<Vec<BarrierState>>,
    live: AtomicUsize,
    stop: AtomicBool,
    /// Idle workers park here; `ops::enqueue` notifies via the system's
    /// enqueue hook, so they wake on work arrival instead of timing out.
    park: Arc<Park>,
    /// Latch for the one-time bound-without-affinity warning (see the
    /// pinning protocol in the module docs).
    pin_warned: AtomicBool,
}

/// API handed to green-thread bodies (thin facade over fiber yields).
#[derive(Clone)]
pub struct GreenApi {
    inner: Arc<Inner>,
}

impl GreenApi {
    /// Voluntary reschedule point.
    pub fn yield_now(&self) {
        super::fiber::yield_now();
    }

    /// Arrive at barrier `id` and wait for all parties.
    pub fn barrier(&self, id: usize) {
        super::fiber::fiber_yield(YieldAction::Barrier(id));
    }

    /// The system (topology, metrics) for introspection.
    pub fn system(&self) -> &Arc<System> {
        &self.inner.sys
    }

    /// The virtual CPU currently running this green thread. Only valid
    /// inside a fiber body on a worker (panics elsewhere).
    pub fn cpu(&self) -> CpuId {
        crate::rq::owner::current_cpu().expect("GreenApi::cpu outside a worker fiber")
    }

    /// Record a memory touch on `region` from this green thread: the
    /// registry resolves the home (first touch homes, striped regions
    /// rotate over their stripes, next-touch migrates), the footprint
    /// accounting follows, and the local/remote access metrics are
    /// bumped — the native counterpart of the simulator's per-chunk
    /// touches (both go through [`System::touch_region`]).
    pub fn touch_region(&self, region: RegionId) -> Touch {
        self.inner.sys.touch_region(region, self.cpu())
    }

    /// Home node of a region (None before first touch; None for
    /// striped regions, whose homes are per stripe).
    pub fn region_home(&self, region: RegionId) -> Option<usize> {
        self.inner.sys.mem.home(region)
    }
}

/// Run report.
#[derive(Debug, Clone)]
pub struct ExecReport {
    /// Wall time of the whole run.
    pub elapsed: std::time::Duration,
    /// Green threads executed.
    pub threads: usize,
}

/// The native executor.
pub struct Executor {
    inner: Arc<Inner>,
    threads: usize,
}

impl Executor {
    /// Build over a system + scheduler. One worker OS thread will be
    /// spawned per topology CPU at [`Executor::run`].
    pub fn new(sys: Arc<System>, sched: Arc<dyn Scheduler>) -> Executor {
        let park = Arc::new(Park::default());
        // Wake parked workers whenever any path enqueues a runnable
        // task (ops::enqueue fires this hook). Protocol: a worker
        // raises `parked` *under the lock and before* its queue-empty
        // check; the hook reads `parked` *after* the push. So either
        // the hook sees parked > 0 (and its locked notify cannot slip
        // into the worker's check→wait window — the worker holds the
        // lock until the wait atomically releases it), or the worker's
        // queue check sees the push and it does not sleep. The common
        // nobody-parked case costs one atomic read, no lock.
        let p = park.clone();
        sys.set_enqueue_hook(Arc::new(move || {
            // Bump the wake generation first: a worker that raced past
            // this notify re-checks `seq` before sleeping. The SeqCst
            // RMW also orders the (Relaxed) runqueue counter increment
            // the caller just performed before our `parked` read;
            // paired with the worker-side fence this closes the
            // handshake on weakly-ordered hardware.
            p.seq.fetch_add(1, Ordering::SeqCst);
            if p.parked.load(Ordering::SeqCst) == 0 {
                return;
            }
            let _guard = p.lock.lock().unwrap();
            p.cv.notify_all();
        }));
        Executor {
            inner: Arc::new(Inner {
                sys,
                sched,
                fibers: Mutex::new(HashMap::new()),
                barriers: Mutex::new(Vec::new()),
                live: AtomicUsize::new(0),
                stop: AtomicBool::new(false),
                park,
                pin_warned: AtomicBool::new(false),
            }),
            threads: 0,
        }
    }

    /// Allocate a native barrier.
    pub fn alloc_barrier(&self, parties: usize) -> usize {
        let mut b = self.inner.barriers.lock().unwrap();
        b.push(BarrierState { parties, arrived: 0, waiting: Vec::new() });
        b.len() - 1
    }

    /// Register a green thread (task must already exist in the system,
    /// e.g. created through [`crate::marcel::Marcel`]).
    pub fn register(&mut self, task: TaskId, body: impl FnOnce(GreenApi) + Send + 'static) {
        let api = GreenApi { inner: self.inner.clone() };
        let fiber = Fiber::new(move || body(api));
        self.inner.fibers.lock().unwrap().insert(task, fiber);
        self.inner.live.fetch_add(1, Ordering::SeqCst);
        self.threads += 1;
    }

    /// Convenience: create + register + wake a loose green thread.
    pub fn spawn(
        &mut self,
        name: impl Into<String>,
        body: impl FnOnce(GreenApi) + Send + 'static,
    ) -> TaskId {
        let t = self.inner.sys.tasks.new_thread(name, crate::task::PRIO_THREAD);
        self.register(t, body);
        self.inner.sched.wake(&self.inner.sys, t);
        t
    }

    /// Wake a task (thread or bubble) through the scheduler.
    pub fn wake(&self, task: TaskId) {
        self.inner.sched.wake(&self.inner.sys, task);
    }

    /// Run until every registered green thread has exited. Spawns one
    /// worker per topology CPU.
    pub fn run(&mut self) -> ExecReport {
        let t0 = Instant::now();
        // Anchor the engine clock to wall time: from here `sys.now()`
        // reports monotonic ns, so native trace records and preemption
        // ticks share one real time base (idempotent across runs).
        self.inner.sys.start_wall_clock();
        let n = self.inner.sys.topo.n_cpus();
        let mut joins = Vec::with_capacity(n);
        for c in 0..n {
            let inner = self.inner.clone();
            joins.push(
                std::thread::Builder::new()
                    .name(format!("vcpu{c}"))
                    .spawn(move || worker_loop(inner, CpuId(c)))
                    .expect("spawn worker"),
            );
        }
        for j in joins {
            j.join().expect("worker panicked");
        }
        ExecReport { elapsed: t0.elapsed(), threads: self.threads }
    }

    /// The underlying system.
    pub fn system(&self) -> &Arc<System> {
        &self.inner.sys
    }

    /// Open a cross-thread submission handle: other OS threads can
    /// register and wake green threads *while the executor runs*. The
    /// handle is a liveness latch — workers will not quiesce while any
    /// `Submitter` is open, even if every registered fiber has exited,
    /// so a run cannot end between two submissions. Drop all handles to
    /// let the executor drain and return.
    pub fn submitter(&self) -> Submitter {
        self.inner.live.fetch_add(1, Ordering::SeqCst);
        Submitter { inner: self.inner.clone() }
    }
}

/// Cross-thread job submission into a running [`Executor`] (the
/// `repro serve` streaming path). Cloneable; each clone holds the
/// liveness latch independently. See [`Executor::submitter`].
pub struct Submitter {
    inner: Arc<Inner>,
}

impl Submitter {
    /// Register a green thread from any OS thread (the task must
    /// already exist in the system). The fiber becomes runnable once
    /// [`Submitter::wake`] reaches its task or job root.
    pub fn register(&self, task: TaskId, body: impl FnOnce(GreenApi) + Send + 'static) {
        let api = GreenApi { inner: self.inner.clone() };
        let fiber = Fiber::new(move || body(api));
        self.inner.fibers.lock().unwrap().insert(task, fiber);
        self.inner.live.fetch_add(1, Ordering::SeqCst);
    }

    /// Wake a task (thread or job-root bubble) through the scheduler.
    pub fn wake(&self, task: TaskId) {
        self.inner.sched.wake(&self.inner.sys, task);
    }

    /// Allocate a native barrier usable by subsequently submitted
    /// fibers.
    pub fn alloc_barrier(&self, parties: usize) -> usize {
        let mut b = self.inner.barriers.lock().unwrap();
        b.push(BarrierState { parties, arrived: 0, waiting: Vec::new() });
        b.len() - 1
    }

    /// The underlying system.
    pub fn system(&self) -> &Arc<System> {
        &self.inner.sys
    }
}

impl Clone for Submitter {
    fn clone(&self) -> Self {
        self.inner.live.fetch_add(1, Ordering::SeqCst);
        Submitter { inner: self.inner.clone() }
    }
}

impl Drop for Submitter {
    fn drop(&mut self) {
        // Release the latch and nudge the workers: if this was the last
        // handle and all fibers have exited, they observe live==0 and
        // quiesce.
        self.inner.live.fetch_sub(1, Ordering::SeqCst);
        self.inner.park.wake_all();
    }
}

/// Pin this worker OS thread to its vCPU's detected OS CPU, per the
/// pinning protocol in the module docs. Best-effort by design: every
/// outcome is counted, none aborts the run.
fn pin_worker(inner: &Inner, cpu: CpuId) {
    match inner.sys.topo.os_cpu(cpu) {
        Some(os) if crate::util::os::pin_to_os_cpu(os) => {
            crate::metrics::Metrics::inc(&inner.sys.metrics.workers_pinned);
        }
        Some(_) => {
            crate::metrics::Metrics::inc(&inner.sys.metrics.pin_failures);
            warn_unbound(inner, cpu, "sched_setaffinity denied");
        }
        // Preset topologies: nothing to pin to. Only a policy whose
        // contract needs real binding makes that worth reporting.
        None => warn_unbound(inner, cpu, "no detected OS-CPU map (preset machine)"),
    }
}

/// One-time loud warning (plus a per-worker metric) when a
/// binding-required policy runs without OS-level affinity.
fn warn_unbound(inner: &Inner, cpu: CpuId, why: &str) {
    if !inner.sched.needs_binding() {
        return;
    }
    crate::metrics::Metrics::inc(&inner.sys.metrics.bound_unpinned);
    if !inner.pin_warned.swap(true, Ordering::SeqCst) {
        eprintln!(
            "warning: policy `{}` requires thread binding, but worker vcpu{} \
             runs unpinned ({why}); bindings are scheduler-level only — use \
             --machine detect on hardware that allows sched_setaffinity for \
             real binding",
            inner.sched.name(),
            cpu.0
        );
    }
}

fn worker_loop(inner: Arc<Inner>, cpu: CpuId) {
    // This OS thread now acts as `cpu`: fibers resumed here attribute
    // their memory touches to it (see GreenApi::touch_region), and the
    // runqueue routes the worker's own same-priority pushes through the
    // leaf's lock-free fast lane (see crate::rq::owner).
    crate::rq::owner::set_current_cpu(Some(cpu));
    pin_worker(&inner, cpu);
    // Current backoff window for queued-but-unpickable work; grows
    // exponentially across consecutive refusals, resets on a pick.
    let mut backoff = BACKOFF_MIN;
    loop {
        if inner.live.load(Ordering::SeqCst) == 0 || inner.stop.load(Ordering::SeqCst) {
            inner.park.wake_all();
            return;
        }
        let seq_before = inner.park.seq.load(Ordering::SeqCst);
        // Time the pick only while tracing: the timer is two clock
        // reads, which would be measurable noise on the idle loop.
        let pick_t0 = inner.sys.trace.enabled().then(Instant::now);
        let picked = inner.sched.pick(&inner.sys, cpu);
        if let Some(t0) = pick_t0 {
            let ns = (t0.elapsed().as_nanos() as u64).max(1);
            inner.sys.metrics.pick_latency.record(ns);
            let ev = crate::trace::Event::PickLatency { cpu, ns, hit: picked.is_some() };
            inner.sys.trace.emit(inner.sys.now(), ev);
        }
        let Some(task) = picked else {
            crate::metrics::Metrics::inc(&inner.sys.metrics.idle_picks);
            inner.sys.rates.on_idle(&inner.sys.topo, cpu);
            // Nothing pickable. Park until the enqueue hook (or a
            // termination path) notifies — see Executor::new for the
            // missed-wakeup protocol — unless a wake already raced the
            // failed pick (generation changed). Work that is queued but
            // not pickable *by this CPU* (a policy refused it, e.g. a
            // moldable gang owning another component) parks too, on a
            // capped exponential backoff: any enqueue still wakes the
            // worker instantly, but it no longer busy-polls an OS core.
            let guard = inner.park.lock.lock().unwrap();
            if inner.live.load(Ordering::SeqCst) == 0 {
                continue; // loop top exits
            }
            inner.park.parked.fetch_add(1, Ordering::SeqCst);
            // Pairs with the SeqCst RMW in the enqueue hook: after it,
            // this thread's raised `parked` and the enqueuer's
            // (Relaxed) queue counters are mutually visible — one side
            // always sees the other.
            std::sync::atomic::fence(Ordering::SeqCst);
            let raced = inner.park.seq.load(Ordering::SeqCst) != seq_before;
            if !raced {
                let timeout = if inner.sys.rq.total_queued() == 0 {
                    PARK_BACKSTOP
                } else {
                    crate::metrics::Metrics::inc(&inner.sys.metrics.exec_backoffs);
                    let t = backoff;
                    backoff = (backoff * 2).min(BACKOFF_MAX);
                    t
                };
                inner.sys.trace_emit(|| crate::trace::Event::WorkerPark { cpu });
                let _ = inner.park.cv.wait_timeout(guard, timeout).unwrap();
                inner.sys.trace_emit(|| crate::trace::Event::WorkerUnpark { cpu });
            }
            // raced: re-pick immediately — the wake may be for work
            // invisible to sys.rq (gang's internal queue).
            inner.park.parked.fetch_sub(1, Ordering::SeqCst);
            continue;
        };
        backoff = BACKOFF_MIN;
        // Take exclusive ownership of the fiber while it runs.
        let mut fiber = {
            let mut fibers = inner.fibers.lock().unwrap();
            match fibers.remove(&task) {
                Some(f) => f,
                None => {
                    // A task without a fiber body (shouldn't happen):
                    // terminate it defensively.
                    inner.sched.stop(&inner.sys, cpu, task, StopReason::Terminate);
                    continue;
                }
            }
        };
        let seg_start = Instant::now();
        let action = fiber.resume();
        // Timeslice accounting, mirroring the simulator's segment_end:
        // charge the segment's wall nanoseconds to the scheduler after
        // every resume. A `true` return preempts a voluntary yield;
        // barrier/exit actions keep their own semantics (the fiber has
        // already passed its yield point), but the tick's side effects
        // (gang rotation, bubble regeneration) still happen.
        let elapsed = (seg_start.elapsed().as_nanos() as u64).max(1);
        let preempt = inner.sched.tick(&inner.sys, cpu, task, elapsed);
        match action {
            YieldAction::Yield => {
                inner.fibers.lock().unwrap().insert(task, fiber);
                let why = if preempt { StopReason::Preempt } else { StopReason::Yield };
                inner.sched.stop(&inner.sys, cpu, task, why);
            }
            YieldAction::Barrier(id) => {
                inner.fibers.lock().unwrap().insert(task, fiber);
                let released = {
                    let mut bars = inner.barriers.lock().unwrap();
                    let bar = &mut bars[id];
                    bar.arrived += 1;
                    if bar.arrived == bar.parties {
                        bar.arrived = 0;
                        Some(std::mem::take(&mut bar.waiting))
                    } else {
                        bar.waiting.push(task);
                        None
                    }
                };
                match released {
                    Some(waiters) => {
                        inner.sys.trace.emit(
                            inner.sys.now(),
                            crate::trace::Event::BarrierRelease {
                                id,
                                waiters: waiters.len() + 1,
                            },
                        );
                        // Last arriver yields; the blocked ones wake —
                        // as one batch, so the release notifies the
                        // park condvar once instead of per waiter.
                        inner.sched.stop(&inner.sys, cpu, task, StopReason::Yield);
                        inner.sys.wake_batch(|| {
                            for w in waiters {
                                inner.sched.wake(&inner.sys, w);
                            }
                        });
                    }
                    None => {
                        inner.sched.stop(&inner.sys, cpu, task, StopReason::Block);
                    }
                }
            }
            YieldAction::Exited => {
                drop(fiber);
                inner.sched.stop(&inner.sys, cpu, task, StopReason::Terminate);
                inner.live.fetch_sub(1, Ordering::SeqCst);
                // Unpark everyone so workers observe live==0 and exit
                // (enqueue-driven wakes do not cover termination). The
                // generation bump + locked notify guarantee a worker
                // about to sleep sees it.
                inner.park.wake_all();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::marcel::Marcel;
    use crate::sched::{BubbleConfig, BubbleScheduler};
    use crate::task::TaskState;
    use crate::topology::Topology;
    use std::sync::atomic::AtomicU64;

    fn executor(topo: Topology) -> Executor {
        let sys = Arc::new(System::new(Arc::new(topo)));
        let sched = Arc::new(BubbleScheduler::new(BubbleConfig::default()));
        Executor::new(sys, sched)
    }

    #[test]
    fn runs_loose_threads_to_completion() {
        let mut ex = executor(Topology::smp(4));
        let count = Arc::new(AtomicU64::new(0));
        for i in 0..16 {
            let c = count.clone();
            ex.spawn(format!("t{i}"), move |api| {
                for _ in 0..3 {
                    c.fetch_add(1, Ordering::SeqCst);
                    api.yield_now();
                }
            });
        }
        let rep = ex.run();
        assert_eq!(rep.threads, 16);
        assert_eq!(count.load(Ordering::SeqCst), 48);
    }

    #[test]
    fn native_barrier_synchronises() {
        let mut ex = executor(Topology::smp(4));
        let bar = ex.alloc_barrier(4);
        let phase = Arc::new(AtomicU64::new(0));
        let after = Arc::new(AtomicU64::new(0));
        for i in 0..4 {
            let (p, a) = (phase.clone(), after.clone());
            ex.spawn(format!("t{i}"), move |api| {
                p.fetch_add(1, Ordering::SeqCst);
                api.barrier(bar);
                // Everyone must have finished phase 1 by now.
                a.fetch_max(p.load(Ordering::SeqCst), Ordering::SeqCst);
            });
        }
        ex.run();
        assert_eq!(after.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn idle_workers_wake_on_late_enqueue() {
        // All workers go idle (nothing runnable), then a task is woken
        // from outside: the enqueue hook must unpark them promptly and
        // the run must complete.
        let sys = Arc::new(System::new(Arc::new(Topology::smp(2))));
        let sched = Arc::new(BubbleScheduler::new(BubbleConfig::default()));
        let mut ex = Executor::new(sys.clone(), sched.clone());
        let done = Arc::new(AtomicU64::new(0));
        let t = sys.tasks.new_thread("late", crate::task::PRIO_THREAD);
        let d = done.clone();
        ex.register(t, move |_| {
            d.fetch_add(1, Ordering::SeqCst);
        });
        let waker = {
            let sys = sys.clone();
            let sched = sched.clone();
            std::thread::spawn(move || {
                std::thread::sleep(std::time::Duration::from_millis(20));
                sched.wake(&sys, t);
            })
        };
        ex.run();
        waker.join().unwrap();
        assert_eq!(done.load(Ordering::SeqCst), 1);
        assert_eq!(sys.tasks.state(t), TaskState::Terminated);
    }

    #[test]
    fn bubble_structured_green_threads() {
        // Full stack: marcel bubbles + bubble scheduler + native
        // fibers on a NUMA topology.
        let sys = Arc::new(System::new(Arc::new(Topology::numa(2, 2))));
        let sched = Arc::new(BubbleScheduler::new(BubbleConfig::default()));
        let m = Marcel::over(sys.clone(), sched.clone());
        let mut ex = Executor::new(sys, sched);
        let done = Arc::new(AtomicU64::new(0));
        let b = m.bubble_init();
        for i in 0..4 {
            let t = m.create_dontsched(format!("w{i}"));
            m.bubble_inserttask(b, t);
            let d = done.clone();
            ex.register(t, move |api| {
                d.fetch_add(1, Ordering::SeqCst);
                api.yield_now();
                d.fetch_add(1, Ordering::SeqCst);
            });
        }
        m.wake_up_bubble(b);
        ex.run();
        assert_eq!(done.load(Ordering::SeqCst), 8);
        assert_eq!(ex.system().tasks.state(b), TaskState::Terminated);
    }

    #[test]
    fn green_threads_touch_regions_on_their_worker_cpu() {
        use crate::mem::AllocPolicy;
        let sys = Arc::new(System::new(Arc::new(Topology::numa(2, 2))));
        let sched = Arc::new(BubbleScheduler::new(BubbleConfig::default()));
        let mut ex = Executor::new(sys.clone(), sched);
        let r = sys.mem.alloc(4096, AllocPolicy::FirstTouch);
        let t = sys.tasks.new_thread("toucher", crate::task::PRIO_THREAD);
        sys.mem.attach(&sys.tasks, t, r);
        let homes = Arc::new(Mutex::new(Vec::new()));
        let h = homes.clone();
        ex.register(t, move |api| {
            let touch = api.touch_region(r);
            h.lock().unwrap().push((touch.home, api.cpu()));
            api.yield_now();
            let touch2 = api.touch_region(r);
            h.lock().unwrap().push((touch2.home, api.cpu()));
        });
        ex.wake(t);
        ex.run();
        let log = homes.lock().unwrap();
        assert_eq!(log.len(), 2);
        // First touch homed the region on the worker CPU's own node,
        // and the home stuck for the second touch.
        let (home0, cpu0) = log[0];
        assert_eq!(home0, sys.topo.numa_of(cpu0));
        assert_eq!(sys.mem.home(r), Some(home0));
        // Registry, metrics and footprint all saw the native touches.
        assert_eq!(sys.mem.regions.total_touches(), 2);
        let locals = sys.metrics.local_accesses.load(Ordering::SeqCst);
        let remotes = sys.metrics.remote_accesses.load(Ordering::SeqCst);
        assert_eq!(locals + remotes, 2);
        assert!(sys.mem.conserved(&sys.tasks));
        assert_eq!(sys.mem.dominant_node(t), Some(home0));
    }

    #[test]
    fn tick_preempts_voluntary_yields() {
        // Two loose threads under strict gang scheduling on one CPU:
        // only a timeslice tick (true return → StopReason::Preempt)
        // can interleave them before the first finishes, and the
        // preemption must be observable in the metrics.
        let sys = Arc::new(System::new(Arc::new(Topology::smp(1))));
        let sched = crate::sched::factory::make(&crate::config::SchedConfig {
            kind: crate::config::SchedKind::Gang,
            timeslice: Some(1), // every segment expires the slice
            ..Default::default()
        });
        let mut ex = Executor::new(sys.clone(), sched);
        let count = Arc::new(AtomicU64::new(0));
        for i in 0..2 {
            let c = count.clone();
            ex.spawn(format!("t{i}"), move |api| {
                for _ in 0..5 {
                    c.fetch_add(1, Ordering::SeqCst);
                    api.yield_now();
                }
            });
        }
        ex.run();
        assert_eq!(count.load(Ordering::SeqCst), 10);
        assert!(
            sys.metrics.preemptions.load(Ordering::SeqCst) > 0,
            "tick must deliver preemptions on the native engine"
        );
    }

    #[test]
    fn submitter_streams_work_into_a_running_executor() {
        // The executor starts with zero fibers; a separate OS thread
        // streams short green threads in through a Submitter while the
        // workers run. The latch keeps the run alive between
        // submissions, and dropping the handle lets it quiesce.
        let sys = Arc::new(System::new(Arc::new(Topology::smp(2))));
        let sched = Arc::new(BubbleScheduler::new(BubbleConfig::default()));
        let mut ex = Executor::new(sys.clone(), sched);
        let sub = ex.submitter();
        let done = Arc::new(AtomicU64::new(0));
        let feeder = {
            let d = done.clone();
            std::thread::spawn(move || {
                for i in 0..50 {
                    let t = sub
                        .system()
                        .tasks
                        .new_thread(format!("s{i}"), crate::task::PRIO_THREAD);
                    let d = d.clone();
                    sub.register(t, move |api| {
                        d.fetch_add(1, Ordering::SeqCst);
                        api.yield_now();
                    });
                    sub.wake(t);
                    if i % 16 == 0 {
                        std::thread::sleep(std::time::Duration::from_micros(200));
                    }
                }
                // sub drops here: latch released, executor may drain.
            })
        };
        let rep = ex.run();
        feeder.join().unwrap();
        assert_eq!(done.load(Ordering::SeqCst), 50);
        // Pre-registered count is zero; the streamed fibers all ran.
        assert_eq!(rep.threads, 0);
    }

    #[test]
    fn workers_pin_or_fall_back_when_an_os_map_exists() {
        // Both vCPUs map to OS CPU 0 (online on every machine): each
        // worker must report exactly one of pinned / pin-failed, even
        // where the sandbox denies affinity calls.
        let mut topo = Topology::smp(2);
        topo.set_os_cpus(vec![0, 0]);
        let sys = Arc::new(System::new(Arc::new(topo)));
        let sched = Arc::new(BubbleScheduler::new(BubbleConfig::default()));
        let mut ex = Executor::new(sys.clone(), sched);
        ex.spawn("t", |_| {});
        ex.run();
        let pinned = sys.metrics.workers_pinned.load(Ordering::SeqCst);
        let failed = sys.metrics.pin_failures.load(Ordering::SeqCst);
        assert_eq!(pinned + failed, 2, "every worker is pinned-or-fallback");
        // Bubble scheduling does not *require* binding: no bound alarm.
        assert_eq!(sys.metrics.bound_unpinned.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn bound_without_affinity_counts_unpinned_workers() {
        // A preset machine has no OS-CPU map, so bound's binding is
        // scheduler-level only — the executor must say so per worker
        // instead of silently degrading.
        let sys = Arc::new(System::new(Arc::new(Topology::smp(2))));
        let sched = Arc::new(crate::sched::baselines::BoundScheduler::new());
        let mut ex = Executor::new(sys.clone(), sched);
        for i in 0..2 {
            ex.spawn(format!("t{i}"), |_| {});
        }
        ex.run();
        assert_eq!(sys.metrics.bound_unpinned.load(Ordering::SeqCst), 2);
        assert_eq!(sys.metrics.workers_pinned.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn barrier_cycles_under_bubbles() {
        // Conduction-shaped native run: stripes + repeated barriers.
        let sys = Arc::new(System::new(Arc::new(Topology::numa(2, 2))));
        let sched = Arc::new(BubbleScheduler::new(BubbleConfig::default()));
        let m = Marcel::over(sys.clone(), sched.clone());
        let mut ex = Executor::new(sys, sched);
        let bar = ex.alloc_barrier(4);
        let sum = Arc::new(AtomicU64::new(0));
        let b = m.bubble_init();
        for i in 0..4 {
            let t = m.create_dontsched(format!("stripe{i}"));
            m.bubble_inserttask(b, t);
            let s = sum.clone();
            ex.register(t, move |api| {
                for _ in 0..5 {
                    s.fetch_add(1, Ordering::SeqCst);
                    api.barrier(bar);
                }
            });
        }
        m.wake_up_bubble(b);
        ex.run();
        assert_eq!(sum.load(Ordering::SeqCst), 20);
    }
}
