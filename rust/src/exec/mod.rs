//! Native two-level executor: MARCEL's architecture for real.
//!
//! One worker OS thread per virtual processor ("it binds one kernel
//! thread on each processor", §4), user-level [`fiber::Fiber`]s on
//! top, and the *same* [`Scheduler`] implementations that drive the
//! simulator deciding who runs where. Green threads block on a native
//! barrier; the compute payload can be anything, including PJRT
//! executions through [`crate::runtime::service::PjrtHandle`].
//!
//! **Native memory path**: a green thread records its data accesses
//! with [`GreenApi::touch_region`]; the touch is attributed to
//! the worker CPU the fiber is *currently* running on, so footprints,
//! next-touch migration and the local/remote access metrics are live
//! on real OS workers — not just in the simulator. Both engines share
//! [`crate::sched::System::touch_region`], which is what makes
//! `repro memcmp --engine native` comparable with the sim numbers and
//! lets the conformance suite enforce the same memory invariants on
//! either engine.

pub mod fiber;
mod worker;

pub use fiber::{fiber_yield, yield_now, Fiber, YieldAction};
pub use worker::{ExecReport, Executor, GreenApi};
