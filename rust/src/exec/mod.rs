//! Native two-level executor: MARCEL's architecture for real.
//!
//! One worker OS thread per virtual processor ("it binds one kernel
//! thread on each processor", §4), user-level [`fiber::Fiber`]s on
//! top, and the *same* [`Scheduler`] implementations that drive the
//! simulator deciding who runs where. Green threads block on a native
//! barrier; the compute payload can be anything, including PJRT
//! executions through [`crate::runtime::service::PjrtHandle`].

pub mod fiber;
mod worker;

pub use fiber::{fiber_yield, yield_now, Fiber, YieldAction};
pub use worker::{ExecReport, Executor, GreenApi};
