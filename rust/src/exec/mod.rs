//! Native two-level executor: MARCEL's architecture for real.
//!
//! One worker OS thread per virtual processor ("it binds one kernel
//! thread on each processor", §4), user-level [`fiber::Fiber`]s on
//! top, and the *same* [`Scheduler`] implementations that drive the
//! simulator deciding who runs where. Green threads block on a native
//! barrier; the compute payload can be anything, including PJRT
//! executions through [`crate::runtime::service::PjrtHandle`].
//!
//! **Native memory path**: a green thread records its data accesses
//! with [`GreenApi::touch_region`]; the touch is attributed to
//! the worker CPU the fiber is *currently* running on, so footprints,
//! next-touch migration and the local/remote access metrics are live
//! on real OS workers — not just in the simulator. Both engines share
//! [`crate::sched::System::touch_region`], which is what makes
//! `repro memcmp --engine native` comparable with the sim numbers and
//! lets the conformance suite enforce the same memory invariants on
//! either engine.
//!
//! **Native tick path**: every fiber resume is one scheduling segment;
//! the worker charges its wall nanoseconds to the policy through
//! [`crate::sched::Scheduler::tick`] and honours a `true` return with a
//! preempt-flavoured stop — so strict-gang rotation, moldable
//! timeslice rotation and bubble preventive regeneration run on real
//! OS workers exactly as on the simulator (`metrics.preemptions` is
//! observable on both engines; see `worker.rs` for the protocol).
//!
//! **Structure axis**: applications present themselves either as loose
//! green threads or as topology-mirroring bubbles — the apps' native
//! builders (`conduction`/`advection`/`amr` `build_native`) take the
//! same [`crate::apps::StructureMode`] as their simulator builders, so
//! `--engine native` reproduces the paper's structured-vs-flat
//! comparison.
//!
//! **Real-machine path** (`--machine detect`): when the topology was
//! discovered from `/sys` ([`crate::topology::Topology::detect`]), the
//! paper's "binds one kernel thread on each processor" becomes literal
//! — each worker pins itself to its vCPU's OS CPU via
//! `sched_setaffinity`, with a graceful per-worker fallback
//! (`metrics.pin_failures`) where affinity is denied, and a loud
//! one-time warning when a binding-*required* policy (`bound`) runs
//! without it. See the pinning protocol in `worker.rs`.

pub mod fiber;
mod worker;

pub use fiber::{fiber_yield, yield_now, Fiber, YieldAction};
pub use worker::{ExecReport, Executor, GreenApi, Submitter};
