//! User-level fibers with real stack switching (x86-64 System V).
//!
//! MARCEL is a two-level library: kernel threads bound to processors
//! perform "fast user-level context switches between user-level
//! threads" (§4). This module is that primitive: a hand-rolled
//! context switch saving the callee-saved registers and swapping
//! stacks — some 20 instructions, which is why Table 1's user-level
//! switch beats NPTL's kernel switch by an order of magnitude.
//!
//! Safety model: a fiber runs on exactly one OS thread at a time (the
//! scheduler's `Running{cpu}` state guarantees single ownership); the
//! `Send` impl lets a *suspended* fiber migrate between workers, which
//! is exactly a MARCEL thread migrating between processors.

use std::cell::Cell;

/// Action a fiber communicates to its runner when yielding.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum YieldAction {
    /// Voluntary yield; reschedule me.
    Yield,
    /// Block me on barrier `id` (runner handles arrival bookkeeping).
    Barrier(usize),
    /// The fiber's closure returned.
    Exited,
}

// Shared switch state between runner and fiber sides.
struct Shared {
    /// Saved stack pointer of the suspended fiber.
    fiber_sp: Cell<*mut u8>,
    /// Saved stack pointer of the runner while the fiber executes.
    runner_sp: Cell<*mut u8>,
    /// Action posted by the fiber at its last yield.
    action: Cell<YieldAction>,
    /// The fiber body; taken by the trampoline on first entry.
    body: Cell<Option<Box<dyn FnOnce()>>>,
}

thread_local! {
    /// The Shared of the fiber currently executing on this OS thread.
    static CURRENT: Cell<*const Shared> = const { Cell::new(std::ptr::null()) };
}

#[cfg(target_arch = "x86_64")]
mod arch {
    // bubbles_fiber_switch(save: *mut *mut u8 /*rdi*/, to: *mut u8 /*rsi*/)
    // Saves callee-saved registers + rsp into *save, installs `to`.
    std::arch::global_asm!(
        ".text",
        ".globl bubbles_fiber_switch",
        ".hidden bubbles_fiber_switch",
        ".type bubbles_fiber_switch, @function",
        "bubbles_fiber_switch:",
        "push rbp",
        "push rbx",
        "push r12",
        "push r13",
        "push r14",
        "push r15",
        "mov [rdi], rsp",
        "mov rsp, rsi",
        "pop r15",
        "pop r14",
        "pop r13",
        "pop r12",
        "pop rbx",
        "pop rbp",
        "ret",
        ".size bubbles_fiber_switch, . - bubbles_fiber_switch",
        // First-entry trampoline: the initial frame parks the Shared
        // pointer in the r12 slot; forward it as the argument.
        ".globl bubbles_fiber_entry",
        ".hidden bubbles_fiber_entry",
        ".type bubbles_fiber_entry, @function",
        "bubbles_fiber_entry:",
        "mov rdi, r12",
        "call bubbles_fiber_main",
        "ud2", // fiber main never returns
        ".size bubbles_fiber_entry, . - bubbles_fiber_entry",
    );

    extern "C" {
        pub fn bubbles_fiber_switch(save: *mut *mut u8, to: *mut u8);
        pub fn bubbles_fiber_entry();
    }
}

#[cfg(target_arch = "x86_64")]
use arch::{bubbles_fiber_entry, bubbles_fiber_switch};

/// Rust-side first-entry point (called by the asm trampoline).
///
/// The body runs under `catch_unwind`: a panicking green thread must
/// not unwind across the hand-rolled switch frame (UB) nor take the
/// whole worker down — it terminates like a normal exit and the panic
/// is reported on stderr (matching what a crashed MARCEL thread would
/// do to its processor).
#[no_mangle]
extern "C" fn bubbles_fiber_main(shared: *const Shared) -> ! {
    let sh = unsafe { &*shared };
    let body = sh.body.take().expect("fiber entered twice");
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(body));
    if let Err(payload) = result {
        let msg = payload
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_else(|| "<non-string panic>".into());
        eprintln!("green thread panicked (treated as exit): {msg}");
    }
    sh.action.set(YieldAction::Exited);
    // Switch back to the runner for the last time; the saved fiber_sp
    // is dead after this.
    unsafe {
        bubbles_fiber_switch(sh.fiber_sp.as_ptr(), sh.runner_sp.get());
    }
    unreachable!("resumed an exited fiber");
}

/// A suspended (or not-yet-started) green thread.
pub struct Fiber {
    shared: Box<Shared>,
    /// Owned stack (kept alive as long as the fiber).
    _stack: Box<[u8]>,
    exited: bool,
}

// A suspended fiber is inert data; single-ownership while running is
// enforced by the scheduler's state machine.
unsafe impl Send for Fiber {}

const STACK_SIZE: usize = 256 * 1024;

impl Fiber {
    /// Create a fiber running `body` when first resumed.
    pub fn new(body: impl FnOnce() + Send + 'static) -> Fiber {
        let mut stack = vec![0u8; STACK_SIZE].into_boxed_slice();
        let shared = Box::new(Shared {
            fiber_sp: Cell::new(std::ptr::null_mut()),
            runner_sp: Cell::new(std::ptr::null_mut()),
            action: Cell::new(YieldAction::Yield),
            body: Cell::new(Some(Box::new(body))),
        });
        // Build the initial frame: 6 callee-saved slots + return
        // address (= trampoline). Alignment: top is 16-aligned, sp =
        // top-56 ⇒ at trampoline entry rsp ≡ 8 (mod 16), matching the
        // post-`call` ABI state. See the module doc for the layout.
        unsafe {
            let top = stack.as_mut_ptr().add(STACK_SIZE);
            let top = top.sub(top as usize % 16); // align down
            let sp = top.sub(7 * 8) as *mut u64;
            // [sp+0..5] = r15,r14,r13,r12,rbx,rbp; [sp+6] = ret.
            for i in 0..6 {
                sp.add(i).write(0);
            }
            // r12 slot (index 3 popped 4th... order: pops r15,r14,r13,r12)
            // push order was rbp,rbx,r12,r13,r14,r15 → memory layout
            // low→high: r15,r14,r13,r12,rbx,rbp.
            sp.add(3).write(&*shared as *const Shared as u64); // r12
            sp.add(6).write(bubbles_fiber_entry as *const () as usize as u64); // ret
            shared.fiber_sp.set(sp as *mut u8);
        }
        Fiber { shared, _stack: stack, exited: false }
    }

    /// Resume the fiber on the current OS thread until it yields.
    /// Returns what it yielded with.
    pub fn resume(&mut self) -> YieldAction {
        assert!(!self.exited, "resumed an exited fiber");
        let sh: *const Shared = &*self.shared;
        let prev = CURRENT.with(|c| c.replace(sh));
        unsafe {
            bubbles_fiber_switch(
                self.shared.runner_sp.as_ptr(),
                self.shared.fiber_sp.get(),
            );
        }
        CURRENT.with(|c| c.set(prev));
        let action = self.shared.action.get();
        if action == YieldAction::Exited {
            self.exited = true;
        }
        action
    }

    /// Has the fiber's body returned?
    pub fn is_exited(&self) -> bool {
        self.exited
    }
}

/// Yield from inside a fiber with the given action. Must be called on
/// a fiber stack (panics otherwise).
pub fn fiber_yield(action: YieldAction) {
    let sh = CURRENT.with(|c| c.get());
    assert!(!sh.is_null(), "fiber_yield outside a fiber");
    let sh = unsafe { &*sh };
    sh.action.set(action);
    unsafe {
        bubbles_fiber_switch(sh.fiber_sp.as_ptr(), sh.runner_sp.get());
    }
}

/// Voluntary reschedule point (the Table-1 "Switch" operation).
pub fn yield_now() {
    fiber_yield(YieldAction::Yield);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn runs_to_completion() {
        let hits = Arc::new(AtomicUsize::new(0));
        let h = hits.clone();
        let mut f = Fiber::new(move || {
            h.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(f.resume(), YieldAction::Exited);
        assert!(f.is_exited());
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn yields_and_resumes_preserving_stack_state() {
        let log = Arc::new(std::sync::Mutex::new(Vec::new()));
        let l = log.clone();
        let mut f = Fiber::new(move || {
            let local = 41; // must survive across yields on the stack
            l.lock().unwrap().push(1);
            yield_now();
            l.lock().unwrap().push(local + 1);
            yield_now();
            l.lock().unwrap().push(local + 2);
        });
        assert_eq!(f.resume(), YieldAction::Yield);
        assert_eq!(f.resume(), YieldAction::Yield);
        assert_eq!(f.resume(), YieldAction::Exited);
        assert_eq!(*log.lock().unwrap(), vec![1, 42, 43]);
    }

    #[test]
    fn barrier_action_round_trip() {
        let mut f = Fiber::new(|| {
            fiber_yield(YieldAction::Barrier(7));
        });
        assert_eq!(f.resume(), YieldAction::Barrier(7));
        assert_eq!(f.resume(), YieldAction::Exited);
    }

    #[test]
    fn interleaves_two_fibers() {
        let order = Arc::new(std::sync::Mutex::new(Vec::new()));
        let (o1, o2) = (order.clone(), order.clone());
        let mut a = Fiber::new(move || {
            o1.lock().unwrap().push("a1");
            yield_now();
            o1.lock().unwrap().push("a2");
        });
        let mut b = Fiber::new(move || {
            o2.lock().unwrap().push("b1");
            yield_now();
            o2.lock().unwrap().push("b2");
        });
        a.resume();
        b.resume();
        a.resume();
        b.resume();
        assert_eq!(*order.lock().unwrap(), vec!["a1", "b1", "a2", "b2"]);
    }

    #[test]
    fn suspended_fiber_migrates_between_threads() {
        // A fiber yielded on one worker may be resumed on another —
        // that is a MARCEL thread migrating between processors.
        let mut f = Fiber::new(|| {
            let x = 7;
            yield_now();
            assert_eq!(x, 7);
        });
        assert_eq!(f.resume(), YieldAction::Yield);
        let handle = std::thread::spawn(move || {
            assert_eq!(f.resume(), YieldAction::Exited);
        });
        handle.join().unwrap();
    }

    #[test]
    fn panicking_fiber_exits_cleanly() {
        let mut f = Fiber::new(|| {
            panic!("boom");
        });
        // The panic must be contained: resume returns Exited, the
        // process (and this test) survives.
        assert_eq!(f.resume(), YieldAction::Exited);
        assert!(f.is_exited());
        // And the runner thread still works fine afterwards.
        let mut g = Fiber::new(|| {});
        assert_eq!(g.resume(), YieldAction::Exited);
    }

    #[test]
    fn deep_recursion_fits_stack() {
        fn rec(n: usize) -> usize {
            if n == 0 {
                0
            } else {
                std::hint::black_box(rec(n - 1) + 1)
            }
        }
        let mut f = Fiber::new(|| {
            assert_eq!(rec(1000), 1000);
        });
        assert_eq!(f.resume(), YieldAction::Exited);
    }
}
