//! Library error type.

use thiserror::Error;

/// Unified error for the bubbles library.
#[derive(Error, Debug)]
pub enum Error {
    /// Configuration file / value errors (config parser, schema).
    #[error("config error: {0}")]
    Config(String),

    /// Topology construction errors (empty machine, bad arity, ...).
    #[error("topology error: {0}")]
    Topology(String),

    /// Scheduler state violations (task not found, bad transition, ...).
    #[error("scheduler error: {0}")]
    Sched(String),

    /// Simulation engine errors.
    #[error("simulation error: {0}")]
    Sim(String),

    /// PJRT runtime / artifact errors.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// XLA crate errors (compile/execute).
    #[error("xla error: {0}")]
    Xla(String),

    /// I/O errors (artifact files, traces).
    #[error(transparent)]
    Io(#[from] std::io::Error),

    /// A command finished with a report that must reach stdout and a
    /// specific process exit code (sweep failures exit 1, sweep-diff
    /// regressions exit 2 — the per-job / gate exit-code contract).
    #[error("{report}")]
    Exit { code: i32, report: String },
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Library result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Shorthand constructor for config errors.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    /// Shorthand constructor for scheduler errors.
    pub fn sched(msg: impl Into<String>) -> Self {
        Error::Sched(msg.into())
    }
}
