//! Command-line launcher (`repro <command>`): regenerates every paper
//! table and figure, renders topologies, and runs config-driven
//! experiments. Arg parsing is hand-rolled (clap is not vendored).

use std::collections::HashMap;

use crate::apps::amr::AmrParams;
use crate::apps::conduction::HeatParams;
use crate::apps::fib::FibParams;
use crate::config::ExperimentConfig;
use crate::error::{Error, Result};
use crate::experiments::{fig5, harness, sweep, table1, table2};
use crate::topology::Topology;

/// Parsed command line: positional command + `--key value` options,
/// plus bare operands for the commands that take them (`sweep diff`).
#[derive(Debug, Default)]
pub struct Args {
    pub command: String,
    pub options: HashMap<String, String>,
    pub positionals: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`. A `--key` consumes the next argument as its
    /// value; the known boolean flags may stand bare
    /// (`repro adaptcmp --smoke`) and default to `"true"`. Any other
    /// `--key` without a value is still an error, so a forgotten value
    /// (`--config` with no path) fails loudly instead of becoming the
    /// literal value `true`. Bare arguments are operands only for the
    /// commands that declare them; everywhere else they stay errors.
    pub fn parse(argv: &[String]) -> Result<Args> {
        const BOOL_FLAGS: &[&str] = &["smoke", "arena", "continue-on-failure"];
        const POSITIONAL_COMMANDS: &[&str] = &["sweep"];
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        args.command = it.next().cloned().unwrap_or_else(|| "help".to_string());
        while let Some(a) = it.next() {
            if a == "-j" {
                // `-j N` is the conventional spelling of `--j N`.
                let val = it
                    .next()
                    .cloned()
                    .ok_or_else(|| Error::config("-j needs a value".to_string()))?;
                args.options.insert("j".to_string(), val);
            } else if let Some(key) = a.strip_prefix("--") {
                let next_is_value = it.peek().map(|v| !v.starts_with("--")).unwrap_or(false);
                let val = if next_is_value {
                    it.next().cloned().unwrap()
                } else if BOOL_FLAGS.contains(&key) {
                    "true".to_string()
                } else {
                    return Err(Error::config(format!("--{key} needs a value")));
                };
                args.options.insert(key.to_string(), val);
            } else if POSITIONAL_COMMANDS.contains(&args.command.as_str()) {
                args.positionals.push(a.clone());
            } else {
                return Err(Error::config(format!("unexpected argument `{a}`")));
            }
        }
        Ok(args)
    }

    /// Option accessor with default.
    pub fn get<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.options.get(key).map(|s| s.as_str()).unwrap_or(default)
    }

    /// Boolean flag: present and not explicitly disabled.
    pub fn flag(&self, key: &str) -> bool {
        self.options.get(key).map(|v| v != "false" && v != "0").unwrap_or(false)
    }

    fn machine(&self) -> Result<Topology> {
        let name = self.get("machine", "numa-4x4");
        Topology::preset(name)
            .ok_or_else(|| Error::config(format!("unknown machine `{name}`; presets: {:?}", Topology::preset_names())))
    }

    fn f64(&self, key: &str, default: f64) -> f64 {
        self.options.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    fn u64(&self, key: &str, default: u64) -> u64 {
        self.options.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
}

/// Top-level dispatch. Returns the text to print.
pub fn run(argv: &[String]) -> Result<String> {
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "help" | "--help" | "-h" => Ok(HELP.to_string()),
        "topology" => cmd_topology(&args),
        "table1" => cmd_table1(&args),
        "table2" => cmd_table2(&args),
        "fig5" => cmd_fig5(&args),
        "ablations" => run_experiment("ablations", &args),
        "memcmp" => run_experiment("memcmp", &args),
        "adaptcmp" => run_experiment("adaptcmp", &args),
        "serve" => run_experiment("serve", &args),
        "sweep" => cmd_sweep(&args),
        "submit" => cmd_submit(&args),
        "run" => cmd_run(&args),
        "analyze" => cmd_analyze(&args),
        "trace" => cmd_trace(&args),
        "evolve" => cmd_evolve(&args),
        "schedulers" => Ok(crate::sched::factory::render_list()),
        other => Err(Error::config(format!("unknown command `{other}`; try `repro help`"))),
    }
}

const HELP: &str = "\
repro — reproduction of 'A Flexible Thread Scheduler for Hierarchical
Multiprocessor Machines' (Thibault, 2005)

USAGE: repro <command> [--key value ...]

COMMANDS
  topology   render a machine tree (Figure 2)    [--machine numa-4x4,
             --json out.json (machine-shape artifact: cpus, NUMA nodes,
             OS-CPU map and SLIT matrix when detected)]
  table1     scheduler micro-costs (Table 1)
  table2     conduction+advection rows (Table 2) [--machine, --scale 1.0]
  fig5       fibonacci bubble gain (Figure 5)    [--machine xeon-2x-ht|numa-4x4]
  ablations  design-choice sweeps     [--workload burst|regen|zoo|memory|all]
  memcmp     local vs remote access ratio per policy [--machine, --scheds a,b,c,
             --engine sim|native, --structure simple|bubbles|both (native),
             --arena (native: back regions with real mmap pages),
             --seed N (sim), --smoke, --trace out.json]
             (--engine native runs real green threads — loose or grouped into
             one bubble per NUMA node — and writes BENCH_mem_native.json;
             --trace exports the first leg as Chrome trace-event JSON)
  adaptcmp   adaptive steal-scope vs fixed scopes on bursty/phase-change load
             [--machine, --scheds a,b,c, --workload phase|bursty|both,
             --seed N, --smoke, --trace out.json]
             (writes BENCH_adaptive.json; --trace exports the first
             phase-changing leg as Chrome trace-event JSON)
  serve      multi-tenant job server: seeded bursty stream of short jobs
             multiplexed over one executor, job-fair vs static-partition
             vs ss [--machine, --jobs N, --seed N, --engine sim|native|both,
             --workload touch|conduction|amr|mix (generated stream),
             --submitters N (native), --queue spool-file, --gap N (queue),
             --smoke (>=1000 jobs), --trace out.json]
             (writes BENCH_serve.json; --trace exports the first leg's
             mix run as Chrome trace-event JSON)
  sweep      provenance-tracked experiment grids  [--grid spec.toml, -j N,
             --continue-on-failure, --out results]
             expands [grid] axes (policy/machine/workload/seed/...) into
             cells, runs each as a subprocess, writes content-addressed
             artifacts + a manifest under results/<cfg-hash>/; exit 1
             when any cell failed. `sweep diff <a> <b>` gates two runs
             (or plain BENCH_*.json artifacts) through the bench
             comparator — exit 2 on a >=1.25x regression
  submit     append one job to a spool file for `serve --queue`
             [--queue file (required), --name, --mode simple|bound|bubbles,
             --class latency|normal|batch, --app touch|conduction|amr,
             --threads, --cycles, --work, --mem 0..1, --touches]
  run        config-driven simulation            [--config file.toml]
  analyze    traced run + scheduler analysis     [--machine, --app, --sched,
             --engine sim|native]
  trace      traced run exported as Chrome trace-event JSON for
             chrome://tracing / ui.perfetto.dev  [--machine, --sched,
             --engine sim|native, --smoke, --out trace.json]
  evolve     traced bubble evolution (Figure 3)  [--machine numa-4x4]
  schedulers list registered scheduling policies (also: --sched list)
  help       this text

MACHINES: xeon-2x-ht, numa-4x4 (novascale), deep, asym, smp-<n>, numa-<a>x<b>,
          detect (discover this machine from /sys: online CPUs, packages,
          cores, NUMA nodes and SLIT distances; native workers then pin to
          the detected OS CPUs. Falls back to smp-N when /sys is absent.)
SCHEDULERS: see `repro schedulers`
";

fn cmd_topology(args: &Args) -> Result<String> {
    let t = args.machine()?;
    let note = match args.options.get("json") {
        Some(path) => format!("\n{}", write_bench_artifact(path, &topology_json(&t))),
        None => String::new(),
    };
    Ok(format!(
        "machine `{}`: {} CPUs, {} NUMA nodes, {} lists, depth {}\n\n{}{}",
        t.name(),
        t.n_cpus(),
        t.n_numa(),
        t.n_components(),
        t.depth(),
        t.render(),
        note
    ))
}

/// Machine-shape JSON for the CI artifact trail (`topology --json`):
/// the shape counts plus — when the machine carries them, i.e. it was
/// discovered from `/sys` — the vCPU→OS-CPU map and the normalized
/// SLIT distance matrix.
fn topology_json(t: &Topology) -> String {
    let mut s = format!(
        "{{\n  \"machine\": \"{}\",\n  \"cpus\": {},\n  \"numa_nodes\": {},\n  \"components\": {},\n  \"depth\": {},\n  \"pinnable\": {}",
        t.name(),
        t.n_cpus(),
        t.n_numa(),
        t.n_components(),
        t.depth(),
        t.os_cpus().is_some()
    );
    if let Some(map) = t.os_cpus() {
        let list: Vec<String> = map.iter().map(|c| c.to_string()).collect();
        s.push_str(&format!(",\n  \"os_cpus\": [{}]", list.join(",")));
    }
    if let Some(m) = t.numa_matrix() {
        let rows: Vec<String> = m
            .iter()
            .map(|r| {
                let cols: Vec<String> = r.iter().map(|f| format!("{f:.3}")).collect();
                format!("[{}]", cols.join(","))
            })
            .collect();
        s.push_str(&format!(",\n  \"numa_matrix\": [{}]", rows.join(",")));
    }
    s.push_str("\n}\n");
    s
}

fn cmd_table1(_args: &Args) -> Result<String> {
    let user_switch = table1::fiber_switch_ns();
    let os_switch = table1::os_switch_ns();
    let t = table1::run(user_switch, os_switch);
    Ok(format!(
        "Table 1 — scheduler micro-costs on this testbed\n\
         (paper, 2.66 GHz Xeon: marcel 186/84 ns, bubbles 250/148 ns, NPTL 672/1488 ns)\n\n{}",
        t.render()
    ))
}

fn cmd_table2(args: &Args) -> Result<String> {
    let topo = args.machine()?;
    let scale = args.f64("scale", 1.0);
    let t2 = table2::run(&topo, scale);
    Ok(format!(
        "Table 2 — conduction & advection on `{}` (scale {scale})\n\
         (paper: Simple 10.58/9.11, Bound 15.82/12.40, Bubbles 15.80/12.40)\n\n{}",
        topo.name(),
        t2.render()
    ))
}

fn cmd_fig5(args: &Args) -> Result<String> {
    let topo = args.machine()?;
    let counts: Vec<usize> = match args.options.get("threads") {
        Some(list) => list
            .split(',')
            .map(|s| s.trim().parse().map_err(|_| Error::config(format!("bad thread count `{s}`"))))
            .collect::<Result<_>>()?,
        None => fig5::default_thread_counts(),
    };
    let series = fig5::run(&topo, &counts, &FibParams::default());
    Ok(format!(
        "Figure 5 — fibonacci gain from bubbles\n\
         (paper: (a) HT Xeon 30-40% from 16 threads; (b) NUMA 40% @32 → 80% @512)\n\n{}",
        series.render()
    ))
}

/// Run a harness experiment from the parsed CLI options: the
/// memcmp/adaptcmp/serve/ablations commands are thin wrappers over the
/// shared [`harness::Experiment`] registry, so the CLI and the sweep
/// runner execute the exact same code path. Writes the experiment's
/// default artifact (when it produced one) and appends the note.
fn run_experiment(name: &str, args: &Args) -> Result<String> {
    let exp = harness::lookup(name).expect("registered experiment");
    let out = exp.run(&harness::Params::from_options(&args.options))?;
    match out.artifact {
        Some(a) => {
            let note = write_bench_artifact(&a.path, &a.artifact.json());
            Ok(format!("{}\n{note}", out.text))
        }
        None => Ok(out.text),
    }
}

fn cmd_sweep(args: &Args) -> Result<String> {
    // Cell mode: one grid cell in this process — the runner's per-job
    // subprocess entry point.
    if let Some(spec) = args.options.get("cell") {
        return sweep::run_cell(spec, args.options.get("cell-out").map(|s| s.as_str()));
    }
    match args.positionals.first().map(|s| s.as_str()) {
        Some("diff") => {
            let (a, b) = match (args.positionals.get(1), args.positionals.get(2)) {
                (Some(a), Some(b)) => (a.clone(), b.clone()),
                (Some(b), None) => {
                    let a = std::env::var("BENCH_BASELINE").map_err(|_| {
                        Error::config(
                            "sweep diff needs two runs (or BENCH_BASELINE=<run> and one)"
                                .to_string(),
                        )
                    })?;
                    (a, b.clone())
                }
                _ => {
                    return Err(Error::config(
                        "usage: repro sweep diff <baseline> <current>".to_string(),
                    ))
                }
            };
            sweep::diff(&a, &b)
        }
        Some(other) => Err(Error::config(format!(
            "unknown sweep subcommand `{other}` (want diff, or --grid <spec.toml>)"
        ))),
        None => {
            let grid_path = args.options.get("grid").ok_or_else(|| {
                Error::config("sweep needs --grid <spec.toml> (or `sweep diff <a> <b>`)")
            })?;
            let grid = crate::config::GridSpec::from_file(grid_path)?;
            let opts = sweep::SweepOptions {
                workers: args.u64("j", 4).max(1) as usize,
                continue_on_failure: args.flag("continue-on-failure"),
                out_dir: args.get("out", "results").to_string(),
                repro_bin: None,
            };
            sweep::run_sweep(&grid, &opts)
        }
    }
}

/// Write a `BENCH_*.json` artifact; returns the note line for the
/// command output (shared by the memcmp/adaptcmp harness commands).
fn write_bench_artifact(path: &str, json: &str) -> String {
    match std::fs::write(path, json) {
        Ok(()) => format!("wrote {path}"),
        Err(e) => format!("could not write {path}: {e}"),
    }
}

fn cmd_submit(args: &Args) -> Result<String> {
    let queue = args
        .options
        .get("queue")
        .ok_or_else(|| Error::config("--queue <spool-file> is required".to_string()))?;
    let mut spec = crate::serve::JobSpec::small(0);
    spec.name = args.get("name", "job").to_string();
    if let Some(m) = args.options.get("mode") {
        spec.mode = crate::serve::parse_mode(m).ok_or_else(|| {
            Error::config(format!("unknown mode `{m}` (want simple|bound|bubbles)"))
        })?;
    }
    if let Some(c) = args.options.get("class") {
        spec.class = crate::sched::DeadlineClass::parse(c).ok_or_else(|| {
            Error::config(format!("unknown class `{c}` (want latency|normal|batch)"))
        })?;
    }
    if let Some(a) = args.options.get("app") {
        spec.app = crate::serve::JobApp::parse(a).ok_or_else(|| {
            Error::config(format!("unknown app `{a}` (want touch|conduction|amr)"))
        })?;
    }
    spec.threads = args.u64("threads", spec.threads as u64) as usize;
    spec.cycles = args.u64("cycles", spec.cycles as u64) as usize;
    spec.work = args.u64("work", spec.work);
    spec.mem_fraction = args.f64("mem", spec.mem_fraction).clamp(0.0, 1.0);
    spec.touches = args.u64("touches", spec.touches as u64) as usize;
    if spec.threads == 0 {
        return Err(Error::config("--threads must be >= 1".to_string()));
    }
    crate::serve::append_spool(queue, &spec)?;
    Ok(format!(
        "queued `{}` ({} threads, class {}, {}) to {queue}\n",
        spec.name,
        spec.threads,
        spec.class.label(),
        spec.mode.label()
    ))
}

fn cmd_run(args: &Args) -> Result<String> {
    let cfg = match args.options.get("config") {
        Some(path) => ExperimentConfig::from_file(path)?,
        None => ExperimentConfig::default(),
    };
    let topo = cfg.machine.build_topology()?;
    let sched = crate::sched::factory::make(&cfg.sched);
    let mut engine = crate::apps::engine_with_model(
        &topo,
        sched,
        crate::sim::SimConfig::default(),
        // Resolved against the built machine: a detected topology's
        // SLIT matrix prices remote access unless the config gave an
        // explicit one.
        cfg.machine.distance_model_for(&topo),
    );
    let w = &cfg.workload;
    match w.app.as_str() {
        "conduction" | "advection" => {
            let p = HeatParams {
                threads: w.threads,
                cycles: w.cycles,
                work: w.work,
                mem_fraction: w.mem_fraction,
            };
            // Structure follows the scheduler choice: bubbles for the
            // bubble scheduler, loose threads otherwise.
            let mode = if cfg.sched.kind == crate::config::SchedKind::Bubble {
                crate::apps::StructureMode::Bubbles
            } else {
                crate::apps::StructureMode::Simple
            };
            crate::apps::conduction::build(&mut engine, mode, &p);
        }
        "fib" => {
            let p = FibParams {
                depth: FibParams::depth_for_threads(w.threads),
                ..FibParams::default()
            };
            crate::apps::fib::build(
                &mut engine,
                cfg.sched.kind == crate::config::SchedKind::Bubble,
                &p,
            );
        }
        "amr" => {
            let p = AmrParams {
                threads: w.threads,
                cycles: w.cycles,
                seed: w.seed,
                mem_fraction: w.mem_fraction,
                ..Default::default()
            };
            let mode = if cfg.sched.kind == crate::config::SchedKind::Bubble {
                crate::apps::StructureMode::Bubbles
            } else {
                crate::apps::StructureMode::Simple
            };
            crate::apps::amr::build(&mut engine, mode, &p);
        }
        other => return Err(Error::config(format!("unknown app `{other}`"))),
    }
    let report = engine.run()?;
    Ok(format!(
        "app `{}` under `{}` on `{}`\nmakespan: {} cycles  utilisation: {:.3}\n\n{}",
        w.app,
        report.sched,
        topo.name(),
        crate::util::fmt::cycles(report.total_time),
        report.utilisation(),
        engine.sys.metrics.report()
    ))
}

fn cmd_analyze(args: &Args) -> Result<String> {
    // Traced run + the §6 analysis tools, on either engine.
    let topo = args.machine()?;
    let sched_name = args.get("sched", "bubble");
    if sched_name == "list" || sched_name == "help" {
        // `--sched list` enumerates the registry instead of running.
        return Ok(crate::sched::factory::render_list());
    }
    let kind = crate::config::SchedKind::parse(sched_name).ok_or_else(|| {
        Error::config(format!(
            "unknown scheduler `{sched_name}`; try `repro schedulers`"
        ))
    })?;
    let sched = crate::sched::factory::make(&crate::config::SchedConfig {
        kind,
        ..Default::default()
    });
    let mode = if kind == crate::config::SchedKind::Bubble {
        crate::apps::StructureMode::Bubbles
    } else {
        crate::apps::StructureMode::Simple
    };
    let p = HeatParams {
        threads: topo.n_cpus(),
        cycles: 10,
        ..HeatParams::conduction()
    };
    match args.get("engine", "sim") {
        "sim" => {
            let mut e = crate::apps::engine_with(&topo, sched, crate::sim::SimConfig::default());
            e.sys.trace.set_enabled(true);
            match args.get("app", "conduction") {
                "conduction" => {
                    crate::apps::conduction::build(&mut e, mode, &p);
                }
                "amr" => {
                    crate::apps::amr::build(&mut e, mode, &AmrParams::default());
                }
                other => return Err(Error::config(format!("unknown app `{other}`"))),
            }
            let rep = e.run()?;
            let analysis = crate::trace::analysis::analyse(&e.sys.trace.records());
            Ok(format!(
                "traced `{}` under `{}` on `{}`: makespan {} cycles\n\n{}",
                args.get("app", "conduction"),
                sched_name,
                topo.name(),
                crate::util::fmt::cycles(rep.total_time),
                analysis.render(&topo)
            ))
        }
        "native" => {
            use std::sync::Arc;
            if args.get("app", "conduction") != "conduction" {
                return Err(Error::config(
                    "--engine native analyzes the conduction workload only".to_string(),
                ));
            }
            let sys = Arc::new(crate::sched::System::new(Arc::new(topo.clone())));
            sys.trace.set_enabled(true);
            let mut ex = crate::exec::Executor::new(sys.clone(), sched);
            crate::apps::conduction::build_native(
                &mut ex,
                mode,
                &p,
                crate::mem::AllocPolicy::FirstTouch,
                2,
            );
            let rep = ex.run();
            let analysis = crate::trace::analysis::analyse(&sys.trace.records());
            Ok(format!(
                "traced `conduction` under `{}` on `{}` (native engine): {:.2} ms wall\n\n{}",
                sched_name,
                topo.name(),
                rep.elapsed.as_secs_f64() * 1e3,
                analysis.render(&topo)
            ))
        }
        other => Err(Error::config(format!("unknown engine `{other}` (want sim|native)"))),
    }
}

fn cmd_trace(args: &Args) -> Result<String> {
    // Traced conduction run exported as Chrome trace-event JSON: one
    // timeline row per CPU with Dispatch→Stop spans and instants for
    // the scheduler's structural events. Open the artifact in
    // chrome://tracing or ui.perfetto.dev.
    let topo = args.machine()?;
    let sched_name = args.get("sched", "bubble");
    if sched_name == "list" || sched_name == "help" {
        return Ok(crate::sched::factory::render_list());
    }
    let kind = crate::config::SchedKind::parse(sched_name).ok_or_else(|| {
        Error::config(format!(
            "unknown scheduler `{sched_name}`; try `repro schedulers`"
        ))
    })?;
    let sched = crate::sched::factory::make(&crate::config::SchedConfig {
        kind,
        ..Default::default()
    });
    let mode = if kind == crate::config::SchedKind::Bubble {
        crate::apps::StructureMode::Bubbles
    } else {
        crate::apps::StructureMode::Simple
    };
    let p = HeatParams {
        threads: topo.n_cpus(),
        cycles: if args.flag("smoke") { 3 } else { 10 },
        ..HeatParams::conduction()
    };
    let out_path = args.get("out", "trace.json");
    let engine = args.get("engine", "sim");
    let (recs, dropped, headline) = match engine {
        "sim" => {
            let mut e = crate::apps::engine_with(&topo, sched, crate::sim::SimConfig::default());
            e.sys.trace.set_enabled(true);
            crate::apps::conduction::build(&mut e, mode, &p);
            let rep = e.run()?;
            let recs = e.sys.trace.drain();
            let dropped = e.sys.trace.dropped();
            let headline =
                format!("makespan {} cycles", crate::util::fmt::cycles(rep.total_time));
            (recs, dropped, headline)
        }
        "native" => {
            use std::sync::Arc;
            let sys = Arc::new(crate::sched::System::new(Arc::new(topo.clone())));
            sys.trace.set_enabled(true);
            let mut ex = crate::exec::Executor::new(sys.clone(), sched);
            crate::apps::conduction::build_native(
                &mut ex,
                mode,
                &p,
                crate::mem::AllocPolicy::FirstTouch,
                2,
            );
            let rep = ex.run();
            let recs = sys.trace.drain();
            let dropped = sys.trace.dropped();
            (recs, dropped, format!("{:.2} ms wall", rep.elapsed.as_secs_f64() * 1e3))
        }
        other => {
            return Err(Error::config(format!("unknown engine `{other}` (want sim|native)")))
        }
    };
    let label = format!("conduction/{sched_name} on {} ({engine})", topo.name());
    let json = crate::trace::export::chrome_json(&recs, topo.n_cpus(), &label);
    let note = write_bench_artifact(out_path, &json);
    Ok(format!(
        "traced conduction under `{sched_name}` on `{}` ({engine} engine): {headline}\n\
         {} events captured ({} dropped)\n\
         {note} — open in chrome://tracing or ui.perfetto.dev\n",
        topo.name(),
        recs.len(),
        dropped
    ))
}

fn cmd_evolve(args: &Args) -> Result<String> {
    // Figure 3 narrated: build a two-level bubble hierarchy, pick from
    // CPU 0, dump the trace.
    use crate::marcel::Marcel;
    use crate::sched::Scheduler;
    let topo = args.machine()?;
    let m = Marcel::new(topo);
    let sys = m.system().clone();
    sys.trace.set_enabled(true);
    let root = m.bubble_init();
    for g in 0..2 {
        let b = m.bubble_init();
        for k in 0..2 {
            let t = m.create_dontsched(format!("g{g}t{k}"));
            m.bubble_inserttask(b, t);
        }
        m.bubble_insertbubble(root, b);
    }
    m.wake_up_bubble(root);
    let sched = m.scheduler().clone();
    let mut picked = Vec::new();
    for c in 0..sys.topo.n_cpus() {
        if let Some(t) = sched.pick(&sys, crate::topology::CpuId(c)) {
            picked.push((c, sys.tasks.name(t)));
        }
    }
    Ok(format!(
        "Figure 3 — bubble evolution trace on `{}`\n\n{}\npicked: {:?}\n",
        sys.topo.name(),
        sys.trace.dump(),
        picked
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_options() {
        let a = Args::parse(&argv("fig5 --machine deep --threads 2,4")).unwrap();
        assert_eq!(a.command, "fig5");
        assert_eq!(a.get("machine", "x"), "deep");
        assert!(Args::parse(&argv("x stray")).is_err());
        // Value-taking options still fail loudly without a value.
        assert!(Args::parse(&argv("x --flag")).is_err());
        assert!(Args::parse(&argv("run --config")).is_err());
        // Known boolean flags may stand bare, before another option or
        // at the end.
        let f = Args::parse(&argv("adaptcmp --smoke --machine deep")).unwrap();
        assert!(f.flag("smoke"));
        assert_eq!(f.get("machine", "x"), "deep");
        let g = Args::parse(&argv("adaptcmp --smoke")).unwrap();
        assert!(g.flag("smoke"));
        assert!(!g.flag("json"));
        let h = Args::parse(&argv("adaptcmp --smoke false")).unwrap();
        assert!(!h.flag("smoke"));
    }

    #[test]
    fn help_and_errors() {
        assert!(run(&argv("help")).unwrap().contains("table2"));
        assert!(run(&argv("nope")).is_err());
        assert!(run(&argv("topology --machine warp")).is_err());
    }

    #[test]
    fn schedulers_command_lists_registry() {
        let out = run(&argv("schedulers")).unwrap();
        assert!(out.contains("bubble"), "{out}");
        assert!(out.contains("gang"), "{out}");
        // `--sched list` is the in-command spelling of the same thing.
        let out2 = run(&argv("analyze --sched list")).unwrap();
        assert_eq!(out, out2);
        // Unknown schedulers point at the listing.
        let err = run(&argv("analyze --sched warp")).unwrap_err();
        assert!(err.to_string().contains("repro schedulers"), "{err}");
    }

    #[test]
    fn topology_command() {
        let out = run(&argv("topology --machine deep")).unwrap();
        assert!(out.contains("16 CPUs"));
        assert!(out.contains("Smt"));
    }

    #[test]
    fn malformed_machine_specs_error_and_list_presets() {
        // Zero-sized and garbage custom specs are rejected loudly, and
        // the error points at the preset list instead of silently
        // building a degenerate machine.
        for bad in ["smp-0", "numa-0x4", "numa-4x0", "numa-2x2x2", "smp-two"] {
            let err = run(&argv(&format!("topology --machine {bad}"))).unwrap_err();
            assert!(err.to_string().contains("presets"), "{bad}: {err}");
            assert!(err.to_string().contains("detect"), "{bad}: {err}");
        }
    }

    #[test]
    fn topology_json_writes_machine_shape_artifact() {
        let path = std::env::temp_dir().join("bubbles-cli-topology.json");
        let cmd = format!("topology --machine numa-2x2 --json {}", path.display());
        let out = run(&argv(&cmd)).unwrap();
        assert!(out.contains("wrote"), "{out}");
        let s = std::fs::read_to_string(&path).unwrap();
        crate::util::json::validate(&s).unwrap_or_else(|e| panic!("invalid JSON: {e}"));
        assert!(s.contains("\"cpus\": 4"), "{s}");
        assert!(s.contains("\"numa_nodes\": 2"), "{s}");
        // Preset machines carry no OS map.
        assert!(s.contains("\"pinnable\": false"), "{s}");
        // The detected machine always carries one (identity map when
        // `/sys` was absent and detection fell back to smp-N).
        let cmd = format!("topology --machine detect --json {}", path.display());
        run(&argv(&cmd)).unwrap();
        let s = std::fs::read_to_string(&path).unwrap();
        crate::util::json::validate(&s).unwrap_or_else(|e| panic!("invalid JSON: {e}"));
        assert!(s.contains("\"pinnable\": true"), "{s}");
        assert!(s.contains("\"os_cpus\""), "{s}");
    }

    #[test]
    fn evolve_traces_burst() {
        let out = run(&argv("evolve --machine numa-2x2")).unwrap();
        assert!(out.contains("Burst"), "{out}");
        assert!(out.contains("picked"));
    }

    #[test]
    fn memcmp_command_reports_ratios() {
        let out = run(&argv("memcmp --machine numa-2x2 --scheds memaware,afs --smoke")).unwrap();
        assert!(out.contains("memaware"), "{out}");
        assert!(out.contains("afs"), "{out}");
        assert!(out.contains("local ratio"), "{out}");
        assert!(out.contains("seed"), "{out}");
        let err = run(&argv("memcmp --machine numa-2x2 --scheds warp")).unwrap_err();
        assert!(err.to_string().contains("unknown scheduler"), "{err}");
        let err = run(&argv("memcmp --machine numa-2x2 --engine warp")).unwrap_err();
        assert!(err.to_string().contains("unknown engine"), "{err}");
    }

    #[test]
    fn memcmp_native_engine_runs_green_threads() {
        // Writes BENCH_mem_native.json into the cwd, like the adaptcmp
        // smoke artifact. The default structure axis reports both the
        // loose-thread and the bubble-structured shape per policy.
        let cmd = "memcmp --machine numa-2x2 --scheds memaware,afs --engine native --smoke";
        let out = run(&argv(cmd)).unwrap();
        assert!(out.contains("native"), "{out}");
        assert!(out.contains("memaware"), "{out}");
        assert!(out.contains("Simple"), "{out}");
        assert!(out.contains("Bubbles"), "{out}");
        assert!(out.contains("BENCH_mem_native.json"), "{out}");
        // The axis is selectable, and garbage is rejected.
        let one =
            "memcmp --machine numa-2x2 --scheds afs --engine native --structure bubbles --smoke";
        let out = run(&argv(one)).unwrap();
        assert!(out.contains("Bubbles"), "{out}");
        assert!(!out.contains("Simple"), "{out}");
        let err = run(&argv(
            "memcmp --machine numa-2x2 --engine native --structure warp --smoke",
        ))
        .unwrap_err();
        assert!(err.to_string().contains("unknown structure"), "{err}");
        // The axis is native-only: the sim engine rejects it loudly
        // instead of silently ignoring it.
        let err = run(&argv("memcmp --machine numa-2x2 --structure bubbles --smoke"))
            .unwrap_err();
        assert!(err.to_string().contains("native only"), "{err}");
    }

    #[test]
    fn memcmp_arena_flag_is_native_only_and_runs() {
        // --arena on the sim engine is a loud error…
        let err = run(&argv("memcmp --machine numa-2x2 --arena --smoke")).unwrap_err();
        assert!(err.to_string().contains("native only"), "{err}");
        // …and on the native engine it backs regions with real mmap
        // pages (best-effort: the run must succeed either way).
        let cmd = "memcmp --machine numa-2x2 --scheds afs --engine native \
                   --structure simple --arena --smoke";
        let out = run(&argv(cmd)).unwrap();
        assert!(out.contains("BENCH_mem_native.json"), "{out}");
        assert!(out.contains("afs"), "{out}");
    }

    #[test]
    fn adaptcmp_command_reports_both_workloads() {
        let out = run(&argv("adaptcmp --machine numa-2x2 --scheds adaptive,afs --smoke")).unwrap();
        assert!(out.contains("adaptive"), "{out}");
        assert!(out.contains("afs"), "{out}");
        assert!(out.contains("phase-changing"), "{out}");
        assert!(out.contains("bursty"), "{out}");
        assert!(out.contains("BENCH_adaptive.json"), "{out}");
        let err = run(&argv("adaptcmp --machine numa-2x2 --scheds warp")).unwrap_err();
        assert!(err.to_string().contains("unknown scheduler"), "{err}");
    }

    #[test]
    fn serve_command_reports_all_legs() {
        // Small generated stream, sim engine only: the three sim legs
        // (job-fair, its static baseline, ss) land in the table and the
        // BENCH_serve.json artifact.
        let out = run(&argv("serve --machine numa-2x2 --jobs 12 --seed 3 --engine sim")).unwrap();
        assert!(out.contains("multi-tenant serve"), "{out}");
        assert!(out.contains("job-fair"), "{out}");
        assert!(out.contains("job-fair-static"), "{out}");
        assert!(out.contains("generated stream"), "{out}");
        assert!(out.contains("BENCH_serve.json"), "{out}");
        let err = run(&argv("serve --machine numa-2x2 --engine warp")).unwrap_err();
        assert!(err.to_string().contains("unknown engine"), "{err}");
    }

    #[test]
    fn submit_then_serve_from_queue() {
        let path = std::env::temp_dir().join("bubbles-cli-serve-spool.txt");
        let _ = std::fs::remove_file(&path);
        let q = path.to_string_lossy().to_string();
        let out = run(&argv(&format!(
            "submit --queue {q} --name web --class latency --threads 2 --mode bubbles"
        )))
        .unwrap();
        assert!(out.contains("web"), "{out}");
        assert!(out.contains("latency"), "{out}");
        run(&argv(&format!("submit --queue {q} --name bulk --class batch --app amr"))).unwrap();
        let out = run(&argv(&format!("serve --machine numa-2x2 --queue {q} --engine sim")))
            .unwrap();
        assert!(out.contains("(2 jobs)"), "{out}");
        assert!(out.contains("job-fair"), "{out}");
        // Misuse fails loudly.
        let err = run(&argv("submit --name x")).unwrap_err();
        assert!(err.to_string().contains("--queue"), "{err}");
        let err = run(&argv(&format!("submit --queue {q} --class warp"))).unwrap_err();
        assert!(err.to_string().contains("unknown class"), "{err}");
        let err = run(&argv(&format!("submit --queue {q} --mode warp"))).unwrap_err();
        assert!(err.to_string().contains("unknown mode"), "{err}");
        let err = run(&argv(&format!("submit --queue {q} --app warp"))).unwrap_err();
        assert!(err.to_string().contains("unknown app"), "{err}");
    }

    #[test]
    fn trace_command_writes_chrome_json() {
        // `repro trace` drops a well-formed Chrome trace-event artifact
        // and points the user at a viewer; help advertises it.
        assert!(run(&argv("help")).unwrap().contains("trace"), "help must mention trace");
        let path = std::env::temp_dir().join("bubbles-cli-trace.json");
        let cmd = format!(
            "trace --machine numa-2x2 --sched afs --smoke --out {}",
            path.display()
        );
        let out = run(&argv(&cmd)).unwrap();
        assert!(out.contains("perfetto"), "{out}");
        assert!(out.contains("events captured"), "{out}");
        let s = std::fs::read_to_string(&path).unwrap();
        crate::util::json::validate(&s).unwrap_or_else(|e| panic!("invalid JSON: {e}"));
        assert!(s.contains("traceEvents"), "{s}");
        let err = run(&argv("trace --machine numa-2x2 --engine warp")).unwrap_err();
        assert!(err.to_string().contains("unknown engine"), "{err}");
    }

    #[test]
    fn memcmp_trace_flag_writes_artifact() {
        let path = std::env::temp_dir().join("bubbles-cli-memcmp-trace.json");
        let cmd = format!(
            "memcmp --machine numa-2x2 --scheds afs --smoke --trace {}",
            path.display()
        );
        let out = run(&argv(&cmd)).unwrap();
        assert!(out.contains("wrote first-leg Chrome trace"), "{out}");
        let s = std::fs::read_to_string(&path).unwrap();
        crate::util::json::validate(&s).unwrap_or_else(|e| panic!("invalid JSON: {e}"));
    }

    #[test]
    fn analyze_native_engine_reports_dispatches() {
        let out = run(&argv("analyze --machine numa-2x2 --sched afs --engine native")).unwrap();
        assert!(out.contains("native engine"), "{out}");
        assert!(out.contains("dispatches"), "{out}");
        let err = run(&argv("analyze --machine numa-2x2 --engine warp")).unwrap_err();
        assert!(err.to_string().contains("unknown engine"), "{err}");
        let err =
            run(&argv("analyze --machine numa-2x2 --engine native --app amr")).unwrap_err();
        assert!(err.to_string().contains("conduction"), "{err}");
    }

    #[test]
    fn run_with_default_config_small() {
        // Use an inline config via a temp file.
        let path = std::env::temp_dir().join("bubbles-cli-test.toml");
        std::fs::write(
            &path,
            "[machine]\npreset = \"numa-2x2\"\n[workload]\napp = \"conduction\"\nthreads = 4\ncycles = 3\nwork = 100000\n",
        )
        .unwrap();
        let out = run(&[
            "run".to_string(),
            "--config".to_string(),
            path.to_string_lossy().to_string(),
        ])
        .unwrap();
        assert!(out.contains("makespan"), "{out}");
    }

    #[test]
    fn sweep_args_and_dispatch_errors() {
        // Operands are allowed for sweep (and only sweep), `-j N` is
        // the worker-count spelling, and the failure modes are loud.
        let a = Args::parse(&argv("sweep diff runA runB -j 8")).unwrap();
        assert_eq!(a.positionals, ["diff", "runA", "runB"]);
        assert_eq!(a.get("j", "4"), "8");
        let a = Args::parse(&argv("sweep --grid g.toml --continue-on-failure")).unwrap();
        assert!(a.flag("continue-on-failure"));
        assert!(Args::parse(&argv("memcmp stray")).is_err());
        assert!(Args::parse(&argv("sweep -j")).is_err());
        let err = run(&argv("sweep")).unwrap_err();
        assert!(err.to_string().contains("--grid"), "{err}");
        let err = run(&argv("sweep warp")).unwrap_err();
        assert!(err.to_string().contains("unknown sweep subcommand"), "{err}");
        let err = run(&argv("sweep --grid /no/such/grid.toml")).unwrap_err();
        assert!(err.to_string().contains("cannot read grid"), "{err}");
    }

    #[test]
    fn sweep_cell_runs_one_grid_cell_in_process() {
        let path = std::env::temp_dir().join("bubbles-cli-sweep-cell.json");
        let argv: Vec<String> = vec![
            "sweep".to_string(),
            "--cell".to_string(),
            "experiment=memcmp machine=numa-2x2 scheds=afs engine=sim seed=3 smoke=true"
                .to_string(),
            "--cell-out".to_string(),
            path.to_string_lossy().to_string(),
        ];
        let out = run(&argv).unwrap();
        assert!(out.contains("afs"), "{out}");
        let s = std::fs::read_to_string(&path).unwrap();
        crate::util::json::validate(&s).unwrap_or_else(|e| panic!("invalid JSON: {e}"));
        assert!(s.contains("\"bench\": \"sweep-cell\""), "{s}");
        assert!(s.contains("\"config_hash\""), "{s}");
    }
}
