//! Task lifecycle states and legal transitions.

use crate::topology::{CpuId, LevelId};

/// Where a task currently is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskState {
    /// Created, not yet inserted anywhere (Figure 4:
    /// `marcel_create_dontsched` creates without starting).
    New,
    /// Held inside a closed bubble, not independently schedulable.
    InBubble,
    /// On the runqueue of `list`, runnable.
    Ready { list: LevelId },
    /// Executing on `cpu`.
    Running { cpu: CpuId },
    /// Blocked on a synchronisation object (barrier, join).
    Blocked,
    /// Finished. Terminal.
    Terminated,
}

impl TaskState {
    /// Whether the transition `self → next` is legal. The schedulers
    /// debug-assert this on every state write; the property tests drive
    /// random schedules through it.
    pub fn can_become(&self, next: &TaskState) -> bool {
        use TaskState::*;
        match (self, next) {
            // New tasks can be adopted by a bubble or woken directly.
            (New, InBubble) | (New, Ready { .. }) => true,
            // A bubble releases its content onto a list; regeneration
            // pulls Ready tasks back in.
            (InBubble, Ready { .. }) => true,
            (Ready { .. }, InBubble) => true,
            // Dispatch and requeue.
            (Ready { .. }, Running { .. }) => true,
            (Running { .. }, Ready { .. }) => true,
            // Running threads may re-enter their regenerating bubble
            // "by themselves" at the next scheduler call (§4).
            (Running { .. }, InBubble) => true,
            (Running { .. }, Blocked) => true,
            (Running { .. }, Terminated) => true,
            // Wakeups.
            (Blocked, Ready { .. }) => true,
            (Blocked, InBubble) => true,
            // Bubbles terminate from wherever they are once empty.
            (Ready { .. }, Terminated) | (InBubble, Terminated) | (Blocked, Terminated) => true,
            // Requeue to a different list (move down/up) is a Ready→Ready.
            (Ready { .. }, Ready { .. }) => true,
            _ => false,
        }
    }

    /// Runnable = sitting on some list.
    pub fn is_ready(&self) -> bool {
        matches!(self, TaskState::Ready { .. })
    }

    /// Executing right now.
    pub fn is_running(&self) -> bool {
        matches!(self, TaskState::Running { .. })
    }

    /// The list this task is queued on, if Ready.
    pub fn ready_list(&self) -> Option<LevelId> {
        match self {
            TaskState::Ready { list } => Some(*list),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn legal_paths() {
        use TaskState::*;
        let l = LevelId(0);
        let c = CpuId(0);
        assert!(New.can_become(&InBubble));
        assert!(InBubble.can_become(&Ready { list: l }));
        assert!(Ready { list: l }.can_become(&Running { cpu: c }));
        assert!(Running { cpu: c }.can_become(&Blocked));
        assert!(Blocked.can_become(&Ready { list: l }));
        assert!(Running { cpu: c }.can_become(&Terminated));
    }

    #[test]
    fn illegal_paths() {
        use TaskState::*;
        let l = LevelId(0);
        let c = CpuId(0);
        assert!(!Terminated.can_become(&Ready { list: l }));
        assert!(!New.can_become(&Running { cpu: c }));
        assert!(!Blocked.can_become(&Running { cpu: c }));
        assert!(!New.can_become(&Blocked));
    }

    #[test]
    fn accessors() {
        let s = TaskState::Ready { list: LevelId(4) };
        assert!(s.is_ready());
        assert_eq!(s.ready_list(), Some(LevelId(4)));
        assert!(!s.is_running());
        assert_eq!(TaskState::Blocked.ready_list(), None);
    }
}
