//! Task model: threads and *bubbles* (paper §3.1, Figures 1 & 4).
//!
//! Threads and bubbles are both "tasks" the execution environment
//! distributes on the machine. A bubble is a nested set of tasks
//! expressing an affinity relation (data sharing, collective operations,
//! SMT symbiosis); bubble nesting expresses refinement of one relation
//! by another.

mod bubble;
mod state;
mod table;

pub use bubble::{BubbleData, BubblePhase, BurstLevel};
pub use state::TaskState;
pub use table::TaskTable;

use crate::topology::{CpuId, LevelId};

/// Task identifier: index into the [`TaskTable`] arena.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskId(pub usize);

impl std::fmt::Display for TaskId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "t{}", self.0)
    }
}

/// Integer priority (paper §3.3.2): when a processor looks for work it
/// scans the lists covering it from most local to most global and runs
/// the *highest-priority* task found, even if less-prioritised tasks sit
/// on more local lists.
pub type Prio = i32;

/// Default thread priority (Figure 1 gives threads higher priority than
/// the bubbles that held them, producing gang scheduling).
pub const PRIO_THREAD: Prio = 2;
/// Default bubble priority.
pub const PRIO_BUBBLE: Prio = 1;
/// A highly-prioritised (e.g. communication) thread, Figure 1.
pub const PRIO_HIGH: Prio = 3;

/// Thread-specific data.
#[derive(Debug, Clone, Default)]
pub struct ThreadData {
    /// Another thread this one runs in SMT *symbiosis* with (§3.1): the
    /// pair can share a physical core without interfering.
    pub symbiotic: Option<TaskId>,
    /// Predetermined binding (used by the `bound` baseline, §2.1).
    pub bound_cpu: Option<CpuId>,
}

/// What a task is.
#[derive(Debug, Clone)]
pub enum TaskKind {
    Thread(ThreadData),
    Bubble(BubbleData),
}

/// A schedulable entity: thread or bubble.
#[derive(Debug, Clone)]
pub struct Task {
    pub id: TaskId,
    pub name: String,
    pub prio: Prio,
    pub state: TaskState,
    pub kind: TaskKind,
    /// The bubble holding this task, if any.
    pub parent: Option<TaskId>,
    /// Last CPU this task ran on (affinity hint + migration accounting).
    pub last_cpu: Option<CpuId>,
    /// The list this task was last queued on (requeue affinity).
    pub last_list: Option<LevelId>,
}

impl Task {
    /// Create a thread task (unqueued; `InBubble` state is set when
    /// inserted into a bubble, `Ready` when woken standalone).
    pub fn thread(id: TaskId, name: impl Into<String>, prio: Prio) -> Task {
        Task {
            id,
            name: name.into(),
            prio,
            state: TaskState::New,
            kind: TaskKind::Thread(ThreadData::default()),
            parent: None,
            last_cpu: None,
            last_list: None,
        }
    }

    /// Create an (empty, closed) bubble task.
    pub fn bubble(id: TaskId, name: impl Into<String>, prio: Prio) -> Task {
        Task {
            id,
            name: name.into(),
            prio,
            state: TaskState::New,
            kind: TaskKind::Bubble(BubbleData::default()),
            parent: None,
            last_cpu: None,
            last_list: None,
        }
    }

    /// Is this a bubble?
    pub fn is_bubble(&self) -> bool {
        matches!(self.kind, TaskKind::Bubble(_))
    }

    /// Is this a thread?
    pub fn is_thread(&self) -> bool {
        matches!(self.kind, TaskKind::Thread(_))
    }

    /// Bubble data accessor (panics on threads — internal misuse bug).
    pub fn bubble_data(&self) -> &BubbleData {
        match &self.kind {
            TaskKind::Bubble(b) => b,
            TaskKind::Thread(_) => panic!("{} is not a bubble", self.id),
        }
    }

    /// Mutable bubble data accessor.
    pub fn bubble_data_mut(&mut self) -> &mut BubbleData {
        match &mut self.kind {
            TaskKind::Bubble(b) => b,
            TaskKind::Thread(_) => panic!("{} is not a bubble", self.id),
        }
    }

    /// Thread data accessor (panics on bubbles).
    pub fn thread_data(&self) -> &ThreadData {
        match &self.kind {
            TaskKind::Thread(t) => t,
            TaskKind::Bubble(_) => panic!("{} is not a thread", self.id),
        }
    }

    /// Mutable thread data accessor.
    pub fn thread_data_mut(&mut self) -> &mut ThreadData {
        match &mut self.kind {
            TaskKind::Thread(t) => t,
            TaskKind::Bubble(_) => panic!("{} is not a thread", self.id),
        }
    }

    /// Clone the contents list of a bubble task (empty for threads).
    pub fn kind_contents_snapshot(&self) -> Vec<TaskId> {
        match &self.kind {
            TaskKind::Bubble(b) => b.contents.clone(),
            TaskKind::Thread(_) => Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors() {
        let t = Task::thread(TaskId(0), "worker", PRIO_THREAD);
        assert!(t.is_thread() && !t.is_bubble());
        assert_eq!(t.state, TaskState::New);
        let b = Task::bubble(TaskId(1), "group", PRIO_BUBBLE);
        assert!(b.is_bubble());
        assert!(b.bubble_data().contents.is_empty());
    }

    #[test]
    #[should_panic]
    fn thread_is_not_a_bubble() {
        Task::thread(TaskId(0), "t", 0).bubble_data();
    }

    #[test]
    fn priorities_order_gang() {
        // Figure 1's configuration must order: bubbles < threads < high.
        assert!(PRIO_BUBBLE < PRIO_THREAD && PRIO_THREAD < PRIO_HIGH);
    }

    #[test]
    fn display() {
        assert_eq!(TaskId(7).to_string(), "t7");
    }
}
