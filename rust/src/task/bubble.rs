//! Bubble-specific task data (paper §3.3).

use super::TaskId;
use crate::topology::{LevelId, LevelKind};

/// Where a bubble should burst (paper §3.3.1: "The main issue is how to
/// specify the right bursting level of a bubble"). Deep levels favour
/// affinity at the risk of imbalance; high levels favour processor use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BurstLevel {
    /// Burst when reaching a component of this kind (e.g. NUMA node).
    Kind(LevelKind),
    /// Burst at an absolute tree depth (root = 0).
    Depth(usize),
    /// Ride all the way down to a single logical CPU's list.
    Leaf,
    /// Burst immediately wherever the bubble is first scheduled.
    Immediate,
}

impl Default for BurstLevel {
    fn default() -> Self {
        // Group per NUMA node by default: the affinity relation most
        // paper workloads express is data sharing within a node.
        BurstLevel::Kind(LevelKind::NumaNode)
    }
}

/// Lifecycle of a bubble.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BubblePhase {
    /// Holding its tasks; may be queued on a list, descending.
    Closed,
    /// Has released its tasks (Figure 3 (d)); records where, for
    /// regeneration.
    Burst,
}

/// Bubble payload inside a [`super::Task`].
#[derive(Debug, Clone)]
pub struct BubbleData {
    /// Directly held tasks (threads and sub-bubbles), insertion order.
    pub contents: Vec<TaskId>,
    /// Bursting level (None → scheduler default).
    pub burst: Option<BurstLevel>,
    /// Closed or burst.
    pub phase: BubblePhase,
    /// The list on which this bubble burst / was released — the place a
    /// regenerated bubble is "moved up" to and re-queued on (§3.3.3, §4).
    pub home_list: Option<LevelId>,
    /// Time slice in engine time units; when the bubble's threads have
    /// consumed it, the bubble is regenerated and requeued at the end of
    /// its list ("extended to Gang Scheduling", §3.3.3).
    pub timeslice: Option<u64>,
    /// Time consumed against `timeslice` since last regeneration.
    pub slice_used: u64,
    /// Regeneration requested: Ready contents have been pulled back in;
    /// Running ones will re-enter the bubble at their next scheduler
    /// call ("those threads go back in the bubble by themselves", §4).
    pub regen_pending: bool,
    /// Where the regenerated bubble re-queues once closed: its home
    /// list for timeslice regeneration, an ancestor covering the idle
    /// CPU for corrective regeneration.
    pub regen_target: Option<LevelId>,
    /// Contents that are currently *outside* the bubble (released and
    /// not yet returned / terminated). The last one back closes the
    /// bubble (§4).
    pub outside: usize,
    /// Contents not yet terminated; 0 ⇒ the bubble itself terminates.
    pub live: usize,
}

impl Default for BubbleData {
    fn default() -> Self {
        BubbleData {
            contents: Vec::new(),
            burst: None,
            phase: BubblePhase::Closed,
            home_list: None,
            timeslice: None,
            slice_used: 0,
            regen_pending: false,
            regen_target: None,
            outside: 0,
            live: 0,
        }
    }
}

impl BubbleData {
    /// Resolve the burst depth against a concrete machine: the depth on
    /// the covering chain at which the bubble bursts.
    pub fn burst_depth(
        &self,
        default: BurstLevel,
        topo: &crate::topology::Topology,
    ) -> usize {
        let level = self.burst.unwrap_or(default);
        let max_depth = topo.depth() - 1;
        match level {
            BurstLevel::Immediate => 0,
            BurstLevel::Leaf => max_depth,
            BurstLevel::Depth(d) => d.min(max_depth),
            BurstLevel::Kind(kind) => {
                // Depth of the first component of this kind; if the
                // machine lacks the level, fall back to the deepest
                // level above it that exists (clamp to root).
                topo.components()
                    .find(|(_, n)| n.kind == kind)
                    .map(|(_, n)| n.depth)
                    .unwrap_or(0)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Topology;

    #[test]
    fn burst_depth_resolution() {
        let numa = Topology::numa(4, 4); // depths: 0 machine, 1 numa, 2 cpu
        let d = BubbleData::default();
        assert_eq!(d.burst_depth(BurstLevel::default(), &numa), 1);
        assert_eq!(d.burst_depth(BurstLevel::Immediate, &numa), 0);
        assert_eq!(d.burst_depth(BurstLevel::Leaf, &numa), 2);
        assert_eq!(d.burst_depth(BurstLevel::Depth(99), &numa), 2);
    }

    #[test]
    fn missing_level_falls_back_to_root() {
        let smp = Topology::smp(4); // no NUMA level
        let d = BubbleData::default();
        assert_eq!(d.burst_depth(BurstLevel::Kind(LevelKind::NumaNode), &smp), 0);
    }

    #[test]
    fn per_bubble_override_wins() {
        let numa = Topology::numa(2, 2);
        let d = BubbleData { burst: Some(BurstLevel::Leaf), ..Default::default() };
        assert_eq!(d.burst_depth(BurstLevel::Immediate, &numa), 2);
    }
}
