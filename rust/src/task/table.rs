//! Concurrent task arena.
//!
//! Append-only vector of `Arc<Mutex<Task>>` slots. The native executor's
//! workers and the single-threaded simulator share this type; slot
//! mutexes are uncontended in the simulator and short-held on the native
//! hot path.

use std::sync::{Arc, Mutex, RwLock};

use super::{Prio, Task, TaskId, TaskState};

/// Shared, growable task table.
#[derive(Debug, Default)]
pub struct TaskTable {
    slots: RwLock<Vec<Arc<Mutex<Task>>>>,
}

impl TaskTable {
    pub fn new() -> TaskTable {
        TaskTable::default()
    }

    /// Allocate a new thread task.
    pub fn new_thread(&self, name: impl Into<String>, prio: Prio) -> TaskId {
        self.insert(|id| Task::thread(id, name, prio))
    }

    /// Allocate a new bubble task.
    pub fn new_bubble(&self, name: impl Into<String>, prio: Prio) -> TaskId {
        self.insert(|id| Task::bubble(id, name, prio))
    }

    fn insert(&self, make: impl FnOnce(TaskId) -> Task) -> TaskId {
        let mut slots = self.slots.write().unwrap();
        let id = TaskId(slots.len());
        slots.push(Arc::new(Mutex::new(make(id))));
        id
    }

    /// Number of tasks ever created.
    pub fn len(&self) -> usize {
        self.slots.read().unwrap().len()
    }

    /// True when no task was created.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Clone the slot handle for `id`.
    pub fn handle(&self, id: TaskId) -> Arc<Mutex<Task>> {
        self.slots.read().unwrap()[id.0].clone()
    }

    /// Run `f` with the locked task.
    ///
    /// Perf note (EXPERIMENTS.md §Perf): the slot mutex is locked while
    /// still under the table's read guard, avoiding an Arc clone+drop
    /// (two contended RMWs) per access on the scheduler hot path. The
    /// read guard only blocks table *growth*, never other accesses.
    pub fn with<R>(&self, id: TaskId, f: impl FnOnce(&mut Task) -> R) -> R {
        let slots = self.slots.read().unwrap();
        let mut guard = slots[id.0].lock().unwrap();
        f(&mut guard)
    }

    /// Read-only convenience accessors -------------------------------

    pub fn state(&self, id: TaskId) -> TaskState {
        self.with(id, |t| t.state)
    }

    pub fn prio(&self, id: TaskId) -> Prio {
        self.with(id, |t| t.prio)
    }

    pub fn name(&self, id: TaskId) -> String {
        self.with(id, |t| t.name.clone())
    }

    pub fn parent(&self, id: TaskId) -> Option<TaskId> {
        self.with(id, |t| t.parent)
    }

    pub fn is_bubble(&self, id: TaskId) -> bool {
        self.with(id, |t| t.is_bubble())
    }

    /// Transition the state, debug-asserting legality. Returns the old
    /// state.
    pub fn set_state(&self, id: TaskId, next: TaskState) -> TaskState {
        self.with(id, |t| {
            debug_assert!(
                t.state.can_become(&next),
                "illegal transition for {}: {:?} -> {:?}",
                t.id,
                t.state,
                next
            );
            std::mem::replace(&mut t.state, next)
        })
    }

    /// Iterate over all task ids.
    pub fn ids(&self) -> Vec<TaskId> {
        (0..self.len()).map(TaskId).collect()
    }

    /// Count of non-terminated thread tasks (simulation end condition).
    pub fn live_threads(&self) -> usize {
        self.ids()
            .into_iter()
            .filter(|&id| {
                self.with(id, |t| t.is_thread() && t.state != TaskState::Terminated)
            })
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::task::PRIO_THREAD;

    #[test]
    fn allocation_and_access() {
        let tbl = TaskTable::new();
        let a = tbl.new_thread("a", PRIO_THREAD);
        let b = tbl.new_bubble("b", 1);
        assert_eq!(tbl.len(), 2);
        assert_eq!(a, TaskId(0));
        assert_eq!(b, TaskId(1));
        assert_eq!(tbl.name(a), "a");
        assert!(tbl.is_bubble(b));
        assert!(!tbl.is_bubble(a));
    }

    #[test]
    fn state_transitions_enforced() {
        let tbl = TaskTable::new();
        let a = tbl.new_thread("a", PRIO_THREAD);
        assert_eq!(tbl.state(a), TaskState::New);
        tbl.set_state(a, TaskState::InBubble);
        assert_eq!(tbl.state(a), TaskState::InBubble);
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn illegal_transition_panics_in_debug() {
        let tbl = TaskTable::new();
        let a = tbl.new_thread("a", PRIO_THREAD);
        tbl.set_state(a, TaskState::Terminated); // New -> Terminated: illegal
    }

    #[test]
    fn concurrent_creation() {
        let tbl = std::sync::Arc::new(TaskTable::new());
        let mut joins = Vec::new();
        for k in 0..8 {
            let t = tbl.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..100 {
                    t.new_thread(format!("w{k}-{i}"), 0);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(tbl.len(), 800);
        // All ids distinct by construction; spot-check names resolve.
        assert!(tbl.name(TaskId(799)).starts_with('w'));
    }

    #[test]
    fn live_threads_counts_only_threads() {
        let tbl = TaskTable::new();
        let a = tbl.new_thread("a", 0);
        let _b = tbl.new_bubble("b", 0);
        assert_eq!(tbl.live_threads(), 1);
        tbl.set_state(a, TaskState::InBubble);
        tbl.set_state(a, TaskState::Terminated);
        assert_eq!(tbl.live_threads(), 0);
    }
}
