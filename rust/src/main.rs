//! `repro` — the launcher binary. See `repro help`.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match bubbles::cli::run(&argv) {
        Ok(out) => println!("{out}"),
        // Exit carries a report for stdout plus a contract exit code
        // (1 = failed sweep cells, 2 = gated regression) so unattended
        // drivers can branch on the status without scraping stderr.
        Err(bubbles::Error::Exit { code, report }) => {
            println!("{report}");
            std::process::exit(code);
        }
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}
