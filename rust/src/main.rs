//! `repro` — the launcher binary. See `repro help`.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match bubbles::cli::run(&argv) {
        Ok(out) => println!("{out}"),
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}
