//! Thread programs for the simulator: what a simulated thread *does*.

use crate::task::TaskId;

/// Memory region handle: programs reference regions registered in the
/// system-wide registry ([`crate::mem`]), which resolves homing
/// (first-touch / explicit / round-robin) and next-touch migration.
pub use crate::mem::RegionId;

/// Barrier handle.
pub type BarrierId = usize;

/// One step of a thread's life.
#[derive(Debug, Clone)]
pub enum WorkItem {
    /// Burn `cycles` of compute, of which `mem_fraction` is
    /// memory-bound on `region` (NUMA-sensitive). `region: None` means
    /// purely local/cache-resident work.
    Compute { cycles: u64, mem_fraction: f64, region: Option<RegionId> },
    /// Arrive at a barrier; blocks until all parties arrive.
    Barrier(BarrierId),
    /// Wake another task (thread or bubble) — models spawning.
    Wake(TaskId),
    /// Block until `task` terminates.
    Join(TaskId),
}

/// A thread's full program (executed once; the thread terminates at the
/// end).
#[derive(Debug, Clone, Default)]
pub struct Program {
    pub items: Vec<WorkItem>,
}

impl Program {
    pub fn new() -> Program {
        Program::default()
    }

    /// Builder: compute step.
    pub fn compute(mut self, cycles: u64, mem_fraction: f64, region: Option<RegionId>) -> Self {
        self.items.push(WorkItem::Compute { cycles, mem_fraction, region });
        self
    }

    /// Builder: barrier arrival.
    pub fn barrier(mut self, b: BarrierId) -> Self {
        self.items.push(WorkItem::Barrier(b));
        self
    }

    /// Builder: wake a task.
    pub fn wake(mut self, t: TaskId) -> Self {
        self.items.push(WorkItem::Wake(t));
        self
    }

    /// Builder: join a task.
    pub fn join(mut self, t: TaskId) -> Self {
        self.items.push(WorkItem::Join(t));
        self
    }

    /// Total raw compute cycles in the program (cost-model-independent).
    pub fn total_cycles(&self) -> u64 {
        self.items
            .iter()
            .map(|i| match i {
                WorkItem::Compute { cycles, .. } => *cycles,
                _ => 0,
            })
            .sum()
    }
}

/// Execution cursor over a program.
#[derive(Debug, Clone, Default)]
pub struct Cursor {
    /// Next item index.
    pub pc: usize,
    /// Cycles already burned inside items[pc] (when it is a Compute).
    pub done_in_item: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_chains() {
        let p = Program::new()
            .compute(100, 0.5, Some(0))
            .barrier(0)
            .wake(TaskId(3))
            .join(TaskId(3))
            .compute(50, 0.0, None);
        assert_eq!(p.items.len(), 5);
        assert_eq!(p.total_cycles(), 150);
    }
}
