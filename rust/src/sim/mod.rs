//! Deterministic discrete-event simulator of a hierarchical machine.
//!
//! This is the evaluation substrate standing in for the paper's
//! testbeds (Bull NovaScale ccNUMA 16× Itanium II, dual HT Xeon — see
//! DESIGN.md §Substitutions): virtual CPUs execute thread *programs*
//! ([`workload::Program`]) under a pluggable [`Scheduler`], with memory
//! placement (first touch), the NUMA factor, cache-migration penalties
//! and SMT sibling effects modelled by [`cost::CostModel`].
//!
//! The simulator calls the scheduler exactly like the paper's MARCEL:
//! per-processor, on preemption / blocking / termination — never
//! globally.

pub mod cost;
pub mod workload;

pub use cost::{ChunkCtx, CostModel};
pub use workload::{BarrierId, Cursor, Program, RegionId, WorkItem};

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::mem::DEFAULT_REGION_BYTES;
use crate::metrics::Metrics;
use crate::sched::{Scheduler, StopReason, System};
use crate::task::{Prio, TaskId, TaskState};
use crate::topology::CpuId;
use crate::trace::Event as TraceEvent;
use crate::util::Rng;

// Region state lives in the system-wide registry ([`crate::mem`]) so
// schedulers can consult it; the engine-local copy this module used to
// keep is gone. The policy type is re-exported for compatibility.
pub use crate::mem::AllocPolicy;

/// Engine tunables.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Max compute cycles executed per scheduling segment (tick
    /// granularity for timeslice accounting).
    pub quantum: u64,
    /// Idle CPUs re-poll the scheduler after this many cycles.
    pub idle_repoll: u64,
    /// Cost of a dispatch (user-level context switch), cycles.
    pub ctx_switch: u64,
    /// Hard wall on simulated time (deadlock/livelock safety net).
    pub max_time: u64,
    /// Relative timing noise on segment durations (cache effects,
    /// interrupts, DRAM refresh...). Deterministic from `seed`.
    /// Without it the simulator is unrealistically stable: a single
    /// global list would keep a perfect thread→CPU mapping forever,
    /// which no real machine does.
    pub jitter: f64,
    /// Seed for the jitter stream.
    pub seed: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            quantum: 1_000_000,
            idle_repoll: 10_000,
            ctx_switch: 400,
            max_time: u64::MAX / 4,
            jitter: 0.05,
            seed: 0x5eed,
        }
    }
}

/// Final run report.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Simulated cycles until the last thread terminated.
    pub total_time: u64,
    /// Per-CPU busy cycles.
    pub busy: Vec<u64>,
    /// Scheduler name.
    pub sched: String,
}

impl SimReport {
    /// Utilisation across CPUs over the makespan.
    pub fn utilisation(&self) -> f64 {
        if self.total_time == 0 {
            return 0.0;
        }
        let total_busy: u64 = self.busy.iter().sum();
        total_busy as f64 / (self.total_time as f64 * self.busy.len() as f64)
    }
}

#[derive(Debug)]
struct BarrierState {
    parties: usize,
    arrived: usize,
    waiting: Vec<TaskId>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Ev {
    /// CPU is free: ask the scheduler for work.
    CpuFree(CpuId),
    /// The running segment on this CPU completed.
    SegmentEnd(CpuId),
}

#[derive(Debug)]
struct RunningState {
    task: TaskId,
    /// Wall cycles of the segment (post-cost-model), for tick charging.
    seg_wall: u64,
}

/// The discrete-event engine.
pub struct SimEngine {
    pub sys: Arc<System>,
    sched: Arc<dyn Scheduler>,
    cost: CostModel,
    cfg: SimConfig,
    programs: HashMap<TaskId, (Program, Cursor)>,
    barriers: Vec<BarrierState>,
    /// join target -> waiters.
    join_waiters: HashMap<TaskId, Vec<TaskId>>,
    /// Engine-side record of each thread's previous CPU (the scheduler
    /// updates Task::last_cpu before we can read it, so the cache
    /// refill penalty is computed from this map).
    prev_cpu: HashMap<TaskId, CpuId>,
    running: Vec<Option<RunningState>>,
    /// Event queue keyed by (time, seq) for determinism.
    queue: BinaryHeap<Reverse<(u64, u64, CpuId, u8)>>,
    seq: u64,
    now: u64,
    busy: Vec<u64>,
    finished_at: u64,
    rng: Rng,
}

impl SimEngine {
    /// Build an engine over a fresh system.
    pub fn new(sys: Arc<System>, sched: Arc<dyn Scheduler>, cost: CostModel, cfg: SimConfig) -> SimEngine {
        let n = sys.topo.n_cpus();
        let cfg_seed = cfg.seed;
        SimEngine {
            sys,
            sched,
            cost,
            cfg,
            programs: HashMap::new(),
            barriers: Vec::new(),
            join_waiters: HashMap::new(),
            prev_cpu: HashMap::new(),
            running: (0..n).map(|_| None).collect(),
            queue: BinaryHeap::new(),
            seq: 0,
            now: 0,
            busy: vec![0; n],
            finished_at: 0,
            rng: Rng::new(cfg_seed),
        }
    }

    /// Allocate a memory region (first-touch homing, default size).
    pub fn alloc_region(&mut self) -> RegionId {
        self.sys.mem.alloc(DEFAULT_REGION_BYTES, AllocPolicy::FirstTouch)
    }

    /// Allocate a region explicitly homed on a NUMA node.
    pub fn alloc_region_on(&mut self, numa: usize) -> RegionId {
        self.sys.mem.alloc(DEFAULT_REGION_BYTES, AllocPolicy::Fixed(numa))
    }

    /// Allocate a region under a policy (paper §2.3: modern systems
    /// "let the application choose the memory allocation policy
    /// (specific memory node, first touch or round robin)").
    pub fn alloc_region_policy(&mut self, policy: AllocPolicy) -> RegionId {
        self.sys.mem.alloc(DEFAULT_REGION_BYTES, policy)
    }

    /// Allocate a region of `bytes` under a policy (footprint-weighted).
    pub fn alloc_region_sized(&mut self, bytes: u64, policy: AllocPolicy) -> RegionId {
        self.sys.mem.alloc(bytes, policy)
    }

    /// Allocate a striped region of `bytes` spread over `nodes` (one
    /// stripe per node — see [`crate::mem::RegionRegistry::alloc_striped`]).
    pub fn alloc_region_striped(&mut self, bytes: u64, nodes: &[usize]) -> RegionId {
        self.sys.mem.alloc_striped(bytes, nodes)
    }

    /// Attach a region to a task: its bytes count towards the task's
    /// (and its bubbles') NUMA footprint (see [`crate::mem`]).
    pub fn attach_region(&mut self, task: TaskId, region: RegionId) {
        self.sys.mem.attach(&self.sys.tasks, task, region);
    }

    /// Create a barrier for `parties` participants.
    pub fn alloc_barrier(&mut self, parties: usize) -> BarrierId {
        self.barriers.push(BarrierState { parties, arrived: 0, waiting: Vec::new() });
        self.barriers.len() - 1
    }

    /// Attach a program to a thread task.
    pub fn set_program(&mut self, task: TaskId, program: Program) {
        self.programs.insert(task, (program, Cursor::default()));
    }

    /// Create a thread with a program (not yet woken).
    pub fn add_thread(&mut self, name: impl Into<String>, prio: Prio, program: Program) -> TaskId {
        let t = self.sys.tasks.new_thread(name, prio);
        self.set_program(t, program);
        t
    }

    /// Wake a task at simulation start (or during setup).
    pub fn wake(&mut self, task: TaskId) {
        self.sched.wake(&self.sys, task);
    }

    /// NUMA home of a region (None before first touch).
    pub fn region_home(&self, r: RegionId) -> Option<usize> {
        self.sys.mem.home(r)
    }

    fn push_event(&mut self, at: u64, cpu: CpuId, kind: u8) {
        self.seq += 1;
        self.queue.push(Reverse((at, self.seq, cpu, kind)));
    }

    /// Run until every thread terminated (or error on deadlock /
    /// max_time).
    pub fn run(&mut self) -> Result<SimReport> {
        // The simulator multiplexes every virtual CPU onto this one OS
        // thread, so the run loop re-points the fast-lane owner context
        // (see [`crate::rq::owner`]) at each event's CPU: scheduler code
        // running "on" a virtual CPU is that CPU's runqueue owner,
        // exactly like a native worker pinned to it — the simulator
        // exercises the same lock-free push/pop paths.
        let out = self.run_inner();
        crate::rq::owner::set_current_cpu(None);
        out
    }

    fn run_inner(&mut self) -> Result<SimReport> {
        for cpu in 0..self.sys.topo.n_cpus() {
            self.push_event(0, CpuId(cpu), 0);
        }
        let mut idle_streak = 0usize;
        while let Some(Reverse((at, _seq, cpu, kind))) = self.queue.pop() {
            self.now = at;
            self.sys.advance_clock(at);
            crate::rq::owner::set_current_cpu(Some(cpu));
            if at > self.cfg.max_time {
                return Err(Error::Sim(format!("exceeded max_time at {at}")));
            }
            let ev = if kind == 0 { Ev::CpuFree(cpu) } else { Ev::SegmentEnd(cpu) };
            match ev {
                Ev::CpuFree(cpu) => {
                    if self.running[cpu.0].is_some() {
                        continue; // stale event: already running (a
                                  // poke raced the CPU's own free path)
                    }
                    if self.sys.tasks.live_threads() == 0 {
                        continue; // drain
                    }
                    if self.dispatch_on(cpu) {
                        idle_streak = 0;
                    } else {
                        idle_streak += 1;
                        Metrics::inc(&self.sys.metrics.idle_picks);
                        self.sys.rates.on_idle(&self.sys.topo, cpu);
                        Metrics::add(&self.sys.metrics.idle_time, self.cfg.idle_repoll);
                        // Deadlock heuristic: every CPU idling with no
                        // segment in flight and nothing ready.
                        if idle_streak > 4 * self.sys.topo.n_cpus()
                            && self.running.iter().all(|r| r.is_none())
                            && self.sys.rq.total_queued() == 0
                        {
                            return Err(Error::Sim(format!(
                                "deadlock at t={}: all CPUs idle, {} live threads blocked",
                                self.now,
                                self.sys.tasks.live_threads()
                            )));
                        }
                        let at = self.now + self.cfg.idle_repoll;
                        self.push_event(at, cpu, 0);
                    }
                }
                Ev::SegmentEnd(cpu) => {
                    self.segment_end(cpu);
                }
            }
            if self.sys.tasks.live_threads() == 0 && self.running.iter().all(|r| r.is_none()) {
                self.finished_at = self.now;
                break;
            }
        }
        if self.sys.tasks.live_threads() > 0 {
            return Err(Error::Sim(format!(
                "simulation drained with {} live threads",
                self.sys.tasks.live_threads()
            )));
        }
        Ok(SimReport {
            total_time: self.finished_at,
            busy: self.busy.clone(),
            sched: self.sched.name(),
        })
    }

    /// Ask the scheduler for work; start a segment if any. Returns
    /// whether the CPU got work.
    fn dispatch_on(&mut self, cpu: CpuId) -> bool {
        // Time the pick only while tracing. The ns value is *host*
        // tool time (how expensive the pick code itself is) while `at`
        // stays in simulated cycles; the record never feeds back into
        // simulated timing, so seeded runs stay reproducible.
        let pick_t0 = self.sys.trace.enabled().then(std::time::Instant::now);
        let picked = self.sched.pick(&self.sys, cpu);
        if let Some(t0) = pick_t0 {
            let ns = (t0.elapsed().as_nanos() as u64).max(1);
            self.sys.metrics.pick_latency.record(ns);
            let ev = TraceEvent::PickLatency { cpu, ns, hit: picked.is_some() };
            self.sys.trace.emit(self.sys.now(), ev);
        }
        let Some(task) = picked else {
            return false;
        };
        // Resume penalty: cache refill if the thread moved CPUs.
        let prev = self.prev_cpu.get(&task).copied();
        let refill = self.cost.resume_cycles(&self.sys.topo, prev, cpu);
        self.prev_cpu.insert(task, cpu);
        self.start_segment(cpu, task, self.cfg.ctx_switch + refill);
        true
    }

    /// Execute program items from the cursor until a blocking point,
    /// quantum expiry, or termination; schedule the SegmentEnd event.
    /// `lead_in` = fixed cost before work (context switch, refill).
    fn start_segment(&mut self, cpu: CpuId, task: TaskId, lead_in: u64) {
        let mut wall: u64 = lead_in;
        let mut work: u64 = 0;
        let mut budget = self.cfg.quantum;

        // Non-compute items are processed instantly (wake/first-touch),
        // compute accumulates until the quantum; blocking items stop
        // the segment (they are handled at segment end).
        loop {
            let (item, done_in_item) = {
                let (prog, cur) = self.programs.get(&task).expect("thread without program");
                if cur.pc >= prog.items.len() {
                    break; // program over -> terminate at segment end
                }
                (prog.items[cur.pc].clone(), cur.done_in_item)
            };
            match item {
                WorkItem::Compute { cycles, mem_fraction, region } => {
                    let remaining = cycles - done_in_item;
                    let slice = remaining.min(budget);
                    if slice == 0 {
                        break; // quantum exhausted
                    }
                    // The shared touch path (System::touch_region)
                    // resolves the touch — first touch homes, striped
                    // regions rotate, next-touch migrates — and keeps
                    // footprint + local/remote metrics in sync exactly
                    // like the native executor's green-thread touches.
                    let touch = region.map(|r| self.sys.touch_region(r, cpu));
                    let (sib_busy, sib_symb) = self.sibling_state(cpu, task);
                    let ctx = match &touch {
                        Some(t) => ChunkCtx::from_touch(t, mem_fraction, sib_busy, sib_symb),
                        None => ChunkCtx {
                            mem_fraction,
                            region_home: None,
                            last_toucher: None,
                            sibling_busy: sib_busy,
                            sibling_symbiotic: sib_symb,
                        },
                    };
                    wall += self.cost.chunk_cycles(&self.sys.topo, cpu, slice, &ctx);
                    work += slice;
                    budget -= slice;
                    let cur = &mut self.programs.get_mut(&task).unwrap().1;
                    cur.done_in_item += slice;
                    if cur.done_in_item >= cycles {
                        cur.pc += 1;
                        cur.done_in_item = 0;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                WorkItem::Wake(target) => {
                    self.sched.wake(&self.sys, target);
                    // Freshly woken work may be picked by idle CPUs
                    // immediately: poke them.
                    self.poke_idle_cpus();
                    let cur = &mut self.programs.get_mut(&task).unwrap().1;
                    cur.pc += 1;
                }
                WorkItem::Barrier(_) | WorkItem::Join(_) => {
                    // Blocking items end the segment; resolved in
                    // segment_end so that time has advanced past the
                    // compute preceding them.
                    break;
                }
            }
        }

        // Timing noise (see SimConfig::jitter).
        if self.cfg.jitter > 0.0 && wall > 0 {
            let f = 1.0 + self.cfg.jitter * (2.0 * self.rng.f64() - 1.0);
            wall = ((wall as f64) * f).round().max(1.0) as u64;
        }
        self.busy[cpu.0] += wall;
        Metrics::add(&self.sys.metrics.busy_time, wall);
        let _ = work; // raw work is folded into the cost model above
        self.running[cpu.0] = Some(RunningState { task, seg_wall: wall });
        let at = self.now + wall.max(1);
        self.push_event(at, cpu, 1);
    }

    /// Segment completed: resolve what stopped it.
    fn segment_end(&mut self, cpu: CpuId) {
        let Some(run) = self.running[cpu.0].take() else { return };
        let task = run.task;
        debug_assert_eq!(self.sys.tasks.state(task), TaskState::Running { cpu });

        // Timeslice accounting for the finished segment.
        let preempt = self.sched.tick(&self.sys, cpu, task, run.seg_wall);

        let (item, program_over) = {
            let (prog, cur) = self.programs.get(&task).unwrap();
            if cur.pc >= prog.items.len() {
                (None, true)
            } else {
                (Some(prog.items[cur.pc].clone()), false)
            }
        };

        if program_over {
            self.sched.stop(&self.sys, cpu, task, StopReason::Terminate);
            self.on_terminated(task);
            self.push_event(self.now, cpu, 0);
            return;
        }
        if preempt {
            self.sched.stop(&self.sys, cpu, task, StopReason::Preempt);
            self.push_event(self.now, cpu, 0);
            return;
        }
        match item {
            Some(WorkItem::Barrier(b)) => {
                let released = {
                    let bar = &mut self.barriers[b];
                    bar.arrived += 1;
                    if bar.arrived == bar.parties {
                        bar.arrived = 0;
                        let mut out = std::mem::take(&mut bar.waiting);
                        out.push(task);
                        Some(out)
                    } else {
                        bar.waiting.push(task);
                        None
                    }
                };
                // Advance everyone past the barrier item.
                match released {
                    Some(list) => {
                        self.sys.trace.emit(
                            self.now,
                            TraceEvent::BarrierRelease { id: b, waiters: list.len() },
                        );
                        for t in list {
                            let cur = &mut self.programs.get_mut(&t).unwrap().1;
                            cur.pc += 1;
                            if t == task {
                                // Last arriver keeps its CPU: yield so
                                // the scheduler can rebalance.
                                self.sched.stop(&self.sys, cpu, t, StopReason::Yield);
                            } else {
                                self.sched.wake(&self.sys, t);
                            }
                        }
                        self.poke_idle_cpus();
                    }
                    None => {
                        self.sched.stop(&self.sys, cpu, task, StopReason::Block);
                    }
                }
                self.push_event(self.now, cpu, 0);
            }
            Some(WorkItem::Join(target)) => {
                if self.sys.tasks.state(target) == TaskState::Terminated {
                    let cur = &mut self.programs.get_mut(&task).unwrap().1;
                    cur.pc += 1;
                    // Keep running: immediately continue with a fresh
                    // segment (no scheduler round-trip on a satisfied
                    // join).
                    self.sched.stop(&self.sys, cpu, task, StopReason::Yield);
                } else {
                    self.join_waiters.entry(target).or_default().push(task);
                    self.sched.stop(&self.sys, cpu, task, StopReason::Block);
                }
                self.push_event(self.now, cpu, 0);
            }
            Some(WorkItem::Compute { .. }) => {
                // Quantum expired mid-compute: voluntary yield point.
                self.sched.stop(&self.sys, cpu, task, StopReason::Yield);
                self.push_event(self.now, cpu, 0);
            }
            Some(WorkItem::Wake(_)) | None => {
                // Wakes are handled inline in start_segment; reaching
                // here means the segment ended exactly at a Wake —
                // continue.
                self.sched.stop(&self.sys, cpu, task, StopReason::Yield);
                self.push_event(self.now, cpu, 0);
            }
        }
    }

    /// A thread terminated: wake its joiners.
    fn on_terminated(&mut self, task: TaskId) {
        if let Some(waiters) = self.join_waiters.remove(&task) {
            for w in waiters {
                let cur = &mut self.programs.get_mut(&w).unwrap().1;
                cur.pc += 1; // step past the Join item
                self.sched.wake(&self.sys, w);
            }
            self.poke_idle_cpus();
        }
    }

    /// Schedule immediate CpuFree events for idle CPUs (new work may
    /// have appeared). Idle CPUs otherwise wake at their next re-poll.
    fn poke_idle_cpus(&mut self) {
        for cpu in 0..self.running.len() {
            if self.running[cpu].is_none() {
                self.push_event(self.now, CpuId(cpu), 0);
            }
        }
    }

    /// SMT sibling state for the cost model.
    fn sibling_state(&self, cpu: CpuId, task: TaskId) -> (bool, bool) {
        let Some(sib) = self.sys.topo.smt_sibling(cpu) else {
            return (false, false);
        };
        let Some(run) = &self.running[sib.0] else {
            return (false, false);
        };
        let partner = self.sys.tasks.with(task, |t| match &t.kind {
            crate::task::TaskKind::Thread(d) => d.symbiotic,
            _ => None,
        });
        (true, partner == Some(run.task))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{BubbleConfig, BubbleScheduler};
    use crate::topology::{DistanceModel, Topology};

    fn engine(topo: Topology) -> SimEngine {
        let sys = Arc::new(System::new(Arc::new(topo)));
        let sched = Arc::new(BubbleScheduler::new(BubbleConfig::default()));
        SimEngine::new(sys, sched, CostModel::new(DistanceModel::default()), SimConfig::default())
    }

    /// Engine whose scheduler never migrates work (pin-respecting).
    fn engine_pinned(topo: Topology) -> SimEngine {
        let sys = Arc::new(System::new(Arc::new(topo)));
        let sched = Arc::new(BubbleScheduler::new(BubbleConfig {
            thread_steal: false,
            idle_regen: false,
            ..BubbleConfig::default()
        }));
        SimEngine::new(sys, sched, CostModel::new(DistanceModel::default()), SimConfig::default())
    }

    #[test]
    fn single_thread_runs_to_completion() {
        let mut e = engine(Topology::smp(1));
        let t = e.add_thread("solo", 2, Program::new().compute(50_000, 0.0, None));
        e.wake(t);
        let rep = e.run().unwrap();
        assert!(rep.total_time >= 50_000);
        assert_eq!(e.sys.tasks.state(t), TaskState::Terminated);
    }

    #[test]
    fn parallel_speedup_on_smp() {
        // 4 independent threads on 4 CPUs ≈ 1 thread's time.
        let work = 400_000u64;
        let mut seq = engine(Topology::smp(1));
        let t = seq.add_thread("t", 2, Program::new().compute(work, 0.0, None));
        seq.wake(t);
        let t_seq = seq.run().unwrap().total_time;

        let mut par = engine(Topology::smp(4));
        for i in 0..4 {
            let t = par.add_thread(format!("t{i}"), 2, Program::new().compute(work, 0.0, None));
            par.wake(t);
        }
        let t_par = par.run().unwrap().total_time;
        let ratio = t_par as f64 / t_seq as f64;
        assert!(ratio < 1.25, "parallel ratio {ratio}");
    }

    #[test]
    fn barrier_synchronises() {
        let mut e = engine(Topology::smp(2));
        let b = e.alloc_barrier(2);
        // Fast thread + slow thread: both must pass the barrier, and
        // the fast one's post-barrier work happens after the slow one
        // arrives.
        let fast =
            e.add_thread("fast", 2, Program::new().compute(10_000, 0.0, None).barrier(b).compute(10_000, 0.0, None));
        let slow =
            e.add_thread("slow", 2, Program::new().compute(200_000, 0.0, None).barrier(b).compute(10_000, 0.0, None));
        e.wake(fast);
        e.wake(slow);
        let rep = e.run().unwrap();
        assert!(rep.total_time >= 210_000, "{}", rep.total_time);
    }

    #[test]
    fn join_waits_for_child() {
        let mut e = engine(Topology::smp(2));
        let child = e.add_thread("child", 2, Program::new().compute(100_000, 0.0, None));
        let parent = e.add_thread(
            "parent",
            2,
            Program::new().compute(1_000, 0.0, None).wake(child).join(child).compute(1_000, 0.0, None),
        );
        e.wake(parent);
        let rep = e.run().unwrap();
        assert!(rep.total_time >= 101_000);
        assert_eq!(e.sys.tasks.state(child), TaskState::Terminated);
        assert_eq!(e.sys.tasks.state(parent), TaskState::Terminated);
    }

    #[test]
    fn first_touch_homes_region() {
        let mut e = engine_pinned(Topology::numa(2, 2));
        let r = e.alloc_region();
        assert_eq!(e.region_home(r), None);
        let t = e.add_thread("t", 2, Program::new().compute(10_000, 0.5, Some(r)));
        // Force placement towards node 1 by binding the thread's list.
        e.sys.tasks.with(t, |x| x.last_list = Some(e.sys.topo.leaf_of(CpuId(3))));
        e.wake(t);
        e.run().unwrap();
        assert_eq!(e.region_home(r), Some(1));
    }

    #[test]
    fn numa_remote_work_is_slower() {
        // One thread, region pre-homed on node 0; pin thread to node 1.
        let run = |pin_cpu: usize| {
            let mut e = engine_pinned(Topology::numa(2, 1));
            let r = e.alloc_region_on(0);
            let t = e.add_thread("t", 2, Program::new().compute(1_000_000, 0.5, Some(r)));
            e.sys.tasks.with(t, |x| x.last_list = Some(e.sys.topo.leaf_of(CpuId(pin_cpu))));
            e.wake(t);
            e.run().unwrap().total_time
        };
        let local = run(0);
        let remote = run(1);
        let ratio = remote as f64 / local as f64;
        assert!(ratio > 1.5, "NUMA factor not visible: {ratio}");
    }

    #[test]
    fn deadlock_is_detected() {
        let mut e = engine(Topology::smp(2));
        let b = e.alloc_barrier(2); // only one thread will arrive
        let t = e.add_thread("stuck", 2, Program::new().barrier(b));
        e.wake(t);
        let err = e.run().unwrap_err().to_string();
        assert!(err.contains("deadlock"), "{err}");
    }

    #[test]
    fn deterministic_replay() {
        let mk = || {
            let mut e = engine(Topology::numa(2, 2));
            let bar = e.alloc_barrier(4);
            for i in 0..4 {
                let r = e.alloc_region();
                let t = e.add_thread(
                    format!("t{i}"),
                    2,
                    Program::new()
                        .compute(50_000 + i as u64 * 7_000, 0.3, Some(r))
                        .barrier(bar)
                        .compute(30_000, 0.3, Some(r)),
                );
                e.wake(t);
            }
            e.run().unwrap().total_time
        };
        assert_eq!(mk(), mk());
    }

    #[test]
    fn report_utilisation_bounds() {
        let mut e = engine(Topology::smp(2));
        for i in 0..2 {
            let t = e.add_thread(format!("t{i}"), 2, Program::new().compute(100_000, 0.0, None));
            e.wake(t);
        }
        let rep = e.run().unwrap();
        let u = rep.utilisation();
        assert!(u > 0.5 && u <= 1.2, "utilisation {u}");
    }
}
