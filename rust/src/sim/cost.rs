//! Simulation cost model: how long a compute chunk takes on a given CPU.
//!
//! Mechanisms modelled, each traceable to the paper:
//! * **NUMA factor** (§5.2): memory-bound work on a remote node costs
//!   `numa_factor`× ("accessing the memory of its own node is about 3
//!   times faster").
//! * **Migration / cache refill** (§2.2's rationale for affinity
//!   scheduling): a one-time penalty when a thread resumes on a
//!   different CPU, growing with the hierarchical separation.
//! * **SMT contention / symbiosis** (§3.1): a busy sibling slows a CPU
//!   unless the two threads were declared symbiotic.

use crate::topology::{CpuId, DistanceModel, Topology};

/// Inputs describing the state around one compute chunk. The memory
/// side (`region_home`, `last_toucher`) is resolved from the region
/// registry via [`ChunkCtx::from_touch`]; only region-less chunks are
/// built by hand.
#[derive(Debug, Clone, Copy)]
pub struct ChunkCtx {
    /// Fraction of the chunk that is memory-bound (NUMA-sensitive).
    pub mem_fraction: f64,
    /// NUMA home of the region being touched (None = cache-resident).
    pub region_home: Option<usize>,
    /// CPU that last touched the region (cache-line ownership).
    pub last_toucher: Option<CpuId>,
    /// Is the SMT sibling of this CPU busy?
    pub sibling_busy: bool,
    /// Is the sibling's thread a declared symbiotic partner?
    pub sibling_symbiotic: bool,
}

impl ChunkCtx {
    /// Build a chunk context from a registry-resolved touch (see
    /// [`crate::mem::MemState::touch`]): the region's home and previous
    /// toucher come from the registry, not caller-supplied fields.
    pub fn from_touch(
        touch: &crate::mem::Touch,
        mem_fraction: f64,
        sibling_busy: bool,
        sibling_symbiotic: bool,
    ) -> ChunkCtx {
        ChunkCtx {
            mem_fraction,
            region_home: Some(touch.home),
            last_toucher: touch.last_toucher,
            sibling_busy,
            sibling_symbiotic,
        }
    }
}

/// Stateless cost evaluator over a machine + distance model.
#[derive(Debug, Clone)]
pub struct CostModel {
    pub dist: DistanceModel,
}

impl CostModel {
    pub fn new(dist: DistanceModel) -> CostModel {
        CostModel { dist }
    }

    /// Wall-cycles needed to execute `cycles` of work on `cpu`.
    pub fn chunk_cycles(&self, topo: &Topology, cpu: CpuId, cycles: u64, ctx: &ChunkCtx) -> u64 {
        let numa_factor = match ctx.region_home {
            Some(home) => self.dist.mem_factor(topo, cpu, home),
            None => 1.0,
        };
        // Cache-line ownership: data last written by a distant CPU
        // costs a transfer surcharge growing with the hierarchical
        // separation (sibling SMT = cheap, other chip/die = expensive).
        let cache_factor = match ctx.last_toucher {
            Some(last) => 1.0 + self.dist.cache_line_penalty * topo.separation(cpu, last) as f64,
            None => 1.0,
        };
        let mem_factor = numa_factor * cache_factor;
        let compute = cycles as f64
            * ((1.0 - ctx.mem_fraction) + ctx.mem_fraction * mem_factor);
        let smt = if ctx.sibling_busy {
            if ctx.sibling_symbiotic {
                self.dist.smt_symbiosis
            } else {
                self.dist.smt_contention
            }
        } else {
            1.0
        };
        (compute / smt).round() as u64
    }

    /// One-time cost of resuming `on` a CPU after last running on
    /// `from` (cache refill across the hierarchy).
    pub fn resume_cycles(&self, topo: &Topology, from: Option<CpuId>, on: CpuId) -> u64 {
        match from {
            Some(f) => self.dist.migration_cycles(topo, f, on),
            None => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Topology;

    fn ctx() -> ChunkCtx {
        ChunkCtx { mem_fraction: 0.4, region_home: Some(0), last_toucher: None, sibling_busy: false, sibling_symbiotic: false }
    }

    #[test]
    fn local_vs_remote_numa() {
        let topo = Topology::numa(4, 4);
        let m = CostModel::new(DistanceModel::default());
        let local = m.chunk_cycles(&topo, CpuId(0), 1000, &ctx());
        let remote = m.chunk_cycles(&topo, CpuId(15), 1000, &ctx());
        assert_eq!(local, 1000);
        // 0.6 + 0.4*3 = 1.8
        assert_eq!(remote, 1800);
    }

    #[test]
    fn pure_compute_ignores_numa() {
        let topo = Topology::numa(2, 2);
        let m = CostModel::new(DistanceModel::default());
        let c = ChunkCtx { mem_fraction: 0.0, ..ctx() };
        assert_eq!(m.chunk_cycles(&topo, CpuId(3), 1000, &c), 1000);
    }

    #[test]
    fn no_region_means_local() {
        let topo = Topology::numa(2, 2);
        let m = CostModel::new(DistanceModel::default());
        let c = ChunkCtx { region_home: None, ..ctx() };
        assert_eq!(m.chunk_cycles(&topo, CpuId(3), 1000, &c), 1000);
    }

    #[test]
    fn smt_contention_and_symbiosis() {
        let topo = Topology::xeon_2x_ht();
        let m = CostModel::new(DistanceModel::default());
        let base = ChunkCtx { mem_fraction: 0.0, region_home: None, last_toucher: None, sibling_busy: false, sibling_symbiotic: false };
        let alone = m.chunk_cycles(&topo, CpuId(0), 1000, &base);
        let contended = m.chunk_cycles(
            &topo,
            CpuId(0),
            1000,
            &ChunkCtx { sibling_busy: true, ..base },
        );
        let symbiotic = m.chunk_cycles(
            &topo,
            CpuId(0),
            1000,
            &ChunkCtx { sibling_busy: true, sibling_symbiotic: true, ..base },
        );
        assert_eq!(alone, 1000);
        assert!(contended > symbiotic && symbiotic > alone);
    }

    #[test]
    fn from_touch_mirrors_registry_state() {
        let t = crate::mem::Touch { home: 2, last_toucher: Some(CpuId(5)), migrated: 0 };
        let ctx = ChunkCtx::from_touch(&t, 0.4, true, false);
        assert_eq!(ctx.region_home, Some(2));
        assert_eq!(ctx.last_toucher, Some(CpuId(5)));
        assert!(ctx.sibling_busy && !ctx.sibling_symbiotic);
    }

    #[test]
    fn resume_penalty_scales() {
        let topo = Topology::numa(2, 2);
        let m = CostModel::new(DistanceModel::default());
        assert_eq!(m.resume_cycles(&topo, None, CpuId(0)), 0);
        assert_eq!(m.resume_cycles(&topo, Some(CpuId(0)), CpuId(0)), 0);
        let near = m.resume_cycles(&topo, Some(CpuId(0)), CpuId(1));
        let far = m.resume_cycles(&topo, Some(CpuId(0)), CpuId(2));
        assert!(far > near);
    }
}
