//! # bubbles — a flexible thread scheduler for hierarchical multiprocessor machines
//!
//! Reproduction of Thibault (2005): the MARCEL *bubble scheduler*.
//!
//! The library has three pillars:
//!
//! * **Models** — [`topology`] models the hierarchical machine as a tree of
//!   levels (machine → NUMA node → die → chip → SMT), [`task`] models the
//!   application as threads grouped into nested *bubbles*, [`rq`] is the
//!   hierarchy of task lists (one runqueue per component of every level),
//!   and [`mem`] models *where the data lives*: a NUMA region registry
//!   plus per-task/per-bubble footprint accounting that memory-aware
//!   policies consult for placement.
//! * **Schedulers** — [`sched`] contains the bubble scheduler (the paper's
//!   contribution: bubbles descend the list hierarchy, burst at their
//!   bursting level, and are regenerated on imbalance or timeslice expiry),
//!   nine baseline schedulers from the paper's related-work section
//!   (SS, GSS, TSS, AFS, LDS, CAFS, HAFS, bound, gang), and the
//!   follow-on policies built on the `sched::core` primitives: the
//!   memory-aware placer (`memaware`), the feedback-driven adaptive
//!   steal scope (`adaptive`), and moldable gangs (`moldable-gang`).
//!   Every policy registers in `sched::factory` and is gated by the
//!   factory-enumerated conformance suite.
//! * **Execution engines** — [`sim`] is a deterministic discrete-event
//!   simulator with a NUMA/cache/SMT cost model (the evaluation substrate:
//!   the paper's Bull NovaScale and Xeon testbeds are simulated per
//!   DESIGN.md §Substitutions), and [`exec`] is a *native* two-level
//!   executor in the image of MARCEL itself: one worker OS thread per
//!   virtual processor running user-level fibers with real context
//!   switches. Both engines drive the same [`sched::Scheduler`] trait.
//!
//! The compute payload of the end-to-end examples (heat conduction and
//! advection, Table 2 of the paper) is AOT-compiled from JAX + Pallas to
//! HLO text at build time and executed through [`runtime`] (PJRT CPU
//! client); python never runs on the request path.
//!
//! Quickstart (mirrors Figure 4 of the paper):
//!
//! ```no_run
//! use bubbles::marcel::Marcel;
//! use bubbles::topology::Topology;
//!
//! let m = Marcel::new(Topology::numa(2, 2));
//! let b = m.bubble_init();
//! let t1 = m.create_dontsched("worker-1");
//! let t2 = m.create_dontsched("worker-2");
//! m.bubble_inserttask(b, t1);
//! m.bubble_inserttask(b, t2);
//! m.wake_up_bubble(b);
//! ```

pub mod apps;
pub mod bench;
pub mod cli;
pub mod config;
pub mod error;
pub mod exec;
pub mod experiments;
pub mod marcel;
pub mod mem;
pub mod metrics;
pub mod rq;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod sim;
pub mod task;
pub mod topology;
pub mod trace;
pub mod util;

pub use error::{Error, Result};
