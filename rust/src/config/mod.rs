//! Configuration system.
//!
//! The `toml`/`serde` crates are not vendored in this environment, so
//! [`toml::parse`] implements the TOML subset the framework needs
//! (sections, key = value with strings / ints / floats / bools / flat
//! arrays, comments), and [`schema`] maps parsed values onto typed
//! experiment configs.

pub mod grid;
pub mod schema;
pub mod toml;

pub use grid::GridSpec;
pub use schema::{ExperimentConfig, MachineConfig, SchedConfig, SchedKind, WorkloadConfig};
pub use toml::{parse, Value};
