//! A small TOML-subset parser.
//!
//! Supported: `[section]` headers (dotted names allowed), `key = value`
//! with strings (`"..."`), integers, floats, booleans, and flat arrays
//! of those; `#` comments; blank lines. Unsupported TOML (multi-line
//! strings, inline tables, dates) is rejected with a line-numbered
//! error.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A parsed TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// Parsed document: keys are `"section.key"` (top-level keys have no
/// section prefix).
pub type Doc = BTreeMap<String, Value>;

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> Result<Doc> {
    let mut doc = Doc::new();
    let mut section = String::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[') {
            let name = name
                .strip_suffix(']')
                .ok_or_else(|| err(ln, "unterminated section header"))?
                .trim();
            if name.is_empty() {
                return Err(err(ln, "empty section name"));
            }
            section = name.to_string();
            continue;
        }
        let eq = line.find('=').ok_or_else(|| err(ln, "expected `key = value`"))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(err(ln, "empty key"));
        }
        let val = parse_value(line[eq + 1..].trim()).map_err(|m| err(ln, &m))?;
        let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
        if doc.insert(full.clone(), val).is_some() {
            return Err(err(ln, &format!("duplicate key `{full}`")));
        }
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // `#` outside a string starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> std::result::Result<Value, String> {
    if s.is_empty() {
        return Err("missing value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        if inner.contains('"') {
            return Err("escaped quotes are not supported".into());
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?.trim();
        if inner.is_empty() {
            return Ok(Value::Array(Vec::new()));
        }
        let items: std::result::Result<Vec<Value>, String> =
            split_array(inner)?.iter().map(|p| parse_value(p.trim())).collect();
        return Ok(Value::Array(items?));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    let cleaned = s.replace('_', "");
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value `{s}`"))
}

/// Split a flat array body on commas (strings may contain commas).
fn split_array(s: &str) -> std::result::Result<Vec<String>, String> {
    let mut parts = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            ',' if !in_str => {
                parts.push(cur.trim().to_string());
                cur.clear();
            }
            '[' | ']' if !in_str => return Err("nested arrays are not supported".into()),
            _ => cur.push(c),
        }
    }
    if in_str {
        return Err("unterminated string in array".into());
    }
    if !cur.trim().is_empty() {
        parts.push(cur.trim().to_string());
    }
    Ok(parts)
}

fn err(line0: usize, msg: &str) -> Error {
    Error::config(format!("line {}: {msg}", line0 + 1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_document() {
        let doc = parse(
            r#"
            # experiment
            title = "table2"
            [machine]
            preset = "numa-4x4"
            numa_factor = 3.0
            [sched]
            kind = "bubble"
            idle_regen = true
            slice = 1_000_000
            levels = ["numa", "core"]
            empty = []
            "#,
        )
        .unwrap();
        assert_eq!(doc["title"], Value::Str("table2".into()));
        assert_eq!(doc["machine.preset"], Value::Str("numa-4x4".into()));
        assert_eq!(doc["machine.numa_factor"], Value::Float(3.0));
        assert_eq!(doc["sched.kind"], Value::Str("bubble".into()));
        assert_eq!(doc["sched.idle_regen"], Value::Bool(true));
        assert_eq!(doc["sched.slice"], Value::Int(1_000_000));
        assert_eq!(
            doc["sched.levels"],
            Value::Array(vec![Value::Str("numa".into()), Value::Str("core".into())])
        );
        assert_eq!(doc["sched.empty"], Value::Array(vec![]));
    }

    #[test]
    fn comments_and_hash_in_string() {
        let doc = parse("x = \"a#b\" # trailing").unwrap();
        assert_eq!(doc["x"], Value::Str("a#b".into()));
    }

    #[test]
    fn negative_and_float_forms() {
        let doc = parse("a = -3\nb = 2.5\nc = 1e3").unwrap();
        assert_eq!(doc["a"], Value::Int(-3));
        assert_eq!(doc["b"], Value::Float(2.5));
        assert_eq!(doc["c"], Value::Float(1000.0));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse("a = 1\nb = @").unwrap_err().to_string();
        assert!(e.contains("line 2"), "{e}");
        assert!(parse("[unclosed").is_err());
        assert!(parse("= 3").is_err());
        assert!(parse("a = 1\na = 2").is_err());
        assert!(parse("x = [1, [2]]").is_err());
    }

    #[test]
    fn accessors() {
        assert_eq!(Value::Int(3).as_float(), Some(3.0));
        assert_eq!(Value::Str("s".into()).as_int(), None);
        assert_eq!(Value::Bool(true).as_bool(), Some(true));
    }
}
