//! Sweep grid specification (`repro sweep --grid spec.toml`).
//!
//! A grid file names one experiment and the axes to sweep:
//!
//! ```toml
//! [grid]
//! experiment = "memcmp"
//! policy     = ["afs", "memaware"]
//! machine    = ["smp-4", "numa-4x4"]
//! seed       = [1, 2]
//!
//! [run]            # constants applied to every cell
//! engine = "sim"
//! smoke  = true
//!
//! [sweep]          # optional runner directives
//! plant_fail = "machine=smp-4 seed=2"   # drill: this cell panics
//! ```
//!
//! `[grid]` arrays are the axes (their cartesian product is the job
//! list), `[run]` scalars ride along on every cell, and
//! `sweep.plant_fail` marks the matching cells as deliberate failures —
//! the `--continue-on-failure` drill the sweep tests exercise. The axis
//! name `policy` maps to the experiments' `scheds` parameter, so grids
//! read in the paper's vocabulary.

use std::collections::BTreeMap;

use super::toml::{self, Value};
use crate::error::{Error, Result};

/// A parsed sweep grid: experiment name, sweep axes (sorted by key),
/// per-cell constants, and the optional planted-failure matcher.
#[derive(Debug, Clone)]
pub struct GridSpec {
    pub experiment: String,
    pub axes: Vec<(String, Vec<String>)>,
    pub extras: Vec<(String, String)>,
    pub plant_fail: Option<Vec<(String, String)>>,
}

/// Grid axes and matchers use the paper's `policy` vocabulary; the
/// experiments take `scheds`.
fn axis_key(name: &str) -> String {
    if name == "policy" {
        "scheds".to_string()
    } else {
        name.to_string()
    }
}

fn scalar(v: &Value) -> Option<String> {
    match v {
        Value::Str(s) => Some(s.clone()),
        Value::Int(i) => Some(i.to_string()),
        Value::Float(f) => Some(format!("{f}")),
        Value::Bool(b) => Some(b.to_string()),
        Value::Array(_) => None,
    }
}

/// Parse a `k=v k=v` matcher string (the `plant_fail` value).
fn parse_matcher(s: &str) -> Result<Vec<(String, String)>> {
    s.split_whitespace()
        .map(|pair| {
            let (k, v) = pair.split_once('=').ok_or_else(|| {
                Error::config(format!("plant_fail needs `k=v` pairs, got `{pair}`"))
            })?;
            Ok((axis_key(k), v.to_string()))
        })
        .collect()
}

impl GridSpec {
    pub fn from_file(path: &str) -> Result<GridSpec> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::config(format!("cannot read grid `{path}`: {e}")))?;
        GridSpec::from_toml(&text)
    }

    pub fn from_toml(text: &str) -> Result<GridSpec> {
        let doc = toml::parse(text)?;
        let mut spec = GridSpec {
            experiment: "memcmp".to_string(),
            axes: Vec::new(),
            extras: Vec::new(),
            plant_fail: None,
        };
        for (key, val) in &doc {
            if key == "grid.experiment" {
                spec.experiment = val
                    .as_str()
                    .ok_or_else(|| Error::config("grid.experiment must be a string"))?
                    .to_string();
            } else if let Some(name) = key.strip_prefix("grid.") {
                let arr = val.as_array().ok_or_else(|| {
                    Error::config(format!("grid axis `{name}` must be an array"))
                })?;
                let values: Vec<String> = arr
                    .iter()
                    .map(|v| {
                        scalar(v).ok_or_else(|| {
                            Error::config(format!("grid axis `{name}` holds a non-scalar value"))
                        })
                    })
                    .collect::<Result<_>>()?;
                if values.is_empty() {
                    return Err(Error::config(format!("grid axis `{name}` is empty")));
                }
                spec.axes.push((axis_key(name), values));
            } else if let Some(name) = key.strip_prefix("run.") {
                let v = scalar(val).ok_or_else(|| {
                    Error::config(format!("run.{name} must be a scalar, not an array"))
                })?;
                spec.extras.push((axis_key(name), v));
            } else if key == "sweep.plant_fail" {
                let s = val
                    .as_str()
                    .ok_or_else(|| Error::config("sweep.plant_fail must be a string"))?;
                spec.plant_fail = Some(parse_matcher(s)?);
            } else {
                return Err(Error::config(format!(
                    "unknown grid key `{key}` (want [grid] axes, [run] constants, \
                     sweep.plant_fail)"
                )));
            }
        }
        if spec.axes.is_empty() {
            return Err(Error::config("grid has no axes (add arrays under [grid])"));
        }
        Ok(spec)
    }

    /// Expand the axes into one parameter map per cell (cartesian
    /// product, `[run]` constants on every cell). Cells matched by the
    /// `plant_fail` matcher carry the `__plant_fail=1` marker the
    /// runner turns into a deliberate panic.
    pub fn jobs(&self) -> Vec<BTreeMap<String, String>> {
        let base: BTreeMap<String, String> = self.extras.iter().cloned().collect();
        let mut out = vec![base];
        for (key, values) in &self.axes {
            let mut next = Vec::with_capacity(out.len() * values.len());
            for cell in &out {
                for v in values {
                    let mut job = cell.clone();
                    job.insert(key.clone(), v.clone());
                    next.push(job);
                }
            }
            out = next;
        }
        if let Some(matcher) = &self.plant_fail {
            for job in &mut out {
                let hit = matcher
                    .iter()
                    .all(|(k, v)| job.get(k).map(String::as_str) == Some(v.as_str()));
                if hit {
                    job.insert("__plant_fail".to_string(), "1".to_string());
                }
            }
        }
        out
    }

    /// Stable one-line identity of the whole grid — the sweep's
    /// content-addressed results directory hashes this.
    pub fn canonical(&self) -> String {
        let mut parts = vec![format!("experiment={}", self.experiment)];
        for (k, vs) in &self.axes {
            parts.push(format!("{k}=[{}]", vs.join(",")));
        }
        for (k, v) in &self.extras {
            parts.push(format!("{k}={v}"));
        }
        if let Some(m) = &self.plant_fail {
            let pairs: Vec<String> = m.iter().map(|(k, v)| format!("{k}={v}")).collect();
            parts.push(format!("plant_fail=[{}]", pairs.join(",")));
        }
        parts.join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GRID: &str = r#"
        [grid]
        experiment = "memcmp"
        policy  = ["afs", "memaware"]
        machine = ["smp-4", "numa-4x4"]
        seed    = [1, 2]

        [run]
        engine = "sim"
        smoke  = true
    "#;

    #[test]
    fn axes_expand_to_the_cartesian_product() {
        let g = GridSpec::from_toml(GRID).unwrap();
        assert_eq!(g.experiment, "memcmp");
        let jobs = g.jobs();
        assert_eq!(jobs.len(), 2 * 2 * 2);
        // policy maps to the experiments' scheds parameter; [run]
        // constants ride on every cell.
        for job in &jobs {
            assert!(job.contains_key("scheds"), "{job:?}");
            assert_eq!(job.get("engine").unwrap(), "sim");
            assert_eq!(job.get("smoke").unwrap(), "true");
        }
        // All cells are distinct.
        let mut keys: Vec<String> = jobs
            .iter()
            .map(|j| {
                j.iter().map(|(k, v)| format!("{k}={v}")).collect::<Vec<_>>().join(" ")
            })
            .collect();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), 8, "cells must be distinct");
    }

    #[test]
    fn plant_fail_marks_only_matching_cells() {
        let spec = format!("{GRID}\n[sweep]\nplant_fail = \"machine=smp-4 seed=2\"\n");
        let g = GridSpec::from_toml(&spec).unwrap();
        let jobs = g.jobs();
        let planted: Vec<_> =
            jobs.iter().filter(|j| j.contains_key("__plant_fail")).collect();
        assert_eq!(planted.len(), 2, "one per policy value");
        for j in &planted {
            assert_eq!(j.get("machine").unwrap(), "smp-4");
            assert_eq!(j.get("seed").unwrap(), "2");
        }
    }

    #[test]
    fn canonical_is_stable_and_covers_the_grid() {
        let a = GridSpec::from_toml(GRID).unwrap().canonical();
        let b = GridSpec::from_toml(GRID).unwrap().canonical();
        assert_eq!(a, b);
        assert!(a.contains("experiment=memcmp"), "{a}");
        assert!(a.contains("scheds=[afs,memaware]"), "{a}");
        assert!(a.contains("engine=sim"), "{a}");
    }

    #[test]
    fn malformed_grids_error_loudly() {
        // A non-array axis.
        assert!(GridSpec::from_toml("[grid]\npolicy = \"afs\"").is_err());
        // An empty axis.
        assert!(GridSpec::from_toml("[grid]\npolicy = []").is_err());
        // No axes at all.
        assert!(GridSpec::from_toml("[run]\nengine = \"sim\"").is_err());
        // Unknown sections.
        assert!(GridSpec::from_toml("[grid]\npolicy = [\"afs\"]\n[warp]\nx = 1").is_err());
        // A malformed matcher.
        let e = GridSpec::from_toml("[grid]\npolicy = [\"afs\"]\n[sweep]\nplant_fail = \"oops\"");
        assert!(e.is_err());
    }
}
