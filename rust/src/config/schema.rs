//! Typed experiment configuration on top of the TOML-subset parser.

use super::toml::{Doc, Value};
use crate::error::{Error, Result};
use crate::sched::BubbleConfig;
use crate::task::BurstLevel;
use crate::topology::{DistanceModel, LevelKind, TopoBuilder, Topology};

/// Machine description: a preset name or explicit levels.
#[derive(Debug, Clone)]
pub struct MachineConfig {
    pub preset: Option<String>,
    /// Explicit `["numa:4", "core:4"]`-style level list.
    pub levels: Vec<(LevelKind, usize)>,
    pub numa_factor: f64,
    pub migration_penalty: u64,
    pub smt_contention: f64,
    pub smt_symbiosis: f64,
    pub cache_line_penalty: f64,
    /// Asymmetric per-node-pair access factors: `machine.numa_matrix`
    /// is an array of `"1.0,1.5,6.0"`-style row strings (one row per
    /// NUMA node, diagonal 1.0). Overrides `numa_factor` where set.
    pub numa_matrix: Option<Vec<Vec<f64>>>,
}

impl Default for MachineConfig {
    fn default() -> Self {
        let d = DistanceModel::default();
        MachineConfig {
            preset: Some("numa-4x4".into()),
            levels: Vec::new(),
            numa_factor: d.numa_factor,
            migration_penalty: d.migration_penalty_per_level,
            smt_contention: d.smt_contention,
            smt_symbiosis: d.smt_symbiosis,
            cache_line_penalty: d.cache_line_penalty,
            numa_matrix: None,
        }
    }
}

impl MachineConfig {
    /// Instantiate the topology. Also the point where the distance
    /// matrix is checked against the machine: a matrix sized for a
    /// different node count would silently half-apply (in-range pairs
    /// priced by the matrix, the rest by the scalar fallback).
    pub fn build_topology(&self) -> Result<Topology> {
        let topo = if let Some(p) = &self.preset {
            Topology::preset(p)
                .ok_or_else(|| Error::config(format!("unknown machine preset `{p}`")))?
        } else {
            if self.levels.is_empty() {
                return Err(Error::config("machine: no preset and no levels"));
            }
            let mut b = TopoBuilder::new("custom");
            for &(kind, arity) in &self.levels {
                b = b.split(kind, arity);
            }
            b.build()?
        };
        if let Some(m) = &self.numa_matrix {
            if m.len() != topo.n_numa() {
                return Err(Error::config(format!(
                    "numa_matrix is {}x{} but machine `{}` has {} NUMA nodes",
                    m.len(),
                    m.len(),
                    topo.name(),
                    topo.n_numa()
                )));
            }
        }
        Ok(topo)
    }

    /// Instantiate the cost distances.
    pub fn distance_model(&self) -> DistanceModel {
        DistanceModel {
            numa_factor: self.numa_factor,
            migration_penalty_per_level: self.migration_penalty,
            smt_contention: self.smt_contention,
            smt_symbiosis: self.smt_symbiosis,
            cache_line_penalty: self.cache_line_penalty,
            numa_matrix: self.numa_matrix.clone(),
        }
    }

    /// Like [`MachineConfig::distance_model`], but resolved against the
    /// built topology: when the config gives no explicit `numa_matrix`
    /// and the machine carries one discovered from `/sys` SLIT
    /// distances (`--machine detect`), the detected matrix prices
    /// remote access. An explicit config matrix always wins.
    pub fn distance_model_for(&self, topo: &Topology) -> DistanceModel {
        let mut d = self.distance_model();
        if d.numa_matrix.is_none() {
            if let Some(m) = topo.numa_matrix() {
                if m.len() == topo.n_numa() {
                    d.numa_matrix = Some(m.clone());
                }
            }
        }
        d
    }
}

/// Which scheduler to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedKind {
    Bubble,
    /// Self-Scheduling: single global list (§2.2).
    Ss,
    /// Guided Self-Scheduling.
    Gss,
    /// Trapezoid Self-Scheduling.
    Tss,
    /// Affinity Scheduling: per-CPU lists + steal.
    Afs,
    /// Locality-based Dynamic Scheduling: locality-aware steal.
    Lds,
    /// Clustered AFS: √p groups aligned to NUMA nodes.
    Cafs,
    /// Hierarchical AFS: idle group steals from most loaded group.
    Hafs,
    /// Predetermined binding (§2.1) — the Table-2 "Bound" row.
    Bound,
    /// Memory-aware: place by NUMA footprint ([`crate::mem`]), refuse
    /// costly remote steals (the ForestGOMP direction).
    Memaware,
    /// Ousterhout gang scheduling (§3.1).
    Gang,
    /// Adaptive steal scope (ARMS direction): per-CPU scope widens on
    /// steal failures, narrows with hysteresis on calm epochs.
    Adaptive,
    /// Moldable gangs: gang scheduling that shrinks a gang's CPU set
    /// instead of idling processors (malleable-job direction).
    MoldableGang,
    /// Cross-job fair scheduling for the server mode: per-job gangs
    /// with deadline classes, starvation-driven squeezes, and a
    /// static-partition baseline ([`crate::serve`]).
    JobFair,
}

impl SchedKind {
    /// Parse a policy name or alias via the registry
    /// ([`crate::sched::factory`]) — no hardcoded name matches.
    pub fn parse(s: &str) -> Option<SchedKind> {
        crate::sched::factory::lookup(s).map(|e| e.kind)
    }

    pub fn all() -> &'static [SchedKind] {
        &[
            SchedKind::Bubble,
            SchedKind::Ss,
            SchedKind::Gss,
            SchedKind::Tss,
            SchedKind::Afs,
            SchedKind::Lds,
            SchedKind::Cafs,
            SchedKind::Hafs,
            SchedKind::Bound,
            SchedKind::Memaware,
            SchedKind::Gang,
            SchedKind::Adaptive,
            SchedKind::MoldableGang,
            SchedKind::JobFair,
        ]
    }

    /// Canonical policy name, from the registry.
    pub fn label(&self) -> &'static str {
        crate::sched::factory::info(*self).name
    }

    /// One-line policy description, from the registry.
    pub fn summary(&self) -> &'static str {
        crate::sched::factory::info(*self).summary
    }
}

/// Scheduler tunables.
#[derive(Debug, Clone)]
pub struct SchedConfig {
    pub kind: SchedKind,
    pub burst: BurstLevel,
    pub idle_regen: bool,
    pub thread_steal: bool,
    /// Timeslice in engine units (`sched.timeslice`, 0 = none):
    /// bubble preventive regeneration, gang rotation, and — when set —
    /// moldable-gang rotation when demand exceeds the machine.
    pub timeslice: Option<u64>,
    pub regen_hysteresis: u64,
    /// `adaptive`: consecutive empty picks before a CPU widens its
    /// steal scope one level (`sched.adapt_widen_after`).
    pub adapt_widen_after: u32,
    /// `adaptive`: pick events per narrow-rate decision epoch
    /// (`sched.adapt_epoch`).
    pub adapt_epoch: u32,
    /// `adaptive`: consecutive calm epochs before the scope narrows
    /// one level (`sched.adapt_hysteresis`).
    pub adapt_hysteresis: u32,
    /// `moldable-gang`: consecutive agreeing resize evaluations before
    /// a gang's CPU set shrinks or expands (`sched.resize_hysteresis`).
    pub resize_hysteresis: u32,
    /// The machine's distance model, resolved from the `[machine]`
    /// section by [`ExperimentConfig::from_toml`]; distance-pricing
    /// policies (`memaware`) read it from here instead of assuming the
    /// NovaScale default.
    pub dist: DistanceModel,
}

impl Default for SchedConfig {
    fn default() -> Self {
        let b = BubbleConfig::default();
        let a = crate::sched::AdaptiveConfig::default();
        let m = crate::sched::MoldableConfig::default();
        SchedConfig {
            kind: SchedKind::Bubble,
            burst: b.default_burst,
            idle_regen: b.idle_regen,
            thread_steal: b.thread_steal,
            timeslice: b.default_timeslice,
            regen_hysteresis: b.regen_hysteresis,
            adapt_widen_after: a.widen_after,
            adapt_epoch: a.epoch,
            adapt_hysteresis: a.hysteresis,
            resize_hysteresis: m.resize_hysteresis,
            dist: DistanceModel::default(),
        }
    }
}

impl SchedConfig {
    /// Bubble-scheduler tunables derived from this config.
    pub fn bubble_config(&self) -> BubbleConfig {
        BubbleConfig {
            default_burst: self.burst,
            idle_regen: self.idle_regen,
            thread_steal: self.thread_steal,
            default_timeslice: self.timeslice,
            regen_hysteresis: self.regen_hysteresis,
        }
    }
}

/// Workload selection for `repro run`.
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    /// "conduction", "advection", "fib", "amr".
    pub app: String,
    pub threads: usize,
    pub cycles: usize,
    /// Per-cycle compute cost in simulated cycles per thread.
    pub work: u64,
    /// Memory-bound fraction of the compute (NUMA-sensitive part).
    pub mem_fraction: f64,
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            app: "conduction".into(),
            threads: 16,
            cycles: 100,
            work: 1_000_000,
            mem_fraction: 0.35,
            seed: 1,
        }
    }
}

/// A full experiment file.
#[derive(Debug, Clone, Default)]
pub struct ExperimentConfig {
    pub machine: MachineConfig,
    pub sched: SchedConfig,
    pub workload: WorkloadConfig,
}

impl ExperimentConfig {
    /// Load from TOML text.
    pub fn from_toml(text: &str) -> Result<ExperimentConfig> {
        let doc = super::toml::parse(text)?;
        let mut cfg = ExperimentConfig::default();
        cfg.machine = machine_from(&doc)?;
        cfg.sched = sched_from(&doc)?;
        // Distance-pricing policies see the *machine's* model, not the
        // built-in default (ROADMAP follow-on: memaware reads the real
        // DistanceModel from config).
        cfg.sched.dist = cfg.machine.distance_model();
        cfg.workload = workload_from(&doc)?;
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn from_file(path: &str) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)?;
        ExperimentConfig::from_toml(&text)
    }
}

fn get_str(doc: &Doc, key: &str) -> Option<String> {
    doc.get(key).and_then(|v| v.as_str()).map(|s| s.to_string())
}

fn get_f64(doc: &Doc, key: &str) -> Option<f64> {
    doc.get(key).and_then(|v| v.as_float())
}

fn get_u64(doc: &Doc, key: &str) -> Option<u64> {
    doc.get(key).and_then(|v| v.as_int()).map(|i| i.max(0) as u64)
}

fn get_bool(doc: &Doc, key: &str) -> Option<bool> {
    doc.get(key).and_then(|v| v.as_bool())
}

fn machine_from(doc: &Doc) -> Result<MachineConfig> {
    let mut m = MachineConfig::default();
    if let Some(p) = get_str(doc, "machine.preset") {
        m.preset = Some(p);
    }
    if let Some(Value::Array(levels)) = doc.get("machine.levels") {
        m.preset = None;
        m.levels.clear();
        for v in levels {
            let s = v
                .as_str()
                .ok_or_else(|| Error::config("machine.levels entries must be strings"))?;
            let (kind, arity) = s
                .split_once(':')
                .ok_or_else(|| Error::config(format!("level `{s}` must be `kind:arity`")))?;
            let kind = LevelKind::parse(kind)
                .ok_or_else(|| Error::config(format!("unknown level kind `{kind}`")))?;
            let arity: usize = arity
                .parse()
                .map_err(|_| Error::config(format!("bad arity in `{s}`")))?;
            m.levels.push((kind, arity));
        }
    }
    if get_bool(doc, "machine.detect") == Some(true) {
        // Discover the real machine from `/sys` instead of a canned
        // shape; overrides any preset/levels given alongside.
        m.preset = Some("detect".into());
        m.levels.clear();
    }
    if let Some(f) = get_f64(doc, "machine.numa_factor") {
        m.numa_factor = f;
    }
    if let Some(p) = get_u64(doc, "machine.migration_penalty") {
        m.migration_penalty = p;
    }
    if let Some(f) = get_f64(doc, "machine.smt_contention") {
        m.smt_contention = f;
    }
    if let Some(f) = get_f64(doc, "machine.smt_symbiosis") {
        m.smt_symbiosis = f;
    }
    if let Some(f) = get_f64(doc, "machine.cache_line_penalty") {
        m.cache_line_penalty = f;
    }
    if let Some(Value::Array(rows)) = doc.get("machine.numa_matrix") {
        let mut matrix = Vec::with_capacity(rows.len());
        for row in rows {
            let s = row
                .as_str()
                .ok_or_else(|| Error::config("machine.numa_matrix rows must be strings"))?;
            let parsed: std::result::Result<Vec<f64>, _> =
                s.split(',').map(|x| x.trim().parse::<f64>()).collect();
            matrix.push(parsed.map_err(|_| {
                Error::config(format!("bad numa_matrix row `{s}` (want `1.0,3.0,…`)"))
            })?);
        }
        let n = matrix.len();
        if matrix.iter().any(|r| r.len() != n) {
            return Err(Error::config("machine.numa_matrix must be square"));
        }
        for (i, row) in matrix.iter().enumerate() {
            for (j, &f) in row.iter().enumerate() {
                // Factors are relative to local access: nothing may be
                // cheaper than local, and the diagonal *is* local.
                if !f.is_finite() || f < 1.0 {
                    return Err(Error::config(format!(
                        "numa_matrix[{i}][{j}] = {f}: factors must be finite and >= 1.0"
                    )));
                }
                if i == j && f != 1.0 {
                    return Err(Error::config(format!(
                        "numa_matrix[{i}][{i}] = {f}: the diagonal (local access) must be 1.0"
                    )));
                }
            }
        }
        m.numa_matrix = Some(matrix);
    }
    Ok(m)
}

fn sched_from(doc: &Doc) -> Result<SchedConfig> {
    let mut s = SchedConfig::default();
    if let Some(kind) = get_str(doc, "sched.kind") {
        s.kind = SchedKind::parse(&kind)
            .ok_or_else(|| Error::config(format!("unknown scheduler `{kind}`")))?;
    }
    if let Some(b) = get_str(doc, "sched.burst") {
        s.burst = match b.as_str() {
            "leaf" => BurstLevel::Leaf,
            "immediate" => BurstLevel::Immediate,
            other => {
                if let Some(d) = other.strip_prefix("depth:") {
                    BurstLevel::Depth(
                        d.parse().map_err(|_| Error::config("bad burst depth"))?,
                    )
                } else {
                    BurstLevel::Kind(
                        LevelKind::parse(other)
                            .ok_or_else(|| Error::config(format!("bad burst level `{other}`")))?,
                    )
                }
            }
        };
    }
    if let Some(b) = get_bool(doc, "sched.idle_regen") {
        s.idle_regen = b;
    }
    if let Some(b) = get_bool(doc, "sched.thread_steal") {
        s.thread_steal = b;
    }
    if let Some(t) = get_u64(doc, "sched.timeslice") {
        s.timeslice = if t == 0 { None } else { Some(t) };
    }
    if let Some(h) = get_u64(doc, "sched.regen_hysteresis") {
        s.regen_hysteresis = h;
    }
    if let Some(v) = get_u64(doc, "sched.adapt_widen_after") {
        s.adapt_widen_after = (v.max(1)).min(u32::MAX as u64) as u32;
    }
    if let Some(v) = get_u64(doc, "sched.adapt_epoch") {
        s.adapt_epoch = (v.max(1)).min(u32::MAX as u64) as u32;
    }
    if let Some(v) = get_u64(doc, "sched.adapt_hysteresis") {
        s.adapt_hysteresis = (v.max(1)).min(u32::MAX as u64) as u32;
    }
    if let Some(v) = get_u64(doc, "sched.resize_hysteresis") {
        s.resize_hysteresis = (v.max(1)).min(u32::MAX as u64) as u32;
    }
    Ok(s)
}

fn workload_from(doc: &Doc) -> Result<WorkloadConfig> {
    let mut w = WorkloadConfig::default();
    if let Some(a) = get_str(doc, "workload.app") {
        w.app = a;
    }
    if let Some(t) = get_u64(doc, "workload.threads") {
        w.threads = t as usize;
    }
    if let Some(c) = get_u64(doc, "workload.cycles") {
        w.cycles = c as usize;
    }
    if let Some(wk) = get_u64(doc, "workload.work") {
        w.work = wk;
    }
    if let Some(f) = get_f64(doc, "workload.mem_fraction") {
        w.mem_fraction = f;
    }
    if let Some(sd) = get_u64(doc, "workload.seed") {
        w.seed = sd;
    }
    Ok(w)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_roundtrip() {
        let cfg = ExperimentConfig::from_toml("").unwrap();
        assert_eq!(cfg.sched.kind, SchedKind::Bubble);
        let t = cfg.machine.build_topology().unwrap();
        assert_eq!(t.n_cpus(), 16);
    }

    #[test]
    fn full_file() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            [machine]
            preset = "deep"
            numa_factor = 2.5
            [sched]
            kind = "hafs"
            [workload]
            app = "fib"
            threads = 64
            seed = 9
            "#,
        )
        .unwrap();
        assert_eq!(cfg.sched.kind, SchedKind::Hafs);
        assert_eq!(cfg.machine.build_topology().unwrap().name(), "deep");
        assert_eq!(cfg.workload.threads, 64);
        assert_eq!(cfg.machine.distance_model().numa_factor, 2.5);
    }

    #[test]
    fn explicit_levels() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            [machine]
            levels = ["numa:2", "die:2", "core:2"]
            "#,
        )
        .unwrap();
        let t = cfg.machine.build_topology().unwrap();
        assert_eq!(t.n_cpus(), 8);
        assert_eq!(t.depth(), 4);
    }

    #[test]
    fn burst_level_forms() {
        for (txt, want) in [
            ("leaf", BurstLevel::Leaf),
            ("immediate", BurstLevel::Immediate),
            ("numa", BurstLevel::Kind(LevelKind::NumaNode)),
            ("depth:2", BurstLevel::Depth(2)),
        ] {
            let cfg = ExperimentConfig::from_toml(&format!("[sched]\nburst = \"{txt}\""))
                .unwrap();
            assert_eq!(cfg.sched.burst, want);
        }
    }

    #[test]
    fn bad_values_error() {
        assert!(ExperimentConfig::from_toml("[sched]\nkind = \"nope\"").is_err());
        assert!(ExperimentConfig::from_toml("[machine]\npreset = \"nope\"")
            .unwrap()
            .machine
            .build_topology()
            .is_err());
        assert!(ExperimentConfig::from_toml("[machine]\nlevels = [\"core\"]").is_err());
    }

    #[test]
    fn sched_kind_parse_all() {
        for k in SchedKind::all() {
            assert_eq!(SchedKind::parse(k.label()), Some(*k));
        }
    }

    #[test]
    fn adaptive_and_moldable_knobs_parse() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            [sched]
            kind = "adaptive"
            adapt_widen_after = 4
            adapt_epoch = 16
            adapt_hysteresis = 3
            resize_hysteresis = 2
            "#,
        )
        .unwrap();
        assert_eq!(cfg.sched.kind, SchedKind::Adaptive);
        assert_eq!(cfg.sched.adapt_widen_after, 4);
        assert_eq!(cfg.sched.adapt_epoch, 16);
        assert_eq!(cfg.sched.adapt_hysteresis, 3);
        assert_eq!(cfg.sched.resize_hysteresis, 2);
        assert_eq!(SchedKind::parse("moldable-gang"), Some(SchedKind::MoldableGang));
        assert_eq!(SchedKind::parse("moldable"), Some(SchedKind::MoldableGang));
        assert_eq!(SchedKind::parse("job-fair"), Some(SchedKind::JobFair));
        assert_eq!(SchedKind::parse("jobs"), Some(SchedKind::JobFair));
    }

    #[test]
    fn detect_key_selects_the_detect_preset() {
        let cfg = ExperimentConfig::from_toml("[machine]\ndetect = true").unwrap();
        assert_eq!(cfg.machine.preset.as_deref(), Some("detect"));
        // Detection never fails: it falls back to smp-N when `/sys` is
        // unreadable, so the topology always builds.
        let t = cfg.machine.build_topology().unwrap();
        assert!(t.n_cpus() >= 1);
        // `detect = true` wins over a preset given alongside.
        let cfg = ExperimentConfig::from_toml("[machine]\npreset = \"deep\"\ndetect = true")
            .unwrap();
        assert_eq!(cfg.machine.preset.as_deref(), Some("detect"));
        // `detect = false` is a no-op.
        let cfg = ExperimentConfig::from_toml("[machine]\npreset = \"deep\"\ndetect = false")
            .unwrap();
        assert_eq!(cfg.machine.preset.as_deref(), Some("deep"));
    }

    #[test]
    fn detected_matrix_feeds_the_distance_model() {
        let m = MachineConfig::default();
        let mut topo = Topology::numa(2, 2);
        topo.set_numa_matrix(vec![vec![1.0, 2.5], vec![2.5, 1.0]]);
        // No config matrix → the topology's detected one is used.
        let d = m.distance_model_for(&topo);
        assert_eq!(d.numa_matrix.as_ref().unwrap()[0][1], 2.5);
        // An explicit config matrix always wins over the detected one.
        let explicit = MachineConfig {
            numa_matrix: Some(vec![vec![1.0, 9.0], vec![9.0, 1.0]]),
            ..MachineConfig::default()
        };
        let d = explicit.distance_model_for(&topo);
        assert_eq!(d.numa_matrix.as_ref().unwrap()[0][1], 9.0);
        // A plain preset machine carries no matrix: scalar fallback.
        let d = m.distance_model_for(&Topology::numa(2, 2));
        assert!(d.numa_matrix.is_none());
    }

    #[test]
    fn machine_distance_model_reaches_sched_config() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            [machine]
            preset = "numa-3x1"
            numa_factor = 1.8
            numa_matrix = ["1.0, 1.5, 6.0", "1.5, 1.0, 2.0", "6.0, 2.0, 1.0"]
            [sched]
            kind = "memaware"
            "#,
        )
        .unwrap();
        // The sched section carries the machine's resolved model…
        assert_eq!(cfg.sched.dist.numa_factor, 1.8);
        let m = cfg.sched.dist.numa_matrix.as_ref().expect("matrix parsed");
        assert_eq!(m.len(), 3);
        assert_eq!(m[0][2], 6.0);
        // …and bad matrices are rejected.
        assert!(ExperimentConfig::from_toml(
            "[machine]\nnuma_matrix = [\"1.0, 2.0\"]"
        )
        .is_err());
        assert!(ExperimentConfig::from_toml("[machine]\nnuma_matrix = [\"x\"]").is_err());
        // Sub-local, non-finite and non-unit-diagonal factors error.
        for bad in [
            "[machine]\nnuma_matrix = [\"1.0, -2.0\", \"0.0, 1.0\"]",
            "[machine]\nnuma_matrix = [\"1.0, 0.5\", \"0.5, 1.0\"]",
            "[machine]\nnuma_matrix = [\"2.0, 3.0\", \"3.0, 2.0\"]",
        ] {
            assert!(ExperimentConfig::from_toml(bad).is_err(), "{bad}");
        }
        // A matrix sized for a different machine is caught at topology
        // build time (parsing cannot know the machine yet).
        let mismatched = ExperimentConfig::from_toml(
            "[machine]\npreset = \"numa-4x4\"\nnuma_matrix = [\"1.0, 2.0\", \"2.0, 1.0\"]",
        )
        .unwrap();
        let err = mismatched.machine.build_topology().unwrap_err();
        assert!(err.to_string().contains("NUMA nodes"), "{err}");
    }
}
