//! Scheduler / engine counters.
//!
//! All counters are atomics so both engines (single-threaded simulator,
//! multi-threaded native executor) share the type. The report is the
//! basis of the evaluation tables: remote-access ratio and migrations
//! are what separate *simple* from *bound*/*bubbles* in Table 2.

pub mod hist;

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::fmt::Table;

pub use hist::{Histogram, LatencyHist};

/// Monotonic counters describing one run.
#[derive(Debug, Default)]
pub struct Metrics {
    /// pick() calls that returned a thread.
    pub picks: AtomicU64,
    /// pick() calls that found nothing (idle).
    pub idle_picks: AtomicU64,
    /// Thread resumed on a different CPU than its last one.
    pub migrations: AtomicU64,
    /// Subset of `migrations` that crossed a NUMA-node boundary (the
    /// expensive kind: the thread leaves its memory behind).
    pub cross_node_migrations: AtomicU64,
    /// Compute work items touching memory on the local NUMA node.
    pub local_accesses: AtomicU64,
    /// Compute work items touching remote NUMA memory.
    pub remote_accesses: AtomicU64,
    /// Regions re-homed by next-touch migration (memory followed a
    /// thread, see [`crate::mem`]).
    pub mem_migrations: AtomicU64,
    /// Bytes moved by next-touch migrations.
    pub migrated_bytes: AtomicU64,
    /// Bubbles moved one level down.
    pub bubble_descents: AtomicU64,
    /// Bubble burst events.
    pub bursts: AtomicU64,
    /// Bubble regenerations (idle-triggered + timeslice).
    pub regenerations: AtomicU64,
    /// Tasks stolen across lists by opportunist baselines.
    pub steals: AtomicU64,
    /// Steal searches that found no victim (the signal the adaptive
    /// policy widens its scope on).
    pub steal_fails: AtomicU64,
    /// Adaptive policy: a CPU widened its steal scope one level.
    pub scope_widens: AtomicU64,
    /// Adaptive policy: a CPU narrowed its steal scope one level.
    pub scope_narrows: AtomicU64,
    /// Moldable gangs: a gang's CPU set shrank to a child component.
    pub gang_shrinks: AtomicU64,
    /// Moldable gangs: a gang's CPU set expanded to its parent.
    pub gang_expands: AtomicU64,
    /// Job server: jobs admitted (job root first woken).
    pub jobs_admitted: AtomicU64,
    /// Job server: jobs whose members all terminated.
    pub jobs_completed: AtomicU64,
    /// Job server: cross-job processor reallocations — a starving
    /// deadline class squeezed or rotated another job off its
    /// component (`job-fair` policy).
    pub job_reallocations: AtomicU64,
    /// Threads preempted by timeslice expiry.
    pub preemptions: AtomicU64,
    /// Busy engine-time units summed over CPUs.
    pub busy_time: AtomicU64,
    /// Idle engine-time units summed over CPUs.
    pub idle_time: AtomicU64,
    /// Two-pass search retries (pass-2 lost the race).
    pub search_retries: AtomicU64,
    /// Searches where footprint headroom redirected the choice away
    /// from the plain scan order and the pick/steal went through: the
    /// pressure-aware pass 1 (`sched::core::pick::pass1_pressure`, a
    /// later equal-priority list won on headroom) and the `memaware`
    /// steal tie-break (an equally distant victim on a lower-pressure
    /// node won) both count here.
    pub pressure_redirects: AtomicU64,
    /// Native executor: backoff waits taken by a worker that saw
    /// queued work it could not pick (the policy refused this CPU —
    /// e.g. a moldable gang owning another component). Each wait parks
    /// on the executor condvar under a capped exponential window, so a
    /// busy-polling regression shows up as a blow-up in this counter
    /// (tests bound it).
    pub exec_backoffs: AtomicU64,
    /// Native executor: workers successfully pinned to their vCPU's
    /// detected OS CPU (`--machine detect` + `sched_setaffinity`).
    pub workers_pinned: AtomicU64,
    /// Native executor: workers whose affinity call was denied (e.g.
    /// cgroup-restricted CI) — they run unpinned, semantics unchanged.
    pub pin_failures: AtomicU64,
    /// Native executor: workers that ran a binding-*required* policy
    /// (see [`crate::sched::Scheduler::needs_binding`], the `bound`
    /// row) without OS-level affinity. Nonzero means the bound numbers
    /// describe scheduler-level binding only, not silicon.
    pub bound_unpinned: AtomicU64,
    /// Host-ns latency of `Scheduler::pick` calls (recorded only while
    /// tracing is enabled — the timer itself costs two clock reads).
    pub pick_latency: LatencyHist,
    /// Host-ns latency of steal searches (same gating).
    pub steal_latency: LatencyHist,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Increment helper.
    pub fn inc(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    /// Add helper.
    pub fn add(counter: &AtomicU64, v: u64) {
        counter.fetch_add(v, Ordering::Relaxed);
    }

    /// Fraction of memory touches that were remote (0 when none).
    pub fn remote_ratio(&self) -> f64 {
        let l = self.local_accesses.load(Ordering::Relaxed) as f64;
        let r = self.remote_accesses.load(Ordering::Relaxed) as f64;
        if l + r == 0.0 {
            0.0
        } else {
            r / (l + r)
        }
    }

    /// Fraction of memory touches that hit the local node (0 when
    /// nothing touched memory) — the headline number of the
    /// memory-aware comparison harness.
    pub fn local_ratio(&self) -> f64 {
        let l = self.local_accesses.load(Ordering::Relaxed) as f64;
        let r = self.remote_accesses.load(Ordering::Relaxed) as f64;
        if l + r == 0.0 {
            0.0
        } else {
            l / (l + r)
        }
    }

    /// CPU utilisation = busy / (busy + idle) (0 when nothing ran).
    pub fn utilisation(&self) -> f64 {
        let b = self.busy_time.load(Ordering::Relaxed) as f64;
        let i = self.idle_time.load(Ordering::Relaxed) as f64;
        if b + i == 0.0 {
            0.0
        } else {
            b / (b + i)
        }
    }

    /// Render all counters as a two-column table.
    pub fn report(&self) -> String {
        let g = |c: &AtomicU64| c.load(Ordering::Relaxed).to_string();
        let mut t = Table::new(&["metric", "value"]);
        t.row(&["picks".into(), g(&self.picks)]);
        t.row(&["idle_picks".into(), g(&self.idle_picks)]);
        t.row(&["migrations".into(), g(&self.migrations)]);
        t.row(&["cross_node_migrations".into(), g(&self.cross_node_migrations)]);
        t.row(&["local_accesses".into(), g(&self.local_accesses)]);
        t.row(&["remote_accesses".into(), g(&self.remote_accesses)]);
        t.row(&["remote_ratio".into(), format!("{:.3}", self.remote_ratio())]);
        t.row(&["mem_migrations".into(), g(&self.mem_migrations)]);
        t.row(&["migrated_bytes".into(), g(&self.migrated_bytes)]);
        t.row(&["bubble_descents".into(), g(&self.bubble_descents)]);
        t.row(&["bursts".into(), g(&self.bursts)]);
        t.row(&["regenerations".into(), g(&self.regenerations)]);
        t.row(&["steals".into(), g(&self.steals)]);
        t.row(&["steal_fails".into(), g(&self.steal_fails)]);
        t.row(&["scope_widens".into(), g(&self.scope_widens)]);
        t.row(&["scope_narrows".into(), g(&self.scope_narrows)]);
        t.row(&["gang_shrinks".into(), g(&self.gang_shrinks)]);
        t.row(&["gang_expands".into(), g(&self.gang_expands)]);
        t.row(&["jobs_admitted".into(), g(&self.jobs_admitted)]);
        t.row(&["jobs_completed".into(), g(&self.jobs_completed)]);
        t.row(&["job_reallocations".into(), g(&self.job_reallocations)]);
        t.row(&["preemptions".into(), g(&self.preemptions)]);
        t.row(&["utilisation".into(), format!("{:.3}", self.utilisation())]);
        t.row(&["search_retries".into(), g(&self.search_retries)]);
        t.row(&["pressure_redirects".into(), g(&self.pressure_redirects)]);
        t.row(&["exec_backoffs".into(), g(&self.exec_backoffs)]);
        t.row(&["workers_pinned".into(), g(&self.workers_pinned)]);
        t.row(&["pin_failures".into(), g(&self.pin_failures)]);
        t.row(&["bound_unpinned".into(), g(&self.bound_unpinned)]);
        t.row(&["pick_latency_samples".into(), self.pick_latency.total().to_string()]);
        t.row(&["steal_latency_samples".into(), self.steal_latency.total().to_string()]);
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratios() {
        let m = Metrics::new();
        assert_eq!(m.remote_ratio(), 0.0);
        assert_eq!(m.local_ratio(), 0.0);
        Metrics::add(&m.local_accesses, 3);
        Metrics::add(&m.remote_accesses, 1);
        assert!((m.remote_ratio() - 0.25).abs() < 1e-12);
        assert!((m.local_ratio() - 0.75).abs() < 1e-12);
        Metrics::add(&m.busy_time, 80);
        Metrics::add(&m.idle_time, 20);
        assert!((m.utilisation() - 0.8).abs() < 1e-12);
    }

    #[test]
    fn report_contains_counters() {
        let m = Metrics::new();
        Metrics::inc(&m.bursts);
        let r = m.report();
        assert!(r.contains("bursts"));
        assert!(r.contains("remote_ratio"));
    }
}
