//! Log-bucketed latency histograms.
//!
//! 64 power-of-two buckets: bucket 0 counts the value 0, bucket `i`
//! (1 ≤ i < 63) counts `[2^(i-1), 2^i)`, bucket 63 is open-ended.
//! Two flavours share the bucketing: [`LatencyHist`] is atomic and
//! lives in [`super::Metrics`] for lock-free hot-path recording;
//! [`Histogram`] is a plain value type used by trace analysis.

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of log2 buckets.
pub const BUCKETS: usize = 64;

/// Bucket index for a value (see the module docs for the boundaries).
pub fn bucket_of(v: u64) -> usize {
    ((64 - v.leading_zeros()) as usize).min(BUCKETS - 1)
}

/// `(inclusive lower, exclusive upper)` bound of bucket `i`.
pub fn bucket_bounds(i: usize) -> (u64, u64) {
    match i {
        0 => (0, 1),
        _ if i >= BUCKETS - 1 => (1 << (BUCKETS - 2), u64::MAX),
        _ => (1 << (i - 1), 1 << i),
    }
}

/// Lock-free histogram: one relaxed `fetch_add` per record.
#[derive(Debug)]
pub struct LatencyHist {
    buckets: Box<[AtomicU64]>,
}

impl Default for LatencyHist {
    fn default() -> Self {
        LatencyHist { buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect() }
    }
}

impl LatencyHist {
    pub fn record(&self, v: u64) {
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshot into a plain [`Histogram`].
    pub fn snapshot(&self) -> Histogram {
        Histogram { counts: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect() }
    }

    pub fn total(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }
}

/// Plain (non-atomic) log2 histogram. Empty until first record —
/// `counts` is either empty or `BUCKETS` long.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Histogram {
    counts: Vec<u64>,
}

impl Histogram {
    pub fn record(&mut self, v: u64) {
        if self.counts.is_empty() {
            self.counts = vec![0; BUCKETS];
        }
        self.counts[bucket_of(v)] += 1;
    }

    pub fn from_samples(samples: impl IntoIterator<Item = u64>) -> Histogram {
        let mut h = Histogram::default();
        for s in samples {
            h.record(s);
        }
        h
    }

    pub fn count(&self, bucket: usize) -> u64 {
        self.counts.get(bucket).copied().unwrap_or(0)
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    pub fn is_empty(&self) -> bool {
        self.total() == 0
    }

    /// Value below which `p` (0.0–1.0) of the samples fall, reported
    /// as the matching bucket's exclusive upper bound (`u64::MAX` for
    /// the open last bucket); 0 when empty.
    pub fn percentile(&self, p: f64) -> u64 {
        let total = self.total();
        if total == 0 {
            return 0;
        }
        let target = (p.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                return bucket_bounds(i).1;
            }
        }
        u64::MAX
    }

    /// Compact text rendering of the non-empty buckets.
    pub fn render(&self, label: &str) -> String {
        let total = self.total();
        let mut out = format!("{label}: {total} samples");
        if total == 0 {
            out.push('\n');
            return out;
        }
        out.push_str(&format!(
            " (p50 < {}, p99 < {})\n",
            self.percentile(0.50),
            self.percentile(0.99)
        ));
        let peak = self.counts.iter().copied().max().unwrap_or(0).max(1);
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let (lo, hi) = bucket_bounds(i);
            let bar = "#".repeat((c * 40).div_ceil(peak) as usize);
            out.push_str(&format!("  [{lo:>12}, {hi:>12})  {c:>8}  {bar}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(7), 3);
        assert_eq!(bucket_of(8), 4);
        assert_eq!(bucket_of(1000), 10);
        assert_eq!(bucket_of(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn bounds_cover_the_line() {
        for i in 0..BUCKETS {
            let (lo, hi) = bucket_bounds(i);
            assert!(lo < hi, "bucket {i}");
            assert_eq!(bucket_of(lo), i, "lower bound of bucket {i}");
            if i < BUCKETS - 1 {
                assert_eq!(bucket_of(hi - 1), i, "upper bound of bucket {i}");
                assert_eq!(bucket_bounds(i + 1).0, hi, "buckets {i}/{} adjoin", i + 1);
            }
        }
    }

    #[test]
    fn atomic_and_plain_agree() {
        let samples = [0u64, 1, 2, 3, 4, 7, 8, 1000, 1 << 40];
        let a = LatencyHist::default();
        for &s in &samples {
            a.record(s);
        }
        let p = Histogram::from_samples(samples);
        assert_eq!(a.snapshot(), p);
        assert_eq!(a.total(), samples.len() as u64);
        assert_eq!(p.count(10), 1);
        assert_eq!(p.count(2), 2);
    }

    #[test]
    fn percentile_reports_bucket_upper_bound() {
        let h = Histogram::from_samples([1u64; 99].into_iter().chain([1000]));
        assert_eq!(h.percentile(0.50), 2);
        assert_eq!(h.percentile(0.995), 1024);
        assert_eq!(Histogram::default().percentile(0.5), 0);
        let open = Histogram::from_samples([u64::MAX]);
        assert_eq!(open.percentile(1.0), u64::MAX);
    }

    #[test]
    fn render_mentions_counts() {
        let h = Histogram::from_samples([5u64, 6, 7]);
        let s = h.render("pick");
        assert!(s.contains("3 samples"));
        assert!(s.contains('#'));
        assert!(Histogram::default().render("empty").contains("0 samples"));
    }
}
