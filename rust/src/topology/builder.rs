//! Arity-based construction of machine trees.

use super::{LevelKind, TopoNode, Topology};
use crate::error::{Error, Result};
use crate::topology::level::LevelId;

/// Builds a [`Topology`] from a per-level arity description: e.g.
/// `machine → 4 NUMA nodes → 4 cores` is
/// `TopoBuilder::new("numa-4x4").split(NumaNode, 4).split(Core, 4)`.
///
/// Leaves (the last level) each cover exactly one logical CPU.
#[derive(Debug, Clone)]
pub struct TopoBuilder {
    name: String,
    levels: Vec<(LevelKind, usize)>,
}

impl TopoBuilder {
    /// Start a machine description. The root machine level is implicit.
    pub fn new(name: impl Into<String>) -> TopoBuilder {
        TopoBuilder { name: name.into(), levels: Vec::new() }
    }

    /// Append a level: every component of the previous level gets
    /// `arity` children of `kind`.
    pub fn split(mut self, kind: LevelKind, arity: usize) -> TopoBuilder {
        self.levels.push((kind, arity));
        self
    }

    /// Build the topology tree (BFS component ids, root = 0).
    pub fn build(self) -> Result<Topology> {
        if self.levels.is_empty() {
            return Err(Error::Topology(format!(
                "machine '{}' has no levels below the root",
                self.name
            )));
        }
        for &(kind, arity) in &self.levels {
            if arity == 0 {
                return Err(Error::Topology(format!("level {kind:?} has arity 0")));
            }
            if kind == LevelKind::Machine {
                return Err(Error::Topology("Machine kind is reserved for the root".into()));
            }
        }
        let total_cpus: usize = self.levels.iter().map(|&(_, a)| a).product();

        let mut nodes: Vec<TopoNode> = vec![TopoNode {
            kind: LevelKind::Machine,
            parent: None,
            children: Vec::new(),
            depth: 0,
            cpu_first: 0,
            cpu_count: total_cpus,
        }];
        // BFS level by level.
        let mut frontier = vec![0usize]; // node indices of previous level
        let mut span = total_cpus; // cpus per component at previous level
        for (depth, &(kind, arity)) in self.levels.iter().enumerate() {
            let child_span = span / arity;
            debug_assert!(span % arity == 0);
            let mut next = Vec::with_capacity(frontier.len() * arity);
            for &p in &frontier {
                let base = nodes[p].cpu_first;
                for k in 0..arity {
                    let id = nodes.len();
                    nodes.push(TopoNode {
                        kind,
                        parent: Some(LevelId(p)),
                        children: Vec::new(),
                        depth: depth + 1,
                        cpu_first: base + k * child_span,
                        cpu_count: child_span,
                    });
                    nodes[p].children.push(LevelId(id));
                    next.push(id);
                }
            }
            frontier = next;
            span = child_span;
        }
        Topology::from_parts(self.name, nodes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::CpuId;

    #[test]
    fn builder_counts() {
        let t = TopoBuilder::new("t")
            .split(LevelKind::NumaNode, 2)
            .split(LevelKind::Core, 3)
            .build()
            .unwrap();
        assert_eq!(t.n_cpus(), 6);
        assert_eq!(t.n_components(), 1 + 2 + 6);
    }

    #[test]
    fn cpu_ranges_partition() {
        let t = TopoBuilder::new("t")
            .split(LevelKind::NumaNode, 2)
            .split(LevelKind::Die, 2)
            .split(LevelKind::Core, 2)
            .build()
            .unwrap();
        // Children of any node partition the parent's range.
        for (_, n) in t.components() {
            if n.children.is_empty() {
                continue;
            }
            let mut covered = vec![false; n.cpu_count];
            for &c in &n.children {
                let cn = t.node(c);
                for cpu in cn.cpus() {
                    let idx = cpu.0 - n.cpu_first;
                    assert!(!covered[idx], "overlap at {cpu}");
                    covered[idx] = true;
                }
            }
            assert!(covered.iter().all(|&b| b), "gap under component");
        }
    }

    #[test]
    fn rejects_zero_arity() {
        assert!(TopoBuilder::new("z").split(LevelKind::Core, 0).build().is_err());
    }

    #[test]
    fn rejects_machine_below_root() {
        assert!(TopoBuilder::new("m").split(LevelKind::Machine, 2).build().is_err());
    }

    #[test]
    fn single_cpu_machine() {
        let t = TopoBuilder::new("uni").split(LevelKind::Core, 1).build().unwrap();
        assert_eq!(t.n_cpus(), 1);
        assert_eq!(t.covering(CpuId(0)).len(), 2);
    }
}
