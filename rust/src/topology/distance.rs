//! NUMA distance / memory-access cost factors.
//!
//! The paper's testbed: "For a given processor, accessing the memory of
//! its own node is about 3 times faster than accessing the memory of
//! another node" (§5.2) — the *NUMA factor*.

use super::{CpuId, Topology};

/// Memory-access cost factors for a machine.
#[derive(Debug, Clone)]
pub struct DistanceModel {
    /// Multiplier on memory access time for remote-node access
    /// (1.0 = local). The paper's NovaScale: 3.0.
    pub numa_factor: f64,
    /// One-time cache-refill penalty (cycles) when a thread resumes on a
    /// different core than it last ran on, per level of separation.
    pub migration_penalty_per_level: u64,
    /// Throughput factor for a CPU whose SMT sibling is busy with an
    /// unrelated task (paper §3.1: "Disable HyperThreading!" — naive
    /// co-scheduling can hurt; Bulpin & Pratt measured losses).
    pub smt_contention: f64,
    /// Throughput factor for a CPU whose SMT sibling runs a *symbiotic*
    /// partner thread (paper §3.1 SMT relation: pairs that exploit the
    /// logical processors without interfering).
    pub smt_symbiosis: f64,
    /// Per-level cache-line transfer surcharge on the memory-bound
    /// fraction when data was last touched by a hierarchically distant
    /// CPU (§3.1 "Data sharing": grouping threads that work on the same
    /// data benefits from cache effects even without NUMA).
    pub cache_line_penalty: f64,
    /// Full per-node-pair access-cost matrix (`numa_matrix[from][to]`,
    /// diagonal 1.0): real interconnects are rarely uniform — a
    /// NovaScale-style board has cheap neighbour links and expensive
    /// far hops. When set it overrides the scalar `numa_factor` in
    /// [`DistanceModel::mem_factor`]; `None` keeps the paper's uniform
    /// "~3× remote" model.
    pub numa_matrix: Option<Vec<Vec<f64>>>,
}

impl Default for DistanceModel {
    fn default() -> Self {
        DistanceModel {
            numa_factor: 3.0,
            migration_penalty_per_level: 20_000,
            smt_contention: 0.65,
            smt_symbiosis: 0.95,
            cache_line_penalty: 0.3,
            numa_matrix: None,
        }
    }
}

impl DistanceModel {
    /// Memory cost factor for `cpu` touching data homed on `numa_node`.
    /// Uses the asymmetric matrix when configured, else the scalar
    /// NUMA factor; out-of-range nodes (a matrix smaller than the
    /// machine) fall back to the scalar.
    pub fn mem_factor(&self, topo: &Topology, cpu: CpuId, numa_node: usize) -> f64 {
        let here = topo.numa_of(cpu);
        if let Some(m) = &self.numa_matrix {
            if let Some(f) = m.get(here).and_then(|row| row.get(numa_node)) {
                return *f;
            }
        }
        if here == numa_node {
            1.0
        } else {
            self.numa_factor
        }
    }

    /// Migration penalty in cycles for moving a thread from `from` to
    /// `to` (0 when resuming in place).
    pub fn migration_cycles(&self, topo: &Topology, from: CpuId, to: CpuId) -> u64 {
        self.migration_penalty_per_level * topo.separation(from, to) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_is_unit_remote_is_factor() {
        let t = Topology::numa(4, 4);
        let d = DistanceModel::default();
        assert_eq!(d.mem_factor(&t, CpuId(0), 0), 1.0);
        assert_eq!(d.mem_factor(&t, CpuId(0), 3), 3.0);
        assert_eq!(d.mem_factor(&t, CpuId(15), 3), 1.0);
    }

    #[test]
    fn migration_scales_with_separation() {
        let t = Topology::numa(2, 2);
        let d = DistanceModel::default();
        assert_eq!(d.migration_cycles(&t, CpuId(0), CpuId(0)), 0);
        let near = d.migration_cycles(&t, CpuId(0), CpuId(1));
        let far = d.migration_cycles(&t, CpuId(0), CpuId(3));
        assert!(far > near && near > 0);
    }

    #[test]
    fn asymmetric_matrix_overrides_scalar_factor() {
        let t = Topology::numa(3, 1);
        let d = DistanceModel {
            numa_matrix: Some(vec![
                vec![1.0, 1.5, 6.0],
                vec![1.5, 1.0, 2.0],
                vec![6.0, 2.0, 1.0],
            ]),
            ..DistanceModel::default()
        };
        assert_eq!(d.mem_factor(&t, CpuId(0), 0), 1.0);
        assert_eq!(d.mem_factor(&t, CpuId(0), 1), 1.5, "cheap neighbour link");
        assert_eq!(d.mem_factor(&t, CpuId(0), 2), 6.0, "expensive far hop");
        assert_eq!(d.mem_factor(&t, CpuId(2), 0), 6.0);
        // A matrix smaller than the machine falls back to the scalar.
        let short = DistanceModel {
            numa_matrix: Some(vec![vec![1.0]]),
            ..DistanceModel::default()
        };
        assert_eq!(short.mem_factor(&t, CpuId(0), 2), 3.0);
    }
}
