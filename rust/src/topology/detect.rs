//! Runtime topology discovery from `/sys/devices/system/{cpu,node}`.
//!
//! The paper's portability argument (and the later BubbleSched/hwloc
//! line of work) rests on discovering the hierarchy of the *running*
//! machine instead of hard-coding it. This module parses the Linux
//! sysfs topology files into the existing [`Topology`] model:
//!
//! * `cpu/online` — the cpulist of online CPUs ("0-3,5" style). Offline
//!   CPUs are simply absent from the resulting machine.
//! * `cpu/cpu<N>/topology/{package_id,core_id}` — physical package and
//!   core of each CPU; CPUs sharing a (package, core) pair become SMT
//!   siblings under one [`LevelKind::Core`] component.
//! * `node/node<N>/cpulist` — NUMA node membership. Memory-only nodes
//!   (no online CPUs) are skipped; non-contiguous node ids are fine.
//! * `node/node<N>/distance` — ACPI SLIT distances, normalised by the
//!   diagonal (local access = 1.0) into [`Topology::numa_matrix`].
//!
//! Detected vCPUs are renumbered contiguously in tree order; the
//! original OS CPU ids are kept in [`Topology::os_cpus`] so the native
//! executor can pin each worker with `sched_setaffinity`.
//!
//! **Fallback:** when `/sys` is missing or unreadable (non-Linux hosts,
//! sandboxes, stripped containers), [`Topology::detect`] degrades to a
//! flat `smp-N` machine with `N = available_parallelism()` and an
//! identity OS-CPU map — the run proceeds, just without hierarchy.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use super::{LevelId, LevelKind, TopoNode, Topology};
use crate::error::{Error, Result};

impl Topology {
    /// Discover the running machine. Never fails: a missing or
    /// malformed `/sys` tree falls back to [`Topology::detect_fallback`].
    pub fn detect() -> Topology {
        Topology::detect_from_sysfs(Path::new("/"))
            .unwrap_or_else(|_| Topology::detect_fallback())
    }

    /// The documented fallback when `/sys` is unavailable: a flat
    /// `smp-N` machine sized by `available_parallelism()`, with an
    /// identity vCPU → OS CPU map (best-effort pinning still applies).
    pub fn detect_fallback() -> Topology {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let mut t = Topology::smp(n);
        t.set_os_cpus((0..n).collect());
        t
    }

    /// Parse a sysfs tree rooted at `root` (so golden tests can feed
    /// canned snapshots: the real machine uses `root = "/"`, i.e. the
    /// files live under `<root>/sys/devices/system/...`).
    pub fn detect_from_sysfs(root: &Path) -> Result<Topology> {
        detect_from(root)
    }
}

/// One online CPU as described by sysfs.
struct OsCpu {
    os: usize,
    package: usize,
    core: usize,
}

fn detect_from(root: &Path) -> Result<Topology> {
    let cpu_dir = root.join("sys/devices/system/cpu");
    let online = std::fs::read_to_string(cpu_dir.join("online"))
        .map_err(|e| Error::Topology(format!("cannot read cpu/online: {e}")))?;
    let online = parse_cpulist(online.trim())?;
    if online.is_empty() {
        return Err(Error::Topology("cpu/online lists no CPUs".into()));
    }

    // Per-CPU physical identity. Missing topology files (very old
    // kernels, incomplete snapshots) degrade to one core per CPU.
    let cpus: Vec<OsCpu> = online
        .iter()
        .map(|&os| {
            let t = cpu_dir.join(format!("cpu{os}/topology"));
            OsCpu {
                os,
                package: read_id(&t.join("package_id")).unwrap_or(0),
                core: read_id(&t.join("core_id")).unwrap_or(os),
            }
        })
        .collect();

    // NUMA nodes: sorted OS node ids that hold at least one online CPU.
    // `all_node_ids` keeps memory-only nodes too — distance rows carry
    // one column per *existing* node, so column selection needs them.
    let node_dir = root.join("sys/devices/system/node");
    let mut all_node_ids: Vec<usize> = Vec::new();
    if let Ok(rd) = std::fs::read_dir(&node_dir) {
        for e in rd.flatten() {
            let name = e.file_name().to_string_lossy().into_owned();
            if let Some(num) = name.strip_prefix("node") {
                if let Ok(id) = num.parse::<usize>() {
                    all_node_ids.push(id);
                }
            }
        }
    }
    all_node_ids.sort_unstable();
    let mut node_of: BTreeMap<usize, usize> = BTreeMap::new(); // os cpu -> os node id
    let mut cpu_nodes: Vec<usize> = Vec::new(); // os node ids with online cpus, sorted
    for &id in &all_node_ids {
        let list = match std::fs::read_to_string(node_dir.join(format!("node{id}/cpulist"))) {
            Ok(s) => parse_cpulist(s.trim())?,
            Err(_) => continue,
        };
        let mut holds_cpu = false;
        for os in list {
            if online.contains(&os) {
                node_of.insert(os, id);
                holds_cpu = true;
            }
        }
        if holds_cpu {
            cpu_nodes.push(id);
        }
    }
    // Build the NUMA level only when every online CPU is covered by a
    // node cpulist; a partial map would misplace the stragglers.
    let has_numa = !cpu_nodes.is_empty() && cpus.iter().all(|c| node_of.contains_key(&c.os));

    // Group CPUs: node (tree order) -> (package, core) -> sorted CPUs.
    let groups: Vec<(Option<usize>, Vec<Vec<OsCpu>>)> = if has_numa {
        cpu_nodes
            .iter()
            .map(|&nid| {
                let members: Vec<&OsCpu> =
                    cpus.iter().filter(|c| node_of[&c.os] == nid).collect();
                (Some(nid), group_cores(&members))
            })
            .collect()
    } else {
        vec![(None, group_cores(&cpus.iter().collect::<Vec<_>>()))]
    };

    let total = cpus.len();
    let mut nodes: Vec<TopoNode> = vec![TopoNode {
        kind: LevelKind::Machine,
        parent: None,
        children: Vec::new(),
        depth: 0,
        cpu_first: 0,
        cpu_count: total,
    }];
    let mut os_map: Vec<usize> = Vec::with_capacity(total);
    let mut next_cpu = 0usize;
    for (nid, cores) in &groups {
        let group_total: usize = cores.iter().map(|c| c.len()).sum();
        let (core_parent, core_depth) = if nid.is_some() {
            let i = nodes.len();
            nodes.push(TopoNode {
                kind: LevelKind::NumaNode,
                parent: Some(LevelId(0)),
                children: Vec::new(),
                depth: 1,
                cpu_first: next_cpu,
                cpu_count: group_total,
            });
            nodes[0].children.push(LevelId(i));
            (i, 2)
        } else {
            (0, 1)
        };
        for core_cpus in cores {
            let ci = nodes.len();
            nodes.push(TopoNode {
                kind: LevelKind::Core,
                parent: Some(LevelId(core_parent)),
                children: Vec::new(),
                depth: core_depth,
                cpu_first: next_cpu,
                cpu_count: core_cpus.len(),
            });
            nodes[core_parent].children.push(LevelId(ci));
            if core_cpus.len() == 1 {
                os_map.push(core_cpus[0].os);
                next_cpu += 1;
            } else {
                // SMT: one logical-processor leaf per hardware thread.
                for c in core_cpus {
                    let si = nodes.len();
                    nodes.push(TopoNode {
                        kind: LevelKind::Smt,
                        parent: Some(LevelId(ci)),
                        children: Vec::new(),
                        depth: core_depth + 1,
                        cpu_first: next_cpu,
                        cpu_count: 1,
                    });
                    nodes[ci].children.push(LevelId(si));
                    os_map.push(c.os);
                    next_cpu += 1;
                }
            }
        }
    }

    let mut topo = Topology::from_parts("detect".into(), nodes)?;
    topo.set_os_cpus(os_map);
    if has_numa {
        if let Some(m) = read_distances(&node_dir, &all_node_ids, &cpu_nodes) {
            topo.set_numa_matrix(m);
        }
    }
    Ok(topo)
}

/// Group a node's CPUs into physical cores by (package_id, core_id),
/// cores ordered by that key, CPUs within a core by OS id.
fn group_cores(members: &[&OsCpu]) -> Vec<Vec<OsCpu>> {
    let mut by_core: BTreeMap<(usize, usize), Vec<OsCpu>> = BTreeMap::new();
    for c in members {
        by_core.entry((c.package, c.core)).or_default().push(OsCpu {
            os: c.os,
            package: c.package,
            core: c.core,
        });
    }
    by_core
        .into_values()
        .map(|mut v| {
            v.sort_by_key(|c| c.os);
            v
        })
        .collect()
}

/// Read and normalise the node distance matrix for the CPU-bearing
/// nodes. SLIT rows carry one column per existing node (including
/// memory-only ones), so columns are selected by position in the full
/// sorted node list. Diagonal normalisation makes local access 1.0;
/// anything unreadable or degenerate yields `None` (no matrix — the
/// distance model falls back to its scalar `numa_factor`).
fn read_distances(
    node_dir: &Path,
    all_node_ids: &[usize],
    cpu_nodes: &[usize],
) -> Option<Vec<Vec<f64>>> {
    let cols: Vec<usize> = cpu_nodes
        .iter()
        .map(|id| all_node_ids.iter().position(|x| x == id).unwrap_or(usize::MAX))
        .collect();
    if cols.iter().any(|&c| c == usize::MAX) {
        return None;
    }
    let mut raw: Vec<Vec<f64>> = Vec::with_capacity(cpu_nodes.len());
    for &id in cpu_nodes {
        let s = std::fs::read_to_string(node_dir.join(format!("node{id}/distance"))).ok()?;
        let row: Vec<f64> = s
            .split_whitespace()
            .map(|t| t.parse::<f64>().ok())
            .collect::<Option<_>>()?;
        if row.len() != all_node_ids.len() {
            return None;
        }
        raw.push(cols.iter().map(|&c| row[c]).collect());
    }
    let mut out = Vec::with_capacity(raw.len());
    for (i, row) in raw.iter().enumerate() {
        let diag = row[i];
        if !(diag.is_finite() && diag > 0.0) {
            return None;
        }
        let mut norm: Vec<f64> = row.iter().map(|&d| (d / diag).max(1.0)).collect();
        norm[i] = 1.0;
        out.push(norm);
    }
    Some(out)
}

fn read_id(p: &PathBuf) -> Option<usize> {
    std::fs::read_to_string(p).ok()?.trim().parse().ok()
}

/// Parse the kernel cpulist format: comma-separated decimal ids and
/// inclusive ranges, e.g. `"0-3,5,8-9"`. An empty string is an empty
/// list (memory-only nodes publish exactly that).
fn parse_cpulist(s: &str) -> Result<Vec<usize>> {
    let mut out = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let bad = || Error::Topology(format!("malformed cpulist entry `{part}`"));
        if let Some((a, b)) = part.split_once('-') {
            let a: usize = a.trim().parse().map_err(|_| bad())?;
            let b: usize = b.trim().parse().map_err(|_| bad())?;
            if b < a {
                return Err(bad());
            }
            out.extend(a..=b);
        } else {
            out.push(part.parse().map_err(|_| bad())?);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpulist_parses_ranges_and_singles() {
        assert_eq!(parse_cpulist("0-3,5,8-9").unwrap(), vec![0, 1, 2, 3, 5, 8, 9]);
        assert_eq!(parse_cpulist("0").unwrap(), vec![0]);
        assert_eq!(parse_cpulist("").unwrap(), Vec::<usize>::new());
        assert_eq!(parse_cpulist(" 2 , 4-5 ").unwrap(), vec![2, 4, 5]);
    }

    #[test]
    fn cpulist_rejects_garbage() {
        assert!(parse_cpulist("3-1").is_err());
        assert!(parse_cpulist("a-b").is_err());
        assert!(parse_cpulist("1,x").is_err());
    }

    #[test]
    fn detect_never_panics_and_covers_the_host() {
        let t = Topology::detect();
        assert!(t.n_cpus() >= 1);
        assert_eq!(t.os_cpus().map(|m| m.len()), Some(t.n_cpus()));
    }

    #[test]
    fn fallback_is_flat_smp_with_identity_map() {
        let t = Topology::detect_fallback();
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        assert_eq!(t.n_cpus(), n);
        assert_eq!(t.depth(), 2);
        assert!(t.name().starts_with("smp-"));
        assert_eq!(t.os_cpus().unwrap(), (0..n).collect::<Vec<_>>());
    }
}
