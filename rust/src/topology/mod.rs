//! Hierarchical machine model (paper §3.2, Figure 2).
//!
//! A machine is a tree of *levels*: the whole machine, NUMA nodes, dies
//! (multicore chips), cores (physical SMT processors) and logical SMT
//! processors. Every component of every level owns exactly one task list
//! (see [`crate::rq`]); a task placed on a component's list may run on any
//! CPU *covered* by that component.

mod builder;
mod detect;
mod distance;
mod level;
mod presets;
mod scan;

pub use builder::TopoBuilder;
pub use distance::DistanceModel;
pub use level::{CpuId, LevelId, LevelKind};
pub use scan::ScanOrder;

use crate::error::{Error, Result};

/// One component of one hierarchical level (a node of the machine tree).
#[derive(Debug, Clone)]
pub struct TopoNode {
    /// Which hierarchical level this component belongs to.
    pub kind: LevelKind,
    /// Parent component (None for the machine root).
    pub parent: Option<LevelId>,
    /// Child components (empty for leaves).
    pub children: Vec<LevelId>,
    /// Depth in the tree; the machine root is 0.
    pub depth: usize,
    /// First CPU covered by this component.
    pub cpu_first: usize,
    /// Number of CPUs covered (contiguous range).
    pub cpu_count: usize,
}

impl TopoNode {
    /// Iterate over the CPUs this component covers.
    pub fn cpus(&self) -> impl Iterator<Item = CpuId> + '_ {
        (self.cpu_first..self.cpu_first + self.cpu_count).map(CpuId)
    }

    /// Whether the component covers the CPU.
    pub fn covers(&self, cpu: CpuId) -> bool {
        cpu.0 >= self.cpu_first && cpu.0 < self.cpu_first + self.cpu_count
    }
}

/// The hierarchical machine: a tree of [`TopoNode`]s plus precomputed
/// lookup tables for the scheduler hot path.
#[derive(Debug, Clone)]
pub struct Topology {
    name: String,
    nodes: Vec<TopoNode>,
    /// Leaf component of each CPU.
    cpu_leaf: Vec<LevelId>,
    /// Per CPU: the chain of components covering it, ordered leaf → root.
    covering: Vec<Vec<LevelId>>,
    /// NUMA domain index of each CPU (0 everywhere if no NUMA level).
    numa_of_cpu: Vec<usize>,
    numa_count: usize,
    /// The *other* logical CPU sharing this CPU's core, if SMT.
    smt_sibling: Vec<Option<CpuId>>,
    /// Per-CPU precomputed scan orders (see [`scan`]): the scheduler
    /// hot path reads slices, it never re-walks the tree.
    scan: Vec<ScanOrder>,
    /// vCPU → OS CPU map, present only when the topology was discovered
    /// from the running machine (see [`detect`]). Presets have none.
    os_cpus: Option<Vec<usize>>,
    /// Normalised NUMA distance matrix parsed from `/sys` node
    /// distances (diagonal 1.0), present only on detected topologies.
    numa_dist: Option<Vec<Vec<f64>>>,
}

impl Topology {
    pub(crate) fn from_parts(name: String, nodes: Vec<TopoNode>) -> Result<Topology> {
        if nodes.is_empty() {
            return Err(Error::Topology("empty machine".into()));
        }
        let n_cpus = nodes[0].cpu_count;
        if n_cpus == 0 {
            return Err(Error::Topology("machine with zero CPUs".into()));
        }
        // Leaf of each cpu.
        let mut cpu_leaf = vec![LevelId(usize::MAX); n_cpus];
        for (i, n) in nodes.iter().enumerate() {
            if n.children.is_empty() {
                if n.cpu_count != 1 {
                    return Err(Error::Topology(format!(
                        "leaf component {i} covers {} CPUs; leaves must cover exactly 1",
                        n.cpu_count
                    )));
                }
                cpu_leaf[n.cpu_first] = LevelId(i);
            }
        }
        if cpu_leaf.iter().any(|l| l.0 == usize::MAX) {
            return Err(Error::Topology("some CPU has no leaf component".into()));
        }
        // Covering chains.
        let mut covering = Vec::with_capacity(n_cpus);
        for cpu in 0..n_cpus {
            let mut chain = Vec::new();
            let mut cur = Some(cpu_leaf[cpu]);
            while let Some(l) = cur {
                chain.push(l);
                cur = nodes[l.0].parent;
            }
            covering.push(chain);
        }
        // NUMA domains: components of kind NumaNode, numbered in order.
        let mut numa_of_cpu = vec![0usize; n_cpus];
        let mut numa_count = 0usize;
        for n in &nodes {
            if n.kind == LevelKind::NumaNode {
                for cpu in n.cpus() {
                    numa_of_cpu[cpu.0] = numa_count;
                }
                numa_count += 1;
            }
        }
        if numa_count == 0 {
            numa_count = 1;
        }
        // SMT siblings: CPUs sharing a parent of kind Core with >1 child,
        // or whose leaf kind is Smt.
        let mut smt_sibling = vec![None; n_cpus];
        for n in &nodes {
            let is_smt_parent = n.children.len() > 1
                && n.children.iter().all(|c| nodes[c.0].kind == LevelKind::Smt);
            if is_smt_parent && n.cpu_count == 2 {
                let a = CpuId(n.cpu_first);
                let b = CpuId(n.cpu_first + 1);
                smt_sibling[a.0] = Some(b);
                smt_sibling[b.0] = Some(a);
            }
        }
        let mut topo = Topology {
            name,
            nodes,
            cpu_leaf,
            covering,
            numa_of_cpu,
            numa_count,
            smt_sibling,
            scan: Vec::new(),
            os_cpus: None,
            numa_dist: None,
        };
        topo.scan = scan::build_orders(&topo);
        Ok(topo)
    }

    /// Human-readable machine name (preset name or "custom").
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Number of logical CPUs.
    pub fn n_cpus(&self) -> usize {
        self.cpu_leaf.len()
    }

    /// Number of components (== number of task lists).
    pub fn n_components(&self) -> usize {
        self.nodes.len()
    }

    /// Number of NUMA domains (1 if the machine has no NUMA level).
    pub fn n_numa(&self) -> usize {
        self.numa_count
    }

    /// The machine root component.
    pub fn root(&self) -> LevelId {
        LevelId(0)
    }

    /// Depth of the tree (number of levels).
    pub fn depth(&self) -> usize {
        self.nodes.iter().map(|n| n.depth).max().unwrap_or(0) + 1
    }

    /// Component accessor.
    pub fn node(&self, l: LevelId) -> &TopoNode {
        &self.nodes[l.0]
    }

    /// All components, root first (construction order is BFS-ish).
    pub fn components(&self) -> impl Iterator<Item = (LevelId, &TopoNode)> {
        self.nodes.iter().enumerate().map(|(i, n)| (LevelId(i), n))
    }

    /// Leaf component of a CPU.
    pub fn leaf_of(&self, cpu: CpuId) -> LevelId {
        self.cpu_leaf[cpu.0]
    }

    /// Chain of components covering `cpu`, ordered leaf → root.
    /// This is the list-search order of the scheduler (local → global).
    pub fn covering(&self, cpu: CpuId) -> &[LevelId] {
        &self.covering[cpu.0]
    }

    /// Covering chain of `cpu`, root → leaf (the bubble descent path).
    pub fn descent_order(&self, cpu: CpuId) -> &[LevelId] {
        &self.scan[cpu.0].descent
    }

    /// Every component ordered most-local-first for `cpu`: the covering
    /// chain is the prefix, then non-covering components by distance.
    pub fn locality_order(&self, cpu: CpuId) -> &[LevelId] {
        &self.scan[cpu.0].locality
    }

    /// The other CPUs' leaf components ordered closest-first (steal
    /// victim order, "sibling-by-distance").
    pub fn steal_order(&self, cpu: CpuId) -> &[LevelId] {
        &self.scan[cpu.0].steal
    }

    /// Lowest ancestor-or-self of `from` that covers `cpu` (where work
    /// pulled from `from` towards `cpu` is hoisted to). Precomputed.
    pub fn hoist_towards(&self, from: LevelId, cpu: CpuId) -> LevelId {
        self.scan[cpu.0].hoist[from.0]
    }

    /// NUMA domain of a CPU.
    pub fn numa_of(&self, cpu: CpuId) -> usize {
        self.numa_of_cpu[cpu.0]
    }

    /// SMT sibling of a CPU (the other logical processor on its core).
    pub fn smt_sibling(&self, cpu: CpuId) -> Option<CpuId> {
        self.smt_sibling[cpu.0]
    }

    /// The OS CPU backing a vCPU, when this topology was discovered from
    /// the running machine (`--machine detect`). `None` on presets: a
    /// pretend machine has nothing to pin to.
    pub fn os_cpu(&self, cpu: CpuId) -> Option<usize> {
        self.os_cpus.as_ref().and_then(|m| m.get(cpu.0).copied())
    }

    /// The full vCPU → OS CPU map, if detected.
    pub fn os_cpus(&self) -> Option<&[usize]> {
        self.os_cpus.as_deref()
    }

    /// Normalised NUMA distance matrix (diagonal 1.0) parsed from the
    /// machine's `/sys` node distances, if detected. Indexed by the
    /// topology's own NUMA numbering (see [`Topology::numa_of`]).
    pub fn numa_matrix(&self) -> Option<&Vec<Vec<f64>>> {
        self.numa_dist.as_ref()
    }

    pub(crate) fn set_os_cpus(&mut self, map: Vec<usize>) {
        debug_assert_eq!(map.len(), self.n_cpus());
        self.os_cpus = Some(map);
    }

    pub(crate) fn set_numa_matrix(&mut self, m: Vec<Vec<f64>>) {
        debug_assert_eq!(m.len(), self.numa_count);
        self.numa_dist = Some(m);
    }

    /// The child of `ancestor` that lies on the path towards `cpu`.
    /// Returns None if `ancestor` is the CPU's leaf (nothing deeper).
    pub fn child_towards(&self, ancestor: LevelId, cpu: CpuId) -> Option<LevelId> {
        let chain = self.covering(cpu);
        let pos = chain.iter().position(|&l| l == ancestor)?;
        if pos == 0 {
            None
        } else {
            Some(chain[pos - 1])
        }
    }

    /// Lowest common ancestor of two CPUs.
    pub fn lca(&self, a: CpuId, b: CpuId) -> LevelId {
        let ca = self.covering(a);
        for &l in ca {
            if self.nodes[l.0].covers(b) {
                return l;
            }
        }
        self.root()
    }

    /// Hierarchical separation of two CPUs: 0 for the same CPU, else the
    /// number of levels between a leaf and the lowest common ancestor.
    /// Used by the cost model (cache affinity) and locality-aware steals.
    pub fn separation(&self, a: CpuId, b: CpuId) -> usize {
        if a == b {
            return 0;
        }
        let lca = self.lca(a, b);
        self.nodes[self.cpu_leaf[a.0].0].depth - self.nodes[lca.0].depth
    }

    /// Components of a given kind, in id order.
    pub fn components_of_kind(&self, kind: LevelKind) -> Vec<LevelId> {
        self.components()
            .filter(|(_, n)| n.kind == kind)
            .map(|(l, _)| l)
            .collect()
    }

    /// The deepest level id chain member of `cpu` whose component kind
    /// matches, if any (e.g. the NUMA node component covering a CPU).
    pub fn ancestor_of_kind(&self, cpu: CpuId, kind: LevelKind) -> Option<LevelId> {
        self.covering(cpu).iter().copied().find(|&l| self.nodes[l.0].kind == kind)
    }

    /// Render the tree as an indented diagram (Figure 2 of the paper).
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_node(self.root(), &mut out);
        out
    }

    fn render_node(&self, l: LevelId, out: &mut String) {
        let n = self.node(l);
        out.push_str(&"  ".repeat(n.depth));
        out.push_str(&format!(
            "{:?}[{}] cpus {}..{}\n",
            n.kind,
            l.0,
            n.cpu_first,
            n.cpu_first + n.cpu_count - 1
        ));
        for &c in &n.children {
            self.render_node(c, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numa_4x4_shape() {
        let t = Topology::numa(4, 4);
        assert_eq!(t.n_cpus(), 16);
        assert_eq!(t.n_numa(), 4);
        // 1 machine + 4 nodes + 16 cpu leaves.
        assert_eq!(t.n_components(), 21);
        assert_eq!(t.depth(), 3);
        assert_eq!(t.numa_of(CpuId(0)), 0);
        assert_eq!(t.numa_of(CpuId(15)), 3);
    }

    #[test]
    fn covering_is_leaf_to_root() {
        let t = Topology::numa(2, 2);
        let chain = t.covering(CpuId(3));
        assert_eq!(chain.len(), 3);
        assert_eq!(chain[chain.len() - 1], t.root());
        assert_eq!(t.node(chain[0]).cpu_count, 1);
        // Monotone: each step covers at least as many CPUs.
        for w in chain.windows(2) {
            assert!(t.node(w[1]).cpu_count >= t.node(w[0]).cpu_count);
        }
    }

    #[test]
    fn xeon_has_smt_siblings() {
        let t = Topology::xeon_2x_ht();
        assert_eq!(t.n_cpus(), 4);
        assert_eq!(t.smt_sibling(CpuId(0)), Some(CpuId(1)));
        assert_eq!(t.smt_sibling(CpuId(1)), Some(CpuId(0)));
        assert_eq!(t.smt_sibling(CpuId(2)), Some(CpuId(3)));
    }

    #[test]
    fn numa_machine_has_no_smt() {
        let t = Topology::numa(4, 4);
        assert!((0..16).all(|c| t.smt_sibling(CpuId(c)).is_none()));
    }

    #[test]
    fn deep_machine_matches_figure_2() {
        let t = Topology::deep();
        assert_eq!(t.n_cpus(), 16);
        assert_eq!(t.depth(), 5); // machine, numa, die, core, smt
        assert_eq!(t.n_numa(), 2);
        assert!(t.smt_sibling(CpuId(0)).is_some());
    }

    #[test]
    fn lca_and_separation() {
        let t = Topology::numa(2, 2);
        assert_eq!(t.lca(CpuId(0), CpuId(1)), t.ancestor_of_kind(CpuId(0), LevelKind::NumaNode).unwrap());
        assert_eq!(t.lca(CpuId(0), CpuId(2)), t.root());
        assert_eq!(t.separation(CpuId(0), CpuId(0)), 0);
        assert_eq!(t.separation(CpuId(0), CpuId(1)), 1);
        assert_eq!(t.separation(CpuId(0), CpuId(3)), 2);
    }

    #[test]
    fn child_towards_descends_correctly() {
        let t = Topology::numa(2, 2);
        let root = t.root();
        let step = t.child_towards(root, CpuId(3)).unwrap();
        assert!(t.node(step).covers(CpuId(3)));
        assert_eq!(t.node(step).kind, LevelKind::NumaNode);
        let leaf = t.leaf_of(CpuId(3));
        assert_eq!(t.child_towards(leaf, CpuId(3)), None);
    }

    #[test]
    fn smp_is_two_levels() {
        let t = Topology::smp(8);
        assert_eq!(t.n_cpus(), 8);
        assert_eq!(t.depth(), 2);
        assert_eq!(t.n_numa(), 1);
        assert_eq!(t.n_components(), 9);
    }

    #[test]
    fn render_mentions_all_levels() {
        let t = Topology::deep();
        let r = t.render();
        assert!(r.contains("Machine"));
        assert!(r.contains("NumaNode"));
        assert!(r.contains("Die"));
        assert!(r.contains("Core"));
        assert!(r.contains("Smt"));
    }

    #[test]
    fn rejects_zero_cpus() {
        assert!(TopoBuilder::new("bad").build().is_err());
    }
}
