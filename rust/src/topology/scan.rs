//! Precomputed per-CPU scan orders (the traversal substrate of the
//! scheduling-primitives core, `crate::sched::core`).
//!
//! The scheduler hot path never walks the component tree: every order a
//! policy might scan is computed **once** at topology construction and
//! served as a slice afterwards.
//!
//! Per CPU we precompute:
//!
//! * `descent` — the covering chain root → leaf (the path a bubble rides
//!   down towards the CPU, Figure 3 of the paper);
//! * `locality` — *every* component, most local first: the covering
//!   chain (leaf → root) followed by all non-covering components
//!   ordered by hierarchical distance (how far up the chain one must go
//!   before covering this CPU), ties broken by component id (BFS order,
//!   so shallower siblings come before their descendants);
//! * `steal` — the other CPUs' leaf components ordered by hierarchical
//!   separation (closest victims first, "sibling-by-distance");
//! * `hoist` — for every component `c`, the lowest ancestor-or-self of
//!   `c` that covers this CPU (where a task is hoisted to when this CPU
//!   pulls remote work towards itself).

use super::{CpuId, LevelId, Topology};

/// All precomputed scan orders of one CPU.
#[derive(Debug, Clone)]
pub struct ScanOrder {
    /// Covering chain, root → leaf.
    pub descent: Vec<LevelId>,
    /// Every component, most local first (covering chain is the prefix).
    pub locality: Vec<LevelId>,
    /// Other CPUs' leaf components, closest first.
    pub steal: Vec<LevelId>,
    /// `hoist[c]` = lowest ancestor-or-self of component `c` covering
    /// this CPU (the root always qualifies).
    pub hoist: Vec<LevelId>,
}

/// Build the scan orders for every CPU. Called once from
/// [`Topology::from_parts`]; `topo.scan` itself is not read here.
pub(crate) fn build_orders(topo: &Topology) -> Vec<ScanOrder> {
    (0..topo.n_cpus()).map(|c| build_one(topo, CpuId(c))).collect()
}

fn build_one(topo: &Topology, cpu: CpuId) -> ScanOrder {
    let covering: Vec<LevelId> = topo.covering(cpu).to_vec();
    let descent: Vec<LevelId> = covering.iter().rev().copied().collect();

    // Hoist targets: walk parents until a component covers the CPU.
    let n_comp = topo.n_components();
    let mut hoist = Vec::with_capacity(n_comp);
    for i in 0..n_comp {
        let mut cur = LevelId(i);
        while !topo.node(cur).covers(cpu) {
            match topo.node(cur).parent {
                Some(p) => cur = p,
                None => break,
            }
        }
        hoist.push(cur);
    }

    // Locality: covering chain first, then the rest by (distance, id).
    let leaf_depth = topo.node(topo.leaf_of(cpu)).depth;
    let mut rest: Vec<(usize, usize)> = topo
        .components()
        .filter(|(_, n)| !n.covers(cpu))
        .map(|(l, _)| {
            let anchor = hoist[l.0];
            (leaf_depth - topo.node(anchor).depth, l.0)
        })
        .collect();
    rest.sort_unstable();
    let mut locality = covering;
    locality.extend(rest.into_iter().map(|(_, id)| LevelId(id)));

    // Steal order: other CPUs' leaves, closest (then lowest id) first.
    let mut victims: Vec<(usize, usize)> = (0..topo.n_cpus())
        .filter(|&c| c != cpu.0)
        .map(|c| (topo.separation(cpu, CpuId(c)), c))
        .collect();
    victims.sort_unstable();
    let steal = victims.into_iter().map(|(_, c)| topo.leaf_of(CpuId(c))).collect();

    ScanOrder { descent, locality, steal, hoist }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn locality_prefix_is_covering_chain() {
        let t = Topology::deep();
        for c in 0..t.n_cpus() {
            let cpu = CpuId(c);
            let chain = t.covering(cpu);
            let loc = t.locality_order(cpu);
            assert_eq!(&loc[..chain.len()], chain);
            assert_eq!(loc.len(), t.n_components());
        }
    }

    #[test]
    fn descent_is_reverse_covering() {
        let t = Topology::numa(2, 2);
        for c in 0..t.n_cpus() {
            let cpu = CpuId(c);
            let mut rev: Vec<LevelId> = t.covering(cpu).to_vec();
            rev.reverse();
            assert_eq!(t.descent_order(cpu), &rev[..]);
        }
    }

    #[test]
    fn steal_order_is_distance_sorted() {
        let t = Topology::numa(2, 2);
        let order = t.steal_order(CpuId(0));
        assert_eq!(order.len(), 3);
        // cpu1 (same node) before cpus 2 and 3 (other node).
        assert_eq!(order[0], t.leaf_of(CpuId(1)));
        assert_eq!(order[1], t.leaf_of(CpuId(2)));
        assert_eq!(order[2], t.leaf_of(CpuId(3)));
    }

    #[test]
    fn hoist_reaches_lowest_covering_ancestor() {
        let t = Topology::numa(2, 2);
        let cpu = CpuId(0);
        // Hoisting cpu3's leaf towards cpu0 lands on the root.
        assert_eq!(t.hoist_towards(t.leaf_of(CpuId(3)), cpu), t.root());
        // Hoisting cpu1's leaf towards cpu0 lands on the shared node.
        assert_eq!(t.hoist_towards(t.leaf_of(CpuId(1)), cpu), t.lca(CpuId(0), CpuId(1)));
        // A component already covering the CPU hoists to itself.
        assert_eq!(t.hoist_towards(t.leaf_of(cpu), cpu), t.leaf_of(cpu));
    }
}
