//! Machine presets matching the paper's testbeds.

use super::{LevelId, LevelKind, TopoBuilder, TopoNode, Topology};

impl Topology {
    /// Flat SMP with `n` identical processors (paper §2.2 setting).
    pub fn smp(n: usize) -> Topology {
        TopoBuilder::new(format!("smp-{n}"))
            .split(LevelKind::Core, n)
            .build()
            .expect("smp preset")
    }

    /// ccNUMA with `nodes` NUMA nodes of `cpus_per_node` processors.
    /// `numa(4, 4)` is the paper's Bull NovaScale (16× Itanium II over
    /// 4 NUMA nodes, §5.2 Table 2).
    pub fn numa(nodes: usize, cpus_per_node: usize) -> Topology {
        TopoBuilder::new(format!("numa-{nodes}x{cpus_per_node}"))
            .split(LevelKind::NumaNode, nodes)
            .split(LevelKind::Core, cpus_per_node)
            .build()
            .expect("numa preset")
    }

    /// The paper's Figure-5(a) testbed: a dual Pentium IV Xeon with
    /// HyperThreading — 2 physical chips × 2 logical processors.
    pub fn xeon_2x_ht() -> Topology {
        TopoBuilder::new("xeon-2x-ht")
            .split(LevelKind::Core, 2)
            .split(LevelKind::Smt, 2)
            .build()
            .expect("xeon preset")
    }

    /// The paper's Figure-2 high-depth machine: NUMA nodes of multicore
    /// dies of SMT cores — every level populated.
    /// 2 nodes × 2 dies × 2 cores × 2 SMT = 16 logical CPUs.
    pub fn deep() -> Topology {
        TopoBuilder::new("deep")
            .split(LevelKind::NumaNode, 2)
            .split(LevelKind::Die, 2)
            .split(LevelKind::Core, 2)
            .split(LevelKind::Smt, 2)
            .build()
            .expect("deep preset")
    }

    /// An *asymmetric* machine (real deployments are rarely uniform:
    /// think a big.LITTLE-style part or a partially-populated NUMA
    /// board). Node 0 holds four plain cores; node 1 holds a single
    /// SMT-capable core with two logical CPUs — 6 CPUs total, covering
    /// chains of different lengths. Exercises scan-order precomputation
    /// on non-uniform trees.
    pub fn asym() -> Topology {
        let node = |kind, parent, children, depth, cpu_first, cpu_count| TopoNode {
            kind,
            parent,
            children,
            depth,
            cpu_first,
            cpu_count,
        };
        let l = |i: usize| LevelId(i);
        let nodes = vec![
            // 0: machine root over cpus 0..6
            node(LevelKind::Machine, None, vec![l(1), l(2)], 0, 0, 6),
            // 1: numa node with four single-CPU cores
            node(LevelKind::NumaNode, Some(l(0)), vec![l(3), l(4), l(5), l(6)], 1, 0, 4),
            // 2: numa node with one SMT core
            node(LevelKind::NumaNode, Some(l(0)), vec![l(7)], 1, 4, 2),
            node(LevelKind::Core, Some(l(1)), vec![], 2, 0, 1),
            node(LevelKind::Core, Some(l(1)), vec![], 2, 1, 1),
            node(LevelKind::Core, Some(l(1)), vec![], 2, 2, 1),
            node(LevelKind::Core, Some(l(1)), vec![], 2, 3, 1),
            // 7: SMT-capable core on node 1
            node(LevelKind::Core, Some(l(2)), vec![l(8), l(9)], 2, 4, 2),
            node(LevelKind::Smt, Some(l(7)), vec![], 3, 4, 1),
            node(LevelKind::Smt, Some(l(7)), vec![], 3, 5, 1),
        ];
        Topology::from_parts("asym".into(), nodes).expect("asym preset")
    }

    /// Look a preset up by name (CLI `--machine`). Malformed custom
    /// specs (`smp-0`, `numa-0x4`, trailing garbage) return `None` so
    /// the CLI can error with the preset list instead of building a
    /// zero-CPU machine.
    pub fn preset(name: &str) -> Option<Topology> {
        match name {
            "xeon-2x-ht" | "xeon" => Some(Topology::xeon_2x_ht()),
            "numa-4x4" | "novascale" => Some(Topology::numa(4, 4)),
            "deep" => Some(Topology::deep()),
            "asym" => Some(Topology::asym()),
            "detect" => Some(Topology::detect()),
            _ => {
                if let Some(n) = name.strip_prefix("smp-") {
                    match n.parse::<usize>() {
                        Ok(n) if n > 0 => Some(Topology::smp(n)),
                        _ => None,
                    }
                } else if let Some(spec) = name.strip_prefix("numa-") {
                    let mut it = spec.split('x');
                    let a: usize = it.next()?.parse().ok()?;
                    let b: usize = it.next()?.parse().ok()?;
                    if a == 0 || b == 0 || it.next().is_some() {
                        return None;
                    }
                    Some(Topology::numa(a, b))
                } else {
                    None
                }
            }
        }
    }

    /// Names of the named presets (for CLI help).
    pub fn preset_names() -> &'static [&'static str] {
        &["xeon-2x-ht", "numa-4x4", "deep", "asym", "detect", "smp-<n>", "numa-<a>x<b>"]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_lookup() {
        assert_eq!(Topology::preset("xeon-2x-ht").unwrap().n_cpus(), 4);
        assert_eq!(Topology::preset("numa-4x4").unwrap().n_cpus(), 16);
        assert_eq!(Topology::preset("deep").unwrap().n_cpus(), 16);
        assert_eq!(Topology::preset("smp-12").unwrap().n_cpus(), 12);
        assert_eq!(Topology::preset("numa-2x8").unwrap().n_cpus(), 16);
        assert!(Topology::preset("warp-drive").is_none());
    }

    #[test]
    fn asym_preset_shape() {
        use crate::topology::CpuId;
        let t = Topology::asym();
        assert_eq!(t.n_cpus(), 6);
        assert_eq!(t.n_numa(), 2);
        assert_eq!(t.n_components(), 10);
        // Covering chains have different lengths on the two nodes.
        assert_eq!(t.covering(CpuId(0)).len(), 3);
        assert_eq!(t.covering(CpuId(5)).len(), 4);
        assert!(t.smt_sibling(CpuId(4)).is_some());
        assert!(t.smt_sibling(CpuId(0)).is_none());
    }

    #[test]
    fn preset_rejects_malformed_custom_specs() {
        // Zero CPUs or zero nodes must not build a machine.
        assert!(Topology::preset("smp-0").is_none());
        assert!(Topology::preset("numa-0x4").is_none());
        assert!(Topology::preset("numa-4x0").is_none());
        assert!(Topology::preset("numa-0x0").is_none());
        // Trailing garbage is rejected, not silently ignored.
        assert!(Topology::preset("numa-2x2x2").is_none());
        assert!(Topology::preset("smp-").is_none());
        assert!(Topology::preset("smp-two").is_none());
        assert!(Topology::preset("numa-2x").is_none());
    }

    #[test]
    fn detect_preset_resolves_to_a_usable_machine() {
        let t = Topology::preset("detect").expect("detect never fails");
        assert!(t.n_cpus() >= 1);
        // Detected or fallback, the OS-CPU map is always present so the
        // native executor has something to pin to.
        assert_eq!(t.os_cpus().map(|m| m.len()), Some(t.n_cpus()));
    }

    #[test]
    fn novascale_alias() {
        let t = Topology::preset("novascale").unwrap();
        assert_eq!(t.n_numa(), 4);
        assert_eq!(t.n_cpus(), 16);
    }
}
