//! Identifier types and level kinds for the machine tree.

/// A logical CPU (the paper's "logical SMT processor" — the unit that
/// actually executes threads).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CpuId(pub usize);

/// A component of a hierarchical level (and its task list). The machine
/// root is always `LevelId(0)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct LevelId(pub usize);

/// The hierarchical levels of a machine (paper Figure 2): Russian-doll
/// nesting from the whole machine down to logical SMT processors.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LevelKind {
    /// The whole machine (root; its list holds machine-wide tasks).
    Machine,
    /// A NUMA node: CPUs sharing a local memory bank.
    NumaNode,
    /// A die / multicore chip: cores sharing cache.
    Die,
    /// A physical processor (possibly SMT-capable).
    Core,
    /// A logical SMT processor.
    Smt,
}

impl LevelKind {
    /// Parse from config text.
    pub fn parse(s: &str) -> Option<LevelKind> {
        match s.to_ascii_lowercase().as_str() {
            "machine" => Some(LevelKind::Machine),
            "numa" | "numanode" | "node" => Some(LevelKind::NumaNode),
            "die" | "chip" => Some(LevelKind::Die),
            "core" | "cpu" | "processor" => Some(LevelKind::Core),
            "smt" | "logical" | "ht" => Some(LevelKind::Smt),
            _ => None,
        }
    }

    /// Short label used in traces and rendered topologies.
    pub fn label(&self) -> &'static str {
        match self {
            LevelKind::Machine => "machine",
            LevelKind::NumaNode => "numa",
            LevelKind::Die => "die",
            LevelKind::Core => "core",
            LevelKind::Smt => "smt",
        }
    }
}

impl std::fmt::Display for CpuId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "cpu{}", self.0)
    }
}

impl std::fmt::Display for LevelId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "L{}", self.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for k in [LevelKind::Machine, LevelKind::NumaNode, LevelKind::Die, LevelKind::Core, LevelKind::Smt] {
            assert_eq!(LevelKind::parse(k.label()), Some(k));
        }
        assert_eq!(LevelKind::parse("bogus"), None);
        assert_eq!(LevelKind::parse("NUMA"), Some(LevelKind::NumaNode));
    }

    #[test]
    fn display_forms() {
        assert_eq!(CpuId(3).to_string(), "cpu3");
        assert_eq!(LevelId(0).to_string(), "L0");
    }
}
