//! The previous `BTreeMap`-based task-list layout, kept verbatim as the
//! comparison baseline for `benches/rq_scaling.rs` (old vs. new bucket
//! layout on the pick path). Not used by any scheduler.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicI32, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::task::{Prio, TaskId};
use crate::topology::LevelId;

/// Priority buckets: FIFO within a priority, highest priority first.
#[derive(Debug, Default)]
struct Buckets {
    by_prio: BTreeMap<Prio, VecDeque<TaskId>>,
}

impl Buckets {
    // Empty buckets are *kept* in the map: the yield hot path pushes
    // and pops the same priority class every cycle, and removing the
    // bucket on empty costs a BTreeMap insert + VecDeque allocation
    // per scheduling round.
    fn push(&mut self, task: TaskId, prio: Prio) {
        self.by_prio.entry(prio).or_default().push_back(task);
    }

    fn pop_max(&mut self) -> Option<(TaskId, Prio)> {
        for (&prio, q) in self.by_prio.iter_mut().rev() {
            if let Some(task) = q.pop_front() {
                return Some((task, prio));
            }
        }
        None
    }

    fn max_prio(&self) -> Prio {
        self.by_prio
            .iter()
            .rev()
            .find(|(_, q)| !q.is_empty())
            .map(|(&p, _)| p)
            .unwrap_or(i32::MIN)
    }

    fn remove(&mut self, task: TaskId) -> bool {
        for q in self.by_prio.values_mut() {
            if let Some(pos) = q.iter().position(|&t| t == task) {
                q.remove(pos);
                return true;
            }
        }
        false
    }

    fn len(&self) -> usize {
        self.by_prio.values().map(|q| q.len()).sum()
    }
}

/// The legacy list layout. Same surface as [`super::RunList`] (modulo
/// `remove` not needing the priority), so benchmarks can swap them.
#[derive(Debug)]
pub struct BtreeRunList {
    level: LevelId,
    inner: Mutex<Buckets>,
    max_prio: AtomicI32,
    count: AtomicUsize,
}

impl BtreeRunList {
    pub fn new(level: LevelId) -> BtreeRunList {
        BtreeRunList {
            level,
            inner: Mutex::new(Buckets::default()),
            max_prio: AtomicI32::new(i32::MIN),
            count: AtomicUsize::new(0),
        }
    }

    pub fn level(&self) -> LevelId {
        self.level
    }

    pub fn push(&self, task: TaskId, prio: Prio) {
        let mut b = self.inner.lock().unwrap();
        b.push(task, prio);
        self.max_prio.store(b.max_prio(), Ordering::Release);
        self.count.store(b.len(), Ordering::Release);
    }

    pub fn pop_max(&self) -> Option<(TaskId, Prio)> {
        let mut b = self.inner.lock().unwrap();
        let out = b.pop_max();
        self.max_prio.store(b.max_prio(), Ordering::Release);
        self.count.store(b.len(), Ordering::Release);
        out
    }

    pub fn peek_max(&self) -> Prio {
        self.max_prio.load(Ordering::Acquire)
    }

    pub fn len(&self) -> usize {
        self.count.load(Ordering::Acquire)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn remove(&self, task: TaskId) -> bool {
        let mut b = self.inner.lock().unwrap();
        let hit = b.remove(task);
        self.max_prio.store(b.max_prio(), Ordering::Release);
        self.count.store(b.len(), Ordering::Release);
        hit
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rq::RunList;
    use crate::util::Rng;

    /// Differential check: the bucket-array layout must behave exactly
    /// like the legacy BTreeMap layout — including priorities outside
    /// the bucket range, which saturate into sorted end buckets.
    #[test]
    fn bucket_layout_matches_btree_layout() {
        let mut rng = Rng::new(0x5eed);
        for _ in 0..200 {
            let new = RunList::new(LevelId(0));
            let old = BtreeRunList::new(LevelId(0));
            let mut live: Vec<(TaskId, Prio)> = Vec::new();
            let mut next_id = 0usize;
            for _ in 0..rng.range(1, 60) {
                match rng.below(4) {
                    0 | 1 => {
                        let t = TaskId(next_id);
                        next_id += 1;
                        // Deliberately exceeds the bucket range on both
                        // ends: the layouts must agree even for
                        // saturated priorities.
                        let p = rng.range(0, 300) as Prio - 150;
                        new.push(t, p);
                        old.push(t, p);
                        live.push((t, p));
                    }
                    2 => {
                        let a = new.pop_max();
                        let b = old.pop_max();
                        assert_eq!(a, b);
                        if let Some((t, _)) = a {
                            live.retain(|&(x, _)| x != t);
                        }
                    }
                    _ => {
                        if !live.is_empty() {
                            let (t, p) = live[rng.range(0, live.len())];
                            assert_eq!(new.remove(t, p), old.remove(t));
                            live.retain(|&(x, _)| x != t);
                        }
                    }
                }
                assert_eq!(new.peek_max(), old.peek_max());
                assert_eq!(new.len(), old.len());
            }
            // Drain both and compare total order.
            loop {
                let a = new.pop_max();
                let b = old.pop_max();
                assert_eq!(a, b);
                if a.is_none() {
                    break;
                }
            }
        }
    }
}
