//! A single task list: priority buckets plus an optional lock-free
//! fast lane.
//!
//! The locked tier is a **fixed-size priority-bucket array with an
//! occupancy bitmask**: `pop_max` and `max_prio` are constant-time word
//! scans (find-highest-set-bit over two `u64`s) instead of a
//! `BTreeMap` walk, and `remove` indexes the task's bucket directly
//! instead of scanning every priority class. (The legacy `BtreeRunList`
//! comparison baseline was dropped in PR 5 once `BENCH_rq.json` had a
//! few PRs of history showing the bucket layout winning.)
//!
//! Leaf lists additionally carry a **fast lane** — a Chase-Lev-style
//! deque ([`super::StealDeque`]) owned by the leaf's CPU. See the
//! module docs of [`crate::rq`] for the routing rules; in short: the
//! owner's same-priority (`FAST_LANE_PRIO`) pushes go to the lane and
//! both local picks and remote steals take from its CAS end, while
//! priority outliers, remote pushes, spills from a full ring, and
//! `remove` use the buckets. A full ring spills *in bulk*: the whole
//! lane plus the overflowing task move under one lock acquisition
//! (see [`RunList::push`]), emptying the lane so the next owner push
//! is lock-free again. On a priority *tie* between the tiers the
//! buckets win, so remote-pushed work can never starve behind an
//! owner's push/pop cycle.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicI32, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use super::deque::{StealDeque, FAST_LANE_CAP};
use super::owner;
use crate::task::{Prio, TaskId, PRIO_THREAD};
use crate::topology::{CpuId, LevelId};

/// Lowest priority with its own bucket; anything below saturates here.
pub const PRIO_FLOOR: Prio = -64;
/// Highest priority with its own bucket; anything above saturates here.
pub const PRIO_CEIL: Prio = 63;

/// The one priority class the fast lane serves: ordinary threads. The
/// common yield/requeue/pick cycle is same-priority FIFO (§3.3.3), so
/// this single class covers the contended hot path; everything else is
/// a priority outlier and takes the buckets.
pub const FAST_LANE_PRIO: Prio = PRIO_THREAD;

const N_BUCKETS: usize = (PRIO_CEIL - PRIO_FLOOR + 1) as usize;
const WORDS: usize = N_BUCKETS / 64;

/// Bucket index of a priority. Out-of-range priorities saturate into
/// the end buckets, which are kept *sorted* (see [`Buckets::push`]) so
/// priority ordering stays exact for every `Prio` value — only the
/// rare overflow entries pay an O(bucket-len) insertion.
fn bucket_of(prio: Prio) -> usize {
    (prio.clamp(PRIO_FLOOR, PRIO_CEIL) - PRIO_FLOOR) as usize
}

fn prio_of_bucket(b: usize) -> Prio {
    b as Prio + PRIO_FLOOR
}

/// Priority buckets: FIFO within a priority, highest priority first.
#[derive(Debug)]
struct Buckets {
    /// One FIFO per bucket. Empty `VecDeque`s hold no heap allocation;
    /// the yield hot path reuses the same bucket's buffer every cycle.
    queues: Vec<VecDeque<(TaskId, Prio)>>,
    /// Bit `b` of word `b / 64` set ⇔ bucket `b` is non-empty.
    occupied: [u64; WORDS],
    len: usize,
}

impl Default for Buckets {
    fn default() -> Buckets {
        Buckets {
            queues: (0..N_BUCKETS).map(|_| VecDeque::new()).collect(),
            occupied: [0; WORDS],
            len: 0,
        }
    }
}

impl Buckets {
    fn push(&mut self, task: TaskId, prio: Prio) {
        let b = bucket_of(prio);
        let q = &mut self.queues[b];
        if b == 0 || b == N_BUCKETS - 1 {
            // End buckets may hold *saturated* (out-of-range)
            // priorities: keep them sorted descending, FIFO within a
            // priority, so `pop_front` is still the global max.
            let pos = q.iter().position(|&(_, p)| p < prio).unwrap_or(q.len());
            q.insert(pos, (task, prio));
        } else {
            // Middle buckets hold exactly one priority: plain FIFO.
            q.push_back((task, prio));
        }
        self.occupied[b / 64] |= 1 << (b % 64);
        self.len += 1;
    }

    /// Highest occupied bucket, if any: a constant-time word scan.
    fn max_bucket(&self) -> Option<usize> {
        for w in (0..WORDS).rev() {
            let word = self.occupied[w];
            if word != 0 {
                return Some(w * 64 + 63 - word.leading_zeros() as usize);
            }
        }
        None
    }

    fn pop_max(&mut self) -> Option<(TaskId, Prio)> {
        let b = self.max_bucket()?;
        let out = self.queues[b].pop_front().expect("occupancy bit lied");
        if self.queues[b].is_empty() {
            self.occupied[b / 64] &= !(1 << (b % 64));
        }
        self.len -= 1;
        Some(out)
    }

    fn max_prio(&self) -> Prio {
        match self.max_bucket() {
            // End buckets are sorted: the front carries the exact
            // (possibly out-of-range) maximum. Middle buckets hold a
            // single priority, so the bucket index is exact.
            Some(b) if b == 0 || b == N_BUCKETS - 1 => self.queues[b][0].1,
            Some(b) => prio_of_bucket(b),
            None => i32::MIN,
        }
    }

    /// Remove `task`, whose push priority was `prio`: only that bucket
    /// is scanned. A full sweep remains as a defensive fallback in case
    /// a caller passes a stale priority.
    fn remove(&mut self, task: TaskId, prio: Prio) -> bool {
        let b = bucket_of(prio);
        if self.remove_from_bucket(b, task) {
            return true;
        }
        for other in 0..N_BUCKETS {
            if other != b
                && self.occupied[other / 64] & (1 << (other % 64)) != 0
                && self.remove_from_bucket(other, task)
            {
                return true;
            }
        }
        false
    }

    fn remove_from_bucket(&mut self, b: usize, task: TaskId) -> bool {
        let q = &mut self.queues[b];
        if let Some(pos) = q.iter().position(|&(t, _)| t == task) {
            q.remove(pos);
            if q.is_empty() {
                self.occupied[b / 64] &= !(1 << (b % 64));
            }
            self.len -= 1;
            return true;
        }
        false
    }

    fn len(&self) -> usize {
        self.len
    }
}

/// The lock-free tier of a leaf list plus its traffic counters (the
/// counters let tests assert the lane actually engaged).
#[derive(Debug)]
struct FastLane {
    owner: CpuId,
    deque: StealDeque,
    pushes: AtomicU64,
    pops: AtomicU64,
    /// Spill batches taken (one bucket-lock round-trip each).
    spills: AtomicU64,
    /// Tasks moved to the buckets by those batches.
    spilled: AtomicU64,
}

/// One task list (one topology component's runqueue).
///
/// `max_prio`/`count` are lock-free *hints* maintained under the lock
/// and covering the **bucket tier only**: pass-1 scans may read
/// slightly stale values; pass 2 re-checks under the lock, exactly as
/// the paper's implementation does (§4). [`RunList::peek_max`] and
/// [`RunList::len`] fold the fast lane in, so callers still see the
/// whole list.
#[derive(Debug)]
pub struct RunList {
    level: LevelId,
    inner: Mutex<Buckets>,
    max_prio: AtomicI32,
    count: AtomicUsize,
    fast: Option<FastLane>,
}

impl RunList {
    /// A bucket-only list (interior components, baselines' shared
    /// lists, and the bench's "locked" comparison leg).
    pub fn new(level: LevelId) -> RunList {
        RunList {
            level,
            inner: Mutex::new(Buckets::default()),
            max_prio: AtomicI32::new(i32::MIN),
            count: AtomicUsize::new(0),
            fast: None,
        }
    }

    /// A leaf list with a fast lane owned by `owner` (the leaf's CPU).
    pub fn with_fast_lane(level: LevelId, owner: CpuId) -> RunList {
        let mut l = RunList::new(level);
        l.fast = Some(FastLane {
            owner,
            deque: StealDeque::new(FAST_LANE_CAP),
            pushes: AtomicU64::new(0),
            pops: AtomicU64::new(0),
            spills: AtomicU64::new(0),
            spilled: AtomicU64::new(0),
        });
        l
    }

    /// Which component this list belongs to.
    pub fn level(&self) -> LevelId {
        self.level
    }

    /// The CPU owning this list's fast lane, if it has one.
    pub fn fast_lane_owner(&self) -> Option<CpuId> {
        self.fast.as_ref().map(|f| f.owner)
    }

    /// (pushes, pops) served by the fast lane so far — test/bench
    /// observability.
    pub fn fast_lane_ops(&self) -> (u64, u64) {
        match &self.fast {
            Some(f) => (f.pushes.load(Ordering::Relaxed), f.pops.load(Ordering::Relaxed)),
            None => (0, 0),
        }
    }

    /// (spill batches, tasks spilled) from a full lane into the
    /// buckets. One batch is one bucket-lock round-trip moving the
    /// whole lane plus the overflowing task — tests pin the ratio.
    pub fn fast_lane_spills(&self) -> (u64, u64) {
        match &self.fast {
            Some(f) => (f.spills.load(Ordering::Relaxed), f.spilled.load(Ordering::Relaxed)),
            None => (0, 0),
        }
    }

    /// Enqueue (FIFO within the priority class). An owner-context push
    /// of the fast-lane class goes to the lock-free lane; everything
    /// else — remote pushes, priority outliers, spills from a full
    /// ring — takes the buckets.
    pub fn push(&self, task: TaskId, prio: Prio) {
        if let Some(f) = &self.fast {
            if prio == FAST_LANE_PRIO && owner::current_cpu() == Some(f.owner) {
                match f.deque.push_bottom(task) {
                    Ok(()) => {
                        f.pushes.fetch_add(1, Ordering::Relaxed);
                    }
                    // Ring full: spill the whole lane plus the
                    // overflowing task in one batch (previously one
                    // lock round-trip per overflowed push).
                    Err(task) => self.spill_lane(f, task),
                }
                return;
            }
        }
        self.push_bucket(task, prio);
    }

    /// Lane-overflow slow path: drain the ring through its steal end
    /// (oldest first), append the task that did not fit, and move the
    /// whole batch into the buckets under a *single* lock acquisition.
    /// Emptying the lane makes the very next owner push lock-free
    /// again, and batch order preserves class FIFO: the buckets win
    /// priority ties, and every batched task is older than anything
    /// pushed to the lane afterwards. Concurrent thieves may shrink the
    /// batch mid-drain — they took those tasks, nothing is lost.
    fn spill_lane(&self, f: &FastLane, task: TaskId) {
        let mut batch = Vec::with_capacity(FAST_LANE_CAP + 1);
        f.deque.drain_into(&mut batch);
        batch.push(task);
        let n = batch.len() as u64;
        {
            let mut b = self.inner.lock().unwrap();
            for t in batch {
                b.push(t, FAST_LANE_PRIO);
            }
            self.max_prio.store(b.max_prio(), Ordering::Release);
            self.count.store(b.len(), Ordering::Release);
        }
        f.spills.fetch_add(1, Ordering::Relaxed);
        f.spilled.fetch_add(n, Ordering::Relaxed);
    }

    fn push_bucket(&self, task: TaskId, prio: Prio) {
        let mut b = self.inner.lock().unwrap();
        b.push(task, prio);
        self.max_prio.store(b.max_prio(), Ordering::Release);
        self.count.store(b.len(), Ordering::Release);
    }

    fn pop_bucket(&self) -> Option<(TaskId, Prio)> {
        let mut b = self.inner.lock().unwrap();
        let out = b.pop_max();
        self.max_prio.store(b.max_prio(), Ordering::Release);
        self.count.store(b.len(), Ordering::Release);
        out
    }

    /// Take from the lane's steal end, retrying lost CAS races while
    /// the lane still looks non-empty (bounded: every lost race means
    /// another CPU took an element).
    fn pop_fast(f: &FastLane) -> Option<(TaskId, Prio)> {
        while !f.deque.is_empty() {
            if let Some(t) = f.deque.steal_top() {
                f.pops.fetch_add(1, Ordering::Relaxed);
                return Some((t, FAST_LANE_PRIO));
            }
        }
        None
    }

    /// Dequeue the highest-priority task. The lane is consumed from the
    /// steal (FIFO) end even by the owner, preserving requeue-at-end
    /// class semantics; a priority tie between the tiers goes to the
    /// buckets (remote pushes must not starve).
    pub fn pop_max(&self) -> Option<(TaskId, Prio)> {
        let Some(f) = &self.fast else {
            return self.pop_bucket();
        };
        // Common contended case: buckets (by their hint) hold nothing
        // at or above the lane's class → serve the lane, no lock.
        if self.max_prio.load(Ordering::Acquire) < FAST_LANE_PRIO {
            if let Some(out) = Self::pop_fast(f) {
                return Some(out);
            }
        }
        // Locked tier: pop it only if it genuinely wins (≥ lane class,
        // or the lane is empty — a lower-priority bucket task must not
        // jump ahead of queued lane work).
        let (out, took_bucket) = {
            let mut b = self.inner.lock().unwrap();
            let take = b.max_prio() >= FAST_LANE_PRIO || f.deque.is_empty();
            let out = if take { b.pop_max() } else { None };
            self.max_prio.store(b.max_prio(), Ordering::Release);
            self.count.store(b.len(), Ordering::Release);
            (out, take)
        };
        if out.is_some() {
            return out;
        }
        if let Some(out) = Self::pop_fast(f) {
            return Some(out);
        }
        // The locked tier was deliberately skipped (lane looked
        // non-empty) but thieves emptied the lane first: the bucket
        // item must still come out.
        if took_bucket {
            None
        } else {
            self.pop_bucket()
        }
    }

    /// Max-priority hint; `i32::MIN` when (probably) empty. Lock-free:
    /// the bucket hint folded with the lane's class when the lane is
    /// non-empty. Exact for every priority, including values outside
    /// [`PRIO_FLOOR`, `PRIO_CEIL`] (those live sorted in the end
    /// buckets).
    pub fn peek_max(&self) -> Prio {
        let hint = self.max_prio.load(Ordering::Acquire);
        match &self.fast {
            Some(f) if !f.deque.is_empty() => hint.max(FAST_LANE_PRIO),
            _ => hint,
        }
    }

    /// Lock-free length hint (both tiers).
    pub fn len(&self) -> usize {
        let fast = self.fast.as_ref().map_or(0, |f| f.deque.len());
        self.count.load(Ordering::Acquire) + fast
    }

    /// True when the hint says empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Remove a specific task, given the priority it was pushed with
    /// (tasks carry a fixed `prio`, so callers always know it). Returns
    /// whether it was found. If the buckets miss, the fast lane is
    /// drained through its steal end and the survivors are respilled
    /// into the buckets in FIFO order — `remove` is the regeneration
    /// slow path, so evicting the lane is fine.
    pub fn remove(&self, task: TaskId, prio: Prio) -> bool {
        {
            let mut b = self.inner.lock().unwrap();
            let hit = b.remove(task, prio);
            self.max_prio.store(b.max_prio(), Ordering::Release);
            self.count.store(b.len(), Ordering::Release);
            if hit {
                return true;
            }
        }
        let Some(f) = &self.fast else {
            return false;
        };
        let mut drained = Vec::new();
        f.deque.drain_into(&mut drained);
        if drained.is_empty() {
            return false;
        }
        let mut found = false;
        let mut b = self.inner.lock().unwrap();
        for t in drained {
            if !found && t == task {
                found = true;
            } else {
                b.push(t, FAST_LANE_PRIO);
            }
        }
        self.max_prio.store(b.max_prio(), Ordering::Release);
        self.count.store(b.len(), Ordering::Release);
        found
    }

    /// Copy of the queue contents (tests / traces), in pop order:
    /// bucket tasks at or above the lane class, then the lane (oldest
    /// first), then the rest of the buckets.
    pub fn snapshot(&self) -> Vec<(TaskId, Prio)> {
        let mut out = Vec::new();
        {
            let b = self.inner.lock().unwrap();
            for bk in (0..N_BUCKETS).rev() {
                for &(t, p) in &b.queues[bk] {
                    out.push((t, p));
                }
            }
        }
        if let Some(f) = &self.fast {
            let pos =
                out.iter().position(|&(_, p)| p < FAST_LANE_PRIO).unwrap_or(out.len());
            let lane: Vec<(TaskId, Prio)> =
                f.deque.snapshot().into_iter().map(|t| (t, FAST_LANE_PRIO)).collect();
            out.splice(pos..pos, lane);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    /// Run `f` with the owner context pointing at `cpu`, restoring the
    /// previous context afterwards (tests share OS threads).
    fn as_cpu<R>(cpu: CpuId, f: impl FnOnce() -> R) -> R {
        let prev = owner::current_cpu();
        owner::set_current_cpu(Some(cpu));
        let out = f();
        owner::set_current_cpu(prev);
        out
    }

    #[test]
    fn hint_is_consistent_after_each_op() {
        let l = RunList::new(LevelId(0));
        l.push(TaskId(0), 4);
        assert_eq!(l.peek_max(), 4);
        l.push(TaskId(1), 9);
        assert_eq!(l.peek_max(), 9);
        l.remove(TaskId(1), 9);
        assert_eq!(l.peek_max(), 4);
        l.pop_max();
        assert_eq!(l.peek_max(), i32::MIN);
        assert!(l.is_empty());
    }

    #[test]
    fn negative_priorities_work() {
        let l = RunList::new(LevelId(0));
        l.push(TaskId(0), -5);
        l.push(TaskId(1), -1);
        assert_eq!(l.pop_max(), Some((TaskId(1), -1)));
    }

    #[test]
    fn remove_middle_of_bucket() {
        let l = RunList::new(LevelId(0));
        for i in 0..4 {
            l.push(TaskId(i), 2);
        }
        assert!(l.remove(TaskId(2), 2));
        let order: Vec<TaskId> = std::iter::from_fn(|| l.pop_max().map(|(t, _)| t)).collect();
        assert_eq!(order, vec![TaskId(0), TaskId(1), TaskId(3)]);
    }

    #[test]
    fn remove_with_stale_prio_still_finds_task() {
        let l = RunList::new(LevelId(0));
        l.push(TaskId(7), 3);
        // Wrong priority: the defensive sweep must still find it.
        assert!(l.remove(TaskId(7), 1));
        assert!(l.is_empty());
    }

    #[test]
    fn out_of_range_priorities_keep_exact_order() {
        let l = RunList::new(LevelId(0));
        // All of these saturate into the top bucket, which must stay
        // priority-ordered (FIFO within equal priorities).
        l.push(TaskId(0), 100);
        l.push(TaskId(1), 1_000);
        l.push(TaskId(2), 70);
        l.push(TaskId(3), 100);
        l.push(TaskId(4), -1_000);
        assert_eq!(l.peek_max(), 1_000, "hint must be exact beyond the bucket range");
        assert_eq!(l.pop_max(), Some((TaskId(1), 1_000)));
        assert_eq!(l.pop_max(), Some((TaskId(0), 100)));
        assert_eq!(l.pop_max(), Some((TaskId(3), 100)), "FIFO within equal priority");
        assert_eq!(l.pop_max(), Some((TaskId(2), 70)));
        assert_eq!(l.peek_max(), -1_000);
        assert_eq!(l.pop_max(), Some((TaskId(4), -1_000)));
    }

    #[test]
    fn bitmask_spans_both_words() {
        // Priorities in both halves of the [-64, 63] range exercise
        // both occupancy words.
        let l = RunList::new(LevelId(0));
        l.push(TaskId(0), -60);
        l.push(TaskId(1), 50);
        l.push(TaskId(2), -10);
        assert_eq!(l.pop_max(), Some((TaskId(1), 50)));
        assert_eq!(l.pop_max(), Some((TaskId(2), -10)));
        assert_eq!(l.pop_max(), Some((TaskId(0), -60)));
        assert_eq!(l.pop_max(), None);
    }

    #[test]
    fn owner_pushes_take_the_lane_and_stay_fifo() {
        let l = RunList::with_fast_lane(LevelId(0), CpuId(1));
        as_cpu(CpuId(1), || {
            for i in 0..4 {
                l.push(TaskId(i), FAST_LANE_PRIO);
            }
        });
        assert_eq!(l.fast_lane_ops().0, 4, "owner pushes must hit the lane");
        assert_eq!(l.peek_max(), FAST_LANE_PRIO);
        assert_eq!(l.len(), 4);
        // FIFO out, from any thread, lock-free (bucket hint stays MIN).
        for i in 0..4 {
            assert_eq!(l.pop_max(), Some((TaskId(i), FAST_LANE_PRIO)));
        }
        assert_eq!(l.fast_lane_ops().1, 4);
        assert_eq!(l.pop_max(), None);
    }

    #[test]
    fn non_owner_and_outlier_pushes_take_buckets() {
        let l = RunList::with_fast_lane(LevelId(0), CpuId(0));
        // No owner context at all → buckets.
        l.push(TaskId(0), FAST_LANE_PRIO);
        // Wrong CPU → buckets.
        as_cpu(CpuId(3), || l.push(TaskId(1), FAST_LANE_PRIO));
        // Right CPU, outlier priority → buckets.
        as_cpu(CpuId(0), || l.push(TaskId(2), FAST_LANE_PRIO + 1));
        assert_eq!(l.fast_lane_ops(), (0, 0));
        assert_eq!(l.pop_max(), Some((TaskId(2), FAST_LANE_PRIO + 1)));
        assert_eq!(l.pop_max(), Some((TaskId(0), FAST_LANE_PRIO)));
        assert_eq!(l.pop_max(), Some((TaskId(1), FAST_LANE_PRIO)));
    }

    #[test]
    fn bucket_wins_priority_ties_and_outliers_win_outright() {
        let l = RunList::with_fast_lane(LevelId(0), CpuId(0));
        as_cpu(CpuId(0), || l.push(TaskId(10), FAST_LANE_PRIO)); // lane
        l.push(TaskId(11), FAST_LANE_PRIO); // bucket, same class
        l.push(TaskId(12), FAST_LANE_PRIO + 2); // bucket, higher
        l.push(TaskId(13), FAST_LANE_PRIO - 1); // bucket, lower
        assert_eq!(l.peek_max(), FAST_LANE_PRIO + 2);
        // Higher bucket priority first, then the tie goes to the
        // bucket, then the lane, then lower bucket priorities.
        assert_eq!(l.pop_max(), Some((TaskId(12), FAST_LANE_PRIO + 2)));
        assert_eq!(l.pop_max(), Some((TaskId(11), FAST_LANE_PRIO)));
        assert_eq!(l.pop_max(), Some((TaskId(10), FAST_LANE_PRIO)));
        assert_eq!(l.pop_max(), Some((TaskId(13), FAST_LANE_PRIO - 1)));
        assert_eq!(l.pop_max(), None);
    }

    #[test]
    fn full_lane_spills_to_buckets_and_loses_nothing() {
        let l = RunList::with_fast_lane(LevelId(0), CpuId(0));
        let n = FAST_LANE_CAP + 10;
        as_cpu(CpuId(0), || {
            for i in 0..n {
                l.push(TaskId(i), FAST_LANE_PRIO);
            }
        });
        assert_eq!(l.len(), n);
        // Push CAP+1 overflows and batch-spills the whole lane; the
        // trailing 9 pushes re-enter the (now empty) lane.
        assert_eq!(l.fast_lane_ops().0 as usize, FAST_LANE_CAP + 9);
        let mut got: Vec<usize> =
            std::iter::from_fn(|| l.pop_max().map(|(t, _)| t.0)).collect();
        got.sort_unstable();
        assert_eq!(got, (0..n).collect::<Vec<_>>());
    }

    #[test]
    fn spill_batch_takes_one_lock_round_trip_and_keeps_fifo() {
        let l = RunList::with_fast_lane(LevelId(0), CpuId(0));
        let n = FAST_LANE_CAP + 1;
        as_cpu(CpuId(0), || {
            for i in 0..n {
                l.push(TaskId(i), FAST_LANE_PRIO);
            }
        });
        // The overflowing push drained the whole lane plus itself into
        // the buckets in ONE batch — one lock round-trip for CAP + 1
        // tasks, not one per task.
        assert_eq!(l.fast_lane_spills(), (1, (FAST_LANE_CAP + 1) as u64));
        // The lane is empty again: the very next owner push is
        // lock-free and triggers no further spill.
        as_cpu(CpuId(0), || l.push(TaskId(n), FAST_LANE_PRIO));
        assert_eq!(l.fast_lane_spills().0, 1, "no second spill");
        assert_eq!(l.fast_lane_ops().0 as usize, FAST_LANE_CAP + 1);
        // Class FIFO survives the spill: the batched (older) tasks in
        // the buckets win the tie against the fresh lane push.
        let order: Vec<usize> =
            std::iter::from_fn(|| l.pop_max().map(|(t, _)| t.0)).collect();
        assert_eq!(order, (0..=n).collect::<Vec<_>>());
    }

    #[test]
    fn remove_reaches_into_the_lane() {
        let l = RunList::with_fast_lane(LevelId(0), CpuId(0));
        as_cpu(CpuId(0), || {
            for i in 0..5 {
                l.push(TaskId(i), FAST_LANE_PRIO);
            }
        });
        assert!(l.remove(TaskId(2), FAST_LANE_PRIO));
        assert!(!l.remove(TaskId(2), FAST_LANE_PRIO));
        // Survivors keep FIFO order (now via the buckets).
        let order: Vec<usize> =
            std::iter::from_fn(|| l.pop_max().map(|(t, _)| t.0)).collect();
        assert_eq!(order, vec![0, 1, 3, 4]);
    }

    #[test]
    fn snapshot_merges_tiers_in_pop_order() {
        let l = RunList::with_fast_lane(LevelId(0), CpuId(0));
        as_cpu(CpuId(0), || l.push(TaskId(1), FAST_LANE_PRIO));
        l.push(TaskId(0), FAST_LANE_PRIO + 1);
        l.push(TaskId(2), FAST_LANE_PRIO - 2);
        let snap = l.snapshot();
        assert_eq!(
            snap,
            vec![
                (TaskId(0), FAST_LANE_PRIO + 1),
                (TaskId(1), FAST_LANE_PRIO),
                (TaskId(2), FAST_LANE_PRIO - 2),
            ]
        );
    }

    #[test]
    fn concurrent_producers_consumers_lose_nothing() {
        let l = Arc::new(RunList::new(LevelId(0)));
        let n_prod = 4;
        let per = 500;
        let mut joins = Vec::new();
        for p in 0..n_prod {
            let l = l.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..per {
                    l.push(TaskId(p * per + i), (i % 3) as Prio);
                }
            }));
        }
        let popped = Arc::new(AtomicUsize::new(0));
        let mut consumers = Vec::new();
        for _ in 0..2 {
            let l = l.clone();
            let popped = popped.clone();
            consumers.push(std::thread::spawn(move || {
                let mut got = 0;
                while popped.load(Ordering::SeqCst) + got < n_prod * per {
                    if l.pop_max().is_some() {
                        got += 1;
                    } else {
                        std::thread::yield_now();
                    }
                }
                popped.fetch_add(got, Ordering::SeqCst);
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        for c in consumers {
            c.join().unwrap();
        }
        // Drain leftovers (consumers race on the termination check).
        let mut rest = 0;
        while l.pop_max().is_some() {
            rest += 1;
        }
        assert_eq!(popped.load(Ordering::SeqCst) + rest, n_prod * per);
    }
}
