//! A single priority task list with a lock-free max-priority hint.
//!
//! The hot-path layout is a **fixed-size priority-bucket array with an
//! occupancy bitmask**: `pop_max` and `max_prio` are constant-time word
//! scans (find-highest-set-bit over two `u64`s) instead of a
//! `BTreeMap` walk, and `remove` indexes the task's bucket directly
//! instead of scanning every priority class. (The legacy `BtreeRunList`
//! comparison baseline was dropped in PR 5 once `BENCH_rq.json` had a
//! few PRs of history showing the bucket layout winning.)

use std::collections::VecDeque;
use std::sync::atomic::{AtomicI32, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::task::{Prio, TaskId};
use crate::topology::LevelId;

/// Lowest priority with its own bucket; anything below saturates here.
pub const PRIO_FLOOR: Prio = -64;
/// Highest priority with its own bucket; anything above saturates here.
pub const PRIO_CEIL: Prio = 63;

const N_BUCKETS: usize = (PRIO_CEIL - PRIO_FLOOR + 1) as usize;
const WORDS: usize = N_BUCKETS / 64;

/// Bucket index of a priority. Out-of-range priorities saturate into
/// the end buckets, which are kept *sorted* (see [`Buckets::push`]) so
/// priority ordering stays exact for every `Prio` value — only the
/// rare overflow entries pay an O(bucket-len) insertion.
fn bucket_of(prio: Prio) -> usize {
    (prio.clamp(PRIO_FLOOR, PRIO_CEIL) - PRIO_FLOOR) as usize
}

fn prio_of_bucket(b: usize) -> Prio {
    b as Prio + PRIO_FLOOR
}

/// Priority buckets: FIFO within a priority, highest priority first.
#[derive(Debug)]
struct Buckets {
    /// One FIFO per bucket. Empty `VecDeque`s hold no heap allocation;
    /// the yield hot path reuses the same bucket's buffer every cycle.
    queues: Vec<VecDeque<(TaskId, Prio)>>,
    /// Bit `b` of word `b / 64` set ⇔ bucket `b` is non-empty.
    occupied: [u64; WORDS],
    len: usize,
}

impl Default for Buckets {
    fn default() -> Buckets {
        Buckets {
            queues: (0..N_BUCKETS).map(|_| VecDeque::new()).collect(),
            occupied: [0; WORDS],
            len: 0,
        }
    }
}

impl Buckets {
    fn push(&mut self, task: TaskId, prio: Prio) {
        let b = bucket_of(prio);
        let q = &mut self.queues[b];
        if b == 0 || b == N_BUCKETS - 1 {
            // End buckets may hold *saturated* (out-of-range)
            // priorities: keep them sorted descending, FIFO within a
            // priority, so `pop_front` is still the global max.
            let pos = q.iter().position(|&(_, p)| p < prio).unwrap_or(q.len());
            q.insert(pos, (task, prio));
        } else {
            // Middle buckets hold exactly one priority: plain FIFO.
            q.push_back((task, prio));
        }
        self.occupied[b / 64] |= 1 << (b % 64);
        self.len += 1;
    }

    /// Highest occupied bucket, if any: a constant-time word scan.
    fn max_bucket(&self) -> Option<usize> {
        for w in (0..WORDS).rev() {
            let word = self.occupied[w];
            if word != 0 {
                return Some(w * 64 + 63 - word.leading_zeros() as usize);
            }
        }
        None
    }

    fn pop_max(&mut self) -> Option<(TaskId, Prio)> {
        let b = self.max_bucket()?;
        let out = self.queues[b].pop_front().expect("occupancy bit lied");
        if self.queues[b].is_empty() {
            self.occupied[b / 64] &= !(1 << (b % 64));
        }
        self.len -= 1;
        Some(out)
    }

    fn max_prio(&self) -> Prio {
        match self.max_bucket() {
            // End buckets are sorted: the front carries the exact
            // (possibly out-of-range) maximum. Middle buckets hold a
            // single priority, so the bucket index is exact.
            Some(b) if b == 0 || b == N_BUCKETS - 1 => self.queues[b][0].1,
            Some(b) => prio_of_bucket(b),
            None => i32::MIN,
        }
    }

    /// Remove `task`, whose push priority was `prio`: only that bucket
    /// is scanned. A full sweep remains as a defensive fallback in case
    /// a caller passes a stale priority.
    fn remove(&mut self, task: TaskId, prio: Prio) -> bool {
        let b = bucket_of(prio);
        if self.remove_from_bucket(b, task) {
            return true;
        }
        for other in 0..N_BUCKETS {
            if other != b
                && self.occupied[other / 64] & (1 << (other % 64)) != 0
                && self.remove_from_bucket(other, task)
            {
                return true;
            }
        }
        false
    }

    fn remove_from_bucket(&mut self, b: usize, task: TaskId) -> bool {
        let q = &mut self.queues[b];
        if let Some(pos) = q.iter().position(|&(t, _)| t == task) {
            q.remove(pos);
            if q.is_empty() {
                self.occupied[b / 64] &= !(1 << (b % 64));
            }
            self.len -= 1;
            return true;
        }
        false
    }

    fn len(&self) -> usize {
        self.len
    }
}

/// One task list (one topology component's runqueue).
///
/// `max_prio`/`count` are lock-free *hints* maintained under the lock:
/// pass-1 scans may read slightly stale values; pass 2 re-checks under
/// the lock, exactly as the paper's implementation does (§4).
#[derive(Debug)]
pub struct RunList {
    level: LevelId,
    inner: Mutex<Buckets>,
    max_prio: AtomicI32,
    count: AtomicUsize,
}

impl RunList {
    pub fn new(level: LevelId) -> RunList {
        RunList {
            level,
            inner: Mutex::new(Buckets::default()),
            max_prio: AtomicI32::new(i32::MIN),
            count: AtomicUsize::new(0),
        }
    }

    /// Which component this list belongs to.
    pub fn level(&self) -> LevelId {
        self.level
    }

    /// Enqueue (FIFO within the priority class).
    pub fn push(&self, task: TaskId, prio: Prio) {
        let mut b = self.inner.lock().unwrap();
        b.push(task, prio);
        self.max_prio.store(b.max_prio(), Ordering::Release);
        self.count.store(b.len(), Ordering::Release);
    }

    /// Dequeue the highest-priority task.
    pub fn pop_max(&self) -> Option<(TaskId, Prio)> {
        let mut b = self.inner.lock().unwrap();
        let out = b.pop_max();
        self.max_prio.store(b.max_prio(), Ordering::Release);
        self.count.store(b.len(), Ordering::Release);
        out
    }

    /// Lock-free max-priority hint; `i32::MIN` when (probably) empty.
    /// Exact for every priority, including values outside
    /// [`PRIO_FLOOR`, `PRIO_CEIL`] (those live sorted in the end
    /// buckets).
    pub fn peek_max(&self) -> Prio {
        self.max_prio.load(Ordering::Acquire)
    }

    /// Lock-free length hint.
    pub fn len(&self) -> usize {
        self.count.load(Ordering::Acquire)
    }

    /// True when the hint says empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Remove a specific task, given the priority it was pushed with
    /// (tasks carry a fixed `prio`, so callers always know it). Returns
    /// whether it was found.
    pub fn remove(&self, task: TaskId, prio: Prio) -> bool {
        let mut b = self.inner.lock().unwrap();
        let hit = b.remove(task, prio);
        self.max_prio.store(b.max_prio(), Ordering::Release);
        self.count.store(b.len(), Ordering::Release);
        hit
    }

    /// Copy of the queue contents (tests / traces), highest first.
    pub fn snapshot(&self) -> Vec<(TaskId, Prio)> {
        let b = self.inner.lock().unwrap();
        let mut out = Vec::new();
        for bk in (0..N_BUCKETS).rev() {
            for &(t, p) in &b.queues[bk] {
                out.push((t, p));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn hint_is_consistent_after_each_op() {
        let l = RunList::new(LevelId(0));
        l.push(TaskId(0), 4);
        assert_eq!(l.peek_max(), 4);
        l.push(TaskId(1), 9);
        assert_eq!(l.peek_max(), 9);
        l.remove(TaskId(1), 9);
        assert_eq!(l.peek_max(), 4);
        l.pop_max();
        assert_eq!(l.peek_max(), i32::MIN);
        assert!(l.is_empty());
    }

    #[test]
    fn negative_priorities_work() {
        let l = RunList::new(LevelId(0));
        l.push(TaskId(0), -5);
        l.push(TaskId(1), -1);
        assert_eq!(l.pop_max(), Some((TaskId(1), -1)));
    }

    #[test]
    fn remove_middle_of_bucket() {
        let l = RunList::new(LevelId(0));
        for i in 0..4 {
            l.push(TaskId(i), 2);
        }
        assert!(l.remove(TaskId(2), 2));
        let order: Vec<TaskId> = std::iter::from_fn(|| l.pop_max().map(|(t, _)| t)).collect();
        assert_eq!(order, vec![TaskId(0), TaskId(1), TaskId(3)]);
    }

    #[test]
    fn remove_with_stale_prio_still_finds_task() {
        let l = RunList::new(LevelId(0));
        l.push(TaskId(7), 3);
        // Wrong priority: the defensive sweep must still find it.
        assert!(l.remove(TaskId(7), 1));
        assert!(l.is_empty());
    }

    #[test]
    fn out_of_range_priorities_keep_exact_order() {
        let l = RunList::new(LevelId(0));
        // All of these saturate into the top bucket, which must stay
        // priority-ordered (FIFO within equal priorities).
        l.push(TaskId(0), 100);
        l.push(TaskId(1), 1_000);
        l.push(TaskId(2), 70);
        l.push(TaskId(3), 100);
        l.push(TaskId(4), -1_000);
        assert_eq!(l.peek_max(), 1_000, "hint must be exact beyond the bucket range");
        assert_eq!(l.pop_max(), Some((TaskId(1), 1_000)));
        assert_eq!(l.pop_max(), Some((TaskId(0), 100)));
        assert_eq!(l.pop_max(), Some((TaskId(3), 100)), "FIFO within equal priority");
        assert_eq!(l.pop_max(), Some((TaskId(2), 70)));
        assert_eq!(l.peek_max(), -1_000);
        assert_eq!(l.pop_max(), Some((TaskId(4), -1_000)));
    }

    #[test]
    fn bitmask_spans_both_words() {
        // Priorities in both halves of the [-64, 63] range exercise
        // both occupancy words.
        let l = RunList::new(LevelId(0));
        l.push(TaskId(0), -60);
        l.push(TaskId(1), 50);
        l.push(TaskId(2), -10);
        assert_eq!(l.pop_max(), Some((TaskId(1), 50)));
        assert_eq!(l.pop_max(), Some((TaskId(2), -10)));
        assert_eq!(l.pop_max(), Some((TaskId(0), -60)));
        assert_eq!(l.pop_max(), None);
    }

    #[test]
    fn concurrent_producers_consumers_lose_nothing() {
        let l = Arc::new(RunList::new(LevelId(0)));
        let n_prod = 4;
        let per = 500;
        let mut joins = Vec::new();
        for p in 0..n_prod {
            let l = l.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..per {
                    l.push(TaskId(p * per + i), (i % 3) as Prio);
                }
            }));
        }
        let popped = Arc::new(AtomicUsize::new(0));
        let mut consumers = Vec::new();
        for _ in 0..2 {
            let l = l.clone();
            let popped = popped.clone();
            consumers.push(std::thread::spawn(move || {
                let mut got = 0;
                while popped.load(Ordering::SeqCst) + got < n_prod * per {
                    if l.pop_max().is_some() {
                        got += 1;
                    } else {
                        std::thread::yield_now();
                    }
                }
                popped.fetch_add(got, Ordering::SeqCst);
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        for c in consumers {
            c.join().unwrap();
        }
        // Drain leftovers (consumers race on the termination check).
        let mut rest = 0;
        while l.pop_max().is_some() {
            rest += 1;
        }
        assert_eq!(popped.load(Ordering::SeqCst) + rest, n_prod * per);
    }
}
