//! A single priority task list with a lock-free max-priority hint.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicI32, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::task::{Prio, TaskId};
use crate::topology::LevelId;

/// Priority buckets: FIFO within a priority, highest priority first.
#[derive(Debug, Default)]
struct Buckets {
    by_prio: BTreeMap<Prio, VecDeque<TaskId>>,
}

impl Buckets {
    // Perf note (EXPERIMENTS.md §Perf): empty buckets are *kept* in the
    // map. The yield hot path pushes and pops the same priority class
    // every cycle; removing the bucket on empty caused a BTreeMap
    // insert + VecDeque allocation per scheduling round.
    fn push(&mut self, task: TaskId, prio: Prio) {
        self.by_prio.entry(prio).or_default().push_back(task);
    }

    fn pop_max(&mut self) -> Option<(TaskId, Prio)> {
        for (&prio, q) in self.by_prio.iter_mut().rev() {
            if let Some(task) = q.pop_front() {
                return Some((task, prio));
            }
        }
        None
    }

    fn max_prio(&self) -> Prio {
        self.by_prio
            .iter()
            .rev()
            .find(|(_, q)| !q.is_empty())
            .map(|(&p, _)| p)
            .unwrap_or(i32::MIN)
    }

    fn remove(&mut self, task: TaskId) -> bool {
        for q in self.by_prio.values_mut() {
            if let Some(pos) = q.iter().position(|&t| t == task) {
                q.remove(pos);
                return true;
            }
        }
        false
    }

    fn len(&self) -> usize {
        self.by_prio.values().map(|q| q.len()).sum()
    }
}

/// One task list (one topology component's runqueue).
///
/// `max_prio`/`count` are lock-free *hints* maintained under the lock:
/// pass-1 scans may read slightly stale values; pass 2 re-checks under
/// the lock, exactly as the paper's implementation does (§4).
#[derive(Debug)]
pub struct RunList {
    level: LevelId,
    inner: Mutex<Buckets>,
    max_prio: AtomicI32,
    count: AtomicUsize,
}

impl RunList {
    pub fn new(level: LevelId) -> RunList {
        RunList {
            level,
            inner: Mutex::new(Buckets::default()),
            max_prio: AtomicI32::new(i32::MIN),
            count: AtomicUsize::new(0),
        }
    }

    /// Which component this list belongs to.
    pub fn level(&self) -> LevelId {
        self.level
    }

    /// Enqueue (FIFO within the priority class).
    pub fn push(&self, task: TaskId, prio: Prio) {
        let mut b = self.inner.lock().unwrap();
        b.push(task, prio);
        self.max_prio.store(b.max_prio(), Ordering::Release);
        self.count.store(b.len(), Ordering::Release);
    }

    /// Dequeue the highest-priority task.
    pub fn pop_max(&self) -> Option<(TaskId, Prio)> {
        let mut b = self.inner.lock().unwrap();
        let out = b.pop_max();
        self.max_prio.store(b.max_prio(), Ordering::Release);
        self.count.store(b.len(), Ordering::Release);
        out
    }

    /// Lock-free max-priority hint; `i32::MIN` when (probably) empty.
    pub fn peek_max(&self) -> Prio {
        self.max_prio.load(Ordering::Acquire)
    }

    /// Lock-free length hint.
    pub fn len(&self) -> usize {
        self.count.load(Ordering::Acquire)
    }

    /// True when the hint says empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Remove a specific task. Returns whether it was found.
    pub fn remove(&self, task: TaskId) -> bool {
        let mut b = self.inner.lock().unwrap();
        let hit = b.remove(task);
        self.max_prio.store(b.max_prio(), Ordering::Release);
        self.count.store(b.len(), Ordering::Release);
        hit
    }

    /// Copy of the queue contents (tests / traces).
    pub fn snapshot(&self) -> Vec<(TaskId, Prio)> {
        let b = self.inner.lock().unwrap();
        let mut out = Vec::new();
        for (&p, q) in b.by_prio.iter().rev() {
            for &t in q {
                out.push((t, p));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn hint_is_consistent_after_each_op() {
        let l = RunList::new(LevelId(0));
        l.push(TaskId(0), 4);
        assert_eq!(l.peek_max(), 4);
        l.push(TaskId(1), 9);
        assert_eq!(l.peek_max(), 9);
        l.remove(TaskId(1));
        assert_eq!(l.peek_max(), 4);
        l.pop_max();
        assert_eq!(l.peek_max(), i32::MIN);
        assert!(l.is_empty());
    }

    #[test]
    fn negative_priorities_work() {
        let l = RunList::new(LevelId(0));
        l.push(TaskId(0), -5);
        l.push(TaskId(1), -1);
        assert_eq!(l.pop_max(), Some((TaskId(1), -1)));
    }

    #[test]
    fn remove_middle_of_bucket() {
        let l = RunList::new(LevelId(0));
        for i in 0..4 {
            l.push(TaskId(i), 2);
        }
        assert!(l.remove(TaskId(2)));
        let order: Vec<TaskId> = std::iter::from_fn(|| l.pop_max().map(|(t, _)| t)).collect();
        assert_eq!(order, vec![TaskId(0), TaskId(1), TaskId(3)]);
    }

    #[test]
    fn concurrent_producers_consumers_lose_nothing() {
        let l = Arc::new(RunList::new(LevelId(0)));
        let n_prod = 4;
        let per = 500;
        let mut joins = Vec::new();
        for p in 0..n_prod {
            let l = l.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..per {
                    l.push(TaskId(p * per + i), (i % 3) as Prio);
                }
            }));
        }
        let popped = Arc::new(AtomicUsize::new(0));
        let mut consumers = Vec::new();
        for _ in 0..2 {
            let l = l.clone();
            let popped = popped.clone();
            consumers.push(std::thread::spawn(move || {
                let mut got = 0;
                while popped.load(Ordering::SeqCst) + got < n_prod * per {
                    if l.pop_max().is_some() {
                        got += 1;
                    } else {
                        std::thread::yield_now();
                    }
                }
                popped.fetch_add(got, Ordering::SeqCst);
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        for c in consumers {
            c.join().unwrap();
        }
        // Drain leftovers (consumers race on the termination check).
        let mut rest = 0;
        while l.pop_max().is_some() {
            rest += 1;
        }
        assert_eq!(popped.load(Ordering::SeqCst) + rest, n_prod * per);
    }
}
