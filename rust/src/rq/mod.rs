//! Hierarchy of task lists (paper §3.2 & §4).
//!
//! "Each component of each level of the hierarchy of the machine has one
//! and only one task list." A task on a component's list may be run by
//! any CPU that component covers — the list expresses the *scheduling
//! area*.
//!
//! The scheduler's two-pass search (§4) relies on each list publishing a
//! lock-free `max_prio` hint: pass 1 scans the hints without locking;
//! pass 2 locks only the selected list and re-checks, in case another
//! processor took the task in the meantime.

mod list;

pub use list::RunList;

use crate::task::{Prio, TaskId};
use crate::topology::{LevelId, Topology};

/// One [`RunList`] per topology component, indexed by [`LevelId`].
#[derive(Debug)]
pub struct RqHierarchy {
    lists: Vec<RunList>,
}

impl RqHierarchy {
    /// Build the list hierarchy for a machine.
    pub fn new(topo: &Topology) -> RqHierarchy {
        RqHierarchy {
            lists: (0..topo.n_components()).map(|i| RunList::new(LevelId(i))).collect(),
        }
    }

    /// The list of component `l`.
    pub fn list(&self, l: LevelId) -> &RunList {
        &self.lists[l.0]
    }

    /// Number of lists (== components).
    pub fn len(&self) -> usize {
        self.lists.len()
    }

    /// True for a zero-component hierarchy (never happens in practice).
    pub fn is_empty(&self) -> bool {
        self.lists.is_empty()
    }

    /// Push a task on a list.
    pub fn push(&self, l: LevelId, task: TaskId, prio: Prio) {
        self.lists[l.0].push(task, prio);
    }

    /// Push at the *end* of a priority class explicitly (regenerated
    /// bubbles go to the end of their list, §3.3.3). Same as `push`;
    /// alias for intent at call sites.
    pub fn push_back(&self, l: LevelId, task: TaskId, prio: Prio) {
        self.lists[l.0].push(task, prio);
    }

    /// Pop the highest-priority task of a list.
    pub fn pop_max(&self, l: LevelId) -> Option<(TaskId, Prio)> {
        self.lists[l.0].pop_max()
    }

    /// Lock-free max-priority hint (i32::MIN when empty).
    pub fn peek_max(&self, l: LevelId) -> Prio {
        self.lists[l.0].peek_max()
    }

    /// Remove a specific task (regeneration pulls threads back into
    /// their bubble). Returns true if it was present.
    pub fn remove(&self, l: LevelId, task: TaskId) -> bool {
        self.lists[l.0].remove(task)
    }

    /// Lock-free length hint of one list.
    pub fn len_of(&self, l: LevelId) -> usize {
        self.lists[l.0].len()
    }

    /// Total queued tasks across all lists (lock-free hints; advisory).
    pub fn total_queued(&self) -> usize {
        self.lists.iter().map(|l| l.len()).sum()
    }

    /// Snapshot of all (list, task, prio) triples — test/trace support.
    pub fn snapshot(&self) -> Vec<(LevelId, TaskId, Prio)> {
        let mut out = Vec::new();
        for list in &self.lists {
            for (t, p) in list.snapshot() {
                out.push((list.level(), t, p));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hierarchy() -> RqHierarchy {
        RqHierarchy::new(&Topology::numa(2, 2))
    }

    #[test]
    fn push_pop_priority_order() {
        let rq = hierarchy();
        let l = LevelId(0);
        rq.push(l, TaskId(1), 1);
        rq.push(l, TaskId(2), 3);
        rq.push(l, TaskId(3), 2);
        assert_eq!(rq.pop_max(l), Some((TaskId(2), 3)));
        assert_eq!(rq.pop_max(l), Some((TaskId(3), 2)));
        assert_eq!(rq.pop_max(l), Some((TaskId(1), 1)));
        assert_eq!(rq.pop_max(l), None);
    }

    #[test]
    fn fifo_within_priority() {
        let rq = hierarchy();
        let l = LevelId(0);
        for i in 0..5 {
            rq.push(l, TaskId(i), 7);
        }
        for i in 0..5 {
            assert_eq!(rq.pop_max(l), Some((TaskId(i), 7)));
        }
    }

    #[test]
    fn peek_tracks_max() {
        let rq = hierarchy();
        let l = LevelId(3);
        assert_eq!(rq.peek_max(l), i32::MIN);
        rq.push(l, TaskId(0), 2);
        rq.push(l, TaskId(1), 5);
        assert_eq!(rq.peek_max(l), 5);
        rq.pop_max(l);
        assert_eq!(rq.peek_max(l), 2);
        rq.pop_max(l);
        assert_eq!(rq.peek_max(l), i32::MIN);
    }

    #[test]
    fn remove_specific() {
        let rq = hierarchy();
        let l = LevelId(1);
        rq.push(l, TaskId(0), 1);
        rq.push(l, TaskId(1), 1);
        assert!(rq.remove(l, TaskId(0)));
        assert!(!rq.remove(l, TaskId(0)));
        assert_eq!(rq.pop_max(l), Some((TaskId(1), 1)));
    }

    #[test]
    fn total_and_snapshot() {
        let rq = hierarchy();
        rq.push(LevelId(0), TaskId(0), 1);
        rq.push(LevelId(2), TaskId(1), 2);
        assert_eq!(rq.total_queued(), 2);
        let snap = rq.snapshot();
        assert_eq!(snap.len(), 2);
        assert!(snap.contains(&(LevelId(2), TaskId(1), 2)));
    }
}
