//! Hierarchy of task lists (paper §3.2 & §4).
//!
//! "Each component of each level of the hierarchy of the machine has one
//! and only one task list." A task on a component's list may be run by
//! any CPU that component covers — the list expresses the *scheduling
//! area*.
//!
//! The scheduler's two-pass search (§4) relies on each list publishing a
//! lock-free `max_prio` hint: pass 1 scans the hints without locking;
//! pass 2 locks only the selected list and re-checks, in case another
//! processor took the task in the meantime.
//!
//! # Two-tier lists: fast lane + priority buckets
//!
//! Every list has a locked **priority-bucket** tier; single-CPU leaf
//! lists additionally carry a lock-free **fast lane** — a
//! Chase-Lev-style deque ([`StealDeque`]) owned by the leaf's CPU
//! (§2.2: a contended shared list "is a bottleneck"). Routing:
//!
//! * the owner CPU's pushes at the common thread priority
//!   ([`FAST_LANE_PRIO`]) go to the lane's bottom, lock-free (owner
//!   identity comes from the [`owner`] thread-local, set by both
//!   execution engines);
//! * picks and steals take from the lane's top with one CAS —
//!   hierarchy-ordered stealing needs no extra machinery, because
//!   every steal path already walks [`crate::topology::Topology`]'s
//!   precomputed scan orders and ends in `pop_max` on the victim leaf;
//! * the **bucket fallback** triggers for priority outliers
//!   (`prio != FAST_LANE_PRIO`), pushes from a thread with no or a
//!   different CPU context (remote wakeups), spills when the lane's
//!   fixed ring is full, and `remove` (which drains the lane through
//!   its steal end and respills survivors). A priority *tie* between
//!   the tiers is served bucket-first so remote work cannot starve.
//!
//! Besides the per-list hints, the hierarchy maintains **incremental
//! subtree occupancy counters**: `queued_subtree(l)` is the number of
//! tasks queued anywhere in `l`'s subtree, updated in O(depth) on every
//! push/pop/remove. Policies consult these instead of rescanning lists
//! (e.g. an idle CPU bails out of a steal attempt in O(1) when the
//! whole machine is empty).

mod deque;
mod list;
pub mod owner;

pub use deque::{StealDeque, FAST_LANE_CAP};
pub use list::{RunList, FAST_LANE_PRIO, PRIO_CEIL, PRIO_FLOOR};

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::task::{Prio, TaskId};
use crate::topology::{LevelId, Topology};

/// One [`RunList`] per topology component, indexed by [`LevelId`].
#[derive(Debug)]
pub struct RqHierarchy {
    lists: Vec<RunList>,
    /// Parent component of each list (None for the root).
    parent: Vec<Option<LevelId>>,
    /// Tasks queued in each component's subtree (self + descendants).
    /// Incremented *before* a task becomes poppable and decremented
    /// *after* it is popped, so the counter never undershoots; reads
    /// are advisory (may transiently overshoot under concurrency).
    subtree: Vec<AtomicUsize>,
}

impl RqHierarchy {
    /// Build the list hierarchy for a machine. Single-CPU leaves get a
    /// fast lane owned by their CPU; every other component (and any
    /// multi-CPU leaf an exotic topology might declare) is bucket-only.
    pub fn new(topo: &Topology) -> RqHierarchy {
        let n = topo.n_components();
        RqHierarchy {
            lists: (0..n)
                .map(|i| {
                    let l = LevelId(i);
                    let node = topo.node(l);
                    if node.children.is_empty() && node.cpu_count == 1 {
                        RunList::with_fast_lane(l, crate::topology::CpuId(node.cpu_first))
                    } else {
                        RunList::new(l)
                    }
                })
                .collect(),
            parent: (0..n).map(|i| topo.node(LevelId(i)).parent).collect(),
            subtree: (0..n).map(|_| AtomicUsize::new(0)).collect(),
        }
    }

    /// The list of component `l`.
    pub fn list(&self, l: LevelId) -> &RunList {
        &self.lists[l.0]
    }

    /// Number of lists (== components).
    pub fn len(&self) -> usize {
        self.lists.len()
    }

    /// True for a zero-component hierarchy (never happens in practice).
    pub fn is_empty(&self) -> bool {
        self.lists.is_empty()
    }

    fn subtree_add(&self, l: LevelId) {
        let mut cur = Some(l);
        while let Some(c) = cur {
            self.subtree[c.0].fetch_add(1, Ordering::Relaxed);
            cur = self.parent[c.0];
        }
    }

    fn subtree_sub(&self, l: LevelId) {
        let mut cur = Some(l);
        while let Some(c) = cur {
            self.subtree[c.0].fetch_sub(1, Ordering::Relaxed);
            cur = self.parent[c.0];
        }
    }

    /// Push a task on a list. FIFO within its priority class, which is
    /// also what the paper's §3.3.3 "requeue at the end of the class"
    /// regeneration semantics needs — there is no separate `push_back`.
    pub fn push(&self, l: LevelId, task: TaskId, prio: Prio) {
        self.subtree_add(l);
        self.lists[l.0].push(task, prio);
    }

    /// Pop the highest-priority task of a list.
    pub fn pop_max(&self, l: LevelId) -> Option<(TaskId, Prio)> {
        let out = self.lists[l.0].pop_max();
        if out.is_some() {
            self.subtree_sub(l);
        }
        out
    }

    /// Lock-free max-priority hint (i32::MIN when empty).
    pub fn peek_max(&self, l: LevelId) -> Prio {
        self.lists[l.0].peek_max()
    }

    /// Remove a specific task pushed with `prio` (regeneration pulls
    /// threads back into their bubble). Returns true if it was present.
    pub fn remove(&self, l: LevelId, task: TaskId, prio: Prio) -> bool {
        let hit = self.lists[l.0].remove(task, prio);
        if hit {
            self.subtree_sub(l);
        }
        hit
    }

    /// Lock-free length hint of one list.
    pub fn len_of(&self, l: LevelId) -> usize {
        self.lists[l.0].len()
    }

    /// Tasks queued anywhere in `l`'s subtree (advisory, O(1)).
    pub fn queued_subtree(&self, l: LevelId) -> usize {
        self.subtree[l.0].load(Ordering::Relaxed)
    }

    /// Total queued tasks across all lists (advisory, O(1): the root's
    /// subtree counter).
    pub fn total_queued(&self) -> usize {
        self.subtree[0].load(Ordering::Relaxed)
    }

    /// Total (pushes, pops) served lock-free by the fast lanes across
    /// all lists — lets tests assert the lockless tier engaged.
    pub fn fast_lane_ops(&self) -> (u64, u64) {
        self.lists.iter().fold((0, 0), |(pu, po), l| {
            let (p, q) = l.fast_lane_ops();
            (pu + p, po + q)
        })
    }

    /// Snapshot of all (list, task, prio) triples — test/trace support.
    pub fn snapshot(&self) -> Vec<(LevelId, TaskId, Prio)> {
        let mut out = Vec::new();
        for list in &self.lists {
            for (t, p) in list.snapshot() {
                out.push((list.level(), t, p));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hierarchy() -> RqHierarchy {
        RqHierarchy::new(&Topology::numa(2, 2))
    }

    #[test]
    fn push_pop_priority_order() {
        let rq = hierarchy();
        let l = LevelId(0);
        rq.push(l, TaskId(1), 1);
        rq.push(l, TaskId(2), 3);
        rq.push(l, TaskId(3), 2);
        assert_eq!(rq.pop_max(l), Some((TaskId(2), 3)));
        assert_eq!(rq.pop_max(l), Some((TaskId(3), 2)));
        assert_eq!(rq.pop_max(l), Some((TaskId(1), 1)));
        assert_eq!(rq.pop_max(l), None);
    }

    #[test]
    fn fifo_within_priority() {
        let rq = hierarchy();
        let l = LevelId(0);
        for i in 0..5 {
            rq.push(l, TaskId(i), 7);
        }
        for i in 0..5 {
            assert_eq!(rq.pop_max(l), Some((TaskId(i), 7)));
        }
    }

    #[test]
    fn peek_tracks_max() {
        let rq = hierarchy();
        let l = LevelId(3);
        assert_eq!(rq.peek_max(l), i32::MIN);
        rq.push(l, TaskId(0), 2);
        rq.push(l, TaskId(1), 5);
        assert_eq!(rq.peek_max(l), 5);
        rq.pop_max(l);
        assert_eq!(rq.peek_max(l), 2);
        rq.pop_max(l);
        assert_eq!(rq.peek_max(l), i32::MIN);
    }

    #[test]
    fn remove_specific() {
        let rq = hierarchy();
        let l = LevelId(1);
        rq.push(l, TaskId(0), 1);
        rq.push(l, TaskId(1), 1);
        assert!(rq.remove(l, TaskId(0), 1));
        assert!(!rq.remove(l, TaskId(0), 1));
        assert_eq!(rq.pop_max(l), Some((TaskId(1), 1)));
    }

    #[test]
    fn total_and_snapshot() {
        let rq = hierarchy();
        rq.push(LevelId(0), TaskId(0), 1);
        rq.push(LevelId(2), TaskId(1), 2);
        assert_eq!(rq.total_queued(), 2);
        let snap = rq.snapshot();
        assert_eq!(snap.len(), 2);
        assert!(snap.contains(&(LevelId(2), TaskId(1), 2)));
    }

    #[test]
    fn leaves_get_fast_lanes_and_counters_stay_exact() {
        let topo = Topology::numa(2, 2);
        let rq = RqHierarchy::new(&topo);
        for i in 0..rq.len() {
            let l = LevelId(i);
            let node = topo.node(l);
            let owner = rq.list(l).fast_lane_owner();
            if node.children.is_empty() {
                assert_eq!(owner, Some(crate::topology::CpuId(node.cpu_first)));
            } else {
                assert_eq!(owner, None);
            }
        }
        // Owner-context pushes ride the lane; subtree counters and the
        // snapshot still see them.
        let cpu = crate::topology::CpuId(1);
        let leaf = topo.leaf_of(cpu);
        owner::set_current_cpu(Some(cpu));
        rq.push(leaf, TaskId(0), FAST_LANE_PRIO);
        rq.push(leaf, TaskId(1), FAST_LANE_PRIO);
        owner::set_current_cpu(None);
        assert_eq!(rq.fast_lane_ops().0, 2);
        assert_eq!(rq.queued_subtree(topo.root()), 2);
        assert_eq!(rq.len_of(leaf), 2);
        assert_eq!(rq.snapshot().len(), 2);
        assert_eq!(rq.pop_max(leaf), Some((TaskId(0), FAST_LANE_PRIO)));
        assert!(rq.remove(leaf, TaskId(1), FAST_LANE_PRIO));
        assert_eq!(rq.total_queued(), 0);
        assert_eq!(rq.fast_lane_ops(), (2, 1));
    }

    #[test]
    fn subtree_counters_track_descendants() {
        // numa(2,2): root 0, nodes 1-2, leaves 3-6 (BFS order).
        let topo = Topology::numa(2, 2);
        let rq = RqHierarchy::new(&topo);
        let node0 = topo.node(topo.root()).children[0];
        let leaf0 = topo.node(node0).children[0];
        rq.push(leaf0, TaskId(0), 1);
        rq.push(node0, TaskId(1), 1);
        assert_eq!(rq.queued_subtree(leaf0), 1);
        assert_eq!(rq.queued_subtree(node0), 2);
        assert_eq!(rq.queued_subtree(topo.root()), 2);
        assert_eq!(rq.total_queued(), 2);
        assert!(rq.remove(leaf0, TaskId(0), 1));
        assert_eq!(rq.queued_subtree(node0), 1);
        rq.pop_max(node0);
        assert_eq!(rq.total_queued(), 0);
    }
}
