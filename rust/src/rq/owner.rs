//! Which virtual CPU the current OS thread is acting as.
//!
//! The fast-lane deque ([`super::deque::StealDeque`]) has a
//! single-producer bottom end: only the leaf's *owning* CPU may push
//! there. "The owner" is a role, not a thread identity — the native
//! executor pins one worker thread per virtual CPU, while the simulator
//! plays every CPU from one thread — so the runqueue asks this
//! thread-local context instead of guessing. A thread with no context
//! set (tests driving lists directly, remote wakeups) simply takes the
//! locked bucket path, which is always correct.

use std::cell::Cell;

use crate::topology::CpuId;

thread_local! {
    static CURRENT_CPU: Cell<Option<usize>> = const { Cell::new(None) };
}

/// Declare that this OS thread is now acting as `cpu` (or, with `None`,
/// as no CPU at all). The native executor sets it once per worker; the
/// simulator re-points it at every event.
pub fn set_current_cpu(cpu: Option<CpuId>) {
    CURRENT_CPU.with(|c| c.set(cpu.map(|c| c.0)));
}

/// The virtual CPU this OS thread is acting as, if any.
pub fn current_cpu() -> Option<CpuId> {
    CURRENT_CPU.with(|c| c.get()).map(CpuId)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_is_per_thread() {
        set_current_cpu(Some(CpuId(3)));
        assert_eq!(current_cpu(), Some(CpuId(3)));
        std::thread::spawn(|| assert_eq!(current_cpu(), None)).join().unwrap();
        set_current_cpu(None);
        assert_eq!(current_cpu(), None);
    }
}
