//! A Chase-Lev-style work-stealing deque — the per-CPU *fast lane*.
//!
//! One end ("bottom") belongs to the owning CPU: it pushes and pops
//! there without taking any lock. Every other CPU is a *thief* and
//! takes from the opposite end ("top") with a single CAS. The memory
//! ordering discipline follows the classic formulation (Chase & Lev,
//! SPAA '05; Lê et al., PPoPP '13): the only cross-thread arbitration
//! is the CAS on `top`, so the common owner push/pop never contends.
//!
//! Differences from the textbook deque, driven by how [`super::RunList`]
//! uses it:
//!
//! * **Fixed capacity, no growth.** The ring is a `Box<[AtomicU64]>`
//!   sized at construction; a full deque makes `push_bottom` return the
//!   task to the caller, which falls back to the locked priority
//!   buckets. No reallocation means no reclamation hazard and the whole
//!   structure is safe Rust.
//! * **FIFO consumption by default.** The paper's §3.3.3 "requeue at
//!   the end of the class" semantics requires FIFO within a priority
//!   class, so the runqueue integration drains the lane from the *top*
//!   (steal) end even on the owner's own picks. `pop_bottom` (owner
//!   LIFO) is provided and tested for policies that want cache-hot
//!   depth-first execution, but the default pick path never uses it.
//!
//! Indices are monotonically increasing `i64`s; `index & mask` locates
//! the slot. A slot can only be overwritten once `top` has advanced
//! past it (the capacity check in `push_bottom` reads `top`), and any
//! advance of `top` fails the in-flight thief CAS, so a thief can never
//! observe a torn or recycled value it then returns.

use std::sync::atomic::{fence, AtomicI64, AtomicU64, Ordering};

use crate::task::TaskId;

/// Fast-lane capacity (slots). Power of two; beyond this, pushes spill
/// to the priority buckets, so it only needs to cover a leaf's typical
/// ready backlog.
pub const FAST_LANE_CAP: usize = 256;

/// The deque proper. All methods are safe to call from any thread, but
/// `push_bottom`/`pop_bottom` assume a **single concurrent caller** (the
/// owner); [`super::RunList`] enforces that by checking the caller's
/// CPU identity before routing here.
#[derive(Debug)]
pub struct StealDeque {
    /// Next index a thief takes. Monotonic.
    top: AtomicI64,
    /// Next index the owner pushes. Only the owner writes it.
    bottom: AtomicI64,
    slots: Box<[AtomicU64]>,
    mask: i64,
}

impl StealDeque {
    /// An empty deque holding up to `cap` tasks (rounded up to a power
    /// of two).
    pub fn new(cap: usize) -> StealDeque {
        let cap = cap.max(2).next_power_of_two();
        StealDeque {
            top: AtomicI64::new(0),
            bottom: AtomicI64::new(0),
            slots: (0..cap).map(|_| AtomicU64::new(0)).collect(),
            mask: cap as i64 - 1,
        }
    }

    fn slot(&self, i: i64) -> &AtomicU64 {
        &self.slots[(i & self.mask) as usize]
    }

    /// Queued tasks (advisory under concurrency, exact when quiescent).
    pub fn len(&self) -> usize {
        let b = self.bottom.load(Ordering::Acquire);
        let t = self.top.load(Ordering::Acquire);
        (b - t).max(0) as usize
    }

    /// True when the deque is (probably) empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Owner-only: enqueue at the bottom. `Err(task)` when the ring is
    /// full — the caller spills to the locked buckets.
    pub fn push_bottom(&self, task: TaskId) -> Result<(), TaskId> {
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Acquire);
        if b - t > self.mask {
            return Err(task); // full (a stale `t` only under-admits)
        }
        self.slot(b).store(task.0 as u64, Ordering::Relaxed);
        // Publish the slot before the new bottom so a thief acquiring
        // `bottom` sees the value.
        self.bottom.store(b + 1, Ordering::Release);
        Ok(())
    }

    /// Owner-only: dequeue at the bottom (LIFO). The final-element race
    /// against thieves is arbitrated by a CAS on `top`.
    pub fn pop_bottom(&self) -> Option<TaskId> {
        let b = self.bottom.load(Ordering::Relaxed) - 1;
        self.bottom.store(b, Ordering::Relaxed);
        fence(Ordering::SeqCst);
        let t = self.top.load(Ordering::Relaxed);
        if t > b {
            // Already empty: undo the decrement.
            self.bottom.store(b + 1, Ordering::Relaxed);
            return None;
        }
        let task = TaskId(self.slot(b).load(Ordering::Relaxed) as usize);
        if t < b {
            return Some(task); // more than one element: no race possible
        }
        // Single element: win it against thieves or concede.
        let won = self
            .top
            .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
            .is_ok();
        self.bottom.store(b + 1, Ordering::Relaxed);
        if won {
            Some(task)
        } else {
            None
        }
    }

    /// Any thread: take the oldest task (FIFO end) with a single CAS.
    /// `None` means empty *or* lost a race — callers that must drain
    /// retry while [`Self::is_empty`] is false (each failed CAS means
    /// another thread took an element, so the retry loop is bounded).
    pub fn steal_top(&self) -> Option<TaskId> {
        let t = self.top.load(Ordering::Acquire);
        fence(Ordering::SeqCst);
        let b = self.bottom.load(Ordering::Acquire);
        if t >= b {
            return None;
        }
        let task = TaskId(self.slot(t).load(Ordering::Relaxed) as usize);
        if self.top.compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed).is_ok() {
            Some(task)
        } else {
            None
        }
    }

    /// Drain from the steal end until an observed-empty, collecting into
    /// `out` in FIFO order. Used by the bucket-fallback `remove` path;
    /// bounded even against a concurrent owner because each iteration
    /// either advances `top` globally or observes empty.
    pub fn drain_into(&self, out: &mut Vec<TaskId>) {
        loop {
            match self.steal_top() {
                Some(t) => out.push(t),
                None if self.is_empty() => break,
                None => continue, // lost a CAS race; someone else advanced
            }
        }
    }

    /// Racy copy of the queued tasks, oldest (steal end) first — test
    /// and trace support only.
    pub fn snapshot(&self) -> Vec<TaskId> {
        let t = self.top.load(Ordering::Acquire);
        let b = self.bottom.load(Ordering::Acquire);
        (t..b.max(t)).map(|i| TaskId(self.slot(i).load(Ordering::Relaxed) as usize)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Arc;

    #[test]
    fn owner_lifo_and_thief_fifo() {
        let d = StealDeque::new(8);
        for i in 0..3 {
            d.push_bottom(TaskId(i)).unwrap();
        }
        // Owner end is LIFO…
        assert_eq!(d.pop_bottom(), Some(TaskId(2)));
        // …the steal end is FIFO.
        assert_eq!(d.steal_top(), Some(TaskId(0)));
        assert_eq!(d.pop_bottom(), Some(TaskId(1)));
        assert_eq!(d.pop_bottom(), None);
        assert_eq!(d.steal_top(), None);
        assert!(d.is_empty());
    }

    #[test]
    fn full_deque_rejects_push() {
        let d = StealDeque::new(4);
        for i in 0..4 {
            d.push_bottom(TaskId(i)).unwrap();
        }
        assert_eq!(d.push_bottom(TaskId(99)), Err(TaskId(99)));
        assert_eq!(d.steal_top(), Some(TaskId(0)));
        // One slot freed: the next push fits again.
        d.push_bottom(TaskId(99)).unwrap();
        assert_eq!(d.len(), 4);
    }

    #[test]
    fn wraparound_keeps_order() {
        let d = StealDeque::new(4);
        for round in 0..10 {
            for i in 0..3 {
                d.push_bottom(TaskId(round * 3 + i)).unwrap();
            }
            for i in 0..3 {
                assert_eq!(d.steal_top(), Some(TaskId(round * 3 + i)));
            }
        }
        assert!(d.is_empty());
    }

    #[test]
    fn drain_collects_fifo() {
        let d = StealDeque::new(8);
        for i in 0..5 {
            d.push_bottom(TaskId(i)).unwrap();
        }
        let mut out = Vec::new();
        d.drain_into(&mut out);
        assert_eq!(out, (0..5).map(TaskId).collect::<Vec<_>>());
        assert!(d.is_empty());
    }

    /// One owner pushing + popping, several thieves stealing: every
    /// pushed id comes out exactly once.
    #[test]
    fn stress_no_loss_no_duplication() {
        let d = Arc::new(StealDeque::new(64));
        let total = 20_000usize;
        let taken = Arc::new(AtomicUsize::new(0));
        let seen: Arc<Vec<AtomicUsize>> =
            Arc::new((0..total).map(|_| AtomicUsize::new(0)).collect());
        let mut thieves = Vec::new();
        for _ in 0..3 {
            let d = d.clone();
            let taken = taken.clone();
            let seen = seen.clone();
            thieves.push(std::thread::spawn(move || {
                while taken.load(Ordering::SeqCst) < total {
                    if let Some(t) = d.steal_top() {
                        seen[t.0].fetch_add(1, Ordering::SeqCst);
                        taken.fetch_add(1, Ordering::SeqCst);
                    } else {
                        std::thread::yield_now();
                    }
                }
            }));
        }
        let mut next = 0usize;
        while next < total {
            match d.push_bottom(TaskId(next)) {
                Ok(()) => next += 1,
                Err(_) => {
                    // Ring full: the owner takes some back itself.
                    if let Some(t) = d.pop_bottom() {
                        seen[t.0].fetch_add(1, Ordering::SeqCst);
                        taken.fetch_add(1, Ordering::SeqCst);
                    }
                }
            }
        }
        for th in thieves {
            th.join().unwrap();
        }
        let counts: HashSet<usize> =
            seen.iter().map(|c| c.load(Ordering::SeqCst)).collect();
        assert_eq!(counts, HashSet::from([1]), "every task exactly once");
    }
}
