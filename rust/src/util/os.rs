//! Thin OS bindings for the real-machine backend: thread→CPU affinity
//! and anonymous memory mappings, declared directly against libc (the
//! build is deliberately dependency-free).
//!
//! Everything here is *best-effort*: a denied `sched_setaffinity`
//! (cgroup-restricted CI, seccomp sandboxes) or an unsupported `mbind`
//! reports failure instead of erroring, and non-Linux builds compile to
//! stubs that report unavailability. Callers decide how loudly to care
//! (see the pinning protocol in [`crate::exec`]).

/// Bits in the affinity/node masks we pass to the kernel (glibc's
/// `cpu_set_t` is 1024 bits; we mirror that as `[u64; 16]`).
const MASK_WORDS: usize = 16;
const MASK_BITS: usize = MASK_WORDS * 64;

/// Pin the *calling* OS thread to the single CPU `os_cpu`.
/// Returns whether the kernel accepted the mask.
#[cfg(target_os = "linux")]
pub fn pin_to_os_cpu(os_cpu: usize) -> bool {
    if os_cpu >= MASK_BITS {
        return false;
    }
    let mut mask = [0u64; MASK_WORDS];
    mask[os_cpu / 64] = 1u64 << (os_cpu % 64);
    extern "C" {
        // pid 0 = the calling thread.
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }
    // SAFETY: `mask` points at MASK_WORDS*8 valid, initialised bytes and
    // outlives the call; the kernel only reads it.
    unsafe { sched_setaffinity(0, MASK_WORDS * 8, mask.as_ptr()) == 0 }
}

#[cfg(not(target_os = "linux"))]
pub fn pin_to_os_cpu(_os_cpu: usize) -> bool {
    false
}

/// Prefer placing the pages of `[ptr, ptr+len)` on NUMA node `node`
/// (`mbind` with `MPOL_PREFERRED`: a preference, not a strict bind, so
/// a full node degrades to remote pages instead of OOM). Returns
/// whether the kernel accepted the policy.
pub fn bind_to_node(ptr: *mut u8, len: usize, node: usize) -> bool {
    #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        #[cfg(target_arch = "x86_64")]
        const NR_MBIND: i64 = 237;
        #[cfg(target_arch = "aarch64")]
        const NR_MBIND: i64 = 235;
        const MPOL_PREFERRED: i32 = 1;
        if node >= MASK_BITS || ptr.is_null() || len == 0 {
            return false;
        }
        let mut mask = [0u64; MASK_WORDS];
        mask[node / 64] = 1u64 << (node % 64);
        extern "C" {
            fn syscall(num: i64, ...) -> i64;
        }
        // SAFETY: the mask buffer is valid for MASK_BITS bits and the
        // kernel treats [ptr, ptr+len) opaquely (no dereference here).
        unsafe {
            syscall(NR_MBIND, ptr, len, MPOL_PREFERRED, mask.as_ptr(), MASK_BITS, 0i32) == 0
        }
    }
    #[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
    {
        let _ = (ptr, len, node);
        false
    }
}

/// An anonymous private memory mapping (the backing store for
/// [`crate::mem::arena`]). Unmapped on drop.
#[derive(Debug)]
pub struct MapRegion {
    ptr: *mut u8,
    len: usize,
}

// SAFETY: the mapping is plain process memory; all mutation goes
// through volatile page touches that tolerate races by design.
unsafe impl Send for MapRegion {}
unsafe impl Sync for MapRegion {}

impl MapRegion {
    /// Map `len` bytes of zeroed anonymous memory, or `None` when the
    /// platform can't (`len == 0`, non-Linux, mmap denied).
    #[cfg(target_os = "linux")]
    pub fn map(len: usize) -> Option<MapRegion> {
        if len == 0 {
            return None;
        }
        const PROT_READ: i32 = 1;
        const PROT_WRITE: i32 = 2;
        const MAP_PRIVATE: i32 = 2;
        const MAP_ANONYMOUS: i32 = 0x20;
        extern "C" {
            fn mmap(
                addr: *mut u8,
                len: usize,
                prot: i32,
                flags: i32,
                fd: i32,
                off: i64,
            ) -> *mut u8;
        }
        // SAFETY: anonymous mapping, no address hint, no fd.
        let p = unsafe {
            mmap(
                std::ptr::null_mut(),
                len,
                PROT_READ | PROT_WRITE,
                MAP_PRIVATE | MAP_ANONYMOUS,
                -1,
                0,
            )
        };
        if p as isize == -1 {
            None
        } else {
            Some(MapRegion { ptr: p, len })
        }
    }

    #[cfg(not(target_os = "linux"))]
    pub fn map(_len: usize) -> Option<MapRegion> {
        None
    }

    pub fn as_ptr(&self) -> *mut u8 {
        self.ptr
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl Drop for MapRegion {
    fn drop(&mut self) {
        #[cfg(target_os = "linux")]
        {
            extern "C" {
                fn munmap(addr: *mut u8, len: usize) -> i32;
            }
            // SAFETY: `ptr/len` came from a successful mmap and nothing
            // hands out references that outlive `self`.
            unsafe {
                munmap(self.ptr, self.len);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pin_rejects_out_of_range_cpus() {
        assert!(!pin_to_os_cpu(usize::MAX / 2));
    }

    #[test]
    #[cfg(target_os = "linux")]
    fn pin_to_cpu_zero_is_accepted_or_cleanly_denied() {
        // CPU 0 is online everywhere; the call may still be denied in
        // restricted sandboxes — either answer is fine, crashing is not.
        let _ = pin_to_os_cpu(0);
    }

    #[test]
    #[cfg(target_os = "linux")]
    fn map_region_is_readable_and_writable() {
        let m = MapRegion::map(4096).expect("anonymous mmap");
        assert_eq!(m.len(), 4096);
        // SAFETY: in-bounds access to a live RW mapping.
        unsafe {
            m.as_ptr().write_volatile(7);
            assert_eq!(m.as_ptr().read_volatile(), 7);
            assert_eq!(m.as_ptr().add(4095).read_volatile(), 0);
        }
    }

    #[test]
    fn bind_handles_bad_input_without_crashing() {
        assert!(!bind_to_node(std::ptr::null_mut(), 0, 0));
    }
}
