//! Descriptive statistics for benchmark samples and metric reports.

/// Summary statistics over a sample set.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub min: f64,
    pub max: f64,
    pub stddev: f64,
    pub p95: f64,
}

impl Summary {
    /// Compute a summary. Panics on an empty slice.
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of(empty)");
        let n = samples.len();
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        Summary {
            n,
            mean,
            median: percentile_sorted(&sorted, 50.0),
            min: sorted[0],
            max: sorted[n - 1],
            stddev: var.sqrt(),
            p95: percentile_sorted(&sorted, 95.0),
        }
    }

    /// Relative stddev (coefficient of variation), 0 when mean is 0.
    pub fn cv(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.stddev / self.mean.abs()
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Trim outliers beyond `k` interquartile ranges (Tukey fences).
/// Returns the retained samples; never returns an empty vec.
pub fn trim_outliers(samples: &[f64], k: f64) -> Vec<f64> {
    if samples.len() < 4 {
        return samples.to_vec();
    }
    let mut sorted: Vec<f64> = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q1 = percentile_sorted(&sorted, 25.0);
    let q3 = percentile_sorted(&sorted, 75.0);
    let iqr = q3 - q1;
    let (lo, hi) = (q1 - k * iqr, q3 + k * iqr);
    let kept: Vec<f64> = samples.iter().copied().filter(|&x| x >= lo && x <= hi).collect();
    if kept.is_empty() {
        samples.to_vec()
    } else {
        kept
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.stddev - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_single_sample() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.mean, 7.0);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.p95, 7.0);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile_sorted(&sorted, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile_sorted(&sorted, 95.0) - 9.5).abs() < 1e-12);
    }

    #[test]
    fn trim_removes_spike() {
        let mut xs = vec![10.0; 20];
        xs.push(1000.0);
        let kept = trim_outliers(&xs, 1.5);
        assert_eq!(kept.len(), 20);
        assert!(kept.iter().all(|&x| x == 10.0));
    }

    #[test]
    fn trim_keeps_small_samples_whole() {
        let xs = vec![1.0, 100.0, 1000.0];
        assert_eq!(trim_outliers(&xs, 1.5), xs);
    }

    #[test]
    fn cv_zero_mean() {
        let s = Summary::of(&[0.0, 0.0]);
        assert_eq!(s.cv(), 0.0);
    }
}
