//! Deterministic PRNG (splitmix64 + xoshiro256**).
//!
//! Everything that samples (workload generation, property tests, steal
//! victim selection) threads one of these through explicitly, so every
//! simulation run and every test is reproducible from its seed.

/// xoshiro256** with splitmix64 seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create from a 64-bit seed (any value, including 0, is fine).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64 bits.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. `n` must be non-zero.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        // Lemire's multiply-shift rejection method.
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "Rng::range empty [{lo}, {hi})");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        // 53 high bits → uniform double in [0,1).
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Sample from an exponential distribution with the given mean.
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Pareto-ish heavy-tailed sample with given minimum and shape.
    /// Used for the AMR-like imbalanced workloads.
    pub fn pareto(&mut self, min: f64, shape: f64) -> f64 {
        let u = 1.0 - self.f64();
        min / u.powf(1.0 / shape)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }

    /// Split off an independent generator (for parallel streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.exp(5.0)).sum::<f64>() / n as f64;
        assert!((mean - 5.0).abs() < 0.25, "mean {mean}");
    }

    #[test]
    fn pareto_respects_min() {
        let mut r = Rng::new(13);
        for _ in 0..1000 {
            assert!(r.pareto(2.0, 1.5) >= 2.0);
        }
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut r = Rng::new(9);
        let mut a = r.fork();
        let mut b = r.fork();
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
