//! Minimal JSON validity checker (recursive descent, no allocation of
//! a document model). The crate is dependency-free, but tests and the
//! trace exporter need to assert "this artifact is well-formed JSON" —
//! this is exactly that check, nothing more (no value access).

/// Validate that `s` is one well-formed JSON value (with surrounding
/// whitespace allowed). `Err` carries the byte offset and what went
/// wrong.
pub fn validate(s: &str) -> Result<(), String> {
    let mut p = Parser { b: s.as_bytes(), i: 0 };
    p.ws();
    p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn err(&self, what: &str) -> String {
        format!("{what} at byte {}", self.i)
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<(), String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => self.string(),
            Some(b't') => self.lit("true"),
            Some(b'f') => self.lit("false"),
            Some(b'n') => self.lit("null"),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<(), String> {
        self.eat(b'{')?;
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(());
        }
        loop {
            self.ws();
            self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            self.value()?;
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(());
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<(), String> {
        self.eat(b'[')?;
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(());
        }
        loop {
            self.ws();
            self.value()?;
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(());
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<(), String> {
        self.eat(b'"')?;
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(());
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"' | b'\\' | b'/' | b'b' | b'f' | b'n' | b'r' | b't') => {
                            self.i += 1;
                        }
                        Some(b'u') => {
                            self.i += 1;
                            for _ in 0..4 {
                                match self.peek() {
                                    Some(c) if c.is_ascii_hexdigit() => self.i += 1,
                                    _ => return Err(self.err("bad \\u escape")),
                                }
                            }
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                Some(c) if c < 0x20 => return Err(self.err("raw control char in string")),
                Some(_) => self.i += 1,
            }
        }
    }

    fn digits(&mut self) -> Result<(), String> {
        let start = self.i;
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.i == start {
            return Err(self.err("expected digits"));
        }
        Ok(())
    }

    fn number(&mut self) -> Result<(), String> {
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        self.digits()?;
        if self.peek() == Some(b'.') {
            self.i += 1;
            self.digits()?;
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            self.digits()?;
        }
        Ok(())
    }

    fn lit(&mut self, word: &str) -> Result<(), String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(())
        } else {
            Err(self.err("bad literal"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::validate;

    #[test]
    fn accepts_well_formed() {
        for s in [
            "{}",
            "[]",
            "null",
            "true",
            "-1.5e+3",
            "\"a\\u00e9\\n\"",
            "{\"a\":[1,2,{\"b\":null}],\"c\":\"x\"}",
            " { \"k\" : [ 1 , 2 ] } ",
        ] {
            validate(s).unwrap_or_else(|e| panic!("{s}: {e}"));
        }
    }

    #[test]
    fn rejects_malformed() {
        for s in [
            "",
            "{",
            "[1,]",
            "{\"a\":}",
            "{\"a\" 1}",
            "\"unterminated",
            "01x",
            "nul",
            "{} {}",
            "\"bad\\q\"",
            "[1 2]",
        ] {
            assert!(validate(s).is_err(), "{s} should be rejected");
        }
    }
}
