//! Minimal JSON validity checker (recursive descent, no allocation of
//! a document model) plus the flat-object field extractors shared by
//! the bench gate and the sweep differ. The crate is dependency-free,
//! but tests and the trace exporter need to assert "this artifact is
//! well-formed JSON", and the regression tooling needs to pull labels
//! and metrics back out of the artifacts this crate itself writes —
//! this module is exactly those two capabilities, nothing more.

/// Validate that `s` is one well-formed JSON value (with surrounding
/// whitespace allowed). `Err` carries the byte offset and what went
/// wrong.
pub fn validate(s: &str) -> Result<(), String> {
    let mut p = Parser { b: s.as_bytes(), i: 0 };
    p.ws();
    p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(())
}

/// Numeric value of `key` in a flat `{...}` object string.
pub fn field_num(obj: &str, key: &str) -> Option<f64> {
    let pat = format!("\"{key}\":");
    let rest = obj[obj.find(&pat)? + pat.len()..].trim_start();
    let end = rest
        .find(|c: char| c == ',' || c == '}' || c.is_whitespace())
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

/// String value of `key` in a flat `{...}` object string.
pub fn field_str(obj: &str, key: &str) -> Option<String> {
    let pat = format!("\"{key}\":");
    let rest = obj[obj.find(&pat)? + pat.len()..].trim_start();
    let quoted = rest.strip_prefix('"')?;
    Some(quoted[..quoted.find('"')?].to_string())
}

/// Innermost `{...}` spans of a document. The artifacts this crate
/// writes keep their result rows as flat objects inside arrays, so the
/// innermost spans are exactly the rows; enclosing objects (which
/// contain them) never appear.
pub fn flat_objects(json: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut start = None;
    for (i, b) in json.bytes().enumerate() {
        match b {
            b'{' => start = Some(i),
            b'}' => {
                if let Some(s) = start.take() {
                    out.push(&json[s..=i]);
                }
            }
            _ => {}
        }
    }
    out
}

/// A scalar field value inside a flat object.
#[derive(Debug, Clone, PartialEq)]
pub enum FieldValue {
    Num(f64),
    Str(String),
    /// Bool / null / array — present but not a gateable scalar.
    Other,
}

/// Every `"key": value` pair of a flat `{...}` object, in document
/// order. String values keep escapes verbatim (our writers never emit
/// any); array values are skipped as [`FieldValue::Other`].
pub fn flat_fields(obj: &str) -> Vec<(String, FieldValue)> {
    fn take_str(b: &[u8], mut i: usize) -> Option<(String, usize)> {
        if b.get(i) != Some(&b'"') {
            return None;
        }
        i += 1;
        let start = i;
        while i < b.len() {
            match b[i] {
                b'\\' => i += 2,
                b'"' => {
                    let s = String::from_utf8_lossy(&b[start..i]).into_owned();
                    return Some((s, i + 1));
                }
                _ => i += 1,
            }
        }
        None
    }
    fn skip_ws(b: &[u8], mut i: usize) -> usize {
        while matches!(b.get(i), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            i += 1;
        }
        i
    }
    let b = obj.as_bytes();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < b.len() {
        let Some((key, j)) = take_str(b, i) else {
            i += 1;
            continue;
        };
        let k = skip_ws(b, j);
        if b.get(k) != Some(&b':') {
            i = j;
            continue;
        }
        let v = skip_ws(b, k + 1);
        match b.get(v) {
            Some(b'"') => {
                if let Some((s, m)) = take_str(b, v) {
                    out.push((key, FieldValue::Str(s)));
                    i = m;
                } else {
                    i = v + 1;
                }
            }
            Some(b'[') => {
                // Skip to the matching bracket, quote-aware.
                let mut depth = 0usize;
                let mut m = v;
                while m < b.len() {
                    match b[m] {
                        b'"' => match take_str(b, m) {
                            Some((_, next)) => {
                                m = next;
                                continue;
                            }
                            None => break,
                        },
                        b'[' => depth += 1,
                        b']' => {
                            depth -= 1;
                            if depth == 0 {
                                m += 1;
                                break;
                            }
                        }
                        _ => {}
                    }
                    m += 1;
                }
                out.push((key, FieldValue::Other));
                i = m;
            }
            Some(_) => {
                let rest = &obj[v..];
                let end = rest
                    .find(|c: char| c == ',' || c == '}' || c.is_whitespace())
                    .unwrap_or(rest.len());
                let token = &rest[..end];
                match token.parse::<f64>() {
                    Ok(n) => out.push((key, FieldValue::Num(n))),
                    Err(_) => out.push((key, FieldValue::Other)),
                }
                i = v + end;
            }
            None => break,
        }
    }
    out
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn err(&self, what: &str) -> String {
        format!("{what} at byte {}", self.i)
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<(), String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => self.string(),
            Some(b't') => self.lit("true"),
            Some(b'f') => self.lit("false"),
            Some(b'n') => self.lit("null"),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<(), String> {
        self.eat(b'{')?;
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(());
        }
        loop {
            self.ws();
            self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            self.value()?;
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(());
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<(), String> {
        self.eat(b'[')?;
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(());
        }
        loop {
            self.ws();
            self.value()?;
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(());
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<(), String> {
        self.eat(b'"')?;
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(());
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"' | b'\\' | b'/' | b'b' | b'f' | b'n' | b'r' | b't') => {
                            self.i += 1;
                        }
                        Some(b'u') => {
                            self.i += 1;
                            for _ in 0..4 {
                                match self.peek() {
                                    Some(c) if c.is_ascii_hexdigit() => self.i += 1,
                                    _ => return Err(self.err("bad \\u escape")),
                                }
                            }
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                Some(c) if c < 0x20 => return Err(self.err("raw control char in string")),
                Some(_) => self.i += 1,
            }
        }
    }

    fn digits(&mut self) -> Result<(), String> {
        let start = self.i;
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.i == start {
            return Err(self.err("expected digits"));
        }
        Ok(())
    }

    fn number(&mut self) -> Result<(), String> {
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        self.digits()?;
        if self.peek() == Some(b'.') {
            self.i += 1;
            self.digits()?;
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            self.digits()?;
        }
        Ok(())
    }

    fn lit(&mut self, word: &str) -> Result<(), String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(())
        } else {
            Err(self.err("bad literal"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::{field_num, field_str, flat_fields, flat_objects, validate, FieldValue};

    #[test]
    fn flat_objects_yield_innermost_rows_only() {
        let doc = r#"{"bench":"x","results":[{"a":1},{"b":"two"}],"tail":3}"#;
        let objs = flat_objects(doc);
        assert_eq!(objs, vec![r#"{"a":1}"#, r#"{"b":"two"}"#]);
    }

    #[test]
    fn field_extractors_pull_scalars() {
        let obj = r#"{"policy":"afs","makespan":1200,"local_ratio":0.7500}"#;
        assert_eq!(field_str(obj, "policy").as_deref(), Some("afs"));
        assert_eq!(field_num(obj, "makespan"), Some(1200.0));
        assert_eq!(field_num(obj, "local_ratio"), Some(0.75));
        assert_eq!(field_num(obj, "absent"), None);
        assert_eq!(field_str(obj, "makespan"), None, "numbers are not strings");
    }

    #[test]
    fn flat_fields_enumerate_labels_and_metrics() {
        let obj = r#"{"engine":"sim","policy":"afs","makespan":1200,"ok":true,"xs":[1,"a"],"r":0.5}"#;
        let fields = flat_fields(obj);
        assert_eq!(fields.len(), 6);
        assert_eq!(fields[0], ("engine".into(), FieldValue::Str("sim".into())));
        assert_eq!(fields[2], ("makespan".into(), FieldValue::Num(1200.0)));
        assert_eq!(fields[3], ("ok".into(), FieldValue::Other));
        assert_eq!(fields[4], ("xs".into(), FieldValue::Other), "arrays are skipped whole");
        assert_eq!(fields[5], ("r".into(), FieldValue::Num(0.5)));
    }

    #[test]
    fn accepts_well_formed() {
        for s in [
            "{}",
            "[]",
            "null",
            "true",
            "-1.5e+3",
            "\"a\\u00e9\\n\"",
            "{\"a\":[1,2,{\"b\":null}],\"c\":\"x\"}",
            " { \"k\" : [ 1 , 2 ] } ",
        ] {
            validate(s).unwrap_or_else(|e| panic!("{s}: {e}"));
        }
    }

    #[test]
    fn rejects_malformed() {
        for s in [
            "",
            "{",
            "[1,]",
            "{\"a\":}",
            "{\"a\" 1}",
            "\"unterminated",
            "01x",
            "nul",
            "{} {}",
            "\"bad\\q\"",
            "[1 2]",
        ] {
            assert!(validate(s).is_err(), "{s} should be rejected");
        }
    }
}
