//! Plain-text table / duration formatting for reports and benches.

/// Format a duration in nanoseconds with an adaptive unit.
pub fn ns(v: f64) -> String {
    if v < 1e3 {
        format!("{v:.0} ns")
    } else if v < 1e6 {
        format!("{:.2} µs", v / 1e3)
    } else if v < 1e9 {
        format!("{:.2} ms", v / 1e6)
    } else {
        format!("{:.3} s", v / 1e9)
    }
}

/// Format simulated cycles with thousands separators.
pub fn cycles(v: u64) -> String {
    let s = v.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, ch) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push('_');
        }
        out.push(ch);
    }
    out
}

/// Fixed-width left-padded cell.
pub fn pad(s: &str, w: usize) -> String {
    if s.len() >= w {
        s.to_string()
    } else {
        format!("{}{}", " ".repeat(w - s.len()), s)
    }
}

/// A minimal monospace table builder (markdown-ish output).
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Start a table with the given column headers.
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row (must match the header arity).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Render with column widths fitted to content.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            w[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], w: &[usize]| -> String {
            let body: Vec<String> =
                cells.iter().zip(w).map(|(c, &wi)| format!("{c:<wi$}")).collect();
            format!("| {} |", body.join(" | "))
        };
        out.push_str(&fmt_row(&self.header, &w));
        out.push('\n');
        let seps: Vec<String> = w.iter().map(|&wi| "-".repeat(wi)).collect();
        out.push_str(&fmt_row(&seps, &w));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &w));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ns_units() {
        assert_eq!(ns(250.0), "250 ns");
        assert_eq!(ns(3_700.0), "3.70 µs");
        assert_eq!(ns(15_840_000_000.0), "15.840 s");
    }

    #[test]
    fn cycles_separators() {
        assert_eq!(cycles(1_234_567), "1_234_567");
        assert_eq!(cycles(12), "12");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "time"]);
        t.row(&["simple".into(), "23.65".into()]);
        t.row(&["bubbles".into(), "15.84".into()]);
        let r = t.render();
        assert!(r.contains("| name    | time  |"), "{r}");
        assert!(r.lines().count() == 4);
    }

    #[test]
    #[should_panic]
    fn table_rejects_bad_arity() {
        Table::new(&["a", "b"]).row(&["x".into()]);
    }
}
