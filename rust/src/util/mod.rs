//! Small self-contained utilities.
//!
//! The offline build environment vendors only the `xla` crate closure, so
//! the usual ecosystem crates (rand, statrs, proptest, ...) are
//! reimplemented here at the scale this project needs.

pub mod fmt;
pub mod json;
pub mod os;
pub mod proptest;
pub mod rng;
pub mod stats;

pub use rng::Rng;
pub use stats::Summary;
