//! A miniature property-testing harness (proptest is not vendored).
//!
//! `check(seed, cases, f)` runs `f` against `cases` independently seeded
//! [`Rng`]s. On failure it retries with the same seed to confirm
//! determinism and reports the failing case seed so the case can be
//! replayed as a targeted regression test.

use super::rng::Rng;

/// Run `cases` property checks. `f` gets a fresh deterministic Rng per
/// case; it should panic (assert!) on property violation.
///
/// Panics with the case seed on the first failing case.
pub fn check<F: Fn(&mut Rng)>(seed: u64, cases: usize, f: F) {
    let mut meta = Rng::new(seed);
    for case in 0..cases {
        let case_seed = meta.next_u64();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::new(case_seed);
            f(&mut rng);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property failed at case {case}/{cases} (replay seed {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Replay a single failing case by seed (for regression pinning).
pub fn replay<F: FnMut(&mut Rng)>(case_seed: u64, mut f: F) {
    let mut rng = Rng::new(case_seed);
    f(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        let counter = std::cell::Cell::new(0usize);
        check(1, 50, |rng| {
            let v = rng.below(100);
            assert!(v < 100);
            counter.set(counter.get() + 1);
        });
        count += counter.get();
        assert_eq!(count, 50);
    }

    #[test]
    fn failing_property_reports_seed() {
        let err = std::panic::catch_unwind(|| {
            check(2, 100, |rng| {
                // Will fail for roughly half the cases.
                assert!(rng.below(2) == 0, "hit a one");
            });
        })
        .unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("replay seed"), "{msg}");
    }

    #[test]
    fn replay_is_deterministic() {
        let mut v1 = 0;
        let mut v2 = 1;
        replay(0xdead, |r| v1 = r.below(1000));
        replay(0xdead, |r| v2 = r.below(1000));
        assert_eq!(v1, v2);
    }
}
