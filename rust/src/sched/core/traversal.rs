//! Named walks over the machine tree (all O(1) on the hot path: the
//! orders are precomputed per CPU at topology construction, see
//! `crate::topology::scan`).
//!
//! Policies pick a traversal and feed it to [`super::pick`]; nothing
//! here allocates or re-walks the tree.

use crate::topology::{CpuId, LevelId, Topology};

/// The covering chain of `cpu`, leaf → root: the paper's §3.3.2 list
/// search order ("from most local to most global").
pub fn covering(topo: &Topology, cpu: CpuId) -> &[LevelId] {
    topo.covering(cpu)
}

/// The covering chain root → leaf: the descent path a bubble rides
/// towards `cpu` (Figure 3).
pub fn descent(topo: &Topology, cpu: CpuId) -> &[LevelId] {
    topo.descent_order(cpu)
}

/// Every component, most local to `cpu` first; the covering chain is
/// the prefix, then non-covering components by hierarchical distance.
pub fn locality(topo: &Topology, cpu: CpuId) -> &[LevelId] {
    topo.locality_order(cpu)
}

/// The other CPUs' leaf lists, closest first ("sibling-by-distance"):
/// the natural steal-victim order.
pub fn steal_leaves(topo: &Topology, cpu: CpuId) -> &[LevelId] {
    topo.steal_order(cpu)
}

/// Lowest ancestor-or-self of `from` covering `cpu`: where work pulled
/// from `from` towards `cpu` is hoisted so both sides can see it.
pub fn hoist_towards(topo: &Topology, from: LevelId, cpu: CpuId) -> LevelId {
    topo.hoist_towards(from, cpu)
}

/// One step down from `from` towards `cpu` (None when `from` is already
/// the leaf): the bubble-descent step.
pub fn descend_towards(topo: &Topology, from: LevelId, cpu: CpuId) -> Option<LevelId> {
    topo.child_towards(from, cpu)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Topology;

    #[test]
    fn walks_agree_with_topology() {
        let t = Topology::deep();
        for c in 0..t.n_cpus() {
            let cpu = CpuId(c);
            assert_eq!(covering(&t, cpu), t.covering(cpu));
            assert_eq!(descent(&t, cpu).last(), Some(&t.leaf_of(cpu)));
            assert_eq!(descent(&t, cpu).first(), Some(&t.root()));
            assert_eq!(locality(&t, cpu).len(), t.n_components());
            assert_eq!(steal_leaves(&t, cpu).len(), t.n_cpus() - 1);
        }
    }

    #[test]
    fn descend_follows_hoist_back_down() {
        let t = Topology::numa(2, 2);
        let cpu = CpuId(3);
        let mut cur = t.root();
        while let Some(next) = descend_towards(&t, cur, cpu) {
            assert!(t.node(next).covers(cpu));
            cur = next;
        }
        assert_eq!(cur, t.leaf_of(cpu));
        assert_eq!(hoist_towards(&t, t.leaf_of(CpuId(0)), cpu), t.root());
    }
}
