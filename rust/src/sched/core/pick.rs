//! The generic two-pass pick (paper §4), parameterised by a scan order.
//!
//! Pass 1 reads the lock-free max-priority hints of the lists in the
//! order — earlier positions are "more local", so on priority ties the
//! earlier list wins. Pass 2 locks only the chosen list and re-pops; if
//! another processor raced us to the task, the search retries (bounded,
//! accounted in `metrics.search_retries`).
//!
//! The **pressure-aware** variants ([`pass1_pressure`] /
//! [`pick_thread_pressure`]) consult the memory subsystem's per-node
//! pressure view ([`crate::mem::MemState::node_pressure`]) in pass 1:
//! on a priority tie the list whose NUMA node has more footprint
//! *headroom* (fewer homed bytes) wins, instead of plain order
//! position — so CPUs drain work towards nodes where subsequent
//! first-touch allocation hurts least. Priority always dominates;
//! pressure only breaks ties. Redirects are accounted in
//! `metrics.pressure_redirects` and the per-level rate counters.
//!
//! Note the tie can only fire when the order holds several
//! simultaneously populated lists: under a policy that enqueues
//! exclusively onto leaves (today's `memaware` wake/stop), a covering
//! chain has one populated list and this degenerates to [`pass1`] —
//! the production home of the headroom preference is the `memaware`
//! *steal* tie-break, which scans many sibling leaves at equal
//! distance and shares the same accounting.

use super::ops;
use crate::metrics::Metrics;
use crate::sched::System;
use crate::task::{Prio, TaskId};
use crate::topology::{CpuId, LevelId};

/// Pass 1: lock-free scan of `order`, most local first. Returns the
/// list holding the (apparently) highest-priority task; ties go to the
/// earlier (more local) list.
pub fn pass1(sys: &System, order: &[LevelId]) -> Option<LevelId> {
    let mut best: Option<(LevelId, Prio)> = None;
    for &l in order {
        let p = sys.rq.peek_max(l);
        if p == i32::MIN {
            continue;
        }
        match best {
            Some((_, bp)) if p <= bp => {}
            _ => best = Some((l, p)),
        }
    }
    best.map(|(l, _)| l)
}

/// The shared two-pass skeleton: run `scan` (a pass 1 returning the
/// chosen list and whether the choice was redirected), lock, re-check,
/// retry on race (bounded, accounted in `metrics.search_retries`).
/// `on_redirect` fires only for a pop that actually succeeded, so
/// raced retries cannot inflate redirect counts.
fn two_pass_with(
    sys: &System,
    order: &[LevelId],
    scan: impl Fn(&System, &[LevelId]) -> Option<(LevelId, bool)>,
    mut on_redirect: impl FnMut(),
) -> Option<(TaskId, Prio, LevelId)> {
    let mut credits = 2 * order.len() + 8;
    while credits > 0 {
        credits -= 1;
        let (list, redirected) = scan(sys, order)?;
        match sys.rq.pop_max(list) {
            Some((task, prio)) => {
                if redirected {
                    on_redirect();
                }
                return Some((task, prio, list));
            }
            None => Metrics::inc(&sys.metrics.search_retries),
        }
    }
    None
}

/// Both passes: scan, lock, re-check, retry on race. Returns the popped
/// task, its priority, and the list it came from; None when every list
/// in the order is (or raced to) empty.
pub fn two_pass(sys: &System, order: &[LevelId]) -> Option<(TaskId, Prio, LevelId)> {
    two_pass_with(sys, order, |sys, order| pass1(sys, order).map(|l| (l, false)), || {})
}

/// The whole thread pick path for policies whose lists only ever hold
/// threads (every baseline): two-pass search + dispatch accounting.
pub fn pick_thread(sys: &System, cpu: CpuId, order: &[LevelId]) -> Option<TaskId> {
    let (task, _prio, from) = two_pass(sys, order)?;
    ops::dispatch(sys, cpu, task, from);
    Some(task)
}

/// Memory pressure of the NUMA node holding list `l` (the node of the
/// list's first CPU stands in for node-spanning lists).
fn list_pressure(sys: &System, l: LevelId) -> u64 {
    let cpu = CpuId(sys.topo.node(l).cpu_first);
    sys.mem.node_pressure(sys.topo.numa_of(cpu))
}

/// Pressure-aware pass 1: like [`pass1`], but a priority tie goes to
/// the list whose node has more footprint headroom (order position only
/// breaks exact pressure ties). Returns the chosen list and whether
/// headroom *redirected* the choice away from the plain-order winner.
pub fn pass1_pressure(sys: &System, order: &[LevelId]) -> Option<(LevelId, bool)> {
    let mut best: Option<(LevelId, Prio, u64)> = None;
    let mut redirected = false;
    for &l in order {
        let p = sys.rq.peek_max(l);
        if p == i32::MIN {
            continue;
        }
        let pressure = list_pressure(sys, l);
        match best {
            Some((_, bp, bpress)) if p > bp || (p == bp && pressure < bpress) => {
                redirected = p == bp;
                best = Some((l, p, pressure));
            }
            Some(_) => {}
            None => best = Some((l, p, pressure)),
        }
    }
    best.map(|(l, _, _)| (l, redirected))
}

/// Both passes over the pressure-aware pass 1 (see [`two_pass`]);
/// redirects of successful picks are accounted against `cpu`'s
/// covering chain.
pub fn two_pass_pressure(
    sys: &System,
    cpu: CpuId,
    order: &[LevelId],
) -> Option<(TaskId, Prio, LevelId)> {
    two_pass_with(sys, order, pass1_pressure, || {
        Metrics::inc(&sys.metrics.pressure_redirects);
        sys.rates.on_pressure_redirect(&sys.topo, cpu);
    })
}

/// Thread pick through the pressure-aware search + dispatch accounting
/// (the `memaware` policy's pick path).
pub fn pick_thread_pressure(sys: &System, cpu: CpuId, order: &[LevelId]) -> Option<TaskId> {
    let (task, _prio, from) = two_pass_pressure(sys, cpu, order)?;
    ops::dispatch(sys, cpu, task, from);
    Some(task)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::testutil::system;
    use crate::task::{TaskState, PRIO_HIGH, PRIO_THREAD};
    use crate::topology::Topology;

    #[test]
    fn pass1_prefers_local_on_ties() {
        let sys = system(Topology::numa(2, 2));
        let leaf = sys.topo.leaf_of(CpuId(0));
        let root = sys.topo.root();
        sys.rq.push(root, TaskId(0), PRIO_THREAD);
        sys.rq.push(leaf, TaskId(1), PRIO_THREAD);
        let order = sys.topo.covering(CpuId(0));
        assert_eq!(pass1(&sys, order), Some(leaf));
    }

    #[test]
    fn pass1_prefers_priority_over_locality() {
        let sys = system(Topology::numa(2, 2));
        let leaf = sys.topo.leaf_of(CpuId(0));
        let root = sys.topo.root();
        sys.rq.push(leaf, TaskId(0), PRIO_THREAD);
        sys.rq.push(root, TaskId(1), PRIO_HIGH);
        assert_eq!(pass1(&sys, sys.topo.covering(CpuId(0))), Some(root));
    }

    #[test]
    fn pick_thread_dispatches_and_accounts() {
        let sys = system(Topology::smp(2));
        let t = sys.tasks.new_thread("t", PRIO_THREAD);
        sys.tasks.set_state(t, TaskState::Ready { list: sys.topo.root() });
        sys.rq.push(sys.topo.root(), t, PRIO_THREAD);
        let got = pick_thread(&sys, CpuId(1), sys.topo.covering(CpuId(1)));
        assert_eq!(got, Some(t));
        assert_eq!(sys.tasks.state(t), TaskState::Running { cpu: CpuId(1) });
        assert_eq!(sys.metrics.picks.load(std::sync::atomic::Ordering::Relaxed), 1);
    }

    #[test]
    fn empty_order_is_none() {
        let sys = system(Topology::smp(2));
        assert_eq!(two_pass(&sys, sys.topo.covering(CpuId(0))), None);
        assert_eq!(two_pass_pressure(&sys, CpuId(0), sys.topo.covering(CpuId(0))), None);
    }

    #[test]
    fn pass1_pressure_prefers_headroom_on_ties() {
        use crate::mem::AllocPolicy;
        let sys = system(Topology::numa(2, 2));
        // Node 0 carries homed bytes; node 1 has headroom.
        let _ = sys.mem.alloc(1 << 20, AllocPolicy::Fixed(0));
        let l0 = sys.topo.leaf_of(CpuId(0)); // node 0
        let l1 = sys.topo.leaf_of(CpuId(2)); // node 1
        sys.rq.push(l0, TaskId(0), PRIO_THREAD);
        sys.rq.push(l1, TaskId(1), PRIO_THREAD);
        let order = [l0, l1];
        // Plain pass 1: the earlier list wins the tie.
        assert_eq!(pass1(&sys, &order), Some(l0));
        // Pressure-aware: node 1's headroom redirects the pick.
        assert_eq!(pass1_pressure(&sys, &order), Some((l1, true)));
        // Priority still dominates pressure.
        sys.rq.push(l0, TaskId(2), PRIO_HIGH);
        assert_eq!(pass1_pressure(&sys, &order), Some((l0, false)));
    }

    #[test]
    fn pick_thread_pressure_accounts_redirects() {
        use crate::mem::AllocPolicy;
        use std::sync::atomic::Ordering;
        let sys = system(Topology::numa(2, 2));
        let _ = sys.mem.alloc(4096, AllocPolicy::Fixed(0));
        let l0 = sys.topo.leaf_of(CpuId(0));
        let l1 = sys.topo.leaf_of(CpuId(2));
        let a = sys.tasks.new_thread("a", PRIO_THREAD);
        let b = sys.tasks.new_thread("b", PRIO_THREAD);
        ops::enqueue(&sys, a, l0);
        ops::enqueue(&sys, b, l1);
        let got = pick_thread_pressure(&sys, CpuId(0), &[l0, l1]);
        assert_eq!(got, Some(b), "headroom list must win the tie");
        assert_eq!(sys.metrics.pressure_redirects.load(Ordering::Relaxed), 1);
        assert_eq!(sys.rates.snap(sys.topo.root()).pressure_redirects, 1);
        // Equal pressure: plain order (locality) decides, no redirect.
        let sys2 = system(Topology::numa(2, 2));
        let c = sys2.tasks.new_thread("c", PRIO_THREAD);
        let d = sys2.tasks.new_thread("d", PRIO_THREAD);
        let m0 = sys2.topo.leaf_of(CpuId(0));
        let m1 = sys2.topo.leaf_of(CpuId(2));
        ops::enqueue(&sys2, c, m0);
        ops::enqueue(&sys2, d, m1);
        assert_eq!(pick_thread_pressure(&sys2, CpuId(0), &[m0, m1]), Some(c));
        assert_eq!(sys2.metrics.pressure_redirects.load(Ordering::Relaxed), 0);
    }
}
