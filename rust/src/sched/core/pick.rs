//! The generic two-pass pick (paper §4), parameterised by a scan order.
//!
//! Pass 1 reads the lock-free max-priority hints of the lists in the
//! order — earlier positions are "more local", so on priority ties the
//! earlier list wins. Pass 2 locks only the chosen list and re-pops; if
//! another processor raced us to the task, the search retries (bounded,
//! accounted in `metrics.search_retries`).

use super::ops;
use crate::metrics::Metrics;
use crate::sched::System;
use crate::task::{Prio, TaskId};
use crate::topology::{CpuId, LevelId};

/// Pass 1: lock-free scan of `order`, most local first. Returns the
/// list holding the (apparently) highest-priority task; ties go to the
/// earlier (more local) list.
pub fn pass1(sys: &System, order: &[LevelId]) -> Option<LevelId> {
    let mut best: Option<(LevelId, Prio)> = None;
    for &l in order {
        let p = sys.rq.peek_max(l);
        if p == i32::MIN {
            continue;
        }
        match best {
            Some((_, bp)) if p <= bp => {}
            _ => best = Some((l, p)),
        }
    }
    best.map(|(l, _)| l)
}

/// Both passes: scan, lock, re-check, retry on race. Returns the popped
/// task, its priority, and the list it came from; None when every list
/// in the order is (or raced to) empty.
pub fn two_pass(sys: &System, order: &[LevelId]) -> Option<(TaskId, Prio, LevelId)> {
    let mut credits = 2 * order.len() + 8;
    while credits > 0 {
        credits -= 1;
        let list = pass1(sys, order)?;
        match sys.rq.pop_max(list) {
            Some((task, prio)) => return Some((task, prio, list)),
            None => Metrics::inc(&sys.metrics.search_retries),
        }
    }
    None
}

/// The whole thread pick path for policies whose lists only ever hold
/// threads (every baseline): two-pass search + dispatch accounting.
pub fn pick_thread(sys: &System, cpu: CpuId, order: &[LevelId]) -> Option<TaskId> {
    let (task, _prio, from) = two_pass(sys, order)?;
    ops::dispatch(sys, cpu, task, from);
    Some(task)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::testutil::system;
    use crate::task::{TaskState, PRIO_HIGH, PRIO_THREAD};
    use crate::topology::Topology;

    #[test]
    fn pass1_prefers_local_on_ties() {
        let sys = system(Topology::numa(2, 2));
        let leaf = sys.topo.leaf_of(CpuId(0));
        let root = sys.topo.root();
        sys.rq.push(root, TaskId(0), PRIO_THREAD);
        sys.rq.push(leaf, TaskId(1), PRIO_THREAD);
        let order = sys.topo.covering(CpuId(0));
        assert_eq!(pass1(&sys, order), Some(leaf));
    }

    #[test]
    fn pass1_prefers_priority_over_locality() {
        let sys = system(Topology::numa(2, 2));
        let leaf = sys.topo.leaf_of(CpuId(0));
        let root = sys.topo.root();
        sys.rq.push(leaf, TaskId(0), PRIO_THREAD);
        sys.rq.push(root, TaskId(1), PRIO_HIGH);
        assert_eq!(pass1(&sys, sys.topo.covering(CpuId(0))), Some(root));
    }

    #[test]
    fn pick_thread_dispatches_and_accounts() {
        let sys = system(Topology::smp(2));
        let t = sys.tasks.new_thread("t", PRIO_THREAD);
        sys.tasks.set_state(t, TaskState::Ready { list: sys.topo.root() });
        sys.rq.push(sys.topo.root(), t, PRIO_THREAD);
        let got = pick_thread(&sys, CpuId(1), sys.topo.covering(CpuId(1)));
        assert_eq!(got, Some(t));
        assert_eq!(sys.tasks.state(t), TaskState::Running { cpu: CpuId(1) });
        assert_eq!(sys.metrics.picks.load(std::sync::atomic::Ordering::Relaxed), 1);
    }

    #[test]
    fn empty_order_is_none() {
        let sys = system(Topology::smp(2));
        assert_eq!(two_pass(&sys, sys.topo.covering(CpuId(0))), None);
    }
}
