//! Reusable state-transition building blocks: queueing, dispatch, the
//! default stop protocol, bubble flattening, and the steal family.
//!
//! Every function keeps task state, trace events, metrics and
//! [`super::stats::LoadStats`] consistent, so policies compose them
//! without re-implementing the accounting.

use crate::metrics::Metrics;
use crate::sched::{StopReason, System};
use crate::task::{Prio, TaskId, TaskState};
use crate::topology::{CpuId, LevelId};
use crate::trace::{Event, StopWhy};

/// Enqueue `task` on `list`, fixing its state and affinity hint.
pub fn enqueue(sys: &System, task: TaskId, list: LevelId) {
    let prio = sys.tasks.with(task, |t| {
        t.state = TaskState::Ready { list };
        t.last_list = Some(list);
        t.prio
    });
    sys.rq.push(list, task, prio);
    sys.trace_emit(|| Event::Enqueue { task, list });
    // Wake parked idle workers (native executor); no-op under the
    // polling simulator.
    sys.notify_enqueue();
}

/// Mark a popped task Running on `cpu`, accounting migrations, picks,
/// per-level running counters and the trace.
pub fn dispatch(sys: &System, cpu: CpuId, task: TaskId, from: LevelId) {
    sys.tasks.with(task, |t| {
        if let Some(last) = t.last_cpu {
            if last != cpu {
                Metrics::inc(&sys.metrics.migrations);
                if sys.topo.numa_of(last) != sys.topo.numa_of(cpu) {
                    Metrics::inc(&sys.metrics.cross_node_migrations);
                    sys.rates.on_cross_node(&sys.topo, cpu);
                }
            }
        }
        t.state = TaskState::Running { cpu };
        t.last_cpu = Some(cpu);
        t.last_list = Some(from);
    });
    sys.stats.on_dispatch(&sys.topo, cpu);
    Metrics::inc(&sys.metrics.picks);
    sys.trace_emit(|| Event::Dispatch { task, cpu });
}

/// Account that the task running on `cpu` stopped (whatever the
/// reason). Every [`crate::sched::Scheduler::stop`] implementation must
/// call this exactly once per stop — [`default_stop`] does it for you.
pub fn note_stop(sys: &System, cpu: CpuId) {
    sys.stats.on_stop(&sys.topo, cpu);
}

/// The outermost bubble containing `task` (itself when loose) — the
/// unit gang-style policies (`gang`, `moldable-gang`) schedule.
pub fn root_bubble(sys: &System, task: TaskId) -> TaskId {
    let mut cur = task;
    while let Some(p) = sys.tasks.parent(cur) {
        cur = p;
    }
    cur
}

/// Collect the *thread* members of a task subtree into `out`, nested
/// bubbles flattened (a loose thread is its own single member).
pub fn thread_members(sys: &System, task: TaskId, out: &mut Vec<TaskId>) {
    if sys.tasks.is_bubble(task) {
        let contents = sys.tasks.with(task, |t| t.kind_contents_snapshot());
        for c in contents {
            thread_members(sys, c, out);
        }
    } else {
        out.push(task);
    }
}

/// True while any thread member of the gang has not terminated
/// (nested bubbles flattened — a parked sub-bubble itself never
/// terminates and must not keep its gang alive).
pub fn gang_live(sys: &System, gang: TaskId) -> bool {
    let mut ms = Vec::new();
    thread_members(sys, gang, &mut ms);
    ms.iter().any(|&m| sys.tasks.state(m) != TaskState::Terminated)
}

/// Flatten-wake: threads go through `push`; bubbles recursively release
/// their contents (opportunist schedulers ignore structure — that is
/// precisely the paper's criticism of them). The whole release runs as
/// one [`System::wake_batch`], so waking an N-thread bubble notifies
/// the executor's parked workers once, not N times.
pub fn flatten_wake(sys: &System, task: TaskId, push: &mut dyn FnMut(&System, TaskId)) {
    sys.wake_batch(|| flatten_wake_inner(sys, task, push));
}

fn flatten_wake_inner(sys: &System, task: TaskId, push: &mut dyn FnMut(&System, TaskId)) {
    if sys.tasks.is_bubble(task) {
        let contents = sys.tasks.with(task, |t| t.kind_contents_snapshot());
        // The bubble itself is inert for baselines: park it off-list.
        sys.tasks.with(task, |t| t.state = TaskState::Blocked);
        for c in contents {
            flatten_wake_inner(sys, c, push);
        }
    } else {
        push(sys, task);
    }
}

/// Default `stop` behaviour shared by the list baselines: requeue on
/// yield/preempt via `requeue`, Block/Terminate adjust state only.
pub fn default_stop(
    sys: &System,
    cpu: CpuId,
    task: TaskId,
    why: StopReason,
    requeue: &mut dyn FnMut(&System, TaskId),
) {
    use StopReason::*;
    note_stop(sys, cpu);
    match why {
        Yield | Preempt => {
            sys.trace_emit(|| Event::Stop {
                task,
                cpu,
                why: if why == Yield { StopWhy::Yield } else { StopWhy::Preempt },
            });
            if why == Preempt {
                Metrics::inc(&sys.metrics.preemptions);
            }
            requeue(sys, task);
        }
        Block => {
            sys.trace_emit(|| Event::Stop { task, cpu, why: StopWhy::Block });
            sys.tasks.set_state(task, TaskState::Blocked);
        }
        Terminate => {
            sys.trace_emit(|| Event::Stop { task, cpu, why: StopWhy::Terminate });
            sys.tasks.set_state(task, TaskState::Terminated);
        }
    }
}

// ----------------------------------------------------------- placement

/// Most loaded leaf list among `cpus`, if any is non-empty (O(1) per
/// list: lock-free count hints).
pub fn most_loaded_leaf(sys: &System, cpus: impl Iterator<Item = CpuId>) -> Option<LevelId> {
    let mut best: Option<(LevelId, usize)> = None;
    for cpu in cpus {
        let l = sys.topo.leaf_of(cpu);
        let n = sys.rq.len_of(l);
        if n > best.map_or(0, |(_, b)| b) {
            best = Some((l, n));
        }
    }
    best.map(|(l, _)| l)
}

/// Least loaded leaf among `cpus` (for initial placement). Load counts
/// both queued *and* currently-running work (the [`super::stats`]
/// counters), so a CPU that is busy but has an empty queue is not
/// mistaken for an idle one. Ties are broken by a rotating offset:
/// real wake-placement is effectively arbitrary among equally loaded
/// CPUs, and a fixed tie-break would give the opportunist baselines
/// accidental (unrealistic) locality — all new threads piling onto
/// cpu0's node. The rotation counter lives on the [`System`] (not a
/// process-wide static) so seeded runs are reproducible in-process.
pub fn least_loaded_leaf(sys: &System, cpus: impl Iterator<Item = CpuId>) -> LevelId {
    let all: Vec<CpuId> = cpus.collect();
    let off = sys.next_placement_rot() % all.len().max(1);
    let mut best: Option<(LevelId, usize)> = None;
    for i in 0..all.len() {
        let cpu = all[(i + off) % all.len()];
        let l = sys.topo.leaf_of(cpu);
        let n = sys.rq.len_of(l) + sys.stats.running(l);
        if best.map_or(true, |(_, b)| n < b) {
            best = Some((l, n));
        }
    }
    best.expect("no cpus").0
}

// --------------------------------------------------------------- steal

/// Pop the best task of `victim` on behalf of `cpu`, accounting the
/// steal (metric + trace) on success.
pub fn pop_steal(sys: &System, cpu: CpuId, victim: LevelId) -> Option<(TaskId, Prio)> {
    let (task, prio) = sys.rq.pop_max(victim)?;
    Metrics::inc(&sys.metrics.steals);
    sys.trace_emit(|| Event::Steal { task, from: victim, by: cpu });
    Some((task, prio))
}

/// Start a steal-search timer iff tracing is on (the timer is two host
/// clock reads — not worth paying on every search otherwise).
fn steal_timer(sys: &System) -> Option<std::time::Instant> {
    sys.trace.enabled().then(std::time::Instant::now)
}

/// Record one finished steal search: latency histogram + StealAttempt
/// trace record. `scope` is the widest level the search considered
/// (the victim's list on a success, the searched root on a miss).
fn note_steal_search(
    sys: &System,
    cpu: CpuId,
    scope: LevelId,
    ok: bool,
    t0: Option<std::time::Instant>,
) {
    let Some(t0) = t0 else { return };
    let ns = (t0.elapsed().as_nanos() as u64).max(1);
    sys.metrics.steal_latency.record(ns);
    sys.trace.emit(sys.now(), Event::StealAttempt { by: cpu, scope, ok, ns });
}

/// Account one steal search that came up empty (metric + per-level
/// rate counters, the adaptive policy's widen signal). Every steal
/// helper here calls it on its `None` path; hand-rolled policy steals
/// should too.
pub fn note_steal_fail(sys: &System, cpu: CpuId) {
    Metrics::inc(&sys.metrics.steal_fails);
    sys.rates.on_steal_fail(&sys.topo, cpu);
}

/// Steal from the fullest list that does *not* cover `cpu` (the bubble
/// scheduler's last-resort rebalancing). O(1) bail-out when the whole
/// machine is empty (root subtree counter).
pub fn steal_fullest(sys: &System, cpu: CpuId) -> Option<(TaskId, LevelId)> {
    sys.rates.on_steal_attempt(&sys.topo, cpu);
    let t0 = steal_timer(sys);
    if sys.rq.total_queued() == 0 {
        note_steal_fail(sys, cpu);
        note_steal_search(sys, cpu, sys.topo.root(), false, t0);
        return None;
    }
    let mut victim: Option<(LevelId, usize)> = None;
    for i in 0..sys.rq.len() {
        let l = LevelId(i);
        if sys.topo.node(l).covers(cpu) {
            continue;
        }
        let len = sys.rq.len_of(l);
        if len > victim.map_or(0, |(_, n)| n) {
            victim = Some((l, len));
        }
    }
    let out =
        victim.and_then(|(l, _)| pop_steal(sys, cpu, l).map(|(task, _prio)| (task, l)));
    if out.is_none() {
        note_steal_fail(sys, cpu);
    }
    note_steal_search(sys, cpu, out.map_or(sys.topo.root(), |(_, v)| v), out.is_some(), t0);
    out
}

/// Steal from the closest loaded CPU (LDS, §2.2): walk the precomputed
/// closest-first victim order; within a tie group of equal hierarchical
/// distance the fullest victim wins.
pub fn steal_closest(sys: &System, cpu: CpuId) -> Option<(TaskId, LevelId)> {
    sys.rates.on_steal_attempt(&sys.topo, cpu);
    let t0 = steal_timer(sys);
    let order = sys.topo.steal_order(cpu);
    let sep = |l: LevelId| sys.topo.separation(cpu, CpuId(sys.topo.node(l).cpu_first));
    let mut i = 0;
    while i < order.len() {
        let d = sep(order[i]);
        let mut j = i;
        let mut best: Option<(usize, LevelId)> = None;
        while j < order.len() && sep(order[j]) == d {
            let n = sys.rq.len_of(order[j]);
            if n > 0 && best.map_or(true, |(bn, _)| n > bn) {
                best = Some((n, order[j]));
            }
            j += 1;
        }
        if let Some((_, v)) = best {
            if let Some((task, _)) = pop_steal(sys, cpu, v) {
                note_steal_search(sys, cpu, v, true, t0);
                return Some((task, v));
            }
        }
        i = j;
    }
    note_steal_fail(sys, cpu);
    note_steal_search(sys, cpu, sys.topo.root(), false, t0);
    None
}

/// Steal from the most loaded CPU machine-wide (AFS, §2.2: the Linux
/// 2.6 / FreeBSD 5 "rebalance" structure).
pub fn steal_most_loaded(sys: &System, cpu: CpuId) -> Option<(TaskId, LevelId)> {
    sys.rates.on_steal_attempt(&sys.topo, cpu);
    let t0 = steal_timer(sys);
    let out = most_loaded_leaf(sys, (0..sys.topo.n_cpus()).map(CpuId).filter(|&c| c != cpu))
        .and_then(|v| pop_steal(sys, cpu, v).map(|(task, _prio)| (task, v)));
    if out.is_none() {
        note_steal_fail(sys, cpu);
    }
    note_steal_search(sys, cpu, out.map_or(sys.topo.root(), |(_, v)| v), out.is_some(), t0);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::testutil::system;
    use crate::task::PRIO_THREAD;
    use crate::topology::Topology;

    #[test]
    fn enqueue_dispatch_roundtrip_keeps_stats() {
        let sys = system(Topology::numa(2, 2));
        let t = sys.tasks.new_thread("t", PRIO_THREAD);
        enqueue(&sys, t, sys.topo.root());
        assert!(sys.tasks.state(t).is_ready());
        dispatch(&sys, CpuId(1), t, sys.topo.root());
        assert_eq!(sys.stats.running(sys.topo.root()), 1);
        assert_eq!(sys.stats.running(sys.topo.leaf_of(CpuId(1))), 1);
        assert_eq!(sys.stats.running(sys.topo.leaf_of(CpuId(0))), 0);
        note_stop(&sys, CpuId(1));
        assert_eq!(sys.stats.running(sys.topo.root()), 0);
    }

    #[test]
    fn steal_fullest_skips_covering_lists() {
        let sys = system(Topology::numa(2, 1));
        let my_leaf = sys.topo.leaf_of(CpuId(0));
        let other_leaf = sys.topo.leaf_of(CpuId(1));
        let a = sys.tasks.new_thread("a", PRIO_THREAD);
        let b = sys.tasks.new_thread("b", PRIO_THREAD);
        enqueue(&sys, a, my_leaf);
        enqueue(&sys, b, other_leaf);
        let (task, from) = steal_fullest(&sys, CpuId(0)).unwrap();
        assert_eq!((task, from), (b, other_leaf));
        // Machine-empty fast path.
        sys.rq.pop_max(my_leaf);
        assert!(steal_fullest(&sys, CpuId(0)).is_none());
    }

    #[test]
    fn steal_closest_prefers_near_victims() {
        let sys = system(Topology::numa(2, 2));
        let near = sys.tasks.new_thread("near", PRIO_THREAD);
        let far = sys.tasks.new_thread("far", PRIO_THREAD);
        enqueue(&sys, near, sys.topo.leaf_of(CpuId(1)));
        enqueue(&sys, far, sys.topo.leaf_of(CpuId(2)));
        let (task, from) = steal_closest(&sys, CpuId(0)).unwrap();
        assert_eq!(task, near);
        assert_eq!(from, sys.topo.leaf_of(CpuId(1)));
    }
}
