//! Incrementally-maintained per-level load statistics.
//!
//! The load surfaces a policy can consult in O(1), instead of
//! rescanning lists:
//!
//! * **task count** — `sys.rq.len_of(l)` (per-list lock-free hint) and
//!   `sys.rq.queued_subtree(l)` (per-level subtree occupancy);
//! * **max priority** — `sys.rq.peek_max(l)` (per-list lock-free hint);
//! * **running count** — [`LoadStats::running`], maintained here: how
//!   many threads are currently executing on CPUs covered by component
//!   `l`. Updated along the covering chain (O(depth)) on every
//!   dispatch/stop by [`super::ops::dispatch`]/[`super::ops::note_stop`].
//! * **event rates** — [`RateStats`]: monotonic per-component counters
//!   of the *feedback* signals an online policy adapts on — steal
//!   attempts and failures, cross-node migrations, idle polls,
//!   pressure-redirected picks — attributed along the acting CPU's
//!   covering chain like the running counts. A feedback policy (the
//!   ARMS-style `adaptive` scheduler)
//!   snapshots a component with [`RateStats::snap`] and diffs two
//!   snapshots to get the rate over its own decision epoch; nothing
//!   here decays or windows, so readers choose their own horizon.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use crate::topology::{CpuId, LevelId, Topology};

/// Per-component running-thread counters.
#[derive(Debug)]
pub struct LoadStats {
    running: Vec<AtomicUsize>,
}

impl LoadStats {
    /// Zeroed counters for a machine.
    pub fn new(topo: &Topology) -> LoadStats {
        LoadStats {
            running: (0..topo.n_components()).map(|_| AtomicUsize::new(0)).collect(),
        }
    }

    /// A thread was dispatched on `cpu`: bump every covering component.
    pub fn on_dispatch(&self, topo: &Topology, cpu: CpuId) {
        for &l in topo.covering(cpu) {
            self.running[l.0].fetch_add(1, Ordering::Relaxed);
        }
    }

    /// The thread running on `cpu` stopped (any reason). Saturating so
    /// an unbalanced call cannot wrap the counters.
    pub fn on_stop(&self, topo: &Topology, cpu: CpuId) {
        for &l in topo.covering(cpu) {
            let _ = self.running[l.0]
                .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| Some(v.saturating_sub(1)));
        }
    }

    /// Threads currently running on CPUs covered by `l` (advisory).
    pub fn running(&self, l: LevelId) -> usize {
        self.running[l.0].load(Ordering::Relaxed)
    }
}

/// One component's cumulative event counts at a point in time (diff two
/// of these for a rate over an interval).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RateSnap {
    /// Steal searches started by CPUs this component covers.
    pub steal_attempts: u64,
    /// Steal searches that found no victim.
    pub steal_fails: u64,
    /// Dispatches that moved a thread across a NUMA-node boundary onto
    /// a CPU this component covers.
    pub cross_node: u64,
    /// Picks that returned nothing (the covered CPU went idle).
    pub idles: u64,
    /// Picks/steals by a covered CPU where footprint headroom
    /// redirected the choice away from the plain scan order (pass-1
    /// priority ties and `memaware` steal distance-tie groups).
    pub pressure_redirects: u64,
}

impl RateSnap {
    /// Event-wise difference against an earlier snapshot (saturating,
    /// so a racing reader cannot produce a wrap).
    pub fn since(&self, earlier: &RateSnap) -> RateSnap {
        RateSnap {
            steal_attempts: self.steal_attempts.saturating_sub(earlier.steal_attempts),
            steal_fails: self.steal_fails.saturating_sub(earlier.steal_fails),
            cross_node: self.cross_node.saturating_sub(earlier.cross_node),
            idles: self.idles.saturating_sub(earlier.idles),
            pressure_redirects: self
                .pressure_redirects
                .saturating_sub(earlier.pressure_redirects),
        }
    }

    /// Fraction of steal searches that failed in this interval (0 when
    /// none were attempted).
    pub fn fail_ratio(&self) -> f64 {
        if self.steal_attempts == 0 {
            0.0
        } else {
            self.steal_fails as f64 / self.steal_attempts as f64
        }
    }
}

/// Per-component feedback-event counters (see module docs). All
/// counters are monotonic and advisory; writers bump every component
/// covering the acting CPU, so a component's counts aggregate its
/// whole subtree.
#[derive(Debug)]
pub struct RateStats {
    steal_attempts: Vec<AtomicU64>,
    steal_fails: Vec<AtomicU64>,
    cross_node: Vec<AtomicU64>,
    idles: Vec<AtomicU64>,
    pressure_redirects: Vec<AtomicU64>,
}

impl RateStats {
    /// Zeroed counters for a machine.
    pub fn new(topo: &Topology) -> RateStats {
        let n = topo.n_components();
        let zeroed = || (0..n).map(|_| AtomicU64::new(0)).collect::<Vec<_>>();
        RateStats {
            steal_attempts: zeroed(),
            steal_fails: zeroed(),
            cross_node: zeroed(),
            idles: zeroed(),
            pressure_redirects: zeroed(),
        }
    }

    fn bump(field: &[AtomicU64], topo: &Topology, cpu: CpuId) {
        for &l in topo.covering(cpu) {
            field[l.0].fetch_add(1, Ordering::Relaxed);
        }
    }

    /// `cpu` started a steal search.
    pub fn on_steal_attempt(&self, topo: &Topology, cpu: CpuId) {
        Self::bump(&self.steal_attempts, topo, cpu);
    }

    /// `cpu`'s steal search found no victim.
    pub fn on_steal_fail(&self, topo: &Topology, cpu: CpuId) {
        Self::bump(&self.steal_fails, topo, cpu);
    }

    /// A thread crossed a NUMA boundary to resume on `cpu`.
    pub fn on_cross_node(&self, topo: &Topology, cpu: CpuId) {
        Self::bump(&self.cross_node, topo, cpu);
    }

    /// `cpu` polled for work and found none.
    pub fn on_idle(&self, topo: &Topology, cpu: CpuId) {
        Self::bump(&self.idles, topo, cpu);
    }

    /// `cpu`'s pressure-aware pass 1 redirected a pick for headroom.
    pub fn on_pressure_redirect(&self, topo: &Topology, cpu: CpuId) {
        Self::bump(&self.pressure_redirects, topo, cpu);
    }

    /// Cumulative counts for one component.
    pub fn snap(&self, l: LevelId) -> RateSnap {
        RateSnap {
            steal_attempts: self.steal_attempts[l.0].load(Ordering::Relaxed),
            steal_fails: self.steal_fails[l.0].load(Ordering::Relaxed),
            cross_node: self.cross_node[l.0].load(Ordering::Relaxed),
            idles: self.idles[l.0].load(Ordering::Relaxed),
            pressure_redirects: self.pressure_redirects[l.0].load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dispatch_stop_balance_along_chain() {
        let topo = Topology::deep();
        let stats = LoadStats::new(&topo);
        stats.on_dispatch(&topo, CpuId(0));
        stats.on_dispatch(&topo, CpuId(15));
        assert_eq!(stats.running(topo.root()), 2);
        assert_eq!(stats.running(topo.leaf_of(CpuId(0))), 1);
        assert_eq!(stats.running(topo.leaf_of(CpuId(1))), 0);
        stats.on_stop(&topo, CpuId(0));
        assert_eq!(stats.running(topo.root()), 1);
        assert_eq!(stats.running(topo.leaf_of(CpuId(0))), 0);
        // Saturating: an extra stop cannot wrap.
        stats.on_stop(&topo, CpuId(0));
        assert_eq!(stats.running(topo.leaf_of(CpuId(0))), 0);
    }

    #[test]
    fn rates_aggregate_along_chain_and_diff() {
        let topo = Topology::numa(2, 2);
        let rates = RateStats::new(&topo);
        let before = rates.snap(topo.root());
        rates.on_steal_attempt(&topo, CpuId(0));
        rates.on_steal_attempt(&topo, CpuId(0));
        rates.on_steal_fail(&topo, CpuId(0));
        rates.on_cross_node(&topo, CpuId(3));
        rates.on_idle(&topo, CpuId(3));
        // Root covers everything; leaves only their own CPU's events.
        let root = rates.snap(topo.root()).since(&before);
        assert_eq!(root.steal_attempts, 2);
        assert_eq!(root.steal_fails, 1);
        assert_eq!(root.cross_node, 1);
        assert_eq!(root.idles, 1);
        assert!((root.fail_ratio() - 0.5).abs() < 1e-12);
        let leaf0 = rates.snap(topo.leaf_of(CpuId(0)));
        assert_eq!((leaf0.steal_attempts, leaf0.cross_node), (2, 0));
        let leaf3 = rates.snap(topo.leaf_of(CpuId(3)));
        assert_eq!((leaf3.steal_attempts, leaf3.cross_node, leaf3.idles), (0, 1, 1));
        // The node above cpu3 aggregates cpu2+cpu3 events.
        let node1 = rates.snap(topo.covering(CpuId(3))[1]);
        assert_eq!(node1.cross_node, 1);
        // Empty interval: zero ratio, no wrap.
        let now = rates.snap(topo.root());
        let empty = now.since(&now);
        assert_eq!(empty, RateSnap::default());
        assert_eq!(empty.fail_ratio(), 0.0);
    }
}
