//! Incrementally-maintained per-level load statistics.
//!
//! The three load surfaces a policy can consult in O(1), instead of
//! rescanning lists:
//!
//! * **task count** — `sys.rq.len_of(l)` (per-list lock-free hint) and
//!   `sys.rq.queued_subtree(l)` (per-level subtree occupancy);
//! * **max priority** — `sys.rq.peek_max(l)` (per-list lock-free hint);
//! * **running count** — [`LoadStats::running`], maintained here: how
//!   many threads are currently executing on CPUs covered by component
//!   `l`. Updated along the covering chain (O(depth)) on every
//!   dispatch/stop by [`super::ops::dispatch`]/[`super::ops::note_stop`].

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::topology::{CpuId, LevelId, Topology};

/// Per-component running-thread counters.
#[derive(Debug)]
pub struct LoadStats {
    running: Vec<AtomicUsize>,
}

impl LoadStats {
    /// Zeroed counters for a machine.
    pub fn new(topo: &Topology) -> LoadStats {
        LoadStats {
            running: (0..topo.n_components()).map(|_| AtomicUsize::new(0)).collect(),
        }
    }

    /// A thread was dispatched on `cpu`: bump every covering component.
    pub fn on_dispatch(&self, topo: &Topology, cpu: CpuId) {
        for &l in topo.covering(cpu) {
            self.running[l.0].fetch_add(1, Ordering::Relaxed);
        }
    }

    /// The thread running on `cpu` stopped (any reason). Saturating so
    /// an unbalanced call cannot wrap the counters.
    pub fn on_stop(&self, topo: &Topology, cpu: CpuId) {
        for &l in topo.covering(cpu) {
            let _ = self.running[l.0]
                .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| Some(v.saturating_sub(1)));
        }
    }

    /// Threads currently running on CPUs covered by `l` (advisory).
    pub fn running(&self, l: LevelId) -> usize {
        self.running[l.0].load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dispatch_stop_balance_along_chain() {
        let topo = Topology::deep();
        let stats = LoadStats::new(&topo);
        stats.on_dispatch(&topo, CpuId(0));
        stats.on_dispatch(&topo, CpuId(15));
        assert_eq!(stats.running(topo.root()), 2);
        assert_eq!(stats.running(topo.leaf_of(CpuId(0))), 1);
        assert_eq!(stats.running(topo.leaf_of(CpuId(1))), 0);
        stats.on_stop(&topo, CpuId(0));
        assert_eq!(stats.running(topo.root()), 1);
        assert_eq!(stats.running(topo.leaf_of(CpuId(0))), 0);
        // Saturating: an extra stop cannot wrap.
        stats.on_stop(&topo, CpuId(0));
        assert_eq!(stats.running(topo.leaf_of(CpuId(0))), 0);
    }
}
