//! # The scheduling-primitives core
//!
//! The paper pitches its contribution as "more than a mere scheduling
//! model … a scheduling experimentation platform" (§3.3.1), and its
//! follow-up (the BubbleSched framework, arXiv 0706.2069) makes the
//! consequence explicit: portable schedulers should be *composed from
//! reusable hierarchy primitives*, not hand-written monoliths. This
//! module is that primitive layer. The bubble scheduler and every
//! baseline under [`crate::sched::baselines`] are thin policy glue over
//! it; new policies (memory-aware, adaptive, moldable — see ROADMAP
//! "Open items") should be too.
//!
//! ## Architecture
//!
//! The core is split along the three axes a hierarchical scheduler
//! varies on:
//!
//! * [`traversal`] — **where to look**. Named walks over the machine
//!   tree, all precomputed once per [`crate::topology::Topology`]
//!   (`topology::scan`): the covering chain leaf→root, its reverse
//!   (descent), the all-components most-local-first order, the
//!   closest-victim-first steal order, and O(1) hoist targets.
//! * [`pick`] — **how to take**. The paper's generic two-pass search
//!   (§4): pass 1 scans lock-free max-priority hints along *any* scan
//!   order (ties go to the more local list), pass 2 locks only the
//!   chosen list and re-checks, retrying on races. Parameterise it with
//!   a traversal and you have a pick path.
//! * [`ops`] — **what to do with it**. Reusable state-transition
//!   building blocks: enqueue/dispatch with trace+metrics accounting,
//!   the default stop protocol, bubble flattening for opportunist
//!   policies, and the steal family (fullest victim, closest victim,
//!   most-loaded victim).
//! * [`stats`] — **what the machine looks like**. Incrementally
//!   maintained per-level load statistics. Together with the runqueue
//!   hints ([`crate::rq`]: per-list task count + max-priority, per-level
//!   subtree occupancy) they let policies consult O(1) counters instead
//!   of rescanning lists: `rq.len_of(l)`, `rq.peek_max(l)`,
//!   `rq.queued_subtree(l)`, `stats.running(l)`. The same module also
//!   keeps **what has been happening** ([`stats::RateStats`],
//!   `sys.rates`): per-level steal-attempt/failure, cross-node
//!   migration and idle-poll counters that feedback policies (the
//!   `adaptive` scheduler) snapshot and diff to steer themselves.
//!
//! A fourth surface lives outside this module but is consulted the same
//! way: `sys.mem` ([`crate::mem::MemState`]) — **where the data
//! lives**. The region registry plus per-task/per-bubble NUMA footprint
//! counters, aggregated up the bubble hierarchy like `stats` aggregates
//! up the machine hierarchy.
//!
//! ## Writing a new policy in ~50 lines
//!
//! A policy implements [`crate::sched::Scheduler`] by choosing a scan
//! order and a fallback. For example, a NUMA-local policy that keeps
//! work inside the waking thread's node and steals closest-first:
//!
//! ```ignore
//! use crate::sched::core::{ops, pick, traversal};
//! use crate::sched::{Scheduler, StopReason, System};
//! use crate::task::TaskId;
//! use crate::topology::{CpuId, LevelKind};
//!
//! #[derive(Debug, Default)]
//! pub struct NumaLocalScheduler;
//!
//! impl Scheduler for NumaLocalScheduler {
//!     fn name(&self) -> String {
//!         "numa-local".into()
//!     }
//!
//!     fn wake(&self, sys: &System, task: TaskId) {
//!         // Opportunist: ignore bubble structure, place on the least
//!         // loaded leaf of the task's last NUMA node (or anywhere).
//!         ops::flatten_wake(sys, task, &mut |sys, t| {
//!             let cpus = match sys.tasks.with(t, |x| x.last_cpu) {
//!                 Some(c) => {
//!                     let node = sys.topo.ancestor_of_kind(c, LevelKind::NumaNode);
//!                     node.map(|n| sys.topo.node(n).cpus().collect::<Vec<_>>())
//!                 }
//!                 None => None,
//!             };
//!             let cpus = cpus.unwrap_or_else(|| (0..sys.topo.n_cpus()).map(CpuId).collect());
//!             let list = ops::least_loaded_leaf(sys, cpus.into_iter());
//!             ops::enqueue(sys, t, list);
//!         });
//!     }
//!
//!     fn pick(&self, sys: &System, cpu: CpuId) -> Option<TaskId> {
//!         // Two-pass over my covering chain, then steal closest-first.
//!         let order = traversal::covering(&sys.topo, cpu);
//!         if let Some(t) = pick::pick_thread(sys, cpu, order) {
//!             return Some(t);
//!         }
//!         let (t, _from) = ops::steal_closest(sys, cpu)?;
//!         ops::dispatch(sys, cpu, t, sys.topo.leaf_of(cpu));
//!         Some(t)
//!     }
//!
//!     fn stop(&self, sys: &System, cpu: CpuId, task: TaskId, why: StopReason) {
//!         ops::default_stop(sys, cpu, task, why, &mut |sys, t| {
//!             ops::enqueue(sys, t, sys.topo.leaf_of(cpu))
//!         });
//!     }
//! }
//! ```
//!
//! Register it in [`crate::sched::factory`] (one table entry: name,
//! summary, build function) and it is reachable from the config file,
//! the CLI (`repro schedulers` lists it) and every experiment harness.
//!
//! ## Consulting the memory footprint from a policy
//!
//! The paper's locality argument (§5.2: local node access is ~3×
//! faster) only becomes actionable once the policy can ask *where a
//! task's data lives*. That is one call against `sys.mem`
//! ([`crate::mem::Footprint`] aggregates region bytes up the bubble
//! hierarchy, so it works for bubbles and threads alike):
//!
//! ```ignore
//! // wake: place the task on the node holding most of its data.
//! let list = match sys.mem.dominant_node(task) {
//!     Some(node) => {
//!         let cpus = (0..sys.topo.n_cpus())
//!             .map(CpuId)
//!             .filter(|&c| sys.topo.numa_of(c) == node);
//!         ops::least_loaded_leaf(sys, cpus)
//!     }
//!     None => ops::least_loaded_leaf(sys, (0..sys.topo.n_cpus()).map(CpuId)),
//! };
//! ops::enqueue(sys, task, list);
//!
//! // steal: price the candidate victim before popping it.
//! let vnode = sys.topo.numa_of(CpuId(sys.topo.node(victim).cpu_first));
//! if dist.mem_factor(&sys.topo, cpu, vnode) > max_factor {
//!     // remote-access surcharge exceeds the idle-CPU gain: refuse,
//!     // or steal anyway and mark the thread's regions next-touch so
//!     // its memory follows it:
//!     sys.mem.mark_task_regions_next_touch(task);
//! }
//! ```
//!
//! [`crate::sched::MemAwareScheduler`] is the worked example: ~100
//! lines of glue over these primitives, registered as `memaware`.
//!
//! ## Invariants the core maintains for you
//!
//! * `ops::enqueue`/`ops::dispatch` keep `TaskState`, `last_list`,
//!   `last_cpu`, migration/pick metrics and the trace consistent.
//! * `ops::dispatch`/`ops::note_stop` keep [`stats::LoadStats`] running
//!   counters balanced (every `Scheduler::stop` implementation must go
//!   through `default_stop` or call `note_stop` once).
//! * `pick::two_pass` accounts `search_retries` and bounds the retry
//!   loop, so a policy cannot spin forever on hint races.

pub mod ops;
pub mod pick;
pub mod stats;
pub mod traversal;
