//! Policy registry: every scheduler is registered here once — name,
//! aliases, a one-line summary, and a build function — and `config`,
//! `cli` and the experiment harnesses instantiate and enumerate
//! policies through it instead of hardcoding matches.
//!
//! Adding a policy is one [`REGISTRY`] entry; it is then reachable from
//! config files (`[sched] kind = "..."`), the CLI (`repro schedulers`
//! lists it, `--sched <name>` selects it) and the scheduler-generic
//! property tests.

use std::sync::Arc;

use super::baselines::{
    AfsScheduler, BoundScheduler, CafsScheduler, GangScheduler, GssScheduler, HafsScheduler,
    LdsScheduler, SsScheduler, TssScheduler,
};
use super::{
    AdaptiveConfig, AdaptiveScheduler, BubbleScheduler, JobFairConfig, JobFairScheduler,
    MemAwareConfig, MemAwareScheduler, MoldableConfig, MoldableGangScheduler, Scheduler,
};
use crate::config::{SchedConfig, SchedKind};
use crate::util::fmt::Table;

/// One registered scheduling policy.
pub struct PolicyInfo {
    pub kind: SchedKind,
    /// Canonical name (what `name()` reports and configs should use).
    pub name: &'static str,
    /// Accepted alternative spellings.
    pub aliases: &'static [&'static str],
    /// One-line description for `repro schedulers`.
    pub summary: &'static str,
    build: fn(&SchedConfig) -> Arc<dyn Scheduler>,
}

static REGISTRY: [PolicyInfo; 14] = [
    PolicyInfo {
        kind: SchedKind::Bubble,
        name: "bubble",
        aliases: &["bubbles"],
        summary: "the paper's bubble scheduler: descend, burst, regenerate (§3.3)",
        build: |cfg| Arc::new(BubbleScheduler::new(cfg.bubble_config())),
    },
    PolicyInfo {
        kind: SchedKind::Ss,
        name: "ss",
        aliases: &["simple"],
        summary: "self-scheduling: one global ready list (Table-2 'Simple')",
        build: |_| Arc::new(SsScheduler::new()),
    },
    PolicyInfo {
        kind: SchedKind::Gss,
        name: "gss",
        aliases: &[],
        summary: "guided self-scheduling: idle CPUs grab ceil(remaining/p) chunks",
        build: |_| Arc::new(GssScheduler::new()),
    },
    PolicyInfo {
        kind: SchedKind::Tss,
        name: "tss",
        aliases: &[],
        summary: "trapezoid self-scheduling: linearly decreasing chunks",
        build: |_| Arc::new(TssScheduler::new()),
    },
    PolicyInfo {
        kind: SchedKind::Afs,
        name: "afs",
        aliases: &[],
        summary: "affinity scheduling: per-CPU lists, steal from the most loaded CPU",
        build: |_| Arc::new(AfsScheduler::new()),
    },
    PolicyInfo {
        kind: SchedKind::Lds,
        name: "lds",
        aliases: &[],
        summary: "locality-based dynamic scheduling: steal from the closest loaded CPU",
        build: |_| Arc::new(LdsScheduler::new()),
    },
    PolicyInfo {
        kind: SchedKind::Cafs,
        name: "cafs",
        aliases: &[],
        summary: "clustered AFS: steal only within the (NUMA-aligned) group",
        build: |_| Arc::new(CafsScheduler::new()),
    },
    PolicyInfo {
        kind: SchedKind::Hafs,
        name: "hafs",
        aliases: &[],
        summary: "hierarchical AFS: dry groups raid the most loaded group",
        build: |_| Arc::new(HafsScheduler::new()),
    },
    PolicyInfo {
        kind: SchedKind::Bound,
        name: "bound",
        aliases: &[],
        summary: "predetermined thread-to-CPU binding (Table-2 'Bound')",
        build: |_| Arc::new(BoundScheduler::new()),
    },
    PolicyInfo {
        kind: SchedKind::Memaware,
        name: "memaware",
        aliases: &["mem", "memory-aware"],
        summary: "memory-aware: place by NUMA footprint, refuse costly remote steals",
        build: |cfg| {
            Arc::new(MemAwareScheduler::new(MemAwareConfig {
                // The machine section's distance model (asymmetric
                // matrices included) prices the steals, not the
                // built-in NovaScale default.
                dist: cfg.dist.clone(),
                ..MemAwareConfig::default()
            }))
        },
    },
    PolicyInfo {
        kind: SchedKind::Gang,
        name: "gang",
        aliases: &[],
        summary: "Ousterhout gang scheduling: one gang owns the whole machine",
        build: |cfg| Arc::new(GangScheduler::new(cfg.timeslice.unwrap_or(1_000_000))),
    },
    PolicyInfo {
        kind: SchedKind::Adaptive,
        name: "adaptive",
        aliases: &["arms", "adaptive-scope"],
        summary: "adaptive steal scope: widen on steal failures, narrow with hysteresis \
                  (knobs: sched.adapt_widen_after / adapt_epoch / adapt_hysteresis)",
        build: |cfg| {
            Arc::new(AdaptiveScheduler::new(AdaptiveConfig {
                widen_after: cfg.adapt_widen_after,
                epoch: cfg.adapt_epoch,
                hysteresis: cfg.adapt_hysteresis,
                ..AdaptiveConfig::default()
            }))
        },
    },
    PolicyInfo {
        kind: SchedKind::MoldableGang,
        name: "moldable-gang",
        aliases: &["moldable", "mgang"],
        summary: "moldable gangs: shrink a gang's CPU set instead of idling processors \
                  (knobs: sched.resize_hysteresis, sched.timeslice for rotation)",
        build: |cfg| {
            Arc::new(MoldableGangScheduler::new(MoldableConfig {
                resize_hysteresis: cfg.resize_hysteresis,
                timeslice: cfg.timeslice,
            }))
        },
    },
    PolicyInfo {
        kind: SchedKind::JobFair,
        name: "job-fair",
        aliases: &["jobs", "jobfair"],
        summary: "cross-job fairness for the server mode: deadline-class admission, \
                  starvation squeezes (knobs: sched.resize_hysteresis, sched.timeslice)",
        build: |cfg| {
            Arc::new(JobFairScheduler::new(JobFairConfig {
                resize_hysteresis: cfg.resize_hysteresis,
                starve_hysteresis: cfg.resize_hysteresis,
                timeslice: cfg.timeslice,
                static_partition: false,
            }))
        },
    },
];

/// All registered policies, in presentation order.
pub fn registry() -> &'static [PolicyInfo] {
    &REGISTRY
}

/// Look a policy up by canonical name or alias (ASCII case-insensitive).
pub fn lookup(name: &str) -> Option<&'static PolicyInfo> {
    REGISTRY.iter().find(|e| {
        e.name.eq_ignore_ascii_case(name)
            || e.aliases.iter().any(|a| a.eq_ignore_ascii_case(name))
    })
}

/// Registry entry of a kind (every kind is registered).
pub fn info(kind: SchedKind) -> &'static PolicyInfo {
    REGISTRY
        .iter()
        .find(|e| e.kind == kind)
        .expect("unregistered scheduler kind")
}

/// Instantiate any scheduler by config.
pub fn make(cfg: &SchedConfig) -> Arc<dyn Scheduler> {
    (info(cfg.kind).build)(cfg)
}

/// Instantiate with defaults for a kind.
pub fn make_default(kind: SchedKind) -> Arc<dyn Scheduler> {
    make(&SchedConfig { kind, ..SchedConfig::default() })
}

/// Human-readable listing for `repro schedulers` / `--sched list`.
pub fn render_list() -> String {
    let mut t = Table::new(&["name", "aliases", "description"]);
    for e in registry() {
        t.row(&[e.name.to_string(), e.aliases.join(", "), e.summary.to_string()]);
    }
    format!(
        "registered scheduling policies ({}):\n\n{}",
        registry().len(),
        t.render()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_kind_is_registered_and_buildable() {
        for &kind in SchedKind::all() {
            let e = info(kind);
            assert_eq!(e.kind, kind);
            let s = make_default(kind);
            assert_eq!(s.name(), e.name, "name() must match the registry");
        }
    }

    #[test]
    fn lookup_accepts_aliases_case_insensitively() {
        assert_eq!(lookup("bubbles").unwrap().kind, SchedKind::Bubble);
        assert_eq!(lookup("SIMPLE").unwrap().kind, SchedKind::Ss);
        assert_eq!(lookup("Hafs").unwrap().kind, SchedKind::Hafs);
        assert!(lookup("nope").is_none());
    }

    #[test]
    fn render_list_mentions_every_policy() {
        let out = render_list();
        for e in registry() {
            assert!(out.contains(e.name), "{} missing from listing", e.name);
        }
    }
}
