//! Shared scheduler state: topology + task table + list hierarchy +
//! metrics + trace, bundled so engines and schedulers pass one handle.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::core::stats::LoadStats;
use crate::metrics::Metrics;
use crate::rq::RqHierarchy;
use crate::task::TaskTable;
use crate::topology::Topology;
use crate::trace::Trace;

/// Everything a scheduler needs to see the machine and its tasks.
#[derive(Debug)]
pub struct System {
    pub topo: Arc<Topology>,
    pub tasks: TaskTable,
    pub rq: RqHierarchy,
    /// Incremental per-level load statistics (see [`LoadStats`]),
    /// maintained by the `sched::core::ops` building blocks.
    pub stats: LoadStats,
    pub metrics: Metrics,
    pub trace: Trace,
    /// Engine clock (simulated cycles / native ns); engines advance it,
    /// schedulers read it for trace timestamps.
    clock: AtomicU64,
}

impl System {
    /// Fresh system over a machine.
    pub fn new(topo: Arc<Topology>) -> System {
        let rq = RqHierarchy::new(&topo);
        let stats = LoadStats::new(&topo);
        System {
            topo,
            tasks: TaskTable::new(),
            rq,
            stats,
            metrics: Metrics::new(),
            trace: Trace::default(),
            clock: AtomicU64::new(0),
        }
    }

    /// Current engine time.
    pub fn now(&self) -> u64 {
        self.clock.load(Ordering::Relaxed)
    }

    /// Advance the engine clock to `t` (monotonic max).
    pub fn advance_clock(&self, t: u64) {
        self.clock.fetch_max(t, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_is_monotonic() {
        let s = System::new(Arc::new(Topology::smp(2)));
        assert_eq!(s.now(), 0);
        s.advance_clock(10);
        s.advance_clock(5);
        assert_eq!(s.now(), 10);
    }

    #[test]
    fn rq_matches_topology() {
        let s = System::new(Arc::new(Topology::numa(4, 4)));
        assert_eq!(s.rq.len(), s.topo.n_components());
    }
}
