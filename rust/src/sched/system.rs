//! Shared scheduler state: topology + task table + list hierarchy +
//! metrics + trace, bundled so engines and schedulers pass one handle.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, RwLock};

use super::core::stats::{LoadStats, RateStats};
use crate::mem::{MemState, RegionId, Touch};
use crate::metrics::Metrics;
use crate::rq::RqHierarchy;
use crate::task::TaskTable;
use crate::topology::{CpuId, Topology};
use crate::trace::Trace;

/// Optional callback fired after every `ops::enqueue` (installed by the
/// native executor so idle workers wake on work arrival instead of
/// timing out; engines that poll never set it). Replaceable, so a
/// second executor over the same system takes over wakeups instead of
/// silently notifying a dead parking lot. The atomic flag keeps the
/// hookless (simulator) enqueue hot path at one relaxed load — no lock,
/// no Arc churn.
#[derive(Default)]
struct EnqueueHook {
    set: AtomicBool,
    hook: RwLock<Option<Arc<dyn Fn() + Send + Sync>>>,
}

impl std::fmt::Debug for EnqueueHook {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(if self.set.load(Ordering::Relaxed) {
            "EnqueueHook(set)"
        } else {
            "EnqueueHook(unset)"
        })
    }
}

/// Everything a scheduler needs to see the machine and its tasks.
#[derive(Debug)]
pub struct System {
    pub topo: Arc<Topology>,
    pub tasks: TaskTable,
    pub rq: RqHierarchy,
    /// Incremental per-level load statistics (see [`LoadStats`]),
    /// maintained by the `sched::core::ops` building blocks.
    pub stats: LoadStats,
    /// Per-level feedback-event rates (steal fails, cross-node
    /// migrations, idle polls — see [`RateStats`]); the input signal of
    /// online policies such as `adaptive`.
    pub rates: RateStats,
    /// Memory state: region registry + per-task/bubble NUMA footprint
    /// (see [`crate::mem`]). Policies consult it on wake/pick/steal.
    pub mem: MemState,
    pub metrics: Metrics,
    pub trace: Trace,
    /// Engine clock (simulated cycles / native ns); engines advance it,
    /// schedulers read it for trace timestamps.
    clock: AtomicU64,
    /// Rotating tie-break offset for wake placement (see
    /// `core::ops::least_loaded_leaf`). Per-system — not a process
    /// global — so two seeded runs in one process place identically.
    placement_rot: AtomicUsize,
    enqueue_hook: EnqueueHook,
}

impl System {
    /// Fresh system over a machine.
    pub fn new(topo: Arc<Topology>) -> System {
        let rq = RqHierarchy::new(&topo);
        let stats = LoadStats::new(&topo);
        let rates = RateStats::new(&topo);
        let mem = MemState::new(&topo);
        System {
            topo,
            tasks: TaskTable::new(),
            rq,
            stats,
            rates,
            mem,
            metrics: Metrics::new(),
            trace: Trace::default(),
            clock: AtomicU64::new(0),
            placement_rot: AtomicUsize::new(0),
            enqueue_hook: EnqueueHook::default(),
        }
    }

    /// Next wake-placement rotation offset (monotonic per system).
    pub fn next_placement_rot(&self) -> usize {
        self.placement_rot.fetch_add(1, Ordering::Relaxed)
    }

    /// Install the enqueue notification hook, replacing any previous
    /// one. Called by engines that park idle workers.
    pub fn set_enqueue_hook(&self, hook: Arc<dyn Fn() + Send + Sync>) {
        *self.enqueue_hook.hook.write().unwrap() = Some(hook);
        self.enqueue_hook.set.store(true, Ordering::Release);
    }

    /// Fire the enqueue hook, if any ([`crate::sched::core::ops::enqueue`]
    /// calls this after pushing a task). Hookless engines pay one
    /// relaxed atomic load; with a hook the Arc is cloned out of the
    /// read lock before the call so a slow hook cannot block
    /// `set_enqueue_hook`.
    pub fn notify_enqueue(&self) {
        if !self.enqueue_hook.set.load(Ordering::Acquire) {
            return;
        }
        let hook = self.enqueue_hook.hook.read().unwrap().clone();
        if let Some(h) = hook {
            h();
        }
    }

    /// Record a memory touch on region `r` by `cpu` and account it:
    /// the registry resolves the home (first touch homes, next-touch
    /// migrates, striped regions rotate over their stripes), the
    /// footprint follows, and the local/remote access + migration
    /// metrics are bumped. Both engines go through here — the simulator
    /// on every memory-bound compute chunk, the native executor from
    /// green threads (`GreenApi::touch_region`) — so the memory
    /// behaviour of a policy is observable identically on either.
    pub fn touch_region(&self, r: RegionId, cpu: CpuId) -> Touch {
        let touch = self.mem.touch(&self.tasks, &self.topo, r, cpu);
        if touch.home == self.topo.numa_of(cpu) {
            Metrics::inc(&self.metrics.local_accesses);
        } else {
            Metrics::inc(&self.metrics.remote_accesses);
        }
        if touch.migrated > 0 {
            Metrics::inc(&self.metrics.mem_migrations);
            Metrics::add(&self.metrics.migrated_bytes, touch.migrated);
        }
        touch
    }

    /// Current engine time.
    pub fn now(&self) -> u64 {
        self.clock.load(Ordering::Relaxed)
    }

    /// Advance the engine clock to `t` (monotonic max).
    pub fn advance_clock(&self, t: u64) {
        self.clock.fetch_max(t, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_is_monotonic() {
        let s = System::new(Arc::new(Topology::smp(2)));
        assert_eq!(s.now(), 0);
        s.advance_clock(10);
        s.advance_clock(5);
        assert_eq!(s.now(), 10);
    }

    #[test]
    fn rq_matches_topology() {
        let s = System::new(Arc::new(Topology::numa(4, 4)));
        assert_eq!(s.rq.len(), s.topo.n_components());
    }

    #[test]
    fn mem_state_matches_numa_count() {
        let s = System::new(Arc::new(Topology::numa(4, 4)));
        assert_eq!(s.mem.footprint.n_nodes(), 4);
    }

    #[test]
    fn enqueue_hook_fires_and_is_replaceable() {
        use std::sync::atomic::AtomicUsize;
        let s = System::new(Arc::new(Topology::smp(2)));
        s.notify_enqueue(); // unset: no-op
        let first = Arc::new(AtomicUsize::new(0));
        let h = first.clone();
        s.set_enqueue_hook(Arc::new(move || {
            h.fetch_add(1, Ordering::SeqCst);
        }));
        s.notify_enqueue();
        assert_eq!(first.load(Ordering::SeqCst), 1);
        // A later engine over the same system takes over the wakeups.
        let second = Arc::new(AtomicUsize::new(0));
        let h = second.clone();
        s.set_enqueue_hook(Arc::new(move || {
            h.fetch_add(1, Ordering::SeqCst);
        }));
        s.notify_enqueue();
        assert_eq!(first.load(Ordering::SeqCst), 1, "old hook must be replaced");
        assert_eq!(second.load(Ordering::SeqCst), 1);
    }
}
