//! Shared scheduler state: topology + task table + list hierarchy +
//! metrics + trace, bundled so engines and schedulers pass one handle.

use std::cell::Cell;
use std::sync::atomic::{AtomicPtr, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Instant;

use super::core::stats::{LoadStats, RateStats};
use crate::mem::{MemState, RegionId, Touch};
use crate::metrics::Metrics;
use crate::rq::RqHierarchy;
use crate::task::TaskTable;
use crate::topology::{CpuId, Topology};
use crate::trace::{Event, Trace};

/// Optional callback fired after every `ops::enqueue` (installed by the
/// native executor so idle workers wake on work arrival instead of
/// timing out; engines that poll never set it). Replaceable, so a
/// second executor over the same system takes over wakeups instead of
/// silently notifying a dead parking lot.
///
/// Stored as an atomic pointer to a heap'd `Arc`, so the enqueue hot
/// path is one acquire load — no lock, no Arc refcount churn. A
/// *replaced* hook is intentionally leaked: a racing `notify_enqueue`
/// may still be inside it, engines install at most a few hooks per
/// system, and a leak is the entire cost of not needing an epoch
/// scheme. The final hook is freed on drop (no readers can race a
/// `&mut self`).
struct EnqueueHook {
    ptr: AtomicPtr<Arc<dyn Fn() + Send + Sync>>,
}

impl Default for EnqueueHook {
    fn default() -> EnqueueHook {
        EnqueueHook { ptr: AtomicPtr::new(std::ptr::null_mut()) }
    }
}

impl Drop for EnqueueHook {
    fn drop(&mut self) {
        let p = *self.ptr.get_mut();
        if !p.is_null() {
            // SAFETY: `p` came from Box::into_raw in `set`, was never
            // freed (replacements leak), and `&mut self` rules out a
            // concurrent reader.
            drop(unsafe { Box::from_raw(p) });
        }
    }
}

impl std::fmt::Debug for EnqueueHook {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(if self.ptr.load(Ordering::Relaxed).is_null() {
            "EnqueueHook(unset)"
        } else {
            "EnqueueHook(set)"
        })
    }
}

thread_local! {
    /// (nesting depth, notification pending) of this thread's enqueue
    /// wake batch — see [`System::wake_batch`].
    static WAKE_BATCH: Cell<(u32, bool)> = const { Cell::new((0, false)) };
}

/// Everything a scheduler needs to see the machine and its tasks.
#[derive(Debug)]
pub struct System {
    pub topo: Arc<Topology>,
    pub tasks: TaskTable,
    pub rq: RqHierarchy,
    /// Incremental per-level load statistics (see [`LoadStats`]),
    /// maintained by the `sched::core::ops` building blocks.
    pub stats: LoadStats,
    /// Per-level feedback-event rates (steal fails, cross-node
    /// migrations, idle polls — see [`RateStats`]); the input signal of
    /// online policies such as `adaptive`.
    pub rates: RateStats,
    /// Memory state: region registry + per-task/bubble NUMA footprint
    /// (see [`crate::mem`]). Policies consult it on wake/pick/steal.
    pub mem: MemState,
    pub metrics: Metrics,
    pub trace: Trace,
    /// Engine clock (simulated cycles / native ns); engines advance it,
    /// schedulers read it for trace timestamps.
    clock: AtomicU64,
    /// Wall-clock anchor set by the native executor
    /// ([`System::start_wall_clock`]). Once set, [`System::now`] reports
    /// monotonic nanoseconds since the anchor instead of the logical
    /// clock, so native trace records carry real timestamps.
    wall_anchor: OnceLock<Instant>,
    /// Rotating tie-break offset for wake placement (see
    /// `core::ops::least_loaded_leaf`). Per-system — not a process
    /// global — so two seeded runs in one process place identically.
    placement_rot: AtomicUsize,
    enqueue_hook: EnqueueHook,
}

impl System {
    /// Fresh system over a machine.
    pub fn new(topo: Arc<Topology>) -> System {
        let rq = RqHierarchy::new(&topo);
        let stats = LoadStats::new(&topo);
        let rates = RateStats::new(&topo);
        let mem = MemState::new(&topo);
        let n_cpus = topo.n_cpus();
        System {
            topo,
            tasks: TaskTable::new(),
            rq,
            stats,
            rates,
            mem,
            metrics: Metrics::new(),
            trace: Trace::for_cpus(n_cpus, 1 << 14),
            clock: AtomicU64::new(0),
            wall_anchor: OnceLock::new(),
            placement_rot: AtomicUsize::new(0),
            enqueue_hook: EnqueueHook::default(),
        }
    }

    /// Next wake-placement rotation offset (monotonic per system).
    pub fn next_placement_rot(&self) -> usize {
        self.placement_rot.fetch_add(1, Ordering::Relaxed)
    }

    /// Install the enqueue notification hook, replacing any previous
    /// one. Called by engines that park idle workers.
    pub fn set_enqueue_hook(&self, hook: Arc<dyn Fn() + Send + Sync>) {
        let raw = Box::into_raw(Box::new(hook));
        // The swapped-out hook is deliberately leaked — see EnqueueHook.
        let _old = self.enqueue_hook.ptr.swap(raw, Ordering::AcqRel);
    }

    /// Fire the enqueue hook, if any ([`crate::sched::core::ops::enqueue`]
    /// calls this after pushing a task). Hookless engines pay one
    /// atomic load; with a hook the pointer is dereferenced directly —
    /// no lock, no refcount traffic. Inside a [`System::wake_batch`]
    /// the call is deferred to the end of the batch.
    pub fn notify_enqueue(&self) {
        let deferred = WAKE_BATCH.with(|b| {
            let (depth, _) = b.get();
            if depth > 0 {
                b.set((depth, true));
                true
            } else {
                false
            }
        });
        if !deferred {
            self.fire_enqueue_hook();
        }
    }

    fn fire_enqueue_hook(&self) {
        let p = self.enqueue_hook.ptr.load(Ordering::Acquire);
        if p.is_null() {
            return;
        }
        // SAFETY: a non-null pointer came from Box::into_raw in
        // set_enqueue_hook and is never freed while the system is
        // shared (replaced hooks leak; the last one is freed by Drop,
        // which requires exclusive access).
        (unsafe { &*p })();
    }

    /// Run `f` with enqueue notifications **coalesced**: however many
    /// tasks it enqueues, parked workers are notified once, when the
    /// outermost batch on this thread closes. Bulk wake paths (bubble
    /// flattening, barrier release) use this so the executor's park
    /// condvar is not taken per task. Nests freely; scoped to the
    /// calling thread, so enqueues must happen inside `f` itself, and a
    /// batch must not span two systems (the pending flag is
    /// per-thread, not per-system — the wake paths never interleave
    /// systems).
    pub fn wake_batch<R>(&self, f: impl FnOnce() -> R) -> R {
        /// Restores the depth even if `f` unwinds, so a caught panic
        /// cannot permanently swallow this thread's notifications.
        struct DepthGuard;
        impl Drop for DepthGuard {
            fn drop(&mut self) {
                WAKE_BATCH.with(|b| {
                    let (depth, pending) = b.get();
                    b.set((depth.saturating_sub(1), pending));
                });
            }
        }
        WAKE_BATCH.with(|b| {
            let (depth, pending) = b.get();
            b.set((depth + 1, pending));
        });
        let out = {
            let _g = DepthGuard;
            f()
        };
        let fire = WAKE_BATCH.with(|b| {
            let (depth, pending) = b.get();
            if depth == 0 && pending {
                b.set((0, false));
                true
            } else {
                false
            }
        });
        if fire {
            self.fire_enqueue_hook();
        }
        out
    }

    /// Record a memory touch on region `r` by `cpu` and account it:
    /// the registry resolves the home (first touch homes, next-touch
    /// migrates, striped regions rotate over their stripes), the
    /// footprint follows, and the local/remote access + migration
    /// metrics are bumped. Both engines go through here — the simulator
    /// on every memory-bound compute chunk, the native executor from
    /// green threads (`GreenApi::touch_region`) — so the memory
    /// behaviour of a policy is observable identically on either.
    pub fn touch_region(&self, r: RegionId, cpu: CpuId) -> Touch {
        // The pre-touch home is only observable before the touch, and
        // only needed for the RegionMigrate record — query it lazily.
        let tracing = self.trace.enabled();
        let pre_home = if tracing { self.mem.home(r) } else { None };
        let touch = self.mem.touch(&self.tasks, &self.topo, r, cpu);
        let local = touch.home == self.topo.numa_of(cpu);
        if local {
            Metrics::inc(&self.metrics.local_accesses);
        } else {
            Metrics::inc(&self.metrics.remote_accesses);
        }
        // Per-region attribution of the same signal, so a job owning a
        // set of regions gets its own local_ratio (crate::serve).
        self.mem.regions.note_locality(r, local);
        if touch.migrated > 0 {
            Metrics::inc(&self.metrics.mem_migrations);
            Metrics::add(&self.metrics.migrated_bytes, touch.migrated);
        }
        if tracing {
            let at = self.now();
            self.trace.emit(at, Event::RegionTouch { region: r, cpu, home: touch.home, local });
            if touch.migrated > 0 {
                self.trace.emit(
                    at,
                    Event::RegionMigrate {
                        region: r,
                        from: pre_home.unwrap_or(touch.home),
                        to: touch.home,
                        bytes: touch.migrated,
                    },
                );
            }
        }
        touch
    }

    /// Current engine time: wall ns since [`System::start_wall_clock`]
    /// once a native run anchored it (offset by 1 so a started clock is
    /// never 0), otherwise the logical clock engines advance.
    pub fn now(&self) -> u64 {
        match self.wall_anchor.get() {
            Some(anchor) => anchor.elapsed().as_nanos() as u64 + 1,
            None => self.clock.load(Ordering::Relaxed),
        }
    }

    /// Anchor the engine clock to the host monotonic clock (native
    /// executor, at run start). Idempotent: the first anchor wins, so
    /// timestamps stay comparable across executors sharing a system.
    pub fn start_wall_clock(&self) {
        self.wall_anchor.get_or_init(Instant::now);
    }

    /// Advance the logical engine clock to `t` (monotonic max; the
    /// simulator's time source — ignored by [`System::now`] once a
    /// wall anchor is set).
    pub fn advance_clock(&self, t: u64) {
        self.clock.fetch_max(t, Ordering::Relaxed);
    }

    /// Emit a trace event without paying to construct it while tracing
    /// is off: `f` runs only when enabled. Hot paths (enqueue,
    /// dispatch, steal, pick timing) come through here.
    pub fn trace_emit(&self, f: impl FnOnce() -> Event) {
        if self.trace.enabled() {
            self.trace.emit(self.now(), f());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_is_monotonic() {
        let s = System::new(Arc::new(Topology::smp(2)));
        assert_eq!(s.now(), 0);
        s.advance_clock(10);
        s.advance_clock(5);
        assert_eq!(s.now(), 10);
    }

    #[test]
    fn wall_clock_overrides_logical_clock() {
        let s = System::new(Arc::new(Topology::smp(2)));
        s.start_wall_clock();
        let a = s.now();
        assert!(a > 0, "anchored clock is never 0");
        std::thread::sleep(std::time::Duration::from_millis(1));
        let b = s.now();
        assert!(b > a, "anchored clock advances with wall time");
        s.advance_clock(u64::MAX);
        assert!(s.now() >= b, "logical advances no longer steer now()");
        assert!(s.now() < u64::MAX / 2);
    }

    #[test]
    fn rq_matches_topology() {
        let s = System::new(Arc::new(Topology::numa(4, 4)));
        assert_eq!(s.rq.len(), s.topo.n_components());
    }

    #[test]
    fn mem_state_matches_numa_count() {
        let s = System::new(Arc::new(Topology::numa(4, 4)));
        assert_eq!(s.mem.footprint.n_nodes(), 4);
    }

    #[test]
    fn enqueue_hook_fires_and_is_replaceable() {
        use std::sync::atomic::AtomicUsize;
        let s = System::new(Arc::new(Topology::smp(2)));
        s.notify_enqueue(); // unset: no-op
        let first = Arc::new(AtomicUsize::new(0));
        let h = first.clone();
        s.set_enqueue_hook(Arc::new(move || {
            h.fetch_add(1, Ordering::SeqCst);
        }));
        s.notify_enqueue();
        assert_eq!(first.load(Ordering::SeqCst), 1);
        // A later engine over the same system takes over the wakeups.
        let second = Arc::new(AtomicUsize::new(0));
        let h = second.clone();
        s.set_enqueue_hook(Arc::new(move || {
            h.fetch_add(1, Ordering::SeqCst);
        }));
        s.notify_enqueue();
        assert_eq!(first.load(Ordering::SeqCst), 1, "old hook must be replaced");
        assert_eq!(second.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn wake_batch_coalesces_notifications() {
        use std::sync::atomic::AtomicUsize;
        let s = System::new(Arc::new(Topology::smp(2)));
        let fired = Arc::new(AtomicUsize::new(0));
        let h = fired.clone();
        s.set_enqueue_hook(Arc::new(move || {
            h.fetch_add(1, Ordering::SeqCst);
        }));
        // A batch of notifies (nested, as flatten_wake recursion
        // produces) collapses to a single hook call at the end.
        s.wake_batch(|| {
            s.notify_enqueue();
            s.wake_batch(|| {
                s.notify_enqueue();
                s.notify_enqueue();
            });
            assert_eq!(fired.load(Ordering::SeqCst), 0, "deferred until batch close");
        });
        assert_eq!(fired.load(Ordering::SeqCst), 1);
        // An empty batch fires nothing; outside a batch each notify
        // fires directly.
        s.wake_batch(|| {});
        assert_eq!(fired.load(Ordering::SeqCst), 1);
        s.notify_enqueue();
        assert_eq!(fired.load(Ordering::SeqCst), 2);
    }
}
