//! Moldable gang scheduling: gangs that resize instead of idling
//! processors.
//!
//! The paper's gang baseline (§3.1, [`super::baselines::GangScheduler`])
//! reproduces Ousterhout's pathology on purpose: one gang owns the
//! whole machine per time slice, so a small gang leaves most CPUs
//! idle. The malleable-job literature (arXiv 1412.4213 direction)
//! fixes exactly that: treat the gang's CPU set as *moldable* — shrink
//! it when the gang's occupancy drops, hand the freed processors to a
//! waiting gang, re-expand when load returns. This policy implements
//! that on the hierarchy: a gang's CPU set is always one topology
//! *component*, so resizing is a walk up or down the machine tree and
//! co-scheduled gangs always occupy hierarchy-aligned (cache/NUMA
//! coherent) CPU sets.
//!
//! * **placement** — active gangs own pairwise-disjoint components;
//!   waiting gangs are placed FIFO on the largest free component (BFS
//!   order: ancestors first). The first gang gets the machine root,
//!   exactly like classic gang scheduling — until someone shrinks.
//! * **shrink** — when a gang's *demand* (members that are runnable or
//!   running) fits in one child of its component for
//!   [`MoldableConfig::resize_hysteresis`] consecutive evaluations, it
//!   shrinks to the child where most of its members last ran. Queued
//!   members migrate to the new component's list.
//! * **expand** — when demand exceeds the component and the parent's
//!   subtree is otherwise free for the same number of evaluations, the
//!   gang expands to the parent.
//! * **park** — a gang whose demand hits zero (every member blocked)
//!   is taken off the machine entirely; the first member wakeup
//!   re-queues it. This is what lets barrier-coupled gangs make
//!   progress without a timeslice: blocking hands the CPUs over.
//!
//! Bubbles woken under this scheduler become gangs (nested bubbles are
//! flattened into one gang); loose threads form singleton gangs.
//! Resizes surface in `metrics.gang_shrinks` / `metrics.gang_expands`;
//! [`MoldableGangScheduler::assignments`], [`force_shrink`] and
//! [`force_expand`] exist for the property tests.
//!
//! [`force_shrink`]: MoldableGangScheduler::force_shrink
//! [`force_expand`]: MoldableGangScheduler::force_expand

use std::collections::VecDeque;
use std::sync::Mutex;

use super::core::{ops, pick};
use super::{Scheduler, StopReason, System};
use crate::metrics::Metrics;
use crate::task::{TaskId, TaskState};
use crate::topology::{CpuId, LevelId, Topology};
use crate::trace::{Event, RegenWhy};

/// Tunables (config keys `sched.resize_hysteresis`, `sched.timeslice`).
#[derive(Debug, Clone)]
pub struct MoldableConfig {
    /// Consecutive resize evaluations that must agree before a
    /// shrink/expand commits (damps resize thrash under bursty load).
    pub resize_hysteresis: u32,
    /// Engine time a gang may own its component while another gang
    /// waits with no free component, before [`Scheduler::tick`] rotates
    /// it off the machine (the ROADMAP "timeslice rotation when demand
    /// exceeds the machine"). `None` keeps pure space-sharing.
    pub timeslice: Option<u64>,
}

impl Default for MoldableConfig {
    fn default() -> Self {
        MoldableConfig { resize_hysteresis: 4, timeslice: None }
    }
}

/// One active gang and the component it owns.
#[derive(Debug, Clone)]
struct GangSlot {
    gang: TaskId,
    comp: LevelId,
    shrink_streak: u32,
    expand_streak: u32,
    /// Engine time consumed since placement (timeslice rotation).
    used: u64,
}

#[derive(Debug, Default)]
struct MoldState {
    /// Gangs currently owning (pairwise-disjoint) components.
    active: Vec<GangSlot>,
    /// Gangs waiting for a free component, FIFO.
    queue: VecDeque<TaskId>,
    /// Gangs off the machine because every member is blocked.
    parked: Vec<TaskId>,
}

/// Moldable gang scheduler (registry name: `moldable-gang`).
#[derive(Debug)]
pub struct MoldableGangScheduler {
    cfg: MoldableConfig,
    st: Mutex<MoldState>,
}

/// Two components' CPU ranges intersect (on a tree this means one
/// contains the other).
fn overlaps(topo: &Topology, a: LevelId, b: LevelId) -> bool {
    let na = topo.node(a);
    let nb = topo.node(b);
    na.cpu_first < nb.cpu_first + nb.cpu_count && nb.cpu_first < na.cpu_first + na.cpu_count
}

/// Members (of `members(sys, gang)`) that want a CPU now or will once
/// activated (not blocked, not finished).
fn demand_of(sys: &System, ms: &[TaskId]) -> usize {
    ms.iter()
        .filter(|&&m| {
            matches!(
                sys.tasks.state(m),
                TaskState::New
                    | TaskState::InBubble
                    | TaskState::Ready { .. }
                    | TaskState::Running { .. }
            )
        })
        .count()
}

/// Collected thread members of a gang (one traversal; callers reuse
/// the list across demand / shrink-target / migration passes).
fn members(sys: &System, gang: TaskId) -> Vec<TaskId> {
    let mut ms = Vec::new();
    ops::thread_members(sys, gang, &mut ms);
    ms
}

impl MoldableGangScheduler {
    pub fn new(cfg: MoldableConfig) -> MoldableGangScheduler {
        MoldableGangScheduler { cfg, st: Mutex::new(MoldState::default()) }
    }

    /// Snapshot of (gang, owned component) pairs — test hook.
    pub fn assignments(&self) -> Vec<(TaskId, LevelId)> {
        let st = self.st.lock().unwrap();
        st.active.iter().map(|s| (s.gang, s.comp)).collect()
    }

    /// Apply one shrink step immediately (no hysteresis). Returns true
    /// if the gang's component changed — property-test hook.
    pub fn force_shrink(&self, sys: &System, gang: TaskId) -> bool {
        let mut st = self.st.lock().unwrap();
        let Some(i) = st.active.iter().position(|s| s.gang == gang) else {
            return false;
        };
        let ms = members(sys, gang);
        let d = demand_of(sys, &ms);
        match self.shrink_target(sys, st.active[i].comp, &ms, d) {
            Some(child) => {
                self.apply_resize(sys, &mut st, i, &ms, child, true);
                true
            }
            None => false,
        }
    }

    /// Apply one expand step immediately (no hysteresis, no demand
    /// check). Returns true if the component changed — property-test
    /// hook. Disjointness is still enforced: expansion is refused when
    /// the parent overlaps another active gang.
    pub fn force_expand(&self, sys: &System, gang: TaskId) -> bool {
        let mut st = self.st.lock().unwrap();
        let Some(i) = st.active.iter().position(|s| s.gang == gang) else {
            return false;
        };
        let comp = st.active[i].comp;
        let Some(parent) = sys.topo.node(comp).parent else {
            return false;
        };
        let blocked = st
            .active
            .iter()
            .enumerate()
            .any(|(j, s)| j != i && overlaps(&sys.topo, parent, s.comp));
        if blocked {
            return false;
        }
        let ms = members(sys, gang);
        self.apply_resize(sys, &mut st, i, &ms, parent, false);
        true
    }

    /// The child of `comp` the gang should shrink into: big enough for
    /// the demand, holding the most members by last-run CPU.
    fn shrink_target(
        &self,
        sys: &System,
        comp: LevelId,
        ms: &[TaskId],
        d: usize,
    ) -> Option<LevelId> {
        let node = sys.topo.node(comp);
        if node.children.is_empty() || d == 0 || d >= node.cpu_count {
            return None;
        }
        let mut best: Option<(usize, LevelId)> = None;
        for &c in &node.children {
            let cn = sys.topo.node(c);
            if cn.cpu_count < d {
                continue; // this child cannot hold the gang
            }
            let count = ms
                .iter()
                .filter(|&&m| {
                    sys.tasks
                        .with(m, |t| t.last_cpu)
                        .map(|cpu| cn.covers(cpu))
                        .unwrap_or(false)
                })
                .count();
            if best.map_or(true, |(bc, _)| count > bc) {
                best = Some((count, c));
            }
        }
        best.map(|(_, c)| c)
    }

    /// Commit a resize: move the slot to `to` and migrate every queued
    /// member onto the new component's list (members keep running where
    /// they are; their stop path requeues them onto the new set).
    fn apply_resize(
        &self,
        sys: &System,
        st: &mut MoldState,
        i: usize,
        ms: &[TaskId],
        to: LevelId,
        shrink: bool,
    ) {
        let gang = st.active[i].gang;
        let from = st.active[i].comp;
        st.active[i].comp = to;
        st.active[i].shrink_streak = 0;
        st.active[i].expand_streak = 0;
        for &m in ms {
            if let Some(list) = sys.tasks.state(m).ready_list() {
                if list != to && sys.rq.remove(list, m, sys.tasks.prio(m)) {
                    ops::enqueue(sys, m, to);
                }
            }
        }
        Metrics::inc(if shrink {
            &sys.metrics.gang_shrinks
        } else {
            &sys.metrics.gang_expands
        });
        sys.trace.emit(sys.now(), Event::RegenDone { bubble: gang, list: to });
        sys.trace_emit(|| Event::GangResize { gang, from, to, grew: !shrink });
    }

    /// Release a gang's runnable members onto its component's list.
    fn activate(&self, sys: &System, gang: TaskId, comp: LevelId) {
        if sys.tasks.is_bubble(gang) {
            // The gang bubble (and any nested bubbles) stay parked;
            // only threads run.
            sys.tasks.with(gang, |t| t.state = TaskState::Blocked);
        }
        let mut ms = Vec::new();
        ops::thread_members(sys, gang, &mut ms);
        for m in ms {
            // Park intermediate bubbles encountered on the way.
            if let Some(p) = sys.tasks.parent(m) {
                if p != gang && sys.tasks.is_bubble(p) {
                    sys.tasks.with(p, |t| t.state = TaskState::Blocked);
                }
            }
            match sys.tasks.state(m) {
                TaskState::New | TaskState::InBubble => ops::enqueue(sys, m, comp),
                TaskState::Ready { list } => {
                    if list != comp && sys.rq.remove(list, m, sys.tasks.prio(m)) {
                        ops::enqueue(sys, m, comp);
                    }
                }
                // Blocked members rejoin on wake; Terminated are done.
                // A loose gang re-queued after blocking is Blocked here
                // and runs again via the enqueue below.
                TaskState::Blocked if m == gang => ops::enqueue(sys, m, comp),
                _ => {}
            }
        }
    }

    /// Place waiting gangs (FIFO) on free components while any exist.
    fn place_waiting(&self, sys: &System, st: &mut MoldState) {
        loop {
            // Drop finished gangs from the head of the queue.
            while let Some(&g) = st.queue.front() {
                if ops::gang_live(sys, g) {
                    break;
                }
                st.queue.pop_front();
            }
            let Some(&g) = st.queue.front() else { return };
            let Some(comp) = self.find_free(sys, st) else { return };
            st.queue.pop_front();
            st.active.push(GangSlot {
                gang: g,
                comp,
                shrink_streak: 0,
                expand_streak: 0,
                used: 0,
            });
            self.activate(sys, g, comp);
        }
    }

    /// Largest free component: first in BFS id order (ancestors come
    /// before descendants) that overlaps no active gang's set.
    fn find_free(&self, sys: &System, st: &MoldState) -> Option<LevelId> {
        (0..sys.topo.n_components()).map(LevelId).find(|&l| {
            st.active.iter().all(|s| !overlaps(&sys.topo, l, s.comp))
        })
    }

    /// Hysteresis-damped resize evaluation for one active gang. The
    /// caller's single membership traversal (`ms`) feeds the demand
    /// count, the shrink-target search and (on commit) the
    /// queued-member migration.
    fn maybe_resize(&self, sys: &System, st: &mut MoldState, i: usize, ms: &[TaskId]) {
        let comp = st.active[i].comp;
        let d = demand_of(sys, ms);
        if let Some(child) = self.shrink_target(sys, comp, ms, d) {
            st.active[i].expand_streak = 0;
            st.active[i].shrink_streak += 1;
            if st.active[i].shrink_streak >= self.cfg.resize_hysteresis {
                self.apply_resize(sys, st, i, ms, child, true);
            }
            return;
        }
        st.active[i].shrink_streak = 0;
        let parent = sys.topo.node(comp).parent;
        if d > sys.topo.node(comp).cpu_count {
            if let Some(parent) = parent {
                let blocked = st
                    .active
                    .iter()
                    .enumerate()
                    .any(|(j, s)| j != i && overlaps(&sys.topo, parent, s.comp));
                if !blocked {
                    st.active[i].expand_streak += 1;
                    if st.active[i].expand_streak >= self.cfg.resize_hysteresis {
                        self.apply_resize(sys, st, i, ms, parent, false);
                    }
                    return;
                }
            }
        }
        st.active[i].expand_streak = 0;
    }
}

impl Default for MoldableGangScheduler {
    fn default() -> Self {
        MoldableGangScheduler::new(MoldableConfig::default())
    }
}

impl Scheduler for MoldableGangScheduler {
    fn name(&self) -> String {
        "moldable-gang".into()
    }

    fn wake(&self, sys: &System, task: TaskId) {
        let mut st = self.st.lock().unwrap();
        if sys.tasks.parent(task).is_some() {
            // A member of some gang woke (barrier release, join, …).
            // Only a genuinely blocked member needs action: a spurious
            // wake of a Ready/Running member must not double-queue it.
            let gang = ops::root_bubble(sys, task);
            if sys.tasks.state(task) == TaskState::Blocked {
                if let Some(slot) = st.active.iter().find(|s| s.gang == gang) {
                    ops::enqueue(sys, task, slot.comp);
                } else {
                    // Hold it inside the gang; (re)queue a parked gang.
                    sys.tasks.set_state(task, TaskState::InBubble);
                    if let Some(p) = st.parked.iter().position(|&g| g == gang) {
                        st.parked.remove(p);
                        st.queue.push_back(gang);
                        self.place_waiting(sys, &mut st);
                    }
                }
            }
            sys.notify_enqueue();
            return;
        }
        // The task IS a gang: a bubble, or a loose (singleton) thread.
        if sys.tasks.is_bubble(task) {
            sys.tasks.with(task, |t| t.state = TaskState::Blocked);
        }
        if let Some(slot) = st.active.iter().find(|s| s.gang == task) {
            if !sys.tasks.is_bubble(task) && sys.tasks.state(task) == TaskState::Blocked {
                // An active loose gang woken again (unblock): rejoin.
                ops::enqueue(sys, task, slot.comp);
            }
        } else {
            if let Some(p) = st.parked.iter().position(|&g| g == task) {
                st.parked.remove(p);
            }
            if !st.queue.contains(&task) {
                st.queue.push_back(task);
            }
            self.place_waiting(sys, &mut st);
        }
        // Gang bookkeeping is internal (no rq push on some paths), so
        // parked native workers are signalled explicitly.
        sys.notify_enqueue();
    }

    fn pick(&self, sys: &System, cpu: CpuId) -> Option<TaskId> {
        let mut st = self.st.lock().unwrap();
        self.place_waiting(sys, &mut st);
        let Some(i) = st.active.iter().position(|s| sys.topo.node(s.comp).covers(cpu)) else {
            return None;
        };
        let comp = st.active[i].comp;
        let gang = st.active[i].gang;
        if let Some(t) = pick::pick_thread(sys, cpu, &[comp]) {
            let ms = members(sys, gang);
            self.maybe_resize(sys, &mut st, i, &ms);
            return Some(t);
        }
        let ms = members(sys, gang);
        if demand_of(sys, &ms) == 0 {
            // Nothing in this gang can run: give the CPUs back.
            st.active.swap_remove(i);
            if ops::gang_live(sys, gang) {
                st.parked.push(gang);
                sys.trace.emit(sys.now(), Event::Regen { bubble: gang, why: RegenWhy::Idle });
            }
            self.place_waiting(sys, &mut st);
            // Retry once: a freshly placed gang may cover this CPU.
            if let Some(j) =
                st.active.iter().position(|s| sys.topo.node(s.comp).covers(cpu))
            {
                let comp = st.active[j].comp;
                return pick::pick_thread(sys, cpu, &[comp]);
            }
            return None;
        }
        self.maybe_resize(sys, &mut st, i, &ms);
        None
    }

    fn stop(&self, sys: &System, cpu: CpuId, task: TaskId, why: StopReason) {
        ops::default_stop(sys, cpu, task, why, &mut |sys, t| {
            let gang = ops::root_bubble(sys, t);
            let mut st = self.st.lock().unwrap();
            if let Some(slot) = st.active.iter().find(|s| s.gang == gang) {
                ops::enqueue(sys, t, slot.comp);
            } else if sys.tasks.parent(t).is_some() {
                // Gang no longer on the machine: wait inside it.
                sys.tasks.set_state(t, TaskState::InBubble);
            } else {
                // A loose gang with no slot: back to the queue.
                sys.tasks.set_state(t, TaskState::Blocked);
                if !st.queue.contains(&t) {
                    st.queue.push_back(t);
                }
                self.place_waiting(sys, &mut st);
            }
        });
        if why == StopReason::Terminate {
            let gang = ops::root_bubble(sys, task);
            let mut st = self.st.lock().unwrap();
            if let Some(i) = st.active.iter().position(|s| s.gang == gang) {
                if !ops::gang_live(sys, gang) {
                    // The whole gang finished: free its component.
                    st.active.swap_remove(i);
                    self.place_waiting(sys, &mut st);
                    sys.notify_enqueue();
                }
            }
        }
    }

    fn tick(&self, sys: &System, _cpu: CpuId, task: TaskId, elapsed: u64) -> bool {
        // Timeslice rotation when demand exceeds the machine: space
        // sharing (shrink/park) is always tried first, so rotation only
        // fires when a live gang is waiting with no free component.
        let Some(slice) = self.cfg.timeslice else { return false };
        let gang = ops::root_bubble(sys, task);
        let mut st = self.st.lock().unwrap();
        let Some(i) = st.active.iter().position(|s| s.gang == gang) else {
            return false;
        };
        st.active[i].used += elapsed;
        if st.active[i].used < slice || !st.queue.iter().any(|&g| ops::gang_live(sys, g)) {
            return false;
        }
        // Rotate: free the component, pull queued members back inside
        // the gang (running members fall back in on their next stop),
        // requeue the gang and hand the space to the waiters.
        let slot = st.active.swap_remove(i);
        let ms = members(sys, gang);
        for &m in &ms {
            if let Some(l) = sys.tasks.state(m).ready_list() {
                if sys.rq.remove(l, m, sys.tasks.prio(m)) {
                    sys.tasks.set_state(
                        m,
                        if sys.tasks.parent(m).is_some() {
                            TaskState::InBubble
                        } else {
                            TaskState::Blocked
                        },
                    );
                }
            }
        }
        st.queue.push_back(slot.gang);
        Metrics::inc(&sys.metrics.regenerations);
        sys.trace.emit(sys.now(), Event::Regen { bubble: gang, why: RegenWhy::Timeslice });
        self.place_waiting(sys, &mut st);
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::marcel::Marcel;
    use crate::sched::baselines::testsupport;
    use crate::sched::testutil::system;
    use crate::task::PRIO_THREAD;
    use crate::topology::Topology;

    fn gang_of(m: &Marcel, n: usize, tag: &str) -> (TaskId, Vec<TaskId>) {
        let b = m.bubble_init();
        let ts: Vec<TaskId> = (0..n).map(|i| m.create_dontsched(format!("{tag}{i}"))).collect();
        for &t in &ts {
            m.bubble_inserttask(b, t);
        }
        (b, ts)
    }

    #[test]
    fn behavioural_suite() {
        testsupport::drains_all_work(
            &MoldableGangScheduler::default(),
            Topology::numa(2, 2),
            40,
        );
        testsupport::flattens_bubbles(&MoldableGangScheduler::default(), Topology::smp(2));
        testsupport::block_wake_roundtrip(&MoldableGangScheduler::default(), Topology::smp(2));
    }

    #[test]
    fn first_gang_owns_the_machine() {
        let sys = system(Topology::smp(4));
        let s = MoldableGangScheduler::default();
        let m = Marcel::with_system(&sys);
        let (g1, t1) = gang_of(&m, 2, "a");
        let (g2, t2) = gang_of(&m, 2, "b");
        s.wake(&sys, g1);
        s.wake(&sys, g2);
        let picked: Vec<TaskId> = (0..4).filter_map(|c| s.pick(&sys, CpuId(c))).collect();
        assert_eq!(picked.len(), 2, "only gang 1 runs before any shrink");
        assert!(picked.iter().all(|t| t1.contains(t)));
        assert_eq!(s.assignments(), vec![(g1, sys.topo.root())]);
        let _ = (g2, t2);
    }

    #[test]
    fn shrink_frees_cpus_for_the_waiting_gang() {
        let sys = system(Topology::numa(2, 2));
        let s = MoldableGangScheduler::new(MoldableConfig {
            resize_hysteresis: 1,
            ..Default::default()
        });
        let m = Marcel::with_system(&sys);
        let (g1, t1) = gang_of(&m, 2, "a");
        let (g2, t2) = gang_of(&m, 2, "b");
        s.wake(&sys, g1);
        s.wake(&sys, g2);
        // Gang 1 owns the root; two picks dispatch its two threads onto
        // node 0's CPUs, and the resize evaluation (demand 2 fits one
        // node) shrinks it with hysteresis 1.
        let x = s.pick(&sys, CpuId(0)).expect("gang1 thread");
        let y = s.pick(&sys, CpuId(1)).expect("gang1 thread");
        assert!(t1.contains(&x) && t1.contains(&y));
        // The shrink happened on a pick above; gang 2 now fits node 1.
        let z = s.pick(&sys, CpuId(2)).expect("gang2 thread after shrink");
        assert!(t2.contains(&z), "gang 2 must run on the freed node");
        let a = s.assignments();
        assert_eq!(a.len(), 2, "both gangs co-scheduled: {a:?}");
        assert!(sys.metrics.gang_shrinks.load(std::sync::atomic::Ordering::Relaxed) >= 1);
        let _ = g2;
    }

    #[test]
    fn blocked_gang_parks_and_returns_on_wake() {
        let sys = system(Topology::smp(2));
        let s = MoldableGangScheduler::default();
        let m = Marcel::with_system(&sys);
        let (g1, t1) = gang_of(&m, 1, "a");
        let (g2, t2) = gang_of(&m, 1, "b");
        s.wake(&sys, g1);
        s.wake(&sys, g2);
        let x = s.pick(&sys, CpuId(0)).unwrap();
        assert_eq!(x, t1[0]);
        s.stop(&sys, CpuId(0), x, StopReason::Block);
        // Gang 1 has zero demand: the next pick parks it and activates
        // gang 2 in its place.
        let y = s.pick(&sys, CpuId(0)).expect("gang2 after park");
        assert_eq!(y, t2[0]);
        // Waking the blocked member brings gang 1 back.
        s.wake(&sys, t1[0]);
        s.stop(&sys, CpuId(0), y, StopReason::Terminate);
        let z = s.pick(&sys, CpuId(0)).expect("gang1 reactivated");
        assert_eq!(z, t1[0]);
        let _ = (g1, g2);
    }

    #[test]
    fn force_resize_roundtrip_preserves_members() {
        let sys = system(Topology::numa(2, 2));
        let s = MoldableGangScheduler::default();
        let m = Marcel::with_system(&sys);
        let (g, ts) = gang_of(&m, 2, "a");
        s.wake(&sys, g);
        assert_eq!(s.assignments(), vec![(g, sys.topo.root())]);
        assert!(s.force_shrink(&sys, g), "demand 2 fits a node");
        let (_, comp) = s.assignments()[0];
        assert_ne!(comp, sys.topo.root());
        // Queued members moved with the gang.
        assert_eq!(sys.rq.len_of(comp), 2);
        assert!(s.force_expand(&sys, g), "parent is free again");
        assert_eq!(s.assignments(), vec![(g, sys.topo.root())]);
        assert_eq!(sys.rq.len_of(sys.topo.root()), 2);
        // Nothing lost or duplicated.
        let mut seen = Vec::new();
        for (l, t, _p) in sys.rq.snapshot() {
            assert_eq!(l, sys.topo.root());
            seen.push(t);
        }
        seen.sort();
        let mut want = ts.clone();
        want.sort();
        assert_eq!(seen, want);
    }

    #[test]
    fn timeslice_rotates_when_demand_exceeds_the_machine() {
        // Two full-machine gangs: no shrink can free space, so only
        // the tick rotation lets them time-share (ROADMAP follow-on).
        let sys = system(Topology::smp(2));
        let s = MoldableGangScheduler::new(MoldableConfig {
            resize_hysteresis: 100,
            timeslice: Some(100),
        });
        let m = Marcel::with_system(&sys);
        let (g1, t1) = gang_of(&m, 2, "a");
        let (g2, t2) = gang_of(&m, 2, "b");
        s.wake(&sys, g1);
        s.wake(&sys, g2);
        let x = s.pick(&sys, CpuId(0)).unwrap();
        let y = s.pick(&sys, CpuId(1)).unwrap();
        assert!(t1.contains(&x) && t1.contains(&y));
        // Slice expiry with a live waiter rotates gang 1 off the root.
        assert!(s.tick(&sys, CpuId(0), x, 150), "slice must expire");
        s.stop(&sys, CpuId(0), x, StopReason::Preempt);
        s.stop(&sys, CpuId(1), y, StopReason::Preempt);
        let z = s.pick(&sys, CpuId(0)).expect("gang 2's turn");
        assert!(t2.contains(&z), "rotation must hand the machine to gang 2");
        // Gang 1 queued again: the next expiry brings it back.
        assert!(s.tick(&sys, CpuId(0), z, 150));
        s.stop(&sys, CpuId(0), z, StopReason::Preempt);
        let w = s.pick(&sys, CpuId(0)).expect("gang 1 back after rotation");
        assert!(t1.contains(&w));
        assert!(sys.metrics.preemptions.load(std::sync::atomic::Ordering::Relaxed) >= 2);
    }

    #[test]
    fn loose_threads_are_singleton_gangs() {
        let sys = system(Topology::smp(2));
        let s = MoldableGangScheduler::new(MoldableConfig {
            resize_hysteresis: 1,
            ..Default::default()
        });
        let a = sys.tasks.new_thread("a", PRIO_THREAD);
        let b = sys.tasks.new_thread("b", PRIO_THREAD);
        s.wake(&sys, a);
        s.wake(&sys, b);
        let x = s.pick(&sys, CpuId(0)).unwrap();
        assert_eq!(x, a);
        // Unlike strict gang scheduling, the singleton shrinks (demand
        // 1 fits a leaf) and b gets the other CPU.
        let y = s.pick(&sys, CpuId(1)).or_else(|| s.pick(&sys, CpuId(1)));
        assert_eq!(y, Some(b), "moldable gangs must not idle the second CPU");
    }
}
