//! Schedulers: the paper's bubble scheduler plus the related-work
//! baselines it is evaluated against.
//!
//! Both execution engines ([`crate::sim`] and [`crate::exec`]) drive a
//! scheduler exclusively through the [`Scheduler`] trait: there is *no
//! global scheduling* — each processor calls the scheduler code itself
//! whenever it preempts or terminates a thread (§4).

mod adaptive;
pub mod baselines;
mod bubble;
pub mod core;
pub mod factory;
mod jobs;
mod memaware;
mod moldable;
mod system;

pub use adaptive::{AdaptiveConfig, AdaptiveScheduler};
pub use bubble::{BubbleConfig, BubbleScheduler};
pub use jobs::{DeadlineClass, JobFairConfig, JobFairScheduler};
pub use memaware::{MemAwareConfig, MemAwareScheduler};
pub use moldable::{MoldableConfig, MoldableGangScheduler};
pub use system::System;

use crate::task::TaskId;
use crate::topology::CpuId;

/// Why a thread left its CPU.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopReason {
    /// Voluntary yield — should be requeued.
    Yield,
    /// Timeslice preemption — requeued at the end of its class.
    Preempt,
    /// Blocked on a synchronisation object — not requeued until `wake`.
    Block,
    /// Finished.
    Terminate,
}

/// The scheduling policy interface (per-processor, no global decisions).
pub trait Scheduler: Send + Sync {
    /// Policy name for reports.
    fn name(&self) -> String;

    /// A task (thread, or closed bubble) becomes runnable: first wakeup,
    /// unblocking, or explicit `marcel_wake_up_bubble`.
    fn wake(&self, sys: &System, task: TaskId);

    /// The processor asks for its next thread. Bubble evolution
    /// (descend / burst / regenerate) happens inside. Returns a thread
    /// in `Running{cpu}` state, or None if the processor must idle.
    fn pick(&self, sys: &System, cpu: CpuId) -> Option<TaskId>;

    /// The running thread stopped. `Yield`/`Preempt` requeue it,
    /// `Block`/`Terminate` do not.
    fn stop(&self, sys: &System, cpu: CpuId, task: TaskId, why: StopReason);

    /// Timeslice accounting: `elapsed` engine-time has passed on `cpu`
    /// running `task`. Returns true if the scheduler wants to preempt.
    /// Both engines call it once per scheduling segment — the simulator
    /// with the segment's simulated cycles, the native executor with
    /// the fiber resume's wall nanoseconds — and honour a `true` return
    /// with a [`StopReason::Preempt`] stop.
    fn tick(&self, _sys: &System, _cpu: CpuId, _task: TaskId, _elapsed: u64) -> bool {
        false
    }

    /// Whether this policy's *contract* requires worker↔CPU binding to
    /// be real (the Table-2 `bound` row: one thread nailed to each
    /// processor). The native executor pins workers to detected OS CPUs
    /// when the topology carries a map (`--machine detect`); when such a
    /// policy runs *without* OS-level affinity — preset machine, or
    /// `sched_setaffinity` denied — the executor emits a one-time
    /// warning and counts `bound_unpinned` instead of silently
    /// degrading to loose threads.
    fn needs_binding(&self) -> bool {
        false
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    //! Shared scheduler test helpers.

    use super::*;
    use crate::task::{TaskState, PRIO_THREAD};
    use crate::topology::Topology;
    use std::sync::Arc;

    /// Build a shared System over a preset machine.
    pub fn system(topo: Topology) -> Arc<System> {
        Arc::new(System::new(Arc::new(topo)))
    }

    /// Create `n` woken threads.
    pub fn spawn_threads(sys: &System, sched: &dyn Scheduler, n: usize) -> Vec<TaskId> {
        (0..n)
            .map(|i| {
                let t = sys.tasks.new_thread(format!("w{i}"), PRIO_THREAD);
                sched.wake(sys, t);
                t
            })
            .collect()
    }

    /// Drain a CPU: pick then immediately terminate, until idle.
    /// Returns the picked order.
    pub fn drain_cpu(sys: &System, sched: &dyn Scheduler, cpu: CpuId) -> Vec<TaskId> {
        let mut order = Vec::new();
        while let Some(t) = sched.pick(sys, cpu) {
            assert_eq!(sys.tasks.state(t), TaskState::Running { cpu });
            order.push(t);
            sched.stop(sys, cpu, t, StopReason::Terminate);
        }
        order
    }
}
