//! The memory-aware policy: place threads where their data lives.
//!
//! ROADMAP's first follow-on to the `sched/core` extraction, in the
//! direction of the paper's successors (BubbleSched, arXiv 0706.2069;
//! ForestGOMP, arXiv 0706.2073): scheduling pays off only when threads
//! run *near their data* (§5.2's 3× NUMA factor), so this policy makes
//! the [`crate::mem`] footprint a first-class placement input:
//!
//! * **wake** — a woken task (bubble or thread) goes to the least
//!   loaded leaf of the NUMA node holding the plurality of its
//!   footprint; bubbles pass their aggregated footprint down to members
//!   with no data of their own. Footprint-less tasks fall back to
//!   last-CPU affinity, then to the least loaded leaf among the nodes
//!   with the most footprint *headroom*
//!   ([`crate::mem::MemState::pressure_view`]) — the place where the
//!   thread's first-touch allocations hurt least.
//! * **pick** — the pressure-aware two-pass search over the covering
//!   chain ([`super::core::pick::pick_thread_pressure`]): priority
//!   first, then footprint headroom on ties, then order position.
//! * **steal** — closest-victim-first, but a steal whose remote-access
//!   surcharge ([`DistanceModel::mem_factor`]) exceeds
//!   `max_steal_factor` is *refused* unless the victim queue is at
//!   least `desperate_queue` deep (only then does the idle-CPU gain
//!   clearly outweigh the NUMA penalty). Among equally distant
//!   admissible victims, the one whose node has the most footprint
//!   *headroom* wins — threads queued where little memory is homed are
//!   the cheapest to move (headroom overrides of the plain scan order
//!   count in `metrics.pressure_redirects`). A cross-node steal marks
//!   the stolen thread's regions **next-touch** so its memory follows
//!   it (migrated bytes surface in `metrics.migrated_bytes`).
//! * **stop** — yielded/preempted threads requeue towards their
//!   footprint's node, snapping back to their data after a forced
//!   remote excursion (unless next-touch migration already moved the
//!   data to them).
//!
//! Pure policy glue over [`super::core`] + [`crate::mem`]: no state of
//! its own beyond tunables.

use super::core::{ops, pick, traversal};
use super::{Scheduler, StopReason, System};
use crate::metrics::Metrics;
use crate::task::TaskId;
use crate::topology::{CpuId, DistanceModel, LevelId};

thread_local! {
    /// Reused pressure-snapshot buffer for the data-less wake fallback
    /// (one Vec per thread for the process lifetime, not one per wake).
    static PRESSURE_BUF: std::cell::RefCell<Vec<u64>> = const { std::cell::RefCell::new(Vec::new()) };
}

/// Tunables for the memory-aware policy.
#[derive(Debug, Clone)]
pub struct MemAwareConfig {
    /// Distance model used to price candidate steals. The factory
    /// fills this from the `[machine]` config section (including an
    /// asymmetric `numa_matrix`), so the policy prices steals with the
    /// *configured* machine, not the built-in NovaScale default.
    pub dist: DistanceModel,
    /// Refuse steals whose `mem_factor` exceeds this…
    pub max_steal_factor: f64,
    /// …unless the victim list holds at least this many tasks (then an
    /// extra CPU wins even at remote-access cost).
    pub desperate_queue: usize,
}

impl Default for MemAwareConfig {
    fn default() -> Self {
        MemAwareConfig {
            dist: DistanceModel::default(),
            max_steal_factor: 2.0,
            desperate_queue: 3,
        }
    }
}

/// Memory-aware scheduler (registry name: `memaware`).
#[derive(Debug)]
pub struct MemAwareScheduler {
    cfg: MemAwareConfig,
}

impl MemAwareScheduler {
    pub fn new(cfg: MemAwareConfig) -> MemAwareScheduler {
        MemAwareScheduler { cfg }
    }

    /// Memory-aware steal: closest victims first, remote ones only when
    /// cheap enough or desperate. Within one distance tie group the
    /// victim whose node has the most footprint *headroom* wins (its
    /// threads have the least locally-homed data holding them in place,
    /// so they are the cheapest to move; deeper queue breaks exact
    /// pressure ties) — this is where the pressure view genuinely picks
    /// between several populated runqueues, and a headroom override of
    /// the plain scan order is counted in `metrics.pressure_redirects`.
    /// Cross-node steals ask the thread's memory to follow it
    /// (next-touch).
    fn steal(&self, sys: &System, cpu: CpuId) -> Option<TaskId> {
        sys.rates.on_steal_attempt(&sys.topo, cpu);
        if sys.rq.total_queued() == 0 {
            ops::note_steal_fail(sys, cpu);
            return None;
        }
        let topo = &sys.topo;
        let here = topo.numa_of(cpu);
        let order = topo.steal_order(cpu);
        let sep = |l: LevelId| topo.separation(cpu, CpuId(topo.node(l).cpu_first));
        let mut i = 0;
        while i < order.len() {
            let d = sep(order[i]);
            let mut j = i;
            while j < order.len() && sep(order[j]) == d {
                j += 1;
            }
            let group = &order[i..j];
            // Headroom-first within the distance tie group, allocation
            // free: pick the admissible victim whose node has the
            // fewest homed bytes (deeper queue breaks exact pressure
            // ties, plain scan order breaks the rest). A pop that
            // races to empty rescans — the emptied victim filters
            // itself out, so still-populated same-distance victims are
            // not skipped (bounded like the two-pass pick).
            let mut credits = 2 * group.len() + 4;
            loop {
                // The victim the plain closest-first scan would take.
                let mut scan_first: Option<(LevelId, u64)> = None;
                let mut best: Option<(LevelId, u64, usize)> = None;
                for &v in group {
                    let qlen = sys.rq.len_of(v);
                    if qlen == 0 {
                        continue;
                    }
                    let vnode = topo.numa_of(CpuId(topo.node(v).cpu_first));
                    let factor = self.cfg.dist.mem_factor(topo, cpu, vnode);
                    if factor > self.cfg.max_steal_factor && qlen < self.cfg.desperate_queue {
                        continue; // remote cost exceeds the idle-CPU gain
                    }
                    let pressure = sys.mem.node_pressure(vnode);
                    if scan_first.is_none() {
                        scan_first = Some((v, pressure));
                    }
                    let better = match best {
                        None => true,
                        Some((_, bp, bq)) => pressure < bp || (pressure == bp && qlen > bq),
                    };
                    if better {
                        best = Some((v, pressure, qlen));
                    }
                }
                let Some((v, pressure, _)) = best else { break };
                let Some((t, _prio)) = ops::pop_steal(sys, cpu, v) else {
                    credits -= 1;
                    if credits == 0 {
                        break;
                    }
                    continue;
                };
                // Count only *pressure-driven* overrides of the plain
                // scan order, and only for steals that happened (not
                // queue-depth tie-breaks).
                if let Some((fv, fp)) = scan_first {
                    if v != fv && pressure < fp {
                        Metrics::inc(&sys.metrics.pressure_redirects);
                        sys.rates.on_pressure_redirect(topo, cpu);
                    }
                }
                let vnode = topo.numa_of(CpuId(topo.node(v).cpu_first));
                if vnode != here {
                    sys.mem.mark_task_regions_next_touch(t);
                }
                ops::dispatch(sys, cpu, t, topo.leaf_of(cpu));
                return Some(t);
            }
            i = j;
        }
        ops::note_steal_fail(sys, cpu);
        None
    }
}

impl Default for MemAwareScheduler {
    fn default() -> Self {
        MemAwareScheduler::new(MemAwareConfig::default())
    }
}

/// Least loaded leaf among the CPUs of one NUMA node.
fn node_leaf(sys: &System, node: usize) -> crate::topology::LevelId {
    ops::least_loaded_leaf(
        sys,
        (0..sys.topo.n_cpus()).map(CpuId).filter(|&c| sys.topo.numa_of(c) == node),
    )
}

impl Scheduler for MemAwareScheduler {
    fn name(&self) -> String {
        "memaware".into()
    }

    fn wake(&self, sys: &System, task: TaskId) {
        // The bubble's aggregated footprint is the group's home; read it
        // before flattening parks the bubble.
        let group = sys.mem.dominant_node(task);
        ops::flatten_wake(sys, task, &mut |sys, t| {
            let list = match sys.mem.dominant_node(t).or(group) {
                Some(node) => node_leaf(sys, node),
                None => sys
                    .tasks
                    .with(t, |x| x.last_cpu)
                    .map(|c| sys.topo.leaf_of(c))
                    .unwrap_or_else(|| {
                        // First placement of a data-less thread: the
                        // least loaded leaf among the nodes with the
                        // most footprint headroom (uniform pressure —
                        // e.g. nothing homed yet — degenerates to the
                        // machine-wide least-loaded fallback). The
                        // pressure snapshot fills a reused per-thread
                        // buffer, so the wake path stays allocation-free
                        // once warm.
                        PRESSURE_BUF.with(|buf| {
                            let mut view = buf.borrow_mut();
                            sys.mem.pressure_view_into(&mut view);
                            let min = view.iter().min().copied().unwrap_or(0);
                            let cpus = (0..sys.topo.n_cpus()).map(CpuId);
                            let open =
                                cpus.filter(|&c| view[sys.topo.numa_of(c)] == min);
                            ops::least_loaded_leaf(sys, open)
                        })
                    }),
            };
            ops::enqueue(sys, t, list);
        });
    }

    fn pick(&self, sys: &System, cpu: CpuId) -> Option<TaskId> {
        let order = traversal::covering(&sys.topo, cpu);
        if let Some(t) = pick::pick_thread_pressure(sys, cpu, order) {
            return Some(t);
        }
        self.steal(sys, cpu)
    }

    fn stop(&self, sys: &System, cpu: CpuId, task: TaskId, why: StopReason) {
        ops::default_stop(sys, cpu, task, why, &mut |sys, t| {
            let here = sys.topo.numa_of(cpu);
            let list = match sys.mem.dominant_node(t) {
                // Requeue towards the data when we drifted off its node.
                Some(node) if node != here => node_leaf(sys, node),
                _ => sys.topo.leaf_of(cpu),
            };
            ops::enqueue(sys, t, list)
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::AllocPolicy;
    use crate::sched::baselines::testsupport;
    use crate::sched::testutil::system;
    use crate::task::PRIO_THREAD;
    use crate::topology::Topology;

    #[test]
    fn behavioural_suite() {
        testsupport::drains_all_work(&MemAwareScheduler::default(), Topology::numa(2, 2), 40);
        testsupport::flattens_bubbles(&MemAwareScheduler::default(), Topology::smp(2));
        testsupport::block_wake_roundtrip(&MemAwareScheduler::default(), Topology::smp(2));
    }

    #[test]
    fn wake_places_on_footprint_node() {
        let sys = system(Topology::numa(2, 2));
        let s = MemAwareScheduler::default();
        let t = sys.tasks.new_thread("t", PRIO_THREAD);
        let r = sys.mem.alloc(1 << 20, AllocPolicy::Fixed(1));
        sys.mem.attach(&sys.tasks, t, r);
        s.wake(&sys, t);
        let list = sys.tasks.with(t, |x| x.last_list).unwrap();
        let leaf_cpu = CpuId(sys.topo.node(list).cpu_first);
        assert_eq!(sys.topo.numa_of(leaf_cpu), 1, "thread must land on its data's node");
    }

    #[test]
    fn dataless_wake_lands_on_headroom_node() {
        let sys = system(Topology::numa(2, 2));
        let s = MemAwareScheduler::default();
        // Node 0 is loaded with homed bytes: a thread with no footprint
        // and no history must first-touch on node 1 instead.
        let _ = sys.mem.alloc(1 << 20, AllocPolicy::Fixed(0));
        let t = sys.tasks.new_thread("t", PRIO_THREAD);
        s.wake(&sys, t);
        let list = sys.tasks.with(t, |x| x.last_list).unwrap();
        let leaf_cpu = CpuId(sys.topo.node(list).cpu_first);
        assert_eq!(sys.topo.numa_of(leaf_cpu), 1, "wake must prefer footprint headroom");
    }

    #[test]
    fn bubble_footprint_guides_members_without_own_data() {
        let sys = system(Topology::numa(2, 2));
        let s = MemAwareScheduler::default();
        let m = crate::marcel::Marcel::with_system(&sys);
        let b = m.bubble_init();
        let owner = m.create_dontsched("owner");
        let tagalong = m.create_dontsched("tagalong");
        m.bubble_inserttask(b, owner);
        m.bubble_inserttask(b, tagalong);
        let r = m.region_alloc(1 << 20, AllocPolicy::Fixed(1));
        m.attach_region(owner, r);
        s.wake(&sys, b);
        for t in [owner, tagalong] {
            let list = sys.tasks.with(t, |x| x.last_list).unwrap();
            let leaf_cpu = CpuId(sys.topo.node(list).cpu_first);
            assert_eq!(sys.topo.numa_of(leaf_cpu), 1, "{}", sys.tasks.name(t));
        }
    }

    #[test]
    fn shallow_remote_steal_is_refused_deep_one_allowed() {
        let sys = system(Topology::numa(2, 2));
        let s = MemAwareScheduler::default();
        let victim = sys.topo.leaf_of(CpuId(2)); // other node than cpu0
        let t0 = sys.tasks.new_thread("t0", PRIO_THREAD);
        ops::enqueue(&sys, t0, victim);
        // One queued remote task: factor 3.0 > cap 2.0, queue 1 < 3.
        assert_eq!(s.pick(&sys, CpuId(0)), None, "shallow remote steal must be refused");
        // Same-node CPUs still take it.
        assert_eq!(s.pick(&sys, CpuId(3)), Some(t0));
        s.stop(&sys, CpuId(3), t0, StopReason::Terminate);
        // Deep remote queue: desperation wins.
        let mut ts = Vec::new();
        for i in 0..3 {
            let t = sys.tasks.new_thread(format!("d{i}"), PRIO_THREAD);
            ops::enqueue(&sys, t, victim);
            ts.push(t);
        }
        let got = s.pick(&sys, CpuId(0));
        assert!(got.is_some(), "deep remote queue must be stolen from");
    }

    #[test]
    fn steal_pricing_uses_the_configured_distance_matrix() {
        // Regression (ROADMAP follow-on): memaware must price steals
        // with the machine's real DistanceModel from config, not its
        // built-in default. On an asymmetric interconnect, node 1 is a
        // cheap neighbour of node 0 (1.5 < cap 2.0) while node 2 is an
        // expensive far hop (6.0): a shallow steal from node 1 must be
        // accepted and the same steal from node 2 refused — under the
        // default uniform 3.0 both would be refused.
        use crate::config::ExperimentConfig;
        let cfg = ExperimentConfig::from_toml(
            r#"
            [machine]
            preset = "numa-3x2"
            numa_matrix = ["1.0, 1.5, 6.0", "1.5, 1.0, 2.0", "6.0, 2.0, 1.0"]
            [sched]
            kind = "memaware"
            "#,
        )
        .unwrap();
        let topo = cfg.machine.build_topology().unwrap();
        let sys = system(topo);
        let s = crate::sched::factory::make(&cfg.sched);

        // One shallow task on the far node (node 2): refused.
        let far = sys.tasks.new_thread("far", PRIO_THREAD);
        ops::enqueue(&sys, far, sys.topo.leaf_of(CpuId(4)));
        assert_eq!(s.pick(&sys, CpuId(0)), None, "6.0-factor steal must be refused");
        // Same depth on the cheap neighbour (node 1): accepted.
        let near = sys.tasks.new_thread("near", PRIO_THREAD);
        ops::enqueue(&sys, near, sys.topo.leaf_of(CpuId(2)));
        assert_eq!(s.pick(&sys, CpuId(0)), Some(near), "1.5-factor steal must be taken");

        // Control: the built-in default refuses both shallow steals.
        let sys2 = system(crate::topology::Topology::numa(3, 2));
        let s2 = MemAwareScheduler::default();
        let t = sys2.tasks.new_thread("t", PRIO_THREAD);
        ops::enqueue(&sys2, t, sys2.topo.leaf_of(CpuId(2)));
        assert_eq!(s2.pick(&sys2, CpuId(0)), None);
    }

    #[test]
    fn steal_prefers_victims_on_headroom_nodes() {
        use std::sync::atomic::Ordering;
        // numa(3,2) from cpu0: nodes 1 and 2 are equally distant, so
        // their leaves share one steal tie group (ascending CPU order:
        // node 1 first). Node 1 carries homed bytes; node 2 has
        // headroom — the steal must take node 2's thread and count the
        // headroom override of the plain scan order.
        let sys = system(Topology::numa(3, 2));
        let s = MemAwareScheduler::default();
        let _ = sys.mem.alloc(1 << 20, AllocPolicy::Fixed(1));
        let mut near = Vec::new();
        let mut far = Vec::new();
        for i in 0..3 {
            // Deep queues on both remote nodes so the 3.0-factor
            // steals are admissible (desperate_queue = 3).
            let a = sys.tasks.new_thread(format!("n1t{i}"), PRIO_THREAD);
            ops::enqueue(&sys, a, sys.topo.leaf_of(CpuId(2)));
            near.push(a);
            let b = sys.tasks.new_thread(format!("n2t{i}"), PRIO_THREAD);
            ops::enqueue(&sys, b, sys.topo.leaf_of(CpuId(4)));
            far.push(b);
        }
        let got = s.pick(&sys, CpuId(0)).expect("desperate steal");
        assert!(far.contains(&got), "steal must come from the headroom node (node 2)");
        assert_eq!(sys.metrics.pressure_redirects.load(Ordering::Relaxed), 1);
        assert_eq!(sys.rates.snap(sys.topo.root()).pressure_redirects, 1);
    }

    #[test]
    fn cross_node_steal_marks_regions_next_touch() {
        let sys = system(Topology::numa(2, 2));
        let s = MemAwareScheduler::default();
        let victim = sys.topo.leaf_of(CpuId(2));
        let mut ts = Vec::new();
        for i in 0..3 {
            let t = sys.tasks.new_thread(format!("t{i}"), PRIO_THREAD);
            let r = sys.mem.alloc(4096, AllocPolicy::Fixed(1));
            sys.mem.attach(&sys.tasks, t, r);
            ops::enqueue(&sys, t, victim);
            ts.push((t, r));
        }
        let got = s.pick(&sys, CpuId(0)).expect("desperate steal");
        let (_, r) = ts.iter().find(|(t, _)| *t == got).unwrap();
        assert!(sys.mem.info(*r).next_touch, "stolen thread's memory must follow it");
    }
}
