//! The adaptive steal-scope policy: per-CPU feedback control over *how
//! far* work may be pulled from.
//!
//! ROADMAP's ARMS-direction follow-on (arXiv 2112.09509: adaptive
//! multi-scope work stealing): a fixed steal scope is always wrong
//! somewhere — machine-wide stealing (AFS) scatters threads away from
//! their data on every load dip, while node-confined stealing (CAFS)
//! idles processors whenever the imbalance is *between* nodes. This
//! policy picks the scope online, per CPU, from the feedback counters
//! the core maintains ([`super::core::stats::RateStats`]):
//!
//! * **scope** — each CPU holds a current scope: a prefix of its
//!   covering chain (core → package → node → machine on a deep
//!   machine). Picks search lists inside the scope; steals only take
//!   victims the scope component covers. A leaf scope steals nothing.
//! * **widen** — [`AdaptiveConfig::widen_after`] consecutive empty
//!   picks widen the scope one level (work exists *somewhere*: the
//!   fail streak is the evidence the current scope cannot see it).
//!   Widening is deliberately cheap to trigger — it is the liveness
//!   direction; a starved CPU always reaches machine scope.
//! * **narrow** — every [`AdaptiveConfig::epoch`] pick events the CPU
//!   diffs its scope component's rate counters; when the epoch's
//!   steal-failure ratio is at or below
//!   [`AdaptiveConfig::narrow_fail_ratio`] for
//!   [`AdaptiveConfig::hysteresis`] consecutive epochs, the scope
//!   narrows one level. Narrowing is the affinity direction and is
//!   deliberately slow (hysteresis) so bursty load cannot make the
//!   scope ping-pong.
//! * **steal** — within the scope, victims are taken closest-first
//!   (the precomputed steal order filtered by the scope component), so
//!   even a machine-wide scope prefers same-node victims; a steal that
//!   does cross a NUMA boundary marks the thread's regions next-touch
//!   so its memory follows it (as `memaware` does).
//!
//! Scope switches surface in `metrics.scope_widens` /
//! `metrics.scope_narrows`; [`AdaptiveScheduler::scope_switches`]
//! totals them for tests.

use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::OnceLock;

use super::core::stats::RateSnap;
use super::core::{ops, pick, traversal};
use super::{Scheduler, StopReason, System};
use crate::metrics::Metrics;
use crate::task::TaskId;
use crate::topology::CpuId;

/// Feedback-loop tunables (config keys `sched.adapt_*`).
#[derive(Debug, Clone)]
pub struct AdaptiveConfig {
    /// Consecutive empty picks on a CPU before its scope widens one
    /// level (the liveness direction — keep it small).
    pub widen_after: u32,
    /// Pick events on a CPU between narrow-rate decisions.
    pub epoch: u32,
    /// Consecutive calm epochs required before the scope narrows one
    /// level (the hysteresis that prevents scope ping-pong).
    pub hysteresis: u32,
    /// An epoch is *calm* when its steal-failure ratio over the scope
    /// component is at or below this.
    pub narrow_fail_ratio: f64,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig {
            // Widening is the liveness direction and must be cheap: at
            // the simulator's idle-repoll cadence 4 empty picks cost
            // ~40k cycles, well under one remote-access chunk penalty.
            widen_after: 4,
            epoch: 32,
            hysteresis: 2,
            narrow_fail_ratio: 0.05,
        }
    }
}

/// Floor on the slot allocation, so an instance built against a small
/// machine still has headroom if a generic harness reuses it over a
/// bigger one.
const MIN_SLOTS: usize = 64;

/// Per-CPU controller state as plain atomics. **Single-writer**: only
/// the thread acting as a CPU runs that CPU's pick path (one worker per
/// virtual CPU natively; the simulator is one thread), so every field
/// is a read-modify-write by one thread and `Relaxed` suffices — the
/// pick hot path reads its scope with one load, no lock. Cross-CPU
/// readers (tests via `scope_of`) see each field individually
/// consistent, which is all this advisory state needs.
#[derive(Debug, Default)]
struct CpuSlot {
    /// Index into the CPU's covering chain: 0 = leaf … len-1 = machine.
    scope: AtomicUsize,
    /// Consecutive picks that found nothing within the scope.
    consec_fails: AtomicU32,
    /// Pick events since the last rate decision.
    epoch_events: AtomicU32,
    /// Consecutive calm epochs (towards a narrow).
    narrow_streak: AtomicU32,
    /// Scope component's rate counters at the last decision
    /// (a [`RateSnap`] exploded into per-field atomics).
    last_steal_attempts: AtomicU64,
    last_steal_fails: AtomicU64,
    last_cross_node: AtomicU64,
    last_idles: AtomicU64,
    last_pressure_redirects: AtomicU64,
}

impl CpuSlot {
    fn load_last(&self) -> RateSnap {
        RateSnap {
            steal_attempts: self.last_steal_attempts.load(Ordering::Relaxed),
            steal_fails: self.last_steal_fails.load(Ordering::Relaxed),
            cross_node: self.last_cross_node.load(Ordering::Relaxed),
            idles: self.last_idles.load(Ordering::Relaxed),
            pressure_redirects: self.last_pressure_redirects.load(Ordering::Relaxed),
        }
    }

    fn store_last(&self, s: RateSnap) {
        self.last_steal_attempts.store(s.steal_attempts, Ordering::Relaxed);
        self.last_steal_fails.store(s.steal_fails, Ordering::Relaxed);
        self.last_cross_node.store(s.cross_node, Ordering::Relaxed);
        self.last_idles.store(s.idles, Ordering::Relaxed);
        self.last_pressure_redirects.store(s.pressure_redirects, Ordering::Relaxed);
    }
}

/// Adaptive steal-scope scheduler (registry name: `adaptive`).
#[derive(Debug)]
pub struct AdaptiveScheduler {
    cfg: AdaptiveConfig,
    /// Per-CPU controller slots, allocated once on first sight of a
    /// machine (schedulers are built before they see a [`System`]),
    /// sized `n_cpus.max(MIN_SLOTS)`. A CPU beyond the allocation (an
    /// instance reused over a machine with more than `MIN_SLOTS` extra
    /// CPUs) shares the last slot — the state is advisory, so aliasing
    /// degrades scope choices, never correctness.
    cpus: OnceLock<Box<[CpuSlot]>>,
    switches: AtomicU64,
}

impl AdaptiveScheduler {
    pub fn new(cfg: AdaptiveConfig) -> AdaptiveScheduler {
        AdaptiveScheduler { cfg, cpus: OnceLock::new(), switches: AtomicU64::new(0) }
    }

    /// Total scope switches (widen + narrow) so far — test/report hook.
    pub fn scope_switches(&self) -> u64 {
        self.switches.load(Ordering::Relaxed)
    }

    /// Current scope depth of a CPU (0 = leaf), for tests.
    pub fn scope_of(&self, cpu: CpuId) -> usize {
        match self.cpus.get() {
            Some(slots) => slots[cpu.0.min(slots.len() - 1)].scope.load(Ordering::Relaxed),
            None => 0,
        }
    }

    fn slot(&self, sys: &System, cpu: CpuId) -> &CpuSlot {
        let slots = self.cpus.get_or_init(|| {
            (0..sys.topo.n_cpus().max(MIN_SLOTS)).map(|_| CpuSlot::default()).collect()
        });
        &slots[cpu.0.min(slots.len() - 1)]
    }

    /// The slot's scope clamped to this machine's chain depth (the same
    /// instance may be reused over a shallower machine by generic
    /// harnesses); persists the clamp so later reads agree.
    fn scope_idx(&self, sl: &CpuSlot, depth: usize) -> usize {
        let raw = sl.scope.load(Ordering::Relaxed);
        let clamped = raw.min(depth - 1);
        if clamped != raw {
            sl.scope.store(clamped, Ordering::Relaxed);
        }
        clamped
    }

    /// A pick succeeded within the scope: advance the epoch and run the
    /// narrow decision when it completes.
    fn note_success(&self, sys: &System, cpu: CpuId) {
        let sl = self.slot(sys, cpu);
        sl.consec_fails.store(0, Ordering::Relaxed);
        let events = sl.epoch_events.load(Ordering::Relaxed) + 1;
        sl.epoch_events.store(events, Ordering::Relaxed);
        if events >= self.cfg.epoch {
            self.decide(sys, cpu, sl);
        }
    }

    /// The scope search failed: widen on a long-enough streak, and keep
    /// the epoch clock ticking so droughts still produce decisions.
    fn note_fail(&self, sys: &System, cpu: CpuId) {
        let sl = self.slot(sys, cpu);
        let fails = sl.consec_fails.load(Ordering::Relaxed).saturating_add(1);
        sl.consec_fails.store(fails, Ordering::Relaxed);
        let events = sl.epoch_events.load(Ordering::Relaxed) + 1;
        sl.epoch_events.store(events, Ordering::Relaxed);
        let depth = sys.topo.covering(cpu).len();
        let scope = self.scope_idx(sl, depth);
        if fails >= self.cfg.widen_after && scope + 1 < depth {
            sl.scope.store(scope + 1, Ordering::Relaxed);
            sl.consec_fails.store(0, Ordering::Relaxed);
            sl.narrow_streak.store(0, Ordering::Relaxed);
            sl.epoch_events.store(0, Ordering::Relaxed);
            sl.store_last(sys.rates.snap(sys.topo.covering(cpu)[scope + 1]));
            Metrics::inc(&sys.metrics.scope_widens);
            self.switches.fetch_add(1, Ordering::Relaxed);
            sys.trace_emit(|| {
                let covering = sys.topo.covering(cpu);
                crate::trace::Event::ScopeChange {
                    cpu,
                    from: covering[scope],
                    to: covering[scope + 1],
                    widened: true,
                }
            });
        } else if events >= self.cfg.epoch {
            self.decide(sys, cpu, sl);
        }
    }

    /// End-of-epoch rate decision over the scope component.
    fn decide(&self, sys: &System, cpu: CpuId, sl: &CpuSlot) {
        let depth = sys.topo.covering(cpu).len();
        let scope = self.scope_idx(sl, depth);
        let now = sys.rates.snap(sys.topo.covering(cpu)[scope]);
        let delta = now.since(&sl.load_last());
        sl.store_last(now);
        sl.epoch_events.store(0, Ordering::Relaxed);
        if scope > 0 && delta.fail_ratio() <= self.cfg.narrow_fail_ratio {
            let streak = sl.narrow_streak.load(Ordering::Relaxed) + 1;
            if streak >= self.cfg.hysteresis {
                sl.scope.store(scope - 1, Ordering::Relaxed);
                sl.narrow_streak.store(0, Ordering::Relaxed);
                sl.consec_fails.store(0, Ordering::Relaxed);
                sl.store_last(sys.rates.snap(sys.topo.covering(cpu)[scope - 1]));
                Metrics::inc(&sys.metrics.scope_narrows);
                self.switches.fetch_add(1, Ordering::Relaxed);
                sys.trace_emit(|| {
                    let covering = sys.topo.covering(cpu);
                    crate::trace::Event::ScopeChange {
                        cpu,
                        from: covering[scope],
                        to: covering[scope - 1],
                        widened: false,
                    }
                });
            } else {
                sl.narrow_streak.store(streak, Ordering::Relaxed);
            }
        } else {
            sl.narrow_streak.store(0, Ordering::Relaxed);
        }
    }

    /// Steal closest-first among victims the scope component covers.
    fn steal_scoped(&self, sys: &System, cpu: CpuId, scope_idx: usize) -> Option<TaskId> {
        if scope_idx == 0 {
            return None; // leaf scope: no stealing at all
        }
        let topo = &sys.topo;
        let scope = topo.covering(cpu)[scope_idx];
        sys.rates.on_steal_attempt(topo, cpu);
        if sys.rq.queued_subtree(scope) == 0 {
            ops::note_steal_fail(sys, cpu);
            return None;
        }
        let here = topo.numa_of(cpu);
        for &v in traversal::steal_leaves(topo, cpu) {
            let victim_cpu = CpuId(topo.node(v).cpu_first);
            if !topo.node(scope).covers(victim_cpu) {
                continue;
            }
            if sys.rq.len_of(v) == 0 {
                continue;
            }
            if let Some((t, _prio)) = ops::pop_steal(sys, cpu, v) {
                if topo.numa_of(victim_cpu) != here {
                    // Cross-node steal: ask the thread's memory to
                    // follow it rather than paying the NUMA factor on
                    // every later touch.
                    sys.mem.mark_task_regions_next_touch(t);
                }
                ops::dispatch(sys, cpu, t, topo.leaf_of(cpu));
                return Some(t);
            }
        }
        ops::note_steal_fail(sys, cpu);
        None
    }
}

impl Default for AdaptiveScheduler {
    fn default() -> Self {
        AdaptiveScheduler::new(AdaptiveConfig::default())
    }
}

impl Scheduler for AdaptiveScheduler {
    fn name(&self) -> String {
        "adaptive".into()
    }

    fn wake(&self, sys: &System, task: TaskId) {
        // Opportunist wake (the adaptation lives on the pick path):
        // last-CPU affinity, new threads to the least loaded leaf.
        ops::flatten_wake(sys, task, &mut |sys, t| {
            let list = sys
                .tasks
                .with(t, |x| x.last_cpu)
                .map(|c| sys.topo.leaf_of(c))
                .unwrap_or_else(|| {
                    ops::least_loaded_leaf(sys, (0..sys.topo.n_cpus()).map(CpuId))
                });
            ops::enqueue(sys, t, list);
        });
    }

    fn pick(&self, sys: &System, cpu: CpuId) -> Option<TaskId> {
        let chain = traversal::covering(&sys.topo, cpu);
        let scope_idx = self.scope_idx(self.slot(sys, cpu), chain.len());
        if let Some(t) = pick::pick_thread(sys, cpu, &chain[..=scope_idx]) {
            self.note_success(sys, cpu);
            return Some(t);
        }
        match self.steal_scoped(sys, cpu, scope_idx) {
            Some(t) => {
                self.note_success(sys, cpu);
                Some(t)
            }
            None => {
                self.note_fail(sys, cpu);
                None
            }
        }
    }

    fn stop(&self, sys: &System, cpu: CpuId, task: TaskId, why: StopReason) {
        ops::default_stop(sys, cpu, task, why, &mut |sys, t| {
            ops::enqueue(sys, t, sys.topo.leaf_of(cpu))
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::baselines::testsupport;
    use crate::sched::testutil::system;
    use crate::task::PRIO_THREAD;
    use crate::topology::Topology;

    #[test]
    fn behavioural_suite() {
        testsupport::drains_all_work(&AdaptiveScheduler::default(), Topology::numa(2, 2), 40);
        testsupport::flattens_bubbles(&AdaptiveScheduler::default(), Topology::smp(2));
        testsupport::block_wake_roundtrip(&AdaptiveScheduler::default(), Topology::smp(2));
    }

    #[test]
    fn leaf_scope_refuses_remote_work_then_widens() {
        let sys = system(Topology::numa(2, 2));
        let s = AdaptiveScheduler::new(AdaptiveConfig { widen_after: 3, ..Default::default() });
        // Work queued on the other node only.
        let t = sys.tasks.new_thread("t", PRIO_THREAD);
        ops::enqueue(&sys, t, sys.topo.leaf_of(CpuId(3)));
        // Leaf scope: cpu0 sees nothing and steals nothing…
        assert_eq!(s.pick(&sys, CpuId(0)), None);
        assert_eq!(s.scope_of(CpuId(0)), 0);
        // …until the fail streak widens it to node, then machine scope,
        // where the steal finally lands.
        let mut got = None;
        for _ in 0..20 {
            if let Some(x) = s.pick(&sys, CpuId(0)) {
                got = Some(x);
                break;
            }
        }
        assert_eq!(got, Some(t), "widening must eventually reach the remote task");
        assert!(s.scope_of(CpuId(0)) >= 2, "scope must have widened to machine");
        assert!(s.scope_switches() >= 2);
    }

    #[test]
    fn calm_epochs_narrow_the_scope_back() {
        let sys = system(Topology::numa(2, 2));
        let cfg = AdaptiveConfig {
            widen_after: 2,
            epoch: 4,
            hysteresis: 2,
            ..Default::default()
        };
        let s = AdaptiveScheduler::new(cfg);
        // Force cpu0 wide: fail until machine scope.
        for _ in 0..6 {
            assert_eq!(s.pick(&sys, CpuId(0)), None);
        }
        assert_eq!(s.scope_of(CpuId(0)), 2);
        // Now feed it a steady local diet: every pick succeeds from its
        // own leaf, so epochs are calm and the scope narrows back.
        for i in 0..40 {
            let t = sys.tasks.new_thread(format!("t{i}"), PRIO_THREAD);
            ops::enqueue(&sys, t, sys.topo.leaf_of(CpuId(0)));
            let got = s.pick(&sys, CpuId(0)).expect("local work");
            s.stop(&sys, CpuId(0), got, StopReason::Terminate);
        }
        assert_eq!(s.scope_of(CpuId(0)), 0, "calm epochs must narrow back to the leaf");
        assert!(sys.metrics.scope_narrows.load(Ordering::Relaxed) >= 2);
    }

    #[test]
    fn scoped_steal_stays_inside_the_scope_component() {
        let sys = system(Topology::numa(2, 2));
        let s = AdaptiveScheduler::new(AdaptiveConfig { widen_after: 1, ..Default::default() });
        // Near victim (same node) and far victim (other node).
        let near = sys.tasks.new_thread("near", PRIO_THREAD);
        let far = sys.tasks.new_thread("far", PRIO_THREAD);
        ops::enqueue(&sys, near, sys.topo.leaf_of(CpuId(1)));
        ops::enqueue(&sys, far, sys.topo.leaf_of(CpuId(2)));
        // First pick fails (leaf scope) and widens to node.
        assert_eq!(s.pick(&sys, CpuId(0)), None);
        assert_eq!(s.scope_of(CpuId(0)), 1);
        // Node scope: only the same-node victim is eligible.
        assert_eq!(s.pick(&sys, CpuId(0)), Some(near));
        // The far task is still where it was.
        assert_eq!(sys.rq.len_of(sys.topo.leaf_of(CpuId(2))), 1);
        let _ = far;
    }

    #[test]
    fn cross_node_steal_marks_memory_next_touch() {
        use crate::mem::AllocPolicy;
        let sys = system(Topology::numa(2, 2));
        let s = AdaptiveScheduler::new(AdaptiveConfig { widen_after: 1, ..Default::default() });
        let t = sys.tasks.new_thread("t", PRIO_THREAD);
        let r = sys.mem.alloc(4096, AllocPolicy::Fixed(1));
        sys.mem.attach(&sys.tasks, t, r);
        ops::enqueue(&sys, t, sys.topo.leaf_of(CpuId(2)));
        // Widen leaf → node → machine, then steal across nodes.
        assert_eq!(s.pick(&sys, CpuId(0)), None);
        assert_eq!(s.pick(&sys, CpuId(0)), None);
        let got = s.pick(&sys, CpuId(0));
        assert_eq!(got, Some(t));
        assert!(sys.mem.info(r).next_touch, "stolen thread's memory must follow it");
    }
}
