//! Cross-job processor reallocation under a hierarchical fairness
//! policy (registry name: `job-fair`).
//!
//! [`super::MoldableGangScheduler`] resizes gangs *within* one
//! application; a job server needs the same machinery *across* jobs
//! (Cao et al., "Scalable Hierarchical Scheduling for Malleable
//! Parallel Jobs"): every job is a gang owning one topology component,
//! and processors move between jobs as their demand and urgency shift.
//! This policy keeps moldable-gang's placement/shrink/expand/park
//! protocol and adds the cross-job fairness layer the server mode
//! (`crate::serve`) schedules its mix with:
//!
//! * **Deadline classes** ([`DeadlineClass`], set per job via
//!   [`JobFairScheduler::set_class`]): `Latency` > `Normal` > `Batch`.
//!   Waiting jobs are admitted to the machine strictest-class first
//!   (FIFO within a class), so a latency job never queues behind a
//!   backlog of batch work.
//! * **Starvation squeeze**: when a live job waits with no free
//!   component for [`JobFairConfig::starve_hysteresis`] consecutive
//!   pick evaluations, the weakest-class active job (largest component
//!   on ties) is *squeezed* — shrunk one level towards its busiest
//!   child even if its demand overcommits the smaller set. A victim
//!   already at a leaf is rotated off entirely, but only for a
//!   strictly stricter waiter. Squeezes are hysteresis-damped exactly
//!   like resizes, and counted in `metrics.job_reallocations`.
//! * **Expansion fairness**: a job never expands while another live
//!   job is waiting for space — freed processors go to waiters first.
//! * **Static partition baseline** ([`JobFairConfig::static_partition`]):
//!   every job is pinned round-robin (by admission order) to one child
//!   of the machine root and never resized — the per-job fixed
//!   partition that `repro serve` compares reallocation against.
//!
//! Fairness knobs: `resize_hysteresis` (demand-driven shrink/expand
//! damping, shared with moldable-gang), `starve_hysteresis` (how long
//! a waiter starves before a squeeze), `timeslice` (rotation of equal
//! jobs when the machine is overcommitted), `static_partition` (the
//! baseline switch). With no classes set and no starving waiters the
//! policy behaves like moldable-gang, which is what lets the whole
//! conformance matrix run over it unchanged.

use std::collections::{HashMap, VecDeque};
use std::sync::Mutex;

use super::core::{ops, pick};
use super::{Scheduler, StopReason, System};
use crate::metrics::Metrics;
use crate::task::{TaskId, TaskState};
use crate::topology::{CpuId, LevelId, Topology};
use crate::trace::{Event, RegenWhy};

/// How urgent a job's completion is. Ordered by strictness: a stricter
/// class is admitted first and can squeeze processors out of weaker
/// ones.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum DeadlineClass {
    /// Throughput work: runs whenever space is left over.
    Batch,
    /// The default class.
    Normal,
    /// Deadline-sensitive work: admitted first, may squeeze others.
    Latency,
}

impl DeadlineClass {
    pub fn label(&self) -> &'static str {
        match self {
            DeadlineClass::Batch => "batch",
            DeadlineClass::Normal => "normal",
            DeadlineClass::Latency => "latency",
        }
    }

    /// Parse a class name (CLI / spool files).
    pub fn parse(s: &str) -> Option<DeadlineClass> {
        match s.trim().to_ascii_lowercase().as_str() {
            "batch" => Some(DeadlineClass::Batch),
            "normal" => Some(DeadlineClass::Normal),
            "latency" => Some(DeadlineClass::Latency),
            _ => None,
        }
    }
}

/// Tunables (config keys `sched.resize_hysteresis`, `sched.timeslice`).
#[derive(Debug, Clone)]
pub struct JobFairConfig {
    /// Consecutive resize evaluations that must agree before a
    /// demand-driven shrink/expand commits (as in moldable-gang).
    pub resize_hysteresis: u32,
    /// Consecutive pick evaluations a live job must starve (waiting
    /// with no free component) before the weakest active job is
    /// squeezed.
    pub starve_hysteresis: u32,
    /// Engine time a job may own its component while another live job
    /// waits, before [`Scheduler::tick`] rotates it off.
    pub timeslice: Option<u64>,
    /// Baseline mode: pin each job round-robin to one child of the
    /// machine root, never resize, never squeeze.
    pub static_partition: bool,
}

impl Default for JobFairConfig {
    fn default() -> Self {
        JobFairConfig {
            resize_hysteresis: 4,
            starve_hysteresis: 4,
            timeslice: None,
            static_partition: false,
        }
    }
}

/// One active job and the component it owns.
#[derive(Debug, Clone)]
struct JobSlot {
    gang: TaskId,
    comp: LevelId,
    shrink_streak: u32,
    expand_streak: u32,
    /// Engine time consumed since placement (timeslice rotation).
    used: u64,
}

#[derive(Debug, Default)]
struct JobState {
    /// Jobs currently owning components (pairwise-disjoint, except in
    /// static-partition mode where jobs may share their pinned child).
    active: Vec<JobSlot>,
    /// Jobs waiting for a free component.
    queue: VecDeque<TaskId>,
    /// Jobs off the machine because every member is blocked.
    parked: Vec<TaskId>,
    /// Deadline class per job root (absent = Normal).
    classes: HashMap<TaskId, DeadlineClass>,
    /// Consecutive pick evaluations some live waiter found no space.
    starve_streak: u32,
    /// Round-robin cursor for static-partition pinning.
    next_static: usize,
    /// Pinned partition per job (static mode; stable across park).
    static_home: HashMap<TaskId, LevelId>,
}

/// Cross-job fair scheduler (registry name: `job-fair`).
#[derive(Debug)]
pub struct JobFairScheduler {
    cfg: JobFairConfig,
    st: Mutex<JobState>,
}

/// Two components' CPU ranges intersect.
fn overlaps(topo: &Topology, a: LevelId, b: LevelId) -> bool {
    let na = topo.node(a);
    let nb = topo.node(b);
    na.cpu_first < nb.cpu_first + nb.cpu_count && nb.cpu_first < na.cpu_first + na.cpu_count
}

/// Members that want a CPU now or will once activated.
fn demand_of(sys: &System, ms: &[TaskId]) -> usize {
    ms.iter()
        .filter(|&&m| {
            matches!(
                sys.tasks.state(m),
                TaskState::New
                    | TaskState::InBubble
                    | TaskState::Ready { .. }
                    | TaskState::Running { .. }
            )
        })
        .count()
}

/// Collected thread members of a job (one traversal per caller).
fn members(sys: &System, gang: TaskId) -> Vec<TaskId> {
    let mut ms = Vec::new();
    ops::thread_members(sys, gang, &mut ms);
    ms
}

/// The class a job runs under (Normal unless declared).
fn class_of(st: &JobState, gang: TaskId) -> DeadlineClass {
    st.classes.get(&gang).copied().unwrap_or(DeadlineClass::Normal)
}

/// The static-partition components: the children of the machine root
/// (the root itself on a flat machine).
fn partitions(topo: &Topology) -> Vec<LevelId> {
    let root = topo.root();
    let ch = &topo.node(root).children;
    if ch.is_empty() {
        vec![root]
    } else {
        ch.clone()
    }
}

impl JobFairScheduler {
    pub fn new(cfg: JobFairConfig) -> JobFairScheduler {
        JobFairScheduler { cfg, st: Mutex::new(JobState::default()) }
    }

    /// Declare a job's deadline class (call before or after waking the
    /// job root; absent = Normal).
    pub fn set_class(&self, gang: TaskId, class: DeadlineClass) {
        self.st.lock().unwrap().classes.insert(gang, class);
    }

    /// Snapshot of (job, owned component) pairs — test hook.
    pub fn assignments(&self) -> Vec<(TaskId, LevelId)> {
        let st = self.st.lock().unwrap();
        st.active.iter().map(|s| (s.gang, s.comp)).collect()
    }

    /// The pinned partition of a job in static mode (assigned round
    /// robin at first placement, stable across park/unpark).
    fn static_home_of(&self, sys: &System, st: &mut JobState, gang: TaskId) -> LevelId {
        if let Some(&h) = st.static_home.get(&gang) {
            return h;
        }
        let parts = partitions(&sys.topo);
        let h = parts[st.next_static % parts.len()];
        st.next_static += 1;
        st.static_home.insert(gang, h);
        h
    }

    /// The child of `comp` the job should shrink into: big enough for
    /// the demand, holding the most members by last-run CPU.
    fn shrink_target(
        &self,
        sys: &System,
        comp: LevelId,
        ms: &[TaskId],
        d: usize,
    ) -> Option<LevelId> {
        let node = sys.topo.node(comp);
        if node.children.is_empty() || d == 0 || d >= node.cpu_count {
            return None;
        }
        let mut best: Option<(usize, LevelId)> = None;
        for &c in &node.children {
            let cn = sys.topo.node(c);
            if cn.cpu_count < d {
                continue;
            }
            let count = ms
                .iter()
                .filter(|&&m| {
                    sys.tasks
                        .with(m, |t| t.last_cpu)
                        .map(|cpu| cn.covers(cpu))
                        .unwrap_or(false)
                })
                .count();
            if best.map_or(true, |(bc, _)| count > bc) {
                best = Some((count, c));
            }
        }
        best.map(|(_, c)| c)
    }

    /// The child of `comp` a *squeeze* forces the job into: the one
    /// holding the most members, capacity ignored (the job overcommits
    /// on purpose — the freed siblings go to the starving waiter).
    fn squeeze_target(&self, sys: &System, comp: LevelId, ms: &[TaskId]) -> LevelId {
        let node = sys.topo.node(comp);
        let mut best = (usize::MAX, node.children[0]);
        for &c in &node.children {
            let cn = sys.topo.node(c);
            let count = ms
                .iter()
                .filter(|&&m| {
                    sys.tasks
                        .with(m, |t| t.last_cpu)
                        .map(|cpu| cn.covers(cpu))
                        .unwrap_or(false)
                })
                .count();
            if best.0 == usize::MAX || count > best.0 {
                best = (count, c);
            }
        }
        best.1
    }

    /// Commit a resize: move the slot to `to` and migrate every queued
    /// member onto the new component's list.
    fn apply_resize(
        &self,
        sys: &System,
        st: &mut JobState,
        i: usize,
        ms: &[TaskId],
        to: LevelId,
        shrink: bool,
    ) {
        let gang = st.active[i].gang;
        let from = st.active[i].comp;
        st.active[i].comp = to;
        st.active[i].shrink_streak = 0;
        st.active[i].expand_streak = 0;
        for &m in ms {
            if let Some(list) = sys.tasks.state(m).ready_list() {
                if list != to && sys.rq.remove(list, m, sys.tasks.prio(m)) {
                    ops::enqueue(sys, m, to);
                }
            }
        }
        Metrics::inc(if shrink {
            &sys.metrics.gang_shrinks
        } else {
            &sys.metrics.gang_expands
        });
        sys.trace.emit(sys.now(), Event::RegenDone { bubble: gang, list: to });
        sys.trace_emit(|| Event::GangResize { gang, from, to, grew: !shrink });
    }

    /// Release a job's runnable members onto its component's list.
    fn activate(&self, sys: &System, gang: TaskId, comp: LevelId) {
        if sys.tasks.is_bubble(gang) {
            sys.tasks.with(gang, |t| t.state = TaskState::Blocked);
        }
        let mut ms = Vec::new();
        ops::thread_members(sys, gang, &mut ms);
        for m in ms {
            if let Some(p) = sys.tasks.parent(m) {
                if p != gang && sys.tasks.is_bubble(p) {
                    sys.tasks.with(p, |t| t.state = TaskState::Blocked);
                }
            }
            match sys.tasks.state(m) {
                TaskState::New | TaskState::InBubble => ops::enqueue(sys, m, comp),
                TaskState::Ready { list } => {
                    if list != comp && sys.rq.remove(list, m, sys.tasks.prio(m)) {
                        ops::enqueue(sys, m, comp);
                    }
                }
                TaskState::Blocked if m == gang => ops::enqueue(sys, m, comp),
                _ => {}
            }
        }
    }

    /// Index (into the queue) of the waiter to admit next: strictest
    /// class first, FIFO within a class. Dead jobs are dropped.
    fn best_waiter(&self, sys: &System, st: &mut JobState) -> Option<usize> {
        st.queue.retain(|&g| ops::gang_live(sys, g));
        let mut best: Option<(usize, DeadlineClass)> = None;
        for (i, &g) in st.queue.iter().enumerate() {
            let c = class_of(st, g);
            if best.map_or(true, |(_, bc)| c > bc) {
                best = Some((i, c));
            }
        }
        best.map(|(i, _)| i)
    }

    /// Place waiting jobs on free components while any exist
    /// (strictest class first; static mode pins each job immediately).
    fn place_waiting(&self, sys: &System, st: &mut JobState) {
        if self.cfg.static_partition {
            loop {
                st.queue.retain(|&g| ops::gang_live(sys, g));
                let Some(g) = st.queue.pop_front() else { return };
                let comp = self.static_home_of(sys, st, g);
                st.active.push(JobSlot {
                    gang: g,
                    comp,
                    shrink_streak: 0,
                    expand_streak: 0,
                    used: 0,
                });
                self.activate(sys, g, comp);
            }
        }
        loop {
            let Some(i) = self.best_waiter(sys, st) else { return };
            let Some(comp) = self.find_free(sys, st) else { return };
            let g = st.queue.remove(i).expect("waiter index in range");
            st.active.push(JobSlot {
                gang: g,
                comp,
                shrink_streak: 0,
                expand_streak: 0,
                used: 0,
            });
            self.activate(sys, g, comp);
        }
    }

    /// Largest free component: first in BFS id order that overlaps no
    /// active job's set.
    fn find_free(&self, sys: &System, st: &JobState) -> Option<LevelId> {
        (0..sys.topo.n_components())
            .map(LevelId)
            .find(|&l| st.active.iter().all(|s| !overlaps(&sys.topo, l, s.comp)))
    }

    /// Hysteresis-damped demand-driven resize for one active job.
    /// Expansion is additionally refused while any live job waits —
    /// freed processors belong to waiters first.
    fn maybe_resize(&self, sys: &System, st: &mut JobState, i: usize, ms: &[TaskId]) {
        if self.cfg.static_partition {
            return;
        }
        let comp = st.active[i].comp;
        let d = demand_of(sys, ms);
        if let Some(child) = self.shrink_target(sys, comp, ms, d) {
            st.active[i].expand_streak = 0;
            st.active[i].shrink_streak += 1;
            if st.active[i].shrink_streak >= self.cfg.resize_hysteresis {
                self.apply_resize(sys, st, i, ms, child, true);
            }
            return;
        }
        st.active[i].shrink_streak = 0;
        let parent = sys.topo.node(comp).parent;
        let waiter = st.queue.iter().any(|&g| ops::gang_live(sys, g));
        if d > sys.topo.node(comp).cpu_count && !waiter {
            if let Some(parent) = parent {
                let blocked = st
                    .active
                    .iter()
                    .enumerate()
                    .any(|(j, s)| j != i && overlaps(&sys.topo, parent, s.comp));
                if !blocked {
                    st.active[i].expand_streak += 1;
                    if st.active[i].expand_streak >= self.cfg.resize_hysteresis {
                        self.apply_resize(sys, st, i, ms, parent, false);
                    }
                    return;
                }
            }
        }
        st.active[i].expand_streak = 0;
    }

    /// The cross-job fairness move: when a live waiter has starved for
    /// `starve_hysteresis` pick evaluations with no free component,
    /// squeeze the weakest-class active job (largest component on
    /// ties) one level towards its busiest child — or rotate it off
    /// entirely when it already sits on a leaf and the waiter's class
    /// is strictly stricter.
    fn maybe_squeeze(&self, sys: &System, st: &mut JobState) {
        let Some(wi) = self.best_waiter(sys, st) else {
            st.starve_streak = 0;
            return;
        };
        if self.find_free(sys, st).is_some() {
            st.starve_streak = 0;
            self.place_waiting(sys, st);
            return;
        }
        st.starve_streak += 1;
        if st.starve_streak < self.cfg.starve_hysteresis {
            return;
        }
        st.starve_streak = 0;
        let wclass = class_of(st, st.queue[wi]);
        let Some(v) = st
            .active
            .iter()
            .enumerate()
            .min_by_key(|(_, s)| {
                (class_of(st, s.gang), std::cmp::Reverse(sys.topo.node(s.comp).cpu_count))
            })
            .map(|(i, _)| i)
        else {
            return;
        };
        let vclass = class_of(st, st.active[v].gang);
        let comp = st.active[v].comp;
        if !sys.topo.node(comp).children.is_empty() {
            let gang = st.active[v].gang;
            let ms = members(sys, gang);
            let child = self.squeeze_target(sys, comp, &ms);
            self.apply_resize(sys, st, v, &ms, child, true);
            Metrics::inc(&sys.metrics.job_reallocations);
        } else if wclass > vclass {
            // Leaf-level victim, strictly stricter waiter: rotate it
            // off the machine (queued members return inside the job;
            // running members fall back in on their next stop).
            let slot = st.active.swap_remove(v);
            let ms = members(sys, slot.gang);
            for &m in &ms {
                if let Some(l) = sys.tasks.state(m).ready_list() {
                    if sys.rq.remove(l, m, sys.tasks.prio(m)) {
                        sys.tasks.set_state(
                            m,
                            if sys.tasks.parent(m).is_some() {
                                TaskState::InBubble
                            } else {
                                TaskState::Blocked
                            },
                        );
                    }
                }
            }
            st.queue.push_back(slot.gang);
            Metrics::inc(&sys.metrics.job_reallocations);
            sys.trace
                .emit(sys.now(), Event::Regen { bubble: slot.gang, why: RegenWhy::Timeslice });
        } else {
            return;
        }
        self.place_waiting(sys, st);
    }
}

impl Default for JobFairScheduler {
    fn default() -> Self {
        JobFairScheduler::new(JobFairConfig::default())
    }
}

impl Scheduler for JobFairScheduler {
    fn name(&self) -> String {
        "job-fair".into()
    }

    fn wake(&self, sys: &System, task: TaskId) {
        let mut st = self.st.lock().unwrap();
        if sys.tasks.parent(task).is_some() {
            // A member of some job woke; only a genuinely blocked
            // member needs action.
            let gang = ops::root_bubble(sys, task);
            if sys.tasks.state(task) == TaskState::Blocked {
                if let Some(slot) = st.active.iter().find(|s| s.gang == gang) {
                    ops::enqueue(sys, task, slot.comp);
                } else {
                    sys.tasks.set_state(task, TaskState::InBubble);
                    if let Some(p) = st.parked.iter().position(|&g| g == gang) {
                        st.parked.remove(p);
                        st.queue.push_back(gang);
                        self.place_waiting(sys, &mut st);
                    }
                }
            }
            sys.notify_enqueue();
            return;
        }
        // The task IS a job root: a bubble, or a loose (singleton)
        // thread.
        if sys.tasks.is_bubble(task) {
            sys.tasks.with(task, |t| t.state = TaskState::Blocked);
        }
        if let Some(slot) = st.active.iter().find(|s| s.gang == task) {
            if !sys.tasks.is_bubble(task) && sys.tasks.state(task) == TaskState::Blocked {
                ops::enqueue(sys, task, slot.comp);
            }
        } else {
            if let Some(p) = st.parked.iter().position(|&g| g == task) {
                st.parked.remove(p);
            }
            if !st.queue.contains(&task) {
                st.queue.push_back(task);
            }
            self.place_waiting(sys, &mut st);
        }
        sys.notify_enqueue();
    }

    fn pick(&self, sys: &System, cpu: CpuId) -> Option<TaskId> {
        let mut st = self.st.lock().unwrap();
        self.place_waiting(sys, &mut st);
        let Some(i) = st.active.iter().position(|s| sys.topo.node(s.comp).covers(cpu)) else {
            if !self.cfg.static_partition {
                self.maybe_squeeze(sys, &mut st);
                if let Some(j) =
                    st.active.iter().position(|s| sys.topo.node(s.comp).covers(cpu))
                {
                    let comp = st.active[j].comp;
                    return pick::pick_thread(sys, cpu, &[comp]);
                }
            }
            return None;
        };
        let comp = st.active[i].comp;
        let gang = st.active[i].gang;
        if let Some(t) = pick::pick_thread(sys, cpu, &[comp]) {
            let ms = members(sys, gang);
            self.maybe_resize(sys, &mut st, i, &ms);
            if !self.cfg.static_partition {
                self.maybe_squeeze(sys, &mut st);
            }
            return Some(t);
        }
        let ms = members(sys, gang);
        if demand_of(sys, &ms) == 0 {
            // Nothing in this job can run: give the CPUs back.
            st.active.swap_remove(i);
            if ops::gang_live(sys, gang) {
                st.parked.push(gang);
                sys.trace.emit(sys.now(), Event::Regen { bubble: gang, why: RegenWhy::Idle });
            }
            self.place_waiting(sys, &mut st);
            // Retry once: a freshly placed job may cover this CPU.
            if let Some(j) =
                st.active.iter().position(|s| sys.topo.node(s.comp).covers(cpu))
            {
                let comp = st.active[j].comp;
                return pick::pick_thread(sys, cpu, &[comp]);
            }
            return None;
        }
        self.maybe_resize(sys, &mut st, i, &ms);
        if !self.cfg.static_partition {
            self.maybe_squeeze(sys, &mut st);
        }
        None
    }

    fn stop(&self, sys: &System, cpu: CpuId, task: TaskId, why: StopReason) {
        ops::default_stop(sys, cpu, task, why, &mut |sys, t| {
            let gang = ops::root_bubble(sys, t);
            let mut st = self.st.lock().unwrap();
            if let Some(slot) = st.active.iter().find(|s| s.gang == gang) {
                ops::enqueue(sys, t, slot.comp);
            } else if sys.tasks.parent(t).is_some() {
                sys.tasks.set_state(t, TaskState::InBubble);
            } else {
                sys.tasks.set_state(t, TaskState::Blocked);
                if !st.queue.contains(&t) {
                    st.queue.push_back(t);
                }
                self.place_waiting(sys, &mut st);
            }
        });
        if why == StopReason::Terminate {
            let gang = ops::root_bubble(sys, task);
            let mut st = self.st.lock().unwrap();
            if let Some(i) = st.active.iter().position(|s| s.gang == gang) {
                if !ops::gang_live(sys, gang) {
                    st.active.swap_remove(i);
                    st.classes.remove(&gang);
                    st.static_home.remove(&gang);
                    self.place_waiting(sys, &mut st);
                    sys.notify_enqueue();
                }
            }
        }
    }

    fn tick(&self, sys: &System, _cpu: CpuId, task: TaskId, elapsed: u64) -> bool {
        // Timeslice rotation when the machine is overcommitted; space
        // sharing (shrink/squeeze/park) is always tried first. The
        // static baseline never rotates — jobs pinned to one partition
        // time-share through their shared list instead.
        let Some(slice) = self.cfg.timeslice else { return false };
        if self.cfg.static_partition {
            return false;
        }
        let gang = ops::root_bubble(sys, task);
        let mut st = self.st.lock().unwrap();
        let Some(i) = st.active.iter().position(|s| s.gang == gang) else {
            return false;
        };
        st.active[i].used += elapsed;
        if st.active[i].used < slice || !st.queue.iter().any(|&g| ops::gang_live(sys, g)) {
            return false;
        }
        let slot = st.active.swap_remove(i);
        let ms = members(sys, gang);
        for &m in &ms {
            if let Some(l) = sys.tasks.state(m).ready_list() {
                if sys.rq.remove(l, m, sys.tasks.prio(m)) {
                    sys.tasks.set_state(
                        m,
                        if sys.tasks.parent(m).is_some() {
                            TaskState::InBubble
                        } else {
                            TaskState::Blocked
                        },
                    );
                }
            }
        }
        st.queue.push_back(slot.gang);
        Metrics::inc(&sys.metrics.regenerations);
        sys.trace.emit(sys.now(), Event::Regen { bubble: gang, why: RegenWhy::Timeslice });
        self.place_waiting(sys, &mut st);
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::marcel::Marcel;
    use crate::sched::baselines::testsupport;
    use crate::sched::testutil::system;
    use crate::topology::Topology;

    fn gang_of(m: &Marcel, n: usize, tag: &str) -> (TaskId, Vec<TaskId>) {
        let b = m.bubble_init();
        let ts: Vec<TaskId> = (0..n).map(|i| m.create_dontsched(format!("{tag}{i}"))).collect();
        for &t in &ts {
            m.bubble_inserttask(b, t);
        }
        (b, ts)
    }

    #[test]
    fn behavioural_suite() {
        testsupport::drains_all_work(&JobFairScheduler::default(), Topology::numa(2, 2), 40);
        testsupport::flattens_bubbles(&JobFairScheduler::default(), Topology::smp(2));
        testsupport::block_wake_roundtrip(&JobFairScheduler::default(), Topology::smp(2));
    }

    #[test]
    fn stricter_class_is_admitted_first() {
        let sys = system(Topology::smp(4));
        let s = JobFairScheduler::default();
        let m = Marcel::with_system(&sys);
        let (g1, t1) = gang_of(&m, 2, "a");
        let (g2, t2) = gang_of(&m, 2, "b");
        let (g3, t3) = gang_of(&m, 2, "c");
        s.set_class(g2, DeadlineClass::Batch);
        s.set_class(g3, DeadlineClass::Latency);
        s.wake(&sys, g1);
        s.wake(&sys, g2);
        s.wake(&sys, g3);
        // Job 1 owns the root (no shrink: demand 2 exceeds every leaf).
        let x = s.pick(&sys, CpuId(0)).expect("job1 thread");
        let y = s.pick(&sys, CpuId(1)).expect("job1 thread");
        assert!(t1.contains(&x) && t1.contains(&y));
        s.stop(&sys, CpuId(0), x, StopReason::Terminate);
        s.stop(&sys, CpuId(1), y, StopReason::Terminate);
        // The freed machine goes to the latency job, not the earlier
        // batch job.
        let z = s.pick(&sys, CpuId(0)).expect("next job thread");
        assert!(t3.contains(&z), "latency job must be admitted before batch");
        let _ = (g1, t2);
    }

    #[test]
    fn starving_waiter_squeezes_the_weakest_job() {
        let sys = system(Topology::numa(2, 2));
        let s = JobFairScheduler::new(JobFairConfig {
            resize_hysteresis: 100, // demand-driven resize never fires
            starve_hysteresis: 1,
            ..Default::default()
        });
        let m = Marcel::with_system(&sys);
        let (g1, t1) = gang_of(&m, 4, "a"); // fills the whole machine
        let (g2, t2) = gang_of(&m, 1, "b");
        s.set_class(g1, DeadlineClass::Batch);
        s.set_class(g2, DeadlineClass::Latency);
        s.wake(&sys, g1);
        s.wake(&sys, g2);
        // Demand 4 = root capacity: no demand shrink is possible, so
        // only the starvation squeeze can make room for job 2.
        let x = s.pick(&sys, CpuId(0)).expect("job1 thread");
        assert!(t1.contains(&x));
        // The pick above observed the starving latency job and squeezed
        // job 1 one level down; job 2 got the freed node.
        let a = s.assignments();
        assert_eq!(a.len(), 2, "both jobs on the machine after the squeeze: {a:?}");
        assert_ne!(a[0].1, sys.topo.root());
        let mut got_t2 = false;
        for c in 0..4 {
            if let Some(t) = s.pick(&sys, CpuId(c)) {
                got_t2 |= t2.contains(&t);
            }
        }
        assert!(got_t2, "the latency job must run on the freed component");
        assert!(
            sys.metrics.job_reallocations.load(std::sync::atomic::Ordering::Relaxed) >= 1
        );
    }

    #[test]
    fn leaf_victim_rotates_off_for_a_stricter_waiter() {
        let sys = system(Topology::smp(2));
        let s = JobFairScheduler::new(JobFairConfig {
            resize_hysteresis: 100,
            starve_hysteresis: 1,
            ..Default::default()
        });
        let m = Marcel::with_system(&sys);
        let (g1, t1) = gang_of(&m, 2, "a");
        s.set_class(g1, DeadlineClass::Batch);
        s.wake(&sys, g1);
        let x = s.pick(&sys, CpuId(0)).expect("job1 thread");
        assert!(t1.contains(&x));
        // A latency waiter arrives: the first squeeze pushes job 1 from
        // the root onto one leaf and places job 2 on the other.
        let (g2, t2) = gang_of(&m, 1, "b");
        s.set_class(g2, DeadlineClass::Latency);
        s.wake(&sys, g2);
        let _ = s.pick(&sys, CpuId(1));
        assert_eq!(s.assignments().len(), 2);
        // A second latency waiter: job 1 now sits on a leaf, so the
        // squeeze rotates it off the machine entirely.
        let (g3, t3) = gang_of(&m, 1, "c");
        s.set_class(g3, DeadlineClass::Latency);
        s.wake(&sys, g3);
        let mut seen = Vec::new();
        for _ in 0..8 {
            for c in 0..2 {
                if let Some(t) = s.pick(&sys, CpuId(c)) {
                    seen.push(t);
                    s.stop(&sys, CpuId(c), t, StopReason::Yield);
                }
            }
        }
        assert!(
            seen.iter().any(|t| t3.contains(t)),
            "second latency job must displace the leaf-level batch job: {seen:?}"
        );
        let _ = t2;
    }

    #[test]
    fn static_partition_pins_jobs_round_robin_and_never_resizes() {
        let sys = system(Topology::numa(2, 2));
        let s = JobFairScheduler::new(JobFairConfig {
            static_partition: true,
            starve_hysteresis: 1,
            resize_hysteresis: 1,
            ..Default::default()
        });
        let m = Marcel::with_system(&sys);
        let (g1, t1) = gang_of(&m, 1, "a");
        let (g2, t2) = gang_of(&m, 1, "b");
        let (g3, t3) = gang_of(&m, 1, "c");
        s.wake(&sys, g1);
        s.wake(&sys, g2);
        s.wake(&sys, g3);
        let a = s.assignments();
        assert_eq!(a.len(), 3, "static mode admits everyone immediately: {a:?}");
        // Round robin over the root's children: jobs 1 and 3 share the
        // first partition, job 2 gets the second.
        assert_eq!(a[0].1, a[2].1, "jobs 1 and 3 share a partition");
        assert_ne!(a[0].1, a[1].1, "job 2 is pinned elsewhere");
        assert_ne!(a[0].1, sys.topo.root(), "nobody owns the whole machine");
        // Both partitions run work; singletons never resize.
        let x = s.pick(&sys, CpuId(0)).expect("partition 0 runs");
        let y = s.pick(&sys, CpuId(2)).expect("partition 1 runs");
        assert!(t1.contains(&x) || t3.contains(&x));
        assert!(t2.contains(&y));
        assert_eq!(s.assignments().len(), 3, "no slot was resized or dropped");
        assert_eq!(
            sys.metrics.gang_shrinks.load(std::sync::atomic::Ordering::Relaxed)
                + sys.metrics.gang_expands.load(std::sync::atomic::Ordering::Relaxed),
            0
        );
    }
}
