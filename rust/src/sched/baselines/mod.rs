//! Baseline schedulers from the paper's related-work section (§2).
//!
//! * [`ss::SsScheduler`] — Self-Scheduling: one global list (Tang & Yew;
//!   the Linux 2.4 / Windows 2000 structure). The Table-2 "Simple" row.
//! * [`chunk::GssScheduler`] / [`chunk::TssScheduler`] — Guided /
//!   Trapezoid Self-Scheduling: idle processors grab decreasing chunks
//!   of the global list.
//! * [`afs::AfsScheduler`] / [`afs::LdsScheduler`] — per-processor lists
//!   with work stealing; LDS picks victims by locality.
//! * [`cafs::CafsScheduler`] / [`cafs::HafsScheduler`] — clustered AFS
//!   (√p groups aligned to NUMA nodes) and its hierarchical variant
//!   (idle groups steal from the most loaded group).
//! * [`bound::BoundScheduler`] — predetermined thread→CPU binding
//!   (§2.1). The Table-2 "Bound" row.
//! * [`gang::GangScheduler`] — Ousterhout gang scheduling (§3.1): one
//!   gang owns the whole machine at a time.
//!
//! All baselines flatten bubbles on wake: opportunist schedulers ignore
//! application structure (that is precisely the paper's criticism).

pub mod afs;
pub mod bound;
pub mod cafs;
pub mod chunk;
pub mod gang;
pub mod ss;

pub use afs::{AfsScheduler, LdsScheduler};
pub use bound::BoundScheduler;
pub use cafs::{CafsScheduler, HafsScheduler};
pub use chunk::{GssScheduler, TssScheduler};
pub use gang::GangScheduler;
pub use ss::SsScheduler;

use std::sync::Arc;

use super::{BubbleScheduler, Scheduler, System};
use crate::config::{SchedConfig, SchedKind};
use crate::metrics::Metrics;
use crate::task::{TaskId, TaskState};
use crate::topology::{CpuId, LevelId};
use crate::trace::Event;

/// Instantiate any scheduler by kind.
pub fn make(cfg: &SchedConfig) -> Arc<dyn Scheduler> {
    match cfg.kind {
        SchedKind::Bubble => Arc::new(BubbleScheduler::new(cfg.bubble_config())),
        SchedKind::Ss => Arc::new(SsScheduler::new()),
        SchedKind::Gss => Arc::new(GssScheduler::new()),
        SchedKind::Tss => Arc::new(TssScheduler::new()),
        SchedKind::Afs => Arc::new(AfsScheduler::new()),
        SchedKind::Lds => Arc::new(LdsScheduler::new()),
        SchedKind::Cafs => Arc::new(CafsScheduler::new()),
        SchedKind::Hafs => Arc::new(HafsScheduler::new()),
        SchedKind::Bound => Arc::new(BoundScheduler::new()),
        SchedKind::Gang => Arc::new(GangScheduler::new(cfg.timeslice.unwrap_or(1_000_000))),
    }
}

/// Instantiate with defaults for a kind.
pub fn make_default(kind: SchedKind) -> Arc<dyn Scheduler> {
    make(&SchedConfig { kind, ..SchedConfig::default() })
}

// ------------------------------------------------------- shared helpers

/// Enqueue `task` on `list`, fixing state (shared by all baselines).
pub(crate) fn enqueue(sys: &System, task: TaskId, list: LevelId) {
    let prio = sys.tasks.with(task, |t| {
        t.state = TaskState::Ready { list };
        t.last_list = Some(list);
        t.prio
    });
    sys.rq.push(list, task, prio);
    sys.trace.emit(sys.now(), Event::Enqueue { task, list });
}

/// Mark a popped thread Running on `cpu` (shared by all baselines).
pub(crate) fn dispatch(sys: &System, cpu: CpuId, task: TaskId, from: LevelId) {
    sys.tasks.with(task, |t| {
        if let Some(last) = t.last_cpu {
            if last != cpu {
                Metrics::inc(&sys.metrics.migrations);
            }
        }
        t.state = TaskState::Running { cpu };
        t.last_cpu = Some(cpu);
        t.last_list = Some(from);
    });
    Metrics::inc(&sys.metrics.picks);
    sys.trace.emit(sys.now(), Event::Dispatch { task, cpu });
}

/// Flatten-wake: threads go through `push`; bubbles recursively release
/// their contents (opportunist schedulers ignore structure).
pub(crate) fn flatten_wake(sys: &System, task: TaskId, push: &mut dyn FnMut(&System, TaskId)) {
    if sys.tasks.is_bubble(task) {
        let contents = sys.tasks.with(task, |t| t.kind_contents_snapshot());
        // The bubble itself is inert for baselines: park it off-list.
        sys.tasks.with(task, |t| t.state = TaskState::Blocked);
        for c in contents {
            flatten_wake(sys, c, push);
        }
    } else {
        push(sys, task);
    }
}

/// Default `stop` behaviour shared by the list baselines: requeue on
/// yield/preempt via `requeue`, Block/Terminate adjust state only.
pub(crate) fn default_stop(
    sys: &System,
    cpu: CpuId,
    task: TaskId,
    why: super::StopReason,
    requeue: &mut dyn FnMut(&System, TaskId),
) {
    use super::StopReason::*;
    use crate::trace::StopWhy;
    match why {
        Yield | Preempt => {
            sys.trace.emit(
                sys.now(),
                Event::Stop {
                    task,
                    cpu,
                    why: if why == Yield { StopWhy::Yield } else { StopWhy::Preempt },
                },
            );
            if why == Preempt {
                Metrics::inc(&sys.metrics.preemptions);
            }
            requeue(sys, task);
        }
        Block => {
            sys.trace.emit(sys.now(), Event::Stop { task, cpu, why: StopWhy::Block });
            sys.tasks.set_state(task, TaskState::Blocked);
        }
        Terminate => {
            sys.trace.emit(sys.now(), Event::Stop { task, cpu, why: StopWhy::Terminate });
            sys.tasks.set_state(task, TaskState::Terminated);
        }
    }
}

/// Most loaded leaf list among `cpus`, if any is non-empty.
pub(crate) fn most_loaded_leaf(sys: &System, cpus: impl Iterator<Item = CpuId>) -> Option<LevelId> {
    let mut best: Option<(LevelId, usize)> = None;
    for cpu in cpus {
        let l = sys.topo.leaf_of(cpu);
        let n = sys.rq.len_of(l);
        if n > best.map_or(0, |(_, b)| b) {
            best = Some((l, n));
        }
    }
    best.map(|(l, _)| l)
}

/// Least loaded leaf among `cpus` (for initial placement). Ties are
/// broken by a rotating offset: real wake-placement is effectively
/// arbitrary among equally loaded CPUs, and a fixed tie-break would
/// give the opportunist baselines accidental (unrealistic) locality —
/// all new threads piling onto cpu0's node.
pub(crate) fn least_loaded_leaf(sys: &System, cpus: impl Iterator<Item = CpuId>) -> LevelId {
    use std::sync::atomic::{AtomicUsize, Ordering};
    static ROT: AtomicUsize = AtomicUsize::new(0);
    let all: Vec<CpuId> = cpus.collect();
    let off = ROT.fetch_add(1, Ordering::Relaxed) % all.len().max(1);
    let mut best: Option<(LevelId, usize)> = None;
    for i in 0..all.len() {
        let cpu = all[(i + off) % all.len()];
        let l = sys.topo.leaf_of(cpu);
        let n = sys.rq.len_of(l);
        if best.map_or(true, |(_, b)| n < b) {
            best = Some((l, n));
        }
    }
    best.expect("no cpus").0
}

#[cfg(test)]
pub(crate) mod testsupport {
    //! Behavioural checks every baseline must pass.

    use super::*;
    use crate::sched::testutil::system;
    use crate::sched::{Scheduler, StopReason};
    use crate::task::PRIO_THREAD;
    use crate::topology::Topology;

    /// All threads woken are eventually picked and terminated when all
    /// CPUs poll round-robin.
    pub fn drains_all_work(s: &dyn Scheduler, topo: Topology, n_tasks: usize) {
        let sys = system(topo);
        let n_cpus = sys.topo.n_cpus();
        let mut remaining = std::collections::HashSet::new();
        for i in 0..n_tasks {
            let t = sys.tasks.new_thread(format!("w{i}"), PRIO_THREAD);
            s.wake(&sys, t);
            remaining.insert(t);
        }
        let mut fuel = 20 * n_tasks * n_cpus + 100;
        let mut cpu = 0;
        while !remaining.is_empty() && fuel > 0 {
            fuel -= 1;
            if let Some(t) = s.pick(&sys, CpuId(cpu)) {
                s.stop(&sys, CpuId(cpu), t, StopReason::Terminate);
                remaining.remove(&t);
            }
            cpu = (cpu + 1) % n_cpus;
        }
        assert!(remaining.is_empty(), "{} lost tasks under {}", remaining.len(), s.name());
    }

    /// Bubbles are flattened: structure must not prevent execution.
    pub fn flattens_bubbles(s: &dyn Scheduler, topo: Topology) {
        let sys = system(topo);
        let m = crate::marcel::Marcel::with_system(&sys);
        let outer = m.bubble_init();
        let inner = m.bubble_init();
        let t1 = m.create_dontsched("t1");
        let t2 = m.create_dontsched("t2");
        m.bubble_inserttask(inner, t1);
        m.bubble_insertbubble(outer, inner);
        m.bubble_inserttask(outer, t2);
        s.wake(&sys, outer);
        let mut got = std::collections::BTreeSet::new();
        let n_cpus = sys.topo.n_cpus();
        for round in 0..(10 * n_cpus) {
            let cpu = CpuId(round % n_cpus);
            if let Some(t) = s.pick(&sys, cpu) {
                got.insert(t);
                s.stop(&sys, cpu, t, StopReason::Terminate);
            }
        }
        assert_eq!(got, [t1, t2].into(), "{} failed to flatten", s.name());
    }

    /// Block → wake round-trips.
    pub fn block_wake_roundtrip(s: &dyn Scheduler, topo: Topology) {
        let sys = system(topo);
        let t = sys.tasks.new_thread("t", PRIO_THREAD);
        s.wake(&sys, t);
        // Some baselines (bound) may assign t to a specific CPU.
        let mut picked = None;
        for c in 0..sys.topo.n_cpus() {
            if let Some(x) = s.pick(&sys, CpuId(c)) {
                picked = Some((CpuId(c), x));
                break;
            }
        }
        let (cpu_got, x) = picked.expect("never picked");
        assert_eq!(x, t);
        s.stop(&sys, cpu_got, x, StopReason::Block);
        assert_eq!(sys.tasks.state(t), TaskState::Blocked);
        s.wake(&sys, t);
        let mut again = None;
        for c in 0..sys.topo.n_cpus() {
            if let Some(x) = s.pick(&sys, CpuId(c)) {
                again = Some(x);
                break;
            }
        }
        assert_eq!(again, Some(t), "{}", s.name());
    }
}
