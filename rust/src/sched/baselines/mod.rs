//! Baseline schedulers from the paper's related-work section (§2).
//!
//! * [`ss::SsScheduler`] — Self-Scheduling: one global list (Tang & Yew;
//!   the Linux 2.4 / Windows 2000 structure). The Table-2 "Simple" row.
//! * [`chunk::GssScheduler`] / [`chunk::TssScheduler`] — Guided /
//!   Trapezoid Self-Scheduling: idle processors grab decreasing chunks
//!   of the global list.
//! * [`afs::AfsScheduler`] / [`afs::LdsScheduler`] — per-processor lists
//!   with work stealing; LDS picks victims by locality.
//! * [`cafs::CafsScheduler`] / [`cafs::HafsScheduler`] — clustered AFS
//!   (√p groups aligned to NUMA nodes) and its hierarchical variant
//!   (idle groups steal from the most loaded group).
//! * [`bound::BoundScheduler`] — predetermined thread→CPU binding
//!   (§2.1). The Table-2 "Bound" row.
//! * [`gang::GangScheduler`] — Ousterhout gang scheduling (§3.1): one
//!   gang owns the whole machine at a time.
//!
//! All baselines flatten bubbles on wake: opportunist schedulers ignore
//! application structure (that is precisely the paper's criticism).
//!
//! Every baseline is thin policy glue over the shared primitives in
//! [`crate::sched::core`] — scan orders, the two-pass pick, and the
//! queueing/steal/stop building blocks. Instantiation goes through the
//! policy registry in [`crate::sched::factory`].

pub mod afs;
pub mod bound;
pub mod cafs;
pub mod chunk;
pub mod gang;
pub mod ss;

pub use afs::{AfsScheduler, LdsScheduler};
pub use bound::BoundScheduler;
pub use cafs::{CafsScheduler, HafsScheduler};
pub use chunk::{GssScheduler, TssScheduler};
pub use gang::GangScheduler;
pub use ss::SsScheduler;

// Kept here for compatibility: instantiation lives in the factory.
pub use crate::sched::factory::{make, make_default};

#[cfg(test)]
pub(crate) mod testsupport {
    //! Behavioural checks every baseline must pass.

    use crate::sched::testutil::system;
    use crate::sched::{Scheduler, StopReason};
    use crate::task::{TaskState, PRIO_THREAD};
    use crate::topology::{CpuId, Topology};

    /// All threads woken are eventually picked and terminated when all
    /// CPUs poll round-robin.
    pub fn drains_all_work(s: &dyn Scheduler, topo: Topology, n_tasks: usize) {
        let sys = system(topo);
        let n_cpus = sys.topo.n_cpus();
        let mut remaining = std::collections::HashSet::new();
        for i in 0..n_tasks {
            let t = sys.tasks.new_thread(format!("w{i}"), PRIO_THREAD);
            s.wake(&sys, t);
            remaining.insert(t);
        }
        let mut fuel = 20 * n_tasks * n_cpus + 100;
        let mut cpu = 0;
        while !remaining.is_empty() && fuel > 0 {
            fuel -= 1;
            if let Some(t) = s.pick(&sys, CpuId(cpu)) {
                s.stop(&sys, CpuId(cpu), t, StopReason::Terminate);
                remaining.remove(&t);
            }
            cpu = (cpu + 1) % n_cpus;
        }
        assert!(remaining.is_empty(), "{} lost tasks under {}", remaining.len(), s.name());
    }

    /// Bubbles are flattened: structure must not prevent execution.
    pub fn flattens_bubbles(s: &dyn Scheduler, topo: Topology) {
        let sys = system(topo);
        let m = crate::marcel::Marcel::with_system(&sys);
        let outer = m.bubble_init();
        let inner = m.bubble_init();
        let t1 = m.create_dontsched("t1");
        let t2 = m.create_dontsched("t2");
        m.bubble_inserttask(inner, t1);
        m.bubble_insertbubble(outer, inner);
        m.bubble_inserttask(outer, t2);
        s.wake(&sys, outer);
        let mut got = std::collections::BTreeSet::new();
        let n_cpus = sys.topo.n_cpus();
        for round in 0..(10 * n_cpus) {
            let cpu = CpuId(round % n_cpus);
            if let Some(t) = s.pick(&sys, cpu) {
                got.insert(t);
                s.stop(&sys, cpu, t, StopReason::Terminate);
            }
        }
        assert_eq!(got, [t1, t2].into(), "{} failed to flatten", s.name());
    }

    /// Block → wake round-trips.
    pub fn block_wake_roundtrip(s: &dyn Scheduler, topo: Topology) {
        let sys = system(topo);
        let t = sys.tasks.new_thread("t", PRIO_THREAD);
        s.wake(&sys, t);
        // Some baselines (bound) may assign t to a specific CPU.
        let mut picked = None;
        for c in 0..sys.topo.n_cpus() {
            if let Some(x) = s.pick(&sys, CpuId(c)) {
                picked = Some((CpuId(c), x));
                break;
            }
        }
        let (cpu_got, x) = picked.expect("never picked");
        assert_eq!(x, t);
        s.stop(&sys, cpu_got, x, StopReason::Block);
        assert_eq!(sys.tasks.state(t), TaskState::Blocked);
        s.wake(&sys, t);
        let mut again = None;
        for c in 0..sys.topo.n_cpus() {
            if let Some(x) = s.pick(&sys, CpuId(c)) {
                again = Some(x);
                break;
            }
        }
        assert_eq!(again, Some(t), "{}", s.name());
    }
}
