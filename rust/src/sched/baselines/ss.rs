//! Self-Scheduling (SS): one global ready list (paper §2.2).
//!
//! "They basically use a single list of ready tasks from which the
//! scheduler just picks up the next thread to be scheduled." This is the
//! Table-2 **Simple** row: the workload balances automatically but
//! threads land on whichever processor is free first, so NUMA affinity
//! is destroyed every reschedule — and the single list is a contention
//! bottleneck as the CPU count grows (measured by `benches/rq_scaling`).
//!
//! Policy glue only: the scan order is `[root]`, everything else is
//! [`crate::sched::core`].

use crate::sched::core::{ops, pick};
use crate::sched::{Scheduler, StopReason, System};
use crate::task::TaskId;
use crate::topology::CpuId;

/// The single-global-list scheduler.
#[derive(Debug, Default)]
pub struct SsScheduler;

impl SsScheduler {
    pub fn new() -> SsScheduler {
        SsScheduler
    }
}

impl Scheduler for SsScheduler {
    fn name(&self) -> String {
        "ss".into()
    }

    fn wake(&self, sys: &System, task: TaskId) {
        ops::flatten_wake(sys, task, &mut |sys, t| ops::enqueue(sys, t, sys.topo.root()));
    }

    fn pick(&self, sys: &System, cpu: CpuId) -> Option<TaskId> {
        pick::pick_thread(sys, cpu, &[sys.topo.root()])
    }

    fn stop(&self, sys: &System, cpu: CpuId, task: TaskId, why: StopReason) {
        ops::default_stop(sys, cpu, task, why, &mut |sys, t| {
            ops::enqueue(sys, t, sys.topo.root())
        });
    }
}

#[cfg(test)]
mod tests {
    use super::super::testsupport;
    use super::*;
    use crate::sched::testutil::system;
    use crate::task::PRIO_THREAD;
    use crate::topology::Topology;

    #[test]
    fn behavioural_suite() {
        testsupport::drains_all_work(&SsScheduler::new(), Topology::numa(2, 2), 20);
        testsupport::flattens_bubbles(&SsScheduler::new(), Topology::smp(2));
        testsupport::block_wake_roundtrip(&SsScheduler::new(), Topology::smp(2));
    }

    #[test]
    fn any_cpu_serves_the_global_list() {
        let sys = system(Topology::numa(2, 2));
        let s = SsScheduler::new();
        let t = sys.tasks.new_thread("t", PRIO_THREAD);
        s.wake(&sys, t);
        // The farthest CPU can take it straight away: no affinity.
        assert_eq!(s.pick(&sys, CpuId(3)), Some(t));
    }

    #[test]
    fn fifo_order() {
        let sys = system(Topology::smp(2));
        let s = SsScheduler::new();
        let a = sys.tasks.new_thread("a", PRIO_THREAD);
        let b = sys.tasks.new_thread("b", PRIO_THREAD);
        s.wake(&sys, a);
        s.wake(&sys, b);
        assert_eq!(s.pick(&sys, CpuId(0)), Some(a));
        assert_eq!(s.pick(&sys, CpuId(1)), Some(b));
    }
}
