//! Predetermined distribution (paper §2.1) — the Table-2 **Bound** row.
//!
//! "Provided that the machine is dedicated to the application, the
//! thread scheduling can be fully controlled by binding exactly one
//! thread to each processor." Threads are bound round-robin at first
//! wake (or via an explicit `bound_cpu`); a CPU only ever runs its own
//! threads — maximum affinity, zero flexibility, and non-portable in
//! the paper's sense (the application must know the machine).
//!
//! Policy glue only: the scan order is `[my leaf]`, no fallback.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::sched::core::{ops, pick};
use crate::sched::{Scheduler, StopReason, System};
use crate::task::TaskId;
use crate::topology::CpuId;

/// The binding scheduler.
#[derive(Debug, Default)]
pub struct BoundScheduler {
    next: AtomicUsize,
}

impl BoundScheduler {
    pub fn new() -> BoundScheduler {
        BoundScheduler { next: AtomicUsize::new(0) }
    }

    fn binding(&self, sys: &System, task: TaskId) -> CpuId {
        let explicit = sys.tasks.with(task, |t| t.thread_data().bound_cpu);
        if let Some(c) = explicit {
            return c;
        }
        let c = CpuId(self.next.fetch_add(1, Ordering::Relaxed) % sys.topo.n_cpus());
        sys.tasks.with(task, |t| t.thread_data_mut().bound_cpu = Some(c));
        c
    }
}

impl Scheduler for BoundScheduler {
    fn name(&self) -> String {
        "bound".into()
    }

    fn wake(&self, sys: &System, task: TaskId) {
        ops::flatten_wake(sys, task, &mut |sys, t| {
            let cpu = self.binding(sys, t);
            ops::enqueue(sys, t, sys.topo.leaf_of(cpu));
        });
    }

    fn pick(&self, sys: &System, cpu: CpuId) -> Option<TaskId> {
        pick::pick_thread(sys, cpu, &[sys.topo.leaf_of(cpu)])
    }

    fn stop(&self, sys: &System, cpu: CpuId, task: TaskId, why: StopReason) {
        ops::default_stop(sys, cpu, task, why, &mut |sys, t| {
            // Bound: always back to the binding, never elsewhere.
            let c = sys.tasks.with(t, |x| x.thread_data().bound_cpu).unwrap_or(cpu);
            ops::enqueue(sys, t, sys.topo.leaf_of(c));
        });
    }

    /// The whole point of this policy is the binding: without OS-level
    /// affinity it only binds threads to *virtual* CPUs, so the native
    /// executor must warn rather than silently degrade.
    fn needs_binding(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::super::testsupport;
    use super::*;
    use crate::sched::testutil::system;
    use crate::task::{PRIO_THREAD, TaskState};
    use crate::topology::Topology;

    #[test]
    fn behavioural_suite() {
        testsupport::drains_all_work(&BoundScheduler::new(), Topology::numa(2, 2), 40);
        testsupport::flattens_bubbles(&BoundScheduler::new(), Topology::smp(2));
        testsupport::block_wake_roundtrip(&BoundScheduler::new(), Topology::smp(2));
    }

    #[test]
    fn bound_declares_its_binding_requirement() {
        use crate::sched::baselines::SsScheduler;
        assert!(BoundScheduler::new().needs_binding());
        // Opportunist baselines don't care where workers really run.
        assert!(!SsScheduler::new().needs_binding());
    }

    #[test]
    fn round_robin_binding() {
        let sys = system(Topology::smp(4));
        let s = BoundScheduler::new();
        for i in 0..8 {
            let t = sys.tasks.new_thread(format!("t{i}"), PRIO_THREAD);
            s.wake(&sys, t);
        }
        for c in 0..4 {
            assert_eq!(sys.rq.len_of(sys.topo.leaf_of(CpuId(c))), 2);
        }
    }

    #[test]
    fn never_migrates() {
        let sys = system(Topology::smp(2));
        let s = BoundScheduler::new();
        let t = sys.tasks.new_thread("t", PRIO_THREAD);
        sys.tasks.with(t, |x| x.thread_data_mut().bound_cpu = Some(CpuId(1)));
        s.wake(&sys, t);
        // cpu0 never sees it.
        assert!(s.pick(&sys, CpuId(0)).is_none());
        assert_eq!(s.pick(&sys, CpuId(1)), Some(t));
        s.stop(&sys, CpuId(1), t, StopReason::Yield);
        assert!(s.pick(&sys, CpuId(0)).is_none());
        assert_eq!(s.pick(&sys, CpuId(1)), Some(t));
        assert_eq!(sys.metrics.migrations.load(std::sync::atomic::Ordering::Relaxed), 0);
        assert_eq!(sys.tasks.state(t), TaskState::Running { cpu: CpuId(1) });
    }
}
