//! Guided and Trapezoid Self-Scheduling (paper §2.2).
//!
//! "To avoid such contention, GSS and TSS make each processor take a
//! whole part of the total work when they are idle, raising the risk of
//! imbalances." Idle processors transfer a *chunk* of the global list
//! to their private leaf list:
//!
//! * GSS (Polychronopoulos & Kuck): chunk = ⌈remaining / p⌉.
//! * TSS (Tzen & Ni): chunk decreases linearly from ⌈N/2p⌉ to 1.
//!
//! Policy glue only: the chunk-size law is the policy; queueing,
//! dispatch and the leaf pick path are [`crate::sched::core`].

use std::sync::atomic::{AtomicU64, Ordering};

use crate::metrics::Metrics;
use crate::sched::core::{ops, pick};
use crate::sched::{Scheduler, StopReason, System};
use crate::task::TaskId;
use crate::topology::CpuId;

/// Chunk policy discriminator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Policy {
    Gss,
    Tss,
}

/// Chunking self-scheduler (GSS/TSS).
#[derive(Debug)]
pub struct ChunkScheduler {
    policy: Policy,
    /// TSS state: the size of the next chunk (monotonically decreasing).
    next_chunk: AtomicU64,
    /// TSS decrement per allocation.
    delta: AtomicU64,
}

/// Guided Self-Scheduling.
#[derive(Debug)]
pub struct GssScheduler(ChunkScheduler);

/// Trapezoid Self-Scheduling.
#[derive(Debug)]
pub struct TssScheduler(ChunkScheduler);

impl GssScheduler {
    pub fn new() -> GssScheduler {
        GssScheduler(ChunkScheduler {
            policy: Policy::Gss,
            next_chunk: AtomicU64::new(0),
            delta: AtomicU64::new(0),
        })
    }
}

impl Default for GssScheduler {
    fn default() -> Self {
        GssScheduler::new()
    }
}

impl TssScheduler {
    pub fn new() -> TssScheduler {
        TssScheduler(ChunkScheduler {
            policy: Policy::Tss,
            next_chunk: AtomicU64::new(0),
            delta: AtomicU64::new(1),
        })
    }
}

impl Default for TssScheduler {
    fn default() -> Self {
        TssScheduler::new()
    }
}

impl ChunkScheduler {
    fn chunk_size(&self, sys: &System) -> usize {
        let remaining = sys.rq.len_of(sys.topo.root()) as u64;
        if remaining == 0 {
            return 0;
        }
        let p = sys.topo.n_cpus() as u64;
        match self.policy {
            Policy::Gss => remaining.div_ceil(p).max(1) as usize,
            Policy::Tss => {
                // First allocation fixes the trapezoid: start at
                // ceil(N/2p), decrease by delta so it reaches 1.
                let mut cur = self.next_chunk.load(Ordering::Relaxed);
                if cur == 0 {
                    let first = remaining.div_ceil(2 * p).max(1);
                    // ~N/(first+1) allocations; keep delta >= 1 step
                    // towards 1 every allocation when possible.
                    self.next_chunk.store(first, Ordering::Relaxed);
                    self.delta.store(1, Ordering::Relaxed);
                    cur = first;
                }
                let d = self.delta.load(Ordering::Relaxed);
                let next = cur.saturating_sub(d).max(1);
                self.next_chunk.store(next, Ordering::Relaxed);
                cur.min(remaining).max(1) as usize
            }
        }
    }

    /// Move a chunk from the global list to `cpu`'s leaf.
    fn grab_chunk(&self, sys: &System, cpu: CpuId) -> bool {
        let n = self.chunk_size(sys);
        if n == 0 {
            return false;
        }
        let root = sys.topo.root();
        let leaf = sys.topo.leaf_of(cpu);
        let mut moved = 0;
        for _ in 0..n {
            match sys.rq.pop_max(root) {
                Some((t, _)) => {
                    ops::enqueue(sys, t, leaf);
                    moved += 1;
                }
                None => break,
            }
        }
        if moved > 0 {
            Metrics::add(&sys.metrics.steals, moved);
        }
        moved > 0
    }

    fn pick_impl(&self, sys: &System, cpu: CpuId) -> Option<TaskId> {
        let leaf = sys.topo.leaf_of(cpu);
        loop {
            if let Some(t) = pick::pick_thread(sys, cpu, &[leaf]) {
                return Some(t);
            }
            if !self.grab_chunk(sys, cpu) {
                return None;
            }
        }
    }
}

macro_rules! impl_chunk_sched {
    ($ty:ty, $name:expr) => {
        impl Scheduler for $ty {
            fn name(&self) -> String {
                $name.into()
            }

            fn wake(&self, sys: &System, task: TaskId) {
                // New work lands on the global list; chunks migrate it.
                ops::flatten_wake(sys, task, &mut |sys, t| {
                    ops::enqueue(sys, t, sys.topo.root())
                });
            }

            fn pick(&self, sys: &System, cpu: CpuId) -> Option<TaskId> {
                self.0.pick_impl(sys, cpu)
            }

            fn stop(&self, sys: &System, cpu: CpuId, task: TaskId, why: StopReason) {
                // Requeue on the leaf it ran on (chunked work stays put).
                ops::default_stop(sys, cpu, task, why, &mut |sys, t| {
                    ops::enqueue(sys, t, sys.topo.leaf_of(cpu))
                });
            }
        }
    };
}

impl_chunk_sched!(GssScheduler, "gss");
impl_chunk_sched!(TssScheduler, "tss");

#[cfg(test)]
mod tests {
    use super::super::testsupport;
    use super::*;
    use crate::sched::testutil::system;
    use crate::task::PRIO_THREAD;
    use crate::topology::Topology;

    #[test]
    fn behavioural_suite_gss() {
        testsupport::drains_all_work(&GssScheduler::new(), Topology::numa(2, 2), 40);
        testsupport::flattens_bubbles(&GssScheduler::new(), Topology::smp(2));
        testsupport::block_wake_roundtrip(&GssScheduler::new(), Topology::smp(2));
    }

    #[test]
    fn behavioural_suite_tss() {
        testsupport::drains_all_work(&TssScheduler::new(), Topology::numa(2, 2), 40);
        testsupport::flattens_bubbles(&TssScheduler::new(), Topology::smp(2));
        testsupport::block_wake_roundtrip(&TssScheduler::new(), Topology::smp(2));
    }

    #[test]
    fn gss_takes_remaining_over_p() {
        let sys = system(Topology::smp(4));
        let s = GssScheduler::new();
        for i in 0..16 {
            let t = sys.tasks.new_thread(format!("t{i}"), PRIO_THREAD);
            s.wake(&sys, t);
        }
        // First pick by cpu0 grabs ceil(16/4) = 4 tasks onto its leaf.
        let t = s.pick(&sys, CpuId(0)).unwrap();
        let leaf = sys.topo.leaf_of(CpuId(0));
        assert_eq!(sys.rq.len_of(leaf), 3, "chunk of 4 minus the dispatched one");
        let _ = t;
        assert_eq!(sys.rq.len_of(sys.topo.root()), 12);
    }

    #[test]
    fn tss_chunks_decrease() {
        let sys = system(Topology::smp(2));
        let s = TssScheduler::new();
        for i in 0..20 {
            let t = sys.tasks.new_thread(format!("t{i}"), PRIO_THREAD);
            s.wake(&sys, t);
        }
        // First chunk = ceil(20/4) = 5; count what lands on the leaf.
        s.pick(&sys, CpuId(0)).unwrap();
        let first = sys.rq.len_of(sys.topo.leaf_of(CpuId(0))) + 1;
        assert_eq!(first, 5);
        // Grab again from the other cpu: must be <= first.
        s.pick(&sys, CpuId(1)).unwrap();
        let second = sys.rq.len_of(sys.topo.leaf_of(CpuId(1))) + 1;
        assert!(second <= first, "{second} > {first}");
    }
}
