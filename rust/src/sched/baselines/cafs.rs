//! Clustered and Hierarchical Affinity Scheduling (Wang et al.) —
//! paper §2.2.
//!
//! CAFS "groups p processors in groups of √p. Whenever idle, rather
//! than looking around the whole machine, processors steal work from
//! the least loaded processor of their group ... by aligning groups to
//! NUMA nodes, data distribution is also localized."
//!
//! HAFS "lets any idle group steal work from the most loaded group" —
//! the structure Linux 2.6 / FreeBSD NUMA development was converging
//! towards when the paper was written.
//!
//! Groups align to NUMA nodes when the machine has them; otherwise the
//! CPUs are partitioned into ⌈√p⌉-sized clusters.
//!
//! Policy glue only: group partitioning is the policy; picking and
//! stealing are [`crate::sched::core`] primitives.

use crate::sched::core::{ops, pick};
use crate::sched::{Scheduler, StopReason, System};
use crate::task::TaskId;
use crate::topology::{CpuId, Topology};

/// Partition the machine into steal groups.
fn groups_of(topo: &Topology) -> Vec<Vec<CpuId>> {
    if topo.n_numa() > 1 {
        let mut groups = vec![Vec::new(); topo.n_numa()];
        for c in 0..topo.n_cpus() {
            groups[topo.numa_of(CpuId(c))].push(CpuId(c));
        }
        groups
    } else {
        let p = topo.n_cpus();
        let size = (p as f64).sqrt().ceil() as usize;
        (0..p)
            .map(CpuId)
            .collect::<Vec<_>>()
            .chunks(size.max(1))
            .map(|c| c.to_vec())
            .collect()
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Scope {
    /// CAFS: steal only within the group.
    GroupOnly,
    /// HAFS: whole idle group may raid the most loaded group.
    Hierarchical,
}

#[derive(Debug)]
struct Clustered {
    scope: Scope,
}

/// Clustered AFS.
#[derive(Debug)]
pub struct CafsScheduler(Clustered);

/// Hierarchical AFS.
#[derive(Debug)]
pub struct HafsScheduler(Clustered);

impl CafsScheduler {
    pub fn new() -> CafsScheduler {
        CafsScheduler(Clustered { scope: Scope::GroupOnly })
    }
}

impl Default for CafsScheduler {
    fn default() -> Self {
        CafsScheduler::new()
    }
}

impl HafsScheduler {
    pub fn new() -> HafsScheduler {
        HafsScheduler(Clustered { scope: Scope::Hierarchical })
    }
}

impl Default for HafsScheduler {
    fn default() -> Self {
        HafsScheduler::new()
    }
}

impl Clustered {
    fn my_group(&self, topo: &Topology, cpu: CpuId) -> Vec<CpuId> {
        groups_of(topo)
            .into_iter()
            .find(|g| g.contains(&cpu))
            .expect("cpu in no group")
    }

    fn wake_impl(&self, sys: &System, task: TaskId) {
        ops::flatten_wake(sys, task, &mut |sys, t| {
            let list = sys
                .tasks
                .with(t, |x| x.last_cpu)
                .map(|c| sys.topo.leaf_of(c))
                .unwrap_or_else(|| {
                    ops::least_loaded_leaf(sys, (0..sys.topo.n_cpus()).map(CpuId))
                });
            ops::enqueue(sys, t, list);
        });
    }

    fn pick_impl(&self, sys: &System, cpu: CpuId) -> Option<TaskId> {
        let leaf = sys.topo.leaf_of(cpu);
        if let Some(t) = pick::pick_thread(sys, cpu, &[leaf]) {
            return Some(t);
        }
        // Steal within the group first.
        let group = self.my_group(&sys.topo, cpu);
        if let Some(v) = ops::most_loaded_leaf(sys, group.iter().copied().filter(|&c| c != cpu))
        {
            if let Some((t, _)) = ops::pop_steal(sys, cpu, v) {
                ops::dispatch(sys, cpu, t, leaf);
                return Some(t);
            }
        }
        if self.scope == Scope::Hierarchical {
            // The whole group ran dry: raid the most loaded group.
            let groups = groups_of(&sys.topo);
            let loaded = groups
                .iter()
                .filter(|g| !g.contains(&cpu))
                .max_by_key(|g| {
                    g.iter().map(|&c| sys.rq.len_of(sys.topo.leaf_of(c))).sum::<usize>()
                })?;
            let v = ops::most_loaded_leaf(sys, loaded.iter().copied())?;
            if let Some((t, _)) = ops::pop_steal(sys, cpu, v) {
                ops::dispatch(sys, cpu, t, leaf);
                return Some(t);
            }
        }
        None
    }
}

macro_rules! impl_clustered_sched {
    ($ty:ty, $name:expr) => {
        impl Scheduler for $ty {
            fn name(&self) -> String {
                $name.into()
            }

            fn wake(&self, sys: &System, task: TaskId) {
                self.0.wake_impl(sys, task);
            }

            fn pick(&self, sys: &System, cpu: CpuId) -> Option<TaskId> {
                self.0.pick_impl(sys, cpu)
            }

            fn stop(&self, sys: &System, cpu: CpuId, task: TaskId, why: StopReason) {
                ops::default_stop(sys, cpu, task, why, &mut |sys, t| {
                    ops::enqueue(sys, t, sys.topo.leaf_of(cpu))
                });
            }
        }
    };
}

impl_clustered_sched!(CafsScheduler, "cafs");
impl_clustered_sched!(HafsScheduler, "hafs");

#[cfg(test)]
mod tests {
    use super::super::testsupport;
    use super::*;
    use crate::sched::testutil::system;
    use crate::task::PRIO_THREAD;
    use crate::topology::Topology;

    #[test]
    fn behavioural_suite_hafs() {
        testsupport::drains_all_work(&HafsScheduler::new(), Topology::numa(2, 2), 40);
        testsupport::flattens_bubbles(&HafsScheduler::new(), Topology::smp(4));
        testsupport::block_wake_roundtrip(&HafsScheduler::new(), Topology::smp(4));
    }

    #[test]
    fn groups_align_to_numa() {
        let g = groups_of(&Topology::numa(4, 4));
        assert_eq!(g.len(), 4);
        assert!(g.iter().all(|grp| grp.len() == 4));
        // Group 2 holds cpus 8..12.
        assert_eq!(g[2], (8..12).map(CpuId).collect::<Vec<_>>());
    }

    #[test]
    fn groups_sqrt_p_without_numa() {
        let g = groups_of(&Topology::smp(16));
        assert_eq!(g.len(), 4);
        assert!(g.iter().all(|grp| grp.len() == 4));
    }

    #[test]
    fn cafs_steals_within_group_only() {
        let sys = system(Topology::numa(2, 2));
        let s = CafsScheduler::new();
        // Work only on node 1 (cpus 2,3).
        for i in 0..4 {
            let t = sys.tasks.new_thread(format!("t{i}"), PRIO_THREAD);
            sys.tasks.with(t, |x| x.last_cpu = Some(CpuId(2 + i % 2)));
            s.wake(&sys, t);
        }
        // cpu0 (node 0) must NOT steal across groups under CAFS.
        assert!(s.pick(&sys, CpuId(0)).is_none());
        // cpu3 (node 1) happily takes from its sibling.
        assert!(s.pick(&sys, CpuId(3)).is_some());
    }

    #[test]
    fn hafs_raids_other_groups_when_dry() {
        let sys = system(Topology::numa(2, 2));
        let s = HafsScheduler::new();
        for i in 0..4 {
            let t = sys.tasks.new_thread(format!("t{i}"), PRIO_THREAD);
            sys.tasks.with(t, |x| x.last_cpu = Some(CpuId(2 + i % 2)));
            s.wake(&sys, t);
        }
        // cpu0's group is dry → hierarchical steal kicks in.
        assert!(s.pick(&sys, CpuId(0)).is_some());
        assert!(sys.metrics.steals.load(std::sync::atomic::Ordering::Relaxed) >= 1);
    }
}
