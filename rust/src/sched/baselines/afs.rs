//! Affinity Scheduling (AFS, Markatos & LeBlanc) and Locality-based
//! Dynamic Scheduling (LDS, Li et al.) — paper §2.2.
//!
//! Per-processor ready lists preserve cache affinity; idle processors
//! steal. AFS picks the most loaded victim machine-wide (the
//! "rebalance" structure of Linux 2.6 / FreeBSD 5 / IRIX the paper
//! cites); LDS refines victim selection by *locality*: the closest
//! loaded processor in the hierarchy wins, so stolen work stays as
//! local as possible.
//!
//! Policy glue only: pick = two-pass over `[my leaf]`, fallback = one of
//! the core steal primitives ([`ops::steal_most_loaded`] for AFS,
//! [`ops::steal_closest`] for LDS).

use crate::sched::core::{ops, pick};
use crate::sched::{Scheduler, StopReason, System};
use crate::task::TaskId;
use crate::topology::CpuId;

/// Victim selection policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Victim {
    /// Most loaded CPU anywhere.
    MostLoaded,
    /// Closest loaded CPU (ties by load).
    Closest,
}

#[derive(Debug)]
struct PerCpuSched {
    victim: Victim,
}

/// Affinity Scheduling.
#[derive(Debug)]
pub struct AfsScheduler(PerCpuSched);

/// Locality-based Dynamic Scheduling.
#[derive(Debug)]
pub struct LdsScheduler(PerCpuSched);

impl AfsScheduler {
    pub fn new() -> AfsScheduler {
        AfsScheduler(PerCpuSched { victim: Victim::MostLoaded })
    }
}

impl Default for AfsScheduler {
    fn default() -> Self {
        AfsScheduler::new()
    }
}

impl LdsScheduler {
    pub fn new() -> LdsScheduler {
        LdsScheduler(PerCpuSched { victim: Victim::Closest })
    }
}

impl Default for LdsScheduler {
    fn default() -> Self {
        LdsScheduler::new()
    }
}

impl PerCpuSched {
    fn wake_impl(&self, sys: &System, task: TaskId) {
        ops::flatten_wake(sys, task, &mut |sys, t| {
            // Affinity: a thread that ran before returns to its last
            // CPU; new threads go to the least loaded list ("new
            // processes are charged to the least loaded processor").
            let list = sys
                .tasks
                .with(t, |x| x.last_cpu)
                .map(|c| sys.topo.leaf_of(c))
                .unwrap_or_else(|| {
                    ops::least_loaded_leaf(sys, (0..sys.topo.n_cpus()).map(CpuId))
                });
            ops::enqueue(sys, t, list);
        });
    }

    fn pick_impl(&self, sys: &System, cpu: CpuId) -> Option<TaskId> {
        let leaf = sys.topo.leaf_of(cpu);
        if let Some(t) = pick::pick_thread(sys, cpu, &[leaf]) {
            return Some(t);
        }
        let (t, _from) = match self.victim {
            Victim::MostLoaded => ops::steal_most_loaded(sys, cpu)?,
            Victim::Closest => ops::steal_closest(sys, cpu)?,
        };
        ops::dispatch(sys, cpu, t, leaf);
        Some(t)
    }
}

macro_rules! impl_percpu_sched {
    ($ty:ty, $name:expr) => {
        impl Scheduler for $ty {
            fn name(&self) -> String {
                $name.into()
            }

            fn wake(&self, sys: &System, task: TaskId) {
                self.0.wake_impl(sys, task);
            }

            fn pick(&self, sys: &System, cpu: CpuId) -> Option<TaskId> {
                self.0.pick_impl(sys, cpu)
            }

            fn stop(&self, sys: &System, cpu: CpuId, task: TaskId, why: StopReason) {
                ops::default_stop(sys, cpu, task, why, &mut |sys, t| {
                    ops::enqueue(sys, t, sys.topo.leaf_of(cpu))
                });
            }
        }
    };
}

impl_percpu_sched!(AfsScheduler, "afs");
impl_percpu_sched!(LdsScheduler, "lds");

#[cfg(test)]
mod tests {
    use super::super::testsupport;
    use super::*;
    use crate::sched::testutil::system;
    use crate::task::PRIO_THREAD;
    use crate::topology::Topology;
    use crate::trace::Event;

    #[test]
    fn behavioural_suite_afs() {
        testsupport::drains_all_work(&AfsScheduler::new(), Topology::numa(2, 2), 40);
        testsupport::flattens_bubbles(&AfsScheduler::new(), Topology::smp(2));
        testsupport::block_wake_roundtrip(&AfsScheduler::new(), Topology::smp(2));
    }

    #[test]
    fn behavioural_suite_lds() {
        testsupport::drains_all_work(&LdsScheduler::new(), Topology::numa(2, 2), 40);
        testsupport::flattens_bubbles(&LdsScheduler::new(), Topology::smp(2));
        testsupport::block_wake_roundtrip(&LdsScheduler::new(), Topology::smp(2));
    }

    #[test]
    fn afs_respects_affinity_on_requeue() {
        let sys = system(Topology::smp(2));
        let s = AfsScheduler::new();
        let t = sys.tasks.new_thread("t", PRIO_THREAD);
        s.wake(&sys, t);
        let cpu = if s.pick(&sys, CpuId(0)).is_some() { CpuId(0) } else { CpuId(1) };
        s.stop(&sys, cpu, t, StopReason::Yield);
        // The thread must be back on the same CPU's list.
        let list = sys.tasks.state(t).ready_list().unwrap();
        assert_eq!(list, sys.topo.leaf_of(cpu));
    }

    #[test]
    fn new_work_spreads_to_least_loaded() {
        let sys = system(Topology::smp(4));
        let s = AfsScheduler::new();
        for i in 0..8 {
            let t = sys.tasks.new_thread(format!("t{i}"), PRIO_THREAD);
            s.wake(&sys, t);
        }
        // 8 tasks over 4 leaf lists → perfectly balanced 2/2/2/2.
        for c in 0..4 {
            assert_eq!(sys.rq.len_of(sys.topo.leaf_of(CpuId(c))), 2);
        }
    }

    #[test]
    fn lds_steals_from_closest_victim() {
        let sys = system(Topology::numa(2, 2));
        let s = LdsScheduler::new();
        // Load cpu1 (same node as cpu0) and cpu2 (other node) equally.
        for (i, c) in [(0, 1), (1, 1), (2, 2), (3, 2)] {
            let t = sys.tasks.new_thread(format!("t{i}"), PRIO_THREAD);
            sys.tasks.with(t, |x| x.last_cpu = Some(CpuId(c)));
            s.wake(&sys, t);
        }
        sys.trace.set_enabled(true);
        // cpu0 is idle: it must steal from cpu1 (separation 1), not
        // cpu2 (separation 2).
        let got = s.pick(&sys, CpuId(0)).unwrap();
        let from = sys
            .trace
            .records()
            .iter()
            .find_map(|r| match r.event {
                Event::Steal { from, .. } => Some(from),
                _ => None,
            })
            .unwrap();
        assert_eq!(from, sys.topo.leaf_of(CpuId(1)));
        let _ = got;
    }
}
