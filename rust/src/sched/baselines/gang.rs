//! Ousterhout gang scheduling (paper §3.1).
//!
//! "Gangs hold a fixed number of threads which are to be launched at
//! the same time on the same machine ... processors may be left idle
//! because a single machine can only run one gang at a time, even if it
//! is small." Exactly that pathology is reproduced here (and measured
//! against the bubble scheduler's generalisation in
//! `benches/ablation_priority.rs`): one gang owns the machine per time
//! slice; idle CPUs stay idle rather than mixing gangs.
//!
//! Bubbles woken under this scheduler become gangs; loose threads form
//! an implicit singleton gang each.
//!
//! Policy glue over [`crate::sched::core`]: the gang rotation is the
//! policy; queueing, the root pick path and stop accounting are shared.

use std::collections::VecDeque;
use std::sync::Mutex;

use crate::metrics::Metrics;
use crate::sched::core::{ops, pick};
use crate::sched::{Scheduler, StopReason, System};
use crate::task::{TaskId, TaskState};
use crate::topology::CpuId;
use crate::trace::{Event, RegenWhy, StopWhy};

#[derive(Debug, Default)]
struct GangState {
    /// Waiting gangs (bubble task ids or singleton thread ids).
    queue: VecDeque<TaskId>,
    /// The gang currently owning the machine.
    active: Option<TaskId>,
    /// Engine time consumed by the active gang.
    used: u64,
}

/// Machine-wide gang scheduler.
#[derive(Debug)]
pub struct GangScheduler {
    slice: u64,
    st: Mutex<GangState>,
}

/// Release the gang's threads onto the root list. Nested bubbles (a
/// topology-mirroring hierarchy woken as one gang) are flattened: the
/// sub-bubbles stay parked, their threads join the gang — "gangs hold
/// a fixed number of threads".
fn release_gang(sys: &System, gang: TaskId) {
    if sys.tasks.is_bubble(gang) {
        let contents = sys.tasks.with(gang, |t| t.kind_contents_snapshot());
        for c in contents {
            if sys.tasks.is_bubble(c) {
                sys.tasks.with(c, |t| t.state = TaskState::Blocked);
                release_gang(sys, c);
                continue;
            }
            let state = sys.tasks.state(c);
            if state == TaskState::InBubble || state.is_ready() {
                if let Some(l) = state.ready_list() {
                    sys.rq.remove(l, c, sys.tasks.prio(c));
                }
                ops::enqueue(sys, c, sys.topo.root());
            }
        }
    } else {
        ops::enqueue(sys, gang, sys.topo.root());
    }
}

/// Pull the gang's ready threads off the lists (rotation), nested
/// bubbles flattened.
fn pull_ready(sys: &System, gang: TaskId) {
    if !sys.tasks.is_bubble(gang) {
        return;
    }
    let contents = sys.tasks.with(gang, |t| t.kind_contents_snapshot());
    for c in contents {
        if sys.tasks.is_bubble(c) {
            pull_ready(sys, c);
        } else if let Some(l) = sys.tasks.state(c).ready_list() {
            if sys.rq.remove(l, c, sys.tasks.prio(c)) {
                sys.tasks.set_state(c, TaskState::InBubble);
            }
        }
    }
}

impl GangScheduler {
    /// `slice` = engine time a gang owns the machine before rotating.
    pub fn new(slice: u64) -> GangScheduler {
        GangScheduler { slice, st: Mutex::new(GangState::default()) }
    }

    /// Pull the active gang off the lists (rotation).
    fn deactivate(&self, sys: &System, gang: TaskId) {
        pull_ready(sys, gang);
        sys.trace.emit(sys.now(), Event::Regen { bubble: gang, why: RegenWhy::Timeslice });
    }

    /// Ensure some gang is active; rotate if the current one is done.
    fn ensure_active(&self, sys: &System, st: &mut GangState) {
        loop {
            match st.active {
                Some(g) if ops::gang_live(sys, g) => return,
                Some(g) => {
                    // Gang finished: drop it.
                    let _ = g;
                    st.active = None;
                    st.used = 0;
                }
                None => match st.queue.pop_front() {
                    Some(g) => {
                        if !ops::gang_live(sys, g) {
                            continue;
                        }
                        st.active = Some(g);
                        st.used = 0;
                        release_gang(sys, g);
                        return;
                    }
                    None => return,
                },
            }
        }
    }
}

impl Scheduler for GangScheduler {
    fn name(&self) -> String {
        "gang".into()
    }

    fn wake(&self, sys: &System, task: TaskId) {
        let mut st = self.st.lock().unwrap();
        let state = sys.tasks.state(task);
        let is_member = sys.tasks.parent(task).is_some();
        if is_member && state == TaskState::Blocked {
            // An unblocked member of some gang: if its gang is active,
            // rejoin the root list, else wait inside the gang. The
            // gang is the *outermost* bubble (nested hierarchies are
            // flattened into one gang). A woken *sub-bubble* releases
            // its threads instead of being enqueued itself.
            let gang = ops::root_bubble(sys, task);
            if sys.tasks.is_bubble(task) {
                if st.active == Some(gang) {
                    release_gang(sys, task);
                }
                return;
            }
            if st.active == Some(gang) {
                ops::enqueue(sys, task, sys.topo.root());
            } else {
                sys.tasks.set_state(task, TaskState::InBubble);
            }
            return;
        }
        if sys.tasks.is_bubble(task) {
            // Park the bubble itself; its members run via activation.
            sys.tasks.with(task, |t| t.state = TaskState::Blocked);
        }
        st.queue.push_back(task);
        // The gang queue is internal (no rq push), so parked native
        // workers would otherwise only notice on their safety-net
        // timeout: signal them explicitly.
        sys.notify_enqueue();
    }

    fn pick(&self, sys: &System, cpu: CpuId) -> Option<TaskId> {
        let mut st = self.st.lock().unwrap();
        self.ensure_active(sys, &mut st);
        st.active?;
        pick::pick_thread(sys, cpu, &[sys.topo.root()])
    }

    fn stop(&self, sys: &System, cpu: CpuId, task: TaskId, why: StopReason) {
        ops::note_stop(sys, cpu);
        match why {
            StopReason::Yield | StopReason::Preempt => {
                let stop_why = if why == StopReason::Preempt {
                    // The engine honoured a rotation tick: count it so
                    // `preemptions` is observable under gang scheduling
                    // on both engines, like every other timeslice user.
                    Metrics::inc(&sys.metrics.preemptions);
                    StopWhy::Preempt
                } else {
                    StopWhy::Yield
                };
                sys.trace.emit(sys.now(), Event::Stop { task, cpu, why: stop_why });
                // One guard for the whole transition: dropping and
                // re-locking between the Blocked transition and the
                // requeue would let a concurrent pick activate the
                // task and this path queue it a second time.
                let mut st = self.st.lock().unwrap();
                let gang_of = ops::root_bubble(sys, task);
                if st.active == Some(gang_of) {
                    ops::enqueue(sys, task, sys.topo.root());
                } else {
                    // Rotated away while running: back into the gang.
                    sys.tasks.set_state(
                        task,
                        if sys.tasks.parent(task).is_some() {
                            TaskState::InBubble
                        } else {
                            TaskState::Blocked
                        },
                    );
                    if sys.tasks.parent(task).is_none() && !st.queue.contains(&task) {
                        // Loose thread: it IS its own gang; requeue it
                        // — unless the rotation tick already did (a
                        // preempted singleton is pushed by tick before
                        // its stop arrives).
                        st.queue.push_back(task);
                    }
                }
            }
            StopReason::Block => {
                sys.trace.emit(sys.now(), Event::Stop { task, cpu, why: StopWhy::Block });
                sys.tasks.set_state(task, TaskState::Blocked);
            }
            StopReason::Terminate => {
                sys.trace.emit(sys.now(), Event::Stop { task, cpu, why: StopWhy::Terminate });
                sys.tasks.set_state(task, TaskState::Terminated);
            }
        }
    }

    fn tick(&self, sys: &System, _cpu: CpuId, _task: TaskId, elapsed: u64) -> bool {
        let mut st = self.st.lock().unwrap();
        st.used += elapsed;
        if st.used >= self.slice && st.queue.iter().any(|&g| ops::gang_live(sys, g)) {
            // Rotate: collect the active gang and requeue it.
            if let Some(g) = st.active.take() {
                self.deactivate(sys, g);
                st.queue.push_back(g);
                Metrics::inc(&sys.metrics.regenerations);
                st.used = 0;
                return true;
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::marcel::Marcel;
    use crate::sched::testutil::system;
    use crate::task::PRIO_THREAD;
    use crate::topology::Topology;

    fn gang_of(
        sys: &std::sync::Arc<crate::sched::System>,
        m: &Marcel,
        n: usize,
        tag: &str,
    ) -> (TaskId, Vec<TaskId>) {
        let b = m.bubble_init();
        let ts: Vec<TaskId> =
            (0..n).map(|i| m.create_dontsched(format!("{tag}{i}"))).collect();
        for &t in &ts {
            m.bubble_inserttask(b, t);
        }
        let _ = sys;
        (b, ts)
    }

    #[test]
    fn one_gang_at_a_time() {
        let sys = system(Topology::smp(4));
        let s = GangScheduler::new(1_000);
        let m = Marcel::with_system(&sys);
        let (g1, t1) = gang_of(&sys, &m, 2, "a");
        let (g2, t2) = gang_of(&sys, &m, 2, "b");
        s.wake(&sys, g1);
        s.wake(&sys, g2);
        // 4 CPUs but gang 1 has only 2 threads: 2 CPUs stay idle
        // (Ousterhout's fragmentation).
        let picked: Vec<Option<TaskId>> = (0..4).map(|c| s.pick(&sys, CpuId(c))).collect();
        let got: Vec<TaskId> = picked.iter().flatten().copied().collect();
        assert_eq!(got.len(), 2, "only the active gang runs: {picked:?}");
        assert!(got.iter().all(|t| t1.contains(t)));
        let _ = (g2, t2);
    }

    #[test]
    fn rotation_on_slice_expiry() {
        let sys = system(Topology::smp(2));
        let s = GangScheduler::new(100);
        let m = Marcel::with_system(&sys);
        let (g1, t1) = gang_of(&sys, &m, 2, "a");
        let (g2, t2) = gang_of(&sys, &m, 2, "b");
        s.wake(&sys, g1);
        s.wake(&sys, g2);
        let x = s.pick(&sys, CpuId(0)).unwrap();
        let y = s.pick(&sys, CpuId(1)).unwrap();
        assert!(t1.contains(&x) && t1.contains(&y));
        assert!(s.tick(&sys, CpuId(0), x, 150), "slice must expire");
        s.stop(&sys, CpuId(0), x, StopReason::Preempt);
        s.stop(&sys, CpuId(1), y, StopReason::Preempt);
        let x2 = s.pick(&sys, CpuId(0)).unwrap();
        assert!(t2.contains(&x2), "second gang's turn");
    }

    #[test]
    fn finished_gang_gives_way() {
        let sys = system(Topology::smp(2));
        let s = GangScheduler::new(1_000_000);
        let m = Marcel::with_system(&sys);
        let (g1, t1) = gang_of(&sys, &m, 1, "a");
        let (g2, t2) = gang_of(&sys, &m, 1, "b");
        s.wake(&sys, g1);
        s.wake(&sys, g2);
        let x = s.pick(&sys, CpuId(0)).unwrap();
        assert_eq!(x, t1[0]);
        s.stop(&sys, CpuId(0), x, StopReason::Terminate);
        let y = s.pick(&sys, CpuId(0)).unwrap();
        assert_eq!(y, t2[0]);
        let _ = (g1, g2);
    }

    #[test]
    fn nested_bubbles_flatten_into_one_gang() {
        // A topology-mirroring hierarchy (root bubble holding per-node
        // bubbles) woken under gang scheduling is one gang: every
        // thread runs together, the parked sub-bubbles never reach a
        // runqueue, and the gang dies when its threads do.
        let sys = system(Topology::numa(2, 2));
        let s = GangScheduler::new(1_000);
        let m = Marcel::with_system(&sys);
        let root = m.bubble_init();
        let mut threads = Vec::new();
        for g in 0..2 {
            let b = m.bubble_init();
            for k in 0..2 {
                let t = m.create_dontsched(format!("g{g}k{k}"));
                m.bubble_inserttask(b, t);
                threads.push(t);
            }
            m.bubble_insertbubble(root, b);
        }
        s.wake(&sys, root);
        let picked: Vec<TaskId> = (0..4).filter_map(|c| s.pick(&sys, CpuId(c))).collect();
        assert_eq!(picked.len(), 4, "all nested threads join the gang: {picked:?}");
        for &t in &picked {
            assert!(threads.contains(&t), "picked a non-thread task {t}");
            s.stop(&sys, CpuId(0), t, StopReason::Terminate);
        }
        assert!(
            s.pick(&sys, CpuId(0)).is_none(),
            "parked sub-bubbles must not keep the gang alive"
        );
    }

    #[test]
    fn loose_threads_are_singleton_gangs() {
        let sys = system(Topology::smp(2));
        let s = GangScheduler::new(1_000);
        let a = sys.tasks.new_thread("a", PRIO_THREAD);
        let b = sys.tasks.new_thread("b", PRIO_THREAD);
        s.wake(&sys, a);
        s.wake(&sys, b);
        let x = s.pick(&sys, CpuId(0)).unwrap();
        assert_eq!(x, a);
        // b is a different gang: cannot run concurrently.
        assert!(s.pick(&sys, CpuId(1)).is_none());
        s.stop(&sys, CpuId(0), x, StopReason::Terminate);
        assert_eq!(s.pick(&sys, CpuId(1)), Some(b));
    }
}
