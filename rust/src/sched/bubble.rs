//! The bubble scheduler (paper §3.3 & §4) — the system contribution.
//!
//! Bubbles *descend* the list hierarchy towards the processors that pick
//! them, *burst* at their bursting level (releasing held threads and
//! sub-bubbles), and are *regenerated* — pulled closed again and moved
//! up — either correctively (an idle processor rebalances work while
//! keeping affinity intact) or preventively (per-bubble time slices,
//! which combined with Figure-1 priorities yields gang scheduling).
//!
//! Scheduling is strictly per-processor: a CPU calls [`BubbleScheduler::pick`]
//! when it needs work. The mechanics — two-pass list search, queueing
//! and dispatch accounting, hierarchy walks, steal fallbacks — live in
//! [`super::core`]; this file is *policy*: what a picked bubble does
//! (descend or burst), when regeneration fires, and how idle processors
//! rebalance.
//!
//! Accounting invariants (checked by the property tests):
//! * `outside` = number of direct contents currently *on lists or
//!   running* (blocked contents are not outside: they hold no list slot,
//!   matching §4 — regeneration "removes all of them from the task
//!   lists, except threads being executed").
//! * A regenerating bubble closes and requeues when `outside` drops to
//!   0 ("the last thread closes the bubble and moves it up").
//! * `live` = non-terminated direct contents; 0 terminates the bubble.
//!
//! Behavioural tests live in `rust/tests/bubble_behaviour.rs`.

use std::sync::Mutex;

use super::core::{ops, pick, traversal};
use super::{Scheduler, StopReason, System};
use crate::metrics::Metrics;
use crate::task::{BubblePhase, BurstLevel, TaskId, TaskKind, TaskState};
use crate::topology::{CpuId, LevelId};
use crate::trace::{Event, RegenWhy, StopWhy};

/// Tunables for the bubble scheduler (the paper §3.3.1 deliberately
/// exposes these: "more than a mere scheduling model, we propose a
/// scheduling experimentation platform").
#[derive(Debug, Clone)]
pub struct BubbleConfig {
    /// Bursting level used by bubbles that don't set their own.
    pub default_burst: BurstLevel,
    /// Corrective regeneration: idle processors may pull a remote
    /// bubble closed and move it up to re-burst on their side (§3.3.3).
    pub idle_regen: bool,
    /// Allow idle processors to steal lone ready *threads* from
    /// non-covering lists when no bubble rebalancing is possible.
    pub thread_steal: bool,
    /// Default per-bubble time slice (engine units); None = no
    /// preventive regeneration.
    pub default_timeslice: Option<u64>,
    /// Minimum engine-time between two regenerations of the same bubble
    /// (hysteresis against the §3.4 "ping-pong" pathology).
    pub regen_hysteresis: u64,
}

impl Default for BubbleConfig {
    fn default() -> Self {
        BubbleConfig {
            default_burst: BurstLevel::default(),
            idle_regen: true,
            thread_steal: true,
            default_timeslice: None,
            regen_hysteresis: 5_000_000,
        }
    }
}

/// Scheduler-private bubble bookkeeping (burst registry, last-regen
/// stamps) kept outside the task table.
#[derive(Debug, Default)]
struct Evolution {
    /// Bubbles currently burst (candidates for corrective regeneration).
    burst_bubbles: Vec<TaskId>,
    /// Engine time of each bubble's last regeneration.
    last_regen: std::collections::HashMap<usize, u64>,
}

/// The bubble scheduler.
#[derive(Debug)]
pub struct BubbleScheduler {
    cfg: BubbleConfig,
    /// Serialises bubble structural evolution (burst, regeneration,
    /// termination accounting). The thread-only fast path (Table 1
    /// "Yield") never takes it.
    evo: Mutex<Evolution>,
}

impl BubbleScheduler {
    pub fn new(cfg: BubbleConfig) -> BubbleScheduler {
        BubbleScheduler { cfg, evo: Mutex::new(Evolution::default()) }
    }

    /// Config accessor.
    pub fn config(&self) -> &BubbleConfig {
        &self.cfg
    }

    // --------------------------------------------------- bubble evolution

    /// A picked bubble takes one evolution step (Figure 3): go down one
    /// level towards the picking CPU, or burst here.
    fn bubble_step(&self, sys: &System, cpu: CpuId, bubble: TaskId, cur: LevelId) {
        let mut evo = self.evo.lock().unwrap();
        let (target_depth, phase) = sys.tasks.with(bubble, |t| {
            let d = t.bubble_data();
            (d.burst_depth(self.cfg.default_burst, &sys.topo), d.phase)
        });
        if phase != BubblePhase::Closed {
            // Raced with a concurrent burst; nothing to do.
            return;
        }
        let cur_depth = sys.topo.node(cur).depth;
        if cur_depth < target_depth && sys.topo.node(cur).covers(cpu) {
            if let Some(to) = traversal::descend_towards(&sys.topo, cur, cpu) {
                // Figure 3 (b)-(c): ride down towards the CPU.
                Metrics::inc(&sys.metrics.bubble_descents);
                sys.trace.emit(sys.now(), Event::BubbleDown { bubble, from: cur, to });
                ops::enqueue(sys, bubble, to);
                return;
            }
        }
        // Figure 3 (d): burst here.
        self.burst(sys, &mut evo, bubble, cur);
    }

    /// Release a bubble's contents onto `list` (§3.3.1: "held threads
    /// and bubbles are released and can be executed (or go deeper)").
    fn burst(&self, sys: &System, evo: &mut Evolution, bubble: TaskId, list: LevelId) {
        let (contents, live) = sys.tasks.with(bubble, |t| {
            let d = t.bubble_data_mut();
            d.phase = BubblePhase::Burst;
            d.home_list = Some(list);
            // Burst bubbles live off-list; Blocked is the off-list state.
            t.state = TaskState::Blocked;
            (t.kind_contents_snapshot(), t.bubble_data().live)
        });
        let mut released = 0usize;
        for c in contents {
            if sys.tasks.state(c) == TaskState::InBubble {
                ops::enqueue(sys, c, list);
                released += 1;
            }
        }
        sys.tasks.with(bubble, |t| {
            t.bubble_data_mut().outside = released;
        });
        evo.burst_bubbles.push(bubble);
        Metrics::inc(&sys.metrics.bursts);
        sys.trace.emit(sys.now(), Event::Burst { bubble, list, released });
        if live == 0 {
            // Empty (or fully-terminated) bubble: it is done.
            self.terminate_bubble(sys, evo, bubble);
        }
    }

    /// Begin regeneration: pull Ready contents back into the bubble;
    /// Running ones will come back by themselves (§4). If everything is
    /// already back, finish immediately.
    fn start_regen(
        &self,
        sys: &System,
        evo: &mut Evolution,
        bubble: TaskId,
        target: LevelId,
        why: RegenWhy,
    ) {
        let contents = sys.tasks.with(bubble, |t| {
            let d = t.bubble_data_mut();
            d.regen_pending = true;
            d.regen_target = Some(target);
            d.slice_used = 0;
            t.kind_contents_snapshot()
        });
        Metrics::inc(&sys.metrics.regenerations);
        sys.trace.emit(sys.now(), Event::Regen { bubble, why });
        evo.last_regen.insert(bubble.0, sys.now());
        let mut returned = 0usize;
        for c in contents {
            let (list, prio) = sys.tasks.with(c, |t| (t.state.ready_list(), t.prio));
            if let Some(l) = list {
                if sys.rq.remove(l, c, prio) {
                    sys.tasks.set_state(c, TaskState::InBubble);
                    returned += 1;
                }
            }
        }
        let outside_now = sys.tasks.with(bubble, |t| {
            let d = t.bubble_data_mut();
            d.outside = d.outside.saturating_sub(returned);
            d.outside
        });
        if outside_now == 0 {
            self.finish_regen(sys, evo, bubble);
        }
    }

    /// Close the bubble and requeue it at the end of its priority class
    /// on the target list ("the last thread closes the bubble and moves
    /// it up", §4; FIFO-within-class push *is* the §3.3.3 end-of-class
    /// requeue).
    fn finish_regen(&self, sys: &System, evo: &mut Evolution, bubble: TaskId) {
        let (target, live) = sys.tasks.with(bubble, |t| {
            let d = t.bubble_data_mut();
            d.phase = BubblePhase::Closed;
            d.regen_pending = false;
            let target = d.regen_target.take().or(d.home_list).unwrap_or(LevelId(0));
            d.home_list = None;
            (target, d.live)
        });
        evo.burst_bubbles.retain(|&b| b != bubble);
        if live == 0 {
            self.terminate_bubble(sys, evo, bubble);
            return;
        }
        ops::enqueue(sys, bubble, target);
        sys.trace.emit(sys.now(), Event::RegenDone { bubble, list: target });
    }

    /// Bubble termination: all contents terminated. Propagates to the
    /// parent bubble like a terminated thread.
    fn terminate_bubble(&self, sys: &System, evo: &mut Evolution, bubble: TaskId) {
        let parent = sys.tasks.with(bubble, |t| {
            // Remove from any list it might still be queued on.
            if let TaskState::Ready { list } = t.state {
                sys.rq.remove(list, t.id, t.prio);
            }
            t.state = TaskState::Terminated;
            t.parent
        });
        evo.burst_bubbles.retain(|&b| b != bubble);
        if let Some(p) = parent {
            self.child_done(sys, evo, p);
        }
    }

    /// A direct child (thread or bubble) of bubble `p` terminated while
    /// outside; decrement both counters and resolve consequences.
    fn child_done(&self, sys: &System, evo: &mut Evolution, p: TaskId) {
        let (live, outside, regen_pending, phase) = sys.tasks.with(p, |t| {
            let d = t.bubble_data_mut();
            d.live = d.live.saturating_sub(1);
            d.outside = d.outside.saturating_sub(1);
            (d.live, d.outside, d.regen_pending, d.phase)
        });
        if regen_pending && outside == 0 {
            self.finish_regen(sys, evo, p);
        } else if live == 0 && phase == BubblePhase::Burst {
            self.terminate_bubble(sys, evo, p);
        }
    }

    /// A content leaves the "outside" population without terminating
    /// (it blocked, or re-entered the bubble).
    fn leave_outside(&self, sys: &System, evo: &mut Evolution, p: TaskId) {
        let (outside, regen_pending) = sys.tasks.with(p, |t| {
            let d = t.bubble_data_mut();
            d.outside = d.outside.saturating_sub(1);
            (d.outside, d.regen_pending)
        });
        if regen_pending && outside == 0 {
            self.finish_regen(sys, evo, p);
        }
    }

    /// A running thread re-enters its regenerating bubble (§4). Returns
    /// false if the regeneration already completed (caller requeues
    /// normally instead).
    fn try_return_to_bubble(&self, sys: &System, task: TaskId, parent: TaskId) -> bool {
        let mut evo = self.evo.lock().unwrap();
        let still_pending = sys.tasks.with(parent, |t| t.bubble_data().regen_pending);
        if !still_pending {
            return false;
        }
        sys.tasks.set_state(task, TaskState::InBubble);
        sys.trace.emit(
            sys.now(),
            Event::Stop { task, cpu: CpuId(usize::MAX), why: StopWhy::BackInBubble },
        );
        self.leave_outside(sys, &mut evo, parent);
        true
    }

    // ------------------------------------------------------ idle handling

    /// Corrective rebalancing (§3.3.3): an idle CPU looks for a burst
    /// bubble homed outside its own subtree that still has ready work,
    /// regenerates it and moves it up to the closest list covering both
    /// — from where this CPU will pull it down and re-burst it locally,
    /// "getting a new distribution suited to the new workload while
    /// keeping affinity intact".
    fn idle_regen(&self, sys: &System, cpu: CpuId) -> bool {
        let mut evo = self.evo.lock().unwrap();
        let now = sys.now();
        let candidates: Vec<TaskId> = evo.burst_bubbles.clone();
        for bubble in candidates {
            let home = sys.tasks.with(bubble, |t| {
                let d = t.bubble_data();
                if d.regen_pending || d.phase != BubblePhase::Burst {
                    None
                } else {
                    d.home_list
                }
            });
            let Some(home) = home else { continue };
            if sys.topo.node(home).covers(cpu) {
                continue; // our own work; nothing to rebalance
            }
            if let Some(&last) = evo.last_regen.get(&bubble.0) {
                if now.saturating_sub(last) < self.cfg.regen_hysteresis {
                    continue;
                }
            }
            // Ready work left in that bubble? And is it recallable at
            // all? A content that is itself a *burst* bubble cannot be
            // pulled back in (its threads are loose beneath it), so
            // regenerating its parent would stall on it — skip those.
            // Moving a bubble for a single ready thread is pointless
            // (plain stealing covers that); require a real group.
            if self.ready_contents(sys, bubble) < 2 || !self.recallable(sys, bubble) {
                continue;
            }
            // Move up to the lowest ancestor of `home` covering `cpu`.
            let target = traversal::hoist_towards(&sys.topo, home, cpu);
            self.start_regen(sys, &mut evo, bubble, target, RegenWhy::Idle);
            return true;
        }
        false
    }

    fn ready_contents(&self, sys: &System, bubble: TaskId) -> usize {
        let contents = sys.tasks.with(bubble, |t| t.kind_contents_snapshot());
        contents.into_iter().filter(|&c| sys.tasks.state(c).is_ready()).count()
    }

    /// A bubble is recallable if none of its live contents is a burst
    /// sub-bubble (those never "return by themselves").
    fn recallable(&self, sys: &System, bubble: TaskId) -> bool {
        let contents = sys.tasks.with(bubble, |t| t.kind_contents_snapshot());
        contents.into_iter().all(|c| {
            sys.tasks.with(c, |t| match &t.kind {
                TaskKind::Bubble(d) => {
                    d.phase != BubblePhase::Burst || t.state == TaskState::Terminated
                }
                TaskKind::Thread(_) => true,
            })
        })
    }
}

impl Scheduler for BubbleScheduler {
    fn name(&self) -> String {
        "bubble".into()
    }

    fn wake(&self, sys: &System, task: TaskId) {
        let parent = sys.tasks.parent(task);
        let state = sys.tasks.state(task);
        match parent {
            None => {
                // Standalone task (or top-level bubble): requeue with
                // affinity to its previous list, else the machine root.
                let list = sys
                    .tasks
                    .with(task, |t| t.last_list)
                    .unwrap_or_else(|| sys.topo.root());
                ops::enqueue(sys, task, list);
            }
            Some(p) => {
                let (phase, regen_pending, home) = sys.tasks.with(p, |t| {
                    let d = t.bubble_data();
                    (d.phase, d.regen_pending, d.home_list)
                });
                match state {
                    TaskState::Blocked if regen_pending => {
                        // Woken into a regenerating bubble: go inside
                        // (it was not "outside": blocked tasks hold no
                        // list slot).
                        let mut evo = self.evo.lock().unwrap();
                        let _ = &mut evo;
                        sys.tasks.set_state(task, TaskState::InBubble);
                    }
                    TaskState::Blocked | TaskState::InBubble
                        if phase == BubblePhase::Burst =>
                    {
                        // Re-join the burst bubble on its home list
                        // (covers Figure 4's insert-after-wake too).
                        let mut evo = self.evo.lock().unwrap();
                        let _ = &mut evo;
                        sys.tasks.with(p, |t| t.bubble_data_mut().outside += 1);
                        ops::enqueue(sys, task, home.unwrap_or_else(|| sys.topo.root()));
                    }
                    TaskState::Blocked => {
                        // Woken into a *closed*, non-regenerating
                        // bubble: return to the held population so the
                        // next burst releases it. (Leaving it Blocked
                        // would drop the wake-up: bursts only release
                        // InBubble contents — found by the conservation
                        // property test.)
                        sys.tasks.set_state(task, TaskState::InBubble);
                    }
                    _ => {
                        // New / InBubble in a closed bubble: already
                        // held; released at burst.
                    }
                }
            }
        }
    }

    fn pick(&self, sys: &System, cpu: CpuId) -> Option<TaskId> {
        // Bound the retry loop: every iteration either dispatches,
        // performs an evolution step, or burns one retry credit.
        let order = traversal::covering(&sys.topo, cpu);
        let mut credits = 4 * sys.rq.len() + 16;
        loop {
            if credits == 0 {
                // Idle accounting lives in the engines (sim idle path /
                // executor park path), not here: pick() has no way to
                // know whether the caller will retry immediately.
                return None;
            }
            credits -= 1;
            let Some(list) = pick::pass1(sys, order) else {
                // Nothing visible from this CPU: rebalance. Thread
                // stealing goes first — it makes progress immediately
                // and cannot stall anyone; whole-bubble regeneration is
                // the last resort (it recalls ready threads and waits
                // for running ones, §4, so it is the blunter tool —
                // the §3.4 ping-pong caveat applies to it).
                if self.cfg.thread_steal {
                    if let Some((task, from)) = ops::steal_fullest(sys, cpu) {
                        if sys.tasks.is_bubble(task) {
                            // Pull the whole bubble towards us: hoist it
                            // to the lowest list covering both sides.
                            let target = traversal::hoist_towards(&sys.topo, from, cpu);
                            ops::enqueue(sys, task, target);
                            continue;
                        }
                        ops::dispatch(sys, cpu, task, from);
                        return Some(task);
                    }
                }
                if self.cfg.idle_regen && self.idle_regen(sys, cpu) {
                    continue;
                }
                return None;
            };
            // Pass 2: lock the chosen list and re-check.
            let Some((task, _prio)) = sys.rq.pop_max(list) else {
                Metrics::inc(&sys.metrics.search_retries);
                continue;
            };
            let (is_bubble, terminated) = sys
                .tasks
                .with(task, |t| (t.is_bubble(), t.state == TaskState::Terminated));
            if terminated {
                continue;
            }
            if is_bubble {
                self.bubble_step(sys, cpu, task, list);
                continue;
            }
            ops::dispatch(sys, cpu, task, list);
            return Some(task);
        }
    }

    fn stop(&self, sys: &System, cpu: CpuId, task: TaskId, why: StopReason) {
        ops::note_stop(sys, cpu);
        let parent = sys.tasks.parent(task);
        match why {
            StopReason::Yield | StopReason::Preempt => {
                sys.trace.emit(
                    sys.now(),
                    Event::Stop {
                        task,
                        cpu,
                        why: if why == StopReason::Yield {
                            StopWhy::Yield
                        } else {
                            StopWhy::Preempt
                        },
                    },
                );
                if parent.is_none() {
                    // Fast path (Table 1 "Yield"): a loose thread
                    // requeues with a single task-lock round trip.
                    let leaf = sys.topo.leaf_of(cpu);
                    let (list, prio) = sys.tasks.with(task, |t| {
                        let list = t.last_list.unwrap_or(leaf);
                        t.state = TaskState::Ready { list };
                        t.last_list = Some(list);
                        (list, t.prio)
                    });
                    if why == StopReason::Preempt {
                        Metrics::inc(&sys.metrics.preemptions);
                    }
                    sys.rq.push(list, task, prio);
                    sys.trace.emit(sys.now(), Event::Enqueue { task, list });
                    // Keep the every-enqueue-notifies invariant the
                    // native executor's parked workers rely on.
                    sys.notify_enqueue();
                    return;
                }
                let parent_regen = parent
                    .map(|p| sys.tasks.with(p, |t| t.bubble_data().regen_pending))
                    .unwrap_or(false);
                if parent_regen && self.try_return_to_bubble(sys, task, parent.unwrap()) {
                    return;
                }
                let list = sys
                    .tasks
                    .with(task, |t| t.last_list)
                    .unwrap_or_else(|| sys.topo.leaf_of(cpu));
                if why == StopReason::Preempt {
                    Metrics::inc(&sys.metrics.preemptions);
                }
                ops::enqueue(sys, task, list);
            }
            StopReason::Block => {
                sys.trace.emit(sys.now(), Event::Stop { task, cpu, why: StopWhy::Block });
                sys.tasks.set_state(task, TaskState::Blocked);
                if let Some(p) = parent {
                    // Blocked threads hold no list slot: they leave the
                    // outside population until woken (§4 semantics).
                    let mut evo = self.evo.lock().unwrap();
                    self.leave_outside(sys, &mut evo, p);
                }
            }
            StopReason::Terminate => {
                sys.trace.emit(sys.now(), Event::Stop { task, cpu, why: StopWhy::Terminate });
                sys.tasks.set_state(task, TaskState::Terminated);
                if let Some(p) = parent {
                    let mut evo = self.evo.lock().unwrap();
                    self.child_done(sys, &mut evo, p);
                }
            }
        }
    }

    fn tick(&self, sys: &System, _cpu: CpuId, task: TaskId, elapsed: u64) -> bool {
        // Charge the nearest ancestor bubble that has a time slice.
        let mut cur = sys.tasks.parent(task);
        while let Some(b) = cur {
            let (slice, parent) = sys.tasks.with(b, |t| {
                let d = t.bubble_data();
                (d.timeslice.or(self.cfg.default_timeslice), t.parent)
            });
            match slice {
                Some(q) => {
                    let expired = sys.tasks.with(b, |t| {
                        let d = t.bubble_data_mut();
                        d.slice_used += elapsed;
                        d.slice_used >= q && !d.regen_pending
                    });
                    if expired {
                        let home = sys.tasks.with(b, |t| t.bubble_data().home_list);
                        if let Some(h) = home {
                            // Preventive regeneration: back to the end
                            // of its own list; another bubble bursts to
                            // occupy the freed processors (§3.3.3).
                            let mut evo = self.evo.lock().unwrap();
                            self.start_regen(sys, &mut evo, b, h, RegenWhy::Timeslice);
                            Metrics::inc(&sys.metrics.preemptions);
                            return true;
                        }
                    }
                    return false;
                }
                None => cur = parent,
            }
        }
        false
    }
}
