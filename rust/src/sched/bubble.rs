//! The bubble scheduler (paper §3.3 & §4) — the system contribution.
//!
//! Bubbles *descend* the list hierarchy towards the processors that pick
//! them, *burst* at their bursting level (releasing held threads and
//! sub-bubbles), and are *regenerated* — pulled closed again and moved
//! up — either correctively (an idle processor rebalances work while
//! keeping affinity intact) or preventively (per-bubble time slices,
//! which combined with Figure-1 priorities yields gang scheduling).
//!
//! Scheduling is strictly per-processor: a CPU calls [`BubbleScheduler::pick`]
//! when it needs work. The pick runs the paper's two-pass search:
//! pass 1 scans the lock-free max-priority hints of the lists covering
//! the CPU (most local first), pass 2 locks only the chosen list and
//! re-checks, retrying if another processor raced us to the task.
//!
//! Accounting invariants (checked by the property tests):
//! * `outside` = number of direct contents currently *on lists or
//!   running* (blocked contents are not outside: they hold no list slot,
//!   matching §4 — regeneration "removes all of them from the task
//!   lists, except threads being executed").
//! * A regenerating bubble closes and requeues when `outside` drops to
//!   0 ("the last thread closes the bubble and moves it up").
//! * `live` = non-terminated direct contents; 0 terminates the bubble.

use std::sync::Mutex;

use super::{Scheduler, StopReason, System};
use crate::metrics::Metrics;
use crate::task::{BubblePhase, BurstLevel, Task, TaskId, TaskKind, TaskState};
use crate::topology::{CpuId, LevelId};
use crate::trace::{Event, RegenWhy, StopWhy};

/// Tunables for the bubble scheduler (the paper §3.3.1 deliberately
/// exposes these: "more than a mere scheduling model, we propose a
/// scheduling experimentation platform").
#[derive(Debug, Clone)]
pub struct BubbleConfig {
    /// Bursting level used by bubbles that don't set their own.
    pub default_burst: BurstLevel,
    /// Corrective regeneration: idle processors may pull a remote
    /// bubble closed and move it up to re-burst on their side (§3.3.3).
    pub idle_regen: bool,
    /// Allow idle processors to steal lone ready *threads* from
    /// non-covering lists when no bubble rebalancing is possible.
    pub thread_steal: bool,
    /// Default per-bubble time slice (engine units); None = no
    /// preventive regeneration.
    pub default_timeslice: Option<u64>,
    /// Minimum engine-time between two regenerations of the same bubble
    /// (hysteresis against the §3.4 "ping-pong" pathology).
    pub regen_hysteresis: u64,
}

impl Default for BubbleConfig {
    fn default() -> Self {
        BubbleConfig {
            default_burst: BurstLevel::default(),
            idle_regen: true,
            thread_steal: true,
            default_timeslice: None,
            regen_hysteresis: 5_000_000,
        }
    }
}

/// Scheduler-private bubble bookkeeping (burst registry, last-regen
/// stamps) kept outside the task table.
#[derive(Debug, Default)]
struct Evolution {
    /// Bubbles currently burst (candidates for corrective regeneration).
    burst_bubbles: Vec<TaskId>,
    /// Engine time of each bubble's last regeneration.
    last_regen: std::collections::HashMap<usize, u64>,
}

/// The bubble scheduler.
#[derive(Debug)]
pub struct BubbleScheduler {
    cfg: BubbleConfig,
    /// Serialises bubble structural evolution (burst, regeneration,
    /// termination accounting). The thread-only fast path (Table 1
    /// "Yield") never takes it.
    evo: Mutex<Evolution>,
}

impl BubbleScheduler {
    pub fn new(cfg: BubbleConfig) -> BubbleScheduler {
        BubbleScheduler { cfg, evo: Mutex::new(Evolution::default()) }
    }

    /// Config accessor.
    pub fn config(&self) -> &BubbleConfig {
        &self.cfg
    }

    // ------------------------------------------------------------ queueing

    /// Put a task on a list and fix its state.
    fn enqueue(&self, sys: &System, task: TaskId, list: LevelId) {
        let prio = sys.tasks.with(task, |t| {
            t.state = TaskState::Ready { list };
            t.last_list = Some(list);
            t.prio
        });
        sys.rq.push(list, task, prio);
        sys.trace.emit(sys.now(), Event::Enqueue { task, list });
    }

    // ------------------------------------------------------- two-pass pick

    /// Pass 1: lock-free scan of the covering lists, most local first.
    /// Returns the list holding the (apparently) highest-priority task;
    /// ties go to the more local list.
    fn pass1(&self, sys: &System, cpu: CpuId) -> Option<LevelId> {
        let mut best: Option<(LevelId, i32)> = None;
        for &l in sys.topo.covering(cpu) {
            let p = sys.rq.peek_max(l);
            if p == i32::MIN {
                continue;
            }
            match best {
                Some((_, bp)) if p <= bp => {}
                _ => best = Some((l, p)),
            }
        }
        best.map(|(l, _)| l)
    }

    /// Dispatch a popped thread on the CPU.
    fn dispatch(&self, sys: &System, cpu: CpuId, task: TaskId, from: LevelId) {
        sys.tasks.with(task, |t| {
            debug_assert!(t.is_thread());
            if let Some(last) = t.last_cpu {
                if last != cpu {
                    Metrics::inc(&sys.metrics.migrations);
                }
            }
            t.state = TaskState::Running { cpu };
            t.last_cpu = Some(cpu);
            t.last_list = Some(from);
        });
        Metrics::inc(&sys.metrics.picks);
        sys.trace.emit(sys.now(), Event::Dispatch { task, cpu });
    }

    // --------------------------------------------------- bubble evolution

    /// A picked bubble takes one evolution step (Figure 3): go down one
    /// level towards the picking CPU, or burst here.
    fn bubble_step(&self, sys: &System, cpu: CpuId, bubble: TaskId, cur: LevelId) {
        let mut evo = self.evo.lock().unwrap();
        let (target_depth, phase) = sys.tasks.with(bubble, |t| {
            let d = t.bubble_data();
            (d.burst_depth(self.cfg.default_burst, &sys.topo), d.phase)
        });
        if phase != BubblePhase::Closed {
            // Raced with a concurrent burst; nothing to do.
            return;
        }
        let cur_depth = sys.topo.node(cur).depth;
        if cur_depth < target_depth && sys.topo.node(cur).covers(cpu) {
            if let Some(to) = sys.topo.child_towards(cur, cpu) {
                // Figure 3 (b)-(c): ride down towards the CPU.
                Metrics::inc(&sys.metrics.bubble_descents);
                sys.trace.emit(sys.now(), Event::BubbleDown { bubble, from: cur, to });
                self.enqueue(sys, bubble, to);
                return;
            }
        }
        // Figure 3 (d): burst here.
        self.burst(sys, &mut evo, bubble, cur);
    }

    /// Release a bubble's contents onto `list` (§3.3.1: "held threads
    /// and bubbles are released and can be executed (or go deeper)").
    fn burst(&self, sys: &System, evo: &mut Evolution, bubble: TaskId, list: LevelId) {
        let (contents, live) = sys.tasks.with(bubble, |t| {
            let d = t.bubble_data_mut();
            d.phase = BubblePhase::Burst;
            d.home_list = Some(list);
            // Burst bubbles live off-list; Blocked is the off-list state.
            t.state = TaskState::Blocked;
            (t.kind_contents_snapshot(), t.bubble_data().live)
        });
        let mut released = 0usize;
        for c in contents {
            if sys.tasks.state(c) == TaskState::InBubble {
                self.enqueue(sys, c, list);
                released += 1;
            }
        }
        sys.tasks.with(bubble, |t| {
            t.bubble_data_mut().outside = released;
        });
        evo.burst_bubbles.push(bubble);
        Metrics::inc(&sys.metrics.bursts);
        sys.trace.emit(sys.now(), Event::Burst { bubble, list, released });
        if live == 0 {
            // Empty (or fully-terminated) bubble: it is done.
            self.terminate_bubble(sys, evo, bubble);
        }
    }

    /// Begin regeneration: pull Ready contents back into the bubble;
    /// Running ones will come back by themselves (§4). If everything is
    /// already back, finish immediately.
    fn start_regen(
        &self,
        sys: &System,
        evo: &mut Evolution,
        bubble: TaskId,
        target: LevelId,
        why: RegenWhy,
    ) {
        let contents = sys.tasks.with(bubble, |t| {
            let d = t.bubble_data_mut();
            d.regen_pending = true;
            d.regen_target = Some(target);
            d.slice_used = 0;
            t.kind_contents_snapshot()
        });
        Metrics::inc(&sys.metrics.regenerations);
        sys.trace.emit(sys.now(), Event::Regen { bubble, why });
        evo.last_regen.insert(bubble.0, sys.now());
        let mut returned = 0usize;
        for c in contents {
            let list = sys.tasks.with(c, |t| t.state.ready_list());
            if let Some(l) = list {
                if sys.rq.remove(l, c) {
                    sys.tasks.set_state(c, TaskState::InBubble);
                    returned += 1;
                }
            }
        }
        let outside_now = sys.tasks.with(bubble, |t| {
            let d = t.bubble_data_mut();
            d.outside = d.outside.saturating_sub(returned);
            d.outside
        });
        if outside_now == 0 {
            self.finish_regen(sys, evo, bubble);
        }
    }

    /// Close the bubble and requeue it at the end of its target list
    /// ("the last thread closes the bubble and moves it up", §4).
    fn finish_regen(&self, sys: &System, evo: &mut Evolution, bubble: TaskId) {
        let (target, prio, live) = sys.tasks.with(bubble, |t| {
            let prio = t.prio;
            let d = t.bubble_data_mut();
            d.phase = BubblePhase::Closed;
            d.regen_pending = false;
            let target = d.regen_target.take().or(d.home_list).unwrap_or(LevelId(0));
            d.home_list = None;
            (target, prio, d.live)
        });
        evo.burst_bubbles.retain(|&b| b != bubble);
        if live == 0 {
            self.terminate_bubble(sys, evo, bubble);
            return;
        }
        sys.tasks.with(bubble, |t| {
            t.state = TaskState::Ready { list: target };
            t.last_list = Some(target);
        });
        sys.rq.push_back(target, bubble, prio);
        sys.trace.emit(sys.now(), Event::RegenDone { bubble, list: target });
    }

    /// Bubble termination: all contents terminated. Propagates to the
    /// parent bubble like a terminated thread.
    fn terminate_bubble(&self, sys: &System, evo: &mut Evolution, bubble: TaskId) {
        let parent = sys.tasks.with(bubble, |t| {
            // Remove from any list it might still be queued on.
            if let TaskState::Ready { list } = t.state {
                sys.rq.remove(list, t.id);
            }
            t.state = TaskState::Terminated;
            t.parent
        });
        evo.burst_bubbles.retain(|&b| b != bubble);
        if let Some(p) = parent {
            self.child_done(sys, evo, p);
        }
    }

    /// A direct child (thread or bubble) of bubble `p` terminated while
    /// outside; decrement both counters and resolve consequences.
    fn child_done(&self, sys: &System, evo: &mut Evolution, p: TaskId) {
        let (live, outside, regen_pending, phase) = sys.tasks.with(p, |t| {
            let d = t.bubble_data_mut();
            d.live = d.live.saturating_sub(1);
            d.outside = d.outside.saturating_sub(1);
            (d.live, d.outside, d.regen_pending, d.phase)
        });
        if regen_pending && outside == 0 {
            self.finish_regen(sys, evo, p);
        } else if live == 0 && phase == BubblePhase::Burst {
            self.terminate_bubble(sys, evo, p);
        }
    }

    /// A content leaves the "outside" population without terminating
    /// (it blocked, or re-entered the bubble).
    fn leave_outside(&self, sys: &System, evo: &mut Evolution, p: TaskId) {
        let (outside, regen_pending) = sys.tasks.with(p, |t| {
            let d = t.bubble_data_mut();
            d.outside = d.outside.saturating_sub(1);
            (d.outside, d.regen_pending)
        });
        if regen_pending && outside == 0 {
            self.finish_regen(sys, evo, p);
        }
    }

    /// A running thread re-enters its regenerating bubble (§4). Returns
    /// false if the regeneration already completed (caller requeues
    /// normally instead).
    fn try_return_to_bubble(&self, sys: &System, task: TaskId, parent: TaskId) -> bool {
        let mut evo = self.evo.lock().unwrap();
        let still_pending = sys.tasks.with(parent, |t| t.bubble_data().regen_pending);
        if !still_pending {
            return false;
        }
        sys.tasks.set_state(task, TaskState::InBubble);
        sys.trace.emit(
            sys.now(),
            Event::Stop { task, cpu: CpuId(usize::MAX), why: StopWhy::BackInBubble },
        );
        self.leave_outside(sys, &mut evo, parent);
        true
    }

    // ------------------------------------------------------ idle handling

    /// Corrective rebalancing (§3.3.3): an idle CPU looks for a burst
    /// bubble homed outside its own subtree that still has ready work,
    /// regenerates it and moves it up to the closest list covering both
    /// — from where this CPU will pull it down and re-burst it locally,
    /// "getting a new distribution suited to the new workload while
    /// keeping affinity intact".
    fn idle_regen(&self, sys: &System, cpu: CpuId) -> bool {
        let mut evo = self.evo.lock().unwrap();
        let now = sys.now();
        let candidates: Vec<TaskId> = evo.burst_bubbles.clone();
        for bubble in candidates {
            let home = sys.tasks.with(bubble, |t| {
                let d = t.bubble_data();
                if d.regen_pending || d.phase != BubblePhase::Burst {
                    None
                } else {
                    d.home_list
                }
            });
            let Some(home) = home else { continue };
            if sys.topo.node(home).covers(cpu) {
                continue; // our own work; nothing to rebalance
            }
            if let Some(&last) = evo.last_regen.get(&bubble.0) {
                if now.saturating_sub(last) < self.cfg.regen_hysteresis {
                    continue;
                }
            }
            // Ready work left in that bubble? And is it recallable at
            // all? A content that is itself a *burst* bubble cannot be
            // pulled back in (its threads are loose beneath it), so
            // regenerating its parent would stall on it — skip those.
            // Moving a bubble for a single ready thread is pointless
            // (plain stealing covers that); require a real group.
            if self.ready_contents(sys, bubble) < 2 || !self.recallable(sys, bubble) {
                continue;
            }
            // Move up to the lowest ancestor of `home` covering `cpu`.
            let mut target = home;
            while !sys.topo.node(target).covers(cpu) {
                match sys.topo.node(target).parent {
                    Some(p) => target = p,
                    None => break,
                }
            }
            self.start_regen(sys, &mut evo, bubble, target, RegenWhy::Idle);
            return true;
        }
        false
    }

    fn ready_contents(&self, sys: &System, bubble: TaskId) -> usize {
        let contents = sys.tasks.with(bubble, |t| t.kind_contents_snapshot());
        contents.into_iter().filter(|&c| sys.tasks.state(c).is_ready()).count()
    }

    /// A bubble is recallable if none of its live contents is a burst
    /// sub-bubble (those never "return by themselves").
    fn recallable(&self, sys: &System, bubble: TaskId) -> bool {
        let contents = sys.tasks.with(bubble, |t| t.kind_contents_snapshot());
        contents.into_iter().all(|c| {
            sys.tasks.with(c, |t| match &t.kind {
                TaskKind::Bubble(d) => {
                    d.phase != BubblePhase::Burst || t.state == TaskState::Terminated
                }
                TaskKind::Thread(_) => true,
            })
        })
    }

    /// Last resort: steal a ready task from the fullest non-covering
    /// list.
    fn steal(&self, sys: &System, cpu: CpuId) -> Option<(TaskId, LevelId)> {
        let mut victim: Option<(LevelId, usize)> = None;
        for i in 0..sys.rq.len() {
            let l = LevelId(i);
            if sys.topo.node(l).covers(cpu) {
                continue;
            }
            let len = sys.rq.len_of(l);
            if len > victim.map_or(0, |(_, n)| n) {
                victim = Some((l, len));
            }
        }
        let (l, _) = victim?;
        let (task, _prio) = sys.rq.pop_max(l)?;
        Metrics::inc(&sys.metrics.steals);
        sys.trace.emit(sys.now(), Event::Steal { task, from: l, by: cpu });
        Some((task, l))
    }
}

impl Scheduler for BubbleScheduler {
    fn name(&self) -> String {
        "bubble".into()
    }

    fn wake(&self, sys: &System, task: TaskId) {
        let parent = sys.tasks.parent(task);
        let state = sys.tasks.state(task);
        match parent {
            None => {
                // Standalone task (or top-level bubble): requeue with
                // affinity to its previous list, else the machine root.
                let list = sys
                    .tasks
                    .with(task, |t| t.last_list)
                    .unwrap_or_else(|| sys.topo.root());
                self.enqueue(sys, task, list);
            }
            Some(p) => {
                let (phase, regen_pending, home) = sys.tasks.with(p, |t| {
                    let d = t.bubble_data();
                    (d.phase, d.regen_pending, d.home_list)
                });
                match state {
                    TaskState::Blocked if regen_pending => {
                        // Woken into a regenerating bubble: go inside
                        // (it was not "outside": blocked tasks hold no
                        // list slot).
                        let mut evo = self.evo.lock().unwrap();
                        let _ = &mut evo;
                        sys.tasks.set_state(task, TaskState::InBubble);
                    }
                    TaskState::Blocked | TaskState::InBubble
                        if phase == BubblePhase::Burst =>
                    {
                        // Re-join the burst bubble on its home list
                        // (covers Figure 4's insert-after-wake too).
                        let mut evo = self.evo.lock().unwrap();
                        let _ = &mut evo;
                        sys.tasks.with(p, |t| t.bubble_data_mut().outside += 1);
                        self.enqueue(sys, task, home.unwrap_or_else(|| sys.topo.root()));
                    }
                    _ => {
                        // Held in a closed bubble: released at burst.
                    }
                }
            }
        }
    }

    fn pick(&self, sys: &System, cpu: CpuId) -> Option<TaskId> {
        // Bound the retry loop: every iteration either dispatches,
        // performs an evolution step, or burns one retry credit.
        let mut credits = 4 * sys.rq.len() + 16;
        loop {
            if credits == 0 {
                Metrics::inc(&sys.metrics.idle_picks);
                return None;
            }
            credits -= 1;
            let Some(list) = self.pass1(sys, cpu) else {
                // Nothing visible from this CPU: rebalance. Thread
                // stealing goes first — it makes progress immediately
                // and cannot stall anyone; whole-bubble regeneration is
                // the last resort (it recalls ready threads and waits
                // for running ones, §4, so it is the blunter tool —
                // the §3.4 ping-pong caveat applies to it).
                if self.cfg.thread_steal {
                    if let Some((task, from)) = self.steal(sys, cpu) {
                        if sys.tasks.is_bubble(task) {
                            // Pull the whole bubble towards us: hoist it
                            // to the lowest list covering both sides.
                            let mut target = from;
                            while !sys.topo.node(target).covers(cpu) {
                                match sys.topo.node(target).parent {
                                    Some(p) => target = p,
                                    None => break,
                                }
                            }
                            self.enqueue(sys, task, target);
                            continue;
                        }
                        self.dispatch(sys, cpu, task, from);
                        return Some(task);
                    }
                }
                if self.cfg.idle_regen && self.idle_regen(sys, cpu) {
                    continue;
                }
                Metrics::inc(&sys.metrics.idle_picks);
                return None;
            };
            // Pass 2: lock the chosen list and re-check.
            let Some((task, _prio)) = sys.rq.pop_max(list) else {
                Metrics::inc(&sys.metrics.search_retries);
                continue;
            };
            let (is_bubble, terminated) = sys
                .tasks
                .with(task, |t| (t.is_bubble(), t.state == TaskState::Terminated));
            if terminated {
                continue;
            }
            if is_bubble {
                self.bubble_step(sys, cpu, task, list);
                continue;
            }
            self.dispatch(sys, cpu, task, list);
            return Some(task);
        }
    }

    fn stop(&self, sys: &System, cpu: CpuId, task: TaskId, why: StopReason) {
        let parent = sys.tasks.parent(task);
        match why {
            StopReason::Yield | StopReason::Preempt => {
                sys.trace.emit(
                    sys.now(),
                    Event::Stop {
                        task,
                        cpu,
                        why: if why == StopReason::Yield {
                            StopWhy::Yield
                        } else {
                            StopWhy::Preempt
                        },
                    },
                );
                if parent.is_none() {
                    // Fast path (Table 1 "Yield"): a loose thread
                    // requeues with a single task-lock round trip.
                    let leaf = sys.topo.leaf_of(cpu);
                    let (list, prio) = sys.tasks.with(task, |t| {
                        let list = t.last_list.unwrap_or(leaf);
                        t.state = TaskState::Ready { list };
                        t.last_list = Some(list);
                        (list, t.prio)
                    });
                    if why == StopReason::Preempt {
                        Metrics::inc(&sys.metrics.preemptions);
                    }
                    sys.rq.push(list, task, prio);
                    sys.trace.emit(sys.now(), Event::Enqueue { task, list });
                    return;
                }
                let parent_regen = parent
                    .map(|p| sys.tasks.with(p, |t| t.bubble_data().regen_pending))
                    .unwrap_or(false);
                if parent_regen {
                    if self.try_return_to_bubble(sys, task, parent.unwrap()) {
                        return;
                    }
                }
                let list = sys
                    .tasks
                    .with(task, |t| t.last_list)
                    .unwrap_or_else(|| sys.topo.leaf_of(cpu));
                if why == StopReason::Preempt {
                    Metrics::inc(&sys.metrics.preemptions);
                }
                self.enqueue(sys, task, list);
            }
            StopReason::Block => {
                sys.trace.emit(sys.now(), Event::Stop { task, cpu, why: StopWhy::Block });
                sys.tasks.set_state(task, TaskState::Blocked);
                if let Some(p) = parent {
                    // Blocked threads hold no list slot: they leave the
                    // outside population until woken (§4 semantics).
                    let mut evo = self.evo.lock().unwrap();
                    self.leave_outside(sys, &mut evo, p);
                }
            }
            StopReason::Terminate => {
                sys.trace.emit(sys.now(), Event::Stop { task, cpu, why: StopWhy::Terminate });
                sys.tasks.set_state(task, TaskState::Terminated);
                if let Some(p) = parent {
                    let mut evo = self.evo.lock().unwrap();
                    self.child_done(sys, &mut evo, p);
                }
            }
        }
    }

    fn tick(&self, sys: &System, _cpu: CpuId, task: TaskId, elapsed: u64) -> bool {
        // Charge the nearest ancestor bubble that has a time slice.
        let mut cur = sys.tasks.parent(task);
        while let Some(b) = cur {
            let (slice, parent) = sys.tasks.with(b, |t| {
                let d = t.bubble_data();
                (d.timeslice.or(self.cfg.default_timeslice), t.parent)
            });
            match slice {
                Some(q) => {
                    let expired = sys.tasks.with(b, |t| {
                        let d = t.bubble_data_mut();
                        d.slice_used += elapsed;
                        d.slice_used >= q && !d.regen_pending
                    });
                    if expired {
                        let home = sys.tasks.with(b, |t| t.bubble_data().home_list);
                        if let Some(h) = home {
                            // Preventive regeneration: back to the end
                            // of its own list; another bubble bursts to
                            // occupy the freed processors (§3.3.3).
                            let mut evo = self.evo.lock().unwrap();
                            self.start_regen(sys, &mut evo, b, h, RegenWhy::Timeslice);
                            Metrics::inc(&sys.metrics.preemptions);
                            return true;
                        }
                    }
                    return false;
                }
                None => cur = parent,
            }
        }
        false
    }
}

// Helper on Task to snapshot bubble contents without exposing internals.
impl Task {
    /// Clone the contents list of a bubble task (empty for threads).
    pub fn kind_contents_snapshot(&self) -> Vec<TaskId> {
        match &self.kind {
            TaskKind::Bubble(b) => b.contents.clone(),
            TaskKind::Thread(_) => Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::marcel::Marcel;
    use crate::sched::testutil::{drain_cpu, spawn_threads, system};
    use crate::task::{PRIO_BUBBLE, PRIO_THREAD};
    use crate::topology::Topology;

    fn sched() -> BubbleScheduler {
        BubbleScheduler::new(BubbleConfig::default())
    }

    #[test]
    fn plain_threads_round_trip() {
        let sys = system(Topology::smp(2));
        let s = sched();
        let ts = spawn_threads(&sys, &s, 3);
        let order = drain_cpu(&sys, &s, CpuId(0));
        assert_eq!(order, ts);
        assert!(s.pick(&sys, CpuId(0)).is_none());
    }

    #[test]
    fn yield_requeues_to_same_list() {
        let sys = system(Topology::smp(2));
        let s = sched();
        let ts = spawn_threads(&sys, &s, 1);
        let t = s.pick(&sys, CpuId(0)).unwrap();
        assert_eq!(t, ts[0]);
        s.stop(&sys, CpuId(0), t, StopReason::Yield);
        assert!(sys.tasks.state(t).is_ready());
        let t2 = s.pick(&sys, CpuId(0)).unwrap();
        assert_eq!(t2, t);
    }

    #[test]
    fn bubble_descends_and_bursts_at_numa_level() {
        let sys = system(Topology::numa(2, 2));
        let s = sched();
        let m = Marcel::with_system(&sys);
        let b = m.bubble_init();
        let t1 = m.create_dontsched("a");
        let t2 = m.create_dontsched("b");
        m.bubble_inserttask(b, t1);
        m.bubble_inserttask(b, t2);
        sys.trace.set_enabled(true);
        s.wake(&sys, b);
        // cpu0 picks: bubble descends from root to numa0, bursts there,
        // then cpu0 gets a thread.
        let got = s.pick(&sys, CpuId(0)).unwrap();
        assert!(got == t1 || got == t2);
        // The burst must have happened on the NUMA-node list (depth 1).
        let records = sys.trace.records();
        let burst_list = records
            .iter()
            .find_map(|r| match r.event {
                Event::Burst { list, .. } => Some(list),
                _ => None,
            })
            .expect("no burst traced");
        assert_eq!(sys.topo.node(burst_list).depth, 1);
        assert_eq!(sys.topo.node(burst_list).kind, crate::topology::LevelKind::NumaNode);
        // The second thread is visible to cpu1 (same node).
        let got2 = s.pick(&sys, CpuId(1)).unwrap();
        assert!(got2 == t1 || got2 == t2);
        assert_ne!(got, got2);
    }

    #[test]
    fn burst_level_leaf_rides_to_cpu_list() {
        let sys = system(Topology::numa(2, 2));
        let s = BubbleScheduler::new(BubbleConfig {
            default_burst: BurstLevel::Leaf,
            ..BubbleConfig::default()
        });
        let m = Marcel::with_system(&sys);
        let b = m.bubble_init();
        let t1 = m.create_dontsched("a");
        m.bubble_inserttask(b, t1);
        sys.trace.set_enabled(true);
        s.wake(&sys, b);
        let got = s.pick(&sys, CpuId(3)).unwrap();
        assert_eq!(got, t1);
        let burst_list = sys
            .trace
            .records()
            .iter()
            .find_map(|r| match r.event {
                Event::Burst { list, .. } => Some(list),
                _ => None,
            })
            .unwrap();
        assert_eq!(burst_list, sys.topo.leaf_of(CpuId(3)));
    }

    #[test]
    fn higher_priority_task_wins_over_fifo_order() {
        let sys = system(Topology::numa(2, 2));
        let s = sched();
        let lo = sys.tasks.new_thread("lo", PRIO_THREAD);
        let hi = sys.tasks.new_thread("hi", crate::task::PRIO_HIGH);
        s.wake(&sys, lo);
        s.wake(&sys, hi);
        let got = s.pick(&sys, CpuId(0)).unwrap();
        assert_eq!(got, hi, "high priority wins despite FIFO order");
    }

    #[test]
    fn local_list_wins_priority_ties() {
        let sys = system(Topology::numa(2, 2));
        let s = sched();
        let global = sys.tasks.new_thread("global", PRIO_THREAD);
        let local = sys.tasks.new_thread("local", PRIO_THREAD);
        s.wake(&sys, global); // root list
        // Place `local` directly on cpu0's leaf list.
        sys.tasks.with(local, |t| t.last_list = Some(sys.topo.leaf_of(CpuId(0))));
        s.wake(&sys, local);
        let got = s.pick(&sys, CpuId(0)).unwrap();
        assert_eq!(got, local, "ties must prefer the most local list");
    }

    #[test]
    fn empty_bubble_terminates_on_burst() {
        let sys = system(Topology::smp(2));
        let s = sched();
        let m = Marcel::with_system(&sys);
        let b = m.bubble_init();
        s.wake(&sys, b);
        assert!(s.pick(&sys, CpuId(0)).is_none());
        assert_eq!(sys.tasks.state(b), TaskState::Terminated);
    }

    #[test]
    fn thread_terminations_terminate_bubble() {
        let sys = system(Topology::smp(2));
        let s = sched();
        let m = Marcel::with_system(&sys);
        let b = m.bubble_init();
        let t1 = m.create_dontsched("a");
        let t2 = m.create_dontsched("b");
        m.bubble_inserttask(b, t1);
        m.bubble_inserttask(b, t2);
        s.wake(&sys, b);
        let a = s.pick(&sys, CpuId(0)).unwrap();
        let c = s.pick(&sys, CpuId(1)).unwrap();
        s.stop(&sys, CpuId(0), a, StopReason::Terminate);
        assert_ne!(sys.tasks.state(b), TaskState::Terminated);
        s.stop(&sys, CpuId(1), c, StopReason::Terminate);
        assert_eq!(sys.tasks.state(b), TaskState::Terminated);
    }

    #[test]
    fn figure4_insert_after_wake() {
        // Figure 4 inserts thread2 *after* wake_up_bubble: the late
        // insertion must land on the burst bubble's home list.
        let sys = system(Topology::smp(2));
        let s = sched();
        let m = Marcel::with_system(&sys);
        let b = m.bubble_init();
        let t1 = m.create_dontsched("t1");
        m.bubble_inserttask(b, t1);
        s.wake(&sys, b);
        let got1 = s.pick(&sys, CpuId(0)).unwrap();
        assert_eq!(got1, t1);
        // Late insertion.
        let t2 = m.create_dontsched("t2");
        m.bubble_inserttask(b, t2);
        s.wake(&sys, t2);
        let got2 = s.pick(&sys, CpuId(1)).unwrap();
        assert_eq!(got2, t2);
        // Both must terminate the bubble.
        s.stop(&sys, CpuId(0), t1, StopReason::Terminate);
        s.stop(&sys, CpuId(1), t2, StopReason::Terminate);
        assert_eq!(sys.tasks.state(b), TaskState::Terminated);
    }

    #[test]
    fn gang_scheduling_via_priorities() {
        // Figure 1: two pair-bubbles under a root bubble; threads
        // prioritised over bubbles. With 2 CPUs, the first burst pair
        // must fully occupy the machine before the second bubble bursts.
        let sys = system(Topology::smp(2));
        let s = BubbleScheduler::new(BubbleConfig {
            default_burst: BurstLevel::Immediate,
            ..BubbleConfig::default()
        });
        let m = Marcel::with_system(&sys);
        let root = m.bubble_init();
        let b1 = m.bubble_init();
        let b2 = m.bubble_init();
        let p1a = m.create_dontsched("p1a");
        let p1b = m.create_dontsched("p1b");
        let p2a = m.create_dontsched("p2a");
        let p2b = m.create_dontsched("p2b");
        m.bubble_inserttask(b1, p1a);
        m.bubble_inserttask(b1, p1b);
        m.bubble_inserttask(b2, p2a);
        m.bubble_inserttask(b2, p2b);
        m.bubble_insertbubble(root, b1);
        m.bubble_insertbubble(root, b2);
        s.wake(&sys, root);
        let x = s.pick(&sys, CpuId(0)).unwrap();
        let y = s.pick(&sys, CpuId(1)).unwrap();
        let first: std::collections::BTreeSet<TaskId> = [x, y].into();
        // Must both come from the same pair-bubble (gang!).
        assert!(
            first == [p1a, p1b].into() || first == [p2a, p2b].into(),
            "first gang mixed: {first:?}"
        );
    }

    #[test]
    fn timeslice_regen_rotates_gangs() {
        let sys = system(Topology::smp(2));
        let s = BubbleScheduler::new(BubbleConfig {
            default_burst: BurstLevel::Immediate,
            default_timeslice: Some(100),
            ..BubbleConfig::default()
        });
        let m = Marcel::with_system(&sys);
        let root = m.bubble_init();
        let mk_pair = |tag: &str| {
            let b = m.bubble_init();
            let x = m.create_dontsched(format!("{tag}a"));
            let y = m.create_dontsched(format!("{tag}b"));
            m.bubble_inserttask(b, x);
            m.bubble_inserttask(b, y);
            (b, x, y)
        };
        let (b1, _p1a, _p1b) = mk_pair("p1");
        let (b2, _p2a, _p2b) = mk_pair("p2");
        m.bubble_insertbubble(root, b1);
        m.bubble_insertbubble(root, b2);
        s.wake(&sys, root);
        let x = s.pick(&sys, CpuId(0)).unwrap();
        let y = s.pick(&sys, CpuId(1)).unwrap();
        let gang1: std::collections::BTreeSet<TaskId> = [x, y].into();
        // Burn the gang's timeslice.
        let preempt_x = s.tick(&sys, CpuId(0), x, 60);
        let preempt_y = s.tick(&sys, CpuId(1), y, 60);
        assert!(preempt_x || preempt_y, "timeslice must trigger");
        s.stop(&sys, CpuId(0), x, StopReason::Preempt);
        s.stop(&sys, CpuId(1), y, StopReason::Preempt);
        // Next picks must be the *other* gang.
        let x2 = s.pick(&sys, CpuId(0)).unwrap();
        let y2 = s.pick(&sys, CpuId(1)).unwrap();
        let gang2: std::collections::BTreeSet<TaskId> = [x2, y2].into();
        assert!(gang2.is_disjoint(&gang1), "gangs must rotate: {gang1:?} vs {gang2:?}");
    }

    #[test]
    fn idle_regen_rebalances_across_nodes() {
        let sys = system(Topology::numa(2, 1)); // 2 nodes, 1 cpu each
        let s = BubbleScheduler::new(BubbleConfig {
            regen_hysteresis: 0,
            thread_steal: false,
            ..BubbleConfig::default()
        });
        let m = Marcel::with_system(&sys);
        let b = m.bubble_init();
        let ts: Vec<TaskId> = (0..4).map(|i| m.create_dontsched(format!("w{i}"))).collect();
        for &t in &ts {
            m.bubble_inserttask(b, t);
        }
        s.wake(&sys, b);
        // cpu0 pulls the bubble to node 0 and bursts it there.
        let t0 = s.pick(&sys, CpuId(0)).unwrap();
        // cpu1 (other node) sees nothing; its pick triggers a
        // corrective regeneration, which per §4 must wait for the
        // running thread before the bubble can move up.
        assert!(s.pick(&sys, CpuId(1)).is_none());
        assert!(sys.metrics.regenerations.load(std::sync::atomic::Ordering::Relaxed) >= 1);
        // The running thread finishes — "the last thread closes the
        // bubble and moves it up".
        s.stop(&sys, CpuId(0), t0, StopReason::Terminate);
        // Now cpu1 can pull the bubble down on its side and re-burst.
        let t1 = s.pick(&sys, CpuId(1)).expect("rebalanced work");
        assert_ne!(t0, t1);
        assert_eq!(sys.tasks.state(t1), TaskState::Running { cpu: CpuId(1) });
    }

    #[test]
    fn thread_steal_fallback() {
        let sys = system(Topology::numa(2, 1));
        let s = BubbleScheduler::new(BubbleConfig {
            idle_regen: false,
            thread_steal: true,
            ..BubbleConfig::default()
        });
        // A loose thread stuck on cpu0's leaf list.
        let t = sys.tasks.new_thread("lone", PRIO_THREAD);
        sys.tasks.with(t, |x| x.last_list = Some(sys.topo.leaf_of(CpuId(0))));
        s.wake(&sys, t);
        // cpu1 can't see that list; stealing must save it.
        let got = s.pick(&sys, CpuId(1)).unwrap();
        assert_eq!(got, t);
        assert_eq!(sys.metrics.steals.load(std::sync::atomic::Ordering::Relaxed), 1);
    }

    #[test]
    fn blocked_thread_wakes_back_to_home_list() {
        let sys = system(Topology::numa(2, 2));
        let s = sched();
        let m = Marcel::with_system(&sys);
        let b = m.bubble_init();
        let t1 = m.create_dontsched("a");
        let t2 = m.create_dontsched("b");
        m.bubble_inserttask(b, t1);
        m.bubble_inserttask(b, t2);
        s.wake(&sys, b);
        let x = s.pick(&sys, CpuId(0)).unwrap();
        s.stop(&sys, CpuId(0), x, StopReason::Block);
        assert_eq!(sys.tasks.state(x), TaskState::Blocked);
        s.wake(&sys, x);
        assert!(sys.tasks.state(x).is_ready());
        // It must be back on the bubble's home list (numa node 0).
        let list = sys.tasks.state(x).ready_list().unwrap();
        assert_eq!(sys.topo.node(list).kind, crate::topology::LevelKind::NumaNode);
    }

    #[test]
    fn no_task_lost_under_chaotic_schedule() {
        // Property: every created thread is eventually picked and
        // terminated; nothing vanishes.
        use crate::util::proptest::check;
        check(0xb0b, 25, |rng| {
            let topo = match rng.below(3) {
                0 => Topology::smp(4),
                1 => Topology::numa(2, 2),
                _ => Topology::deep(),
            };
            let n_cpus = topo.n_cpus();
            let sys = system(topo);
            let s = BubbleScheduler::new(BubbleConfig {
                regen_hysteresis: 0,
                ..Default::default()
            });
            let m = Marcel::with_system(&sys);
            let mut all_threads = Vec::new();
            for bi in 0..rng.range(1, 4) {
                let b = m.bubble_init();
                for ti in 0..rng.range(1, 5) {
                    let t = m.create_dontsched(format!("b{bi}t{ti}"));
                    m.bubble_inserttask(b, t);
                    all_threads.push(t);
                }
                s.wake(&sys, b);
            }
            for i in 0..rng.range(0, 3) {
                let t = sys.tasks.new_thread(format!("loose{i}"), PRIO_THREAD);
                s.wake(&sys, t);
                all_threads.push(t);
            }
            let mut remaining: std::collections::HashSet<TaskId> =
                all_threads.iter().copied().collect();
            let mut fuel = 10_000;
            while !remaining.is_empty() && fuel > 0 {
                fuel -= 1;
                let cpu = CpuId(rng.range(0, n_cpus));
                if let Some(t) = s.pick(&sys, cpu) {
                    if rng.chance(0.3) {
                        s.stop(&sys, cpu, t, StopReason::Yield);
                    } else {
                        s.stop(&sys, cpu, t, StopReason::Terminate);
                        remaining.remove(&t);
                    }
                }
            }
            assert!(remaining.is_empty(), "lost tasks: {remaining:?}");
        });
    }

    #[test]
    fn bubble_priority_below_thread_keeps_machine_busy() {
        // Paper Figure 1 rationale: a bubble bursts only when running
        // threads can no longer occupy all processors.
        let sys = system(Topology::smp(2));
        let s = BubbleScheduler::new(BubbleConfig {
            default_burst: BurstLevel::Immediate,
            ..Default::default()
        });
        let m = Marcel::with_system(&sys);
        let a = sys.tasks.new_thread("a", PRIO_THREAD);
        let bt = sys.tasks.new_thread("b", PRIO_THREAD);
        s.wake(&sys, a);
        s.wake(&sys, bt);
        let bub = m.bubble_init();
        let c = m.create_dontsched("c");
        let d = m.create_dontsched("d");
        m.bubble_inserttask(bub, c);
        m.bubble_inserttask(bub, d);
        s.wake(&sys, bub);
        let x = s.pick(&sys, CpuId(0)).unwrap();
        let y = s.pick(&sys, CpuId(1)).unwrap();
        assert_eq!(
            std::collections::BTreeSet::from([x, y]),
            std::collections::BTreeSet::from([a, bt]),
            "threads must be scheduled before the bubble bursts"
        );
        assert_eq!(sys.tasks.with(bub, |t| t.bubble_data().phase), BubblePhase::Closed);
        assert_eq!(sys.tasks.prio(bub), PRIO_BUBBLE);
    }
}
