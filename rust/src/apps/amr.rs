//! AMR-like imbalanced workload (paper §5.2 closing paragraph).
//!
//! "In the future these applications will be modified to benefit from
//! Adaptive Mesh Refinement (AMR) which increases computing precision
//! on interesting areas. This will entail large workload imbalances in
//! the mesh both at runtime and according to the computation results."
//!
//! We synthesise that future workload: stripes whose per-cycle work is
//! drawn from a heavy-tailed (Pareto) distribution and *re-drawn* every
//! cycle block — the refinement front moving through the mesh. This is
//! the workload where corrective bubble regeneration (§3.3.3) earns
//! its keep: `Bound` suffers pinned imbalance, `Simple` balances but
//! destroys affinity, bubbles rebalance *groups* while keeping
//! affinity.

use crate::marcel::Marcel;
use crate::sim::{Program, SimEngine, SimReport};
use crate::task::{TaskId, PRIO_THREAD};
use crate::topology::Topology;
use crate::util::Rng;

use super::StructureMode;

/// Imbalanced-stripe parameters.
#[derive(Debug, Clone)]
pub struct AmrParams {
    pub threads: usize,
    /// Barrier cycles in total.
    pub cycles: usize,
    /// Cycles between re-draws of the imbalance pattern.
    pub redraw_every: usize,
    /// Mean per-stripe work per cycle.
    pub mean_work: u64,
    /// Pareto shape (smaller = heavier tail = worse imbalance).
    pub shape: f64,
    pub mem_fraction: f64,
    pub seed: u64,
}

impl Default for AmrParams {
    fn default() -> Self {
        AmrParams {
            // Twice as many stripes as the reference machine's CPUs:
            // rebalancing is meaningless at 1 thread/CPU (every
            // schedule then executes one stripe per CPU per cycle).
            threads: 32,
            cycles: 24,
            redraw_every: 6,
            mean_work: 800_000,
            shape: 1.2,
            // AMR work is compute-dominated; the refinement data is
            // small relative to the arithmetic on it.
            mem_fraction: 0.15,
            seed: 42,
        }
    }
}

/// Per-stripe per-cycle work table (deterministic from the seed).
pub fn work_table(p: &AmrParams) -> Vec<Vec<u64>> {
    let mut rng = Rng::new(p.seed);
    let mut table = vec![vec![0u64; p.cycles]; p.threads];
    let mut current: Vec<u64> = vec![p.mean_work; p.threads];
    for c in 0..p.cycles {
        if c % p.redraw_every == 0 {
            // Refinement front moved: re-draw stripe weights with the
            // same total (the mesh is the same size, detail moved).
            let draws: Vec<f64> =
                (0..p.threads).map(|_| rng.pareto(1.0, p.shape)).collect();
            let total: f64 = draws.iter().sum();
            for (i, d) in draws.iter().enumerate() {
                current[i] =
                    ((d / total) * p.mean_work as f64 * p.threads as f64).max(1.0) as u64;
            }
        }
        for i in 0..p.threads {
            table[i][c] = current[i];
        }
    }
    table
}

/// Build the AMR workload under a structure mode.
pub fn build(engine: &mut SimEngine, mode: StructureMode, p: &AmrParams) -> Vec<TaskId> {
    build_inner(engine, mode, p, None)
}

/// Build like [`build`], plus the *coarse* mesh every refinement level
/// hangs off — one **striped** region spread over all NUMA nodes that
/// each thread touches every cycle. Returns the threads and the mesh
/// region (left unattached: shared data is nobody's footprint).
pub fn build_with_shared_mesh(
    engine: &mut SimEngine,
    mode: StructureMode,
    p: &AmrParams,
    mesh_bytes: u64,
) -> (Vec<TaskId>, crate::mem::RegionId) {
    let mesh = super::conduction::alloc_all_node_striped(engine, mesh_bytes);
    (build_inner(engine, mode, p, Some(mesh)), mesh)
}

fn build_inner(
    engine: &mut SimEngine,
    mode: StructureMode,
    p: &AmrParams,
    mesh: Option<crate::mem::RegionId>,
) -> Vec<TaskId> {
    let table = work_table(p);
    let barrier = engine.alloc_barrier(p.threads);
    // AMR refinement data is small relative to the arithmetic on it:
    // declare a modest region per stripe.
    let regions: Vec<_> = (0..p.threads)
        .map(|_| engine.alloc_region_sized(1 << 20, crate::sim::AllocPolicy::FirstTouch))
        .collect();
    let program = |i: usize, r| {
        let mut prog = Program::new();
        for c in 0..p.cycles {
            prog = prog.compute(table[i][c], p.mem_fraction, Some(r));
            if let Some(mesh) = mesh {
                let slice = (table[i][c] / super::conduction::MESH_SLICE_DIV).max(1);
                prog = prog.compute(slice, p.mem_fraction, Some(mesh));
            }
            prog = prog.barrier(barrier);
        }
        prog
    };
    match mode {
        StructureMode::Simple | StructureMode::Bound => {
            let mut out = Vec::new();
            for (i, &r) in regions.iter().enumerate() {
                let t = engine.add_thread(format!("amr{i}"), PRIO_THREAD, program(i, r));
                engine.attach_region(t, r);
                engine.wake(t);
                out.push(t);
            }
            out
        }
        StructureMode::Bubbles => {
            let sys = engine.sys.clone();
            let m = Marcel::with_system(&sys);
            let names: Vec<String> = (0..p.threads).map(|i| format!("amr{i}")).collect();
            let (root, threads) = m.bubbles_from_topology(&names);
            for (i, (&t, &r)) in threads.iter().zip(regions.iter()).enumerate() {
                engine.set_program(t, program(i, r));
                m.attach_region(t, r);
            }
            engine.wake(root);
            threads
        }
    }
}

/// Build the AMR workload as real green threads on the native executor
/// under the same structure axis as the simulator builder (`Simple`/
/// `Bound` = loose threads, `Bubbles` = one bubble per NUMA node via
/// [`Marcel::bubbles_from_topology`]). The per-stripe imbalance
/// survives the translation: each cycle a stripe records a number of
/// region touches proportional to its [`work_table`] weight (at least
/// one), with a yield after every touch, then arrives at the global
/// barrier. `touches` scales the mean touches per cycle.
pub fn build_native(
    ex: &mut crate::exec::Executor,
    mode: StructureMode,
    p: &AmrParams,
    policy: crate::mem::AllocPolicy,
    touches: usize,
) -> Vec<TaskId> {
    let table = work_table(p);
    let sys = ex.system().clone();
    let bar = ex.alloc_barrier(p.threads);
    let touches = touches.max(1);
    let regions: Vec<_> = (0..p.threads).map(|_| sys.mem.alloc(1 << 20, policy)).collect();
    // Touch counts per (stripe, cycle): mean `touches`, skewed like the
    // simulated work table.
    let counts: Vec<Vec<u64>> = (0..p.threads)
        .map(|i| {
            (0..p.cycles)
                .map(|c| {
                    ((table[i][c] as f64 / p.mean_work as f64) * touches as f64).round().max(1.0)
                        as u64
                })
                .collect()
        })
        .collect();
    let body = move |r: crate::mem::RegionId, mine: Vec<u64>| {
        move |api: crate::exec::GreenApi| {
            for &n in &mine {
                for _ in 0..n {
                    api.touch_region(r);
                    api.yield_now();
                }
                api.barrier(bar);
            }
        }
    };
    match mode {
        StructureMode::Simple | StructureMode::Bound => {
            let mut out = Vec::with_capacity(p.threads);
            for (i, &r) in regions.iter().enumerate() {
                let t = sys.tasks.new_thread(format!("amr{i}"), PRIO_THREAD);
                sys.mem.attach(&sys.tasks, t, r);
                ex.register(t, body(r, counts[i].clone()));
                out.push(t);
            }
            for &t in &out {
                ex.wake(t);
            }
            out
        }
        StructureMode::Bubbles => {
            let m = Marcel::with_system(&sys);
            let names: Vec<String> = (0..p.threads).map(|i| format!("amr{i}")).collect();
            let (root, threads) = m.bubbles_from_topology(&names);
            for (i, (&t, &r)) in threads.iter().zip(regions.iter()).enumerate() {
                m.attach_region(t, r);
                ex.register(t, body(r, counts[i].clone()));
            }
            ex.wake(root);
            threads
        }
    }
}

/// Run one AMR row.
pub fn run(topo: &Topology, mode: StructureMode, p: &AmrParams) -> SimReport {
    let mut e = super::engine_for(topo, mode);
    build(&mut e, mode, p);
    e.run().expect("amr run")
}

// --------------------------------------------------------------------
// Terminal imbalance: the §3.3.3 scenario proper.
// --------------------------------------------------------------------

/// Parameters for the skewed-groups workload: "it is possible that a
/// whole thread group has far less work than others and terminates
/// before them, leaving idle the whole part of the machine that was
/// running it" (§3.3.3). One group per NUMA node, one group much
/// heavier; no barrier coupling, so rebalancing genuinely shortens the
/// makespan.
#[derive(Debug, Clone)]
pub struct SkewParams {
    /// Bubbles per NUMA node. Using more than one gives corrective
    /// regeneration a unit it can actually split the heavy group by.
    pub bubbles_per_node: usize,
    /// Threads per bubble.
    pub threads_per_bubble: usize,
    /// Compute per thread (identical for all threads; the *imbalance*
    /// is in thread count, which is what bubble affinity pins).
    pub light_work: u64,
    /// Node 0's bubbles hold `heavy_factor`× as many threads.
    pub heavy_factor: f64,
    /// Chunks each thread's work is split into (yield points).
    pub chunks: usize,
    pub mem_fraction: f64,
}

impl Default for SkewParams {
    fn default() -> Self {
        SkewParams {
            bubbles_per_node: 1,
            threads_per_bubble: 4,
            light_work: 4_000_000,
            heavy_factor: 3.0,
            chunks: 8,
            mem_fraction: 0.15,
        }
    }
}

impl SkewParams {
    /// Threads per NUMA-node group.
    pub fn threads_per_group(&self) -> usize {
        self.bubbles_per_node * self.threads_per_bubble
    }
}

/// Build the skewed-groups workload (bubble structure:
/// `bubbles_per_node` bubbles per node, node 0's bubbles heavy).
/// Returns the thread ids.
pub fn build_skewed(engine: &mut SimEngine, p: &SkewParams) -> Vec<TaskId> {
    let n_nodes = engine.sys.topo.n_numa().max(2);
    let sys = engine.sys.clone();
    let m = Marcel::with_system(&sys);
    let root = m.bubble_init_with(
        crate::task::BurstLevel::Immediate,
        crate::task::PRIO_BUBBLE,
    );
    let mut threads = Vec::new();
    for node in 0..n_nodes {
        for b in 0..p.bubbles_per_node {
            let bubble = m.bubble_init();
            // The heavy group holds more threads — the imbalance a
            // purely affinity-driven distribution cannot absorb,
            // because the whole bubble lands on one node.
            let n_threads = if node == 0 {
                (p.threads_per_bubble as f64 * p.heavy_factor) as usize
            } else {
                p.threads_per_bubble
            };
            for k in 0..n_threads {
                let t = m.create_dontsched(format!("skew-n{node}-b{b}-t{k}"));
                m.bubble_inserttask(bubble, t);
                let r = engine.alloc_region();
                m.attach_region(t, r);
                let mut prog = Program::new();
                for _ in 0..p.chunks {
                    prog = prog.compute(
                        p.light_work / p.chunks as u64,
                        p.mem_fraction,
                        Some(r),
                    );
                }
                engine.set_program(t, prog);
                threads.push(t);
            }
            m.bubble_insertbubble(root, bubble);
        }
    }
    engine.wake(root);
    threads
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::StructureMode::*;

    #[test]
    fn work_table_is_deterministic_and_imbalanced() {
        let p = AmrParams::default();
        let a = work_table(&p);
        let b = work_table(&p);
        assert_eq!(a, b);
        // Within one cycle, max/min across stripes must be skewed.
        let col: Vec<u64> = (0..p.threads).map(|i| a[i][0]).collect();
        let max = *col.iter().max().unwrap() as f64;
        let min = *col.iter().min().unwrap() as f64;
        assert!(max / min > 2.0, "imbalance too mild: {max}/{min}");
    }

    #[test]
    fn redraw_changes_pattern() {
        let p = AmrParams::default();
        let t = work_table(&p);
        let before: Vec<u64> = (0..p.threads).map(|i| t[i][0]).collect();
        let after: Vec<u64> = (0..p.threads).map(|i| t[i][p.redraw_every]).collect();
        assert_ne!(before, after);
    }

    #[test]
    fn all_modes_complete() {
        let topo = Topology::numa(2, 2);
        let p = AmrParams { threads: 4, cycles: 8, redraw_every: 4, ..Default::default() };
        for mode in [Simple, Bound, Bubbles] {
            assert!(run(&topo, mode, &p).total_time > 0, "{mode:?}");
        }
    }

    #[test]
    fn shared_coarse_mesh_is_striped_and_conserved() {
        let topo = Topology::numa(2, 2);
        let p = AmrParams { threads: 4, cycles: 6, redraw_every: 3, ..Default::default() };
        let mut e = crate::apps::engine_for(&topo, Bubbles);
        let (threads, mesh) = build_with_shared_mesh(&mut e, Bubbles, &p, 4 << 20);
        e.run().unwrap();
        let info = e.sys.mem.info(mesh);
        assert_eq!(info.stripes.len(), 2, "one stripe per NUMA node");
        assert!(info.touches >= (p.threads * p.cycles) as u64);
        assert!(e.sys.mem.conserved(&e.sys.tasks));
        assert!(e.sys.mem.hierarchy_consistent(&e.sys.tasks));
        assert_eq!(threads.len(), p.threads);
    }

    #[test]
    fn native_builder_runs_imbalanced_stripes_under_both_structures() {
        use crate::sched::{BubbleConfig, BubbleScheduler, System};
        use std::sync::Arc;
        let p = AmrParams { threads: 4, cycles: 4, redraw_every: 2, ..Default::default() };
        for mode in [Simple, Bubbles] {
            let sys = Arc::new(System::new(Arc::new(Topology::numa(2, 2))));
            let sched = Arc::new(BubbleScheduler::new(BubbleConfig::default()));
            let mut ex = crate::exec::Executor::new(sys.clone(), sched);
            let threads =
                build_native(&mut ex, mode, &p, crate::mem::AllocPolicy::FirstTouch, 2);
            ex.run();
            for &t in &threads {
                assert_eq!(sys.tasks.state(t), crate::task::TaskState::Terminated, "{mode:?}");
            }
            // At least one touch per stripe per cycle, all attributed.
            assert!(
                sys.mem.regions.total_touches() >= (p.threads * p.cycles) as u64,
                "{mode:?}"
            );
            assert!(sys.mem.conserved(&sys.tasks), "{mode:?}");
            let parented = threads.iter().filter(|&&t| sys.tasks.parent(t).is_some()).count();
            match mode {
                Bubbles => assert_eq!(parented, p.threads),
                _ => assert_eq!(parented, 0),
            }
        }
    }

    #[test]
    fn imbalance_erodes_the_bound_advantage() {
        // On the balanced conduction workload Bound dominates Simple by
        // a wide margin (Table 2). Under AMR imbalance, pinning loses
        // part of that advantage: the simple/bound ratio must shrink.
        let topo = Topology::numa(4, 4);
        let p = AmrParams { cycles: 12, redraw_every: 3, shape: 2.5, ..Default::default() };
        let bound = run(&topo, Bound, &p).total_time as f64;
        let simple = run(&topo, Simple, &p).total_time as f64;
        let ratio_amr = simple / bound;

        let hp = crate::apps::conduction::HeatParams {
            threads: 32,
            cycles: 12,
            work: 800_000,
            mem_fraction: 0.15,
        };
        let bound_c = crate::apps::conduction::run(&topo, Bound, &hp).total_time as f64;
        let simple_c = crate::apps::conduction::run(&topo, Simple, &hp).total_time as f64;
        let ratio_balanced = simple_c / bound_c;
        assert!(
            ratio_amr < ratio_balanced,
            "pinning advantage should erode under imbalance: \
             amr {ratio_amr:.2} vs balanced {ratio_balanced:.2}"
        );
    }

    #[test]
    fn skewed_groups_complete() {
        let topo = Topology::numa(2, 2);
        let p = SkewParams {
            bubbles_per_node: 1,
            threads_per_bubble: 2,
            heavy_factor: 3.0,
            ..Default::default()
        };
        let mut e = crate::apps::engine_for(&topo, Bubbles);
        let threads = build_skewed(&mut e, &p);
        assert_eq!(threads.len(), 8); // 6 heavy + 2 light on 2 nodes
        assert!(e.run().unwrap().total_time > 0);
    }
}
