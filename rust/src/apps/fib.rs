//! Divide-and-conquer fibonacci test-case (paper §5.1, Figure 5).
//!
//! "Test-case examples of recursive creation of threads, such as
//! divide-and-conquer Fibonacci show that the cost of systematically
//! adding bubbles that express the natural recursion of threads
//! creations is quickly balanced by the localization that they bring."
//!
//! The *total* problem size is fixed; sweeping the thread count makes
//! the per-thread granularity finer (a lower recursion cutoff in the
//! paper's code), which is precisely what makes the classical
//! opportunist scheduler bleed: more migrations, more remote/cache-cold
//! accesses, while bubbles keep each sibling pair together.
//!
//! Each internal node spawns two children that both work on a *pair
//! region* allocated by their parent (the shared sub-problem data).
//! With bubbles, each pair is wrapped in a bubble bursting one level
//! above the leaves (physical chip on the HT Xeon, NUMA node on the
//! NovaScale) and the pair is declared SMT-*symbiotic* (§3.1) — the
//! application expressing that the two threads can share a physical
//! core without interfering. The classical baseline (AFS per-CPU lists
//! + steal) receives no structure, as in the paper.
//!
//! Gain = `(t_classic − t_bubble) / t_classic`, plotted in Figure 5.

use std::sync::Arc;

use crate::marcel::Marcel;
use crate::sched::{BubbleConfig, BubbleScheduler};
use crate::sim::{Program, RegionId, SimConfig, SimEngine};
use crate::task::{BurstLevel, TaskId, PRIO_THREAD};
use crate::topology::Topology;

/// Fibonacci workload parameters.
#[derive(Debug, Clone)]
pub struct FibParams {
    /// Spawn-tree depth: `2^(depth+1) − 1` threads in total.
    pub depth: usize,
    /// Total compute cycles across all leaves (fixed problem size).
    pub total_leaf_work: u64,
    /// Total compute cycles across all internal nodes.
    pub total_node_work: u64,
    /// Memory-bound fraction (sibling-shared pair region).
    pub mem_fraction: f64,
    /// Lower bound on any single chunk (models the recursion cutoff).
    pub min_chunk: u64,
}

impl Default for FibParams {
    fn default() -> Self {
        FibParams {
            depth: 4,
            total_leaf_work: 24_000_000,
            total_node_work: 6_000_000,
            mem_fraction: 0.5,
            min_chunk: 10_000,
        }
    }
}

impl FibParams {
    /// Threads produced by this tree.
    pub fn n_threads(&self) -> usize {
        (1 << (self.depth + 1)) - 1
    }

    /// Leaves in the tree.
    pub fn n_leaves(&self) -> usize {
        1 << self.depth
    }

    /// Per-leaf compute (total work split across leaves).
    pub fn leaf_work(&self) -> u64 {
        (self.total_leaf_work / self.n_leaves() as u64).max(self.min_chunk)
    }

    /// Per-internal-node compute.
    pub fn node_work(&self) -> u64 {
        let internal = (self.n_threads() - self.n_leaves()) as u64;
        (self.total_node_work / internal.max(1)).max(self.min_chunk)
    }

    /// Smallest depth whose tree reaches `n` threads.
    pub fn depth_for_threads(n: usize) -> usize {
        let mut d = 0;
        while ((1usize << (d + 1)) - 1) < n {
            d += 1;
        }
        d
    }
}

/// Build one node of the spawn tree (post-order: children first).
/// Returns the node's thread id.
fn build_node(
    engine: &mut SimEngine,
    marcel: Option<&Marcel>,
    p: &FibParams,
    level: usize,
    pair_region: RegionId,
    pair_burst: BurstLevel,
) -> TaskId {
    if level == p.depth {
        // Leaf: pure compute on the pair region shared with the sibling.
        return engine.add_thread(
            format!("fib-leaf-{level}"),
            PRIO_THREAD,
            Program::new().compute(p.leaf_work(), p.mem_fraction, Some(pair_region)),
        );
    }
    // Internal node: its children share a fresh pair region.
    let child_region = engine.alloc_region();
    let left = build_node(engine, marcel, p, level + 1, child_region, pair_burst);
    let right = build_node(engine, marcel, p, level + 1, child_region, pair_burst);

    // With bubbles, the pair is wrapped so the scheduler keeps it
    // together and declared symbiotic (SMT relation, §3.1); the parent
    // wakes the bubble instead of the threads.
    let wake_target: Vec<TaskId> = match marcel {
        Some(m) => {
            let b = m.bubble_init_with(pair_burst, crate::task::PRIO_BUBBLE);
            m.bubble_inserttask(b, left);
            m.bubble_inserttask(b, right);
            m.set_symbiotic(left, right);
            vec![b]
        }
        None => vec![left, right],
    };

    let nw = p.node_work();
    let mut prog = Program::new().compute(nw / 2, p.mem_fraction, Some(pair_region));
    for &w in &wake_target {
        prog = prog.wake(w);
    }
    prog = prog
        .join(left)
        .join(right)
        .compute(nw / 2, p.mem_fraction, Some(pair_region));
    engine.add_thread(format!("fib-node-{level}"), PRIO_THREAD, prog)
}

/// Build the whole tree into `engine`; returns the root thread.
pub fn build(engine: &mut SimEngine, with_bubbles: bool, p: &FibParams) -> TaskId {
    let root_region = engine.alloc_region();
    let pair_burst = pair_burst_level(&engine.sys.topo);
    let root = if with_bubbles {
        let sys = engine.sys.clone();
        let m = Marcel::with_system(&sys);
        build_node(engine, Some(&m), p, 0, root_region, pair_burst)
    } else {
        build_node(engine, None, p, 0, root_region, pair_burst)
    };
    engine.wake(root);
    root
}

/// Pair bubbles burst one level above the leaves: the smallest
/// component still covering several CPUs (physical chip on the HT
/// Xeon, NUMA node on the NovaScale).
pub fn pair_burst_level(topo: &Topology) -> BurstLevel {
    BurstLevel::Depth(topo.depth().saturating_sub(2))
}

/// Run fib on `topo`; `with_bubbles` picks bubble scheduler + bubbles
/// vs AFS + loose threads. Returns the makespan.
pub fn run(topo: &Topology, with_bubbles: bool, p: &FibParams) -> u64 {
    let sched: Arc<dyn crate::sched::Scheduler> = if with_bubbles {
        Arc::new(BubbleScheduler::new(BubbleConfig {
            default_burst: pair_burst_level(topo),
            ..BubbleConfig::default()
        }))
    } else {
        crate::sched::factory::make_default(crate::config::SchedKind::Afs)
    };
    let mut e = super::engine_with(topo, sched, SimConfig::default());
    build(&mut e, with_bubbles, p);
    e.run().expect("fib run").total_time
}

/// Figure-5 data point: gain (%) of bubbles over the classical
/// scheduler for a given thread count (fixed total problem size).
pub fn gain_percent(topo: &Topology, n_threads: usize, p_base: &FibParams) -> f64 {
    let p = FibParams { depth: FibParams::depth_for_threads(n_threads), ..p_base.clone() };
    let t_classic = run(topo, false, &p);
    let t_bubble = run(topo, true, &p);
    100.0 * (t_classic as f64 - t_bubble as f64) / t_classic as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_count_math() {
        assert_eq!(FibParams { depth: 3, ..Default::default() }.n_threads(), 15);
        assert_eq!(FibParams::depth_for_threads(2), 1);
        assert_eq!(FibParams::depth_for_threads(16), 4);
        assert_eq!(FibParams::depth_for_threads(512), 9);
    }

    #[test]
    fn work_scales_down_with_depth() {
        let shallow = FibParams { depth: 2, ..Default::default() };
        let deep = FibParams { depth: 6, ..Default::default() };
        assert!(deep.leaf_work() < shallow.leaf_work());
        // Total stays roughly constant (up to the min-chunk floor).
        let total = |p: &FibParams| p.leaf_work() * p.n_leaves() as u64;
        let ratio = total(&deep) as f64 / total(&shallow) as f64;
        assert!((0.8..1.2).contains(&ratio), "{ratio}");
    }

    #[test]
    fn both_modes_complete() {
        let topo = Topology::numa(2, 2);
        let p = FibParams { depth: 3, ..Default::default() };
        assert!(run(&topo, false, &p) > 0);
        assert!(run(&topo, true, &p) > 0);
    }

    #[test]
    fn deterministic() {
        let topo = Topology::numa(2, 2);
        let p = FibParams { depth: 3, ..Default::default() };
        assert_eq!(run(&topo, true, &p), run(&topo, true, &p));
    }

    #[test]
    fn bubbles_gain_on_numa_with_enough_threads() {
        // Figure 5(b): on the NUMA machine the gain is clearly positive
        // once the tree is deep enough to cover the machine.
        let topo = Topology::numa(4, 4);
        let g = gain_percent(&topo, 64, &FibParams::default());
        assert!(g > 5.0, "expected positive gain, got {g:.1}%");
    }

    #[test]
    fn pair_burst_levels() {
        assert_eq!(pair_burst_level(&Topology::xeon_2x_ht()), BurstLevel::Depth(1));
        assert_eq!(pair_burst_level(&Topology::numa(4, 4)), BurstLevel::Depth(1));
        assert_eq!(pair_burst_level(&Topology::deep()), BurstLevel::Depth(3));
    }
}
