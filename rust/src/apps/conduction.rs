//! Heat-conduction simulation workload (paper §5.2, Table 2).
//!
//! "The applications perform cycles of fully parallel computing
//! followed by global hierarchical communication barrier." The mesh is
//! split into as many stripes as threads; each stripe's data is homed
//! by first touch; every cycle each thread computes its stripe and all
//! threads synchronise.
//!
//! The *Bubbles* variant queries the topology and builds one bubble per
//! NUMA node (4 bubbles × 4 threads on the paper's NovaScale).

use crate::marcel::Marcel;
use crate::sim::{Program, SimEngine, SimReport};
use crate::task::{TaskId, PRIO_THREAD};

use super::StructureMode;

/// Bytes of mesh data per stripe, declared to the region registry so
/// footprint accounting (and the `memaware` policy) can see the data
/// each thread works on.
pub const STRIPE_BYTES: u64 = 4 << 20;

/// Stripe-cycle workload parameters.
#[derive(Debug, Clone)]
pub struct HeatParams {
    /// Number of stripes (= threads). The paper uses one per CPU.
    pub threads: usize,
    /// Barrier cycles.
    pub cycles: usize,
    /// Compute cycles per stripe per barrier cycle.
    pub work: u64,
    /// Memory-bound fraction of the stripe compute.
    pub mem_fraction: f64,
}

impl HeatParams {
    /// Table-2 conduction: heavy, long run (sequential 250.2 s).
    pub fn conduction() -> HeatParams {
        HeatParams { threads: 16, cycles: 60, work: 2_000_000, mem_fraction: 0.35 }
    }

    /// Table-2 advection: same structure, far less work per cycle
    /// (sequential 16.13 s) so fixed costs weigh more.
    pub fn advection() -> HeatParams {
        HeatParams { threads: 16, cycles: 40, work: 190_000, mem_fraction: 0.35 }
    }
}

/// Bytes of the globally shared mesh frame (halo cells, global index
/// tables) declared as one *striped* region over every NUMA node by
/// [`build_with_shared_mesh`].
pub const SHARED_MESH_BYTES: u64 = 8 << 20;

/// Consulting the shared mesh costs `work / MESH_SLICE_DIV` of each
/// cycle's compute (shared by conduction and the AMR coarse mesh).
pub const MESH_SLICE_DIV: u64 = 8;

/// Declare one striped region spanning every NUMA node of the engine's
/// machine — the shared-mesh layout conduction and amr both use.
pub(crate) fn alloc_all_node_striped(
    engine: &mut SimEngine,
    bytes: u64,
) -> crate::mem::RegionId {
    let nodes: Vec<usize> = (0..engine.sys.topo.n_numa().max(1)).collect();
    engine.alloc_region_striped(bytes, &nodes)
}

/// Build the striped workload into `engine` under the given structure
/// mode. Returns the thread ids.
pub fn build(engine: &mut SimEngine, mode: StructureMode, p: &HeatParams) -> Vec<TaskId> {
    build_with_policy(engine, mode, p, crate::sim::AllocPolicy::FirstTouch)
}

/// Build with an explicit memory allocation policy (§2.3 ablation).
pub fn build_with_policy(
    engine: &mut SimEngine,
    mode: StructureMode,
    p: &HeatParams,
    policy: crate::sim::AllocPolicy,
) -> Vec<TaskId> {
    build_inner(engine, mode, p, policy, None)
}

/// Build like [`build`], plus one **striped** region spread over every
/// NUMA node — the globally shared mesh frame no single stripe owns —
/// that every thread touches each cycle (a small slice of the cycle's
/// work). Returns the thread ids and the mesh region. The mesh is left
/// unattached: shared data belongs to no one thread's footprint, but
/// its touches still rotate over the stripes and count in the
/// local/remote metrics.
pub fn build_with_shared_mesh(
    engine: &mut SimEngine,
    mode: StructureMode,
    p: &HeatParams,
    mesh_bytes: u64,
) -> (Vec<TaskId>, crate::mem::RegionId) {
    let mesh = alloc_all_node_striped(engine, mesh_bytes);
    let threads = build_inner(engine, mode, p, crate::sim::AllocPolicy::FirstTouch, Some(mesh));
    (threads, mesh)
}

fn build_inner(
    engine: &mut SimEngine,
    mode: StructureMode,
    p: &HeatParams,
    policy: crate::sim::AllocPolicy,
    mesh: Option<crate::mem::RegionId>,
) -> Vec<TaskId> {
    let barrier = engine.alloc_barrier(p.threads);
    let regions: Vec<_> = (0..p.threads)
        .map(|_| engine.alloc_region_sized(STRIPE_BYTES, policy))
        .collect();
    let program = |r| {
        let mut prog = Program::new();
        for _ in 0..p.cycles {
            prog = prog.compute(p.work, p.mem_fraction, Some(r));
            if let Some(mesh) = mesh {
                let slice = (p.work / MESH_SLICE_DIV).max(1);
                prog = prog.compute(slice, p.mem_fraction, Some(mesh));
            }
            prog = prog.barrier(barrier);
        }
        prog
    };
    match mode {
        StructureMode::Simple | StructureMode::Bound => {
            // Loose threads; the scheduler decides everything. Each
            // stripe is declared as the thread's region so the
            // footprint accounting knows whose data it is.
            let mut out = Vec::with_capacity(p.threads);
            for (i, &r) in regions.iter().enumerate() {
                let t = engine.add_thread(format!("stripe{i}"), PRIO_THREAD, program(r));
                engine.attach_region(t, r);
                engine.wake(t);
                out.push(t);
            }
            out
        }
        StructureMode::Bubbles => {
            // Figure-4 style: query the machine, group stripes into one
            // bubble per NUMA node, wake the root bubble.
            let sys = engine.sys.clone();
            let m = Marcel::with_system(&sys);
            let names: Vec<String> = (0..p.threads).map(|i| format!("stripe{i}")).collect();
            let (root, threads) = m.bubbles_from_topology(&names);
            for (&t, &r) in threads.iter().zip(regions.iter()) {
                engine.set_program(t, program(r));
                m.attach_region(t, r);
            }
            engine.wake(root);
            threads
        }
    }
}

/// Build the striped workload as real green threads on the native
/// executor, under the same **structure axis** as the simulator
/// builder: `Simple`/`Bound` spawn loose threads, `Bubbles` queries
/// the machine through [`Marcel::bubbles_from_topology`] and groups
/// the stripes into one bubble per NUMA node — the Figure-4 shape on
/// real OS workers. Stripe regions are homed per `policy` and attached
/// per thread either way, so footprint-driven policies see the same
/// declarations on both engines. Each cycle every thread records
/// `touches` region touches through [`crate::exec::GreenApi`] with a
/// yield between them, so scheduling decisions — and their memory
/// consequences — happen mid-cycle exactly as in the simulator.
/// Threads (or the root bubble) are registered and woken; the caller
/// runs the executor.
pub fn build_native(
    ex: &mut crate::exec::Executor,
    mode: StructureMode,
    p: &HeatParams,
    policy: crate::mem::AllocPolicy,
    touches: usize,
) -> Vec<TaskId> {
    let sys = ex.system().clone();
    let bar = ex.alloc_barrier(p.threads);
    let cycles = p.cycles;
    let touches = touches.max(1);
    let regions: Vec<_> = (0..p.threads).map(|_| sys.mem.alloc(STRIPE_BYTES, policy)).collect();
    let body = move |r: crate::mem::RegionId| {
        move |api: crate::exec::GreenApi| {
            for _ in 0..cycles {
                for _ in 0..touches {
                    api.touch_region(r);
                    api.yield_now();
                }
                api.barrier(bar);
            }
        }
    };
    match mode {
        StructureMode::Simple | StructureMode::Bound => {
            // Loose green threads; the scheduler decides everything
            // (there is no native pinning, so Bound degrades to Simple).
            let mut out = Vec::with_capacity(p.threads);
            for (i, &r) in regions.iter().enumerate() {
                let t = sys.tasks.new_thread(format!("stripe{i}"), PRIO_THREAD);
                sys.mem.attach(&sys.tasks, t, r);
                ex.register(t, body(r));
                out.push(t);
            }
            for &t in &out {
                ex.wake(t);
            }
            out
        }
        StructureMode::Bubbles => {
            // Figure-4 style, natively: one bubble per NUMA node, the
            // root woken through the executor's scheduler (opportunist
            // policies flatten it; the bubble scheduler descends it).
            let m = Marcel::with_system(&sys);
            let names: Vec<String> = (0..p.threads).map(|i| format!("stripe{i}")).collect();
            let (root, threads) = m.bubbles_from_topology(&names);
            for (&t, &r) in threads.iter().zip(regions.iter()) {
                m.attach_region(t, r);
                ex.register(t, body(r));
            }
            ex.wake(root);
            threads
        }
    }
}

/// Sequential baseline: one thread computes all stripes, no barriers.
pub fn build_sequential(engine: &mut SimEngine, p: &HeatParams) -> TaskId {
    let regions: Vec<_> = (0..p.threads)
        .map(|_| engine.alloc_region_sized(STRIPE_BYTES, crate::sim::AllocPolicy::FirstTouch))
        .collect();
    let mut prog = Program::new();
    for _ in 0..p.cycles {
        for &r in &regions {
            prog = prog.compute(p.work, p.mem_fraction, Some(r));
        }
    }
    let t = engine.add_thread("sequential", PRIO_THREAD, prog);
    for &r in &regions {
        engine.attach_region(t, r);
    }
    engine.wake(t);
    t
}

/// Run one Table-2 row; returns the simulated makespan.
pub fn run(topo: &crate::topology::Topology, mode: StructureMode, p: &HeatParams) -> SimReport {
    let mut e = super::engine_for(topo, mode);
    build(&mut e, mode, p);
    e.run().expect("conduction run")
}

/// Run the sequential row.
pub fn run_sequential(topo: &crate::topology::Topology, p: &HeatParams) -> SimReport {
    // The scheduler is irrelevant for one thread; use Bound to pin it.
    let mut e = super::engine_for(topo, StructureMode::Bound);
    build_sequential(&mut e, p);
    e.run().expect("sequential run")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::StructureMode::*;
    use crate::topology::Topology;

    fn small() -> HeatParams {
        HeatParams { threads: 8, cycles: 6, work: 200_000, mem_fraction: 0.35 }
    }

    #[test]
    fn all_modes_complete() {
        let topo = Topology::numa(2, 4);
        for mode in [Simple, Bound, Bubbles] {
            let rep = run(&topo, mode, &small());
            assert!(rep.total_time > 0, "{mode:?}");
        }
    }

    #[test]
    fn parallel_beats_sequential() {
        let topo = Topology::numa(2, 4);
        let seq = run_sequential(&topo, &small()).total_time;
        let par = run(&topo, Bound, &small()).total_time;
        let speedup = seq as f64 / par as f64;
        assert!(speedup > 4.0, "speedup {speedup}");
    }

    #[test]
    fn bound_and_bubbles_beat_simple() {
        // The Table-2 shape: affinity-preserving schedules win.
        let topo = Topology::numa(4, 4);
        let p = HeatParams { threads: 16, cycles: 10, work: 500_000, mem_fraction: 0.35 };
        let simple = run(&topo, Simple, &p).total_time;
        let bound = run(&topo, Bound, &p).total_time;
        let bubbles = run(&topo, Bubbles, &p).total_time;
        assert!(bound < simple, "bound {bound} vs simple {simple}");
        assert!(bubbles < simple, "bubbles {bubbles} vs simple {simple}");
        // Bubbles within 15% of handmade binding (paper: 15.84 vs 15.82 s).
        let gap = bubbles as f64 / bound as f64;
        assert!(gap < 1.15, "bubbles/bound = {gap}");
    }

    #[test]
    fn bubbles_mode_keeps_accesses_local() {
        let topo = Topology::numa(4, 4);
        let p = small();
        let mut e = crate::apps::engine_for(&topo, Bubbles);
        build(&mut e, Bubbles, &p);
        e.run().unwrap();
        let ratio = e.sys.metrics.remote_ratio();
        assert!(ratio < 0.2, "remote ratio {ratio} too high for bubbles");
    }

    #[test]
    fn stripes_are_attached_and_conserved() {
        let topo = Topology::numa(2, 2);
        let p = small();
        let mut e = crate::apps::engine_for(&topo, Bubbles);
        let threads = build(&mut e, Bubbles, &p);
        e.run().unwrap();
        // Every stripe homed + attached: footprint conservation holds
        // and each thread knows where its data lives.
        assert!(e.sys.mem.conserved(&e.sys.tasks));
        for t in threads {
            assert!(e.sys.mem.dominant_node(t).is_some(), "{t} has no footprint");
        }
    }

    #[test]
    fn shared_mesh_is_striped_over_every_node_and_conserved() {
        let topo = Topology::numa(2, 2);
        let p = small();
        let mut e = crate::apps::engine_for(&topo, Bubbles);
        let (threads, mesh) = build_with_shared_mesh(&mut e, Bubbles, &p, SHARED_MESH_BYTES);
        e.run().unwrap();
        let info = e.sys.mem.info(mesh);
        assert_eq!(info.stripes.len(), 2, "one stripe per NUMA node");
        assert_eq!(info.stripes.iter().map(|s| s.size).sum::<u64>(), SHARED_MESH_BYTES);
        // Every thread touched the shared frame once per cycle.
        assert!(info.touches >= (p.threads * p.cycles) as u64);
        assert!(e.sys.mem.conserved(&e.sys.tasks));
        assert!(e.sys.mem.hierarchy_consistent(&e.sys.tasks));
        assert_eq!(threads.len(), p.threads);
    }

    #[test]
    fn native_builder_supports_both_structures() {
        use crate::sched::{BubbleConfig, BubbleScheduler, System};
        use std::sync::Arc;
        let p = HeatParams { threads: 8, cycles: 3, work: 0, mem_fraction: 0.0 };
        for mode in [Simple, Bubbles] {
            let sys = Arc::new(System::new(Arc::new(Topology::numa(2, 2))));
            let sched = Arc::new(BubbleScheduler::new(BubbleConfig::default()));
            let mut ex = crate::exec::Executor::new(sys.clone(), sched);
            let threads =
                build_native(&mut ex, mode, &p, crate::mem::AllocPolicy::FirstTouch, 2);
            ex.run();
            assert_eq!(threads.len(), p.threads, "{mode:?}");
            for &t in &threads {
                assert_eq!(sys.tasks.state(t), crate::task::TaskState::Terminated, "{mode:?}");
            }
            // Every green-thread touch went through the registry, and
            // the attached stripes conserve.
            assert_eq!(
                sys.mem.regions.total_touches(),
                (p.threads * p.cycles * 2) as u64,
                "{mode:?}"
            );
            assert!(sys.mem.conserved(&sys.tasks), "{mode:?}");
            // The structure axis is real: bubble mode nests the threads
            // under per-node bubbles, simple mode leaves them loose.
            let parented = threads.iter().filter(|&&t| sys.tasks.parent(t).is_some()).count();
            match mode {
                Bubbles => assert_eq!(parented, p.threads, "threads must sit in bubbles"),
                _ => assert_eq!(parented, 0, "loose threads must have no bubble"),
            }
        }
    }

    #[test]
    fn simple_mode_scatters_accesses() {
        let topo = Topology::numa(4, 4);
        let p = HeatParams { threads: 16, cycles: 10, work: 500_000, mem_fraction: 0.35 };
        let mut e = crate::apps::engine_for(&topo, Simple);
        build(&mut e, Simple, &p);
        e.run().unwrap();
        let ratio = e.sys.metrics.remote_ratio();
        assert!(ratio > 0.3, "SS should scatter accesses, got {ratio}");
    }
}
