//! Advection simulation workload (paper §5.2, Table 2, right half).
//!
//! Structurally identical to [`super::conduction`] — parallel stripes +
//! global barrier — but with far less compute per cycle (sequential
//! 16.13 s vs 250.2 s), so scheduling and synchronisation overheads
//! weigh more and speedups are lower across the board (paper: 12.40 vs
//! 15.82 for Bound).

use crate::sim::SimReport;
use crate::task::TaskId;
use crate::topology::Topology;

use super::conduction::{self, HeatParams};
use super::StructureMode;

/// Advection parameters (thin wrapper: the stripe/barrier structure is
/// shared with conduction, as in the paper).
pub fn params() -> HeatParams {
    HeatParams::advection()
}

/// Build into an engine.
pub fn build(
    engine: &mut crate::sim::SimEngine,
    mode: StructureMode,
    p: &HeatParams,
) -> Vec<TaskId> {
    conduction::build(engine, mode, p)
}

/// Build with the shared striped mesh frame (advection advects *one*
/// global field: see [`conduction::build_with_shared_mesh`]).
pub fn build_with_shared_mesh(
    engine: &mut crate::sim::SimEngine,
    mode: StructureMode,
    p: &HeatParams,
    mesh_bytes: u64,
) -> (Vec<TaskId>, crate::mem::RegionId) {
    conduction::build_with_shared_mesh(engine, mode, p, mesh_bytes)
}

/// Build as real green threads on the native executor under the same
/// structure axis as the simulator builder (loose threads vs one
/// bubble per NUMA node — see [`conduction::build_native`]).
pub fn build_native(
    ex: &mut crate::exec::Executor,
    mode: StructureMode,
    p: &HeatParams,
    policy: crate::mem::AllocPolicy,
    touches: usize,
) -> Vec<TaskId> {
    conduction::build_native(ex, mode, p, policy, touches)
}

/// Run one row.
pub fn run(topo: &Topology, mode: StructureMode, p: &HeatParams) -> SimReport {
    conduction::run(topo, mode, p)
}

/// Run the sequential row.
pub fn run_sequential(topo: &Topology, p: &HeatParams) -> SimReport {
    conduction::run_sequential(topo, p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::StructureMode::*;

    #[test]
    fn advection_speedups_below_conduction() {
        // Less work per barrier → relatively more overhead → lower
        // speedup (the Table-2 contrast between the two columns).
        let topo = Topology::numa(4, 4);
        let heavy = HeatParams { cycles: 8, ..HeatParams::conduction() };
        let light = HeatParams { cycles: 8, ..HeatParams::advection() };

        let su = |p: &HeatParams| {
            let seq = run_sequential(&topo, p).total_time as f64;
            let par = run(&topo, Bound, p).total_time as f64;
            seq / par
        };
        let su_heavy = su(&heavy);
        let su_light = su(&light);
        assert!(
            su_light < su_heavy,
            "advection speedup {su_light} should trail conduction {su_heavy}"
        );
        assert!(su_light > 6.0, "still a real speedup: {su_light}");
    }

    #[test]
    fn advection_runs_under_memaware_with_conserved_footprint() {
        use crate::config::SchedKind;
        use crate::sched::factory::make_default;
        let topo = crate::topology::Topology::numa(2, 2);
        let p = HeatParams { threads: 8, cycles: 6, ..HeatParams::advection() };
        let mut e = crate::apps::engine_with(
            &topo,
            make_default(SchedKind::Memaware),
            crate::sim::SimConfig::default(),
        );
        build(&mut e, Simple, &p);
        let rep = e.run().unwrap();
        assert!(rep.total_time > 0);
        assert!(e.sys.mem.conserved(&e.sys.tasks));
    }
}
