//! Application workloads from the paper's evaluation (§5).
//!
//! * [`conduction`] / [`advection`] — Pérache's heat-conduction and
//!   advection simulations (Table 2): parallel stripe compute +
//!   global barrier cycles, run as *Simple* / *Bound* / *Bubbles*.
//! * [`fib`] — the divide-and-conquer fibonacci test-case (Figure 5):
//!   recursive thread creation with and without structure-mirroring
//!   bubbles.
//! * [`amr`] — the paper's stated future workload (§5.2): Adaptive Mesh
//!   Refinement-like *imbalanced* stripes, exercising bubble
//!   regeneration.

pub mod advection;
pub mod amr;
pub mod conduction;
pub mod fib;

use std::sync::Arc;

use crate::config::SchedKind;
use crate::sched::factory::make_default;
use crate::sched::{BubbleConfig, BubbleScheduler, Scheduler, System};
use crate::sim::{CostModel, SimConfig, SimEngine};
use crate::topology::{DistanceModel, Topology};

/// How the application presents itself to the execution environment
/// (the three Table-2 rows besides Sequential).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StructureMode {
    /// Loose threads on an opportunist scheduler ("Simple").
    Simple,
    /// Threads explicitly pinned round-robin ("Bound", non-portable).
    Bound,
    /// Topology-mirroring bubbles on the bubble scheduler ("Bubbles").
    Bubbles,
}

impl StructureMode {
    pub fn label(&self) -> &'static str {
        match self {
            StructureMode::Simple => "Simple",
            StructureMode::Bound => "Bound",
            StructureMode::Bubbles => "Bubbles",
        }
    }
}

/// Build a ready-to-run engine for a structure mode on a machine:
/// Simple → SS, Bound → Bound, Bubbles → bubble scheduler.
pub fn engine_for(topo: &Topology, mode: StructureMode) -> SimEngine {
    engine_with(topo, scheduler_for(mode), SimConfig::default())
}

/// Scheduler used by each structure mode.
pub fn scheduler_for(mode: StructureMode) -> Arc<dyn Scheduler> {
    match mode {
        StructureMode::Simple => make_default(SchedKind::Ss),
        StructureMode::Bound => make_default(SchedKind::Bound),
        StructureMode::Bubbles => Arc::new(BubbleScheduler::new(BubbleConfig::default())),
    }
}

/// Engine over an explicit scheduler (ablations sweep these).
pub fn engine_with(topo: &Topology, sched: Arc<dyn Scheduler>, cfg: SimConfig) -> SimEngine {
    engine_with_model(topo, sched, cfg, DistanceModel::default())
}

/// Engine over an explicit scheduler *and* distance model (config-driven
/// runs price memory accesses with the machine's own model, asymmetric
/// matrices included).
pub fn engine_with_model(
    topo: &Topology,
    sched: Arc<dyn Scheduler>,
    cfg: SimConfig,
    dist: DistanceModel,
) -> SimEngine {
    let sys = Arc::new(System::new(Arc::new(topo.clone())));
    SimEngine::new(sys, sched, CostModel::new(dist), cfg)
}
