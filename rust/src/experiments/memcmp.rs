//! Local-vs-remote memory-access comparison harness.
//!
//! The paper's locality claims — and the `memaware` policy's reason to
//! exist — become a *reported number* here: run a memory-bound app
//! under several policies on the same machine and compare the
//! local-access ratio, steals, and next-touch migration traffic
//! (`repro memcmp` prints the table; the tests pin the ordering).
//!
//! The harness has an **engine axis**: [`run`] drives the simulator,
//! [`run_native`] the native executor — real OS workers running green
//! threads that record their region touches through `GreenApi`. Both
//! report the same [`MemRow`] shape (native makespans are wall
//! nanoseconds), so `repro memcmp --engine native` makes the memory
//! behaviour of the two engines directly comparable; its rows land in
//! `BENCH_mem_native.json`. Sim runs take an explicit `seed` and are
//! reproducible run-to-run (pinned by a test).
//!
//! The native leg additionally has a **structure axis**
//! ([`StructureMode`]): every policy runs the workload both as loose
//! green threads and as topology-mirroring bubbles
//! (`--structure simple|bubbles|both`), reproducing the paper's
//! structured-vs-flat comparison on real OS workers.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use super::harness;
use crate::apps::conduction::{self, HeatParams};
use crate::apps::{engine_with, StructureMode};
use crate::config::SchedKind;
use crate::error::{Error, Result};
use crate::exec::Executor;
use crate::mem::AllocPolicy;
use crate::sched::factory::make_default;
use crate::sched::System;
use crate::sim::SimConfig;
use crate::topology::Topology;
use crate::util::fmt::Table;

/// One policy's memory behaviour on the workload.
#[derive(Debug, Clone)]
pub struct MemRow {
    pub sched: String,
    /// Structure the application presented itself with
    /// ([`StructureMode::label`]): loose threads vs topology-mirroring
    /// bubbles — the paper's structured-vs-flat axis.
    pub structure: String,
    pub makespan: u64,
    /// Fraction of memory touches on the local node (higher = better).
    pub local_ratio: f64,
    pub steals: u64,
    pub mem_migrations: u64,
    pub migrated_bytes: u64,
    /// Timeslice preemptions delivered during the run (proof that
    /// `Scheduler::tick` is live on the engine that produced the row).
    pub preemptions: u64,
    /// Workers that pinned themselves to a detected OS CPU — non-zero
    /// only on the native engine with `--machine detect`.
    pub workers_pinned: u64,
    /// Workers whose `sched_setaffinity` was denied and who fell back
    /// to running unpinned (CI sandboxes commonly deny affinity).
    pub pin_failures: u64,
}

/// The comparison result.
#[derive(Debug, Clone)]
pub struct MemCmp {
    pub title: String,
    pub rows: Vec<MemRow>,
}

impl MemCmp {
    /// Row accessor by policy name — first matching row in structure
    /// order (panics on unknown name — harness misuse).
    pub fn get(&self, sched: &str) -> &MemRow {
        self.rows.iter().find(|r| r.sched == sched).expect("unknown policy row")
    }

    /// Row accessor by (policy, structure) pair — the native harness
    /// reports one row per point on the structure axis.
    pub fn get_structured(&self, sched: &str, structure: StructureMode) -> &MemRow {
        self.rows
            .iter()
            .find(|r| r.sched == sched && r.structure == structure.label())
            .expect("unknown (policy, structure) row")
    }

    pub fn render(&self) -> String {
        let mut t = Table::new(&[
            "policy",
            "structure",
            "makespan (Mcycles)",
            "local ratio",
            "steals",
            "mem migrations",
            "migrated MiB",
            "preemptions",
        ]);
        for r in &self.rows {
            t.row(&[
                r.sched.clone(),
                r.structure.clone(),
                format!("{:.2}", r.makespan as f64 / 1e6),
                format!("{:.3}", r.local_ratio),
                r.steals.to_string(),
                r.mem_migrations.to_string(),
                format!("{:.1}", r.migrated_bytes as f64 / (1u64 << 20) as f64),
                r.preemptions.to_string(),
            ]);
        }
        format!("== {} ==\n{}", self.title, t.render())
    }

    /// Structured harness rows for the artifact trail and the sweep
    /// runner: labels identify the cell, metrics carry the numbers.
    pub fn harness_rows(&self, engine: &str) -> Vec<harness::Row> {
        self.rows
            .iter()
            .map(|r| {
                harness::Row::new()
                    .label("engine", engine)
                    .label("policy", r.sched.clone())
                    .label("structure", r.structure.clone())
                    .int("makespan", r.makespan)
                    .float("local_ratio", r.local_ratio)
                    .int("steals", r.steals)
                    .int("mem_migrations", r.mem_migrations)
                    .int("migrated_bytes", r.migrated_bytes)
                    .int("preemptions", r.preemptions)
                    .int("workers_pinned", r.workers_pinned)
                    .int("pin_failures", r.pin_failures)
            })
            .collect()
    }
}

/// The `memcmp` experiment on the shared harness: `repro memcmp` and
/// sweep grid cells both run through here.
pub struct MemCmpExperiment;

const PARAMS: &[harness::ParamSpec] = &[
    harness::ParamSpec { key: "machine", help: "machine preset (default numa-4x4)" },
    harness::ParamSpec { key: "scheds", help: "comma-separated policy list" },
    harness::ParamSpec { key: "engine", help: "sim|native (default sim)" },
    harness::ParamSpec { key: "structure", help: "simple|bubbles|both (native only)" },
    harness::ParamSpec { key: "arena", help: "back regions with real mmap pages (native only)" },
    harness::ParamSpec { key: "seed", help: "sim engine seed" },
    harness::ParamSpec { key: "smoke", help: "small CI-sized run" },
    harness::ParamSpec { key: "trace", help: "write first-leg Chrome trace to this path" },
];

impl harness::Experiment for MemCmpExperiment {
    fn name(&self) -> &'static str {
        "memcmp"
    }

    fn param_schema(&self) -> &'static [harness::ParamSpec] {
        PARAMS
    }

    fn run(&self, args: &harness::Params) -> Result<harness::RunOutput> {
        let topo = args.machine()?;
        let kinds = args.kinds(default_kinds())?;
        let smoke = args.flag("smoke");
        let seed = args.u64_or("seed", SimConfig::default().seed);
        let trace_out = args.get("trace");
        let trace_note = match trace_out {
            Some(p) => format!("\nwrote first-leg Chrome trace to {p}"),
            None => String::new(),
        };
        // Oversubscribe the machine so rebalancing pressure is real:
        // that is where memory-blind policies scatter accesses.
        let p = HeatParams {
            threads: topo.n_cpus() + topo.n_cpus() / 2,
            cycles: if smoke { 4 } else { 20 },
            ..HeatParams::conduction()
        };
        match args.str_or("engine", "sim") {
            "sim" => {
                if args.get("structure").is_some() {
                    return Err(Error::config(
                        "--structure applies to --engine native only (the sim harness \
                         picks the structure per policy)"
                            .to_string(),
                    ));
                }
                if args.flag("arena") {
                    return Err(Error::config(
                        "--arena applies to --engine native only (the sim engine models \
                         memory, it does not touch real pages)"
                            .to_string(),
                    ));
                }
                let c = run(&topo, &p, &kinds, seed, trace_out);
                let text = format!(
                    "memory locality comparison on `{}` ({} stripes, {} cycles, seed {seed})\n\n{}{}",
                    topo.name(),
                    p.threads,
                    p.cycles,
                    c.render(),
                    trace_note
                );
                Ok(harness::RunOutput { text, rows: c.harness_rows("sim"), artifact: None })
            }
            "native" => {
                let touches = if smoke { 2 } else { 4 };
                let structure = args.str_or("structure", "both");
                let modes: Vec<StructureMode> = match structure {
                    "simple" => vec![StructureMode::Simple],
                    "bubbles" => vec![StructureMode::Bubbles],
                    "both" => vec![StructureMode::Simple, StructureMode::Bubbles],
                    other => {
                        return Err(Error::config(format!(
                            "unknown structure `{other}` (want simple|bubbles|both)"
                        )))
                    }
                };
                let c = run_native(
                    &topo,
                    &p,
                    &kinds,
                    touches,
                    AllocPolicy::FirstTouch,
                    args.flag("arena"),
                    &modes,
                    trace_out,
                );
                let rows = c.harness_rows("native");
                // No seed in the native artifact: native makespans are
                // wall clock and OS scheduling makes them run-to-run
                // noisy — a seed field would falsely promise
                // reproducibility. The structure axis lives on each
                // result row, and the detected shape rides along so the
                // CI detect leg can check the machine the workers
                // actually ran on.
                let artifact = harness::Artifact {
                    bench: "memcmp".to_string(),
                    mode: if smoke { "smoke" } else { "full" }.to_string(),
                    machine: topo.name().to_string(),
                    seed: None,
                    config: args.canonical(),
                    extras: vec![
                        ("engine".to_string(), "\"native\"".to_string()),
                        ("cpus".to_string(), topo.n_cpus().to_string()),
                        ("numa_nodes".to_string(), topo.n_numa().to_string()),
                        ("pinnable".to_string(), topo.os_cpus().is_some().to_string()),
                    ],
                    rows: rows.clone(),
                };
                let seed_note = if args.get("seed").is_some() {
                    "\nnote: --seed applies to the sim engine only; native makespans are wall-clock"
                } else {
                    ""
                };
                let text = format!(
                    "memory locality comparison on `{}` (native engine, {} green threads, {} cycles, structure {})\n\n{}{}{}",
                    topo.name(),
                    p.threads,
                    p.cycles,
                    structure,
                    c.render(),
                    seed_note,
                    trace_note
                );
                Ok(harness::RunOutput {
                    text,
                    rows,
                    artifact: Some(harness::ArtifactOut {
                        path: "BENCH_mem_native.json".to_string(),
                        artifact,
                    }),
                })
            }
            other => Err(Error::config(format!("unknown engine `{other}` (want sim|native)"))),
        }
    }
}

/// Policies compared by default: the memory-aware policy against the
/// paper's bubble scheduler and the strongest opportunist baselines.
pub fn default_kinds() -> Vec<SchedKind> {
    vec![SchedKind::Memaware, SchedKind::Bubble, SchedKind::Afs, SchedKind::Lds, SchedKind::Ss]
}

/// Write the first comparison leg's trace as a Chrome trace-event JSON
/// artifact. Only the first leg is traced: the point of `--trace` on a
/// comparison harness is one representative timeline, not N.
fn write_trace(trace: &crate::trace::Trace, topo: &Topology, path: &str, label: &str) {
    let recs = trace.drain();
    let json = crate::trace::export::chrome_json(&recs, topo.n_cpus(), label);
    std::fs::write(path, json).unwrap_or_else(|e| panic!("write trace {path}: {e}"));
}

/// Run the conduction workload under each policy on the simulator and
/// collect the memory behaviour. `seed` drives the engine's timing
/// jitter; two runs with the same seed are bit-identical. `trace_out`
/// writes the first leg's event stream as Chrome trace-event JSON.
pub fn run(
    topo: &Topology,
    p: &HeatParams,
    kinds: &[SchedKind],
    seed: u64,
    trace_out: Option<&str>,
) -> MemCmp {
    let mut rows = Vec::with_capacity(kinds.len());
    for (i, &kind) in kinds.iter().enumerate() {
        let mode = if kind == SchedKind::Bubble {
            StructureMode::Bubbles
        } else {
            StructureMode::Simple
        };
        let cfg = SimConfig { seed, ..SimConfig::default() };
        let mut e = engine_with(topo, make_default(kind), cfg);
        let traced = i == 0 && trace_out.is_some();
        if traced {
            e.sys.trace.set_enabled(true);
        }
        conduction::build(&mut e, mode, p);
        let rep = e.run().expect("memcmp run");
        if traced {
            let label = format!("memcmp sim/{} on {}", kind.label(), topo.name());
            write_trace(&e.sys.trace, topo, trace_out.unwrap(), &label);
        }
        debug_assert!(e.sys.mem.conserved(&e.sys.tasks), "footprint leak under {kind:?}");
        let m = &e.sys.metrics;
        rows.push(MemRow {
            sched: kind.label().to_string(),
            structure: mode.label().to_string(),
            makespan: rep.total_time,
            local_ratio: m.local_ratio(),
            steals: m.steals.load(Ordering::Relaxed),
            mem_migrations: m.mem_migrations.load(Ordering::Relaxed),
            migrated_bytes: m.migrated_bytes.load(Ordering::Relaxed),
            preemptions: m.preemptions.load(Ordering::Relaxed),
            workers_pinned: m.workers_pinned.load(Ordering::Relaxed),
            pin_failures: m.pin_failures.load(Ordering::Relaxed),
        });
    }
    MemCmp { title: format!("local vs remote accesses (conduction, {})", topo.name()), rows }
}

/// Run the conduction-shaped green-thread workload under each policy ×
/// structure mode on the **native executor** (real OS workers, fibers
/// recording their region touches through `GreenApi`) and collect the
/// same memory behaviour the sim harness reports. `makespan` is wall
/// nanoseconds here; `touches` is the number of touch+yield points per
/// barrier cycle and `policy` homes the stripe regions (first-touch
/// exercises native homing; round-robin pre-homes so placement quality
/// alone is measured). `modes` is the structure axis: `Simple` spawns
/// loose green threads, `Bubbles` builds one bubble per NUMA node
/// through `Marcel::bubbles_from_topology` — the paper's
/// structured-vs-flat comparison on real OS workers. `trace_out`
/// writes the first (policy, structure) leg's event stream as Chrome
/// trace-event JSON — with wall-clock timestamps, since the native
/// engine anchors `sys.now()` to a monotonic timer. `arena` backs each
/// region with a real `mmap` arena ([`crate::mem::ArenaSet`]) so every
/// `touch_region` also walks real bytes (`--arena`).
pub fn run_native(
    topo: &Topology,
    p: &HeatParams,
    kinds: &[SchedKind],
    touches: usize,
    policy: AllocPolicy,
    arena: bool,
    modes: &[StructureMode],
    trace_out: Option<&str>,
) -> MemCmp {
    let mut rows = Vec::with_capacity(kinds.len() * modes.len());
    let mut traced_legs = 0usize;
    for &kind in kinds {
        for &mode in modes {
            let sys = Arc::new(System::new(Arc::new(topo.clone())));
            if arena {
                sys.mem.enable_arenas();
            }
            let sched = make_default(kind);
            let mut ex = Executor::new(sys.clone(), sched);
            let traced = traced_legs == 0 && trace_out.is_some();
            traced_legs += 1;
            if traced {
                sys.trace.set_enabled(true);
            }
            conduction::build_native(&mut ex, mode, p, policy, touches);
            let rep = ex.run();
            if traced {
                let label =
                    format!("memcmp native/{}/{} on {}", kind.label(), mode.label(), topo.name());
                write_trace(&sys.trace, topo, trace_out.unwrap(), &label);
            }
            debug_assert!(
                sys.mem.conserved(&sys.tasks),
                "footprint leak under {kind:?}/{mode:?}"
            );
            let m = &sys.metrics;
            rows.push(MemRow {
                sched: kind.label().to_string(),
                structure: mode.label().to_string(),
                makespan: rep.elapsed.as_nanos() as u64,
                local_ratio: m.local_ratio(),
                steals: m.steals.load(Ordering::Relaxed),
                mem_migrations: m.mem_migrations.load(Ordering::Relaxed),
                migrated_bytes: m.migrated_bytes.load(Ordering::Relaxed),
                preemptions: m.preemptions.load(Ordering::Relaxed),
                workers_pinned: m.workers_pinned.load(Ordering::Relaxed),
                pin_failures: m.pin_failures.load(Ordering::Relaxed),
            });
        }
    }
    MemCmp {
        title: format!("local vs remote accesses (native conduction, {})", topo.name()),
        rows,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Oversubscribed stripes force rebalancing every cycle, which is
    /// exactly where memory-blind stealing scatters accesses.
    fn contended() -> HeatParams {
        HeatParams { threads: 24, cycles: 8, work: 400_000, mem_fraction: 0.35 }
    }

    const SEED: u64 = 0x5eed;

    #[test]
    fn memaware_beats_afs_on_locality() {
        // ISSUE-2 acceptance: strictly higher local-access ratio than
        // the AFS baseline on the numa(4,4) preset.
        let topo = Topology::numa(4, 4);
        let c = run(&topo, &contended(), &[SchedKind::Memaware, SchedKind::Afs], SEED, None);
        let ma = c.get("memaware");
        let afs = c.get("afs");
        assert!(ma.makespan > 0 && afs.makespan > 0);
        assert!(
            ma.local_ratio > afs.local_ratio,
            "memaware {:.3} must beat afs {:.3} on locality",
            ma.local_ratio,
            afs.local_ratio
        );
    }

    #[test]
    fn memaware_keeps_most_accesses_local() {
        let topo = Topology::numa(4, 4);
        let c = run(&topo, &contended(), &[SchedKind::Memaware], SEED, None);
        let ma = c.get("memaware");
        assert!(ma.local_ratio > 0.6, "local ratio {:.3} too low", ma.local_ratio);
    }

    #[test]
    fn render_lists_every_policy() {
        let topo = Topology::numa(2, 2);
        let p = HeatParams { threads: 4, cycles: 3, work: 200_000, mem_fraction: 0.35 };
        let c = run(&topo, &p, &default_kinds(), SEED, None);
        let out = c.render();
        for k in default_kinds() {
            assert!(out.contains(k.label()), "{} missing:\n{out}", k.label());
        }
        assert_eq!(c.harness_rows("sim").len(), default_kinds().len());
    }

    #[test]
    fn seeded_smoke_runs_reproduce_identical_makespans() {
        // ISSUE-4 satellite: the same CLI seed must reproduce the
        // BENCH numbers bit-for-bit, even within one process (the
        // wake-placement rotation is per system, not a global).
        let topo = Topology::numa(2, 2);
        let p = HeatParams { threads: 6, cycles: 3, work: 150_000, mem_fraction: 0.35 };
        let kinds = [SchedKind::Memaware, SchedKind::Afs, SchedKind::Ss];
        let spans = |c: &MemCmp| c.rows.iter().map(|r| r.makespan).collect::<Vec<_>>();
        let a = run(&topo, &p, &kinds, 7, None);
        let b = run(&topo, &p, &kinds, 7, None);
        assert_eq!(spans(&a), spans(&b), "same seed must reproduce identical makespans");
    }

    #[test]
    fn native_engine_attributes_touches() {
        // The native engine must report a non-trivial local ratio:
        // touches are attributed on real OS workers, locals + remotes
        // equal the registry's touch count.
        let topo = Topology::numa(2, 2);
        let p = HeatParams { threads: 6, cycles: 3, work: 0, mem_fraction: 0.0 };
        let c = run_native(
            &topo,
            &p,
            &[SchedKind::Memaware, SchedKind::Afs],
            2,
            AllocPolicy::FirstTouch,
            true, // arena-backed: every touch also walks real mmap'd bytes
            &[StructureMode::Simple],
            None,
        );
        for row in &c.rows {
            assert!(row.makespan > 0, "{}", row.sched);
            assert!(
                row.local_ratio > 0.0 && row.local_ratio <= 1.0,
                "{}: local ratio {:.3} not attributed",
                row.sched,
                row.local_ratio
            );
        }
    }

    #[test]
    fn native_structure_axis_reports_one_row_per_mode() {
        // Every (policy, structure) point gets its own row, reachable
        // through get_structured, and both structures complete.
        let topo = Topology::numa(2, 2);
        let p = HeatParams { threads: 6, cycles: 3, work: 0, mem_fraction: 0.0 };
        let kinds = [SchedKind::Bubble, SchedKind::Ss];
        let modes = [StructureMode::Simple, StructureMode::Bubbles];
        let c = run_native(&topo, &p, &kinds, 2, AllocPolicy::FirstTouch, false, &modes, None);
        assert_eq!(c.rows.len(), kinds.len() * modes.len());
        for kind in &kinds {
            for &mode in &modes {
                let row = c.get_structured(kind.label(), mode);
                assert!(row.makespan > 0, "{} {:?}", kind.label(), mode);
                assert!(row.local_ratio > 0.0, "{} {:?}", kind.label(), mode);
            }
        }
        let out = c.render();
        assert!(out.contains("Simple") && out.contains("Bubbles"), "{out}");
        for r in c.harness_rows("native") {
            let j = r.json();
            assert!(j.contains("\"structure\""), "{j}");
        }
    }
}
