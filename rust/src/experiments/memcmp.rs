//! Local-vs-remote memory-access comparison harness.
//!
//! The paper's locality claims — and the `memaware` policy's reason to
//! exist — become a *reported number* here: run a memory-bound app
//! under several policies on the same machine and compare the
//! local-access ratio, steals, and next-touch migration traffic
//! (`repro memcmp` prints the table; the tests pin the ordering).

use std::sync::atomic::Ordering;

use crate::apps::conduction::{self, HeatParams};
use crate::apps::{engine_with, StructureMode};
use crate::config::SchedKind;
use crate::sched::factory::make_default;
use crate::sim::SimConfig;
use crate::topology::Topology;
use crate::util::fmt::Table;

/// One policy's memory behaviour on the workload.
#[derive(Debug, Clone)]
pub struct MemRow {
    pub sched: String,
    pub makespan: u64,
    /// Fraction of memory touches on the local node (higher = better).
    pub local_ratio: f64,
    pub steals: u64,
    pub mem_migrations: u64,
    pub migrated_bytes: u64,
}

/// The comparison result.
#[derive(Debug, Clone)]
pub struct MemCmp {
    pub title: String,
    pub rows: Vec<MemRow>,
}

impl MemCmp {
    /// Row accessor by policy name (panics on unknown name — harness
    /// misuse).
    pub fn get(&self, sched: &str) -> &MemRow {
        self.rows.iter().find(|r| r.sched == sched).expect("unknown policy row")
    }

    pub fn render(&self) -> String {
        let mut t = Table::new(&[
            "policy",
            "makespan (Mcycles)",
            "local ratio",
            "steals",
            "mem migrations",
            "migrated MiB",
        ]);
        for r in &self.rows {
            t.row(&[
                r.sched.clone(),
                format!("{:.2}", r.makespan as f64 / 1e6),
                format!("{:.3}", r.local_ratio),
                r.steals.to_string(),
                r.mem_migrations.to_string(),
                format!("{:.1}", r.migrated_bytes as f64 / (1u64 << 20) as f64),
            ]);
        }
        format!("== {} ==\n{}", self.title, t.render())
    }
}

/// Policies compared by default: the memory-aware policy against the
/// paper's bubble scheduler and the strongest opportunist baselines.
pub fn default_kinds() -> Vec<SchedKind> {
    vec![SchedKind::Memaware, SchedKind::Bubble, SchedKind::Afs, SchedKind::Lds, SchedKind::Ss]
}

/// Run the conduction workload under each policy and collect the
/// memory behaviour.
pub fn run(topo: &Topology, p: &HeatParams, kinds: &[SchedKind]) -> MemCmp {
    let mut rows = Vec::with_capacity(kinds.len());
    for &kind in kinds {
        let mode = if kind == SchedKind::Bubble {
            StructureMode::Bubbles
        } else {
            StructureMode::Simple
        };
        let mut e = engine_with(topo, make_default(kind), SimConfig::default());
        conduction::build(&mut e, mode, p);
        let rep = e.run().expect("memcmp run");
        debug_assert!(e.sys.mem.conserved(&e.sys.tasks), "footprint leak under {kind:?}");
        let m = &e.sys.metrics;
        rows.push(MemRow {
            sched: kind.label().to_string(),
            makespan: rep.total_time,
            local_ratio: m.local_ratio(),
            steals: m.steals.load(Ordering::Relaxed),
            mem_migrations: m.mem_migrations.load(Ordering::Relaxed),
            migrated_bytes: m.migrated_bytes.load(Ordering::Relaxed),
        });
    }
    MemCmp { title: format!("local vs remote accesses (conduction, {})", topo.name()), rows }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Oversubscribed stripes force rebalancing every cycle, which is
    /// exactly where memory-blind stealing scatters accesses.
    fn contended() -> HeatParams {
        HeatParams { threads: 24, cycles: 8, work: 400_000, mem_fraction: 0.35 }
    }

    #[test]
    fn memaware_beats_afs_on_locality() {
        // ISSUE-2 acceptance: strictly higher local-access ratio than
        // the AFS baseline on the numa(4,4) preset.
        let topo = Topology::numa(4, 4);
        let c = run(&topo, &contended(), &[SchedKind::Memaware, SchedKind::Afs]);
        let ma = c.get("memaware");
        let afs = c.get("afs");
        assert!(ma.makespan > 0 && afs.makespan > 0);
        assert!(
            ma.local_ratio > afs.local_ratio,
            "memaware {:.3} must beat afs {:.3} on locality",
            ma.local_ratio,
            afs.local_ratio
        );
    }

    #[test]
    fn memaware_keeps_most_accesses_local() {
        let topo = Topology::numa(4, 4);
        let c = run(&topo, &contended(), &[SchedKind::Memaware]);
        let ma = c.get("memaware");
        assert!(ma.local_ratio > 0.6, "local ratio {:.3} too low", ma.local_ratio);
    }

    #[test]
    fn render_lists_every_policy() {
        let topo = Topology::numa(2, 2);
        let p = HeatParams { threads: 4, cycles: 3, work: 200_000, mem_fraction: 0.35 };
        let c = run(&topo, &p, &default_kinds());
        let out = c.render();
        for k in default_kinds() {
            assert!(out.contains(k.label()), "{} missing:\n{out}", k.label());
        }
    }
}
